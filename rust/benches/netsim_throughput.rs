//! L3/L5 hot-path microbenchmarks: the discrete-event simulator's event
//! rate, incremental vs full-oracle re-rating, max-min fair-share
//! recomputation, gossip planning, and the moderator's full M+O+S
//! computation — the pieces §Perf of EXPERIMENTS.md tracks.
//!
//! Emits one `JSON {...}` line per measurement; CI smoke-runs this bench
//! and uploads them as the `netsim-throughput` artifact.
//!
//! ```bash
//! cargo bench --bench netsim_throughput             # full iteration counts
//! cargo bench --bench netsim_throughput -- --smoke  # CI subset (fewer iters)
//! ```

use mosgu::bench::{bench, section, BenchResult};
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::gossip::GossipState;
use mosgu::coordinator::moderator::Moderator;
use mosgu::coordinator::session::GossipSession;
use mosgu::netsim::fairshare::max_min_rates;
use mosgu::netsim::testbed::Testbed;
use mosgu::netsim::NetSim;
use mosgu::util::rng::Pcg64;

/// One machine-readable line per measurement (`events` = simulator events
/// per closure run when the bench drives a DES; 0 for pure-CPU kernels).
fn emit(r: &BenchResult, events: u64) {
    let ev_per_s = if events > 0 { events as f64 / r.mean_s } else { 0.0 };
    println!(
        "JSON {{\"bench\":\"netsim_throughput\",\"name\":\"{}\",\"iters\":{},\
         \"mean_s\":{:.9},\"std_s\":{:.9},\"min_s\":{:.9},\
         \"per_sec\":{:.3},\"events\":{events},\"events_per_sec\":{:.1}}}",
        r.name,
        r.iters,
        r.mean_s,
        r.std_s,
        r.min_s,
        r.per_sec(),
        ev_per_s,
    );
}

/// The broadcast traffic pattern as a raw simulator: every ordered pair
/// of the testbed's nodes ships one 14 MB flow over its flat route — 90
/// concurrent flows contending on shared device links.
fn broadcast_sim(tb: &Testbed, full_rerate: bool) -> NetSim {
    let n = tb.node_count();
    let mut sim = tb.netsim(1);
    sim.set_full_rerate(full_rerate);
    for src in 0..n {
        for dst in 0..n {
            if src != dst {
                sim.start_flow(src, dst, tb.route(src, dst), 14.0, (src * n + dst) as u64);
            }
        }
    }
    sim
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = ExperimentConfig { latency_jitter: 0.0, ..Default::default() };
    // smoke mode trims warmup/iteration counts for CI wall-clock budget;
    // the measured quantities are identical
    let (w, it) = if smoke { (1u32, 5u32) } else { (3, 30) };
    let (w_big, it_big) = if smoke { (1u32, 3u32) } else { (1, 5) };

    section("fair-share allocation (full water-filling kernel)");
    let mut rng = Pcg64::new(1);
    for (nc, nf) in [(32usize, 100usize), (64, 500), (128, 2000)] {
        let caps: Vec<f64> = (0..nc).map(|_| rng.gen_f64_range(5.0, 50.0)).collect();
        let routes: Vec<Vec<usize>> = (0..nf)
            .map(|_| {
                let hops = 1 + rng.gen_range(3);
                (0..hops).map(|_| rng.gen_range(nc)).collect()
            })
            .collect();
        let r = bench(&format!("max_min_rates {nc}ch x {nf}flows"), w, it, || {
            max_min_rates(&caps, &routes)
        });
        println!("{}", r.report());
        emit(&r, 0);
    }

    section("DES event rate: incremental vs full-oracle re-rate");
    let tb = Testbed::new(&cfg);
    // events per drain is deterministic — count once, time separately
    let events_per_drain = {
        let mut sim = broadcast_sim(&tb, false);
        sim.run_until_idle();
        sim.counters().events
    };
    let r_inc = bench("drain 90 flows, incremental re-rate", w, it, || {
        let mut sim = broadcast_sim(&tb, false);
        sim.run_until_idle();
        sim.now()
    });
    println!("{}  ({:.0} events/s)", r_inc.report(), events_per_drain as f64 / r_inc.mean_s);
    emit(&r_inc, events_per_drain);
    let r_full = bench("drain 90 flows, full-rerate oracle", w, it, || {
        let mut sim = broadcast_sim(&tb, true);
        sim.run_until_idle();
        sim.now()
    });
    println!(
        "{}  ({:.0} events/s, incremental speedup {:.2}x)",
        r_full.report(),
        events_per_drain as f64 / r_full.mean_s,
        r_full.mean_s / r_inc.mean_s
    );
    emit(&r_full, events_per_drain);

    section("DES end-to-end: broadcast round (90 concurrent flows)");
    let r = bench("broadcast round N=10", w, it, || {
        mosgu::coordinator::broadcast::paper_baseline(&tb, 14.0, 1)
    });
    let round_events = mosgu::coordinator::broadcast::paper_baseline(&tb, 14.0, 1).sim.events;
    println!(
        "{}  ({:.0} rounds/s, {:.0} events/s)",
        r.report(),
        r.per_sec(),
        round_events as f64 / r.mean_s
    );
    emit(&r, round_events);

    section("gossip protocol planning (no DES)");
    let session = GossipSession::new(&cfg).expect("session");
    let tree = session.tree().clone();
    let sched = session.schedule().clone();
    let r = bench("full logical round N=10", w, if smoke { 10 } else { 100 }, || {
        let mut st = GossipState::new(tree.clone(), 0);
        for slot in 0..200 {
            if st.is_complete() {
                break;
            }
            let planned = st.plan_slot(&sched.transmitters(slot));
            for s in GossipState::sorted_sends(&planned) {
                st.deliver(s);
            }
        }
        st
    });
    println!("{}  ({:.0} rounds/s)", r.report(), r.per_sec());
    emit(&r, 0);

    section("moderator M+O+S computation (reports -> schedule)");
    let costs = session.costs().clone();
    let r = bench("moderator schedule N=10 complete", w, if smoke { 10 } else { 100 }, || {
        let mut m = Moderator::new(
            0,
            10,
            mosgu::mst::MstAlgorithm::Prim,
            mosgu::coloring::ColoringAlgorithm::Bfs,
        );
        for u in 0..10 {
            let peers: Vec<(usize, f64)> =
                costs.neighbors(u).iter().map(|&(v, w)| (v, w)).collect();
            m.submit_report(u, &peers);
        }
        m.compute_schedule(14.0, 56, 1).unwrap().tree.edge_count()
    });
    println!("{}", r.report());
    emit(&r, 0);

    section("timed MOSGU round through the DES");
    let r = bench("mosgu sim round N=10 (14MB)", w, it, || session.run_mosgu_round(14.0, 1, 0.0));
    let mosgu_events = session.run_mosgu_round(14.0, 1, 0.0).sim.events;
    println!(
        "{}  ({:.0} rounds/s, {:.0} events/s)",
        r.report(),
        r.per_sec(),
        mosgu_events as f64 / r.mean_s
    );
    emit(&r, mosgu_events);
    let r = bench("full Table cell (5 repeats b+p)", w_big, it_big, || {
        let mut b = mosgu::metrics::RepeatedMetrics::default();
        for rep in 0..5u64 {
            b.push(&session.run_broadcast_round(14.0, rep));
            b.push(&session.run_mosgu_round(14.0, rep, 0.0));
        }
        b
    });
    println!("{}", r.report());
    emit(&r, 0);
}
