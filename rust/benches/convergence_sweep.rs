//! Convergence sweep: the learning-dynamics scenario zoo (Dirichlet
//! non-IID shards, partial participation, stragglers, FedAvg vs D-PSGD,
//! compression) driven through the convergence harness — real engine
//! timing and reception orders, synthetic quadratic learning. Emits one
//! `JSON {...}` line per cell, each carrying the full accuracy-vs-round
//! (`acc_curve`) and accuracy-vs-wire-MB (`wire_curve`) trajectories; CI
//! uploads them as the `convergence-sweep` artifact.
//!
//! The full grid's gates are the PR's acceptance bar: every scenario
//! still learns (final eval beats round-0 eval), and quant-8 + error
//! feedback matches the uncompressed final eval loss within tolerance on
//! a Dirichlet non-IID scenario while moving strictly fewer wire bytes.
//!
//! ```bash
//! cargo bench --bench convergence_sweep             # full grid
//! cargo bench --bench convergence_sweep -- --smoke  # CI smoke subset
//! ```

use mosgu::bench::section;
use mosgu::config::ExperimentConfig;
use mosgu::dfl::compress::CompressionKind;
use mosgu::dfl::convergence::{run_convergence, ConvergenceOptions, ConvergenceReport};
use mosgu::dfl::data::AlgoKind;
use mosgu::graph::topology::TopologyKind;

/// One sweep cell: a named scenario-zoo configuration.
struct Cell {
    label: &'static str,
    cfg: ExperimentConfig,
}

fn base_cfg(topology: TopologyKind) -> ExperimentConfig {
    ExperimentConfig { topology, nodes: 10, latency_jitter: 0.0, ..Default::default() }
}

fn cells(topology: TopologyKind, smoke: bool) -> Vec<Cell> {
    let base = base_cfg(topology);
    let mut cells = vec![
        Cell { label: "baseline", cfg: base.clone() },
        Cell {
            label: "dirichlet-0.3",
            cfg: ExperimentConfig { dirichlet_alpha: 0.3, ..base.clone() },
        },
        Cell {
            label: "quant8",
            cfg: ExperimentConfig { compress: CompressionKind::Quant, ..base.clone() },
        },
    ];
    if !smoke {
        cells.extend([
            Cell {
                label: "dirichlet-1.0",
                cfg: ExperimentConfig { dirichlet_alpha: 1.0, ..base.clone() },
            },
            Cell {
                label: "dirichlet-0.1",
                cfg: ExperimentConfig { dirichlet_alpha: 0.1, ..base.clone() },
            },
            Cell {
                label: "participation-0.6",
                cfg: ExperimentConfig { participation: 0.6, ..base.clone() },
            },
            Cell {
                label: "stragglers",
                cfg: ExperimentConfig {
                    straggler_frac: 0.2,
                    straggler_slowdown: 4.0,
                    ..base.clone()
                },
            },
            Cell {
                label: "dpsgd",
                cfg: ExperimentConfig { algo: AlgoKind::DPsgd, ..base.clone() },
            },
            Cell {
                label: "kitchen-sink",
                cfg: ExperimentConfig {
                    dirichlet_alpha: 0.3,
                    participation: 0.8,
                    straggler_frac: 0.2,
                    straggler_slowdown: 3.0,
                    compress: CompressionKind::Quant,
                    ..base.clone()
                },
            },
        ]);
    }
    cells
}

fn curve_json(values: impl Iterator<Item = f64>) -> String {
    let parts: Vec<String> = values.map(|v| format!("{v:.6}")).collect();
    format!("[{}]", parts.join(","))
}

fn report_cell(topology: TopologyKind, label: &str, report: &ConvergenceReport) {
    println!(
        "{:<16} {:<18} {:>8} {:>12.4} {:>12.4} {:>10.4} {:>10.1} {:>9.3}",
        topology.name(),
        label,
        report.algo,
        report.first_eval_loss(),
        report.final_eval_loss(),
        report.final_accuracy(),
        report.total_wire_mb(),
        report.total_time_s
    );
    println!(
        "JSON {{\"bench\":\"convergence_sweep\",\"topology\":\"{}\",\
         \"scenario\":\"{}\",\"algo\":\"{}\",\"rounds\":{},\
         \"first_eval\":{:.6},\"final_eval\":{:.6},\"final_acc\":{:.6},\
         \"wire_mb\":{:.6},\"total_s\":{:.6},\"stragglers\":{},\
         \"acc_curve\":{},\"wire_curve\":{}}}",
        topology.name(),
        label,
        report.algo,
        report.rounds.len(),
        report.first_eval_loss(),
        report.final_eval_loss(),
        report.final_accuracy(),
        report.total_wire_mb(),
        report.total_time_s,
        report.stragglers.len(),
        curve_json(report.rounds.iter().map(|r| r.accuracy)),
        curve_json(report.rounds.iter().map(|r| r.cum_wire_mb)),
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let topologies: &[TopologyKind] = if smoke {
        &[TopologyKind::BalancedTree]
    } else {
        &[TopologyKind::Chain, TopologyKind::Ring, TopologyKind::BalancedTree]
    };
    let opts = ConvergenceOptions {
        rounds: if smoke { 3 } else { 8 },
        dim: if smoke { 16 } else { 64 },
        ..Default::default()
    };

    section(&format!(
        "convergence sweep: scenario zoo x topology ({} mode)",
        if smoke { "smoke" } else { "full" }
    ));
    println!(
        "{:<16} {:<18} {:>8} {:>12} {:>12} {:>10} {:>10} {:>9}",
        "topology", "scenario", "algo", "first_eval", "final_eval", "final_acc", "wire_mb", "time_s"
    );
    let mut ok = true;
    for &topology in topologies {
        for cell in cells(topology, smoke) {
            let report = run_convergence(&cell.cfg, &opts).expect("convergence run");
            report_cell(topology, cell.label, &report);
            // every scenario must still learn
            if !report.improved() {
                println!("  FAIL: scenario {} did not improve", cell.label);
                ok = false;
            }
            // curves must be well-formed for the artifact consumers
            let monotone = report
                .rounds
                .windows(2)
                .all(|w| w[0].cum_wire_mb <= w[1].cum_wire_mb && w[0].done_s < w[1].done_s);
            if !monotone {
                println!("  FAIL: scenario {} curve not monotone", cell.label);
                ok = false;
            }
        }
    }

    // acceptance gate: quant-8 + error feedback tracks the uncompressed
    // final eval loss on a Dirichlet non-IID scenario, for fewer bytes
    section("acceptance check: quant-8 + EF matches uncompressed on Dirichlet shards");
    let noniid = ExperimentConfig { dirichlet_alpha: 0.3, ..base_cfg(TopologyKind::BalancedTree) };
    let gate_opts = ConvergenceOptions { rounds: if smoke { 4 } else { 10 }, ..opts };
    let plain = run_convergence(&noniid, &gate_opts).expect("uncompressed run");
    let quant = run_convergence(
        &ExperimentConfig { compress: CompressionKind::Quant, quant_bits: 8, ..noniid },
        &gate_opts,
    )
    .expect("quant run");
    let diff = (quant.final_eval_loss() - plain.final_eval_loss()).abs();
    let tol = 0.05;
    let tracks = diff < tol && quant.total_wire_mb() < plain.total_wire_mb();
    println!(
        "  plain: eval={:.4} wire={:.1} MB; quant8+EF: eval={:.4} wire={:.1} MB; |diff|={:.2e} (tol {tol}) -> {}",
        plain.final_eval_loss(),
        plain.total_wire_mb(),
        quant.final_eval_loss(),
        quant.total_wire_mb(),
        diff,
        if tracks { "pass" } else { "FAIL" }
    );
    ok &= tracks;
    println!("acceptance: {}", if ok { "pass" } else { "FAIL" });
    if !ok {
        std::process::exit(1);
    }
}
