//! Binary message codec for the live transport (no serde offline): a
//! 1-byte tag, little-endian fixed-width fields, u32 length prefixes.

use anyhow::{bail, Context, Result};

/// Protocol messages of the live MOSGU deployment (paper §III-A/D).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// RTT probe (the paper's ping measurement for edge costs).
    Ping { nonce: u64 },
    Pong { nonce: u64 },
    /// A node's connectivity report to the moderator: (peer, cost_ms).
    Report { edges: Vec<(u32, f64)> },
    /// Moderator's published schedule: tree edges, node colors, slot secs.
    Schedule { tree_edges: Vec<(u32, u32)>, colors: Vec<u8>, slot_len_s: f64, first_color: u8 },
    /// A model payload moving through the gossip round.
    Model { owner: u32, round: u32, payload: Vec<u8> },
    /// Vote for the next moderator.
    Vote { candidate: u32 },
    /// Announcement of the elected moderator.
    ModeratorIs { node: u32 },
    /// Orderly shutdown.
    Shutdown,
}

impl Message {
    pub fn tag(&self) -> u8 {
        match self {
            Message::Ping { .. } => 1,
            Message::Pong { .. } => 2,
            Message::Report { .. } => 3,
            Message::Schedule { .. } => 4,
            Message::Model { .. } => 5,
            Message::Vote { .. } => 6,
            Message::ModeratorIs { .. } => 7,
            Message::Shutdown => 8,
        }
    }

    /// Encode into a self-describing frame (without the outer length).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = vec![self.tag()];
        match self {
            Message::Ping { nonce } | Message::Pong { nonce } => {
                out.extend_from_slice(&nonce.to_le_bytes());
            }
            Message::Report { edges } => {
                out.extend_from_slice(&(edges.len() as u32).to_le_bytes());
                for &(peer, cost) in edges {
                    out.extend_from_slice(&peer.to_le_bytes());
                    out.extend_from_slice(&cost.to_le_bytes());
                }
            }
            Message::Schedule { tree_edges, colors, slot_len_s, first_color } => {
                out.extend_from_slice(&(tree_edges.len() as u32).to_le_bytes());
                for &(u, v) in tree_edges {
                    out.extend_from_slice(&u.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out.extend_from_slice(&(colors.len() as u32).to_le_bytes());
                out.extend_from_slice(colors);
                out.extend_from_slice(&slot_len_s.to_le_bytes());
                out.push(*first_color);
            }
            Message::Model { owner, round, payload } => {
                out.extend_from_slice(&owner.to_le_bytes());
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                out.extend_from_slice(payload);
            }
            Message::Vote { candidate } => out.extend_from_slice(&candidate.to_le_bytes()),
            Message::ModeratorIs { node } => out.extend_from_slice(&node.to_le_bytes()),
            Message::Shutdown => {}
        }
        out
    }

    /// Decode a frame produced by [`Message::encode`].
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut r = Reader { buf, pos: 0 };
        let tag = r.u8()?;
        let msg = match tag {
            1 => Message::Ping { nonce: r.u64()? },
            2 => Message::Pong { nonce: r.u64()? },
            3 => {
                let n = r.u32()? as usize;
                let mut edges = Vec::with_capacity(n);
                for _ in 0..n {
                    edges.push((r.u32()?, r.f64()?));
                }
                Message::Report { edges }
            }
            4 => {
                let ne = r.u32()? as usize;
                let mut tree_edges = Vec::with_capacity(ne);
                for _ in 0..ne {
                    tree_edges.push((r.u32()?, r.u32()?));
                }
                let nc = r.u32()? as usize;
                let colors = r.bytes(nc)?.to_vec();
                let slot_len_s = r.f64()?;
                let first_color = r.u8()?;
                Message::Schedule { tree_edges, colors, slot_len_s, first_color }
            }
            5 => {
                let owner = r.u32()?;
                let round = r.u32()?;
                let len = r.u32()? as usize;
                Message::Model { owner, round, payload: r.bytes(len)?.to_vec() }
            }
            6 => Message::Vote { candidate: r.u32()? },
            7 => Message::ModeratorIs { node: r.u32()? },
            8 => Message::Shutdown,
            t => bail!("unknown message tag {t}"),
        };
        if r.pos != buf.len() {
            bail!("trailing {} bytes after message", buf.len() - r.pos);
        }
        Ok(msg)
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos + n;
        let s = self.buf.get(self.pos..end).context("truncated message")?;
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let enc = msg.encode();
        let dec = Message::decode(&enc).unwrap();
        assert_eq!(msg, dec);
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(Message::Ping { nonce: 42 });
        roundtrip(Message::Pong { nonce: u64::MAX });
        roundtrip(Message::Report { edges: vec![(1, 2.5), (7, 0.125)] });
        roundtrip(Message::Report { edges: vec![] });
        roundtrip(Message::Schedule {
            tree_edges: vec![(0, 1), (1, 2)],
            colors: vec![0, 1, 0],
            slot_len_s: 5.25,
            first_color: 1,
        });
        roundtrip(Message::Model { owner: 3, round: 9, payload: vec![1, 2, 3, 255] });
        roundtrip(Message::Model { owner: 0, round: 0, payload: vec![0u8; 100_000] });
        roundtrip(Message::Vote { candidate: 4 });
        roundtrip(Message::ModeratorIs { node: 9 });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn rejects_unknown_tag() {
        assert!(Message::decode(&[99]).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let enc = Message::Model { owner: 1, round: 2, payload: vec![9; 8] }.encode();
        assert!(Message::decode(&enc[..enc.len() - 1]).is_err());
        let mut extended = enc.clone();
        extended.push(0);
        assert!(Message::decode(&extended).is_err());
    }
}
