//! Persistent work-stealing drain pool for round barriers.
//!
//! The old barrier spawned one OS thread per subnet per round
//! (`std::thread::scope` in `ShardedNetSim::drain_and_sync`): at 256
//! subnets that is 256 thread spawns/joins per barrier, with most threads
//! doing microseconds of work. [`DrainPool`] decouples parallelism from
//! shard count: a fixed set of workers lives across barriers, each busy
//! shard becomes one stealable *task*, and workers (plus the submitting
//! thread) claim tasks from a shared index until the queue is dry.
//!
//! ## Determinism
//!
//! Within a barrier window the shards share no state — each task drains
//! one `NetSim` to idle with purely private data. Claim order therefore
//! cannot influence any result: every drain computes the same trajectory
//! regardless of which worker runs it or when. Pool drains with 1, 2, or
//! N workers are bit-identical to each other and to a sequential drain
//! (pinned by tests here and in `tests/scale_shard.rs`).

use super::NetSim;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A claimable drain task. The raw pointer erases the caller's borrow so
/// the long-lived workers can hold it; [`DrainPool::drain`] re-establishes
/// the safety contract (see its implementation).
#[derive(Clone, Copy)]
struct Task(*mut NetSim);

// SAFETY: a Task is only ever dereferenced by the single thread that
// claimed it under the pool mutex, and the NetSim it points at is Send
// (owned Vecs, Pcg64, Arc<str> labels).
unsafe impl Send for Task {}

struct PoolState {
    /// tasks for the current barrier window
    tasks: Vec<Task>,
    /// next unclaimed index into `tasks`
    next: usize,
    /// claimed tasks not yet finished + unclaimed tasks
    outstanding: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// workers wait here for tasks (or shutdown)
    work_cv: Condvar,
    /// the submitter waits here for `outstanding == 0`
    done_cv: Condvar,
}

/// A persistent pool draining batches of independent `NetSim`s.
pub struct DrainPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    parallelism: usize,
}

impl DrainPool {
    /// Build a pool with `parallelism` concurrent drainers. The submitting
    /// thread participates in every drain, so `parallelism - 1` worker
    /// threads are spawned; `parallelism <= 1` spawns none and
    /// [`DrainPool::drain`] degenerates to a sequential loop.
    pub fn new(parallelism: usize) -> Self {
        let parallelism = parallelism.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                tasks: Vec::new(),
                next: 0,
                outstanding: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..parallelism)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        DrainPool { shared, handles, parallelism }
    }

    /// Concurrent drainers this pool runs with (including the submitter).
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Drain every sim in `sims` to idle, stealing tasks onto all workers
    /// plus the calling thread. Blocks until the last task finishes.
    ///
    /// SAFETY argument for the internal pointer erasure: each `&mut
    /// NetSim` becomes exactly one task; a task is claimed by exactly one
    /// thread (the claim increments `next` under the mutex); and this
    /// function does not return until `outstanding` reaches zero, so no
    /// worker touches a sim after the caller's borrows are released.
    /// Exclusive access per sim is therefore preserved end to end.
    pub fn drain<'a, I>(&self, sims: I)
    where
        I: IntoIterator<Item = &'a mut NetSim>,
    {
        let tasks: Vec<Task> = sims.into_iter().map(|s| Task(s as *mut NetSim)).collect();
        if tasks.is_empty() {
            return;
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.outstanding == 0, "overlapping drain calls");
            st.outstanding = tasks.len();
            st.tasks = tasks;
            st.next = 0;
            self.shared.work_cv.notify_all();
        }
        // the submitter steals too: a 1-wide pool is just this loop
        loop {
            let task = {
                let mut st = self.shared.state.lock().unwrap();
                if st.next < st.tasks.len() {
                    let t = st.tasks[st.next];
                    st.next += 1;
                    Some(t)
                } else {
                    None
                }
            };
            match task {
                // SAFETY: see above — this thread is the sole claimant
                Some(t) => {
                    unsafe { (*t.0).run_until_idle() };
                    finish_one(&self.shared);
                }
                None => break,
            }
        }
        let mut st = self.shared.state.lock().unwrap();
        while st.outstanding > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.tasks.clear();
    }
}

impl Drop for DrainPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.next < st.tasks.len() {
                    let t = st.tasks[st.next];
                    st.next += 1;
                    break t;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: sole claimant; see DrainPool::drain
        unsafe { (*task.0).run_until_idle() };
        finish_one(shared);
    }
}

fn finish_one(shared: &Shared) {
    let mut st = shared.state.lock().unwrap();
    st.outstanding -= 1;
    if st.outstanding == 0 {
        shared.done_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Channel, LossModel};

    fn busy_sims(n: usize) -> Vec<NetSim> {
        (0..n)
            .map(|i| {
                let chans = vec![
                    Channel { capacity_mbps: 8.0 + i as f64, latency_s: 0.01, label: "a".into() },
                    Channel { capacity_mbps: 3.0, latency_s: 0.0, label: "b".into() },
                ];
                let mut sim = NetSim::new(chans, LossModel::default(), 0.02, 7 + i as u64);
                for k in 0..5 {
                    sim.start_flow(0, 1, vec![0], 2.0 + k as f64, k as u64);
                    sim.start_flow(1, 0, vec![1], 1.5, (10 + k) as u64);
                }
                sim
            })
            .collect()
    }

    fn fingerprint(sims: &[NetSim]) -> Vec<(u64, usize)> {
        sims.iter().map(|s| (s.now().to_bits(), s.completed().len())).collect()
    }

    #[test]
    fn pool_drain_matches_sequential_bit_for_bit() {
        let mut seq = busy_sims(7);
        for s in seq.iter_mut() {
            s.run_until_idle();
        }
        let pool = DrainPool::new(4);
        let mut par = busy_sims(7);
        pool.drain(par.iter_mut());
        assert_eq!(fingerprint(&seq), fingerprint(&par));
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.completed(), b.completed());
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let base = {
            let mut sims = busy_sims(9);
            DrainPool::new(1).drain(sims.iter_mut());
            fingerprint(&sims)
        };
        for workers in [2, 3, 16] {
            let mut sims = busy_sims(9);
            DrainPool::new(workers).drain(sims.iter_mut());
            assert_eq!(fingerprint(&sims), base, "{workers} workers diverged");
        }
    }

    #[test]
    fn pool_is_reusable_across_barriers() {
        let pool = DrainPool::new(3);
        let mut sims = busy_sims(5);
        pool.drain(sims.iter_mut());
        assert!(sims.iter().all(|s| s.active_flow_count() == 0));
        // second barrier window: launch more flows, drain again
        for (i, s) in sims.iter_mut().enumerate() {
            s.start_flow(0, 1, vec![0], 4.0, 100 + i as u64);
        }
        pool.drain(sims.iter_mut().filter(|s| s.active_flow_count() > 0));
        assert!(sims.iter().all(|s| s.active_flow_count() == 0));
        // an empty batch is a no-op
        pool.drain(std::iter::empty());
    }

    #[test]
    fn tasks_exceeding_workers_all_complete() {
        let pool = DrainPool::new(2);
        let mut sims = busy_sims(40);
        pool.drain(sims.iter_mut());
        assert!(sims.iter().all(|s| s.active_flow_count() == 0));
    }
}
