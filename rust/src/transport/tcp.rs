//! Loopback-TCP transport mesh with token-bucket bandwidth shaping.
//!
//! The live counterpart of the simulated testbed: N OS threads, each with
//! a listener on 127.0.0.1, full mesh of connections, frames =
//! `u32 len | u32 from | payload` with the payload shaped through a
//! per-endpoint [`TokenBucket`] so loopback behaves like the paper's
//! rate-limited routers. Writer threads fan incoming frames into one
//! mpsc queue per endpoint, preserving per-sender FIFO order.

use super::{Message, TokenBucket, Transport};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Maximum frame payload (64 MB — comfortably above the largest model).
const MAX_FRAME: u32 = 64 * 1024 * 1024;
/// Shaping chunk: tokens are charged per chunk for smoother pacing.
const CHUNK: usize = 64 * 1024;

/// One TCP endpoint of the mesh.
pub struct TcpEndpoint {
    node: usize,
    n: usize,
    /// outgoing connections (lazily shaped on write)
    out: Vec<Option<TcpStream>>,
    bucket: Arc<Mutex<TokenBucket>>,
    rx: Receiver<(usize, Message)>,
    /// keep listener thread handles alive
    _readers: Vec<std::thread::JoinHandle<()>>,
}

/// Whether an `accept(2)` failure is a per-connection hiccup the acceptor
/// should skip (the handshake that died does not doom the listener) or a
/// listener-level fault that must be reported. Aborted/reset handshakes
/// and EINTR/EAGAIN are routine on loaded hosts; treating them as fatal
/// used to kill the acceptor thread and deadlock [`mesh`].
fn accept_error_is_transient(e: &std::io::Error) -> bool {
    use std::io::ErrorKind;
    matches!(
        e.kind(),
        ErrorKind::ConnectionAborted
            | ErrorKind::ConnectionReset
            | ErrorKind::Interrupted
            | ErrorKind::WouldBlock
            | ErrorKind::TimedOut
    )
}

/// What one acceptor thread hands back: the parked reader handles, or
/// the listener-level I/O error that stopped it.
type AcceptorResult = std::io::Result<Vec<std::thread::JoinHandle<()>>>;

/// Build an n-node loopback mesh with `rate_mbps` per-endpoint uplink
/// shaping (MB/s). Returns the endpoints in node order.
///
/// Setup I/O failures — binding, reading a listener address, dialing,
/// or a non-transient `accept` error — propagate as `Err` instead of
/// panicking inside the acceptor thread (which would leave the dialing
/// side blocked forever); transient accept failures are skipped and the
/// acceptor keeps waiting for the expected peers.
pub fn mesh(n: usize, rate_mbps: f64) -> Result<Vec<TcpEndpoint>> {
    // bind listeners on ephemeral ports first
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0").context("bind"))
        .collect::<Result<_>>()?;
    let ports: Vec<u16> = listeners
        .iter()
        .map(|l| Ok(l.local_addr().context("listener local_addr")?.port()))
        .collect::<Result<_>>()?;

    // each endpoint's incoming queue
    let mut queues: Vec<(Sender<(usize, Message)>, Receiver<(usize, Message)>)> =
        (0..n).map(|_| channel()).collect();

    // connect the full mesh: node i dials every j (i -> j stream carries
    // i's frames to j); j's acceptor spawns a reader per connection
    let mut endpoints: Vec<TcpEndpoint> = Vec::with_capacity(n);
    let mut accept_threads = Vec::new();
    for (node, listener) in listeners.into_iter().enumerate() {
        let (tx, rx) = {
            let (tx, rx) = std::mem::replace(&mut queues[node], channel());
            (tx, rx)
        };
        let expected = n - 1;
        let accept_handle = std::thread::spawn(move || -> AcceptorResult {
            // budget on skipped transient failures: each dialer connects
            // exactly once, so a "transient" abort may still have
            // consumed a peer that will never re-dial — without a bound
            // that would turn the old panic into a silent join() hang
            let mut transient_budget = 2 * expected + 16;
            let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while handles.len() < expected {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx = tx.clone();
                        handles.push(std::thread::spawn(move || reader_loop(stream, tx)));
                    }
                    // a dying handshake is not a dying listener: skip it
                    // and keep accepting the expected peers (bounded)
                    Err(e) if accept_error_is_transient(&e) && transient_budget > 0 => {
                        transient_budget -= 1;
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
            Ok(handles)
        });
        accept_threads.push(accept_handle);
        endpoints.push(TcpEndpoint {
            node,
            n,
            out: (0..n).map(|_| None).collect(),
            bucket: Arc::new(Mutex::new(TokenBucket::new(
                rate_mbps * 1024.0 * 1024.0,
                (rate_mbps * 1024.0 * 1024.0 * 0.05).max(CHUNK as f64),
            ))),
            rx,
            _readers: Vec::new(),
        });
    }
    // dial
    for i in 0..n {
        for (j, &port) in ports.iter().enumerate() {
            if i == j {
                continue;
            }
            let mut stream = TcpStream::connect(("127.0.0.1", port))
                .with_context(|| format!("dial {i}->{j}"))?;
            stream.set_nodelay(true).ok();
            // identify ourselves: first 4 bytes of the connection
            stream.write_all(&(i as u32).to_le_bytes())?;
            endpoints[i].out[j] = Some(stream);
        }
    }
    // park reader threads; acceptor-side I/O errors surface here
    for (node, (ep, handle)) in endpoints.iter_mut().zip(accept_threads).enumerate() {
        ep._readers = handle
            .join()
            .map_err(|_| anyhow::anyhow!("acceptor thread for node {node} panicked"))?
            .with_context(|| format!("accepting mesh connections for node {node}"))?;
    }
    Ok(endpoints)
}

fn reader_loop(mut stream: TcpStream, tx: Sender<(usize, Message)>) {
    // connection preamble: sender id
    let mut id_buf = [0u8; 4];
    if stream.read_exact(&mut id_buf).is_err() {
        return;
    }
    let from = u32::from_le_bytes(id_buf) as usize;
    loop {
        let mut len_buf = [0u8; 4];
        if stream.read_exact(&mut len_buf).is_err() {
            return; // peer closed
        }
        let len = u32::from_le_bytes(len_buf);
        if len > MAX_FRAME {
            return;
        }
        let mut payload = vec![0u8; len as usize];
        if stream.read_exact(&mut payload).is_err() {
            return;
        }
        let Ok(msg) = Message::decode(&payload) else { return };
        if tx.send((from, msg)).is_err() {
            return; // endpoint dropped
        }
    }
}

impl Transport for TcpEndpoint {
    fn node(&self) -> usize {
        self.node
    }

    fn len(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, msg: Message) -> Result<()> {
        anyhow::ensure!(to < self.n && to != self.node, "bad recipient {to}");
        let frame = msg.encode();
        let stream = self.out[to].as_mut().context("no connection")?;
        stream.write_all(&(frame.len() as u32).to_le_bytes())?;
        // shape payload bytes through the uplink bucket, chunk by chunk
        let mut off = 0;
        while off < frame.len() {
            let end = (off + CHUNK).min(frame.len());
            self.bucket.lock().unwrap().consume(end - off);
            stream.write_all(&frame[off..end])?;
            off = end;
        }
        stream.flush()?;
        Ok(())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<(usize, Message)>> {
        match self.rx.recv_timeout(timeout) {
            Ok(pair) => Ok(Some(pair)),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(e) => anyhow::bail!("tcp mesh disconnected: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_error_classification() {
        use std::io::{Error, ErrorKind};
        // per-connection hiccups are skipped...
        for kind in [
            ErrorKind::ConnectionAborted,
            ErrorKind::ConnectionReset,
            ErrorKind::Interrupted,
            ErrorKind::WouldBlock,
            ErrorKind::TimedOut,
        ] {
            assert!(accept_error_is_transient(&Error::from(kind)), "{kind:?}");
        }
        // ...listener-level faults propagate through mesh()'s Result
        for kind in [
            ErrorKind::InvalidInput,
            ErrorKind::PermissionDenied,
            ErrorKind::AddrNotAvailable,
            ErrorKind::OutOfMemory,
        ] {
            assert!(!accept_error_is_transient(&Error::from(kind)), "{kind:?}");
        }
    }

    #[test]
    fn tcp_mesh_roundtrip() {
        let mut eps = mesh(3, 1000.0).unwrap();
        let mut c = eps.remove(2);
        let mut a = eps.remove(0);
        a.send(2, Message::Vote { candidate: 7 }).unwrap();
        let (from, msg) = c.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(from, 0);
        assert_eq!(msg, Message::Vote { candidate: 7 });
    }

    #[test]
    fn tcp_large_payload_shaped() {
        // 2 MB at 20 MB/s => >= ~0.08 s on the shaped path
        let mut eps = mesh(2, 20.0).unwrap();
        let mut b = eps.remove(1);
        let mut a = eps.remove(0);
        let payload = vec![0xabu8; 2 * 1024 * 1024];
        let t0 = std::time::Instant::now();
        a.send(1, Message::Model { owner: 0, round: 0, payload: payload.clone() }).unwrap();
        let (_, msg) = b.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        match msg {
            Message::Model { payload: got, .. } => assert_eq!(got.len(), payload.len()),
            other => panic!("unexpected {other:?}"),
        }
        assert!(dt >= 0.05, "shaping too loose: {dt}");
    }

    #[test]
    fn tcp_model_segments_frame_and_reassemble_in_order() {
        // the segment-granular transfer plane over real sockets: four
        // shaped ModelSegment frames arrive intact and in send order
        let mut eps = mesh(2, 1000.0).unwrap();
        let mut b = eps.remove(1);
        let mut a = eps.remove(0);
        let total = 4u16;
        for index in 0..total {
            let payload = vec![index as u8; 32 * 1024];
            a.send(1, Message::ModelSegment { owner: 0, round: 3, index, total, payload })
                .unwrap();
        }
        for want in 0..total {
            let (_, msg) = b.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            match msg {
                Message::ModelSegment { owner: 0, round: 3, index, total: 4, payload } => {
                    assert_eq!(index, want, "segments must keep FIFO order");
                    assert!(payload.iter().all(|&x| x == want as u8));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn tcp_ping_pong_rtt_measurable() {
        let mut eps = mesh(2, 1000.0).unwrap();
        let mut b = eps.remove(1);
        let mut a = eps.remove(0);
        let t0 = std::time::Instant::now();
        a.send(1, Message::Ping { nonce: 1 }).unwrap();
        let (_, msg) = b.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(msg, Message::Ping { nonce: 1 });
        b.send(0, Message::Pong { nonce: 1 }).unwrap();
        let (_, msg) = a.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(msg, Message::Pong { nonce: 1 });
        assert!(t0.elapsed().as_secs_f64() < 1.0);
    }
}
