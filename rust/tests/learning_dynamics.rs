//! Learning-dynamics scenario-zoo contracts (artifact-free):
//!
//! 1. Dirichlet sharding — per-node class shares are a simplex point,
//!    deterministic per seed, uniform in the large-α limit, and the
//!    `α = inf` sentinel reproduces the legacy one-hot task exactly.
//! 2. Partial participation — each round's sampled set has exactly
//!    `ceil(p·n)` members, replays per seed, varies across rounds, and
//!    at the engine level non-participants never originate a copy while
//!    still relaying (every node receives every originator's model).
//! 3. Stragglers — the sampled plan holds exactly `ceil(frac·n)` nodes,
//!    a zero-frac / unit-slowdown config is structurally a no-op, and at
//!    the engine level holds only ever push round completion later.

use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::GossipSession;
use mosgu::dfl::data::{
    dirichlet_shares, node_shares, trainer_shares, ParticipationPlan, StragglerPlan,
    STRIDE_CLASSES,
};
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;
use mosgu::{prop_assert, prop_assert_eq};

fn quiet_cfg() -> ExperimentConfig {
    ExperimentConfig { latency_jitter: 0.0, ..Default::default() }
}

// --- 1. Dirichlet sharding -------------------------------------------------

#[test]
fn dirichlet_shares_are_simplex_points() {
    check("dirichlet simplex", 128, |rng| {
        let alpha = rng.gen_f64_range(0.05, 20.0);
        let k = 2 + rng.gen_range(8);
        let shares = dirichlet_shares(rng, alpha, k);
        prop_assert_eq!(shares.len(), k);
        let sum: f64 = shares.iter().sum();
        prop_assert!((sum - 1.0).abs() < 1e-9, "sum {sum} at alpha {alpha}");
        prop_assert!(shares.iter().all(|&s| s >= 0.0), "negative share at alpha {alpha}");
        Ok(())
    });
}

#[test]
fn node_shares_are_deterministic_per_seed_and_vary_across_seeds() {
    check("dirichlet determinism", 64, |rng| {
        let alpha = rng.gen_f64_range(0.1, 5.0);
        let seed = rng.next_u64();
        let a = node_shares(alpha, 10, STRIDE_CLASSES, seed);
        let b = node_shares(alpha, 10, STRIDE_CLASSES, seed);
        prop_assert!(a == b, "same seed must replay identical shards");
        let c = node_shares(alpha, 10, STRIDE_CLASSES, seed ^ 0x1);
        prop_assert!(a != c, "distinct seeds must deal distinct shards");
        // nodes draw independent mixtures: at least two must differ
        prop_assert!(a.windows(2).any(|w| w[0] != w[1]), "all nodes got one shard");
        Ok(())
    });
}

#[test]
fn large_alpha_approaches_the_uniform_mixture() {
    let shares = node_shares(1e6, 10, STRIDE_CLASSES, 42);
    let uniform = 1.0 / STRIDE_CLASSES as f64;
    for row in &shares {
        for &s in row {
            assert!((s - uniform).abs() < 0.02, "share {s} far from uniform at alpha 1e6");
        }
    }
}

#[test]
fn infinite_alpha_is_the_exact_off_sentinel() {
    // dirichlet_shares(inf) is the mathematical limit: exactly uniform
    let mut rng = Pcg64::new(7);
    let shares = dirichlet_shares(&mut rng, f64::INFINITY, 5);
    assert!(shares.iter().all(|&s| s == 0.2));
    // trainer_shares(inf) is the *config* sentinel: the legacy one-hot
    // node % 5 task, so flipping the knob on cannot move the baseline
    let legacy = trainer_shares(f64::INFINITY, 10, STRIDE_CLASSES, 42);
    for (u, row) in legacy.iter().enumerate() {
        for (c, &s) in row.iter().enumerate() {
            assert_eq!(s, if c == u % STRIDE_CLASSES { 1.0 } else { 0.0 });
        }
    }
}

#[test]
fn smaller_alpha_concentrates_the_shards() {
    // mean max-share grows as alpha shrinks (more skew per node)
    let mean_max = |alpha: f64| {
        let shares = node_shares(alpha, 40, STRIDE_CLASSES, 9);
        shares.iter().map(|r| r.iter().cloned().fold(0.0, f64::max)).sum::<f64>() / 40.0
    };
    let skewed = mean_max(0.1);
    let mild = mean_max(10.0);
    assert!(
        skewed > mild + 0.1,
        "alpha 0.1 should concentrate far more than alpha 10 ({skewed} vs {mild})"
    );
}

// --- 2. partial participation ----------------------------------------------

#[test]
fn participation_sets_have_exact_size_and_replay_per_seed() {
    check("participation sampling", 64, |rng| {
        let n = 2 + rng.gen_range(38);
        let p = rng.gen_f64_range(0.05, 1.0);
        let seed = rng.next_u64();
        let rounds = 1 + rng.gen_range(6) as u64;
        let expect = ((p * n as f64).ceil() as usize).clamp(1, n);
        let plan = ParticipationPlan::sample(p, n, rounds, seed);
        prop_assert_eq!(plan.rounds(), rounds as usize);
        for r in 0..rounds {
            let set = plan.participants(r).unwrap();
            prop_assert_eq!(set.len(), expect);
            prop_assert!(set.windows(2).all(|w| w[0] < w[1]), "set must be sorted + unique");
            prop_assert!(set.iter().all(|&u| u < n), "participant out of range");
            for u in 0..n {
                prop_assert_eq!(plan.originates(r, u), set.contains(&u));
            }
            // past the planned horizon everyone originates
            prop_assert!(plan.originates(rounds + r, 0));
        }
        let replay = ParticipationPlan::sample(p, n, rounds, seed);
        for r in 0..rounds {
            prop_assert_eq!(plan.participants(r).unwrap(), replay.participants(r).unwrap());
        }
        Ok(())
    });
}

#[test]
fn participation_rounds_sample_different_subsets() {
    // with p = 0.5 over 20 nodes, 6 rounds drawing the same subset every
    // time would be astronomically unlikely
    let plan = ParticipationPlan::sample(0.5, 20, 6, 1234);
    let first = plan.participants(0).unwrap();
    assert!(
        (1..6).any(|r| plan.participants(r).unwrap() != first),
        "every round sampled the identical subset"
    );
}

#[test]
fn engine_prunes_non_participant_originations() {
    let cfg = ExperimentConfig { participation: 0.6, ..quiet_cfg() };
    let session = GossipSession::new(&cfg).unwrap();
    let rounds = 3u64;
    let plan = session.participation_plan(rounds).expect("p < 1 must build a plan");
    let p = session.run_pipelined_rounds(5.0, rounds, 0x90551b);
    assert_eq!(p.received.len(), rounds as usize);
    for r in 0..rounds {
        let originators = plan.participants(r).unwrap();
        assert_eq!(originators.len(), 6, "ceil(0.6 * 10)");
        for (u, order) in p.received[r as usize].iter().enumerate() {
            // every node (relaying non-participants included) receives
            // every originator's copy except its own — and nothing else
            let mut expect: Vec<usize> =
                originators.iter().copied().filter(|&o| o != u).collect();
            let mut got = order.clone();
            got.sort_unstable();
            expect.sort_unstable();
            assert_eq!(got, expect, "node {u} round {r}");
        }
    }
}

#[test]
fn full_participation_is_structurally_dormant() {
    let session = GossipSession::new(&quiet_cfg()).unwrap();
    assert!(session.participation_plan(5).is_none(), "p = 1 must not build a plan");
    // and the engine output is bit-identical to a config that never
    // heard of the knob (same struct, explicit default)
    let explicit = ExperimentConfig { participation: 1.0, ..quiet_cfg() };
    let a = GossipSession::new(&quiet_cfg()).unwrap().run_pipelined_rounds(5.0, 2, 7);
    let b = GossipSession::new(&explicit).unwrap().run_pipelined_rounds(5.0, 2, 7);
    assert_eq!(a.transfers, b.transfers);
    assert_eq!(a.received, b.received);
}

// --- 3. stragglers ----------------------------------------------------------

#[test]
fn straggler_plans_hold_the_sampled_subset() {
    check("straggler sampling", 64, |rng| {
        let n = 2 + rng.gen_range(38);
        let frac = rng.gen_f64();
        let slowdown = 1.0 + rng.gen_f64_range(0.0, 8.0);
        let seed = rng.next_u64();
        let plan = StragglerPlan::sample(frac, slowdown, n, seed);
        let expect_nodes = ((frac * n as f64).ceil() as usize).min(n);
        let expect_hold = (slowdown - 1.0).ceil() as u32;
        if expect_hold == 0 || expect_nodes == 0 {
            prop_assert!(plan.is_noop(), "unit slowdown or empty subset must be a no-op");
        } else {
            prop_assert_eq!(plan.stragglers().len(), expect_nodes);
            for u in plan.stragglers() {
                prop_assert_eq!(plan.hold_slots[u], expect_hold);
            }
            let replay = StragglerPlan::sample(frac, slowdown, n, seed);
            prop_assert_eq!(plan.stragglers(), replay.stragglers());
        }
        Ok(())
    });
}

#[test]
fn zero_straggler_config_is_structurally_dormant() {
    let session = GossipSession::new(&quiet_cfg()).unwrap();
    assert!(session.straggler_plan().is_none(), "frac = 0 must not build a plan");
    // frac > 0 but slowdown = 1 holds nobody -> also no plan
    let unit = ExperimentConfig { straggler_frac: 0.5, straggler_slowdown: 1.0, ..quiet_cfg() };
    assert!(GossipSession::new(&unit).unwrap().straggler_plan().is_none());
    // engine output matches a knob-free run bit for bit
    let explicit = ExperimentConfig { straggler_frac: 0.0, ..quiet_cfg() };
    let a = GossipSession::new(&quiet_cfg()).unwrap().run_pipelined_rounds(5.0, 2, 7);
    let b = GossipSession::new(&explicit).unwrap().run_pipelined_rounds(5.0, 2, 7);
    assert_eq!(a.transfers, b.transfers);
}

#[test]
fn straggler_holds_only_push_rounds_later() {
    let baseline = GossipSession::new(&quiet_cfg()).unwrap().run_pipelined_rounds(5.0, 3, 7);
    let slow_cfg = ExperimentConfig {
        straggler_frac: 0.2,
        straggler_slowdown: 4.0,
        ..quiet_cfg()
    };
    let session = GossipSession::new(&slow_cfg).unwrap();
    let plan = session.straggler_plan().expect("frac 0.2 must build a plan");
    assert_eq!(plan.stragglers().len(), 2, "ceil(0.2 * 10)");
    let slow = session.run_pipelined_rounds(5.0, 3, 7);

    assert_eq!(slow.received.len(), 3, "held rounds must still complete");
    // reception *sets* are untouched (stragglers delay, they don't drop)
    for (r, round) in slow.received.iter().enumerate() {
        for (u, order) in round.iter().enumerate() {
            let mut got = order.clone();
            got.sort_unstable();
            let mut want = baseline.received[r][u].clone();
            want.sort_unstable();
            assert_eq!(got, want, "node {u} round {r} lost copies");
        }
    }
    // with a 4x slowdown on two nodes the schedule must actually move
    assert_ne!(slow.transfers, baseline.transfers, "4x holds must reshape the schedule");

    // delays only push transmissions later: within the held run, each
    // straggler spends its first transmit opportunities computing, so its
    // first send comes strictly after the earliest non-straggler send
    let stragglers = plan.stragglers();
    let first_send = |u: usize| {
        slow.transfers
            .iter()
            .filter(|t| t.src == u)
            .map(|t| t.start)
            .fold(f64::INFINITY, f64::min)
    };
    let earliest_normal = (0..10)
        .filter(|u| !stragglers.contains(u))
        .map(first_send)
        .fold(f64::INFINITY, f64::min);
    assert!(earliest_normal.is_finite(), "non-stragglers must transmit");
    for &u in &stragglers {
        let held = first_send(u);
        assert!(held.is_finite(), "straggler {u} must eventually transmit");
        assert!(
            held > earliest_normal,
            "straggler {u} sent at {held} despite holds (earliest normal send {earliest_normal})"
        );
    }
}
