//! Decentralized federated learning layer: the Table II model registry,
//! the artifact-driven per-node trainer, segment-granular transfer
//! planning, payload compression codecs (quantization / top-k with
//! error feedback), DFL round orchestration (train → gossip →
//! aggregate), the adversarial robustness plane (Byzantine node
//! behaviors, robust fold policies, and the chaos-injection harness),
//! and the learning-dynamics scenario zoo (Dirichlet non-IID shards,
//! partial participation, stragglers, D-PSGD — with the convergence
//! harness validating compression against accuracy).

pub mod adversary;
pub mod chaos;
pub mod compress;
pub mod convergence;
pub mod data;
pub mod models;
pub mod robust;
pub mod round;
pub mod trainer;
pub mod transfer;
