//! Link hygiene for the documentation pages.
//!
//! Intra-doc references (`[`crate::...`]`) in `docs/*.md` are resolved
//! by `cargo doc --no-deps` because `lib.rs` embeds the pages as
//! `mosgu::docs::*` (CI denies rustdoc warnings). This test covers what
//! rustdoc does not: **relative file links** in the markdown — every
//! `[text](path)` that is not an external URL or a pure anchor must
//! point at a file that exists, and anchors into a markdown file must
//! match one of its headings.

use std::fs;
use std::path::{Path, PathBuf};

/// Repo root: this file lives at `<repo>/rust/tests/docs_links.rs`.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("workspace root").to_path_buf()
}

/// Extract `(target, line)` pairs from every markdown inline link,
/// skipping fenced code blocks.
fn markdown_links(text: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let bytes = line.as_bytes();
        let mut i = 0;
        while i + 1 < bytes.len() {
            if bytes[i] == b']' && bytes[i + 1] == b'(' {
                let start = i + 2;
                if let Some(rel_end) = line[start..].find(')') {
                    out.push((line[start..start + rel_end].to_string(), lineno + 1));
                    i = start + rel_end;
                }
            }
            i += 1;
        }
    }
    out
}

/// GitHub-style heading slugs: lowercase, drop non-alphanumerics except
/// spaces/hyphens, spaces → hyphens.
fn heading_anchors(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence || !line.starts_with('#') {
            continue;
        }
        let title = line.trim_start_matches('#').trim();
        let slug: String = title
            .chars()
            .filter_map(|c| {
                if c.is_alphanumeric() {
                    Some(c.to_ascii_lowercase())
                } else if c == ' ' || c == '-' {
                    Some('-')
                } else {
                    None
                }
            })
            .collect();
        out.push(slug);
    }
    out
}

fn is_external(target: &str) -> bool {
    target.starts_with("http://")
        || target.starts_with("https://")
        || target.starts_with("mailto:")
        || target.starts_with('#') && target.len() > 1 && !target.contains('/')
}

#[test]
fn docs_relative_links_resolve() {
    let root = repo_root();
    let pages = ["README.md", "docs/ARCHITECTURE.md", "docs/EXPERIMENTS.md"];
    let mut checked = 0;
    let mut failures: Vec<String> = Vec::new();
    for page in pages {
        let path = root.join(page);
        let text = fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {page}: {e}"));
        let dir = path.parent().expect("page has a directory");
        for (target, line) in markdown_links(&text) {
            if is_external(&target) || target.starts_with('#') {
                continue;
            }
            let (file_part, anchor) = match target.split_once('#') {
                Some((f, a)) => (f, Some(a.to_string())),
                None => (target.as_str(), None),
            };
            if file_part.is_empty() {
                continue;
            }
            checked += 1;
            let dest = dir.join(file_part);
            if !dest.exists() {
                failures.push(format!("{page}:{line}: broken relative link -> {target}"));
                continue;
            }
            if let Some(anchor) = anchor {
                if file_part.ends_with(".md") {
                    let dest_text = fs::read_to_string(&dest)
                        .unwrap_or_else(|e| panic!("read {}: {e}", dest.display()));
                    if !heading_anchors(&dest_text).contains(&anchor) {
                        failures.push(format!(
                            "{page}:{line}: anchor #{anchor} missing in {file_part}"
                        ));
                    }
                }
            }
        }
    }
    assert!(failures.is_empty(), "broken docs links:\n{}", failures.join("\n"));
    assert!(checked >= 4, "link extraction found too few relative links ({checked})");
}

#[test]
fn docs_pages_exist_and_are_embedded() {
    let root = repo_root();
    for page in ["docs/ARCHITECTURE.md", "docs/EXPERIMENTS.md"] {
        assert!(root.join(page).exists(), "{page} missing");
    }
    // the pages referenced by code comments carry their anchors
    let experiments = fs::read_to_string(root.join("docs/EXPERIMENTS.md")).unwrap();
    for heading in ["## Calibration", "## Deviations", "### Perf/L3", "### Perf/L4"] {
        assert!(
            experiments.contains(heading),
            "docs/EXPERIMENTS.md lost the {heading:?} section code comments point at"
        );
    }
    let lib = fs::read_to_string(root.join("rust/src/lib.rs")).unwrap();
    assert!(
        lib.contains("include_str!(\"../../docs/ARCHITECTURE.md\")")
            && lib.contains("include_str!(\"../../docs/EXPERIMENTS.md\")"),
        "docs pages must stay embedded in rustdoc for CI link-checking"
    );
}

#[test]
fn markdown_link_extractor_behaves() {
    let text = "see [a](x.md) and [b](http://e.com) and\n```\n[c](skip.md)\n```\n[d](y.md#z)";
    let links = markdown_links(text);
    let targets: Vec<&str> = links.iter().map(|(t, _)| t.as_str()).collect();
    assert_eq!(targets, vec!["x.md", "http://e.com", "y.md#z"]);
    assert_eq!(heading_anchors("# A B\n## Perf/L3\n"), vec!["a-b", "perfl3"]);
}
