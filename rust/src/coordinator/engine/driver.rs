//! Byte-movement drivers for the [`RoundEngine`](super::RoundEngine).
//!
//! The engine owns protocol state and slot structure; a [`Driver`] owns
//! the substrate that actually carries transfer units and tells the
//! engine, **per flow**, when each unit has arrived. Since the
//! segment-granular refactor the transfer unit is a
//! [`SegmentKey`] — one slice of a model copy under the active
//! [`TransferPlan`](crate::dfl::transfer::TransferPlan); whole-model
//! transfers are the `total == 1` special case and preserve the legacy
//! behavior bit for bit.
//!
//! * [`SimDriver`] — the discrete-event network simulator (`netsim`),
//!   stepping one completion event at a time via
//!   [`NetSim::run_next_completion`](crate::netsim::NetSim::run_next_completion).
//!   Supports relabeled node ids for churn's induced subgraphs. The loss
//!   model sees segment-sized payloads, so congestion inflation applies
//!   per transfer unit.
//! * [`LogicalDriver`] — untimed instant delivery; one clock tick per
//!   batch. This is the substrate behind the paper's Table I queue trace.
//! * [`LiveDriver`] — real byte payloads over a [`Transport`] mesh
//!   (in-memory channels or shaped loopback TCP), timed on the wall
//!   clock. Segments travel as [`Message::ModelSegment`] frames and are
//!   reassembled per `(src, dst, model)` in the driver's reassembly
//!   buffer.

use crate::coordinator::broadcast::flow_tag_segment;
use crate::coordinator::queue::{ModelKey, SegmentKey};
use crate::graph::NodeId;
use crate::netsim::testbed::Testbed;
use crate::netsim::{DriftProcess, FlowRecord, NetSim, SimCounters};
use crate::transport::{Message, Transport};
use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

/// Opaque handle for one launched transfer unit.
pub type CopyToken = u64;

/// One transfer unit has fully arrived at its recipient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub token: CopyToken,
    /// Driver-clock delivery time (seconds).
    pub at_s: f64,
}

/// A substrate that moves transfer units and reports per-flow completion
/// events. All engine modes (simulated, logical, live) implement this.
pub trait Driver {
    /// Begin transferring one `payload_mb`-sized unit — segment
    /// `seg.index` of `seg.total` of `seg.model` — from `from` to `to`.
    /// Returns a token identifying the unit.
    fn launch(&mut self, from: NodeId, to: NodeId, seg: SegmentKey, payload_mb: f64) -> CopyToken;

    /// Advance the substrate until at least one in-flight unit completes
    /// and return the newly completed units. An empty vector means
    /// nothing is in flight (or the substrate stalled — the engine treats
    /// that as fatal while units are outstanding).
    fn wait_any(&mut self) -> Vec<Completion>;

    /// Current driver clock in seconds.
    fn now(&self) -> f64;

    /// Drain the low-level transfer records accumulated so far.
    fn take_transfers(&mut self) -> Vec<FlowRecord>;

    /// Cumulative simulator work counters (events processed, rate
    /// recomputes) since the substrate was built. Substrates with no
    /// simulator report zeros. Callers that want per-round figures
    /// snapshot at round start and diff via
    /// [`SimCounters::since`](crate::netsim::SimCounters::since).
    fn sim_counters(&self) -> SimCounters {
        SimCounters::default()
    }

    /// Measure the substrate's **current** round-trip ping between two
    /// nodes in milliseconds, for a probe of `probe_bytes` — the paper's
    /// §III-A edge cost, re-observed online by `coordinator::probe`.
    /// Probes are passive reads of link state: no flow is launched and
    /// the clock does not advance, so probing never perturbs the byte
    /// trajectory. Substrates without measurable link state return
    /// `None` (the prober then keeps its last estimate).
    fn probe_ping_ms(&self, _from: NodeId, _to: NodeId, _probe_bytes: u64) -> Option<f64> {
        None
    }
}

/// Driver over the discrete-event fluid-flow simulator.
///
/// `map[protocol id] = device id` relabels flows onto testbed hosts; the
/// identity map is the common case, churn passes the induced-subgraph
/// relabeling so surviving members keep their original routes.
pub struct SimDriver<'a> {
    testbed: &'a Testbed,
    sim: NetSim,
    map: Vec<NodeId>,
}

impl<'a> SimDriver<'a> {
    /// Fresh simulator over the testbed wiring, identity node map.
    pub fn new(testbed: &'a Testbed, seed: u64) -> Self {
        let map = (0..testbed.node_count()).collect();
        SimDriver { testbed, sim: testbed.netsim(seed), map }
    }

    /// As [`SimDriver::new`] with an explicit protocol-id → device-id map
    /// (churn's relabeled trees).
    pub fn with_map(testbed: &'a Testbed, seed: u64, map: Vec<NodeId>) -> Self {
        assert!(
            map.iter().all(|&d| d < testbed.node_count()),
            "map addresses a device outside the testbed"
        );
        SimDriver { testbed, sim: testbed.netsim(seed), map }
    }

    /// As [`SimDriver::new`] with seeded link-quality drift installed on
    /// the simulator (`drift.amplitude == 0` is bit-identical to
    /// [`SimDriver::new`]).
    pub fn with_drift(testbed: &'a Testbed, seed: u64, drift: DriftProcess) -> Self {
        let map = (0..testbed.node_count()).collect();
        Self::with_map_drift(testbed, seed, map, drift)
    }

    /// Explicit node map **and** link drift (churn under drifting links).
    pub fn with_map_drift(
        testbed: &'a Testbed,
        seed: u64,
        map: Vec<NodeId>,
        drift: DriftProcess,
    ) -> Self {
        assert!(
            map.iter().all(|&d| d < testbed.node_count()),
            "map addresses a device outside the testbed"
        );
        SimDriver { testbed, sim: testbed.netsim_with_drift(seed, drift), map }
    }

    pub fn sim(&self) -> &NetSim {
        &self.sim
    }

    /// Direct access for scripting [`crate::netsim::ChannelShift`]s onto
    /// the simulator (tests and benches of the adaptive plane).
    pub fn sim_mut(&mut self) -> &mut NetSim {
        &mut self.sim
    }
}

impl Driver for SimDriver<'_> {
    fn launch(&mut self, from: NodeId, to: NodeId, seg: SegmentKey, payload_mb: f64) -> CopyToken {
        let (src, dst) = (self.map[from], self.map[to]);
        self.sim.start_flow(
            src,
            dst,
            self.testbed.route(src, dst),
            payload_mb,
            flow_tag_segment(self.map[seg.model.owner], src, seg.index),
        ) as CopyToken
    }

    fn wait_any(&mut self) -> Vec<Completion> {
        self.sim
            .run_next_completion()
            .into_iter()
            .map(|r| Completion { token: r.flow as CopyToken, at_s: r.end })
            .collect()
    }

    fn now(&self) -> f64 {
        self.sim.now()
    }

    fn take_transfers(&mut self) -> Vec<FlowRecord> {
        self.sim.take_completed()
    }

    fn sim_counters(&self) -> SimCounters {
        self.sim.counters()
    }

    fn probe_ping_ms(&self, from: NodeId, to: NodeId, probe_bytes: u64) -> Option<f64> {
        let (src, dst) = (self.map[from], self.map[to]);
        if src == dst {
            return None;
        }
        Some(self.sim.route_ping_ms(&self.testbed.route(src, dst), probe_bytes))
    }
}

/// Driver over a synthetic **per-edge** channel mesh: every directed
/// overlay edge (u, v) gets its own simulator channel (one-way latency =
/// half the edge's RTT cost, uniform capacity), so link quality can be
/// scripted or drifted per overlay edge — the substrate of the
/// re-planning scenarios in `coordinator::probe`. Routes are single
/// channels and node ids map to themselves.
pub struct MeshSimDriver {
    sim: NetSim,
    /// (src, dst) → its dedicated channel
    route_of: HashMap<(NodeId, NodeId), crate::netsim::ChannelId>,
}

impl MeshSimDriver {
    /// Build from an overlay cost graph whose edge weights are RTT pings
    /// in milliseconds. The loss model is disabled (per-edge channels
    /// never share a bottleneck with foreign traffic).
    pub fn from_costs(costs: &crate::graph::Graph, capacity_mbps: f64, seed: u64) -> Self {
        assert!(capacity_mbps > 0.0);
        let mut channels = Vec::new();
        let mut route_of = HashMap::new();
        for e in costs.edges() {
            for (a, b) in [(e.u, e.v), (e.v, e.u)] {
                route_of.insert((a, b), channels.len());
                channels.push(crate::netsim::Channel {
                    capacity_mbps,
                    latency_s: e.weight / 2.0 / 1e3,
                    label: format!("{a}->{b}").into(),
                });
            }
        }
        let loss = crate::netsim::LossModel { gain: 0.0, size_scale_mb: 1.0 };
        MeshSimDriver { sim: NetSim::new(channels, loss, 0.0, seed), route_of }
    }

    /// The channel carrying traffic from `u` to `v`, if the overlay has
    /// that edge.
    pub fn channel_of(&self, u: NodeId, v: NodeId) -> Option<crate::netsim::ChannelId> {
        self.route_of.get(&(u, v)).copied()
    }

    pub fn sim(&self) -> &NetSim {
        &self.sim
    }

    /// Direct simulator access for scripting shifts/drift.
    pub fn sim_mut(&mut self) -> &mut NetSim {
        &mut self.sim
    }
}

impl Driver for MeshSimDriver {
    fn launch(&mut self, from: NodeId, to: NodeId, seg: SegmentKey, payload_mb: f64) -> CopyToken {
        let c = *self
            .route_of
            .get(&(from, to))
            .unwrap_or_else(|| panic!("mesh has no edge {from}->{to}"));
        self.sim.start_flow(
            from,
            to,
            vec![c],
            payload_mb,
            flow_tag_segment(seg.model.owner, from, seg.index),
        ) as CopyToken
    }

    fn wait_any(&mut self) -> Vec<Completion> {
        self.sim
            .run_next_completion()
            .into_iter()
            .map(|r| Completion { token: r.flow as CopyToken, at_s: r.end })
            .collect()
    }

    fn now(&self) -> f64 {
        self.sim.now()
    }

    fn take_transfers(&mut self) -> Vec<FlowRecord> {
        self.sim.take_completed()
    }

    fn sim_counters(&self) -> SimCounters {
        self.sim.counters()
    }

    fn probe_ping_ms(&self, from: NodeId, to: NodeId, probe_bytes: u64) -> Option<f64> {
        self.route_of.get(&(from, to)).map(|&c| self.sim.route_ping_ms(&[c], probe_bytes))
    }
}

/// Untimed driver: every launched unit completes at the next `wait_any`,
/// which advances the clock by one tick. Produces the exact slot-by-slot
/// semantics of the paper's Table I (whole-model plans tick once per
/// slot; segmented plans tick once per pipeline wave).
#[derive(Debug, Default)]
pub struct LogicalDriver {
    clock: f64,
    next_token: CopyToken,
    inflight: Vec<(CopyToken, NodeId, NodeId, SegmentKey, f64)>,
    transfers: Vec<FlowRecord>,
}

impl LogicalDriver {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Driver for LogicalDriver {
    fn launch(&mut self, from: NodeId, to: NodeId, seg: SegmentKey, payload_mb: f64) -> CopyToken {
        let token = self.next_token;
        self.next_token += 1;
        self.inflight.push((token, from, to, seg, payload_mb));
        token
    }

    fn wait_any(&mut self) -> Vec<Completion> {
        if self.inflight.is_empty() {
            return Vec::new();
        }
        self.clock += 1.0;
        let done = std::mem::take(&mut self.inflight);
        done.into_iter()
            .map(|(token, from, to, seg, payload_mb)| {
                self.transfers.push(FlowRecord {
                    flow: token as usize,
                    src: from,
                    dst: to,
                    payload_mb,
                    start: self.clock - 1.0,
                    end: self.clock,
                    tag: flow_tag_segment(seg.model.owner, from, seg.index),
                });
                Completion { token, at_s: self.clock }
            })
            .collect()
    }

    fn now(&self) -> f64 {
        self.clock
    }

    fn take_transfers(&mut self) -> Vec<FlowRecord> {
        std::mem::take(&mut self.transfers)
    }
}

/// Per-model reassembly progress at one live receiver.
#[derive(Debug)]
struct Reassembly {
    total: u16,
    seen: Vec<bool>,
    received: u16,
    bytes: usize,
}

/// Driver over real transports: transfer units are actual byte payloads
/// pushed through a [`Transport`] mesh (in-memory channels for tests,
/// token-bucket-shaped loopback TCP for the live cluster), timed on the
/// wall clock.
///
/// The driver owns every endpoint of the mesh, so the engine remains the
/// single protocol authority — the in-process counterpart of the paper's
/// moderator-scheduled deployment. Endpoint `i` must carry node id `i`.
///
/// Segmented plans frame each unit as [`Message::ModelSegment`]; the
/// driver keeps a per-`(dst, src, model)` reassembly buffer so "node
/// holds model" can be asserted at the byte level
/// ([`LiveDriver::reassembled_models`]).
pub struct LiveDriver<T: Transport> {
    endpoints: Vec<T>,
    epoch: Instant,
    next_token: CopyToken,
    /// (sender, recipient, segment) → tokens awaiting that arrival, FIFO
    /// so retransmissions of the same unit resolve in launch order.
    inflight: HashMap<(NodeId, NodeId, SegmentKey), VecDeque<CopyToken>>,
    inflight_count: usize,
    launched: HashMap<CopyToken, (NodeId, NodeId, SegmentKey, f64, f64)>,
    transfers: Vec<FlowRecord>,
    /// (dst, src, model) → segments collected so far.
    reassembly: HashMap<(NodeId, NodeId, ModelKey), Reassembly>,
    reassembled: usize,
    /// Payload bytes of fully reassembled models (byte-level goodput).
    reassembled_bytes: usize,
    poll: Duration,
    stall_timeout: Duration,
}

impl<T: Transport> LiveDriver<T> {
    pub fn new(endpoints: Vec<T>) -> Self {
        assert!(!endpoints.is_empty(), "live driver needs at least one endpoint");
        for (i, ep) in endpoints.iter().enumerate() {
            assert_eq!(ep.node(), i, "endpoints must be ordered by node id");
        }
        LiveDriver {
            endpoints,
            epoch: Instant::now(),
            next_token: 0,
            inflight: HashMap::new(),
            inflight_count: 0,
            launched: HashMap::new(),
            transfers: Vec::new(),
            reassembly: HashMap::new(),
            reassembled: 0,
            reassembled_bytes: 0,
            poll: Duration::from_millis(2),
            stall_timeout: Duration::from_secs(30),
        }
    }

    /// How long `wait_any` keeps polling before declaring the mesh
    /// stalled (the engine then aborts the round).
    pub fn set_stall_timeout(&mut self, timeout: Duration) {
        self.stall_timeout = timeout;
    }

    /// Model copies whose segments have all arrived and been reassembled
    /// at their recipients (byte-level completeness; whole-model frames
    /// count as single-segment reassemblies).
    pub fn reassembled_models(&self) -> usize {
        self.reassembled
    }

    /// Copies with at least one segment received but not yet complete.
    pub fn pending_reassemblies(&self) -> usize {
        self.reassembly.len()
    }

    /// Payload bytes of fully reassembled model copies — the byte-level
    /// goodput counterpart of [`LiveDriver::reassembled_models`].
    pub fn reassembled_bytes(&self) -> usize {
        self.reassembled_bytes
    }

    /// Record one arrived segment in the reassembly buffer; counts the
    /// model (and its payload bytes) once its full segment set is present.
    fn reassemble(&mut self, dst: NodeId, src: NodeId, seg: SegmentKey, bytes: usize) {
        if seg.total == 1 {
            self.reassembled += 1;
            self.reassembled_bytes += bytes;
            return;
        }
        let entry = self.reassembly.entry((dst, src, seg.model)).or_insert_with(|| Reassembly {
            total: seg.total,
            seen: vec![false; seg.total as usize],
            received: 0,
            bytes: 0,
        });
        assert_eq!(entry.total, seg.total, "segment total changed mid-reassembly");
        if !entry.seen[seg.index as usize] {
            entry.seen[seg.index as usize] = true;
            entry.received += 1;
            entry.bytes += bytes;
        }
        if entry.received == entry.total {
            // invariant: the entry was created (or found) just above in
            // this same call, and nothing between inserts can remove it
            #[allow(clippy::expect_used)]
            let done = self.reassembly.remove(&(dst, src, seg.model)).expect("entry exists");
            self.reassembled += 1;
            self.reassembled_bytes += done.bytes;
        }
    }
}

impl<T: Transport> Driver for LiveDriver<T> {
    fn launch(&mut self, from: NodeId, to: NodeId, seg: SegmentKey, payload_mb: f64) -> CopyToken {
        let bytes = ((payload_mb * 1024.0 * 1024.0).ceil() as usize).max(1);
        let token = self.next_token;
        self.next_token += 1;
        let start = self.epoch.elapsed().as_secs_f64();
        let owner = seg.model.owner as u32;
        let round = seg.model.round as u32;
        let msg = if seg.total == 1 {
            Message::Model { owner, round, payload: vec![owner as u8; bytes] }
        } else {
            Message::ModelSegment {
                owner,
                round,
                index: seg.index,
                total: seg.total,
                payload: vec![owner as u8; bytes],
            }
        };
        // invariant-documented panic: `launch` cannot surface transport
        // errors through the Driver trait, and a failed send means the
        // live mesh is torn down — no round can make progress past it
        #[allow(clippy::expect_used)]
        self.endpoints[from].send(to, msg).expect("live transport send failed");
        self.inflight.entry((from, to, seg)).or_default().push_back(token);
        self.inflight_count += 1;
        self.launched.insert(token, (from, to, seg, payload_mb, start));
        token
    }

    fn wait_any(&mut self) -> Vec<Completion> {
        if self.inflight_count == 0 {
            return Vec::new();
        }
        let deadline = Instant::now() + self.stall_timeout;
        let mut out = Vec::new();
        while out.is_empty() {
            if Instant::now() > deadline {
                return out; // stalled: engine asserts with units in flight
            }
            for d in 0..self.endpoints.len() {
                loop {
                    // invariant-documented panic: a recv error means the
                    // mesh endpoint is gone; the engine would stall on
                    // in-flight units anyway, so fail loudly here
                    #[allow(clippy::expect_used)]
                    let msg = self.endpoints[d].try_recv().expect("live transport recv failed");
                    let Some((src, msg)) = msg else { break };
                    let (seg, bytes) = match msg {
                        Message::Model { owner, round, payload } => (
                            SegmentKey::whole(ModelKey::new(owner as usize, round as u64)),
                            payload.len(),
                        ),
                        Message::ModelSegment { owner, round, index, total, payload } => (
                            SegmentKey::new(
                                ModelKey::new(owner as usize, round as u64),
                                index,
                                total,
                            ),
                            payload.len(),
                        ),
                        _ => continue,
                    };
                    self.reassemble(d, src, seg, bytes);
                    let Some(queue) = self.inflight.get_mut(&(src, d, seg)) else { continue };
                    let Some(token) = queue.pop_front() else { continue };
                    self.inflight_count -= 1;
                    let at = self.epoch.elapsed().as_secs_f64();
                    // invariant: every token in an `inflight` queue was
                    // inserted into `launched` by the same `launch` call,
                    // and only this line ever removes it
                    #[allow(clippy::expect_used)]
                    let (from, to, seg, payload_mb, start) =
                        self.launched.remove(&token).expect("completion for unknown token");
                    self.transfers.push(FlowRecord {
                        flow: token as usize,
                        src: from,
                        dst: to,
                        payload_mb,
                        start,
                        end: at,
                        tag: flow_tag_segment(seg.model.owner, from, seg.index),
                    });
                    out.push(Completion { token, at_s: at });
                }
            }
            if out.is_empty() {
                std::thread::sleep(self.poll);
            }
        }
        out
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn take_transfers(&mut self) -> Vec<FlowRecord> {
        std::mem::take(&mut self.transfers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::transport::memory;

    fn testbed() -> Testbed {
        Testbed::new(&ExperimentConfig { latency_jitter: 0.0, ..Default::default() })
    }

    fn whole(owner: NodeId) -> SegmentKey {
        SegmentKey::whole(ModelKey::new(owner, 0))
    }

    #[test]
    fn sim_driver_reports_per_flow_completions() {
        let tb = testbed();
        let mut d = SimDriver::new(&tb, 1);
        let t0 = d.launch(0, 1, whole(0), 2.0);
        let t1 = d.launch(2, 5, whole(2), 14.0);
        let first = d.wait_any();
        assert_eq!(first.len(), 1, "unequal sizes must complete separately");
        assert_eq!(first[0].token, t0);
        let second = d.wait_any();
        assert_eq!(second[0].token, t1);
        assert!(second[0].at_s > first[0].at_s);
        assert!(d.wait_any().is_empty());
        assert_eq!(d.take_transfers().len(), 2);
    }

    #[test]
    fn sim_driver_map_relabels_devices() {
        let tb = testbed();
        // protocol node 0 -> device 7, protocol node 1 -> device 2
        let map = vec![7, 2, 0, 1, 3, 4, 5, 6, 8, 9];
        let mut d = SimDriver::with_map(&tb, 1, map);
        d.launch(0, 1, whole(0), 1.0);
        d.wait_any();
        let rec = &d.take_transfers()[0];
        assert_eq!((rec.src, rec.dst), (7, 2));
        assert_eq!(crate::coordinator::broadcast::tag_owner(rec.tag), 7);
    }

    #[test]
    fn sim_driver_tags_carry_segment_index() {
        let tb = testbed();
        let mut d = SimDriver::new(&tb, 1);
        let key = ModelKey::new(3, 0);
        d.launch(3, 4, SegmentKey::new(key, 2, 4), 3.5);
        d.wait_any();
        let rec = &d.take_transfers()[0];
        assert_eq!(crate::coordinator::broadcast::tag_owner(rec.tag), 3);
        assert_eq!(crate::coordinator::broadcast::tag_segment(rec.tag), 2);
        assert!((rec.payload_mb - 3.5).abs() < 1e-12, "loss model sees segment payloads");
    }

    #[test]
    fn sim_driver_probe_matches_testbed_ping_until_links_shift() {
        let tb = testbed();
        let mut d = SimDriver::new(&tb, 1);
        let before = d.probe_ping_ms(0, 1, 56).unwrap();
        assert!((before - tb.ping_ms(0, 1)).abs() < 1e-9);
        assert!(d.probe_ping_ms(3, 3, 56).is_none(), "self-probe is meaningless");
        // degrade every channel on the 0->1 route 4x: probe sees it
        let route = tb.route(0, 1);
        let shifts: Vec<crate::netsim::ChannelShift> = route
            .iter()
            .map(|&c| {
                let ch = d.sim().channel(c);
                crate::netsim::ChannelShift {
                    at_s: 0.0,
                    channel: c,
                    capacity_mbps: ch.capacity_mbps / 4.0,
                    latency_s: ch.latency_s * 4.0,
                }
            })
            .collect();
        d.sim_mut().schedule_shifts(shifts);
        // shifts apply at the next event; drive one through
        d.launch(0, 1, whole(0), 0.5);
        d.wait_any();
        let after = d.probe_ping_ms(0, 1, 56).unwrap();
        assert!(after > 3.0 * before, "degradation invisible to probe: {before} -> {after}");
    }

    #[test]
    fn logical_driver_has_no_probe() {
        let d = LogicalDriver::new();
        assert!(d.probe_ping_ms(0, 1, 56).is_none());
    }

    #[test]
    fn mesh_driver_moves_copies_over_dedicated_channels() {
        // triangle overlay, 10 ms RTT edges, 10 MB/s
        let mut costs = crate::graph::Graph::new(3);
        costs.add_edge(0, 1, 10.0);
        costs.add_edge(1, 2, 10.0);
        costs.add_edge(0, 2, 30.0);
        let mut d = MeshSimDriver::from_costs(&costs, 10.0, 1);
        assert!((d.probe_ping_ms(0, 1, 56).unwrap() - 10.0).abs() < 0.1);
        assert!((d.probe_ping_ms(2, 0, 56).unwrap() - 30.0).abs() < 0.1);
        assert!(d.probe_ping_ms(0, 0, 56).is_none());
        let t = d.launch(0, 1, whole(0), 5.0);
        let done = d.wait_any();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].token, t);
        // 5 MB at 10 MB/s + 5 ms one-way propagation
        assert!((done[0].at_s - 0.505).abs() < 1e-9, "at {}", done[0].at_s);
        let rec = &d.take_transfers()[0];
        assert_eq!((rec.src, rec.dst), (0, 1));
        // independent edges do not contend
        d.launch(0, 1, whole(0), 5.0);
        d.launch(1, 2, whole(1), 5.0);
        let mut seen = 0;
        while seen < 2 {
            seen += d.wait_any().len();
        }
        for rec in d.take_transfers() {
            assert!((rec.duration() - 0.505).abs() < 1e-9, "{rec:?}");
        }
    }

    #[test]
    fn logical_driver_ticks_one_unit_per_batch() {
        let mut d = LogicalDriver::new();
        assert!(d.wait_any().is_empty());
        d.launch(0, 1, whole(0), 1.0);
        d.launch(1, 0, whole(1), 1.0);
        let done = d.wait_any();
        assert_eq!(done.len(), 2);
        assert_eq!(d.now(), 1.0);
        d.launch(0, 1, whole(1), 1.0);
        d.wait_any();
        assert_eq!(d.now(), 2.0);
        assert_eq!(d.take_transfers().len(), 3);
    }

    #[test]
    fn live_driver_moves_bytes_over_memory_mesh() {
        let mut d = LiveDriver::new(memory::mesh(4));
        let token = d.launch(2, 3, whole(2), 0.0001);
        let done = d.wait_any();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].token, token);
        let recs = d.take_transfers();
        assert_eq!((recs[0].src, recs[0].dst), (2, 3));
        assert!(recs[0].end >= recs[0].start);
        assert!(d.wait_any().is_empty());
        assert_eq!(d.reassembled_models(), 1);
    }

    #[test]
    fn live_driver_reassembles_segmented_copies() {
        let mut d = LiveDriver::new(memory::mesh(3));
        let key = ModelKey::new(0, 1);
        // three segments of one copy, launched serially as the engine does
        for i in 0..3u16 {
            d.launch(0, 1, SegmentKey::new(key, i, 3), 0.0001);
            let done = d.wait_any();
            assert_eq!(done.len(), 1);
            if i < 2 {
                assert_eq!(d.reassembled_models(), 0, "incomplete after segment {i}");
                assert_eq!(d.pending_reassemblies(), 1);
            }
        }
        assert_eq!(d.reassembled_models(), 1);
        assert_eq!(d.pending_reassemblies(), 0);
        // 3 segments × ceil(0.0001 MB) = 3 × 105 payload bytes reassembled
        let seg_bytes = ((0.0001f64 * 1024.0 * 1024.0).ceil() as usize).max(1);
        assert_eq!(d.reassembled_bytes(), 3 * seg_bytes);
        let recs = d.take_transfers();
        assert_eq!(recs.len(), 3);
        for (i, rec) in recs.iter().enumerate() {
            assert_eq!(crate::coordinator::broadcast::tag_segment(rec.tag), i as u16);
        }
    }
}
