//! Live cluster mode: the MOSGU protocol running over **real loopback TCP
//! sockets** with token-bucket bandwidth shaping — the in-process
//! counterpart of the paper's physical ten-device deployment.
//!
//! Ten OS threads each own a shaped TCP endpoint. The run executes the
//! full M-O-S-GU pipeline live:
//!
//! 1. **M** — node 0 announces itself moderator; every node measures real
//!    ping RTTs to its peers and reports them;
//! 2. **O/S** — the moderator builds the MST, BFS-colors it and broadcasts
//!    the schedule;
//! 3. **GU** — alternating color slots gossip real byte payloads over the
//!    shaped sockets until every node holds all models.
//!
//! ```bash
//! cargo run --release --example live_cluster [NODES] [PAYLOAD_MB]
//! ```

use anyhow::{Context, Result};
use mosgu::coloring::ColoringAlgorithm;
use mosgu::coordinator::moderator::Moderator;
use mosgu::coordinator::queue::{GossipQueue, ModelKey};
use mosgu::graph::Graph;
use mosgu::mst::MstAlgorithm;
use mosgu::transport::{tcp, Message, Transport};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    mosgu::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(10);
    let payload_mb: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(2.0);
    let payload_bytes = (payload_mb * 1024.0 * 1024.0) as usize;

    println!("live cluster: {n} nodes over shaped loopback TCP, {payload_mb} MB models");
    let endpoints = tcp::mesh(n, 40.0).context("building TCP mesh")?;
    let barrier = Arc::new(Barrier::new(n));

    let t0 = Instant::now();
    let handles: Vec<_> = endpoints
        .into_iter()
        .map(|ep| {
            let barrier = barrier.clone();
            std::thread::spawn(move || node_main(ep, barrier, payload_bytes))
        })
        .collect();

    let mut total_transfers = 0usize;
    let mut held_all = true;
    for h in handles {
        let stats = h.join().expect("node thread panicked").expect("node failed");
        total_transfers += stats.sent;
        held_all &= stats.complete;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n== live round summary ==");
    println!("wall time: {wall:.2} s, {total_transfers} model transmissions");
    println!(
        "aggregate goodput: {:.1} MB/s across the mesh",
        total_transfers as f64 * payload_mb / wall
    );
    anyhow::ensure!(held_all, "some node did not receive all models");
    println!("OK: every node holds all {n} models");
    Ok(())
}

struct NodeStats {
    sent: usize,
    complete: bool,
}

fn node_main(
    mut ep: tcp::TcpEndpoint,
    barrier: Arc<Barrier>,
    payload_bytes: usize,
) -> Result<NodeStats> {
    let me = ep.node();
    let n = ep.len();

    // --- M: ping measurement (real RTTs over the shaped mesh) ---
    barrier.wait();
    let mut rtt_ms = vec![0.0f64; n];
    let mut pongs_pending = n - 1;
    let mut sent_at = vec![Instant::now(); n];
    for peer in 0..n {
        if peer != me {
            sent_at[peer] = Instant::now();
            ep.send(peer, Message::Ping { nonce: peer as u64 })?;
        }
    }
    while pongs_pending > 0 {
        match ep.recv_timeout(Duration::from_secs(10))? {
            Some((from, Message::Ping { nonce })) => {
                ep.send(from, Message::Pong { nonce })?;
            }
            Some((from, Message::Pong { .. })) => {
                rtt_ms[from] = sent_at[from].elapsed().as_secs_f64() * 1e3;
                pongs_pending -= 1;
            }
            Some(_) => {}
            None => anyhow::bail!("node {me}: ping phase timed out"),
        }
    }

    // --- report to the moderator (node 0) ---
    let edges: Vec<(u32, f64)> =
        (0..n).filter(|&p| p != me).map(|p| (p as u32, rtt_ms[p].max(0.01))).collect();
    barrier.wait();
    let schedule = if me == 0 {
        let mut moderator = Moderator::new(0, n, MstAlgorithm::Prim, ColoringAlgorithm::Bfs);
        moderator.submit_report(0, &edges.iter().map(|&(p, c)| (p as usize, c)).collect::<Vec<_>>());
        let mut reports = n - 1;
        while reports > 0 {
            match ep.recv_timeout(Duration::from_secs(10))? {
                Some((from, Message::Report { edges })) => {
                    let peers: Vec<(usize, f64)> =
                        edges.iter().map(|&(p, c)| (p as usize, c)).collect();
                    moderator.submit_report(from, &peers);
                    reports -= 1;
                }
                Some(_) => {}
                None => anyhow::bail!("moderator: report phase timed out"),
            }
        }
        let mb = payload_bytes as f64 / (1024.0 * 1024.0);
        let bundle = moderator.compute_schedule(mb, 56, 1)?.clone();
        let msg = Message::Schedule {
            tree_edges: bundle.tree.edges().iter().map(|e| (e.u as u32, e.v as u32)).collect(),
            colors: bundle.schedule.coloring.assignment().iter().map(|&c| c as u8).collect(),
            slot_len_s: bundle.schedule.slot_len_s,
            first_color: 1,
        };
        ep.broadcast(msg.clone())?;
        msg
    } else {
        ep.send(0, Message::Report { edges })?;
        loop {
            match ep.recv_timeout(Duration::from_secs(20))? {
                Some((_, msg @ Message::Schedule { .. })) => break msg,
                Some(_) => {}
                None => anyhow::bail!("node {me}: no schedule received"),
            }
        }
    };
    let Message::Schedule { tree_edges, colors, first_color, .. } = schedule else {
        unreachable!()
    };
    let mut tree = Graph::new(n);
    for (u, v) in &tree_edges {
        tree.add_edge(*u as usize, *v as usize, 1.0);
    }
    let my_color = colors[me] as usize;
    let neighbors = tree.neighbor_ids(me);
    let degree = neighbors.len();

    // --- GU: alternating slots over real sockets ---
    let mut queue = GossipQueue::new(me);
    queue.seed_own(0);
    let mut sent = 0usize;
    // generous wall-clock slot cadence derived from shaping rate
    let slot_dur = Duration::from_secs_f64(
        (payload_bytes as f64 / (40.0 * 1024.0 * 1024.0)) * (degree.max(1) as f64) * 1.8 + 0.05,
    );
    barrier.wait();
    let start = Instant::now();
    let max_slots = 4 * n + 16;
    for slot in 0..max_slots {
        if queue.held_count() == n && queue.is_drained() {
            // stay responsive for peers still catching up
        }
        let slot_color = ((first_color as usize) + slot) % 2;
        let deadline = start + slot_dur * (slot as u32 + 1);
        if slot_color == my_color {
            if let Some(entry) = queue.pop_oldest() {
                for &v in &neighbors {
                    if Some(v) == entry.received_from {
                        continue;
                    }
                    let msg = Message::Model {
                        owner: entry.key.owner as u32,
                        round: 0,
                        payload: vec![entry.key.owner as u8; payload_bytes],
                    };
                    ep.send(v, msg)?;
                    sent += 1;
                }
            }
        }
        // drain receptions until the slot deadline
        while Instant::now() < deadline {
            let left = deadline.saturating_duration_since(Instant::now());
            match ep.recv_timeout(left.min(Duration::from_millis(50)))? {
                Some((from, Message::Model { owner, payload, .. })) => {
                    anyhow::ensure!(payload.len() == payload_bytes, "short payload");
                    queue.receive(ModelKey::new(owner as usize, 0), from, degree > 1);
                }
                Some(_) | None => {}
            }
        }
        if queue.held_count() == n && queue.is_drained() && slot >= 2 * n {
            break;
        }
    }
    // keep the endpoint (and its connections) alive until every node is
    // done, otherwise stragglers see their peers hang up mid-round
    barrier.wait();
    Ok(NodeStats { sent, complete: queue.held_count() == n })
}
