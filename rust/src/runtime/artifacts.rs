//! Artifact management: the manifest written by `python -m compile.aot`
//! and the convenience loader bundling the three computations the DFL
//! layer needs (train, eval, aggregate) plus the initial parameters.

use super::{read_f32_file, LoadedComputation, Runtime};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed `manifest.txt` (flat `key = value` integers).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactManifest {
    pub param_dim: usize,
    pub param_count: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub pad_multiple: usize,
}

impl ArtifactManifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut kv: BTreeMap<&str, usize> = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("bad manifest line {line:?}"))?;
            kv.insert(k.trim(), v.trim().parse::<usize>()
                .with_context(|| format!("bad manifest value {line:?}"))?);
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k).copied().with_context(|| format!("manifest missing {k:?}"))
        };
        Ok(ArtifactManifest {
            param_dim: get("param_dim")?,
            param_count: get("param_count")?,
            batch: get("batch")?,
            seq_len: get("seq_len")?,
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            d_ff: get("d_ff")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            pad_multiple: get("pad_multiple")?,
        })
    }
}

/// The full artifact bundle, compiled and ready to execute.
pub struct ArtifactSet {
    pub manifest: ArtifactManifest,
    pub train_step: LoadedComputation,
    pub eval_step: LoadedComputation,
    pub aggregate: LoadedComputation,
    pub init_params: Vec<f32>,
    pub dir: PathBuf,
}

impl ArtifactSet {
    /// Load and compile everything from an artifacts directory.
    pub fn load(rt: &Runtime, dir: &Path) -> Result<Self> {
        anyhow::ensure!(
            dir.join("manifest.txt").exists(),
            "no artifacts in {dir:?} — run `make artifacts` first"
        );
        let manifest = ArtifactManifest::load(&dir.join("manifest.txt"))?;
        let train_step = rt.load_hlo_text(&dir.join("train_step.hlo.txt"))?;
        let eval_step = rt.load_hlo_text(&dir.join("eval_step.hlo.txt"))?;
        let aggregate = rt.load_hlo_text(&dir.join("aggregate.hlo.txt"))?;
        let init_params = read_f32_file(&dir.join("init_params.f32"))?;
        anyhow::ensure!(
            init_params.len() == manifest.param_dim,
            "init_params length {} != manifest param_dim {}",
            init_params.len(),
            manifest.param_dim
        );
        Ok(ArtifactSet {
            manifest,
            train_step,
            eval_step,
            aggregate,
            init_params,
            dir: dir.to_path_buf(),
        })
    }

    /// Serialized parameter size in MB — what one gossip transfer moves.
    pub fn model_mb(&self) -> f64 {
        (self.manifest.param_dim * 4) as f64 / (1024.0 * 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "param_dim = 524288\nparam_count = 469504\nbatch = 8\n\
        seq_len = 64\nvocab = 256\nd_model = 128\nd_ff = 512\nn_layers = 2\n\
        n_heads = 4\npad_multiple = 65536\n";

    #[test]
    fn parse_manifest() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.param_dim, 524288);
        assert_eq!(m.param_count, 469504);
        assert_eq!(m.batch, 8);
        assert_eq!(m.seq_len, 64);
        assert_eq!(m.n_heads, 4);
    }

    #[test]
    fn parse_rejects_missing_key() {
        assert!(ArtifactManifest::parse("param_dim = 4\n").is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ArtifactManifest::parse("param_dim four\n").is_err());
        assert!(ArtifactManifest::parse(&SAMPLE.replace("8", "eight")).is_err());
    }
}
