//! Regenerates **Table V** — average total time (s) to complete one FL
//! communication round (exchange phase; see metrics::RoundMetrics docs),
//! broadcast vs MOSGU, per topology × model. Also reports the full
//! dissemination time for reference.
//!
//! Paper reference values: broadcast 10 s (v3s) → 83 s (b3); proposed
//! 3.16–38 s (improvements up to 4.4×).

use mosgu::bench::section;
use mosgu::bench::tables::{all_models, render, run_grid, PaperTable};
use mosgu::config::ExperimentConfig;
use mosgu::graph::topology::TopologyKind;

fn main() {
    let cfg = ExperimentConfig::default();
    section("Table V: communication-round total time grid");
    let cells = run_grid(&cfg, &TopologyKind::ALL, &all_models(), |s| eprintln!("  {s}"))
        .expect("grid");
    println!("{}", render(PaperTable::RoundTime, &cells));

    section("full-dissemination time (all N models at all nodes), MOSGU");
    println!("{:<17}{:>10}{:>12}", "topology", "model", "dissem (s)");
    for c in &cells {
        if ["v3s", "b0", "b3"].contains(&c.model.as_str()) {
            println!("{:<17}{:>10}{:>12.2}", c.topology, c.model, c.proposed.total.mean());
        }
    }
}
