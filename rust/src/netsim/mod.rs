//! Discrete-event fluid-flow network simulator.
//!
//! Stands in for the paper's physical testbed (ten Ubuntu devices behind
//! three routers, §IV-A / Fig 3). Hosts exchange fixed-size payloads over
//! directed channels with capacity and propagation latency; concurrent
//! flows on a channel share bandwidth max-min fairly; sustained
//! oversubscription inflates the bytes a flow must move (TCP-loss /
//! retransmission model — the paper's "packet loss … necessitates
//! retransmission, worsening congestion").
//!
//! The simulation is event-driven: rates are piecewise constant between
//! flow arrivals/completions, so the engine jumps from completion to
//! completion rather than ticking.
//!
//! ## Time-varying channels (the dynamic network plane)
//!
//! Channels are no longer frozen at construction: a piecewise schedule of
//! [`ChannelShift`]s (scripted degradations/recoveries) and/or a seeded
//! [`DriftProcess`] (random link-quality drift) re-rate the system at
//! simulated points in time. Each change is one extra event horizon: the
//! loop drains bytes at the old rates up to the change, applies it, and
//! re-plans — so byte conservation and the monotone clock hold under any
//! capacity/latency schedule. With no shifts and no drift installed, the
//! event loop takes exactly the legacy path, float for float.
//!
//! ## Hot path at scale (§Perf/L5)
//!
//! Flow state lives in a struct-of-arrays arena (`FlowArena`): parallel
//! column vectors indexed by `FlowId`, with routes packed end-to-end in
//! one flat arena behind an offsets table. The per-event work is
//! *incremental*: each channel keeps its active-user list, and on a flow
//! arrival/completion (or a capacity change) only the connected component
//! of channels/flows transitively sharing a bottleneck with the change is
//! re-water-filled — components are independent in max-min allocation, so
//! the restricted pass is bit-identical to the full one (the full pass in
//! [`fairshare::max_min_rates`] is kept as the differential-test oracle;
//! see [`NetSim::set_full_rerate`]). Changes landing at the same event
//! horizon batch into one recompute via lazy dirty marks.
//!
//! ## Scaling out
//!
//! One event queue is sequential by construction; the multi-subnet
//! scale-out plane runs one `NetSim` per subnet plus a backbone queue,
//! re-synchronized at round barriers by a persistent work-stealing pool —
//! see [`shard::ShardedNetSim`] and [`pool::DrainPool`].
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod fairshare;
pub mod pool;
pub mod shard;
pub mod sync;
pub mod testbed;

use crate::util::rng::Pcg64;
use fairshare::max_min_rates;

/// Identifier of a simulated host (device or router).
pub type HostId = usize;
/// Identifier of a directed channel.
pub type ChannelId = usize;
/// Identifier of a flow.
pub type FlowId = usize;

/// A directed channel with fixed capacity and propagation latency.
#[derive(Debug, Clone)]
pub struct Channel {
    pub capacity_mbps: f64,
    pub latency_s: f64,
    /// human-readable endpoint description for debugging; interned so
    /// clone-heavy paths (the backbone shard clones every device link)
    /// share one allocation instead of copying a `String` per clone
    pub label: std::sync::Arc<str>,
}

/// One scripted change to a channel's quality at a point in simulated
/// time: from `at_s` on, the channel runs at `capacity_mbps` and delivers
/// with `latency_s` propagation. Flows in flight drain at the old rate up
/// to `at_s` and at the new rate afterwards; latency applies to flows
/// completing after the shift.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelShift {
    pub at_s: f64,
    pub channel: ChannelId,
    pub capacity_mbps: f64,
    pub latency_s: f64,
}

/// Seeded piecewise-constant link-quality drift: every `interval_s` of
/// simulated time, each channel draws an independent quality factor
/// `q ∈ [1 − amplitude, 1 + amplitude]` and runs at `base_capacity · q`
/// with latency `base_latency / q` until the next draw — degraded links
/// lose rate and gain delay together, and recover on a later draw.
/// `amplitude == 0` disables the process entirely.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftProcess {
    pub amplitude: f64,
    pub interval_s: f64,
}

/// Installed drift state: the process, its own RNG stream, the next tick
/// time, and the base (capacity, latency) each factor scales around.
#[derive(Debug, Clone)]
struct DriftState {
    process: DriftProcess,
    rng: Pcg64,
    next_at: f64,
    base: Vec<(f64, f64)>,
}

/// Loss/retransmission model parameters (see DESIGN.md §2).
///
/// When a flow starts on a route whose bottleneck channel carries `k`
/// concurrent flows, the bytes it must move are inflated by
/// `1 + gain · ln(k) · (1 − exp(−size_mb / size_scale_mb))`:
/// more sharing ⇒ more loss; longer saturation (bigger payload) ⇒ the
/// loss compounds. Calibrated against the paper's broadcast column.
#[derive(Debug, Clone, Copy)]
pub struct LossModel {
    pub gain: f64,
    pub size_scale_mb: f64,
}

impl Default for LossModel {
    fn default() -> Self {
        // Calibrated against the paper's Table III broadcast column (see
        // EXPERIMENTS.md §Calibration): bandwidth 1.79→0.77 MB/s as model
        // size grows 11.6→48 MB under ~9-way uplink contention.
        LossModel { gain: 1.8, size_scale_mb: 60.0 }
    }
}

impl LossModel {
    /// Byte inflation factor for a new flow.
    pub fn inflation(&self, size_mb: f64, bottleneck_flows: usize) -> f64 {
        if bottleneck_flows <= 1 || self.gain == 0.0 {
            return 1.0;
        }
        1.0 + self.gain * (bottleneck_flows as f64).ln() * (1.0 - (-size_mb / self.size_scale_mb).exp())
    }
}

/// Hot-path work counters, measured (not inferred from wall clock) so
/// benches and metrics can report events/sec and recompute amortization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SimCounters {
    /// event-loop iterations processed (completions + change horizons +
    /// idle clock jumps)
    pub events: u64,
    /// water-filling passes actually run (component-restricted passes in
    /// the incremental mode, full passes in oracle mode)
    pub rate_recomputes: u64,
}

impl SimCounters {
    /// Accumulate another counter set (shard aggregation).
    pub fn merge(&mut self, other: SimCounters) {
        self.events += other.events;
        self.rate_recomputes += other.rate_recomputes;
    }

    /// Counters accumulated since an earlier snapshot of the same sim.
    pub fn since(self, earlier: SimCounters) -> SimCounters {
        SimCounters {
            events: self.events - earlier.events,
            rate_recomputes: self.rate_recomputes - earlier.rate_recomputes,
        }
    }
}

/// Struct-of-arrays flow state: one column per field, indexed by
/// `FlowId`, with every route packed end-to-end in one flat arena behind
/// an offsets table (`route_offsets[f]..route_offsets[f+1]`). Replaces
/// the old `Vec<Flow>` of per-flow structs: the event loop touches only
/// `remaining_mb` when draining, and routes stop being a pointer-chase
/// per flow (§Perf/L5).
#[derive(Debug, Default)]
struct FlowArena {
    src: Vec<HostId>,
    dst: Vec<HostId>,
    /// payload size before loss inflation (MB)
    payload_mb: Vec<f64>,
    /// bytes still to move, including inflation (MB)
    remaining_mb: Vec<f64>,
    start: Vec<f64>,
    /// opaque tag the driver can use (model owner id, etc.)
    tag: Vec<u64>,
    done: Vec<bool>,
    /// `route_offsets[f]..route_offsets[f+1]` bounds flow `f`'s route in
    /// `route_arena`; always starts with a leading 0 sentinel
    route_offsets: Vec<u32>,
    route_arena: Vec<ChannelId>,
}

impl FlowArena {
    fn new() -> Self {
        FlowArena { route_offsets: vec![0], ..Default::default() }
    }

    fn len(&self) -> usize {
        self.src.len()
    }

    #[allow(clippy::too_many_arguments)]
    fn push(
        &mut self,
        src: HostId,
        dst: HostId,
        route: &[ChannelId],
        payload_mb: f64,
        remaining_mb: f64,
        start: f64,
        tag: u64,
    ) -> FlowId {
        let id = self.len();
        self.src.push(src);
        self.dst.push(dst);
        self.payload_mb.push(payload_mb);
        self.remaining_mb.push(remaining_mb);
        self.start.push(start);
        self.tag.push(tag);
        self.done.push(false);
        self.route_arena.extend_from_slice(route);
        self.route_offsets.push(self.route_arena.len() as u32);
        id
    }

    fn route(&self, f: FlowId) -> &[ChannelId] {
        &self.route_arena[self.route_offsets[f] as usize..self.route_offsets[f + 1] as usize]
    }
}

/// Reused scratch for the incremental re-rate: epoch-stamped mark arrays
/// (no clearing between recomputes) plus the component worklists. Lives
/// on the sim so the per-event `Vec<Vec<usize>>` users allocation the old
/// full pass paid is gone entirely.
#[derive(Debug, Default)]
struct RerateScratch {
    /// current stamp; a mark array entry equals it iff set this recompute
    epoch: u64,
    chan_mark: Vec<u64>,
    flow_mark: Vec<u64>,
    /// per-flow "frozen in this water-filling pass" stamp
    frozen_mark: Vec<u64>,
    /// channel id → dense slot in `remaining`/`unfrozen` (valid only for
    /// channels of the current component)
    chan_slot: Vec<u32>,
    comp_channels: Vec<ChannelId>,
    comp_flows: Vec<FlowId>,
    remaining: Vec<f64>,
    unfrozen: Vec<usize>,
    queue: Vec<ChannelId>,
}

/// Completed-transfer record handed to metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowRecord {
    pub flow: FlowId,
    pub src: HostId,
    pub dst: HostId,
    pub payload_mb: f64,
    pub start: f64,
    pub end: f64,
    pub tag: u64,
}

impl FlowRecord {
    /// Transfer duration in seconds.
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }

    /// Observed goodput — the paper's "bandwidth (MB/s)" indicator is the
    /// payload (not retransmitted bytes) over wall time.
    pub fn bandwidth_mbps(&self) -> f64 {
        self.payload_mb / self.duration()
    }
}

/// The simulator.
pub struct NetSim {
    now: f64,
    channels: Vec<Channel>,
    /// cached channel capacities (hot: read once per event)
    caps: Vec<f64>,
    flows: FlowArena,
    /// ids of flows still draining, ascending (hot: every event iterates
    /// exactly the active set instead of scanning every flow ever created
    /// — the O(total-flows) per-event scan that dominated n ≥ 10k runs;
    /// see docs/EXPERIMENTS.md §Perf/L4)
    active_ids: Vec<FlowId>,
    /// channel → active flows crossing it, ascending, one entry per route
    /// occurrence (a flow crossing a channel twice appears twice — the
    /// water-filling subtraction is per occurrence)
    channel_users: Vec<Vec<FlowId>>,
    /// cached goodput per flow, valid whenever no dirty marks are pending;
    /// indexed by `FlowId` (stale entries for completed flows are inert)
    flow_rate: Vec<f64>,
    /// channels whose capacity or user set changed since the last re-rate
    /// (seeds for the component BFS; duplicates fine)
    dirty_channels: Vec<ChannelId>,
    /// every channel changed at once (a drift tick re-caps all of them)
    all_dirty: bool,
    /// oracle mode: full water-filling on every event (differential tests)
    full_rerate: bool,
    scratch: RerateScratch,
    counters: SimCounters,
    loss: LossModel,
    /// per-flow protocol overhead fraction (headers/acks)
    protocol_overhead: f64,
    rng: Pcg64,
    /// relative jitter applied to each flow's effective size
    transfer_jitter: f64,
    completed: Vec<FlowRecord>,
    /// scripted channel changes, sorted by time; `next_shift` indexes the
    /// first not yet applied
    shifts: Vec<ChannelShift>,
    next_shift: usize,
    /// seeded random link-quality drift (None = static links)
    drift: Option<DriftState>,
}

impl NetSim {
    pub fn new(channels: Vec<Channel>, loss: LossModel, protocol_overhead: f64, seed: u64) -> Self {
        assert!((0.0..1.0).contains(&protocol_overhead));
        let caps: Vec<f64> = channels.iter().map(|c| c.capacity_mbps).collect();
        let nc = channels.len();
        NetSim {
            now: 0.0,
            channels,
            caps,
            flows: FlowArena::new(),
            active_ids: Vec::new(),
            channel_users: vec![Vec::new(); nc],
            flow_rate: Vec::new(),
            dirty_channels: Vec::new(),
            all_dirty: false,
            full_rerate: false,
            scratch: RerateScratch::default(),
            counters: SimCounters::default(),
            loss,
            protocol_overhead,
            rng: Pcg64::new(seed),
            transfer_jitter: 0.0,
            completed: Vec::new(),
            shifts: Vec::new(),
            next_shift: 0,
            drift: None,
        }
    }

    /// Work counters accumulated since construction.
    pub fn counters(&self) -> SimCounters {
        self.counters
    }

    /// Force the legacy full water-filling pass on every event instead of
    /// the incremental per-component re-rate. This is the differential-
    /// test oracle: components are independent under max-min allocation,
    /// so both modes produce bit-identical trajectories — only the work
    /// counters differ (pinned by `tests/netsim_rerate.rs`).
    pub fn set_full_rerate(&mut self, full: bool) {
        self.full_rerate = full;
    }

    /// Install scripted channel shifts (appended to any already
    /// scheduled, then kept sorted by time; ties apply in channel order).
    /// Shifts at or before the current clock apply at the next event.
    pub fn schedule_shifts(&mut self, shifts: Vec<ChannelShift>) {
        for s in &shifts {
            assert!(s.at_s.is_finite(), "non-finite shift time in {s:?}");
            assert!(s.channel < self.channels.len(), "shift on bad channel {}", s.channel);
            assert!(
                s.capacity_mbps.is_finite() && s.capacity_mbps > 0.0,
                "shifted capacity must stay positive and finite: {s:?}"
            );
            assert!(s.latency_s.is_finite() && s.latency_s >= 0.0, "bad shift latency {s:?}");
        }
        // drop already-applied shifts, merge the new ones, re-sort.
        // total_cmp keeps the sort panic-free under any float input (the
        // asserts above reject non-finite times before they can reorder
        // the schedule) — same hardening as the PR-5 MST edge sort.
        self.shifts.drain(..self.next_shift);
        self.next_shift = 0;
        self.shifts.extend(shifts);
        self.shifts.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.channel.cmp(&b.channel)));
    }

    /// Install seeded link-quality drift (see [`DriftProcess`]); the
    /// first draw happens `interval_s` into the simulation. An amplitude
    /// of zero uninstalls the process, leaving the trajectory untouched.
    pub fn set_drift(&mut self, process: DriftProcess, seed: u64) {
        assert!((0.0..1.0).contains(&process.amplitude), "drift amplitude must be in [0,1)");
        if process.amplitude == 0.0 {
            self.drift = None;
            return;
        }
        assert!(process.interval_s > 0.0, "drift interval must be positive");
        self.drift = Some(DriftState {
            process,
            rng: Pcg64::new(seed),
            next_at: self.now + process.interval_s,
            base: self.channels.iter().map(|c| (c.capacity_mbps, c.latency_s)).collect(),
        });
    }

    /// Earliest pending channel change strictly after `now` (shifts due
    /// at or before `now` are applied eagerly by the event loop).
    fn next_change_at(&self) -> Option<f64> {
        let shift = self.shifts.get(self.next_shift).map(|s| s.at_s);
        let drift = self.drift.as_ref().map(|d| d.next_at);
        match (shift, drift) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Apply every scripted shift and drift tick due at or before the
    /// current clock. No-op (and allocation-free) when nothing is
    /// installed, so the static-link trajectory is untouched bit for bit.
    fn apply_due_changes(&mut self) {
        while let Some(s) = self.shifts.get(self.next_shift) {
            if s.at_s > self.now {
                break;
            }
            let (c, cap, lat) = (s.channel, s.capacity_mbps, s.latency_s);
            self.channels[c].capacity_mbps = cap;
            self.channels[c].latency_s = lat;
            self.caps[c] = cap;
            self.dirty_channels.push(c);
            // a scripted shift redefines the channel's *base* quality, so
            // an installed drift process wiggles around the shifted value
            // instead of silently erasing the shift at its next tick
            if let Some(d) = self.drift.as_mut() {
                d.base[c] = (cap, lat);
            }
            self.next_shift += 1;
        }
        if let Some(d) = self.drift.as_mut() {
            while d.next_at <= self.now {
                for (c, &(base_cap, base_lat)) in d.base.iter().enumerate() {
                    let a = d.process.amplitude;
                    let q = 1.0 + d.rng.gen_f64_range(-a, a);
                    self.channels[c].capacity_mbps = base_cap * q;
                    self.channels[c].latency_s = base_lat / q;
                    self.caps[c] = base_cap * q;
                }
                // every channel re-capped at once; shifts and drift ticks
                // landing at the same horizon batch into one recompute
                self.all_dirty = true;
                d.next_at += d.process.interval_s;
            }
        }
    }

    /// Enable per-transfer size jitter (fraction, uniform ±).
    pub fn set_transfer_jitter(&mut self, j: f64) {
        assert!((0.0..0.5).contains(&j));
        self.transfer_jitter = j;
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn channel(&self, c: ChannelId) -> &Channel {
        &self.channels[c]
    }

    /// Round-trip ping (ms) along `route` for a `probe_bytes` probe,
    /// evaluated against the channels' **current** — possibly shifted or
    /// drifted — state: two one-way propagations plus two serializations
    /// at the bottleneck. This is the online counterpart of
    /// `Testbed::ping_ms` (which reads the build-time state) and the
    /// measurement behind the engine drivers' `probe_ping_ms`.
    pub fn route_ping_ms(&self, route: &[ChannelId], probe_bytes: u64) -> f64 {
        let one_way: f64 = route.iter().map(|&c| self.channels[c].latency_s).sum();
        let probe_mb = probe_bytes as f64 / (1024.0 * 1024.0);
        let min_rate = route
            .iter()
            .map(|&c| self.channels[c].capacity_mbps)
            .fold(f64::INFINITY, f64::min);
        (2.0 * one_way + 2.0 * probe_mb / min_rate) * 1e3
    }

    pub fn active_flow_count(&self) -> usize {
        self.active_ids.len()
    }

    /// Records of all completed flows so far.
    pub fn completed(&self) -> &[FlowRecord] {
        &self.completed
    }

    pub fn take_completed(&mut self) -> Vec<FlowRecord> {
        std::mem::take(&mut self.completed)
    }

    /// Start a transfer of `payload_mb` along `route` at the current time.
    ///
    /// The effective bytes to move include protocol overhead and optional
    /// jitter. Congestion loss is applied *dynamically* while the flow is
    /// draining: whenever its bottleneck channel is shared by `k` flows,
    /// the goodput drops below the fair share by the [`LossModel`]
    /// inflation factor — so loss reacts to congestion arriving and
    /// leaving during the transfer, symmetric in start order.
    pub fn start_flow(
        &mut self,
        src: HostId,
        dst: HostId,
        route: Vec<ChannelId>,
        payload_mb: f64,
        tag: u64,
    ) -> FlowId {
        assert!(!route.is_empty(), "empty route {src}->{dst}");
        assert!(payload_mb > 0.0, "payload must be positive");
        for &c in &route {
            assert!(c < self.channels.len(), "bad channel {c}");
        }
        let jitter = if self.transfer_jitter > 0.0 {
            1.0 + self.rng.gen_f64_range(-self.transfer_jitter, self.transfer_jitter)
        } else {
            1.0
        };
        let effective = payload_mb * (1.0 + self.protocol_overhead) * jitter;
        let id = self.flows.len();
        // new ids are strictly increasing, so pushes keep both the active
        // list and every per-channel user list sorted ascending — the
        // order the water-filling freeze step depends on
        self.active_ids.push(id);
        self.flow_rate.push(0.0);
        for &c in &route {
            self.channel_users[c].push(id);
            self.dirty_channels.push(c);
        }
        self.flows.push(src, dst, &route, payload_mb, effective, self.now, tag)
    }

    /// Bring the `flow_rate` cache up to date. No-op when nothing changed
    /// since the last call — that is how same-horizon arrival/shift/drift
    /// batches collapse into one recompute.
    ///
    /// Incremental mode re-water-fills only the connected component(s) of
    /// channels/flows reachable from the dirty channels over the
    /// channel↔flow incidence. Restricting the pass is bit-exact because
    /// max-min components are arithmetically independent: every
    /// `remaining -= share` involves only component-local values, the
    /// bottleneck order within a component is preserved under any global
    /// interleaving, ties resolve by ascending channel id in both passes,
    /// users freeze in ascending flow order in both, and the negative-
    /// remaining clamp is idempotent. See docs/EXPERIMENTS.md §Perf/L5.
    fn ensure_rates(&mut self) {
        if self.full_rerate {
            // oracle: the legacy full pass, every event, regardless of
            // dirty state (recomputing a clean system reproduces the same
            // values, so the trajectory cannot differ)
            self.dirty_channels.clear();
            self.all_dirty = false;
            self.recompute_all_rates();
            return;
        }
        if !self.all_dirty && self.dirty_channels.is_empty() {
            return;
        }
        let nc = self.channels.len();
        let nf = self.flows.len();
        let s = &mut self.scratch;
        s.epoch += 1;
        let epoch = s.epoch;
        s.chan_mark.resize(nc, 0);
        s.chan_slot.resize(nc, 0);
        s.flow_mark.resize(nf, 0);
        s.frozen_mark.resize(nf, 0);
        s.comp_channels.clear();
        s.comp_flows.clear();
        s.queue.clear();
        if self.all_dirty {
            for (c, users) in self.channel_users.iter().enumerate() {
                if !users.is_empty() {
                    s.chan_mark[c] = epoch;
                    s.queue.push(c);
                }
            }
        } else {
            for &c in &self.dirty_channels {
                if s.chan_mark[c] != epoch {
                    s.chan_mark[c] = epoch;
                    s.queue.push(c);
                }
            }
        }
        self.dirty_channels.clear();
        self.all_dirty = false;
        // BFS over the channel↔flow incidence: everything transitively
        // sharing a (potential) bottleneck with a dirty channel
        while let Some(c) = s.queue.pop() {
            s.comp_channels.push(c);
            for &f in &self.channel_users[c] {
                if s.flow_mark[f] != epoch {
                    s.flow_mark[f] = epoch;
                    s.comp_flows.push(f);
                    for &c2 in self.flows.route(f) {
                        if s.chan_mark[c2] != epoch {
                            s.chan_mark[c2] = epoch;
                            s.queue.push(c2);
                        }
                    }
                }
            }
        }
        if s.comp_flows.is_empty() {
            return;
        }
        self.counters.rate_recomputes += 1;
        // ascending order is load-bearing: the bottleneck tie-break and
        // the freeze order must match the full pass's 0..n scans
        s.comp_channels.sort_unstable();
        s.comp_flows.sort_unstable();
        s.remaining.clear();
        s.unfrozen.clear();
        for (slot, &c) in s.comp_channels.iter().enumerate() {
            s.chan_slot[c] = slot as u32;
            s.remaining.push(self.caps[c]);
            s.unfrozen.push(self.channel_users[c].len());
        }
        // progressive filling restricted to the component
        let mut left = s.comp_flows.len();
        while left > 0 {
            let mut best_share = f64::INFINITY;
            let mut best = usize::MAX;
            for (i, (&rem, &un)) in s.remaining.iter().zip(&s.unfrozen).enumerate() {
                if un == 0 {
                    continue;
                }
                let share = rem / un as f64;
                if share < best_share {
                    best_share = share;
                    best = i;
                }
            }
            assert!(best != usize::MAX, "unfrozen flows with no channel");
            let bottleneck = s.comp_channels[best];
            for &f in &self.channel_users[bottleneck] {
                if s.frozen_mark[f] == epoch {
                    continue; // duplicate occurrence already frozen
                }
                s.frozen_mark[f] = epoch;
                self.flow_rate[f] = best_share;
                left -= 1;
                // subtraction is per route occurrence, like the full pass
                for &c2 in self.flows.route(f) {
                    let slot = s.chan_slot[c2] as usize;
                    s.remaining[slot] -= best_share;
                    s.unfrozen[slot] -= 1;
                }
            }
            // guard against fp drift (idempotent, so the full pass's
            // extra interleaved clamps cannot diverge from this one)
            for r in s.remaining.iter_mut() {
                if *r < 0.0 {
                    *r = 0.0;
                }
            }
        }
        // congestion-loss inflation at current occupancy (`share / infl`,
        // the exact op order of the full pass)
        for &f in &s.comp_flows {
            // an active flow occupies every channel on its own route, so
            // occupancy is >= 1 and an empty route cannot occur; 1 keeps
            // the loss model neutral if it ever did
            let bottleneck = self
                .flows
                .route(f)
                .iter()
                .map(|&c| self.channel_users[c].len())
                .max()
                .unwrap_or(1);
            let infl = self.loss.inflation(self.flows.payload_mb[f], bottleneck);
            self.flow_rate[f] /= infl;
        }
    }

    /// The legacy per-event path: one full [`max_min_rates`] pass over
    /// every active flow. Kept as the oracle the incremental re-rate is
    /// differentially tested against.
    fn recompute_all_rates(&mut self) {
        if self.active_ids.is_empty() {
            return;
        }
        self.counters.rate_recomputes += 1;
        let routes: Vec<&[usize]> = self.active_ids.iter().map(|&f| self.flows.route(f)).collect();
        let rates = max_min_rates(&self.caps, &routes);
        // current per-channel occupancy for the loss model
        let mut occupancy = vec![0usize; self.channels.len()];
        for route in &routes {
            for &c in *route {
                occupancy[c] += 1;
            }
        }
        for (i, (&f, r)) in self.active_ids.iter().zip(rates).enumerate() {
            // same >= 1 occupancy argument as the incremental pass
            let bottleneck = routes[i].iter().map(|&c| occupancy[c]).max().unwrap_or(1);
            let infl = self.loss.inflation(self.flows.payload_mb[f], bottleneck);
            self.flow_rate[f] = r / infl;
        }
    }

    /// Advance simulated time to `t`, draining flow bytes at current rates
    /// and completing flows along the way. `t` must be ≥ `now`. Scheduled
    /// channel changes before `t` re-rate the system mid-advance.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t >= self.now - 1e-12, "cannot rewind time {} -> {t}", self.now);
        while self.now < t {
            self.apply_due_changes();
            self.ensure_rates();
            self.counters.events += 1;
            if self.active_ids.is_empty() {
                // idle: jump change to change so drift/shifts land on time
                match self.next_change_at() {
                    Some(ts) if ts <= t => self.now = ts,
                    _ => self.now = t,
                }
                continue;
            }
            // earliest completion under current rates
            let mut next_done: Option<(f64, FlowId)> = None;
            for &f in &self.active_ids {
                let r = self.flow_rate[f];
                if r <= 0.0 {
                    continue;
                }
                let eta = self.now + self.flows.remaining_mb[f] / r;
                if next_done.map_or(true, |(best, _)| eta < best) {
                    next_done = Some((eta, f));
                }
            }
            let mut expected = match next_done {
                Some((eta, f)) if eta <= t => Some(f),
                _ => None,
            };
            let mut horizon = match next_done {
                Some((eta, _)) if eta <= t => eta,
                _ => t,
            };
            // a channel change before the horizon caps the constant-rate
            // window; no flow is forced complete at a change boundary
            if let Some(ts) = self.next_change_at() {
                if ts < horizon {
                    horizon = ts;
                    expected = None;
                }
            }
            let dt = horizon - self.now;
            for &f in &self.active_ids {
                let r = self.flow_rate[f];
                self.flows.remaining_mb[f] = (self.flows.remaining_mb[f] - r * dt).max(0.0);
            }
            // Force-complete the flow whose ETA set the horizon: when `now`
            // is large, `horizon - now` cancels catastrophically and can
            // leave a ~1e-12 MB remainder that never crosses the threshold,
            // livelocking the event loop (§Perf/L3 bugfix).
            if let Some(f) = expected {
                self.flows.remaining_mb[f] = 0.0;
            }
            self.now = horizon;
            // complete every drained flow (ties complete together);
            // 1e-9 MB ≈ 1 byte — physically nothing left to send
            let drained: Vec<FlowId> = self
                .active_ids
                .iter()
                .copied()
                .filter(|&f| self.flows.remaining_mb[f] <= 1e-9)
                .collect();
            for f in drained {
                self.complete(f);
            }
        }
    }

    /// Run until every flow has completed; returns the completion time of
    /// the last one (or `now` if nothing was active).
    ///
    /// Loops over [`NetSim::run_next_completion`] — the single-allocation
    /// event step (§Perf/L3) — so the barrier drive and the engine's
    /// per-event drive share one trajectory by construction.
    pub fn run_until_idle(&mut self) -> f64 {
        loop {
            if self.run_next_completion().is_empty() {
                return self.now;
            }
        }
    }

    /// Advance to the next flow-completion event and return the records
    /// that completed at it (rate ties complete together). Returns an
    /// empty vector when nothing is in flight.
    ///
    /// This is the per-flow completion-event API the round engine keys
    /// its slot state on. One call is exactly one iteration of
    /// [`NetSim::run_until_idle`] — a single max-min allocation per event
    /// (§Perf/L3), identical float trajectory — so engine-driven rounds
    /// stay bit-identical to the legacy global-barrier loop.
    pub fn run_next_completion(&mut self) -> Vec<FlowRecord> {
        let before = self.completed.len();
        loop {
            self.apply_due_changes();
            self.ensure_rates();
            if self.active_ids.is_empty() {
                return Vec::new();
            }
            self.counters.events += 1;
            let mut eta_min = f64::INFINITY;
            let mut f_min = usize::MAX;
            for &f in &self.active_ids {
                let r = self.flow_rate[f];
                if r > 0.0 {
                    let eta = self.now + self.flows.remaining_mb[f] / r;
                    if eta < eta_min {
                        eta_min = eta;
                        f_min = f;
                    }
                }
            }
            assert!(eta_min.is_finite(), "active flows with zero rate — capacity exhausted");
            // a scheduled channel change before the next completion
            // re-rates the system: drain to the change, apply, re-plan
            if let Some(ts) = self.next_change_at() {
                if ts < eta_min {
                    let dt = ts - self.now;
                    if dt > 0.0 {
                        for &f in &self.active_ids {
                            let r = self.flow_rate[f];
                            self.flows.remaining_mb[f] =
                                (self.flows.remaining_mb[f] - r * dt).max(0.0);
                        }
                    }
                    self.now = ts;
                    continue;
                }
            }
            let dt = eta_min - self.now;
            for &f in &self.active_ids {
                let r = self.flow_rate[f];
                self.flows.remaining_mb[f] = (self.flows.remaining_mb[f] - r * dt).max(0.0);
            }
            // see run_until_idle: force the horizon-setting flow to complete
            // so float cancellation cannot livelock the event loop
            self.flows.remaining_mb[f_min] = 0.0;
            self.now = eta_min;
            let drained: Vec<FlowId> = self
                .active_ids
                .iter()
                .copied()
                .filter(|&f| self.flows.remaining_mb[f] <= 1e-9)
                .collect();
            for f in drained {
                self.complete(f);
            }
            return self.completed[before..].to_vec();
        }
    }

    /// Next flow-completion time if the system runs undisturbed. Takes
    /// `&mut self` because it refreshes the lazy rate cache.
    pub fn next_completion_eta(&mut self) -> Option<f64> {
        self.ensure_rates();
        let mut eta = f64::INFINITY;
        for &f in &self.active_ids {
            let r = self.flow_rate[f];
            if r > 0.0 {
                eta = eta.min(self.now + self.flows.remaining_mb[f] / r);
            }
        }
        eta.is_finite().then_some(eta)
    }

    fn complete(&mut self, f: FlowId) {
        debug_assert!(!self.flows.done[f], "double-complete of flow {f}");
        if let Ok(pos) = self.active_ids.binary_search(&f) {
            self.active_ids.remove(pos);
        }
        let mut latency = 0.0;
        let (lo, hi) =
            (self.flows.route_offsets[f] as usize, self.flows.route_offsets[f + 1] as usize);
        for i in lo..hi {
            let c = self.flows.route_arena[i];
            latency += self.channels[c].latency_s;
            // drop one user entry per route occurrence; the remaining
            // users' shares just changed, so the channel goes dirty
            let users = &mut self.channel_users[c];
            if let Ok(pos) = users.binary_search(&f) {
                users.remove(pos);
            }
            self.dirty_channels.push(c);
        }
        self.flows.done[f] = true;
        self.completed.push(FlowRecord {
            flow: f,
            src: self.flows.src[f],
            dst: self.flows.dst[f],
            payload_mb: self.flows.payload_mb[f],
            start: self.flows.start[f],
            // delivery = drain completion + propagation along the route
            end: self.now + latency,
            tag: self.flows.tag[f],
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_host_net(cap: f64, lat: f64) -> NetSim {
        // channel 0: h0 -> h1, channel 1: h1 -> h0
        let ch = |label: &str| Channel { capacity_mbps: cap, latency_s: lat, label: label.into() };
        NetSim::new(vec![ch("0->1"), ch("1->0")], LossModel { gain: 0.0, size_scale_mb: 1.0 }, 0.0, 1)
    }

    #[test]
    fn single_transfer_time_is_size_over_rate_plus_latency() {
        let mut sim = two_host_net(10.0, 0.05);
        sim.start_flow(0, 1, vec![0], 20.0, 0);
        let t = sim.run_until_idle();
        assert!((t - 2.0).abs() < 1e-9, "drain time {t}");
        let rec = &sim.completed()[0];
        assert!((rec.end - 2.05).abs() < 1e-9, "delivery {}", rec.end);
        assert!((rec.duration() - 2.05).abs() < 1e-9);
    }

    #[test]
    fn two_flows_share_fairly_doubling_duration() {
        let mut sim = two_host_net(10.0, 0.0);
        sim.start_flow(0, 1, vec![0], 10.0, 0);
        sim.start_flow(0, 1, vec![0], 10.0, 1);
        sim.run_until_idle();
        for rec in sim.completed() {
            assert!((rec.duration() - 2.0).abs() < 1e-9, "{rec:?}");
            assert!((rec.bandwidth_mbps() - 5.0).abs() < 1e-9);
        }
    }

    #[test]
    fn staggered_flow_speeds_up_after_first_completes() {
        let mut sim = two_host_net(10.0, 0.0);
        sim.start_flow(0, 1, vec![0], 5.0, 0); // alone: 0.5s; shared: longer
        sim.advance_to(0.25);
        sim.start_flow(0, 1, vec![0], 10.0, 1);
        let t = sim.run_until_idle();
        // flow0 has 2.5MB left at t=.25 shared at 5MB/s -> done t=0.75
        // flow1 moves 2.5MB by 0.75, then 7.5MB alone at 10 -> done t=1.5
        assert!((t - 1.5).abs() < 1e-9, "t={t}");
        let d0 = sim.completed()[0].duration();
        let d1 = sim.completed()[1].duration();
        assert!((d0 - 0.75).abs() < 1e-9, "d0={d0}");
        assert!((d1 - 1.25).abs() < 1e-9, "d1={d1}");
    }

    #[test]
    fn byte_conservation_zero_loss() {
        let mut sim = two_host_net(8.0, 0.0);
        sim.start_flow(0, 1, vec![0], 4.0, 0);
        sim.start_flow(1, 0, vec![1], 4.0, 1);
        let t = sim.run_until_idle();
        // duplex: opposite directions don't contend
        assert!((t - 0.5).abs() < 1e-9);
    }

    #[test]
    fn loss_inflation_slows_contended_big_transfers() {
        let loss = LossModel { gain: 0.5, size_scale_mb: 10.0 };
        let ch = Channel { capacity_mbps: 10.0, latency_s: 0.0, label: "c".into() };
        let mut sim = NetSim::new(vec![ch], loss, 0.0, 1);
        sim.start_flow(0, 1, vec![0], 30.0, 0);
        sim.start_flow(0, 1, vec![0], 30.0, 1);
        sim.run_until_idle();
        let bw = sim.completed()[0].bandwidth_mbps();
        // fair share would be 5.0; inflation must push goodput below that
        assert!(bw < 5.0, "bw={bw}");
        // and small transfers should be inflated less
        let ch = Channel { capacity_mbps: 10.0, latency_s: 0.0, label: "c".into() };
        let mut sim2 = NetSim::new(vec![ch], loss, 0.0, 1);
        sim2.start_flow(0, 1, vec![0], 1.0, 0);
        sim2.start_flow(0, 1, vec![0], 1.0, 1);
        sim2.run_until_idle();
        let bw_small = sim2.completed()[0].bandwidth_mbps();
        // normalize by payload: compare goodput fractions of fair share
        assert!(bw_small / 5.0 > bw / 5.0, "small {bw_small} should beat large {bw}");
    }

    #[test]
    fn protocol_overhead_extends_duration() {
        let ch = Channel { capacity_mbps: 10.0, latency_s: 0.0, label: "c".into() };
        let mut sim = NetSim::new(vec![ch], LossModel { gain: 0.0, size_scale_mb: 1.0 }, 0.10, 1);
        sim.start_flow(0, 1, vec![0], 10.0, 0);
        let t = sim.run_until_idle();
        assert!((t - 1.1).abs() < 1e-9, "t={t}");
        // but reported bandwidth uses payload only
        assert!((sim.completed()[0].bandwidth_mbps() - 10.0 / 1.1).abs() < 1e-9);
    }

    #[test]
    fn advance_to_without_flows_just_moves_clock() {
        let mut sim = two_host_net(1.0, 0.0);
        sim.advance_to(5.0);
        assert_eq!(sim.now(), 5.0);
    }

    #[test]
    #[should_panic(expected = "cannot rewind")]
    fn advance_backwards_panics() {
        let mut sim = two_host_net(1.0, 0.0);
        sim.advance_to(1.0);
        sim.advance_to(0.5);
    }

    #[test]
    fn multihop_route_bottleneck() {
        // h0 -> r (10), r -> h1 (2): end-to-end rate 2
        let chans = vec![
            Channel { capacity_mbps: 10.0, latency_s: 0.0, label: "up".into() },
            Channel { capacity_mbps: 2.0, latency_s: 0.0, label: "down".into() },
        ];
        let mut sim = NetSim::new(chans, LossModel { gain: 0.0, size_scale_mb: 1.0 }, 0.0, 1);
        sim.start_flow(0, 1, vec![0, 1], 4.0, 0);
        let t = sim.run_until_idle();
        assert!((t - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ties_complete_together() {
        let mut sim = two_host_net(10.0, 0.0);
        sim.start_flow(0, 1, vec![0], 5.0, 0);
        sim.start_flow(0, 1, vec![0], 5.0, 1);
        sim.run_until_idle();
        assert_eq!(sim.completed().len(), 2);
        let e0 = sim.completed()[0].end;
        let e1 = sim.completed()[1].end;
        assert!((e0 - e1).abs() < 1e-9);
    }

    #[test]
    fn per_event_stepping_matches_run_until_idle() {
        // identical flow sets through both drive styles must produce
        // bit-identical clocks and completion records
        let build = || {
            let mut sim = two_host_net(10.0, 0.01);
            sim.start_flow(0, 1, vec![0], 5.0, 0);
            sim.start_flow(0, 1, vec![0], 9.0, 1);
            sim.start_flow(1, 0, vec![1], 3.0, 2);
            sim
        };
        let mut barrier = build();
        barrier.run_until_idle();
        let mut stepped = build();
        let mut seen = 0;
        loop {
            let events = stepped.run_next_completion();
            if events.is_empty() {
                break;
            }
            seen += events.len();
        }
        assert_eq!(seen, 3);
        assert_eq!(stepped.now().to_bits(), barrier.now().to_bits());
        assert_eq!(stepped.completed().len(), barrier.completed().len());
        for (a, b) in stepped.completed().iter().zip(barrier.completed()) {
            assert_eq!(a, b);
            assert_eq!(a.end.to_bits(), b.end.to_bits());
        }
    }

    #[test]
    fn run_next_completion_empty_when_idle() {
        let mut sim = two_host_net(10.0, 0.0);
        assert!(sim.run_next_completion().is_empty());
    }

    #[test]
    fn tags_are_preserved() {
        let mut sim = two_host_net(10.0, 0.0);
        sim.start_flow(0, 1, vec![0], 1.0, 77);
        sim.run_until_idle();
        assert_eq!(sim.completed()[0].tag, 77);
    }

    #[test]
    fn capacity_shift_slows_flow_mid_drain() {
        // 10 MB/s for 0.5 s (5 MB moved), then 2.5 MB/s: remaining 5 MB
        // takes 2 s -> completion at 2.5 s
        let mut sim = two_host_net(10.0, 0.0);
        sim.schedule_shifts(vec![ChannelShift {
            at_s: 0.5,
            channel: 0,
            capacity_mbps: 2.5,
            latency_s: 0.0,
        }]);
        sim.start_flow(0, 1, vec![0], 10.0, 0);
        let t = sim.run_until_idle();
        assert!((t - 2.5).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn capacity_shift_recovery_speeds_flow_back_up() {
        // degrade 10 -> 2 at t=0.2 (2 MB moved), recover at t=1.2 (2 MB
        // moved), remaining 6 MB at 10 MB/s -> done at 1.8 s
        let mut sim = two_host_net(10.0, 0.0);
        sim.schedule_shifts(vec![
            ChannelShift { at_s: 0.2, channel: 0, capacity_mbps: 2.0, latency_s: 0.0 },
            ChannelShift { at_s: 1.2, channel: 0, capacity_mbps: 10.0, latency_s: 0.0 },
        ]);
        sim.start_flow(0, 1, vec![0], 10.0, 0);
        let t = sim.run_until_idle();
        assert!((t - 1.8).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn latency_shift_applies_to_later_completions() {
        let mut sim = two_host_net(10.0, 0.05);
        sim.schedule_shifts(vec![ChannelShift {
            at_s: 0.5,
            channel: 0,
            capacity_mbps: 10.0,
            latency_s: 0.2,
        }]);
        sim.start_flow(0, 1, vec![0], 10.0, 0); // drains at t=1.0, after the shift
        sim.run_until_idle();
        let rec = &sim.completed()[0];
        assert!((rec.end - 1.2).abs() < 1e-9, "delivery {}", rec.end);
    }

    #[test]
    fn shift_before_any_flow_applies_to_new_flows() {
        let mut sim = two_host_net(10.0, 0.0);
        sim.schedule_shifts(vec![ChannelShift {
            at_s: 1.0,
            channel: 0,
            capacity_mbps: 5.0,
            latency_s: 0.0,
        }]);
        sim.advance_to(2.0); // idle advance crosses the shift
        sim.start_flow(0, 1, vec![0], 5.0, 0);
        let t = sim.run_until_idle();
        assert!((t - 3.0).abs() < 1e-9, "t={t}");
        assert_eq!(sim.channel(0).capacity_mbps, 5.0);
    }

    #[test]
    fn no_shift_trajectory_is_bit_identical() {
        let build = || {
            let mut sim = two_host_net(10.0, 0.01);
            sim.start_flow(0, 1, vec![0], 5.0, 0);
            sim.start_flow(0, 1, vec![0], 9.0, 1);
            sim.start_flow(1, 0, vec![1], 3.0, 2);
            sim
        };
        let mut plain = build();
        plain.run_until_idle();
        let mut with_machinery = build();
        // install a zero-amplitude drift (uninstalls itself) and no shifts
        with_machinery.set_drift(DriftProcess { amplitude: 0.0, interval_s: 1.0 }, 7);
        with_machinery.run_until_idle();
        assert_eq!(plain.now().to_bits(), with_machinery.now().to_bits());
        for (a, b) in plain.completed().iter().zip(with_machinery.completed()) {
            assert_eq!(a, b);
            assert_eq!(a.end.to_bits(), b.end.to_bits());
        }
    }

    #[test]
    fn drift_is_deterministic_and_bounded() {
        let run = |seed| {
            let mut sim = two_host_net(10.0, 0.0);
            sim.set_drift(DriftProcess { amplitude: 0.3, interval_s: 0.25 }, seed);
            for i in 0..6 {
                sim.start_flow(0, 1, vec![0], 4.0, i);
            }
            sim.run_until_idle();
            (sim.now(), sim.completed().to_vec())
        };
        let (t1, r1) = run(42);
        let (t2, r2) = run(42);
        assert_eq!(t1.to_bits(), t2.to_bits(), "same seed must replay");
        assert_eq!(r1, r2);
        let (t3, _) = run(43);
        assert!(t1 != t3, "different drift seed should perturb the trajectory");
        // capacity stays inside the drift envelope at all times
        let mut sim = two_host_net(10.0, 0.0);
        sim.set_drift(DriftProcess { amplitude: 0.3, interval_s: 0.25 }, 5);
        for k in 1..20 {
            sim.advance_to(0.25 * k as f64 + 0.01);
            let cap = sim.channel(0).capacity_mbps;
            assert!((7.0..=13.0).contains(&cap), "cap {cap} outside envelope");
        }
    }

    #[test]
    fn scripted_shift_rebases_the_drift_process() {
        // a 4x degradation must survive later drift ticks: the process
        // wiggles around the shifted base, not the install-time one
        let mut sim = two_host_net(20.0, 0.0);
        sim.set_drift(DriftProcess { amplitude: 0.1, interval_s: 0.25 }, 9);
        sim.schedule_shifts(vec![ChannelShift {
            at_s: 0.1,
            channel: 0,
            capacity_mbps: 5.0,
            latency_s: 0.0,
        }]);
        sim.advance_to(3.0); // crosses the shift and many drift ticks
        let cap = sim.channel(0).capacity_mbps;
        assert!(
            (4.5..=5.5).contains(&cap),
            "drift erased the scripted degradation: cap {cap}"
        );
    }

    #[test]
    fn route_ping_reflects_current_channel_state() {
        let mut sim = two_host_net(10.0, 0.05);
        let before = sim.route_ping_ms(&[0], 56);
        assert!((before - 100.0).abs() < 0.1, "2×50 ms propagation, got {before}");
        sim.schedule_shifts(vec![ChannelShift {
            at_s: 1.0,
            channel: 0,
            capacity_mbps: 2.5,
            latency_s: 0.2,
        }]);
        sim.advance_to(2.0);
        let after = sim.route_ping_ms(&[0], 56);
        assert!((after - 400.0).abs() < 0.5, "degraded ping {after}");
    }

    #[test]
    fn active_flow_bookkeeping_tracks_completions() {
        // the maintained active-id list (the §Perf/L4 fix) must shrink as
        // flows drain and stay consistent under interleaved launches
        let mut sim = two_host_net(10.0, 0.0);
        assert_eq!(sim.active_flow_count(), 0);
        sim.start_flow(0, 1, vec![0], 5.0, 0);
        sim.start_flow(0, 1, vec![0], 9.0, 1);
        assert_eq!(sim.active_flow_count(), 2);
        sim.run_next_completion();
        assert_eq!(sim.active_flow_count(), 1);
        sim.start_flow(1, 0, vec![1], 1.0, 2);
        assert_eq!(sim.active_flow_count(), 2);
        sim.run_until_idle();
        assert_eq!(sim.active_flow_count(), 0);
        assert_eq!(sim.completed().len(), 3);
    }

    #[test]
    #[should_panic(expected = "non-finite shift time")]
    fn non_finite_shift_time_is_rejected_up_front() {
        let mut sim = two_host_net(10.0, 0.0);
        sim.schedule_shifts(vec![ChannelShift {
            at_s: f64::NAN,
            channel: 0,
            capacity_mbps: 1.0,
            latency_s: 0.0,
        }]);
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn infinite_shift_capacity_is_rejected() {
        let mut sim = two_host_net(10.0, 0.0);
        sim.schedule_shifts(vec![ChannelShift {
            at_s: 1.0,
            channel: 0,
            capacity_mbps: f64::INFINITY,
            latency_s: 0.0,
        }]);
    }

    #[test]
    fn incremental_rerate_matches_full_oracle_with_changes() {
        // shifts, drift, staggered arrivals, shared bottlenecks: the
        // component-restricted re-rate and the full per-event pass must
        // produce one bit-identical trajectory
        let run = |full: bool| {
            let mut sim = two_host_net(10.0, 0.01);
            sim.set_full_rerate(full);
            sim.set_drift(DriftProcess { amplitude: 0.2, interval_s: 0.3 }, 11);
            sim.schedule_shifts(vec![
                ChannelShift { at_s: 0.4, channel: 0, capacity_mbps: 4.0, latency_s: 0.02 },
                ChannelShift { at_s: 0.4, channel: 1, capacity_mbps: 6.0, latency_s: 0.0 },
            ]);
            sim.start_flow(0, 1, vec![0], 5.0, 0);
            sim.start_flow(0, 1, vec![0], 9.0, 1);
            sim.start_flow(1, 0, vec![1], 3.0, 2);
            sim.advance_to(0.2);
            sim.start_flow(0, 1, vec![0, 1], 7.0, 3); // couples both channels
            let t = sim.run_until_idle();
            (t, sim.take_completed(), sim.counters())
        };
        let (t_inc, rec_inc, c_inc) = run(false);
        let (t_full, rec_full, c_full) = run(true);
        assert_eq!(t_inc.to_bits(), t_full.to_bits());
        assert_eq!(rec_inc.len(), rec_full.len());
        for (a, b) in rec_inc.iter().zip(&rec_full) {
            assert_eq!(a, b);
            assert_eq!(a.end.to_bits(), b.end.to_bits());
        }
        assert_eq!(c_inc.events, c_full.events, "same event decomposition");
        assert!(c_inc.rate_recomputes <= c_full.rate_recomputes);
    }

    #[test]
    fn counters_track_events_and_recomputes() {
        let mut sim = two_host_net(10.0, 0.0);
        assert_eq!(sim.counters(), SimCounters::default());
        sim.start_flow(0, 1, vec![0], 5.0, 0);
        sim.start_flow(0, 1, vec![0], 9.0, 1);
        sim.run_until_idle();
        let c = sim.counters();
        assert!(c.events >= 2, "two completions = at least two events, got {c:?}");
        assert!(c.rate_recomputes >= 1, "{c:?}");
        let mut merged = SimCounters::default();
        merged.merge(c);
        merged.merge(c);
        assert_eq!(merged.events, 2 * c.events);
        assert_eq!(merged.since(c), c);
    }

    #[test]
    fn disjoint_components_skip_recompute_for_untouched_flows() {
        // flows on channel 1 never share a bottleneck with channel 0:
        // completing channel-0 flows must not re-waterfill channel 1's
        let mut sim = two_host_net(10.0, 0.0);
        sim.start_flow(1, 0, vec![1], 100.0, 9); // long-lived, isolated
        for i in 0..8 {
            sim.start_flow(0, 1, vec![0], 1.0 + i as f64 * 0.5, i);
        }
        sim.run_until_idle();
        let c = sim.counters();
        // the full oracle would recompute once per event; the incremental
        // path must do strictly less work here than events processed
        assert!(
            c.rate_recomputes < c.events,
            "no recompute amortization: {c:?}"
        );
    }

    #[test]
    fn jitter_varies_durations_but_stays_bounded() {
        let ch = Channel { capacity_mbps: 10.0, latency_s: 0.0, label: "c".into() };
        let mut sim = NetSim::new(vec![ch], LossModel { gain: 0.0, size_scale_mb: 1.0 }, 0.0, 3);
        sim.set_transfer_jitter(0.1);
        for i in 0..10 {
            sim.start_flow(0, 1, vec![0], 10.0, i);
            sim.run_until_idle();
        }
        let durs: Vec<f64> = sim.completed().iter().map(|r| r.duration()).collect();
        assert!(durs.iter().any(|&d| (d - 1.0).abs() > 1e-6), "jitter had no effect");
        assert!(durs.iter().all(|&d| (0.9..=1.1).contains(&d)), "{durs:?}");
    }
}
