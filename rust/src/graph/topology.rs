//! Topology generators for the paper's four experimental underlays
//! (§IV-B, Fig 4) — Erdős–Rényi, Watts–Strogatz, Barabási–Albert,
//! Complete — plus three deterministic shapes (ring, star, balanced
//! binary tree) used by the pipelining benches and scenario sweeps.
//! The scale-out generator suite (random geometric, router hierarchy)
//! lives in [`crate::graph::generators`].
//!
//! Generators produce *structure only* (unit edge weights). The testbed
//! model (`netsim::testbed`) then assigns each node to a subnet and replaces
//! weights with simulated ping costs, mirroring how the paper measures edge
//! costs on its physical three-router deployment.

use super::Graph;
use crate::util::rng::Pcg64;

/// Topology families: the paper's four experimental underlays (in
/// [`TopologyKind::ALL`], the grid the tables run over) plus
/// deterministic extras for benches and scenario sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    /// G(n, p) random graph (Erdős–Rényi 1959).
    ErdosRenyi,
    /// Small-world ring-rewire model (Watts–Strogatz 1998).
    WattsStrogatz,
    /// Scale-free preferential attachment (Barabási–Albert 1999).
    BarabasiAlbert,
    /// Every pair connected.
    Complete,
    /// Cycle over all nodes (its MST is a path — the worst-case gossip
    /// diameter, where pipelining gains most).
    Ring,
    /// Node 0 connected to everyone else (maximal forwarding hot-spot).
    Star,
    /// Balanced binary tree rooted at node 0.
    BalancedTree,
    /// Path 0–1–…–(n−1): the deepest possible relay chain, where
    /// segment-granular cut-through forwarding gains most.
    Chain,
}

impl TopologyKind {
    /// The paper's evaluation grid (Tables III–V rows).
    pub const ALL: [TopologyKind; 4] = [
        TopologyKind::ErdosRenyi,
        TopologyKind::WattsStrogatz,
        TopologyKind::BarabasiAlbert,
        TopologyKind::Complete,
    ];

    /// Every supported family, including the deterministic bench shapes.
    pub const EXTENDED: [TopologyKind; 8] = [
        TopologyKind::ErdosRenyi,
        TopologyKind::WattsStrogatz,
        TopologyKind::BarabasiAlbert,
        TopologyKind::Complete,
        TopologyKind::Ring,
        TopologyKind::Star,
        TopologyKind::BalancedTree,
        TopologyKind::Chain,
    ];

    /// Display name matching the paper's table rows.
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::ErdosRenyi => "Erdos-Renyi",
            TopologyKind::WattsStrogatz => "Watts-Strogatz",
            TopologyKind::BarabasiAlbert => "Barabasi-Albert",
            TopologyKind::Complete => "Complete",
            TopologyKind::Ring => "Ring",
            TopologyKind::Star => "Star",
            TopologyKind::BalancedTree => "Balanced-Tree",
            TopologyKind::Chain => "Chain",
        }
    }

    pub fn parse(s: &str) -> Option<TopologyKind> {
        match s.to_ascii_lowercase().replace(['_', ' '], "-").as_str() {
            "erdos-renyi" | "er" => Some(TopologyKind::ErdosRenyi),
            "watts-strogatz" | "ws" | "watt" => Some(TopologyKind::WattsStrogatz),
            "barabasi-albert" | "ba" | "barabasi" => Some(TopologyKind::BarabasiAlbert),
            "complete" | "full" => Some(TopologyKind::Complete),
            "ring" | "cycle" => Some(TopologyKind::Ring),
            "star" => Some(TopologyKind::Star),
            "balanced-tree" | "tree" | "bt" => Some(TopologyKind::BalancedTree),
            "chain" | "path" | "line" => Some(TopologyKind::Chain),
            _ => None,
        }
    }
}

/// Generator parameters. Defaults follow the paper's N=10 setup: ER edge
/// probability 0.35 (sparse but connectable), WS ring degree 4 with 0.3
/// rewiring, BA attachment m=2, geometric radius 0.35 (unit square).
#[derive(Debug, Clone, Copy)]
pub struct TopologyParams {
    /// Erdős–Rényi edge probability.
    pub er_p: f64,
    /// Watts–Strogatz even ring degree k (also the intra-subnet lattice
    /// degree of the router-hierarchy generator).
    pub ws_k: usize,
    /// Watts–Strogatz rewiring probability β.
    pub ws_beta: f64,
    /// Barabási–Albert edges added per new node.
    pub ba_m: usize,
    /// Random-geometric connection radius in the unit square
    /// (`generators::random_geometric`).
    pub geo_radius: f64,
}

impl Default for TopologyParams {
    fn default() -> Self {
        TopologyParams { er_p: 0.35, ws_k: 4, ws_beta: 0.3, ba_m: 2, geo_radius: 0.35 }
    }
}

/// Generate a **connected** instance of the requested topology with unit
/// weights. Randomized families retry with fresh randomness until connected
/// (bounded), then fall back to augmenting the largest component — so the
/// function always returns a connected graph.
pub fn generate(kind: TopologyKind, n: usize, params: &TopologyParams, rng: &mut Pcg64) -> Graph {
    assert!(n >= 2, "need at least 2 nodes, got {n}");
    match kind {
        TopologyKind::Complete => complete(n),
        TopologyKind::ErdosRenyi => connected_or_augmented(rng, |rng| erdos_renyi(n, params.er_p, rng)),
        TopologyKind::WattsStrogatz => {
            connected_or_augmented(rng, |rng| watts_strogatz(n, params.ws_k, params.ws_beta, rng))
        }
        TopologyKind::BarabasiAlbert => barabasi_albert(n, params.ba_m, rng), // connected by construction
        TopologyKind::Ring => ring(n),
        TopologyKind::Star => star(n),
        TopologyKind::BalancedTree => balanced_tree(n),
        TopologyKind::Chain => chain(n),
    }
}

fn connected_or_augmented<F>(rng: &mut Pcg64, mut gen: F) -> Graph
where
    F: FnMut(&mut Pcg64) -> Graph,
{
    const MAX_TRIES: usize = 64;
    let mut g = gen(rng);
    for _ in 0..MAX_TRIES {
        if g.is_connected() {
            return g;
        }
        g = gen(rng);
    }
    augment_to_connected(g, rng)
}

/// Join components with random cross edges until connected.
fn augment_to_connected(mut g: Graph, rng: &mut Pcg64) -> Graph {
    loop {
        let comp = components(&g);
        let k = *comp.iter().max().unwrap() + 1;
        if k == 1 {
            return g;
        }
        // connect a random node of component 0 to a random node of another
        let a: Vec<usize> = (0..g.node_count()).filter(|&u| comp[u] == 0).collect();
        let b: Vec<usize> = (0..g.node_count()).filter(|&u| comp[u] != 0).collect();
        let (u, v) = (*rng.choose(&a), *rng.choose(&b));
        if !g.has_edge(u, v) {
            g.add_edge(u, v, 1.0);
        }
    }
}

/// Label each node with a connected-component id (0-based, discovery
/// order). Shared with `graph::generators`' connectivity augmentation.
pub(crate) fn components(g: &Graph) -> Vec<usize> {
    let n = g.node_count();
    let mut comp = vec![usize::MAX; n];
    let mut next = 0;
    for start in 0..n {
        if comp[start] != usize::MAX {
            continue;
        }
        comp[start] = next;
        let mut stack = vec![start];
        while let Some(u) = stack.pop() {
            for &(v, _) in g.neighbors(u) {
                if comp[v] == usize::MAX {
                    comp[v] = next;
                    stack.push(v);
                }
            }
        }
        next += 1;
    }
    comp
}

/// Cycle graph C_n (a single edge for n = 2).
pub fn ring(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for u in 0..n {
        let v = (u + 1) % n;
        if u != v && !g.has_edge(u, v) {
            g.add_edge(u, v, 1.0);
        }
    }
    g
}

/// Star graph: node 0 adjacent to every other node.
pub fn star(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for v in 1..n {
        g.add_edge(0, v, 1.0);
    }
    g
}

/// Balanced binary tree rooted at node 0 (heap indexing: node v's parent
/// is (v − 1) / 2).
pub fn balanced_tree(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for v in 1..n {
        g.add_edge((v - 1) / 2, v, 1.0);
    }
    g
}

/// Path graph P_n: node v adjacent to v+1.
pub fn chain(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for v in 1..n {
        g.add_edge(v - 1, v, 1.0);
    }
    g
}

/// Complete graph K_n.
pub fn complete(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            g.add_edge(u, v, 1.0);
        }
    }
    g
}

/// Erdős–Rényi G(n,p): each pair connected independently with probability p.
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Pcg64) -> Graph {
    assert!((0.0..=1.0).contains(&p), "p={p} out of [0,1]");
    let mut g = Graph::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            if rng.gen_bool(p) {
                g.add_edge(u, v, 1.0);
            }
        }
    }
    g
}

/// Watts–Strogatz: ring lattice of even degree `k`, each lattice edge
/// rewired with probability `beta` to a uniform random non-duplicate target.
pub fn watts_strogatz(n: usize, k: usize, beta: f64, rng: &mut Pcg64) -> Graph {
    assert!(k % 2 == 0, "WS ring degree k must be even, got {k}");
    assert!(k < n, "WS requires k < n (k={k}, n={n})");
    assert!((0.0..=1.0).contains(&beta));
    let mut g = Graph::new(n);
    // ring lattice: node i connects to i+1 ..= i+k/2 (mod n)
    for u in 0..n {
        for d in 1..=(k / 2) {
            let v = (u + d) % n;
            if !g.has_edge(u, v) {
                g.add_edge(u, v, 1.0);
            }
        }
    }
    // rewire: for each lattice edge (u, u+d), with prob beta replace by (u, w)
    // Collect first to avoid mutating while iterating.
    let originals: Vec<(usize, usize)> = g.edges().iter().map(|e| (e.u, e.v)).collect();
    let mut edge_set: std::collections::BTreeSet<(usize, usize)> = originals.iter().copied().collect();
    for (u, v) in originals {
        if !rng.gen_bool(beta) {
            continue;
        }
        // choose a new endpoint w != u, not already adjacent to u
        let mut w = rng.gen_range(n);
        let mut guard = 0;
        while w == u || edge_set.contains(&ord(u, w)) {
            w = rng.gen_range(n);
            guard += 1;
            if guard > 4 * n {
                break; // node saturated; keep original edge
            }
        }
        if guard > 4 * n {
            continue;
        }
        edge_set.remove(&ord(u, v));
        edge_set.insert(ord(u, w));
    }
    let mut out = Graph::new(n);
    for (u, v) in edge_set {
        out.add_edge(u, v, 1.0);
    }
    out
}

#[inline]
fn ord(a: usize, b: usize) -> (usize, usize) {
    if a < b {
        (a, b)
    } else {
        (b, a)
    }
}

/// Barabási–Albert preferential attachment: start from an (m+1)-clique,
/// each new node attaches to `m` distinct existing nodes with probability
/// proportional to their degree.
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Pcg64) -> Graph {
    assert!(m >= 1 && m < n, "BA requires 1 <= m < n (m={m}, n={n})");
    let seed = m + 1;
    let mut g = Graph::new(n);
    for u in 0..seed {
        for v in (u + 1)..seed {
            g.add_edge(u, v, 1.0);
        }
    }
    // repeated-endpoints list implements degree-proportional sampling
    let mut endpoints: Vec<usize> = Vec::new();
    for e in g.edges() {
        endpoints.push(e.u);
        endpoints.push(e.v);
    }
    for u in seed..n {
        let mut targets: Vec<usize> = Vec::with_capacity(m);
        let mut guard = 0;
        while targets.len() < m {
            let t = *rng.choose(&endpoints);
            if t != u && !targets.contains(&t) {
                targets.push(t);
            }
            guard += 1;
            if guard > 1000 * m {
                // fallback: fill with lowest-id nodes not yet chosen
                for t in 0..u {
                    if targets.len() == m {
                        break;
                    }
                    if !targets.contains(&t) {
                        targets.push(t);
                    }
                }
            }
        }
        for t in targets {
            g.add_edge(u, t, 1.0);
            endpoints.push(u);
            endpoints.push(t);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_has_all_pairs() {
        let g = complete(10);
        assert_eq!(g.edge_count(), 45);
        assert!(g.is_connected());
        assert_eq!(g.degree(3), 9);
    }

    #[test]
    fn er_density_tracks_p() {
        let mut rng = Pcg64::new(1);
        let n = 40;
        let g = erdos_renyi(n, 0.3, &mut rng);
        let max_edges = n * (n - 1) / 2;
        let density = g.edge_count() as f64 / max_edges as f64;
        assert!((density - 0.3).abs() < 0.08, "density {density}");
    }

    #[test]
    fn er_extremes() {
        let mut rng = Pcg64::new(2);
        assert_eq!(erdos_renyi(8, 0.0, &mut rng).edge_count(), 0);
        assert_eq!(erdos_renyi(8, 1.0, &mut rng).edge_count(), 28);
    }

    #[test]
    fn ws_no_rewire_is_ring_lattice() {
        let mut rng = Pcg64::new(3);
        let g = watts_strogatz(10, 4, 0.0, &mut rng);
        assert_eq!(g.edge_count(), 20); // n*k/2
        for u in 0..10 {
            assert_eq!(g.degree(u), 4);
        }
        assert!(g.is_connected());
    }

    #[test]
    fn ws_rewiring_preserves_edge_count() {
        let mut rng = Pcg64::new(4);
        let g = watts_strogatz(20, 4, 0.5, &mut rng);
        assert_eq!(g.edge_count(), 40);
    }

    #[test]
    fn ba_edge_count_and_connectivity() {
        let mut rng = Pcg64::new(5);
        let n = 30;
        let m = 2;
        let g = barabasi_albert(n, m, &mut rng);
        let seed = m + 1;
        assert_eq!(g.edge_count(), seed * (seed - 1) / 2 + (n - seed) * m);
        assert!(g.is_connected());
    }

    #[test]
    fn ba_has_hubs() {
        // scale-free: max degree should far exceed m
        let mut rng = Pcg64::new(6);
        let g = barabasi_albert(100, 2, &mut rng);
        let max_deg = (0..100).map(|u| g.degree(u)).max().unwrap();
        assert!(max_deg >= 8, "expected a hub, max degree {max_deg}");
    }

    #[test]
    fn generate_always_connected() {
        let mut rng = Pcg64::new(7);
        for kind in TopologyKind::ALL {
            for _ in 0..10 {
                let g = generate(kind, 10, &TopologyParams::default(), &mut rng);
                assert!(g.is_connected(), "{kind:?} produced disconnected graph");
                assert_eq!(g.node_count(), 10);
            }
        }
    }

    #[test]
    fn generate_connected_even_with_sparse_er() {
        // p low enough that raw draws are usually disconnected
        let mut rng = Pcg64::new(8);
        let params = TopologyParams { er_p: 0.02, ..Default::default() };
        let g = generate(TopologyKind::ErdosRenyi, 12, &params, &mut rng);
        assert!(g.is_connected());
    }

    #[test]
    fn names_and_parse_roundtrip() {
        for kind in TopologyKind::EXTENDED {
            assert_eq!(TopologyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(TopologyKind::parse("ws"), Some(TopologyKind::WattsStrogatz));
        assert_eq!(TopologyKind::parse("ring"), Some(TopologyKind::Ring));
        assert_eq!(TopologyKind::parse("tree"), Some(TopologyKind::BalancedTree));
        assert_eq!(TopologyKind::parse("nope"), None);
    }

    #[test]
    fn deterministic_shapes_have_expected_structure() {
        let r = ring(10);
        assert_eq!(r.edge_count(), 10);
        assert!(r.is_connected());
        assert!((0..10).all(|u| r.degree(u) == 2));

        let s = star(10);
        assert_eq!(s.edge_count(), 9);
        assert!(s.is_tree());
        assert_eq!(s.degree(0), 9);

        let t = balanced_tree(10);
        assert_eq!(t.edge_count(), 9);
        assert!(t.is_tree());
        assert_eq!(t.degree(0), 2);
        assert!(t.has_edge(0, 1) && t.has_edge(0, 2) && t.has_edge(1, 3));

        let c = chain(10);
        assert_eq!(c.edge_count(), 9);
        assert!(c.is_tree());
        assert_eq!(c.degree(0), 1);
        assert_eq!(c.degree(5), 2);
        assert!(c.has_edge(4, 5) && !c.has_edge(0, 9));

        // degenerate sizes stay connected
        assert!(ring(2).is_connected());
        assert_eq!(ring(2).edge_count(), 1);
        assert!(star(2).is_tree());
        assert!(chain(2).is_tree());
    }

    #[test]
    fn extended_generate_always_connected() {
        let mut rng = Pcg64::new(11);
        for kind in
            [TopologyKind::Ring, TopologyKind::Star, TopologyKind::BalancedTree, TopologyKind::Chain]
        {
            let g = generate(kind, 12, &TopologyParams::default(), &mut rng);
            assert!(g.is_connected(), "{kind:?}");
            assert_eq!(g.node_count(), 12);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let params = TopologyParams::default();
        let a = generate(TopologyKind::BarabasiAlbert, 15, &params, &mut Pcg64::new(99));
        let b = generate(TopologyKind::BarabasiAlbert, 15, &params, &mut Pcg64::new(99));
        assert_eq!(a.sorted_edges().len(), b.sorted_edges().len());
        for (ea, eb) in a.sorted_edges().iter().zip(b.sorted_edges().iter()) {
            assert_eq!((ea.u, ea.v), (eb.u, eb.v));
        }
    }
}
