//! Learning-dynamics scenario zoo: seeded Dirichlet(α) non-IID data
//! shards, partial per-round participation, heterogeneous per-node
//! compute (stragglers), and the D-PSGD neighbor-mixing algorithm.
//!
//! Everything here is deterministic per seed and dormant by default:
//! `dirichlet_alpha = ∞` reproduces today's one-stride-class-per-node
//! sharding, `participation = 1` originates every node every round,
//! `straggler_frac = 0` injects no compute holds, and `algo = fedavg`
//! keeps the full-dissemination fold. With the knobs at those defaults
//! the engine is pinned bit-identical to the pre-zoo pipeline in
//! `tests/engine_equivalence.rs`.

use crate::graph::Graph;
use crate::util::rng::Pcg64;

/// Distinct stride classes in the synthetic task (`synth_batch` maps
/// class `c` to stride `3 + 2c`); the Dirichlet shards distribute over
/// this class space.
pub const STRIDE_CLASSES: usize = 5;

/// Which DFL algorithm folds received payloads each round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AlgoKind {
    /// Full-dissemination FedAvg: every node folds every originated
    /// model of the round (the legacy path, bit-identical).
    #[default]
    FedAvg,
    /// D-PSGD-style neighbor mixing: each node mixes only with its tree
    /// neighbors' models under Metropolis–Hastings weights.
    DPsgd,
}

impl AlgoKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fedavg" => Some(AlgoKind::FedAvg),
            "dpsgd" | "d-psgd" => Some(AlgoKind::DPsgd),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::FedAvg => "fedavg",
            AlgoKind::DPsgd => "dpsgd",
        }
    }
}

/// One Gamma(α, 1) draw via Marsaglia–Tsang squeeze (with the
/// `Gamma(α) = Gamma(α+1)·U^{1/α}` boost below α = 1).
pub fn gamma_sample(rng: &mut Pcg64, alpha: f64) -> f64 {
    assert!(alpha > 0.0 && alpha.is_finite(), "gamma needs finite alpha > 0");
    if alpha < 1.0 {
        let u = rng.gen_f64().max(f64::MIN_POSITIVE);
        return gamma_sample(rng, alpha + 1.0) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.gen_normal(0.0, 1.0);
        let t = 1.0 + c * x;
        if t <= 0.0 {
            continue;
        }
        let v = t * t * t;
        let u = rng.gen_f64();
        if u < 1.0 - 0.0331 * (x * x) * (x * x) {
            return d * v;
        }
        if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// One Dirichlet(α, …, α) draw over `k` classes: non-negative shares
/// summing to 1. `α = ∞` returns the exact uniform vector (the
/// concentration limit), small α concentrates mass on few classes.
pub fn dirichlet_shares(rng: &mut Pcg64, alpha: f64, k: usize) -> Vec<f64> {
    assert!(k > 0, "dirichlet needs at least one class");
    assert!(alpha > 0.0, "dirichlet needs alpha > 0");
    if alpha.is_infinite() {
        return vec![1.0 / k as f64; k];
    }
    let mut g: Vec<f64> = (0..k).map(|_| gamma_sample(rng, alpha)).collect();
    let sum: f64 = g.iter().sum();
    if !(sum > 0.0 && sum.is_finite()) {
        // all draws underflowed (pathologically small alpha): fall back
        // to a single random class rather than dividing by zero
        let mut one_hot = vec![0.0; k];
        one_hot[rng.gen_range(k)] = 1.0;
        return one_hot;
    }
    for x in &mut g {
        *x /= sum;
    }
    g
}

/// Per-node Dirichlet(α) class shares, independently seeded per node so
/// a node's shard never depends on how many peers exist before it.
pub fn node_shares(alpha: f64, nodes: usize, classes: usize, seed: u64) -> Vec<Vec<f64>> {
    (0..nodes)
        .map(|u| {
            let mut rng =
                Pcg64::new(seed ^ 0xd1a1 ^ (u as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            dirichlet_shares(&mut rng, alpha, classes)
        })
        .collect()
}

/// The class distributions the trainer actually samples from: finite α
/// draws [`node_shares`]; `α = ∞` (the dormant default) reproduces
/// today's deterministic one-class-per-node assignment (`node %
/// STRIDE_CLASSES`), not the uniform mixture — flipping the knob on must
/// not silently change the baseline task.
pub fn trainer_shares(alpha: f64, nodes: usize, classes: usize, seed: u64) -> Vec<Vec<f64>> {
    if alpha.is_infinite() {
        return (0..nodes)
            .map(|u| {
                let mut s = vec![0.0; classes];
                s[u % classes] = 1.0;
                s
            })
            .collect();
    }
    node_shares(alpha, nodes, classes, seed)
}

/// One categorical draw from a share vector (inverse-CDF walk; the last
/// class absorbs fp dust).
pub fn sample_class(rng: &mut Pcg64, shares: &[f64]) -> usize {
    let x = rng.gen_f64();
    let mut acc = 0.0;
    for (c, &s) in shares.iter().enumerate() {
        acc += s;
        if x < acc {
            return c;
        }
    }
    shares.len() - 1
}

/// Per-round originator sets for partial participation (`--participation
/// p`): each round a seeded subset of `ceil(p·n)` nodes (never fewer
/// than one) trains and originates its payload; everyone else still
/// relays on the tree. Rounds beyond the plan originate everywhere.
#[derive(Debug, Clone)]
pub struct ParticipationPlan {
    sets: Vec<Vec<usize>>,
    mask: Vec<Vec<bool>>,
}

impl ParticipationPlan {
    /// Sample `rounds` participant sets over `nodes` nodes.
    pub fn sample(p: f64, nodes: usize, rounds: u64, seed: u64) -> Self {
        assert!(p > 0.0 && p <= 1.0, "participation must be in (0, 1]");
        assert!(nodes > 0, "participation needs nodes");
        let k = ((p * nodes as f64).ceil() as usize).clamp(1, nodes);
        let mut sets = Vec::with_capacity(rounds as usize);
        let mut mask = Vec::with_capacity(rounds as usize);
        for r in 0..rounds {
            let mut rng = Pcg64::new(seed ^ 0x9a47 ^ r.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut set = rng.sample_indices(nodes, k);
            set.sort_unstable();
            let mut m = vec![false; nodes];
            for &u in &set {
                m[u] = true;
            }
            sets.push(set);
            mask.push(m);
        }
        ParticipationPlan { sets, mask }
    }

    /// The sorted participant set of `round`, or `None` past the plan's
    /// horizon (⇒ everyone originates).
    pub fn participants(&self, round: u64) -> Option<&[usize]> {
        self.sets.get(round as usize).map(Vec::as_slice)
    }

    /// Does `node` train and originate in `round`?
    pub fn originates(&self, round: u64, node: usize) -> bool {
        match self.mask.get(round as usize) {
            Some(m) => m[node],
            None => true,
        }
    }

    /// Rounds the plan covers.
    pub fn rounds(&self) -> usize {
        self.sets.len()
    }
}

/// Per-node compute holds for straggler injection (`--straggler-frac` /
/// `--straggler-slowdown`): a seeded `ceil(frac·n)`-node subset "trains
/// slower", modeled as skipping the first `hold_slots[u]` transmit
/// opportunities of every round node `u` originates in — its own copy
/// enters the slot schedule that many color turns late, and the
/// pipelined overlap accounting absorbs (or exposes) the delay.
#[derive(Debug, Clone)]
pub struct StragglerPlan {
    /// Transmit opportunities node `u` sits out at each round start.
    pub hold_slots: Vec<u32>,
}

impl StragglerPlan {
    /// Sample the straggler subset and its holds. A slowdown of `s`
    /// means local compute takes `s×` the baseline, so the node misses
    /// `ceil(s − 1)` of its transmit turns.
    pub fn sample(frac: f64, slowdown: f64, nodes: usize, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&frac), "straggler_frac must be in [0, 1]");
        assert!(slowdown >= 1.0 && slowdown.is_finite(), "straggler_slowdown must be >= 1");
        let mut hold_slots = vec![0u32; nodes];
        let k = ((frac * nodes as f64).ceil() as usize).min(nodes);
        let hold = (slowdown - 1.0).ceil() as u32;
        if k > 0 && hold > 0 {
            let mut rng = Pcg64::new(seed ^ 0x57a6);
            for u in rng.sample_indices(nodes, k) {
                hold_slots[u] = hold;
            }
        }
        StragglerPlan { hold_slots }
    }

    /// The straggling nodes (non-zero holds), ascending.
    pub fn stragglers(&self) -> Vec<usize> {
        (0..self.hold_slots.len()).filter(|&u| self.hold_slots[u] > 0).collect()
    }

    /// A plan that holds nobody is structurally a no-op.
    pub fn is_noop(&self) -> bool {
        self.hold_slots.iter().all(|&h| h == 0)
    }
}

/// D-PSGD mixing step over the gossip tree: Metropolis–Hastings weights
/// `W_uv = 1 / (1 + max(deg u, deg v))` for each neighbor payload that
/// arrived, self-weight `1 − Σ W_uv` (row-stochastic, symmetric on the
/// full tree). `peers` may be any subset of `node`'s tree neighbors —
/// absent neighbors (non-participants, dropped copies) shift their mass
/// back onto the self-weight, which is exactly the lazy-update D-PSGD
/// convention for sampled participation.
pub fn dpsgd_mix(tree: &Graph, node: usize, own: &[f32], peers: &[(usize, &[f32])]) -> Vec<f32> {
    let du = tree.degree(node);
    let mut out: Vec<f64> = vec![0.0; own.len()];
    let mut self_w = 1.0f64;
    for &(v, params) in peers {
        debug_assert!(
            tree.neighbors(node).iter().any(|&(w, _)| w == v),
            "dpsgd_mix peers must be tree neighbors"
        );
        debug_assert_eq!(params.len(), own.len());
        let w = 1.0 / (1.0 + du.max(tree.degree(v)) as f64);
        self_w -= w;
        for (o, &x) in out.iter_mut().zip(params) {
            *o += w * x as f64;
        }
    }
    for (o, &x) in out.iter_mut().zip(own) {
        *o += self_w * x as f64;
    }
    out.into_iter().map(|x| x as f32).collect()
}

/// Accuracy proxy for the synthetic tasks: `1 / (1 + loss)` — monotone
/// in the loss, 1 at zero loss, comparable across scenario-zoo cells.
pub fn accuracy_proxy(loss: f64) -> f64 {
    1.0 / (1.0 + loss.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_sampler_matches_moments() {
        for &alpha in &[0.5, 1.0, 4.0] {
            let mut rng = Pcg64::new(7);
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| gamma_sample(&mut rng, alpha)).sum::<f64>() / n as f64;
            // Gamma(α, 1) has mean α
            assert!((mean - alpha).abs() < 0.1 * alpha.max(1.0), "α={alpha} mean={mean}");
        }
    }

    #[test]
    fn infinite_alpha_is_exact_uniform() {
        let mut rng = Pcg64::new(1);
        let s = dirichlet_shares(&mut rng, f64::INFINITY, 5);
        assert!(s.iter().all(|&x| x == 0.2));
    }

    #[test]
    fn trainer_shares_sentinel_is_one_hot() {
        let s = trainer_shares(f64::INFINITY, 7, STRIDE_CLASSES, 99);
        for (u, shares) in s.iter().enumerate() {
            for (c, &x) in shares.iter().enumerate() {
                assert_eq!(x, if c == u % STRIDE_CLASSES { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn sample_class_respects_support() {
        let mut rng = Pcg64::new(3);
        let shares = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(sample_class(&mut rng, &shares), 2);
        }
    }

    #[test]
    fn dpsgd_mix_is_convex_and_symmetric_on_a_path() {
        // path 0-1-2: deg = [1, 2, 1]
        let mut tree = Graph::new(3);
        tree.add_edge(0, 1, 1.0);
        tree.add_edge(1, 2, 1.0);
        let a = [1.0f32];
        let b = [4.0f32];
        let c = [7.0f32];
        // node 1 mixes both leaves with W = 1/3 each, keeps 1/3
        let m1 = dpsgd_mix(&tree, 1, &b, &[(0, &a), (2, &c)]);
        assert!((m1[0] - 4.0).abs() < 1e-6);
        // leaf 0 uses the same W_01 = 1/3 — symmetric weights
        let m0 = dpsgd_mix(&tree, 0, &a, &[(1, &b)]);
        assert!((m0[0] - 2.0).abs() < 1e-6);
        // no peers = identity (all mass on self)
        let lone = dpsgd_mix(&tree, 2, &c, &[]);
        assert_eq!(lone[0], 7.0);
    }
}
