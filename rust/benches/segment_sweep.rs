//! Whole-model vs segment-granular transfer time through the cut-through
//! engine: full-dissemination time for one MOSGU round across the Table II
//! model sizes on ring, star, balanced-tree and chain underlays.
//!
//! Whole = the legacy single-flow transfer plane (`segments = 1`).
//! Segmented = `k` serial segment flows per hop with cut-through relay
//! forwarding (segment `i` moves downstream while `i+1` is still in
//! flight upstream — see `coordinator::engine`). Emits one `JSON {...}`
//! line per cell for the bench trajectory; CI uploads them as the
//! `segment-sweep` artifact.
//!
//! ```bash
//! cargo bench --bench segment_sweep             # full Table II grid
//! cargo bench --bench segment_sweep -- --smoke  # CI smoke subset
//! ```

use mosgu::bench::section;
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::GossipSession;
use mosgu::dfl::models::{by_code, MODELS};
use mosgu::dfl::transfer::TransferPlan;
use mosgu::graph::topology::TopologyKind;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let models: Vec<_> = if smoke {
        // CI smoke: one small, the two large models the acceptance bar names
        ["v3s", "b2", "b3"].iter().map(|c| by_code(c).unwrap()).collect()
    } else {
        MODELS.iter().collect()
    };
    let node_counts: &[usize] = if smoke { &[10] } else { &[10, 16] };
    let segment_counts: &[usize] = if smoke { &[4] } else { &[2, 4, 8] };

    section(&format!(
        "segment sweep: whole-model vs cut-through segmented dissemination ({} mode)",
        if smoke { "smoke" } else { "full" }
    ));
    println!(
        "{:<16} {:>4} {:>6} {:>4} {:>12} {:>12} {:>9} {:>9}",
        "topology", "n", "model", "k", "whole_s", "segment_s", "speedup", "relays"
    );
    for kind in [
        TopologyKind::Ring,
        TopologyKind::Star,
        TopologyKind::BalancedTree,
        TopologyKind::Chain,
    ] {
        for &n in node_counts {
            let cfg = ExperimentConfig {
                topology: kind,
                nodes: n,
                latency_jitter: 0.0,
                ..Default::default()
            };
            let session = GossipSession::new(&cfg).expect("session");
            for spec in &models {
                let whole = session
                    .run_mosgu_round_planned(TransferPlan::whole(spec.capacity_mb), 1, 0.0);
                for &k in segment_counts {
                    let seg = session.run_mosgu_round_planned(
                        TransferPlan::segmented(spec.capacity_mb, k),
                        1,
                        0.0,
                    );
                    let speedup = whole.total_time_s / seg.total_time_s;
                    println!(
                        "{:<16} {:>4} {:>6} {:>4} {:>12.3} {:>12.3} {:>8.3}x {:>9}",
                        kind.name(),
                        n,
                        spec.code,
                        k,
                        whole.total_time_s,
                        seg.total_time_s,
                        speedup,
                        seg.relay_copies
                    );
                    println!(
                        "JSON {{\"bench\":\"segment_sweep\",\"topology\":\"{}\",\"n\":{},\
                         \"model\":\"{}\",\"model_mb\":{},\"segments\":{},\
                         \"whole_s\":{:.6},\"segmented_s\":{:.6},\"speedup\":{:.4},\
                         \"relay_copies\":{},\"whole_bw_mbps\":{:.4},\"seg_bw_mbps\":{:.4},\
                         \"seg_unit_bw_mbps\":{:.4}}}",
                        kind.name(),
                        n,
                        spec.code,
                        spec.capacity_mb,
                        k,
                        whole.total_time_s,
                        seg.total_time_s,
                        speedup,
                        seg.relay_copies,
                        whole.bandwidth_mbps(),
                        seg.bandwidth_mbps(),
                        seg.per_segment_bandwidth_mbps(),
                    );
                }
            }
        }
    }

    section("acceptance check: b2/b3 on chain + balanced-tree, k=4, n=10");
    let mut ok = true;
    for kind in [TopologyKind::Chain, TopologyKind::BalancedTree] {
        let cfg = ExperimentConfig {
            topology: kind,
            nodes: 10,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let session = GossipSession::new(&cfg).expect("session");
        for code in ["b2", "b3"] {
            let mb = by_code(code).unwrap().capacity_mb;
            let whole = session.run_mosgu_round_planned(TransferPlan::whole(mb), 1, 0.0);
            let seg = session.run_mosgu_round_planned(TransferPlan::segmented(mb, 4), 1, 0.0);
            let pass = seg.total_time_s < whole.total_time_s;
            ok &= pass;
            println!(
                "  {:<14} {code}: whole {:>9.3} s vs segmented {:>9.3} s -> {}",
                kind.name(),
                whole.total_time_s,
                seg.total_time_s,
                if pass { "faster (pass)" } else { "SLOWER (fail)" }
            );
        }
    }
    println!("acceptance: {}", if ok { "pass" } else { "FAIL" });
    if !ok {
        std::process::exit(1);
    }
}
