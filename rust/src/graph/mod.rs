//! Graph substrate: weighted undirected graphs, cost adjacency matrices
//! (paper §III-A, Fig 1), topology generators for the four experimental
//! underlays (paper §IV-B, Fig 4) plus the scale-out generator suite
//! (random geometric, router hierarchy — [`generators`]), and DOT export
//! for the figures.

pub mod dot;
pub mod generators;
pub mod matrix;
pub mod topology;

use std::collections::{BTreeSet, VecDeque};

/// Node identifier — dense indices `0..n`.
pub type NodeId = usize;

/// An undirected weighted edge. Canonical form keeps `u < v`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub u: NodeId,
    pub v: NodeId,
    /// Communication cost (the paper uses ping latency in ms; geographic
    /// distance or hop count are equally valid — §III-A).
    pub weight: f64,
}

impl Edge {
    pub fn new(u: NodeId, v: NodeId, weight: f64) -> Self {
        let (u, v) = if u <= v { (u, v) } else { (v, u) };
        Edge { u, v, weight }
    }

    /// The endpoint that is not `node`; panics if `node` is not an endpoint.
    pub fn other(&self, node: NodeId) -> NodeId {
        if node == self.u {
            self.v
        } else if node == self.v {
            self.u
        } else {
            panic!("node {node} is not an endpoint of {self:?}")
        }
    }
}

/// Undirected weighted graph in adjacency-list form.
///
/// Dense `0..n` node ids; parallel edges are rejected, self-loops are
/// rejected (neither occurs in the paper's overlays).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    n: usize,
    /// adj[u] = list of (neighbor, weight)
    adj: Vec<Vec<(NodeId, f64)>>,
    edges: Vec<Edge>,
}

impl Graph {
    pub fn new(n: usize) -> Self {
        Graph { n, adj: vec![Vec::new(); n], edges: Vec::new() }
    }

    pub fn node_count(&self) -> usize {
        self.n
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Add an undirected edge. Panics on self-loop, out-of-range id,
    /// duplicate edge, or a non-finite/negative weight — programming
    /// errors in this codebase. Non-finite weights are rejected *here*,
    /// at construction time, so graph consumers (MST orderings, slot
    /// budgets) never have to defend against NaN costs; online producers
    /// of weights (e.g. `coordinator::probe`) filter unusable readings
    /// before building a graph.
    pub fn add_edge(&mut self, u: NodeId, v: NodeId, weight: f64) {
        assert!(u != v, "self-loop {u}");
        assert!(u < self.n && v < self.n, "edge ({u},{v}) out of range n={}", self.n);
        assert!(!self.has_edge(u, v), "duplicate edge ({u},{v})");
        assert!(
            weight.is_finite() && weight >= 0.0,
            "edge ({u},{v}) weight must be finite and >= 0, got {weight}"
        );
        self.adj[u].push((v, weight));
        self.adj[v].push((u, weight));
        self.edges.push(Edge::new(u, v, weight));
    }

    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.adj.get(u).is_some_and(|l| l.iter().any(|&(w, _)| w == v))
    }

    pub fn weight(&self, u: NodeId, v: NodeId) -> Option<f64> {
        self.adj.get(u)?.iter().find(|&&(w, _)| w == v).map(|&(_, wt)| wt)
    }

    /// Neighbors of `u` with weights.
    pub fn neighbors(&self, u: NodeId) -> &[(NodeId, f64)] {
        &self.adj[u]
    }

    /// Neighbor ids only (sorted, for deterministic iteration).
    pub fn neighbor_ids(&self, u: NodeId) -> Vec<NodeId> {
        let mut ids: Vec<NodeId> = self.adj[u].iter().map(|&(v, _)| v).collect();
        ids.sort_unstable();
        ids
    }

    pub fn degree(&self, u: NodeId) -> usize {
        self.adj[u].len()
    }

    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|e| e.weight).sum()
    }

    /// True iff every node is reachable from node 0 (or the graph is empty).
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = VecDeque::from([0]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &(v, _) in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// True iff the graph is a tree: connected with exactly n-1 edges.
    pub fn is_tree(&self) -> bool {
        self.n > 0 && self.edges.len() == self.n - 1 && self.is_connected()
    }

    /// BFS hop distance from `src` to every node (`usize::MAX` = unreachable).
    pub fn bfs_hops(&self, src: NodeId) -> Vec<usize> {
        let mut dist = vec![usize::MAX; self.n];
        let mut queue = VecDeque::from([src]);
        dist[src] = 0;
        while let Some(u) = queue.pop_front() {
            for &(v, _) in &self.adj[u] {
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// Graph diameter in hops (`None` if disconnected or empty).
    pub fn diameter_hops(&self) -> Option<usize> {
        if self.n == 0 {
            return None;
        }
        let mut best = 0;
        for src in 0..self.n {
            let d = self.bfs_hops(src);
            let m = *d.iter().max().unwrap();
            if m == usize::MAX {
                return None;
            }
            best = best.max(m);
        }
        Some(best)
    }

    /// Dijkstra weighted shortest-path distances from `src`.
    pub fn dijkstra(&self, src: NodeId) -> Vec<f64> {
        let mut dist = vec![f64::INFINITY; self.n];
        dist[src] = 0.0;
        // (ordered) set keyed by (dist, node); f64 wrapped via total ordering
        let mut frontier: BTreeSet<(u64, NodeId)> = BTreeSet::new();
        frontier.insert((0, src));
        while let Some(&(dk, u)) = frontier.iter().next() {
            frontier.remove(&(dk, u));
            let du = f64::from_bits(dk);
            if du > dist[u] {
                continue;
            }
            for &(v, w) in &self.adj[u] {
                let cand = du + w;
                if cand < dist[v] {
                    if dist[v].is_finite() {
                        frontier.remove(&(dist[v].to_bits(), v));
                    }
                    dist[v] = cand;
                    frontier.insert((cand.to_bits(), v));
                }
            }
        }
        dist
    }

    /// Induced subgraph on `keep` (ascending, deduped), relabeling nodes
    /// to dense `0..keep.len()`. Returns the subgraph and the mapping
    /// `new_id -> old_id`. Used by the churn driver when members leave.
    pub fn induced(&self, keep: &[NodeId]) -> (Graph, Vec<NodeId>) {
        let mut ids: Vec<NodeId> = keep.to_vec();
        ids.sort_unstable();
        ids.dedup();
        assert!(ids.iter().all(|&u| u < self.n), "induced: id out of range");
        let mut new_of = vec![usize::MAX; self.n];
        for (new, &old) in ids.iter().enumerate() {
            new_of[old] = new;
        }
        let mut g = Graph::new(ids.len());
        for e in &self.edges {
            let (u, v) = (new_of[e.u], new_of[e.v]);
            if u != usize::MAX && v != usize::MAX {
                g.add_edge(u, v, e.weight);
            }
        }
        (g, ids)
    }

    /// Deterministic edge ordering (by weight then endpoints) — used by
    /// Kruskal and by golden tests.
    pub fn sorted_edges(&self) -> Vec<Edge> {
        let mut es = self.edges.clone();
        es.sort_by(|a, b| {
            a.weight
                .partial_cmp(&b.weight)
                .unwrap()
                .then(a.u.cmp(&b.u))
                .then(a.v.cmp(&b.v))
        });
        es
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path4() -> Graph {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(2, 3, 3.0);
        g
    }

    #[test]
    fn edge_canonical_order() {
        let e = Edge::new(5, 2, 1.0);
        assert_eq!((e.u, e.v), (2, 5));
        assert_eq!(e.other(2), 5);
        assert_eq!(e.other(5), 2);
    }

    #[test]
    #[should_panic(expected = "not an endpoint")]
    fn edge_other_panics_for_non_endpoint() {
        Edge::new(0, 1, 1.0).other(7);
    }

    #[test]
    fn add_edge_updates_both_adjacencies() {
        let g = path4();
        assert_eq!(g.edge_count(), 3);
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        assert_eq!(g.weight(1, 2), Some(2.0));
        assert_eq!(g.weight(0, 3), None);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.degree(3), 1);
    }

    #[test]
    #[should_panic(expected = "duplicate edge")]
    fn duplicate_edge_rejected() {
        let mut g = path4();
        g.add_edge(1, 0, 9.0);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn self_loop_rejected() {
        let mut g = Graph::new(2);
        g.add_edge(1, 1, 1.0);
    }

    #[test]
    fn connectivity_and_tree() {
        let g = path4();
        assert!(g.is_connected());
        assert!(g.is_tree());
        let mut g2 = Graph::new(4);
        g2.add_edge(0, 1, 1.0);
        assert!(!g2.is_connected());
        assert!(!g2.is_tree());
        // cycle: connected but not a tree
        let mut g3 = path4();
        g3.add_edge(0, 3, 1.0);
        assert!(g3.is_connected());
        assert!(!g3.is_tree());
    }

    #[test]
    fn bfs_hops_path() {
        let g = path4();
        assert_eq!(g.bfs_hops(0), vec![0, 1, 2, 3]);
        assert_eq!(g.diameter_hops(), Some(3));
    }

    #[test]
    fn diameter_none_when_disconnected() {
        let mut g = Graph::new(3);
        g.add_edge(0, 1, 1.0);
        assert_eq!(g.diameter_hops(), None);
    }

    #[test]
    fn dijkstra_prefers_lighter_path() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 3, 1.0);
        g.add_edge(0, 2, 5.0);
        g.add_edge(2, 3, 1.0);
        let d = g.dijkstra(0);
        assert_eq!(d[3], 2.0);
        assert_eq!(d[2], 3.0); // via 0-1-3-2, not the direct 5.0 edge
    }

    #[test]
    fn sorted_edges_deterministic() {
        let mut g = Graph::new(3);
        g.add_edge(0, 2, 3.0);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 1.0);
        let es = g.sorted_edges();
        assert_eq!((es[0].u, es[0].v), (0, 1));
        assert_eq!((es[1].u, es[1].v), (1, 2));
        assert_eq!((es[2].u, es[2].v), (0, 2));
    }

    #[test]
    fn induced_subgraph_relabels_and_keeps_weights() {
        let g = path4();
        let (sub, map) = g.induced(&[1, 2, 3]);
        assert_eq!(sub.node_count(), 3);
        assert_eq!(map, vec![1, 2, 3]);
        assert_eq!(sub.weight(0, 1), Some(2.0)); // old edge 1-2
        assert_eq!(sub.weight(1, 2), Some(3.0)); // old edge 2-3
        assert_eq!(sub.edge_count(), 2);
    }

    #[test]
    fn induced_drops_cross_edges() {
        let g = path4();
        let (sub, _) = g.induced(&[0, 2]);
        assert_eq!(sub.edge_count(), 0);
        assert!(!sub.is_connected());
    }

    #[test]
    fn neighbor_ids_sorted() {
        let mut g = Graph::new(4);
        g.add_edge(2, 3, 1.0);
        g.add_edge(2, 0, 1.0);
        g.add_edge(2, 1, 1.0);
        assert_eq!(g.neighbor_ids(2), vec![0, 1, 3]);
    }
}
