//! The engine refactor's contract tests:
//!
//! 1. the event-driven `RoundEngine` + `SimDriver` reproduces the legacy
//!    global-barrier slot loop **bit for bit** (same `total_time_s`,
//!    `slots`, transfer set) across every paper topology and under
//!    failure injection;
//! 2. `SimDriver` rounds are byte-identical across runs for a fixed seed;
//! 3. `LogicalDriver` through the engine replays the seed's untimed
//!    queue-trace semantics exactly (property-tested over random trees);
//! 4. multi-round pipelining strictly beats sequential execution on
//!    ring, star and balanced-tree topologies at n ≥ 10;
//! 5. `LiveDriver` runs the same protocol over a real in-memory
//!    transport mesh;
//! 6. the segment-granular transfer plane anchors to the whole-model
//!    engine: a `segments = 1` plan is **bit-identical** to the legacy
//!    path across all paper topologies, jitter, and failure injection,
//!    while `segments ≥ 4` cut-through forwarding strictly beats
//!    whole-model transfers for large models on deep trees (chain,
//!    balanced tree) at n ≥ 10;
//! 7. the scale-out plane anchors to the flat engine: single-subnet
//!    hierarchical planning reproduces the flat planner bit for bit, and
//!    the single-shard sharded simulator replays the flat engine's round
//!    **bit for bit** across topologies, jitter, and failure injection;
//! 8. the multi-tree plane anchors to the single-MST engine: an explicit
//!    `trees = 1` config carves no extra lanes and replays the default
//!    session **bit for bit** across every path (engine, segmented,
//!    sharded), a one-lane forest round is exactly the segmented engine
//!    on that tree, and `trees = 2` forests stay edge-disjoint, conserve
//!    bytes, and replay deterministically;
//! 9. the robustness plane anchors to the unhardened engine: a
//!    `--fold mean --adversary none` config (dormant attack/fold knobs
//!    set) replays the default session bit for bit across every path,
//!    jitter, and failure injection;
//! 10. the learning-dynamics scenario zoo anchors to the plain engine:
//!    comm-neutral knobs (`--dirichlet-alpha`, `--algo`, and a straggler
//!    slowdown with no stragglers sampled) replay the default session
//!    bit for bit across every path, jitter, and failure injection —
//!    only `--participation < 1` and a live straggler subset may touch
//!    the wire.

use mosgu::coloring::bfs_coloring;
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::broadcast::{tag_owner, tag_sender};
use mosgu::coordinator::engine::driver::{LiveDriver, LogicalDriver, SimDriver};
use mosgu::coordinator::engine::{RoundEngine, RoundOptions, TreeLane};
use mosgu::coordinator::example;
use mosgu::coordinator::gossip::{run_logical_round, GossipState, Send};
use mosgu::coordinator::schedule::{build_schedule, Schedule};
use mosgu::coordinator::session::GossipSession;
use mosgu::dfl::transfer::TransferPlan;
use mosgu::graph::topology::TopologyKind;
use mosgu::graph::Graph;
use mosgu::metrics::RoundMetrics;
use mosgu::netsim::FlowRecord;
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;

fn quiet_cfg(kind: TopologyKind) -> ExperimentConfig {
    ExperimentConfig { topology: kind, latency_jitter: 0.0, ..Default::default() }
}

/// The seed's original `run_mosgu_round`: global `run_until_idle` barrier
/// per slot, kept verbatim as the reference the engine must match.
fn legacy_mosgu_round(
    session: &GossipSession,
    model_mb: f64,
    seed: u64,
    failure_prob: f64,
) -> (Vec<FlowRecord>, f64, f64, usize) {
    let mut sim = session.testbed().netsim(seed);
    let mut state = GossipState::new(session.tree().clone(), 0);
    let mut rng = Pcg64::new(seed ^ 0xfa11);
    let schedule = session.schedule();
    let n = state.node_count();
    let max_slots = 8 * n + 64;
    let mut slots_used = 0;
    for slot in 0..max_slots {
        if state.is_complete() {
            break;
        }
        slots_used = slot + 1;
        let transmitters = schedule.transmitters(slot);
        let planned = state.plan_slot(&transmitters);
        if planned.is_empty() {
            continue;
        }
        let mut flow_meta = Vec::new();
        for (i, tx) in planned.iter().enumerate() {
            for &to in &tx.recipients {
                sim.start_flow(
                    tx.from,
                    to,
                    session.testbed().route(tx.from, to),
                    model_mb,
                    ((tx.from as u64) << 32) | tx.entry.key.owner as u64,
                );
                flow_meta.push((i, to));
            }
        }
        sim.run_until_idle();
        let mut order: Vec<usize> = (0..flow_meta.len()).collect();
        order.sort_by_key(|&j| (planned[flow_meta[j].0].from, flow_meta[j].1));
        let mut failed = vec![false; planned.len()];
        for j in order {
            let (i, to) = flow_meta[j];
            if failure_prob > 0.0 && rng.gen_bool(failure_prob) {
                failed[i] = true;
                continue;
            }
            let tx = &planned[i];
            state.deliver(Send { from: tx.from, to, key: tx.entry.key });
        }
        for (i, tx) in planned.iter().enumerate() {
            if failed[i] {
                state.requeue(tx);
            }
        }
    }
    assert!(state.is_complete(), "legacy reference round incomplete");
    let total = sim.now();
    let transfers = sim.take_completed();
    let exchange = transfers
        .iter()
        .filter(|r| tag_owner(r.tag) == tag_sender(r.tag))
        .map(|r| r.end)
        .fold(0.0, f64::max);
    (transfers, total, exchange, slots_used)
}

fn assert_metrics_match_legacy(m: &RoundMetrics, legacy: &(Vec<FlowRecord>, f64, f64, usize)) {
    let (transfers, total, exchange, slots) = legacy;
    assert_eq!(m.slots, *slots, "slot count diverged");
    assert_eq!(
        m.total_time_s.to_bits(),
        total.to_bits(),
        "total time diverged: {} vs {total}",
        m.total_time_s
    );
    assert_eq!(
        m.exchange_time_s.to_bits(),
        exchange.to_bits(),
        "exchange time diverged: {} vs {exchange}",
        m.exchange_time_s
    );
    assert_eq!(m.transfers.len(), transfers.len(), "transfer count diverged");
    for (a, b) in m.transfers.iter().zip(transfers) {
        assert_eq!(a, b, "transfer record diverged");
        assert_eq!(a.start.to_bits(), b.start.to_bits());
        assert_eq!(a.end.to_bits(), b.end.to_bits());
    }
}

#[test]
fn engine_matches_legacy_slot_loop_on_all_topologies() {
    for kind in TopologyKind::ALL {
        let session = GossipSession::new(&quiet_cfg(kind)).unwrap();
        for (model_mb, seed) in [(11.6, 1u64), (48.0, 7u64)] {
            let legacy = legacy_mosgu_round(&session, model_mb, seed, 0.0);
            let engine = session.run_mosgu_round(model_mb, seed, 0.0);
            assert_metrics_match_legacy(&engine, &legacy);
        }
    }
}

#[test]
fn engine_matches_legacy_slot_loop_with_jitter_and_failures() {
    // jittered testbed + failure injection: the rng draw sequence and the
    // retransmission schedule must replay identically
    let cfg = ExperimentConfig::default(); // latency_jitter = 0.08
    let session = GossipSession::new(&cfg).unwrap();
    for failure_prob in [0.0, 0.15] {
        let legacy = legacy_mosgu_round(&session, 14.0, 3, failure_prob);
        let engine = session.run_mosgu_round(14.0, 3, failure_prob);
        assert_metrics_match_legacy(&engine, &legacy);
    }
}

#[test]
fn segments_one_plan_is_bit_identical_to_legacy_on_all_topologies() {
    // the segment plane's compatibility anchor: an explicit one-segment
    // TransferPlan must replay the pre-segmentation engine bit for bit on
    // every paper topology
    for kind in TopologyKind::ALL {
        let session = GossipSession::new(&quiet_cfg(kind)).unwrap();
        for (model_mb, seed) in [(11.6, 1u64), (48.0, 7u64)] {
            let legacy = legacy_mosgu_round(&session, model_mb, seed, 0.0);
            let planned =
                session.run_mosgu_round_planned(TransferPlan::segmented(model_mb, 1), seed, 0.0);
            assert_metrics_match_legacy(&planned, &legacy);
            assert_eq!(planned.segments, 1);
            assert_eq!(planned.relay_copies, 0, "no cut-through under whole-model plans");
        }
    }
}

#[test]
fn segments_one_plan_is_bit_identical_under_jitter_and_failures() {
    // jittered testbed + failure injection through the segment-plan API:
    // rng draw sequence and retransmission schedule must replay exactly
    let cfg = ExperimentConfig::default(); // latency_jitter = 0.08
    let session = GossipSession::new(&cfg).unwrap();
    for failure_prob in [0.0, 0.15] {
        let legacy = legacy_mosgu_round(&session, 14.0, 3, failure_prob);
        let planned =
            session.run_mosgu_round_planned(TransferPlan::segmented(14.0, 1), 3, failure_prob);
        assert_metrics_match_legacy(&planned, &legacy);
    }
}

#[test]
fn segmented_cut_through_beats_whole_model_on_deep_trees() {
    // the refactor's payoff (and this PR's acceptance bar): pipelined
    // dissemination of large models (b2 = 36.8 MB, b3 = 48 MB) on chain
    // and balanced-tree underlays at n >= 10 is strictly faster with
    // segments >= 4 than with whole-model transfers
    for kind in [TopologyKind::Chain, TopologyKind::BalancedTree] {
        for n in [10usize, 12] {
            let cfg = ExperimentConfig { nodes: n, ..quiet_cfg(kind) };
            let session = GossipSession::new(&cfg).unwrap();
            for model_mb in [36.8, 48.0] {
                let whole =
                    session.run_mosgu_round_planned(TransferPlan::whole(model_mb), 1, 0.0);
                let seg = session.run_mosgu_round_planned(
                    TransferPlan::segmented(model_mb, 4),
                    1,
                    0.0,
                );
                assert!(
                    seg.total_time_s < whole.total_time_s,
                    "{kind:?} n={n} model={model_mb}: segmented {} vs whole {}",
                    seg.total_time_s,
                    whole.total_time_s
                );
                // same bytes delivered: every model crosses every edge once
                assert_eq!(seg.model_copy_count(), whole.transfer_count());
                assert_eq!(seg.transfer_count(), 4 * whole.transfer_count());
            }
        }
    }
}

#[test]
fn segmented_rounds_disseminate_completely_under_failures() {
    let session = GossipSession::new(&quiet_cfg(TopologyKind::Chain)).unwrap();
    let clean = session.run_mosgu_round_planned(TransferPlan::segmented(14.0, 4), 2, 0.0);
    let lossy = session.run_mosgu_round_planned(TransferPlan::segmented(14.0, 4), 2, 0.15);
    assert!(lossy.slots >= clean.slots, "failures must not shorten the round");
    assert!(lossy.transfer_count() >= clean.transfer_count());
    // deterministic replay with the same seed
    let again = session.run_mosgu_round_planned(TransferPlan::segmented(14.0, 4), 2, 0.15);
    assert_eq!(lossy.total_time_s.to_bits(), again.total_time_s.to_bits());
    assert_eq!(lossy.transfers, again.transfers);
}

fn assert_rounds_bit_identical(a: &RoundMetrics, b: &RoundMetrics, label: &str) {
    assert_eq!(a.slots, b.slots, "{label}: slot count diverged");
    assert_eq!(
        a.total_time_s.to_bits(),
        b.total_time_s.to_bits(),
        "{label}: total time diverged ({} vs {})",
        a.total_time_s,
        b.total_time_s
    );
    assert_eq!(
        a.exchange_time_s.to_bits(),
        b.exchange_time_s.to_bits(),
        "{label}: exchange time diverged"
    );
    assert_eq!(a.transfers.len(), b.transfers.len(), "{label}: transfer count diverged");
    for (x, y) in a.transfers.iter().zip(&b.transfers) {
        assert_eq!(x, y, "{label}: transfer record diverged");
        assert_eq!(x.start.to_bits(), y.start.to_bits(), "{label}");
        assert_eq!(x.end.to_bits(), y.end.to_bits(), "{label}");
    }
    assert_eq!(a.slot_timings.len(), b.slot_timings.len(), "{label}");
    for (x, y) in a.slot_timings.iter().zip(&b.slot_timings) {
        assert_eq!(x, y, "{label}: slot timing diverged");
        assert_eq!(x.start_s.to_bits(), y.start_s.to_bits(), "{label}");
        assert_eq!(x.end_s.to_bits(), y.end_s.to_bits(), "{label}");
    }
}

#[test]
fn single_shard_sharded_round_is_bit_identical_to_flat_engine() {
    // the scale-out plane's compatibility anchor: with one subnet the
    // sharded barrier runner must replay the flat event-driven engine
    // bit for bit on every paper topology
    for kind in TopologyKind::ALL {
        let cfg = ExperimentConfig { subnets: 1, ..quiet_cfg(kind) };
        let session = GossipSession::new(&cfg).unwrap();
        for (model_mb, seed) in [(11.6, 1u64), (48.0, 7u64)] {
            let flat = session.run_mosgu_round(model_mb, seed, 0.0);
            let sharded = session.run_sharded_round(model_mb, seed, 0.0, false);
            assert_rounds_bit_identical(&sharded, &flat, &format!("{kind:?} mb={model_mb}"));
        }
    }
}

#[test]
fn single_shard_sharded_round_is_bit_identical_under_jitter_and_failures() {
    // jittered testbed + failure injection: the rng draw sequences (per-
    // transfer jitter and the failure coins) must replay exactly
    let cfg = ExperimentConfig { subnets: 1, ..Default::default() }; // latency_jitter = 0.08
    let session = GossipSession::new(&cfg).unwrap();
    for failure_prob in [0.0, 0.15] {
        let flat = session.run_mosgu_round(14.0, 3, failure_prob);
        let sharded = session.run_sharded_round(14.0, 3, failure_prob, false);
        assert_rounds_bit_identical(&sharded, &flat, &format!("fp={failure_prob}"));
    }
}

#[test]
fn hierarchical_planning_single_subnet_is_bit_identical_to_flat() {
    // per-topology: a moderator planning hierarchically over a flat
    // (single-subnet) hierarchy publishes the flat bundle bit for bit
    use mosgu::coordinator::moderator::Moderator;
    use mosgu::graph::generators::Hierarchy;
    for kind in TopologyKind::ALL {
        let session = GossipSession::new(&quiet_cfg(kind)).unwrap();
        let cfg = session.config();
        let submit = |m: &mut Moderator| {
            for u in 0..10 {
                let peers: Vec<(usize, f64)> = session.costs().neighbors(u).to_vec();
                m.submit_report(u, &peers);
            }
        };
        let mut flat = Moderator::new(0, 10, cfg.mst, cfg.coloring);
        submit(&mut flat);
        let flat_bundle = flat.compute_schedule(14.0, 56, 1).unwrap().clone();
        let mut hier = Moderator::new(0, 10, cfg.mst, cfg.coloring);
        submit(&mut hier);
        let hier_bundle = hier
            .compute_schedule_hierarchical(&Hierarchy::flat(10), 14.0, 56, 1)
            .unwrap()
            .clone();
        assert_eq!(hier_bundle.tree.edge_count(), flat_bundle.tree.edge_count(), "{kind:?}");
        for e in flat_bundle.tree.edges() {
            assert!(hier_bundle.tree.has_edge(e.u, e.v), "{kind:?}: tree diverged");
            assert_eq!(
                hier_bundle.tree.weight(e.u, e.v).unwrap().to_bits(),
                e.weight.to_bits(),
                "{kind:?}: weight diverged"
            );
        }
        assert_eq!(
            hier_bundle.schedule.coloring.assignment(),
            flat_bundle.schedule.coloring.assignment(),
            "{kind:?}: coloring diverged"
        );
        assert_eq!(
            hier_bundle.schedule.slot_len_s.to_bits(),
            flat_bundle.schedule.slot_len_s.to_bits(),
            "{kind:?}: slot budget diverged"
        );
        assert_eq!(hier_bundle.neighbor_table, flat_bundle.neighbor_table, "{kind:?}");
    }
}

#[test]
fn compress_none_config_is_bit_identical_across_topologies_jitter_failures() {
    // the compression plane's compatibility anchor: an explicit
    // `compress = "none"` config (with dormant quant/topk knobs set)
    // must replay the default engine bit for bit — single rounds,
    // adaptive pipelines, and sharded rounds, under jitter and failure
    // injection — and still match the pre-compression legacy slot loop
    for kind in TopologyKind::ALL {
        for jitter in [0.0, 0.08] {
            let base = ExperimentConfig {
                topology: kind,
                latency_jitter: jitter,
                subnets: 1,
                ..Default::default()
            };
            let mut none = base.clone();
            none.compress = mosgu::dfl::compress::CompressionKind::None;
            none.quant_bits = 4; // dormant knobs must not leak
            none.topk_frac = 0.5;
            let s_base = GossipSession::new(&base).unwrap();
            let s_none = GossipSession::new(&none).unwrap();
            for failure_prob in [0.0, 0.15] {
                let a = s_base.run_mosgu_round(14.0, 3, failure_prob);
                let b = s_none.run_mosgu_round(14.0, 3, failure_prob);
                let label = format!("{kind:?} j={jitter} f={failure_prob}");
                assert_rounds_bit_identical(&b, &a, &label);
                // and against the seed's legacy loop (failure-free +
                // jittered cases both covered by the loop above)
                let legacy = legacy_mosgu_round(&s_none, 14.0, 3, failure_prob);
                assert_metrics_match_legacy(&b, &legacy);
            }
            let ap = s_base.run_adaptive_rounds(14.0, 2, 5);
            let bp = s_none.run_adaptive_rounds(14.0, 2, 5);
            assert_eq!(ap.total_time_s.to_bits(), bp.total_time_s.to_bits(), "{kind:?}");
            assert_eq!(ap.transfers, bp.transfers, "{kind:?}");
            let ash = s_base.run_sharded_round(14.0, 3, 0.15, false);
            let bsh = s_none.run_sharded_round(14.0, 3, 0.15, false);
            assert_rounds_bit_identical(&bsh, &ash, &format!("{kind:?} sharded"));
        }
    }
}

#[test]
fn fold_mean_adversary_none_is_bit_identical_across_topologies_jitter_failures() {
    // the robustness plane's compatibility anchor: `--fold mean
    // --adversary none` (with the dormant attack/fold knobs set) must
    // replay the default engine bit for bit — single rounds, adaptive
    // pipelines, and sharded rounds, under jitter and failure injection —
    // and still match the pre-robustness legacy slot loop
    for kind in TopologyKind::ALL {
        for jitter in [0.0, 0.08] {
            let base = ExperimentConfig {
                topology: kind,
                latency_jitter: jitter,
                subnets: 1,
                ..Default::default()
            };
            let mut pinned = base.clone();
            pinned.adversary = mosgu::dfl::adversary::AdversaryKind::None;
            pinned.fold = mosgu::dfl::robust::FoldKind::Mean;
            pinned.adversary_frac = 0.3; // dormant knobs must not leak
            pinned.poison_scale = -5.0;
            pinned.drop_edge_frac = 0.5;
            pinned.fold_f = 3;
            let s_base = GossipSession::new(&base).unwrap();
            let s_pin = GossipSession::new(&pinned).unwrap();
            assert!(s_pin.adversary().is_none(), "{kind:?}: no scenario without an attack");
            assert!(s_pin.fold_policy().is_mean(), "{kind:?}: mean fold must stay mean");
            for failure_prob in [0.0, 0.15] {
                let a = s_base.run_mosgu_round(14.0, 3, failure_prob);
                let b = s_pin.run_mosgu_round(14.0, 3, failure_prob);
                let label = format!("{kind:?} j={jitter} f={failure_prob}");
                assert_rounds_bit_identical(&b, &a, &label);
                let legacy = legacy_mosgu_round(&s_pin, 14.0, 3, failure_prob);
                assert_metrics_match_legacy(&b, &legacy);
            }
            let ap = s_base.run_adaptive_rounds(14.0, 2, 5);
            let bp = s_pin.run_adaptive_rounds(14.0, 2, 5);
            assert_eq!(ap.total_time_s.to_bits(), bp.total_time_s.to_bits(), "{kind:?}");
            assert_eq!(ap.transfers, bp.transfers, "{kind:?}");
            assert_eq!(ap.received, bp.received, "{kind:?}: fold inputs diverged");
            let ash = s_base.run_sharded_round(14.0, 3, 0.15, false);
            let bsh = s_pin.run_sharded_round(14.0, 3, 0.15, false);
            assert_rounds_bit_identical(&bsh, &ash, &format!("{kind:?} sharded"));
        }
    }
}

#[test]
fn comm_neutral_zoo_knobs_are_bit_identical_across_topologies_jitter_failures() {
    // the scenario zoo's compatibility anchor: knobs that change what
    // nodes *learn* but not what they *transmit* — a finite Dirichlet
    // alpha, the D-PSGD fold, and a straggler slowdown with a zero
    // straggler fraction — must replay the default engine bit for bit.
    // Only `participation < 1` and a sampled straggler subset are allowed
    // to reshape the wire (covered by tests/learning_dynamics.rs).
    for kind in TopologyKind::ALL {
        for jitter in [0.0, 0.08] {
            let base = ExperimentConfig {
                topology: kind,
                latency_jitter: jitter,
                subnets: 1,
                ..Default::default()
            };
            let mut pinned = base.clone();
            pinned.dirichlet_alpha = 0.5; // learning-side knobs must not leak
            pinned.algo = mosgu::dfl::data::AlgoKind::DPsgd;
            pinned.participation = 1.0; // explicit defaults stay dormant
            pinned.straggler_frac = 0.0;
            pinned.straggler_slowdown = 9.0; // meaningless without stragglers
            pinned.validate().expect("the pinned zoo config must validate");
            let s_base = GossipSession::new(&base).unwrap();
            let s_pin = GossipSession::new(&pinned).unwrap();
            assert!(s_pin.participation_plan(3).is_none(), "{kind:?}: p = 1 must be dormant");
            assert!(s_pin.straggler_plan().is_none(), "{kind:?}: frac 0 must be dormant");
            for failure_prob in [0.0, 0.15] {
                let a = s_base.run_mosgu_round(14.0, 3, failure_prob);
                let b = s_pin.run_mosgu_round(14.0, 3, failure_prob);
                let label = format!("{kind:?} j={jitter} f={failure_prob}");
                assert_rounds_bit_identical(&b, &a, &label);
                let legacy = legacy_mosgu_round(&s_pin, 14.0, 3, failure_prob);
                assert_metrics_match_legacy(&b, &legacy);
            }
            let ap = s_base.run_adaptive_rounds(14.0, 2, 5);
            let bp = s_pin.run_adaptive_rounds(14.0, 2, 5);
            assert_eq!(ap.total_time_s.to_bits(), bp.total_time_s.to_bits(), "{kind:?}");
            assert_eq!(ap.transfers, bp.transfers, "{kind:?}");
            assert_eq!(ap.received, bp.received, "{kind:?}: fold inputs diverged");
            let pp = s_base.run_pipelined_rounds(14.0, 2, 5);
            let qp = s_pin.run_pipelined_rounds(14.0, 2, 5);
            assert_eq!(pp.transfers, qp.transfers, "{kind:?} pipelined");
            assert_eq!(pp.received, qp.received, "{kind:?} pipelined fold inputs");
        }
    }
}

#[test]
fn full_rerate_oracle_matches_incremental_through_the_engine() {
    // the incremental re-rate's engine-level anchor: a SimDriver whose
    // simulator is forced into full-water-filling oracle mode must run
    // the identical round float for float — only the work counters in
    // RoundMetrics::sim may differ (the oracle recomputes at least as
    // often). Complements the simulator-level differential suite in
    // tests/netsim_rerate.rs.
    for kind in TopologyKind::ALL {
        let session = GossipSession::new(&quiet_cfg(kind)).unwrap();
        let base = session.run_mosgu_round(14.0, 3, 0.0);
        let mut driver = SimDriver::new(session.testbed(), 3);
        driver.sim_mut().set_full_rerate(true);
        let mut engine = RoundEngine::new(&mut driver, session.schedule());
        let mut state = GossipState::new(session.tree().clone(), 0);
        let m = engine.run_round(&mut state, RoundOptions::reliable(14.0, 144), |_, _| {});
        assert_rounds_bit_identical(&m, &base, &format!("{kind:?} oracle"));
        assert_eq!(m.sim.events, base.sim.events, "{kind:?}: event walks diverged");
        assert!(
            m.sim.rate_recomputes >= base.sim.rate_recomputes,
            "{kind:?}: oracle must recompute at least as often ({} vs {})",
            m.sim.rate_recomputes,
            base.sim.rate_recomputes
        );
    }
}

#[test]
fn sim_rounds_are_byte_identical_for_fixed_seed() {
    let session = GossipSession::new(&quiet_cfg(TopologyKind::WattsStrogatz)).unwrap();
    let a = session.run_mosgu_round(14.0, 42, 0.1);
    let b = session.run_mosgu_round(14.0, 42, 0.1);
    assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
    assert_eq!(a.exchange_time_s.to_bits(), b.exchange_time_s.to_bits());
    assert_eq!(a.slots, b.slots);
    assert_eq!(a.transfers, b.transfers);
    assert_eq!(a.slot_timings.len(), b.slot_timings.len());
    for (x, y) in a.slot_timings.iter().zip(&b.slot_timings) {
        assert_eq!(x, y);
        assert_eq!(x.start_s.to_bits(), y.start_s.to_bits());
        assert_eq!(x.end_s.to_bits(), y.end_s.to_bits());
    }
}

/// The seed's original untimed slot loop, kept as the reference for the
/// engine's `LogicalDriver` mode.
fn reference_logical_trace(
    state: &mut GossipState,
    schedule: &Schedule,
    max_slots: usize,
) -> (Vec<(usize, Vec<Send>)>, Vec<Vec<usize>>) {
    let n = state.node_count();
    let mut slots = Vec::new();
    let mut held_counts = Vec::new();
    for slot in 0..max_slots {
        if state.is_complete() {
            break;
        }
        let color = schedule.color_of_slot(slot);
        let transmitters = schedule.transmitters(slot);
        let planned = state.plan_slot(&transmitters);
        let sends = GossipState::sorted_sends(&planned);
        for &s in &sends {
            state.deliver(s);
        }
        slots.push((color, sends));
        held_counts.push((0..n).map(|u| state.queue(u).held_count()).collect());
    }
    assert!(state.is_complete(), "reference trace incomplete");
    (slots, held_counts)
}

fn random_tree(n: usize, rng: &mut Pcg64) -> Graph {
    let mut g = Graph::new(n);
    for v in 1..n {
        let u = rng.gen_range(v);
        g.add_edge(u, v, rng.gen_f64_range(1.0, 50.0));
    }
    g
}

#[test]
fn logical_engine_replays_reference_trace_on_random_trees() {
    check("engine replays untimed trace", 120, |rng| {
        let n = 2 + rng.gen_range(24);
        let tree = random_tree(n, rng);
        let schedule =
            Schedule { coloring: bfs_coloring(&tree), slot_len_s: 1.0, first_color: 1 };
        let max_slots = 16 * n + 64;

        let mut ref_state = GossipState::new(tree.clone(), 0);
        let (ref_slots, ref_held) = reference_logical_trace(&mut ref_state, &schedule, max_slots);

        let mut eng_state = GossipState::new(tree, 0);
        let trace = run_logical_round(&mut eng_state, &schedule, |_| 'x', max_slots);

        if trace.slots.len() != ref_slots.len() {
            return Err(format!(
                "slot count {} vs reference {}",
                trace.slots.len(),
                ref_slots.len()
            ));
        }
        for (i, slot) in trace.slots.iter().enumerate() {
            let (ref_color, ref_sends) = &ref_slots[i];
            if slot.color != *ref_color {
                return Err(format!("slot {i} color {} vs {ref_color}", slot.color));
            }
            if &slot.sends != ref_sends {
                return Err(format!("slot {i} sends diverged"));
            }
            // every label is one char, so row string length == held count
            for (u, row) in trace.rows[i].iter().enumerate() {
                if row.len() != ref_held[i][u] {
                    return Err(format!(
                        "slot {i} node {u}: holds {} vs reference {}",
                        row.len(),
                        ref_held[i][u]
                    ));
                }
            }
        }
        for u in 0..eng_state.node_count() {
            if eng_state.held_string(u, |_| 'x') != ref_state.held_string(u, |_| 'x') {
                return Err(format!("node {u} final holdings diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn table1_trace_still_exact_through_engine() {
    // belt and braces beside tests/table1_trace.rs: the engine-backed
    // logical round still lands the paper's final row and slot count
    let schedule = build_schedule(
        &example::paper_example_graph(),
        example::paper_example_coloring(),
        14.0,
        56,
        example::RED,
    );
    let mut state = GossipState::new(example::paper_example_mst(), 0);
    let trace = run_logical_round(&mut state, &schedule, example::label, 64);
    assert_eq!(trace.slots.len(), 23);
    assert_eq!(state.held_string(example::K, example::label), "KGIFBECHDA");
}

#[test]
fn pipelining_strictly_beats_sequential_on_ring_star_tree() {
    let rounds = 3u64;
    for kind in [TopologyKind::Ring, TopologyKind::Star, TopologyKind::BalancedTree] {
        for n in [10usize, 12] {
            let cfg = ExperimentConfig { nodes: n, ..quiet_cfg(kind) };
            let session = GossipSession::new(&cfg).unwrap();
            let sequential: f64 =
                (0..rounds).map(|_| session.run_mosgu_round(14.0, 1, 0.0).total_time_s).sum();
            let pipelined = session.run_pipelined_rounds(14.0, rounds, 1);
            assert_eq!(pipelined.rounds.len(), rounds as usize, "{kind:?} n={n}");
            assert!(
                pipelined.total_time_s < sequential,
                "{kind:?} n={n}: pipelined {} must beat sequential {}",
                pipelined.total_time_s,
                sequential
            );
            // every round still disseminates completely
            for (r, orders) in pipelined.received.iter().enumerate() {
                for (u, order) in orders.iter().enumerate() {
                    assert_eq!(order.len(), n - 1, "{kind:?} round {r} node {u}");
                }
            }
        }
    }
}

#[test]
fn adaptive_rounds_with_static_plane_are_bit_identical_across_topologies() {
    // the adaptive plane's compatibility anchor: --drift 0 --probe-every 0
    // (the defaults) must replay the PR-2 pipelined engine bit for bit on
    // every paper topology, with the default latency jitter enabled
    for kind in TopologyKind::ALL {
        let cfg = ExperimentConfig { topology: kind, ..Default::default() }; // jitter 0.08
        assert_eq!(cfg.drift, 0.0);
        assert_eq!(cfg.probe_every, 0);
        let session = GossipSession::new(&cfg).unwrap();
        let base = session.run_pipelined_rounds(14.0, 3, 5);
        let adaptive = session.run_adaptive_rounds(14.0, 3, 5);
        assert!(adaptive.replans.is_empty(), "{kind:?}: static plane must never replan");
        assert_eq!(adaptive.slots, base.slots, "{kind:?}");
        assert_eq!(
            adaptive.total_time_s.to_bits(),
            base.total_time_s.to_bits(),
            "{kind:?}: total time diverged"
        );
        assert_eq!(adaptive.transfers.len(), base.transfers.len(), "{kind:?}");
        for (a, b) in adaptive.transfers.iter().zip(&base.transfers) {
            assert_eq!(a, b, "{kind:?}: transfer diverged");
            assert_eq!(a.end.to_bits(), b.end.to_bits());
        }
        assert_eq!(adaptive.rounds.len(), base.rounds.len());
        for (a, b) in adaptive.rounds.iter().zip(&base.rounds) {
            assert_eq!(a.done_s.to_bits(), b.done_s.to_bits(), "{kind:?}: phase diverged");
            assert_eq!(a.first_seed_s.to_bits(), b.first_seed_s.to_bits());
            assert_eq!((a.first_slot, a.last_slot), (b.first_slot, b.last_slot));
        }
        assert_eq!(adaptive.received, base.received, "{kind:?}: fold inputs diverged");
    }
}

#[test]
fn adaptive_noop_hook_is_bit_identical_under_failures_and_segments() {
    // engine-level: run_pipelined vs run_pipelined_adaptive with a no-op
    // hook, under failure injection and under a segmented plan — future
    // edits to the adaptive path must not fork the static trajectory
    let cfg = ExperimentConfig::default(); // jitter 0.08
    let session = GossipSession::new(&cfg).unwrap();
    let tree = session.tree().clone();
    let mk_opts = |plan: TransferPlan| mosgu::coordinator::engine::PipelineOptions {
        rounds: 3,
        plan,
        max_slots: 4 * (8 * 10 + 64),
        failure_prob: 0.15,
        failure_rng: Pcg64::new(11),
        drops: None,
        participants: None,
        stragglers: None,
    };
    for plan in [TransferPlan::whole(14.0), TransferPlan::segmented(36.8, 4)] {
        let mut d1 = SimDriver::new(session.testbed(), 9);
        let mut e1 = RoundEngine::new(&mut d1, session.schedule());
        let plain = e1.run_pipelined(&tree, mk_opts(plan));
        let mut d2 = SimDriver::new(session.testbed(), 9);
        let mut e2 = RoundEngine::new(&mut d2, session.schedule());
        let adaptive = e2.run_pipelined_adaptive(&tree, mk_opts(plan), |_, _, _| None);
        assert_eq!(plain.total_time_s.to_bits(), adaptive.total_time_s.to_bits());
        assert_eq!(plain.slots, adaptive.slots);
        assert_eq!(plain.transfers, adaptive.transfers);
        assert_eq!(plain.received, adaptive.received);
        assert!(adaptive.replans.is_empty());
    }
}

#[test]
fn live_driver_runs_the_same_protocol_over_a_memory_mesh() {
    let schedule = build_schedule(
        &example::paper_example_graph(),
        example::paper_example_coloring(),
        14.0,
        56,
        example::RED,
    );
    let mut driver = LiveDriver::new(mosgu::transport::memory::mesh(10));
    let mut engine = RoundEngine::new(&mut driver, &schedule);
    let mut state = GossipState::new(example::paper_example_mst(), 0);
    // tiny payloads: the protocol structure, not the byte rate, is under test
    let m = engine.run_round(&mut state, RoundOptions::reliable(0.0005, 64), |_, _| {});
    assert!(state.is_complete());
    assert_eq!(m.transfer_count(), 90, "live mesh must move the same copies");
    assert_eq!(m.slots, 23, "live protocol structure matches the logical trace");
    assert_eq!(state.held_string(example::K, example::label), "KGIFBECHDA");
}

#[test]
fn logical_driver_and_sim_driver_agree_on_protocol_structure() {
    // same schedule, different substrates: slots and copy counts match
    let session = GossipSession::new(&quiet_cfg(TopologyKind::Complete)).unwrap();
    let mut state = GossipState::new(session.tree().clone(), 0);
    let mut driver = LogicalDriver::new();
    let mut engine = RoundEngine::new(&mut driver, session.schedule());
    let logical = engine.run_round(&mut state, RoundOptions::reliable(14.0, 144), |_, _| {});
    let timed = session.run_mosgu_round(14.0, 1, 0.0);
    assert_eq!(logical.slots, timed.slots);
    assert_eq!(logical.transfer_count(), timed.transfer_count());
}

#[test]
fn trees_one_config_is_bit_identical_across_engine_paths() {
    // the multi-tree plane's compatibility anchor (this PR's acceptance
    // bar): an explicit `--trees 1` config must carve no extra lanes and
    // replay the default single-MST session bit for bit on every paper
    // topology — single rounds under jitter and failure injection,
    // segmented plans, and the sharded runner — and still match the
    // seed's legacy slot loop
    for kind in TopologyKind::ALL {
        for jitter in [0.0, 0.08] {
            let base = ExperimentConfig {
                topology: kind,
                latency_jitter: jitter,
                subnets: 1,
                ..Default::default()
            };
            let pinned = ExperimentConfig { trees: 1, ..base.clone() };
            let s_base = GossipSession::new(&base).unwrap();
            let s_pin = GossipSession::new(&pinned).unwrap();
            assert!(
                s_pin.extra_lanes().is_empty(),
                "{kind:?}: trees = 1 must never carve extra lanes"
            );
            for failure_prob in [0.0, 0.15] {
                let a = s_base.run_mosgu_round(14.0, 3, failure_prob);
                let b = s_pin.run_mosgu_round(14.0, 3, failure_prob);
                let label = format!("{kind:?} j={jitter} f={failure_prob}");
                assert_rounds_bit_identical(&b, &a, &label);
                let legacy = legacy_mosgu_round(&s_pin, 14.0, 3, failure_prob);
                assert_metrics_match_legacy(&b, &legacy);
            }
            let seg_a = s_base.run_mosgu_round_planned(TransferPlan::segmented(36.8, 4), 3, 0.15);
            let seg_b = s_pin.run_mosgu_round_planned(TransferPlan::segmented(36.8, 4), 3, 0.15);
            assert_rounds_bit_identical(&seg_b, &seg_a, &format!("{kind:?} segmented"));
            let sh_a = s_base.run_sharded_round(14.0, 3, 0.15, false);
            let sh_b = s_pin.run_sharded_round(14.0, 3, 0.15, false);
            assert_rounds_bit_identical(&sh_b, &sh_a, &format!("{kind:?} sharded"));
            // compression composes: the quantized wire plan stays on the
            // single-tree path under an explicit trees = 1
            let mut comp = base.clone();
            comp.compress = mosgu::dfl::compress::CompressionKind::Quant;
            comp.quant_bits = 8;
            let comp_pin = ExperimentConfig { trees: 1, ..comp.clone() };
            let qa = GossipSession::new(&comp).unwrap().run_mosgu_round(14.0, 3, 0.15);
            let qb = GossipSession::new(&comp_pin).unwrap().run_mosgu_round(14.0, 3, 0.15);
            assert_rounds_bit_identical(&qb, &qa, &format!("{kind:?} quant"));
        }
    }
}

#[test]
fn single_lane_forest_round_matches_segmented_engine_on_all_topologies() {
    // the forest executor's own anchor: one lane carrying the session's
    // tree + schedule is exactly the segmented cut-through engine on that
    // tree (`stripe(1)` is the identity, so the lane sees the same plan
    // bits), bit for bit, including under failure injection. The session
    // keeps whole-model `trees = 1` rounds on `run_round`, which the
    // tests above pin to the legacy slot loop.
    for kind in TopologyKind::ALL {
        let session = GossipSession::new(&quiet_cfg(kind)).unwrap();
        let plan = TransferPlan::segmented(14.0, 4);
        for failure_prob in [0.0, 0.15] {
            let reference = session.run_mosgu_round_planned(plan, 3, failure_prob);
            let mut driver = SimDriver::new(session.testbed(), 3);
            let mut engine = RoundEngine::new(&mut driver, session.schedule());
            let lanes = vec![TreeLane {
                tree: session.tree().clone(),
                schedule: session.schedule().clone(),
            }];
            let m = engine.run_forest_round(
                &lanes,
                0,
                RoundOptions {
                    plan,
                    failure_prob,
                    max_slots: 8 * 10 + 64,
                    failure_rng: Pcg64::new(3 ^ 0xfa11),
                    drops: None,
                },
            );
            let label = format!("{kind:?} f={failure_prob}");
            assert_rounds_bit_identical(&m, &reference, &label);
            assert_eq!(m.relay_copies, reference.relay_copies, "{label}: cascades diverged");
        }
    }
}

#[test]
fn multi_tree_rounds_stay_disjoint_conserve_bytes_and_replay() {
    // trees = 2 on the dense default overlay: the session plans a second
    // edge-disjoint lane, every lane moves each model across its n-1
    // edges at half the bytes (totals conserved), and fixed seeds replay
    // bit for bit — through both the event engine and the sharded runner
    let cfg = ExperimentConfig { trees: 2, ..quiet_cfg(TopologyKind::Complete) };
    let session = GossipSession::new(&cfg).unwrap();
    assert_eq!(session.extra_lanes().len(), 1, "complete n=10 admits a second lane");
    let lanes = session.lanes();
    for (a, la) in lanes.iter().enumerate() {
        for lb in &lanes[a + 1..] {
            for e in la.tree.edges() {
                assert!(!lb.tree.has_edge(e.u, e.v), "lanes share edge {}-{}", e.u, e.v);
            }
        }
    }
    let m = session.run_mosgu_round(48.0, 1, 0.0);
    assert_eq!(m.transfer_count(), 2 * 90, "each lane moves 90 half-size stripes");
    assert!((m.total_payload_mb() - 90.0 * 48.0).abs() < 1e-6, "byte total is lane-invariant");
    let again = session.run_mosgu_round(48.0, 1, 0.0);
    assert_eq!(m.total_time_s.to_bits(), again.total_time_s.to_bits());
    assert_eq!(m.transfers, again.transfers);
    let sharded = session.run_sharded_round(48.0, 1, 0.0, false);
    assert_eq!(sharded.transfer_count(), 2 * 90);
    assert!((sharded.total_payload_mb() - 90.0 * 48.0).abs() < 1e-6);
}

#[test]
fn sim_driver_with_map_preserves_round_structure() {
    // running the paper round relabeled onto different devices moves the
    // same copies through the same slots
    let session = GossipSession::new(&quiet_cfg(TopologyKind::Complete)).unwrap();
    let tb = session.testbed();
    let n = 10;
    let map: Vec<usize> = (0..n).map(|u| (u + 3) % n).collect();
    let mut driver = SimDriver::with_map(tb, 1, map);
    let mut engine = RoundEngine::new(&mut driver, session.schedule());
    let mut state = GossipState::new(session.tree().clone(), 0);
    let m = engine.run_round(&mut state, RoundOptions::reliable(14.0, 144), |_, _| {});
    let identity = session.run_mosgu_round(14.0, 1, 0.0);
    assert_eq!(m.slots, identity.slots);
    assert_eq!(m.transfer_count(), identity.transfer_count());
}
