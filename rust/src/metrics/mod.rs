//! Communication metrics — the paper's three evaluation indicators (§V):
//! bandwidth (MB/s), average single-transfer time (s), and total time for
//! one communication round (s) — plus table formatting for the CLI and
//! benches.

use crate::netsim::FlowRecord;
use crate::util::stats::Summary;

/// Metrics of one measured communication round.
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    /// Every completed model transfer in the round.
    pub transfers: Vec<FlowRecord>,
    /// Wall-clock (simulated) duration until full dissemination (every
    /// node holds every model).
    pub total_time_s: f64,
    /// Duration of the *exchange phase*: every node's own round-t update
    /// delivered to its gossip neighbors — the blocking part of one FL
    /// communication round (Table V's "total time"; dissemination of
    /// forwarded copies pipelines with the next round). For broadcast the
    /// two coincide.
    pub exchange_time_s: f64,
    /// Number of slots the schedule used (0 for broadcast).
    pub slots: usize,
}

impl RoundMetrics {
    /// Mean observed per-transfer goodput — the paper's "Bandwidth (MB/s)".
    pub fn bandwidth_mbps(&self) -> f64 {
        let mut s = Summary::new();
        for t in &self.transfers {
            s.push(t.bandwidth_mbps());
        }
        s.mean()
    }

    /// Mean single-transfer duration — the paper's Table IV indicator.
    pub fn avg_transfer_s(&self) -> f64 {
        let mut s = Summary::new();
        for t in &self.transfers {
            s.push(t.duration());
        }
        s.mean()
    }

    pub fn transfer_count(&self) -> usize {
        self.transfers.len()
    }

    /// Total payload moved (MB), counting every copy.
    pub fn total_payload_mb(&self) -> f64 {
        self.transfers.iter().map(|t| t.payload_mb).sum()
    }
}

/// Aggregate over repeated rounds (the paper reports averaged figures).
#[derive(Debug, Clone, Default)]
pub struct RepeatedMetrics {
    pub bandwidth: Summary,
    pub transfer: Summary,
    /// full-dissemination time
    pub total: Summary,
    /// exchange-phase time (Table V's indicator)
    pub exchange: Summary,
}

impl RepeatedMetrics {
    pub fn push(&mut self, round: &RoundMetrics) {
        self.bandwidth.push(round.bandwidth_mbps());
        self.transfer.push(round.avg_transfer_s());
        self.total.push(round.total_time_s);
        self.exchange.push(round.exchange_time_s);
    }
}

/// One cell of a paper table: broadcast vs proposed for a (topology,
/// model) pair.
#[derive(Debug, Clone)]
pub struct Cell {
    pub topology: String,
    pub model: String,
    pub broadcast: RepeatedMetrics,
    pub proposed: RepeatedMetrics,
}

/// Table renderer shared by the CLI and bench harnesses: rows = topologies,
/// column groups = models, broadcast block then proposed block — mirroring
/// the layout of Tables III–V.
pub fn render_table(
    title: &str,
    topologies: &[String],
    models: &[String],
    value: impl Fn(&Cell) -> (f64, f64),
    cells: &[Cell],
) -> String {
    let find = |t: &str, m: &str| cells.iter().find(|c| c.topology == t && c.model == m);
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let width = 9;
    out.push_str(&format!("{:<17}", "topology"));
    for side in ["B", "P"] {
        for m in models {
            out.push_str(&format!("{:>width$}", format!("{side}:{m}")));
        }
    }
    out.push('\n');
    for t in topologies {
        out.push_str(&format!("{t:<17}"));
        for pick_broadcast in [true, false] {
            for m in models {
                match find(t, m) {
                    Some(cell) => {
                        let (b, p) = value(cell);
                        let v = if pick_broadcast { b } else { p };
                        out.push_str(&format!("{v:>width$.3}"));
                    }
                    None => out.push_str(&format!("{:>width$}", "-")),
                }
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::FlowRecord;

    fn rec(mb: f64, start: f64, end: f64) -> FlowRecord {
        FlowRecord { flow: 0, src: 0, dst: 1, payload_mb: mb, start, end, tag: 0 }
    }

    #[test]
    fn round_metrics_aggregates() {
        let m = RoundMetrics {
            transfers: vec![rec(10.0, 0.0, 2.0), rec(10.0, 0.0, 5.0)],
            total_time_s: 5.0,
            exchange_time_s: 5.0,
            slots: 2,
        };
        assert!((m.bandwidth_mbps() - (5.0 + 2.0) / 2.0).abs() < 1e-12);
        assert!((m.avg_transfer_s() - 3.5).abs() < 1e-12);
        assert_eq!(m.transfer_count(), 2);
        assert!((m.total_payload_mb() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn repeated_metrics_average_rounds() {
        let mut rep = RepeatedMetrics::default();
        for total in [10.0, 20.0] {
            rep.push(&RoundMetrics {
                transfers: vec![rec(10.0, 0.0, 2.0)],
                total_time_s: total,
                exchange_time_s: total,
                slots: 1,
            });
        }
        assert_eq!(rep.total.count(), 2);
        assert!((rep.total.mean() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn render_table_layout() {
        let mut cell = Cell {
            topology: "Complete".into(),
            model: "v3s".into(),
            broadcast: RepeatedMetrics::default(),
            proposed: RepeatedMetrics::default(),
        };
        cell.broadcast.push(&RoundMetrics {
            transfers: vec![rec(10.0, 0.0, 10.0)],
            total_time_s: 10.0,
            exchange_time_s: 10.0,
            slots: 0,
        });
        cell.proposed.push(&RoundMetrics {
            transfers: vec![rec(10.0, 0.0, 2.0)],
            total_time_s: 3.0,
            exchange_time_s: 2.0,
            slots: 23,
        });
        let s = render_table(
            "Table V",
            &["Complete".into()],
            &["v3s".into()],
            |c| (c.broadcast.total.mean(), c.proposed.total.mean()),
            &[cell],
        );
        assert!(s.contains("Table V"));
        assert!(s.contains("Complete"));
        assert!(s.contains("10.000"));
        assert!(s.contains("3.000"));
        assert!(s.contains("B:v3s") && s.contains("P:v3s"));
    }
}
