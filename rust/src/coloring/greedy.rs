//! Degree-ordered greedy colorings: Welsh–Powell and Largest-Degree-First
//! (the remaining §III-C candidates).
//!
//! Both order nodes by non-increasing degree. LDF then runs plain
//! first-fit down that order; Welsh–Powell instead fills one color class
//! at a time (assign color c to every not-yet-colored node not adjacent to
//! the class built so far), which is the classic 1967 formulation.

use super::Coloring;
use crate::graph::Graph;

/// Nodes sorted by non-increasing degree, ties by ascending id.
fn degree_order(g: &Graph) -> Vec<usize> {
    let mut order: Vec<usize> = (0..g.node_count()).collect();
    order.sort_by(|&a, &b| g.degree(b).cmp(&g.degree(a)).then(a.cmp(&b)));
    order
}

/// Largest-Degree-First: first-fit greedy down the degree order.
pub fn largest_degree_first(g: &Graph) -> Coloring {
    let n = g.node_count();
    let mut color = vec![usize::MAX; n];
    for &u in &degree_order(g) {
        let mut used = vec![false; g.degree(u) + 1];
        for &(v, _) in g.neighbors(u) {
            if color[v] != usize::MAX && color[v] < used.len() {
                used[color[v]] = true;
            }
        }
        color[u] = used.iter().position(|&b| !b).unwrap();
    }
    Coloring::new(color)
}

/// Welsh–Powell: build maximal independent color classes in degree order.
pub fn welsh_powell(g: &Graph) -> Coloring {
    let n = g.node_count();
    let mut color = vec![usize::MAX; n];
    let order = degree_order(g);
    let mut next_color = 0;
    let mut remaining = n;
    while remaining > 0 {
        // greedily extend class `next_color`
        let mut in_class: Vec<bool> = vec![false; n];
        for &u in &order {
            if color[u] != usize::MAX {
                continue;
            }
            let conflict = g.neighbors(u).iter().any(|&(v, _)| in_class[v]);
            if !conflict {
                color[u] = next_color;
                in_class[u] = true;
                remaining -= 1;
            }
        }
        next_color += 1;
    }
    Coloring::new(color)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn petersen() -> Graph {
        // 3-chromatic, 3-regular classic
        let mut g = Graph::new(10);
        for u in 0..5 {
            g.add_edge(u, (u + 1) % 5, 1.0); // outer cycle
            g.add_edge(u + 5, (u + 2) % 5 + 5, 1.0); // inner pentagram
            g.add_edge(u, u + 5, 1.0); // spokes
        }
        g
    }

    #[test]
    fn ldf_proper_on_petersen() {
        let g = petersen();
        let c = largest_degree_first(&g);
        assert!(c.is_proper(&g));
        assert!(c.num_colors() <= 4); // greedy bound Δ+1
    }

    #[test]
    fn wp_proper_on_petersen() {
        let g = petersen();
        let c = welsh_powell(&g);
        assert!(c.is_proper(&g));
        assert!(c.num_colors() <= 4);
    }

    #[test]
    fn both_two_color_trees() {
        let mut g = Graph::new(8);
        for v in 1..8 {
            g.add_edge((v - 1) / 2, v, 1.0);
        }
        for c in [largest_degree_first(&g), welsh_powell(&g)] {
            assert!(c.is_proper(&g));
            assert_eq!(c.num_colors(), 2);
        }
    }

    #[test]
    fn wp_classes_are_independent_sets() {
        let g = petersen();
        let c = welsh_powell(&g);
        for class in c.classes() {
            for (i, &u) in class.iter().enumerate() {
                for &v in &class[i + 1..] {
                    assert!(!g.has_edge(u, v), "class contains edge ({u},{v})");
                }
            }
        }
    }

    #[test]
    fn complete_graph_needs_n_colors() {
        let g = crate::graph::topology::complete(6);
        assert_eq!(largest_degree_first(&g).num_colors(), 6);
        assert_eq!(welsh_powell(&g).num_colors(), 6);
    }

    #[test]
    fn empty_graph_one_color() {
        let g = Graph::new(4);
        assert_eq!(largest_degree_first(&g).num_colors(), 1);
        assert_eq!(welsh_powell(&g).num_colors(), 1);
    }
}
