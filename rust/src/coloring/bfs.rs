//! BFS coloring — the paper's selected algorithm (§III-C): start from a
//! root, give it color 0, alternate per BFS level. On bipartite graphs
//! (every tree) this yields a proper 2-coloring in O(V+E). On non-bipartite
//! inputs a level-alternating scheme cannot be proper, so we fall back to
//! greedy first-fit along the same BFS order, still O(V+E)·Δ worst case,
//! keeping the function total.

use super::Coloring;
use crate::graph::Graph;
use std::collections::VecDeque;

/// BFS 2-coloring from node 0 (also covers disconnected remainders by
/// restarting at the next unvisited node, each with color 0).
pub fn bfs_coloring(g: &Graph) -> Coloring {
    let n = g.node_count();
    let mut color = vec![usize::MAX; n];
    let mut bipartite = true;

    for start in 0..n {
        if color[start] != usize::MAX {
            continue;
        }
        color[start] = 0;
        let mut queue = VecDeque::from([start]);
        while let Some(u) = queue.pop_front() {
            for &(v, _) in g.neighbors(u) {
                if color[v] == usize::MAX {
                    color[v] = 1 - color[u];
                    queue.push_back(v);
                } else if color[v] == color[u] {
                    bipartite = false;
                }
            }
        }
    }

    if bipartite {
        return Coloring::new(color);
    }

    // Odd cycle present: redo as greedy first-fit in BFS visit order.
    let order = bfs_order(g);
    let mut color = vec![usize::MAX; n];
    for &u in &order {
        let mut used: Vec<bool> = vec![false; g.degree(u) + 1];
        for &(v, _) in g.neighbors(u) {
            if color[v] != usize::MAX && color[v] < used.len() {
                used[color[v]] = true;
            }
        }
        color[u] = used.iter().position(|&b| !b).unwrap();
    }
    Coloring::new(color)
}

/// BFS visitation order over all components, starting at node 0.
pub fn bfs_order(g: &Graph) -> Vec<usize> {
    let n = g.node_count();
    let mut seen = vec![false; n];
    let mut order = Vec::with_capacity(n);
    for start in 0..n {
        if seen[start] {
            continue;
        }
        seen[start] = true;
        let mut queue = VecDeque::from([start]);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &(v, _) in g.neighbors(u) {
                if !seen[v] {
                    seen[v] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_alternates() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 1.0);
        g.add_edge(2, 3, 1.0);
        let c = bfs_coloring(&g);
        assert_eq!(c.assignment(), &[0, 1, 0, 1]);
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors(), 2);
    }

    #[test]
    fn star_two_colors() {
        let mut g = Graph::new(5);
        for v in 1..5 {
            g.add_edge(0, v, 1.0);
        }
        let c = bfs_coloring(&g);
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors(), 2);
        assert_eq!(c.class(0), vec![0]);
    }

    #[test]
    fn even_cycle_two_colors() {
        let mut g = Graph::new(6);
        for u in 0..6 {
            g.add_edge(u, (u + 1) % 6, 1.0);
        }
        let c = bfs_coloring(&g);
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors(), 2);
    }

    #[test]
    fn odd_cycle_falls_back_to_proper_three_coloring() {
        let mut g = Graph::new(5);
        for u in 0..5 {
            g.add_edge(u, (u + 1) % 5, 1.0);
        }
        let c = bfs_coloring(&g);
        assert!(c.is_proper(&g), "fallback must still be proper");
        assert_eq!(c.num_colors(), 3);
    }

    #[test]
    fn disconnected_components_each_colored() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 1.0);
        let c = bfs_coloring(&g);
        assert!(c.is_proper(&g));
        assert_eq!(c.color_of(0), 0);
        assert_eq!(c.color_of(2), 0); // new component restarts at color 0
    }

    #[test]
    fn isolated_nodes_get_color_zero() {
        let g = Graph::new(3);
        let c = bfs_coloring(&g);
        assert_eq!(c.assignment(), &[0, 0, 0]);
    }

    #[test]
    fn bfs_order_visits_all_once() {
        let mut g = Graph::new(5);
        g.add_edge(0, 1, 1.0);
        g.add_edge(0, 2, 1.0);
        g.add_edge(3, 4, 1.0);
        let order = bfs_order(&g);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
        assert_eq!(order[0], 0);
    }
}
