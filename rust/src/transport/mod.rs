//! Node-to-node transports for the live (non-simulated) deployment mode:
//! a binary message codec, an in-memory channel mesh for tests, and a
//! loopback-TCP mesh with token-bucket shaping that emulates the paper's
//! router bandwidth limits on real sockets.

pub mod codec;
pub mod memory;
pub mod tcp;

pub use codec::Message;

use anyhow::Result;
use std::time::Duration;

/// A reliable, ordered, point-to-point message transport between the N
/// participants (node ids `0..n`).
pub trait Transport: Send {
    /// This endpoint's node id.
    fn node(&self) -> usize;
    /// Number of participants.
    fn len(&self) -> usize;
    /// Send a message to `to` (blocking until enqueued/written).
    fn send(&mut self, to: usize, msg: Message) -> Result<()>;
    /// Receive the next message, with a timeout. `Ok(None)` = timed out.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<(usize, Message)>>;

    /// Non-blocking receive: drain one already-delivered message if any.
    /// `Ok(None)` = nothing pending. The engine's `LiveDriver` polls the
    /// whole mesh through this.
    fn try_recv(&mut self) -> Result<Option<(usize, Message)>> {
        self.recv_timeout(Duration::ZERO)
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Broadcast to every other node.
    fn broadcast(&mut self, msg: Message) -> Result<()> {
        for to in 0..self.len() {
            if to != self.node() {
                self.send(to, msg.clone())?;
            }
        }
        Ok(())
    }
}

/// Token-bucket rate limiter used by the TCP transport to emulate link
/// capacity (bytes per second) on loopback sockets.
#[derive(Debug)]
pub struct TokenBucket {
    rate_bytes_per_s: f64,
    burst_bytes: f64,
    tokens: f64,
    last: std::time::Instant,
}

impl TokenBucket {
    pub fn new(rate_bytes_per_s: f64, burst_bytes: f64) -> Self {
        assert!(rate_bytes_per_s > 0.0);
        TokenBucket {
            rate_bytes_per_s,
            burst_bytes,
            tokens: burst_bytes,
            last: std::time::Instant::now(),
        }
    }

    /// Block until `bytes` may pass, consuming tokens.
    pub fn consume(&mut self, bytes: usize) {
        let mut need = bytes as f64;
        loop {
            let now = std::time::Instant::now();
            self.tokens = (self.tokens + now.duration_since(self.last).as_secs_f64() * self.rate_bytes_per_s)
                .min(self.burst_bytes.max(need));
            self.last = now;
            if self.tokens >= need {
                self.tokens -= need;
                return;
            }
            // sleep long enough for at most one chunk of tokens to refill
            let deficit = need - self.tokens;
            need = need.min(self.burst_bytes.max(1.0));
            let wait = (deficit / self.rate_bytes_per_s).min(0.05).max(0.0005);
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn token_bucket_enforces_rate() {
        // 1 MB/s, pass 200 KB => >= ~0.15 s (with 50 KB burst headroom)
        let mut tb = TokenBucket::new(1_000_000.0, 50_000.0);
        let t0 = Instant::now();
        for _ in 0..4 {
            tb.consume(50_000);
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.10, "elapsed {dt}");
    }

    #[test]
    fn try_recv_is_nonblocking() {
        use crate::transport::memory;
        let mut eps = memory::mesh(2);
        let mut b = eps.remove(1);
        let mut a = eps.remove(0);
        let t0 = Instant::now();
        assert!(b.try_recv().unwrap().is_none());
        assert!(t0.elapsed().as_secs_f64() < 0.05, "try_recv must not block");
        a.send(1, Message::Vote { candidate: 4 }).unwrap();
        let (from, msg) = b.try_recv().unwrap().expect("message pending");
        assert_eq!(from, 0);
        assert_eq!(msg, Message::Vote { candidate: 4 });
    }

    #[test]
    fn token_bucket_burst_is_instant() {
        let mut tb = TokenBucket::new(1_000.0, 10_000.0);
        let t0 = Instant::now();
        tb.consume(10_000); // fits the initial burst
        assert!(t0.elapsed().as_secs_f64() < 0.05);
    }
}
