//! MOSGU coordination protocol (paper §III): **M**anage connectivity,
//! **O**ptimize connectivity, **S**chedule communication, **G**ossip &
//! **U**pdate — plus the flooding-broadcast baseline and the experiment
//! session gluing protocol, moderator and network simulator together.
//!
//! # Architecture: one engine, many drivers
//!
//! All round execution flows through [`engine::RoundEngine`], the single
//! protocol driver. The engine owns slot structure and protocol state
//! ([`gossip::GossipState`]) and keys each slot on per-flow completion
//! events supplied by an [`engine::driver::Driver`] implementation:
//!
//! | driver | substrate | used by |
//! |---|---|---|
//! | `SimDriver` | discrete-event network simulator | [`session`] (Tables III–V), [`churn`] (relabeled trees) |
//! | `MeshSimDriver` | per-edge channel mesh (scriptable link quality) | [`probe`]'s re-planning scenarios |
//! | `LogicalDriver` | instant untimed delivery | [`gossip::run_logical_round`] (Table I trace) |
//! | `LiveDriver` | real transports (memory / shaped TCP) | in-process live mode (engine owns every endpoint) |
//!
//! Links are no longer frozen at session start: `netsim` channels drift
//! or take scripted [`crate::netsim::ChannelShift`]s, the [`probe`]
//! module re-measures pings online through the drivers, and
//! `engine::RoundEngine::run_pipelined_adaptive` migrates the pipeline
//! to re-planned trees/schedules at round boundaries.
//!
//! (`examples/live_cluster.rs` remains the *distributed* live
//! deployment — one OS thread per node running its own loop; the
//! engine-backed `LiveDriver` is its centralized in-process
//! counterpart.)
//!
//! The engine also implements multi-round pipelining
//! ([`engine::RoundEngine::run_pipelined`]): rounds share one long-lived
//! driver and each node seeds round `t+1` as soon as it has aggregated
//! round `t`, so next-round seeds gossip in slots the previous round has
//! vacated (§III-D). The DFL layer (`dfl::round::run_dfl`) trains through
//! this path.
//!
//! The wire-level transfer unit is a [`queue::SegmentKey`] under a
//! segment-granular `dfl::transfer::TransferPlan`: `segments = 1` moves
//! whole checkpoints exactly as the pre-segmentation engine did, while
//! `segments ≥ 2` enables the engine's cut-through relay forwarding
//! (segment `i` re-launched downstream the moment it arrives — see
//! [`engine`]).

pub mod broadcast;
pub mod churn;
pub mod engine;
pub mod example;
pub mod gossip;
pub mod hierarchy;
pub mod moderator;
pub mod probe;
pub mod queue;
pub mod schedule;
pub mod session;
