//! The static verification plane, exercised two ways:
//!
//! 1. **Clean coverage** — every planner path the repo ships (flat
//!    topologies, hierarchical stitching, multi-tree forests, replanned
//!    bundles, the sparse large-n `ScaleScenario`) must lint clean.
//!    These asserts hold in release builds too, where the moderator's
//!    `debug_assert` hook is compiled out.
//! 2. **Mutation suite** — seeded corruptions of known-good plans (drop
//!    a tree edge, merge two colors, overlap two lanes, shrink the slot
//!    budget, ...) must each be flagged with the expected
//!    [`Violation::kind`], and the unmutated plan must stay silent.
//!    This is the linter's own soundness/sensitivity check: a lint that
//!    misses a seeded defect, or fires on a correct plan, fails here.

use mosgu::analysis::{lint_bundle, lint_epoch, LintContext, PlanLinter, Violation};
use mosgu::coloring::{Coloring, ColoringAlgorithm};
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::engine::{PlanEpoch, TreeLane};
use mosgu::coordinator::moderator::Moderator;
use mosgu::coordinator::schedule::{build_schedule, Schedule};
use mosgu::coordinator::session::{sessions_for_all_topologies, GossipSession, ScaleScenario};
use mosgu::dfl::data::ParticipationPlan;
use mosgu::dfl::transfer::TransferPlan;
use mosgu::graph::generators::GeneratorKind;
use mosgu::graph::Graph;
use mosgu::mst::MstAlgorithm;
use mosgu::prop_assert;
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;

fn quiet_cfg() -> ExperimentConfig {
    ExperimentConfig { latency_jitter: 0.0, ..Default::default() }
}

// ---------------------------------------------------------------------------
// clean coverage: every planner output across the paper topologies
// ---------------------------------------------------------------------------

#[test]
fn every_paper_topology_lints_clean() {
    let sessions = sessions_for_all_topologies(&quiet_cfg()).unwrap();
    assert_eq!(sessions.len(), 4);
    for (kind, s) in sessions {
        let report = s.lint_report(8);
        assert!(report.is_clean(), "{kind:?}: {report}");
    }
}

#[test]
fn hierarchical_session_lints_clean() {
    let cfg = ExperimentConfig {
        nodes: 12,
        subnets: 3,
        topology_gen: GeneratorKind::Hierarchy,
        ..quiet_cfg()
    };
    let s = GossipSession::new(&cfg).unwrap();
    let report = s.lint_report(8);
    assert!(report.is_clean(), "{report}");
}

#[test]
fn forest_session_lints_clean() {
    let cfg = ExperimentConfig { trees: 2, ..quiet_cfg() };
    let s = GossipSession::new(&cfg).unwrap();
    assert_eq!(s.extra_lanes().len(), 1, "complete n=10 admits an extra lane");
    let report = s.lint_report(8);
    assert!(report.is_clean(), "{report}");
}

#[test]
fn segmented_and_partial_participation_sessions_lint_clean() {
    // segments ≥ 2 exercises the stripe/segment-bounds checks for real
    let cfg = ExperimentConfig { segments: 4, ..quiet_cfg() };
    let s = GossipSession::new(&cfg).unwrap();
    let report = s.lint_report(8);
    assert!(report.is_clean(), "segmented: {report}");

    // participation < 1 exercises the origination-consistency checks
    let cfg = ExperimentConfig { participation: 0.5, ..quiet_cfg() };
    let s = GossipSession::new(&cfg).unwrap();
    let report = s.lint_report(8);
    assert!(report.is_clean(), "participation: {report}");
}

#[test]
fn replanned_bundle_lints_clean_against_fresh_estimates() {
    let n = 10;
    let costs = dense_costs(n);
    let mut m = Moderator::new(0, n, MstAlgorithm::Prim, ColoringAlgorithm::Bfs);
    for u in 0..n {
        let peers: Vec<(usize, f64)> = costs.neighbors(u).to_vec();
        m.submit_report(u, &peers);
    }
    let bundle = m.compute_schedule(14.0, 56, 1).unwrap().clone();
    let measured = m.matrix().unwrap().to_graph();
    let ctx = LintContext { costs: &measured, unit_mb: 14.0, ping_size_bytes: 56 };
    let report = lint_bundle(&bundle, &ctx);
    assert!(report.is_clean(), "initial: {report}");

    // drift every edge a little and replan: the fresh bundle must lint
    // clean against the estimates it was re-budgeted from
    let mut estimates = Graph::new(n);
    for (i, e) in measured.edges().iter().enumerate() {
        estimates.add_edge(e.u, e.v, e.weight * (1.0 + 0.2 * ((i % 5) as f64 - 2.0) / 10.0));
    }
    let after = m.replan_with_costs(&estimates, 14.0, 56, 1).unwrap().clone();
    let ctx = LintContext { costs: &estimates, unit_mb: 14.0, ping_size_bytes: 56 };
    let report = lint_bundle(&after, &ctx);
    assert!(report.is_clean(), "replanned: {report}");
}

#[test]
fn scale_scenario_epoch_lints_clean() {
    let cfg = ExperimentConfig { nodes: 48, subnets: 6, trees: 2, ..quiet_cfg() };
    let sc = ScaleScenario::new(&cfg, 14.0).unwrap();
    // ScaleScenario plans straight from the sparse overlay costs (no
    // report noise), so the lint baseline is recomputable from its parts
    let costs = sc.testbed().overlay_costs(sc.structure());
    let epoch = PlanEpoch {
        tree: sc.tree().clone(),
        schedule: sc.schedule().clone(),
        extra: sc.extra_lanes().to_vec(),
    };
    let unit_mb = cfg.transfer_plan(14.0).segment_mb();
    let ctx = LintContext { costs: &costs, unit_mb, ping_size_bytes: cfg.ping_size_bytes };
    let report = lint_epoch(&epoch, &ctx);
    assert!(report.is_clean(), "{report}");
}

// ---------------------------------------------------------------------------
// mutation suite: seeded corruptions must be flagged, by kind
// ---------------------------------------------------------------------------

fn dense_costs(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            g.add_edge(u, v, if v == u + 1 { 1.0 } else { 2.0 + (u * n + v) as f64 * 0.01 });
        }
    }
    g
}

fn random_costs(rng: &mut Pcg64, n: usize) -> Graph {
    let mut g = Graph::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            g.add_edge(u, v, rng.gen_f64_range(1.0, 50.0));
        }
    }
    g
}

fn plan(costs: &Graph) -> PlanEpoch {
    let tree = MstAlgorithm::Prim.run(costs).unwrap();
    let coloring = ColoringAlgorithm::Bfs.run(&tree);
    let schedule = build_schedule(costs, coloring, 14.0, 56, 1);
    PlanEpoch::single(tree, schedule)
}

fn ctx(costs: &Graph) -> LintContext<'_> {
    LintContext { costs, unit_mb: 14.0, ping_size_bytes: 56 }
}

/// Rebuild the epoch's schedule around a mutated coloring, keeping the
/// published budget/rotation so only the seeded defect differs.
fn with_coloring(epoch: &PlanEpoch, assignment: Vec<usize>) -> PlanEpoch {
    let schedule = Schedule {
        coloring: Coloring::new(assignment),
        slot_len_s: epoch.schedule.slot_len_s,
        first_color: epoch.schedule.first_color,
    };
    PlanEpoch::single(epoch.tree.clone(), schedule)
}

#[test]
fn mutation_suite_flags_every_seeded_corruption() {
    check("plan-lint mutations", 96, |rng| {
        let n = 6 + rng.gen_range(6); // 6..=11 nodes
        let costs = random_costs(rng, n);
        let epoch = plan(&costs);
        let report = lint_epoch(&epoch, &ctx(&costs));
        prop_assert!(report.is_clean(), "unmutated plan must lint clean: {report}");

        match rng.gen_range(8) {
            // drop a random tree edge: the lane no longer spans
            0 => {
                let drop = rng.gen_range(epoch.tree.edge_count());
                let mut broken = Graph::new(n);
                for (i, e) in epoch.tree.edges().iter().enumerate() {
                    if i != drop {
                        broken.add_edge(e.u, e.v, e.weight);
                    }
                }
                let mutated = PlanEpoch::single(broken, epoch.schedule.clone());
                let report = lint_epoch(&mutated, &ctx(&costs));
                prop_assert!(report.has("not-spanning"), "dropped edge {drop}: {report}");
                prop_assert!(report.has("disconnected"), "dropped edge {drop}: {report}");
            }
            // merge the colors across a random tree edge: properness and
            // per-slot half-duplex conflict freedom both break
            1 => {
                let e = epoch.tree.edges()[rng.gen_range(epoch.tree.edge_count())];
                let mut assignment = epoch.schedule.coloring.assignment().to_vec();
                assignment[e.v] = assignment[e.u];
                let report = lint_epoch(&with_coloring(&epoch, assignment), &ctx(&costs));
                prop_assert!(report.has("improper-edge"), "merged ({},{}): {report}", e.u, e.v);
                prop_assert!(report.has("slot-conflict"), "merged ({},{}): {report}", e.u, e.v);
            }
            // clone lane 0 as an extra lane: every edge is shared
            2 => {
                let mutated = PlanEpoch {
                    tree: epoch.tree.clone(),
                    schedule: epoch.schedule.clone(),
                    extra: vec![TreeLane {
                        tree: epoch.tree.clone(),
                        schedule: epoch.schedule.clone(),
                    }],
                };
                let report = lint_epoch(&mutated, &ctx(&costs));
                prop_assert!(report.has("shared-edge"), "{report}");
            }
            // scale the published slot budget: the §III-C formula recompute
            // must disagree
            3 => {
                let factor = rng.gen_f64_range(1.5, 3.0);
                let schedule = Schedule {
                    slot_len_s: epoch.schedule.slot_len_s * factor,
                    ..epoch.schedule.clone()
                };
                let mutated = PlanEpoch::single(epoch.tree.clone(), schedule);
                let report = lint_epoch(&mutated, &ctx(&costs));
                prop_assert!(report.has("slot-budget-mismatch"), "factor {factor}: {report}");
            }
            // rotate the slot cycle off the end of the color range
            4 => {
                let k = epoch.schedule.coloring.num_colors();
                let schedule =
                    Schedule { first_color: k + rng.gen_range(4), ..epoch.schedule.clone() };
                let mutated = PlanEpoch::single(epoch.tree.clone(), schedule);
                let report = lint_epoch(&mutated, &ctx(&costs));
                prop_assert!(report.has("first-color-out-of-range"), "{report}");
            }
            // shift every color up by one: class 0 goes empty (a slot with
            // zero transmitters each cycle) while properness survives
            5 => {
                let assignment: Vec<usize> =
                    epoch.schedule.coloring.assignment().iter().map(|&c| c + 1).collect();
                let report = lint_epoch(&with_coloring(&epoch, assignment), &ctx(&costs));
                prop_assert!(report.has("empty-color-class"), "{report}");
                prop_assert!(!report.has("improper-edge"), "shift keeps properness: {report}");
            }
            // truncate the coloring: wrong shape, reported without panicking
            6 => {
                let mut assignment = epoch.schedule.coloring.assignment().to_vec();
                assignment.pop();
                let report = lint_epoch(&with_coloring(&epoch, assignment), &ctx(&costs));
                prop_assert!(report.has("coloring-length"), "{report}");
            }
            // grow the tree by a phantom node: plan/tree node sets diverge
            _ => {
                let mut grown = Graph::new(n + 1);
                for e in epoch.tree.edges() {
                    grown.add_edge(e.u, e.v, e.weight);
                }
                let mutated = PlanEpoch::single(grown, epoch.schedule.clone());
                let report = lint_epoch(&mutated, &ctx(&costs));
                prop_assert!(report.has("wrong-node-count"), "{report}");
            }
        }
        Ok(())
    });
}

#[test]
fn foreign_edge_mutation_is_flagged_on_sparse_costs() {
    check("plan-lint foreign edge", 48, |rng| {
        // chain costs: the tree IS the chain, and (u, u+2) is never measured
        let n = 5 + rng.gen_range(6);
        let mut costs = Graph::new(n);
        for u in 0..n - 1 {
            costs.add_edge(u, u + 1, rng.gen_f64_range(1.0, 20.0));
        }
        let epoch = plan(&costs);
        prop_assert!(lint_epoch(&epoch, &ctx(&costs)).is_clean(), "chain plan must be clean");

        let u = rng.gen_range(n - 2);
        let mut rewired = Graph::new(n);
        for e in epoch.tree.edges() {
            if (e.u.min(e.v), e.u.max(e.v)) == (u, u + 1) {
                rewired.add_edge(u, u + 2, e.weight);
            } else {
                rewired.add_edge(e.u, e.v, e.weight);
            }
        }
        let mutated = PlanEpoch::single(rewired, epoch.schedule.clone());
        let report = lint_epoch(&mutated, &ctx(&costs));
        prop_assert!(report.has("foreign-edge"), "rewired ({u},{}): {report}", u + 2);
        Ok(())
    });
}

#[test]
fn stripe_mutations_lose_bytes_or_segments() {
    check("plan-lint stripe mutations", 48, |rng| {
        let segments = 2 + rng.gen_range(7); // 2..=8
        let plan = TransferPlan::segmented(48.0, segments);
        let lanes = 2 + rng.gen_range(2); // 2..=3
        let good: Vec<TransferPlan> = vec![plan.stripe(lanes); lanes];
        let mut linter = PlanLinter::new(ctx(&dense_costs(4)));
        linter.check_stripes(&plan, &good);
        let report = linter.finish();
        prop_assert!(report.is_clean(), "even stripes must be clean: {report}");

        // drop one lane's stripe entirely: bytes are lost
        let short = &good[..lanes - 1];
        let mut linter = PlanLinter::new(ctx(&dense_costs(4)));
        linter.check_stripes(&plan, short);
        let report = linter.finish();
        prop_assert!(report.has("stripe-byte-loss"), "{report}");
        Ok(())
    });
}

#[test]
fn participation_mutations_are_flagged() {
    let costs = dense_costs(6);
    let plan = ParticipationPlan::sample(0.5, 6, 3, 7);

    // linting past the sampled horizon: rounds 3+ have no participant set
    let mut linter = PlanLinter::new(ctx(&costs));
    linter.check_participation(&plan, 6, 5);
    let report = linter.finish();
    assert!(report.has("missing-participants"), "{report}");

    // linting against a *smaller* node count: full participation sampled
    // over 6 nodes guarantees ids 3..6 overflow a 3-node session
    let full = ParticipationPlan::sample(1.0, 6, 3, 7);
    let mut linter = PlanLinter::new(ctx(&costs));
    linter.check_participation(&full, 3, 3);
    let report = linter.finish();
    assert!(report.has("participant-out-of-range"), "{report}");
}

#[test]
fn corrupted_neighbor_table_is_flagged() {
    let n = 8;
    let costs = dense_costs(n);
    let mut m = Moderator::new(0, n, MstAlgorithm::Prim, ColoringAlgorithm::Bfs);
    for u in 0..n {
        let peers: Vec<(usize, f64)> = costs.neighbors(u).to_vec();
        m.submit_report(u, &peers);
    }
    let mut bundle = m.compute_schedule(14.0, 56, 1).unwrap().clone();
    let measured = m.matrix().unwrap().to_graph();
    let ctx = LintContext { costs: &measured, unit_mb: 14.0, ping_size_bytes: 56 };
    assert!(lint_bundle(&bundle, &ctx).is_clean());

    // point node 0's advertised neighbors somewhere else entirely
    bundle.neighbor_table[0] = vec![(bundle.neighbor_table[0][0] + 1) % n];
    let report = lint_bundle(&bundle, &ctx);
    assert!(report.has("neighbor-table-mismatch"), "{report}");
    assert!(
        report
            .violations()
            .iter()
            .any(|v| matches!(v, Violation::NeighborTableMismatch { node: 0 })),
        "the corrupted node must be named: {report}"
    );
}
