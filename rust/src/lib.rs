//! # MOSGU — graph-based gossiping for decentralized federated learning
//!
//! Production-grade reproduction of *"Graph-based Gossiping for
//! Communication Efficiency in Decentralized Federated Learning"*
//! (Nguyen et al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate):** the MOSGU coordination protocol — rotating
//!   moderator, MST pruning, BFS-colored slot scheduling, FIFO gossip —
//!   plus a discrete-event network simulator standing in for the paper's
//!   physical three-router testbed, a flooding-broadcast baseline, and a
//!   live TCP cluster mode.
//! - **Layer 2 (build-time JAX):** the federated model's train/eval steps,
//!   AOT-lowered to HLO text artifacts.
//! - **Layer 1 (build-time Pallas):** aggregation / fused-linear / SGD
//!   kernels called from Layer 2 (interpret mode → portable HLO).
//!
//! ## The engine/driver architecture
//!
//! Every execution mode drives the protocol through **one** code path:
//! [`coordinator::engine::RoundEngine`], an event-driven round engine
//! that keys per-color slot state on per-flow completion events rather
//! than a global slot barrier. The substrate behind those events is a
//! pluggable [`coordinator::engine::driver::Driver`]:
//!
//! - `SimDriver` — the discrete-event simulator (timing experiments,
//!   Tables III–V; also churn's relabeled subgraph rounds),
//! - `MeshSimDriver` — a per-edge channel mesh with scriptable link
//!   quality (the re-planning scenarios),
//! - `LogicalDriver` — untimed instant delivery (the Table I trace),
//! - `LiveDriver` — real byte payloads over `transport` meshes
//!   (in-memory channels or shaped loopback TCP).
//!
//! The transfer unit the drivers move is set by a segment-granular
//! [`dfl::transfer::TransferPlan`]: `segments = 1` ships whole
//! checkpoints (bit-identical to the pre-segmentation engine), while
//! `segments ≥ 2` slices each copy into serial segment flows with
//! **cut-through forwarding** — a relay re-launches segment *i*
//! downstream the moment it arrives, while *i+1* is still in flight
//! upstream (after Hu et al., arXiv:1908.07782; see
//! `coordinator::engine`). [`metrics::RoundMetrics`] rolls per-segment
//! flows back up into reassembled model copies so the paper's Table III
//! bandwidth column stays comparable.
//!
//! Payloads need not ship at full fp32 width: the **compression plane**
//! ([`dfl::compress`] — `--compress {none,quant,topk}`, `--quant-bits`,
//! `--topk-frac`) quantizes or top-k-sparsifies each checkpoint with
//! per-node error feedback, and the [`dfl::transfer::TransferPlan`]
//! carries the compressed *wire* size into every flow launch, the
//! §III-C slot budget, and the loss model, with `compress = none`
//! bit-identical to the full-width engine.
//!
//! Nor are payloads trusted: the **robustness plane**
//! ([`dfl::adversary`] / [`dfl::robust`] — `--adversary`, `--fold`)
//! plans seeded Byzantine behaviors (scaled/random poisoning, sybil
//! cliques, and dropping relays that junk forwarded copies on tree
//! edges without perturbing timing) and swaps the FedAvg fold for a
//! robust aggregator (trimmed mean, coordinate median, Krum) over a
//! canonical owner-sorted candidate set, so honest nodes reach exact
//! consensus with outputs confined to the trusted inputs' envelope.
//! The [`dfl::chaos`] harness composes attacks with drift, failures and
//! compression; `--fold mean --adversary none` is bit-identical to the
//! unhardened engine.
//!
//! Nor is the learning itself idealized: the **scenario zoo**
//! ([`dfl::data`] — `--dirichlet-alpha`, `--participation`,
//! `--straggler-frac`/`--straggler-slowdown`, `--algo {fedavg,dpsgd}`)
//! deals seeded Dirichlet non-IID class shards to each node, samples a
//! per-round participant subset (non-participants skip training and
//! originate nothing but still relay), holds straggler transmit
//! opportunities back by a slowdown factor inside the slot schedule, and
//! can swap the FedAvg fold for D-PSGD neighbor mixing. The
//! [`dfl::convergence`] harness runs the zoo end to end over the real
//! engine with a synthetic quadratic learner and reports
//! accuracy-vs-round and accuracy-vs-wire-MB curves
//! (`benches/convergence_sweep.rs`); every knob's default (`α = inf`,
//! `p = 1`, no stragglers, FedAvg) is bit-identical to the plain engine
//! (`tests/engine_equivalence.rs`, `tests/learning_dynamics.rs`).
//!
//! On top of single rounds the engine pipelines **multiple rounds over
//! one long-lived simulator** ([`coordinator::engine::RoundEngine::run_pipelined`]):
//! each node seeds round *t+1* the moment it has aggregated round *t*,
//! so next-round seeds gossip in slots round *t* has vacated — the
//! paper's §III-D observation that forwarded copies pipeline with the
//! next round. `dfl::round::run_dfl` trains through this path, and
//! [`metrics::RoundMetrics`] carries per-slot timing so the overlap is
//! measurable (see `benches/engine_pipeline.rs` and
//! `benches/segment_sweep.rs`).
//!
//! Dissemination need not ride a single tree either: the **multi-tree
//! plane** ([`mst::disjoint`], `--trees k`) carves up to `k` pairwise
//! edge-disjoint spanning trees from the measured costs and
//! [`coordinator::engine::RoundEngine::run_forest_round`] stripes each
//! model copy across them ([`dfl::transfer::TransferPlan::stripe`]) —
//! `k` thinner concurrent streams over disjoint edges instead of one
//! thick one through the MST hub, with `trees = 1` bit-identical to the
//! single-MST engine. `benches/planner_tournament.rs` races flooding,
//! random gossip, the single MST, and the forest head to head.
//!
//! Links are not frozen at session start: `netsim` channels take
//! scripted shifts or seeded drift, `coordinator::probe` re-measures
//! pings online through the drivers and re-plans (incremental MST via
//! `mst::incremental`, recolor, fresh §III-C slot budget), and
//! `coordinator::engine::RoundEngine::run_pipelined_adaptive` migrates
//! the pipeline to each new plan at the next round boundary
//! (`--drift` / `--probe-every` / `--replan-threshold`; static
//! defaults are bit-identical to the frozen engine).
//!
//! ## The scale-out plane (hierarchical subnets + sharded simulation)
//!
//! Beyond the paper's flat ten-node overlay, the reproduction scales to
//! hierarchies of tens of thousands of nodes: [`graph::generators`]
//! builds router-hierarchy overlays (subnets joined by gateway/backbone
//! links, plus random-geometric graphs), [`coordinator::hierarchy`]
//! plans per-subnet MSTs and colorings stitched through a backbone MST
//! into one [`coordinator::engine::PlanEpoch`], and
//! [`netsim::shard::ShardedNetSim`] simulates each subnet on its own
//! event queue with only gateway flows crossing shards (thread-parallel
//! between round barriers — see
//! [`coordinator::engine::sharded`]). Every knob collapses to the flat
//! paper pipeline when neutral: one subnet plans flat bit for bit, one
//! shard simulates flat bit for bit (`tests/engine_equivalence.rs`).
//! `--topology-gen hierarchy --subnets S --gateway-links L` on the CLI;
//! [`coordinator::session::ScaleScenario`] and `benches/scale_sweep.rs`
//! drive it to n = 10k.
//!
//! None of these artifacts are taken on faith: the **static verification
//! plane** ([`analysis::plan_lint`]) re-checks every published plan
//! without running a simulator — trees span and stay acyclic, colorings
//! are proper with zero half-duplex conflicts in any slot, forest lanes
//! are pairwise edge-disjoint, the slot budget matches the §III-C
//! formula over the measured costs, stripes conserve bytes against the
//! [`dfl::transfer::TransferPlan`], and participation masks agree with
//! origination. The linter runs as a `debug_assertions` hook after every
//! moderator plan/replan, as the `lint-plan` CLI subcommand, and as a
//! mutation-tested suite (`tests/plan_lint.rs`). Its concurrency
//! counterpart model-checks the work-stealing [`netsim::pool`] under
//! loom (`--features loom`) with Miri and ThreadSanitizer jobs in CI.
//!
//! The `runtime` module loads the AOT artifacts through PJRT so the gossip
//! request path never touches Python.
//!
//! Start with [`coordinator::session::GossipSession`] (one line to schedule
//! and run a round) or `examples/quickstart.rs`. A layer-by-layer tour
//! lives in [`docs::architecture`] (docs/ARCHITECTURE.md) and a runnable
//! scenario cookbook in [`docs::experiments`] (docs/EXPERIMENTS.md).

pub mod analysis;
pub mod coloring;
pub mod config;
pub mod coordinator;
pub mod dfl;
pub mod graph;
pub mod metrics;
pub mod mst;
pub mod netsim;
pub mod runtime;
pub mod transport;
pub mod util;

pub mod bench;

/// Rendered project documentation — the `docs/` pages embedded so
/// `cargo doc --no-deps` (CI runs it with `-D warnings`) resolves and
/// link-checks their intra-doc references on every push.
pub mod docs {
    #[doc = include_str!("../../docs/ARCHITECTURE.md")]
    pub mod architecture {}

    #[doc = include_str!("../../docs/EXPERIMENTS.md")]
    pub mod experiments {}
}
