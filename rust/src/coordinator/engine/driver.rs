//! Byte-movement drivers for the [`RoundEngine`](super::RoundEngine).
//!
//! The engine owns protocol state and slot structure; a [`Driver`] owns
//! the substrate that actually carries model copies and tells the engine,
//! **per flow**, when each copy has arrived:
//!
//! * [`SimDriver`] — the discrete-event network simulator (`netsim`),
//!   stepping one completion event at a time via
//!   [`NetSim::run_next_completion`](crate::netsim::NetSim::run_next_completion).
//!   Supports relabeled node ids for churn's induced subgraphs.
//! * [`LogicalDriver`] — untimed instant delivery; one clock tick per
//!   slot. This is the substrate behind the paper's Table I queue trace.
//! * [`LiveDriver`] — real byte payloads over a [`Transport`] mesh
//!   (in-memory channels or shaped loopback TCP), timed on the wall
//!   clock.

use crate::coordinator::broadcast::flow_tag;
use crate::coordinator::queue::ModelKey;
use crate::graph::NodeId;
use crate::netsim::testbed::Testbed;
use crate::netsim::{FlowRecord, NetSim};
use crate::transport::{Message, Transport};
use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

/// Opaque handle for one launched model copy.
pub type CopyToken = u64;

/// One copy has fully arrived at its recipient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub token: CopyToken,
    /// Driver-clock delivery time (seconds).
    pub at_s: f64,
}

/// A substrate that moves model copies and reports per-flow completion
/// events. All engine modes (simulated, logical, live) implement this.
pub trait Driver {
    /// Begin transferring one `model_mb`-sized copy of `key`'s model from
    /// `from` to `to`. Returns a token identifying the copy.
    fn launch(&mut self, from: NodeId, to: NodeId, key: ModelKey, model_mb: f64) -> CopyToken;

    /// Advance the substrate until at least one in-flight copy completes
    /// and return the newly completed copies. An empty vector means
    /// nothing is in flight (or the substrate stalled — the engine treats
    /// that as fatal while copies are outstanding).
    fn wait_any(&mut self) -> Vec<Completion>;

    /// Current driver clock in seconds.
    fn now(&self) -> f64;

    /// Drain the low-level transfer records accumulated so far.
    fn take_transfers(&mut self) -> Vec<FlowRecord>;
}

/// Driver over the discrete-event fluid-flow simulator.
///
/// `map[protocol id] = device id` relabels flows onto testbed hosts; the
/// identity map is the common case, churn passes the induced-subgraph
/// relabeling so surviving members keep their original routes.
pub struct SimDriver<'a> {
    testbed: &'a Testbed,
    sim: NetSim,
    map: Vec<NodeId>,
}

impl<'a> SimDriver<'a> {
    /// Fresh simulator over the testbed wiring, identity node map.
    pub fn new(testbed: &'a Testbed, seed: u64) -> Self {
        let map = (0..testbed.node_count()).collect();
        SimDriver { testbed, sim: testbed.netsim(seed), map }
    }

    /// As [`SimDriver::new`] with an explicit protocol-id → device-id map
    /// (churn's relabeled trees).
    pub fn with_map(testbed: &'a Testbed, seed: u64, map: Vec<NodeId>) -> Self {
        assert!(
            map.iter().all(|&d| d < testbed.node_count()),
            "map addresses a device outside the testbed"
        );
        SimDriver { testbed, sim: testbed.netsim(seed), map }
    }

    pub fn sim(&self) -> &NetSim {
        &self.sim
    }
}

impl Driver for SimDriver<'_> {
    fn launch(&mut self, from: NodeId, to: NodeId, key: ModelKey, model_mb: f64) -> CopyToken {
        let (src, dst) = (self.map[from], self.map[to]);
        self.sim.start_flow(
            src,
            dst,
            self.testbed.route(src, dst),
            model_mb,
            flow_tag(self.map[key.owner], src),
        ) as CopyToken
    }

    fn wait_any(&mut self) -> Vec<Completion> {
        self.sim
            .run_next_completion()
            .into_iter()
            .map(|r| Completion { token: r.flow as CopyToken, at_s: r.end })
            .collect()
    }

    fn now(&self) -> f64 {
        self.sim.now()
    }

    fn take_transfers(&mut self) -> Vec<FlowRecord> {
        self.sim.take_completed()
    }
}

/// Untimed driver: every launched copy completes at the next `wait_any`,
/// which advances the clock by one unit (≈ one slot). Produces the exact
/// slot-by-slot semantics of the paper's Table I.
#[derive(Debug, Default)]
pub struct LogicalDriver {
    clock: f64,
    next_token: CopyToken,
    inflight: Vec<(CopyToken, NodeId, NodeId, ModelKey, f64)>,
    transfers: Vec<FlowRecord>,
}

impl LogicalDriver {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Driver for LogicalDriver {
    fn launch(&mut self, from: NodeId, to: NodeId, key: ModelKey, model_mb: f64) -> CopyToken {
        let token = self.next_token;
        self.next_token += 1;
        self.inflight.push((token, from, to, key, model_mb));
        token
    }

    fn wait_any(&mut self) -> Vec<Completion> {
        if self.inflight.is_empty() {
            return Vec::new();
        }
        self.clock += 1.0;
        let done = std::mem::take(&mut self.inflight);
        done.into_iter()
            .map(|(token, from, to, key, model_mb)| {
                self.transfers.push(FlowRecord {
                    flow: token as usize,
                    src: from,
                    dst: to,
                    payload_mb: model_mb,
                    start: self.clock - 1.0,
                    end: self.clock,
                    tag: flow_tag(key.owner, from),
                });
                Completion { token, at_s: self.clock }
            })
            .collect()
    }

    fn now(&self) -> f64 {
        self.clock
    }

    fn take_transfers(&mut self) -> Vec<FlowRecord> {
        std::mem::take(&mut self.transfers)
    }
}

/// Driver over real transports: model copies are actual byte payloads
/// pushed through a [`Transport`] mesh (in-memory channels for tests,
/// token-bucket-shaped loopback TCP for the live cluster), timed on the
/// wall clock.
///
/// The driver owns every endpoint of the mesh, so the engine remains the
/// single protocol authority — the in-process counterpart of the paper's
/// moderator-scheduled deployment. Endpoint `i` must carry node id `i`.
pub struct LiveDriver<T: Transport> {
    endpoints: Vec<T>,
    epoch: Instant,
    next_token: CopyToken,
    /// (sender, recipient, model) → tokens awaiting that arrival, FIFO so
    /// retransmissions of the same copy resolve in launch order.
    inflight: HashMap<(NodeId, NodeId, ModelKey), VecDeque<CopyToken>>,
    inflight_count: usize,
    launched: HashMap<CopyToken, (NodeId, NodeId, ModelKey, f64, f64)>,
    transfers: Vec<FlowRecord>,
    poll: Duration,
    stall_timeout: Duration,
}

impl<T: Transport> LiveDriver<T> {
    pub fn new(endpoints: Vec<T>) -> Self {
        assert!(!endpoints.is_empty(), "live driver needs at least one endpoint");
        for (i, ep) in endpoints.iter().enumerate() {
            assert_eq!(ep.node(), i, "endpoints must be ordered by node id");
        }
        LiveDriver {
            endpoints,
            epoch: Instant::now(),
            next_token: 0,
            inflight: HashMap::new(),
            inflight_count: 0,
            launched: HashMap::new(),
            transfers: Vec::new(),
            poll: Duration::from_millis(2),
            stall_timeout: Duration::from_secs(30),
        }
    }

    /// How long `wait_any` keeps polling before declaring the mesh
    /// stalled (the engine then aborts the round).
    pub fn set_stall_timeout(&mut self, timeout: Duration) {
        self.stall_timeout = timeout;
    }
}

impl<T: Transport> Driver for LiveDriver<T> {
    fn launch(&mut self, from: NodeId, to: NodeId, key: ModelKey, model_mb: f64) -> CopyToken {
        let bytes = ((model_mb * 1024.0 * 1024.0).ceil() as usize).max(1);
        let token = self.next_token;
        self.next_token += 1;
        let start = self.epoch.elapsed().as_secs_f64();
        self.endpoints[from]
            .send(
                to,
                Message::Model {
                    owner: key.owner as u32,
                    round: key.round as u32,
                    payload: vec![key.owner as u8; bytes],
                },
            )
            .expect("live transport send failed");
        self.inflight.entry((from, to, key)).or_default().push_back(token);
        self.inflight_count += 1;
        self.launched.insert(token, (from, to, key, model_mb, start));
        token
    }

    fn wait_any(&mut self) -> Vec<Completion> {
        if self.inflight_count == 0 {
            return Vec::new();
        }
        let deadline = Instant::now() + self.stall_timeout;
        let mut out = Vec::new();
        while out.is_empty() {
            if Instant::now() > deadline {
                return out; // stalled: engine asserts with copies in flight
            }
            for (d, endpoint) in self.endpoints.iter_mut().enumerate() {
                loop {
                    let msg = endpoint.try_recv().expect("live transport recv failed");
                    let Some((src, msg)) = msg else { break };
                    let Message::Model { owner, round, .. } = msg else { continue };
                    let key = ModelKey::new(owner as usize, round as u64);
                    let Some(queue) = self.inflight.get_mut(&(src, d, key)) else { continue };
                    let Some(token) = queue.pop_front() else { continue };
                    self.inflight_count -= 1;
                    let at = self.epoch.elapsed().as_secs_f64();
                    let (from, to, key, model_mb, start) =
                        self.launched.remove(&token).expect("completion for unknown token");
                    self.transfers.push(FlowRecord {
                        flow: token as usize,
                        src: from,
                        dst: to,
                        payload_mb: model_mb,
                        start,
                        end: at,
                        tag: flow_tag(key.owner, from),
                    });
                    out.push(Completion { token, at_s: at });
                }
            }
            if out.is_empty() {
                std::thread::sleep(self.poll);
            }
        }
        out
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn take_transfers(&mut self) -> Vec<FlowRecord> {
        std::mem::take(&mut self.transfers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::transport::memory;

    fn testbed() -> Testbed {
        Testbed::new(&ExperimentConfig { latency_jitter: 0.0, ..Default::default() })
    }

    #[test]
    fn sim_driver_reports_per_flow_completions() {
        let tb = testbed();
        let mut d = SimDriver::new(&tb, 1);
        let t0 = d.launch(0, 1, ModelKey::new(0, 0), 2.0);
        let t1 = d.launch(2, 5, ModelKey::new(2, 0), 14.0);
        let first = d.wait_any();
        assert_eq!(first.len(), 1, "unequal sizes must complete separately");
        assert_eq!(first[0].token, t0);
        let second = d.wait_any();
        assert_eq!(second[0].token, t1);
        assert!(second[0].at_s > first[0].at_s);
        assert!(d.wait_any().is_empty());
        assert_eq!(d.take_transfers().len(), 2);
    }

    #[test]
    fn sim_driver_map_relabels_devices() {
        let tb = testbed();
        // protocol node 0 -> device 7, protocol node 1 -> device 2
        let map = vec![7, 2, 0, 1, 3, 4, 5, 6, 8, 9];
        let mut d = SimDriver::with_map(&tb, 1, map);
        d.launch(0, 1, ModelKey::new(0, 0), 1.0);
        d.wait_any();
        let rec = &d.take_transfers()[0];
        assert_eq!((rec.src, rec.dst), (7, 2));
        assert_eq!(crate::coordinator::broadcast::tag_owner(rec.tag), 7);
    }

    #[test]
    fn logical_driver_ticks_one_unit_per_batch() {
        let mut d = LogicalDriver::new();
        assert!(d.wait_any().is_empty());
        d.launch(0, 1, ModelKey::new(0, 0), 1.0);
        d.launch(1, 0, ModelKey::new(1, 0), 1.0);
        let done = d.wait_any();
        assert_eq!(done.len(), 2);
        assert_eq!(d.now(), 1.0);
        d.launch(0, 1, ModelKey::new(1, 0), 1.0);
        d.wait_any();
        assert_eq!(d.now(), 2.0);
        assert_eq!(d.take_transfers().len(), 3);
    }

    #[test]
    fn live_driver_moves_bytes_over_memory_mesh() {
        let mut d = LiveDriver::new(memory::mesh(4));
        let key = ModelKey::new(2, 0);
        let token = d.launch(2, 3, key, 0.0001);
        let done = d.wait_any();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].token, token);
        let recs = d.take_transfers();
        assert_eq!((recs[0].src, recs[0].dst), (2, 3));
        assert!(recs[0].end >= recs[0].start);
        assert!(d.wait_any().is_empty());
    }
}
