//! Ablation: MST algorithm choice (paper §III-B's complexity discussion —
//! Kruskal O(E log E), Prim O(E + V log V), Borůvka O(E log V); the paper
//! picks Prim for dense/complete overlays). Times all three on graphs of
//! growing size and density and verifies they agree on total weight.

use mosgu::bench::{bench, section};
use mosgu::graph::topology::{complete, erdos_renyi};
use mosgu::graph::Graph;
use mosgu::mst::MstAlgorithm;
use mosgu::util::rng::Pcg64;

fn weighted(g: &Graph, rng: &mut Pcg64) -> Graph {
    let mut out = Graph::new(g.node_count());
    for e in g.sorted_edges() {
        out.add_edge(e.u, e.v, rng.gen_f64_range(1.0, 100.0));
    }
    out
}

fn main() {
    let mut rng = Pcg64::new(42);
    for (label, n) in [("paper scale", 10usize), ("medium", 100), ("large", 400)] {
        section(&format!("{label}: complete graph K_{n} (dense — the paper's overlay)"));
        let g = weighted(&complete(n), &mut rng);
        let mut weights = Vec::new();
        for alg in MstAlgorithm::ALL {
            let r = bench(&format!("{} on K_{n}", alg.name()), 2, 12, || alg.run(&g).unwrap());
            println!("{}", r.report());
            weights.push(alg.run(&g).unwrap().total_weight());
        }
        assert!(
            weights.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-6),
            "MST algorithms disagree: {weights:?}"
        );
        println!("  all algorithms agree: total weight {:.3}", weights[0]);
    }

    section("sparse Erdos-Renyi (p=0.05, n=400) — Kruskal's best case");
    let g = weighted(&erdos_renyi(400, 0.05, &mut rng), &mut rng);
    if g.is_connected() {
        for alg in MstAlgorithm::ALL {
            let r = bench(&format!("{} on sparse ER", alg.name()), 2, 12, || alg.run(&g).unwrap());
            println!("{}", r.report());
        }
    }
}
