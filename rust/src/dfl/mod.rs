//! Decentralized federated learning layer: the Table II model registry,
//! the artifact-driven per-node trainer, segment-granular transfer
//! planning, payload compression codecs (quantization / top-k with
//! error feedback), DFL round orchestration (train → gossip →
//! aggregate), and the adversarial robustness plane (Byzantine node
//! behaviors, robust fold policies, and the chaos-injection harness).

pub mod adversary;
pub mod chaos;
pub mod compress;
pub mod models;
pub mod robust;
pub mod round;
pub mod trainer;
pub mod transfer;
