//! The MOSGU gossip protocol state (paper §III-D).
//!
//! [`GossipState`] holds the protocol logic — who sends which queue entry
//! to whom in a slot, and how deliveries update the recipients' queues.
//! It does not move bytes or advance clocks itself: every execution mode
//! drives it through `coordinator::engine::RoundEngine` over a `Driver`
//! ([`run_logical_round`] uses the untimed `LogicalDriver` to produce the
//! paper's Table I trace; `session::run_mosgu_round` the simulator-backed
//! `SimDriver` for the timing metrics of Tables III–V; `LiveDriver` the
//! real-socket transports).
//!
//! For multi-round pipelining (§III-D, "forwarded copies pipeline with
//! the next round") the state supports *per-node* seeding: a node joins
//! round `t+1` as soon as it has aggregated round `t`, while its
//! remaining round-`t` forwarding obligations stay queued ahead of the
//! new seed.

use super::queue::{GossipQueue, ModelKey, QueueEntry};
use super::schedule::Schedule;
use crate::dfl::adversary::DropPlan;
use crate::graph::{Graph, NodeId};
use std::collections::HashSet;
use std::rc::Rc;

/// One delivered copy: `from` forwards model `key` to `to`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Send {
    pub from: NodeId,
    pub to: NodeId,
    pub key: ModelKey,
}

/// One transmitter's planned slot activity: the popped queue entry and the
/// neighbors it addresses. A network failure re-queues the *entry* (all
/// recipients retried next turn; duplicate deliveries are deduplicated at
/// the receiver).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlannedTx {
    pub from: NodeId,
    pub entry: QueueEntry,
    pub recipients: Vec<NodeId>,
}

impl PlannedTx {
    pub fn sends(&self) -> impl Iterator<Item = Send> + '_ {
        self.recipients.iter().map(move |&to| Send { from: self.from, to, key: self.entry.key })
    }
}

/// Protocol state for one communication round over a gossip tree.
#[derive(Debug, Clone)]
pub struct GossipState {
    tree: Graph,
    queues: Vec<GossipQueue>,
    round: u64,
    /// Byzantine dropping-relay plan (robustness plane). `None` — the
    /// default — is the zero-overhead honest path.
    drops: Option<Rc<DropPlan>>,
    /// `(holder, owner)` pairs whose held copy is junk: a dropping relay
    /// garbled the content somewhere upstream. Timing, queueing and
    /// completion are untouched (the attack is stealthy — right-sized
    /// garbage bytes still flow); only the fold excludes these copies.
    junk: HashSet<(NodeId, NodeId)>,
}

impl GossipState {
    /// Start a round: every node seeds its locally trained model.
    pub fn new(tree: Graph, round: u64) -> Self {
        let mut state = Self::unseeded(tree, round);
        for u in 0..state.node_count() {
            state.seed_node(u);
        }
        state
    }

    /// Start a round with **no** node seeded yet. The pipelined engine
    /// seeds nodes individually (via [`GossipState::seed_node`]) as each
    /// finishes the previous round.
    pub fn unseeded(tree: Graph, round: u64) -> Self {
        assert!(tree.is_tree(), "gossip graph must be the moderator's MST");
        let n = tree.node_count();
        let queues: Vec<GossipQueue> = (0..n).map(GossipQueue::new).collect();
        GossipState { tree, queues, round, drops: None, junk: HashSet::new() }
    }

    /// Install (or clear) the Byzantine dropping-relay plan. Junk markers
    /// from a previous plan are discarded.
    pub fn set_drops(&mut self, drops: Option<Rc<DropPlan>>) {
        self.drops = drops;
        self.junk.clear();
    }

    /// Whether the copy of `owner`'s model held at `holder` is junk
    /// (garbled by a dropping relay upstream). Junk copies must not feed
    /// the fold.
    pub fn is_junk(&self, holder: NodeId, owner: NodeId) -> bool {
        !self.junk.is_empty() && self.junk.contains(&(holder, owner))
    }

    /// Number of junked copies across all nodes (diagnostics).
    pub fn junk_count(&self) -> usize {
        self.junk.len()
    }

    /// Track content integrity for a delivery: the copy lands junked if
    /// the sender's own held copy was already junk (garbage propagates
    /// downstream) or the sender is a dropping relay junking this edge.
    /// A node's **own** model is always sent honestly (`owner == from`) —
    /// the relay attack corrupts only what it forwards for others, which
    /// both keeps the attacker covert and matches the lethal case: a
    /// relay that garbles its own model too would be trivially detected.
    fn track_junk(&mut self, send: Send) {
        if let Some(drops) = &self.drops {
            if send.key.owner != send.from
                && (self.junk.contains(&(send.from, send.key.owner))
                    || drops.drops(send.from, send.to))
            {
                self.junk.insert((send.to, send.key.owner));
            }
        }
    }

    /// Seed node `u`'s locally trained model for this round (panics if
    /// seeded twice).
    pub fn seed_node(&mut self, u: NodeId) {
        self.queues[u].seed_own(self.round);
    }

    pub fn tree(&self) -> &Graph {
        &self.tree
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    pub fn queue(&self, u: NodeId) -> &GossipQueue {
        &self.queues[u]
    }

    pub fn node_count(&self) -> usize {
        self.tree.node_count()
    }

    /// All nodes hold all models ⇒ the communication round is complete.
    pub fn is_complete(&self) -> bool {
        let n = self.tree.node_count();
        self.queues.iter().all(|q| q.held_count() == n)
    }

    /// Every node holds at least `goal` models — round completion under
    /// a partial-participation plan, where `goal` is the round's
    /// originator count ([`is_complete`](Self::is_complete) with `goal =
    /// n`: nobody can hold more models than exist).
    pub fn all_hold(&self, goal: usize) -> bool {
        self.queues.iter().all(|q| q.held_count() >= goal)
    }

    /// Plan the transmissions of one slot for the given transmitting class.
    ///
    /// Each transmitter pops its oldest entry and addresses every tree
    /// neighbor except the entry's source. Entries are consumed here;
    /// failed transmissions go back via [`GossipState::requeue`].
    pub fn plan_slot(&mut self, transmitters: &[NodeId]) -> Vec<PlannedTx> {
        transmitters.iter().filter_map(|&u| self.plan_node(u)).collect()
    }

    /// Plan at most one transmission for node `u`: pop its oldest pending
    /// entry and address every tree neighbor except the entry's source.
    /// `None` when the node has nothing queued (it idles — or, in the
    /// pipelined engine, services the next round instead).
    pub fn plan_node(&mut self, u: NodeId) -> Option<PlannedTx> {
        let entry = self.queues[u].pop_oldest()?;
        let recipients: Vec<NodeId> = self
            .tree
            .neighbor_ids(u)
            .into_iter()
            .filter(|&v| Some(v) != entry.received_from)
            .collect();
        debug_assert!(
            !recipients.is_empty() || entry.received_from.is_some(),
            "own model must always have a recipient"
        );
        Some(PlannedTx { from: u, entry, recipients })
    }

    /// Apply a successful delivery. Returns `true` if the model was new to
    /// the recipient (false = deduplicated retransmission). Degree-1
    /// recipients hold but never re-forward (§III-D).
    pub fn deliver(&mut self, send: Send) -> bool {
        self.track_junk(send);
        let enqueue = self.tree.degree(send.to) > 1;
        self.queues[send.to].receive(send.key, send.from, enqueue)
    }

    /// Apply a cut-through delivery: the recipient holds the reassembled
    /// model but queues **no** forwarding obligation — the engine's relay
    /// cascade already forwarded every segment inline as it arrived (see
    /// `coordinator::engine`). Returns `true` if the model was new.
    pub fn deliver_reassembled(&mut self, send: Send) -> bool {
        self.track_junk(send);
        self.queues[send.to].receive(send.key, send.from, false)
    }

    /// Queue a normal-path retransmission at `node` after one of its
    /// inline cut-through forwards was disrupted: the relay holds the
    /// model (so [`GossipState::deliver`] would deduplicate it) but must
    /// re-offer it to its neighbors on its next turn. No-op when the key
    /// is already pending at the node.
    pub fn enqueue_forward(&mut self, node: NodeId, key: ModelKey, received_from: NodeId) {
        if !self.queues[node].has_pending(&key) {
            self.queues[node].push_back(QueueEntry { key, received_from: Some(received_from) });
        }
    }

    /// Re-queue an entry whose transmission failed (network disruption),
    /// at the front, so the node retries on its next turn.
    pub fn requeue(&mut self, tx: &PlannedTx) {
        self.queues[tx.from].push_front(tx.entry);
    }

    /// Deterministic delivery order within a slot: ascending sender id,
    /// then recipient id — reproduces the paper's Table I strings.
    pub fn sorted_sends(planned: &[PlannedTx]) -> Vec<Send> {
        let mut sends: Vec<Send> = planned.iter().flat_map(|tx| tx.sends()).collect();
        sends.sort_by_key(|s| (s.from, s.to));
        sends
    }

    /// Render a node's queue like Table I: concatenated owner labels in
    /// reception order (e.g. "FEGH" for node F).
    pub fn held_string(&self, u: NodeId, label: impl Fn(NodeId) -> char) -> String {
        self.queues[u].held_order().iter().map(|k| label(k.owner)).collect()
    }
}

/// Outcome of one untimed slot.
#[derive(Debug, Clone)]
pub struct SlotTrace {
    pub slot: usize,
    pub color: usize,
    pub sends: Vec<Send>,
}

/// Full untimed round trace (the paper's Table I).
#[derive(Debug, Clone)]
pub struct RoundTrace {
    pub slots: Vec<SlotTrace>,
    /// held-order strings per node after each slot (row-major: slot, node)
    pub rows: Vec<Vec<String>>,
}

impl RoundTrace {
    /// Render the trace as a Table-I-like text table.
    pub fn render(&self, labels: &[String], color_names: &[&str]) -> String {
        let mut out = String::new();
        out.push_str("slot color ");
        for l in labels {
            out.push_str(&format!("{l:>12}"));
        }
        out.push('\n');
        for (i, slot) in self.slots.iter().enumerate() {
            out.push_str(&format!(
                "{:>4} {:>5} ",
                slot.slot + 1,
                color_names.get(slot.color).copied().unwrap_or("?")
            ));
            for cell in &self.rows[i] {
                out.push_str(&format!("{cell:>12}"));
            }
            out.push('\n');
        }
        out
    }
}

/// Run one communication round slot-by-slot with instant transfers,
/// recording the queue-evolution rows of Table I. Panics if the round does
/// not complete within `max_slots` (protocol bug guard).
///
/// This is the engine's untimed mode: [`RoundEngine`] over a
/// [`LogicalDriver`], with an observer capturing the per-slot rows. The
/// delivery order (ascending sender, then recipient) is the engine's
/// deterministic order, so the trace reproduces the paper's Table I
/// strings move for move.
pub fn run_logical_round(
    state: &mut GossipState,
    schedule: &Schedule,
    label: impl Fn(NodeId) -> char + Copy,
    max_slots: usize,
) -> RoundTrace {
    use super::engine::driver::LogicalDriver;
    use super::engine::{RoundEngine, RoundOptions};

    let n = state.tree.node_count();
    let mut driver = LogicalDriver::new();
    let mut engine = RoundEngine::new(&mut driver, schedule);
    let mut trace = RoundTrace { slots: Vec::new(), rows: Vec::new() };
    let _ = engine.run_round(state, RoundOptions::reliable(1.0, max_slots), |out, st| {
        trace.slots.push(SlotTrace { slot: out.slot, color: out.color, sends: out.sends.clone() });
        trace.rows.push((0..n).map(|u| st.held_string(u, label)).collect());
    });
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::example;
    use crate::coordinator::schedule::build_schedule;

    fn example_state() -> GossipState {
        GossipState::new(example::paper_example_mst(), 0)
    }

    fn example_schedule() -> Schedule {
        build_schedule(
            &example::paper_example_graph(),
            example::paper_example_coloring(),
            14.0,
            56,
            example::RED,
        )
    }

    #[test]
    fn seeding_gives_each_node_its_own_model() {
        let st = example_state();
        for u in 0..10 {
            assert_eq!(st.queue(u).held_count(), 1);
            assert!(st.queue(u).holds(&ModelKey::new(u, 0)));
        }
        assert!(!st.is_complete());
    }

    #[test]
    fn first_red_slot_matches_table1_row1() {
        let mut st = example_state();
        let sched = example_schedule();
        let tx = sched.transmitters(0);
        // red class = {C, E, G, H, I}
        let labels: Vec<char> = tx.iter().map(|&u| example::label(u)).collect();
        assert_eq!(labels, vec!['C', 'E', 'G', 'H', 'I']);
        let planned = st.plan_slot(&tx);
        for s in GossipState::sorted_sends(&planned) {
            st.deliver(s);
        }
        // Table I row 1: A=AH, B=BCI, D=DC, F=FEGH, K=KGI
        let s = |u| st.held_string(u, example::label);
        assert_eq!(s(example::A), "AH");
        assert_eq!(s(example::B), "BCI");
        assert_eq!(s(example::D), "DC");
        assert_eq!(s(example::F), "FEGH");
        assert_eq!(s(example::K), "KGI");
    }

    #[test]
    fn own_model_goes_to_all_neighbors() {
        let mut st = example_state();
        let planned = st.plan_slot(&[example::F]);
        // F's neighbors: E, G, H (own model — nobody to skip)
        assert_eq!(planned.len(), 1);
        let tos: Vec<char> = planned[0].recipients.iter().map(|&v| example::label(v)).collect();
        assert_eq!(tos, vec!['E', 'G', 'H']);
    }

    #[test]
    fn forwarded_model_skips_source() {
        let mut st = example_state();
        // H sends its model to A and F
        for s in GossipState::sorted_sends(&st.plan_slot(&[example::H])) {
            st.deliver(s);
        }
        // A sends its own model to H
        for s in GossipState::sorted_sends(&st.plan_slot(&[example::A])) {
            st.deliver(s);
        }
        // H forwards A's model: must go to F only (skip source A)
        let planned = st.plan_slot(&[example::H]);
        assert_eq!(planned.len(), 1);
        assert_eq!(planned[0].entry.key.owner, example::A);
        assert_eq!(planned[0].recipients, vec![example::F]);
    }

    #[test]
    fn degree_one_never_forwards_received() {
        let mut st = example_state();
        // E is a leaf: deliver F's model to E, E's queue must stay own-only
        for s in GossipState::sorted_sends(&st.plan_slot(&[example::F])) {
            st.deliver(s);
        }
        // E pops own model first
        let own = st.plan_slot(&[example::E]);
        assert_eq!(own[0].entry.key.owner, example::E);
        for s in GossipState::sorted_sends(&own) {
            st.deliver(s);
        }
        // after that, E has nothing pending although it holds F's model
        assert!(st.queue(example::E).holds(&ModelKey::new(example::F, 0)));
        assert!(st.plan_slot(&[example::E]).is_empty());
    }

    #[test]
    fn full_round_completes_and_matches_paper_final_row() {
        let mut st = example_state();
        let sched = example_schedule();
        let trace = run_logical_round(&mut st, &sched, example::label, 64);
        assert!(st.is_complete());
        // Paper Table I final row (all models at all nodes, reception order):
        let expect = [
            (example::A, "AHFEGKIBCD"),
            (example::B, "BCIDKGFEHA"),
            (example::C, "CBDIKGFEHA"),
            (example::D, "DCBIKGFEHA"),
            (example::E, "EFGHAKIBCD"),
            (example::F, "FEGHAKIBCD"),
            (example::G, "GFKEIHABCD"),
            (example::H, "HAFEGKIBCD"),
            (example::I, "IBKCGDFEHA"),
            (example::K, "KGIFBECHDA"),
        ];
        for (u, want) in expect {
            assert_eq!(
                st.held_string(u, example::label),
                want,
                "node {} order mismatch",
                example::label(u)
            );
        }
        // Table I has 23 rows (12 red, 11 blue)
        assert_eq!(trace.slots.len(), 23, "paper's trace has 23 slots");
    }

    #[test]
    fn failed_transmission_is_retried_and_deduplicated() {
        let mut st = example_state();
        // C transmits its model to B and D, but the network drops it
        let planned = st.plan_slot(&[example::C]);
        assert_eq!(planned.len(), 1);
        // partial failure: B received, D did not
        let sends: Vec<Send> = planned[0].sends().collect();
        let to_b = sends.iter().find(|s| s.to == example::B).unwrap();
        assert!(st.deliver(*to_b));
        st.requeue(&planned[0]);
        // next turn: C retries the same entry to both; B dedups
        let retry = st.plan_slot(&[example::C]);
        assert_eq!(retry[0].entry.key.owner, example::C);
        let sends = GossipState::sorted_sends(&retry);
        let mut fresh = 0;
        for s in sends {
            if st.deliver(s) {
                fresh += 1;
            }
        }
        assert_eq!(fresh, 1, "only D should be new on retry");
        assert!(st.queue(example::D).holds(&ModelKey::new(example::C, 0)));
    }

    #[test]
    fn reassembled_delivery_holds_without_forward_obligation() {
        let mut st = example_state();
        // F (degree 3) receives H's model via cut-through: held, not queued
        let send = Send { from: example::H, to: example::F, key: ModelKey::new(example::H, 0) };
        assert!(st.deliver_reassembled(send));
        assert!(st.queue(example::F).holds(&ModelKey::new(example::H, 0)));
        assert!(!st.queue(example::F).has_pending(&ModelKey::new(example::H, 0)));
        // duplicate reassembly is deduplicated
        assert!(!st.deliver_reassembled(send));
        // a disrupted inline forward re-queues exactly once
        st.enqueue_forward(example::F, ModelKey::new(example::H, 0), example::H);
        st.enqueue_forward(example::F, ModelKey::new(example::H, 0), example::H);
        let pending: Vec<_> = st
            .queue(example::F)
            .pending_keys()
            .into_iter()
            .filter(|k| k.owner == example::H)
            .collect();
        assert_eq!(pending.len(), 1);
    }

    #[test]
    fn line_graph_round_completes() {
        // 4-node path: dissemination needs several alternating slots
        let mut tree = Graph::new(4);
        tree.add_edge(0, 1, 1.0);
        tree.add_edge(1, 2, 1.0);
        tree.add_edge(2, 3, 1.0);
        let coloring = crate::coloring::bfs_coloring(&tree);
        let sched = Schedule { coloring, slot_len_s: 1.0, first_color: 0 };
        let mut st = GossipState::new(tree, 0);
        let trace = run_logical_round(&mut st, &sched, |u| (b'a' + u as u8) as char, 32);
        assert!(st.is_complete());
        assert!(trace.slots.len() >= 4);
    }

    #[test]
    fn dropping_relay_junks_forwards_but_not_own_model() {
        // chain 0-1-2-3, Byzantine relay 1 junking the 1→2 edge
        let mut tree = Graph::new(4);
        tree.add_edge(0, 1, 1.0);
        tree.add_edge(1, 2, 1.0);
        tree.add_edge(2, 3, 1.0);
        let coloring = crate::coloring::bfs_coloring(&tree);
        let sched = Schedule { coloring, slot_len_s: 1.0, first_color: 0 };
        let mut st = GossipState::new(tree, 0);
        st.set_drops(Some(Rc::new(DropPlan::from_edges([(1, 2)]))));
        run_logical_round(&mut st, &sched, |u| (b'a' + u as u8) as char, 32);
        assert!(st.is_complete(), "junking is stealthy: dissemination still completes");
        // relay 1 ships its own model honestly over the junked edge
        assert!(!st.is_junk(2, 1));
        assert!(!st.is_junk(3, 1));
        // 0's model is forwarded by 1 over the junked edge → junk at 2,
        // and the garbage propagates downstream to 3
        assert!(st.is_junk(2, 0));
        assert!(st.is_junk(3, 0));
        // the honest direction (2 → 1) is untouched
        assert!(!st.is_junk(1, 2));
        assert!(!st.is_junk(1, 3));
        assert!(!st.is_junk(0, 3));
        assert_eq!(st.junk_count(), 2);
        // clearing the plan clears the markers
        st.set_drops(None);
        assert_eq!(st.junk_count(), 0);
    }

    #[test]
    fn trace_render_contains_rows() {
        let mut st = example_state();
        let sched = example_schedule();
        let trace = run_logical_round(&mut st, &sched, example::label, 64);
        let labels: Vec<String> = (0..10).map(|u| example::label(u).to_string()).collect();
        let s = trace.render(&labels, &["blue", "red"]);
        assert!(s.contains("red"));
        assert!(s.contains("blue"));
        assert!(s.contains("KGIFBECHDA"));
    }
}
