//! The unified event-driven round engine (paper §III-C/D).
//!
//! One protocol driver for every execution mode. [`RoundEngine`] owns the
//! slot structure — which color class transmits, what each transmitter
//! pops, how deliveries update queues — and keys slot state on **per-flow
//! completion events** from a [`Driver`] instead of a global per-slot
//! barrier. The same code path serves:
//!
//! * the simulated timing experiments (`SimDriver` over `netsim`) that
//!   reproduce Tables III–V,
//! * the untimed Table I queue trace (`LogicalDriver`),
//! * churn's relabeled subgraph rounds (`SimDriver::with_map`),
//! * real sockets (`LiveDriver` over `transport`).
//!
//! On top of single rounds, [`RoundEngine::run_pipelined`] implements the
//! paper's §III-D observation that *"forwarded copies pipeline with the
//! next round"*: rounds share one long-lived driver, and each node seeds
//! round `t+1` the moment it holds all round-`t` models — so round
//! `t+1`'s seeds start gossiping in the slots round `t` has vacated while
//! round `t`'s forwarding tail is still draining. [`PipelineMetrics`]
//! records per-round phases and per-slot timing so the overlap is
//! directly measurable against sequential execution.

pub mod driver;

use self::driver::{CopyToken, Driver};
use super::broadcast;
use super::gossip::{GossipState, PlannedTx, Send};
use super::schedule::Schedule;
use crate::graph::{Graph, NodeId};
use crate::metrics::{RoundMetrics, SlotTiming};
use crate::netsim::FlowRecord;
use crate::util::rng::Pcg64;

/// Knobs of one engine-driven communication round.
#[derive(Debug, Clone)]
pub struct RoundOptions {
    /// Size of one model copy in MB.
    pub model_mb: f64,
    /// Per-delivery network-disruption probability (§III-D): the copy's
    /// bytes are spent but nothing arrives, and the popped entry is
    /// re-queued for the sender's next turn.
    pub failure_prob: f64,
    /// Hard slot budget (protocol-bug guard).
    pub max_slots: usize,
    /// RNG that draws the failure coin per delivery, in deterministic
    /// (sender, recipient) order.
    pub failure_rng: Pcg64,
}

impl RoundOptions {
    /// A failure-free round — the common case.
    pub fn reliable(model_mb: f64, max_slots: usize) -> Self {
        RoundOptions { model_mb, failure_prob: 0.0, max_slots, failure_rng: Pcg64::new(0) }
    }
}

/// What one slot did, reported to the observer after its deliveries are
/// applied.
#[derive(Debug, Clone)]
pub struct SlotOutcome {
    pub slot: usize,
    /// Transmitting color class.
    pub color: usize,
    /// Successful deliveries, in deterministic (sender, recipient) order.
    pub sends: Vec<Send>,
    /// Driver clock when the slot's copies were launched.
    pub start_s: f64,
    /// Driver clock when the last copy finished draining.
    pub end_s: f64,
    /// Copies launched (0 = idle color; failed copies are counted).
    pub launched: usize,
}

/// Knobs of a pipelined multi-round run.
#[derive(Debug, Clone)]
pub struct PipelineOptions {
    /// Communication rounds to push through the shared driver.
    pub rounds: u64,
    pub model_mb: f64,
    /// Hard slot budget across *all* rounds.
    pub max_slots: usize,
    pub failure_prob: f64,
    pub failure_rng: Pcg64,
}

impl PipelineOptions {
    /// Failure-free pipeline with a generous slot budget.
    pub fn reliable(rounds: u64, model_mb: f64, nodes: usize) -> Self {
        PipelineOptions {
            rounds,
            model_mb,
            max_slots: (rounds as usize + 1) * (8 * nodes + 64),
            failure_prob: 0.0,
            failure_rng: Pcg64::new(0),
        }
    }
}

/// Timeline of one round inside a pipelined run (all times on the shared
/// driver clock, all slots on the shared slot counter).
#[derive(Debug, Clone)]
pub struct RoundPhase {
    pub round: u64,
    /// When the first node seeded this round (it had aggregated the
    /// previous one).
    pub first_seed_s: f64,
    /// When the last node seeded this round.
    pub all_seeded_s: f64,
    /// When every node's own model had reached all its tree neighbors —
    /// the exchange phase of this round (Table V's blocking part). Unlike
    /// the single-round `RoundMetrics::exchange_time_s` (which uses
    /// latency-inclusive delivery times), all `RoundPhase` times sit on
    /// the driver's drain clock so the phases are directly comparable.
    pub exchange_done_s: f64,
    /// When every node held every model of this round.
    pub done_s: f64,
    pub first_slot: usize,
    pub last_slot: usize,
}

impl RoundPhase {
    /// Simulated span from first seed to full dissemination.
    pub fn span_s(&self) -> f64 {
        self.done_s - self.first_seed_s
    }

    /// Slots this round's traffic was active in.
    pub fn slot_span(&self) -> usize {
        self.last_slot - self.first_slot + 1
    }
}

/// Result of a pipelined multi-round run.
#[derive(Debug, Clone)]
pub struct PipelineMetrics {
    /// Every completed transfer across all rounds, in completion order.
    pub transfers: Vec<FlowRecord>,
    /// Driver clock when the last round fully disseminated.
    pub total_time_s: f64,
    /// Slots consumed across all rounds.
    pub slots: usize,
    pub slot_timings: Vec<SlotTiming>,
    /// Per-round phase timeline, indexed by round.
    pub rounds: Vec<RoundPhase>,
    /// `received[round][node]` = model owners in reception order
    /// (excluding the node's own model) — the aggregation order the DFL
    /// layer folds with.
    pub received: Vec<Vec<Vec<NodeId>>>,
}

impl PipelineMetrics {
    /// Sum of per-round spans — what sequential execution would cost if
    /// every round took its pipelined span. Comparing against
    /// `total_time_s` quantifies the overlap the pipeline bought.
    pub fn summed_round_spans_s(&self) -> f64 {
        self.rounds.iter().map(|p| p.span_s()).sum()
    }
}

/// One round of a pipelined run that is still in flight.
struct ActiveRound {
    state: GossipState,
    seeded: Vec<bool>,
    seeded_count: usize,
    /// Own-model copies not yet (freshly) delivered; 0 = exchange done.
    own_left: usize,
    phase: RoundPhase,
}

/// The unified protocol driver: plans slots over [`GossipState`], moves
/// copies through a [`Driver`], and applies deliveries in deterministic
/// order as completion events arrive.
pub struct RoundEngine<'a, D: Driver> {
    driver: &'a mut D,
    schedule: &'a Schedule,
}

impl<'a, D: Driver> RoundEngine<'a, D> {
    pub fn new(driver: &'a mut D, schedule: &'a Schedule) -> Self {
        RoundEngine { driver, schedule }
    }

    /// Launch every copy of the slot's planned transmissions; returns
    /// `(planned index, recipient, token)` per copy.
    fn launch_slot(
        &mut self,
        planned: &[PlannedTx],
        model_mb: f64,
    ) -> Vec<(usize, NodeId, CopyToken)> {
        let mut meta = Vec::new();
        for (i, tx) in planned.iter().enumerate() {
            for &to in &tx.recipients {
                let token = self.driver.launch(tx.from, to, tx.entry.key, model_mb);
                meta.push((i, to, token));
            }
        }
        meta
    }

    /// Consume per-flow completion events until every one of the slot's
    /// `copies` launched copies has arrived.
    fn drain_slot(&mut self, copies: usize) {
        let mut done = 0;
        while done < copies {
            let events = self.driver.wait_any();
            assert!(
                !events.is_empty(),
                "driver made no progress with {} copies in flight",
                copies - done
            );
            done += events.len();
        }
    }

    /// Deterministic delivery order: ascending sender id, then recipient
    /// id — the order that reproduces the paper's Table I strings and the
    /// legacy slot loop's failure-coin sequence.
    fn delivery_order(planned: &[PlannedTx], meta: &[(usize, NodeId, CopyToken)]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..meta.len()).collect();
        order.sort_by_key(|&j| (planned[meta[j].0].from, meta[j].1));
        order
    }

    /// Run one communication round to full dissemination.
    ///
    /// `on_slot` observes every slot entered (including idle colors, which
    /// burn no driver time) after its deliveries are applied — the hook
    /// the Table I trace and experiment logging build on.
    pub fn run_round(
        &mut self,
        state: &mut GossipState,
        mut opts: RoundOptions,
        mut on_slot: impl FnMut(&SlotOutcome, &GossipState),
    ) -> RoundMetrics {
        let mut slots_used = 0;
        let mut slot_timings = Vec::new();
        for slot in 0..opts.max_slots {
            if state.is_complete() {
                break;
            }
            slots_used = slot + 1;
            let color = self.schedule.color_of_slot(slot);
            let transmitters = self.schedule.transmitters(slot);
            let planned = state.plan_slot(&transmitters);
            let start_s = self.driver.now();
            if planned.is_empty() {
                // idle color: burns no simulated time
                slot_timings.push(SlotTiming { slot, color, start_s, end_s: start_s, copies: 0 });
                on_slot(
                    &SlotOutcome { slot, color, sends: Vec::new(), start_s, end_s: start_s, launched: 0 },
                    state,
                );
                continue;
            }
            let meta = self.launch_slot(&planned, opts.model_mb);
            self.drain_slot(meta.len());
            let end_s = self.driver.now();

            let mut failed = vec![false; planned.len()];
            let mut sends = Vec::with_capacity(meta.len());
            for j in Self::delivery_order(&planned, &meta) {
                let (i, to, _) = meta[j];
                if opts.failure_prob > 0.0 && opts.failure_rng.gen_bool(opts.failure_prob) {
                    failed[i] = true;
                    continue;
                }
                let tx = &planned[i];
                let send = Send { from: tx.from, to, key: tx.entry.key };
                state.deliver(send);
                sends.push(send);
            }
            for (i, tx) in planned.iter().enumerate() {
                if failed[i] {
                    state.requeue(tx);
                }
            }
            slot_timings.push(SlotTiming { slot, color, start_s, end_s, copies: meta.len() });
            on_slot(
                &SlotOutcome { slot, color, sends, start_s, end_s, launched: meta.len() },
                state,
            );
        }
        assert!(
            state.is_complete(),
            "round did not complete within {} slots (failure_prob={})",
            opts.max_slots,
            opts.failure_prob
        );
        let total_time_s = self.driver.now();
        let transfers = self.driver.take_transfers();
        let exchange_time_s = exchange_time(&transfers);
        RoundMetrics { transfers, total_time_s, exchange_time_s, slots: slots_used, slot_timings }
    }

    /// Run `opts.rounds` communication rounds through one long-lived
    /// driver with multi-round pipelining.
    ///
    /// Round 0 seeds every node up front (everyone trained before the
    /// protocol starts). From then on, a node seeds round `t+1` the
    /// moment a delivery completes its round-`t` model set — its
    /// remaining round-`t` forwards stay queued ahead of the new seed, so
    /// per-node FIFO order is preserved while round `t+1` traffic fills
    /// slots round `t` no longer needs. Within a slot every transmitter
    /// services its oldest round with pending work; color classes are
    /// fixed per node, so the proper-coloring guarantee (no adjacent
    /// transmitters) holds across mixed-round slots too.
    pub fn run_pipelined(&mut self, tree: &Graph, mut opts: PipelineOptions) -> PipelineMetrics {
        let n = tree.node_count();
        assert!(tree.is_tree(), "pipelined gossip runs on the moderator's MST");
        // every node's own model crosses each incident tree edge once
        let own_copies: usize = (0..n).map(|u| tree.degree(u)).sum();

        let fresh_round = |round: u64, now: f64, slot: usize| ActiveRound {
            state: GossipState::unseeded(tree.clone(), round),
            seeded: vec![false; n],
            seeded_count: 0,
            own_left: own_copies,
            phase: RoundPhase {
                round,
                first_seed_s: now,
                all_seeded_s: now,
                exchange_done_s: f64::NAN,
                done_s: f64::NAN,
                first_slot: slot,
                last_slot: slot,
            },
        };

        let mut active: Vec<ActiveRound> = Vec::new();
        let mut finished: Vec<Option<(RoundPhase, Vec<Vec<NodeId>>)>> =
            (0..opts.rounds).map(|_| None).collect();
        let mut slot_timings = Vec::new();
        let mut slots_used = 0;

        if opts.rounds > 0 {
            let mut first = fresh_round(0, self.driver.now(), 0);
            for u in 0..n {
                first.state.seed_node(u);
                first.seeded[u] = true;
            }
            first.seeded_count = n;
            active.push(first);
        }

        let mut slot = 0usize;
        while !active.is_empty() {
            assert!(
                slot < opts.max_slots,
                "pipeline did not complete within {} slots",
                opts.max_slots
            );
            slots_used = slot + 1;
            let color = self.schedule.color_of_slot(slot);
            let transmitters = self.schedule.transmitters(slot);

            // plan: each transmitter services its oldest round with work
            let mut planned_rounds: Vec<usize> = Vec::new(); // active index per tx
            let mut planned: Vec<PlannedTx> = Vec::new();
            for &u in &transmitters {
                for (ai, ar) in active.iter_mut().enumerate() {
                    if let Some(tx) = ar.state.plan_node(u) {
                        planned_rounds.push(ai);
                        planned.push(tx);
                        break;
                    }
                }
            }
            let start_s = self.driver.now();
            if planned.is_empty() {
                slot_timings.push(SlotTiming { slot, color, start_s, end_s: start_s, copies: 0 });
                slot += 1;
                continue;
            }

            let meta = self.launch_slot(&planned, opts.model_mb);
            self.drain_slot(meta.len());
            let end_s = self.driver.now();

            // deliveries in deterministic order, routed to their round
            let mut failed = vec![false; planned.len()];
            let mut completed_nodes: Vec<(usize, NodeId)> = Vec::new(); // (active idx, node)
            for j in Self::delivery_order(&planned, &meta) {
                let (i, to, _) = meta[j];
                if opts.failure_prob > 0.0 && opts.failure_rng.gen_bool(opts.failure_prob) {
                    failed[i] = true;
                    continue;
                }
                let tx = &planned[i];
                let ai = planned_rounds[i];
                let send = Send { from: tx.from, to, key: tx.entry.key };
                let ar = &mut active[ai];
                let fresh = ar.state.deliver(send);
                ar.phase.last_slot = slot;
                if !fresh {
                    continue; // deduplicated retransmission
                }
                if send.from == send.key.owner {
                    // an own-model copy landed: exchange-phase accounting
                    // (drain clock, so exchange_done_s <= done_s always)
                    ar.own_left -= 1;
                    if ar.own_left == 0 {
                        ar.phase.exchange_done_s = end_s;
                    }
                }
                if ar.state.queue(to).held_count() == n {
                    completed_nodes.push((ai, to));
                }
            }
            for (i, tx) in planned.iter().enumerate() {
                if failed[i] {
                    active[planned_rounds[i]].state.requeue(tx);
                }
            }

            // nodes that finished a round seed the next one: its traffic
            // becomes eligible from the next slot of its color
            for (ai, u) in completed_nodes {
                let next = active[ai].state.round() + 1;
                if next >= opts.rounds {
                    continue;
                }
                let ni = match active.iter().position(|ar| ar.state.round() == next) {
                    Some(i) => i,
                    None => {
                        active.push(fresh_round(next, end_s, slot + 1));
                        active.len() - 1
                    }
                };
                let ar = &mut active[ni];
                if !ar.seeded[u] {
                    ar.state.seed_node(u);
                    ar.seeded[u] = true;
                    if ar.seeded_count == 0 {
                        ar.phase.first_seed_s = end_s;
                        ar.phase.first_slot = slot + 1;
                    }
                    ar.seeded_count += 1;
                    if ar.seeded_count == n {
                        ar.phase.all_seeded_s = end_s;
                    }
                }
            }

            // retire fully disseminated rounds
            active.retain_mut(|ar| {
                if !ar.state.is_complete() {
                    return true;
                }
                ar.phase.done_s = end_s;
                ar.phase.last_slot = slot;
                let orders: Vec<Vec<NodeId>> = (0..n)
                    .map(|u| {
                        ar.state
                            .queue(u)
                            .held_order()
                            .iter()
                            .map(|k| k.owner)
                            .filter(|&o| o != u)
                            .collect()
                    })
                    .collect();
                finished[ar.phase.round as usize] = Some((ar.phase.clone(), orders));
                false
            });

            slot_timings.push(SlotTiming { slot, color, start_s, end_s, copies: meta.len() });
            slot += 1;
        }

        let total_time_s = self.driver.now();
        let transfers = self.driver.take_transfers();
        let mut rounds = Vec::with_capacity(finished.len());
        let mut received = Vec::with_capacity(finished.len());
        for entry in finished {
            let (phase, orders) = entry.expect("every pipelined round completed");
            rounds.push(phase);
            received.push(orders);
        }
        PipelineMetrics { transfers, total_time_s, slots: slots_used, slot_timings, rounds, received }
    }
}

/// Exchange-phase end: the latest delivery among own-model copies (owner
/// == sender in the flow tag) — the blocking part of one FL round.
fn exchange_time(transfers: &[FlowRecord]) -> f64 {
    transfers
        .iter()
        .filter(|r| broadcast::tag_owner(r.tag) == broadcast::tag_sender(r.tag))
        .map(|r| r.end)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::driver::{LogicalDriver, SimDriver};
    use super::*;
    use crate::coloring::bfs_coloring;
    use crate::config::ExperimentConfig;
    use crate::coordinator::example;
    use crate::coordinator::schedule::build_schedule;
    use crate::netsim::testbed::Testbed;

    fn quiet_testbed() -> Testbed {
        Testbed::new(&ExperimentConfig { latency_jitter: 0.0, ..Default::default() })
    }

    fn paper_schedule() -> Schedule {
        build_schedule(
            &example::paper_example_graph(),
            example::paper_example_coloring(),
            14.0,
            56,
            example::RED,
        )
    }

    #[test]
    fn logical_engine_round_completes_in_23_slots() {
        let mut driver = LogicalDriver::new();
        let schedule = paper_schedule();
        let mut engine = RoundEngine::new(&mut driver, &schedule);
        let mut state = GossipState::new(example::paper_example_mst(), 0);
        let m = engine.run_round(&mut state, RoundOptions::reliable(14.0, 64), |_, _| {});
        assert!(state.is_complete());
        assert_eq!(m.slots, 23);
        assert_eq!(m.transfer_count(), 90);
        assert_eq!(m.slot_timings.len(), 23);
    }

    #[test]
    fn observer_sees_every_slot_in_order() {
        let mut driver = LogicalDriver::new();
        let schedule = paper_schedule();
        let mut engine = RoundEngine::new(&mut driver, &schedule);
        let mut state = GossipState::new(example::paper_example_mst(), 0);
        let mut seen = Vec::new();
        engine.run_round(&mut state, RoundOptions::reliable(14.0, 64), |out, _| {
            seen.push((out.slot, out.color));
        });
        assert_eq!(seen.len(), 23);
        for (i, &(slot, color)) in seen.iter().enumerate() {
            assert_eq!(slot, i);
            assert_eq!(color, schedule.color_of_slot(i));
        }
    }

    #[test]
    fn sim_engine_round_with_failures_completes() {
        let tb = quiet_testbed();
        let mut driver = SimDriver::new(&tb, 5);
        let schedule = paper_schedule();
        let mut engine = RoundEngine::new(&mut driver, &schedule);
        let mut state = GossipState::new(example::paper_example_mst(), 0);
        let opts = RoundOptions {
            model_mb: 5.0,
            failure_prob: 0.2,
            max_slots: 144,
            failure_rng: Pcg64::new(42),
        };
        let m = engine.run_round(&mut state, opts, |_, _| {});
        assert!(state.is_complete());
        assert!(m.transfer_count() > 90, "failures force retransmissions");
        // every launched copy is accounted for in the slot timings
        let copies: usize = m.slot_timings.iter().map(|s| s.copies).sum();
        assert_eq!(copies, m.transfer_count());
    }

    #[test]
    fn pipelined_rounds_all_complete_with_full_reception_orders() {
        let tb = quiet_testbed();
        let mut driver = SimDriver::new(&tb, 1);
        let schedule = paper_schedule();
        let mut engine = RoundEngine::new(&mut driver, &schedule);
        let tree = example::paper_example_mst();
        let p = engine.run_pipelined(&tree, PipelineOptions::reliable(3, 5.0, 10));
        assert_eq!(p.rounds.len(), 3);
        assert_eq!(p.received.len(), 3);
        assert_eq!(p.transfers.len(), 3 * 90);
        for (r, phase) in p.rounds.iter().enumerate() {
            assert_eq!(phase.round, r as u64);
            assert!(phase.exchange_done_s <= phase.done_s + 1e-9);
            assert!(phase.first_seed_s <= phase.all_seeded_s);
            assert!(phase.span_s() > 0.0);
            for (u, order) in p.received[r].iter().enumerate() {
                assert_eq!(order.len(), 9, "round {r} node {u} missed models");
            }
        }
        // rounds progress through the shared clock in order
        assert!(p.rounds[0].done_s <= p.rounds[1].done_s);
        assert!(p.rounds[1].done_s <= p.rounds[2].done_s);
        assert!((p.total_time_s - p.rounds[2].done_s).abs() < 1e-9);
    }

    #[test]
    fn pipelined_overlaps_rounds() {
        let tb = quiet_testbed();
        let schedule = paper_schedule();
        let tree = example::paper_example_mst();
        let mut driver = SimDriver::new(&tb, 1);
        let mut engine = RoundEngine::new(&mut driver, &schedule);
        let p = engine.run_pipelined(&tree, PipelineOptions::reliable(3, 14.0, 10));
        // round 1 must start seeding strictly before round 0 finishes
        assert!(
            p.rounds[1].first_seed_s < p.rounds[0].done_s,
            "no overlap: round 1 seeded at {} but round 0 ended at {}",
            p.rounds[1].first_seed_s,
            p.rounds[0].done_s
        );
        assert!(p.total_time_s < p.summed_round_spans_s());
    }

    #[test]
    fn pipelined_single_round_matches_run_round_protocol() {
        // with rounds=1 the pipeline is just an engine round: same copies,
        // same slot count
        let tb = quiet_testbed();
        let schedule = paper_schedule();
        let tree = example::paper_example_mst();

        let mut d1 = SimDriver::new(&tb, 9);
        let mut e1 = RoundEngine::new(&mut d1, &schedule);
        let mut state = GossipState::new(tree.clone(), 0);
        let single = e1.run_round(&mut state, RoundOptions::reliable(11.6, 144), |_, _| {});

        let mut d2 = SimDriver::new(&tb, 9);
        let mut e2 = RoundEngine::new(&mut d2, &schedule);
        let p = e2.run_pipelined(&tree, PipelineOptions::reliable(1, 11.6, 10));
        assert_eq!(p.transfers.len(), single.transfer_count());
        assert_eq!(p.slots, single.slots);
        assert_eq!(p.total_time_s.to_bits(), single.total_time_s.to_bits());
    }

    #[test]
    fn pipelined_zero_rounds_is_empty() {
        let tb = quiet_testbed();
        let schedule = paper_schedule();
        let mut driver = SimDriver::new(&tb, 1);
        let mut engine = RoundEngine::new(&mut driver, &schedule);
        let p = engine.run_pipelined(
            &example::paper_example_mst(),
            PipelineOptions::reliable(0, 14.0, 10),
        );
        assert!(p.rounds.is_empty());
        assert!(p.transfers.is_empty());
        assert_eq!(p.slots, 0);
    }

    #[test]
    fn pipelined_respects_coloring_in_mixed_slots() {
        // no two adjacent nodes may transmit in the same slot, even when
        // servicing different rounds
        let mut tree = Graph::new(6);
        for v in 1..6 {
            tree.add_edge(v - 1, v, 1.0); // path
        }
        let coloring = bfs_coloring(&tree);
        let schedule = Schedule { coloring, slot_len_s: 1.0, first_color: 0 };
        let mut driver = LogicalDriver::new();
        let mut engine = RoundEngine::new(&mut driver, &schedule);
        let p = engine.run_pipelined(&tree, PipelineOptions::reliable(2, 1.0, 6));
        assert_eq!(p.rounds.len(), 2);
        for st in &p.slot_timings {
            let class = schedule.transmitters(st.slot);
            for (i, &u) in class.iter().enumerate() {
                for &v in &class[i + 1..] {
                    assert!(!tree.has_edge(u, v), "adjacent {u},{v} share slot {}", st.slot);
                }
            }
        }
    }
}
