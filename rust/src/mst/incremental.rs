//! Incremental MST maintenance for the adaptive re-planning plane.
//!
//! When online probing (see `coordinator::probe`) reports that an edge's
//! ping drifted, the moderator does not need to re-run Prim/Kruskal from
//! scratch: a **single** changed weight admits an O(E α(n)) edge-swap
//! update built on [`UnionFind`]:
//!
//! * changed edge **in** the tree → removing it cuts the tree in two;
//!   the new MST keeps the rest of the tree and reconnects the cut with
//!   the minimum crossing edge (cut property). A decreased tree edge is
//!   its own minimum, so the tree survives unchanged.
//! * changed edge **not in** the tree → adding it closes one cycle along
//!   the tree path between its endpoints; the new MST drops the cycle's
//!   heaviest edge if the changed edge is now strictly lighter (cycle
//!   property), and is unchanged otherwise.
//!
//! [`update_mst`] is the moderator-facing entry: it diffs the old and new
//! cost graphs, takes the edge-swap fast path when exactly one weight
//! changed, and falls back to a from-scratch [`kruskal`] run otherwise
//! (EWMA smoothing typically moves several edges at once after a drift
//! episode). Differential property tests
//! (`tests/mst_incremental.rs`) pin the swap against from-scratch
//! Kruskal/Prim/Borůvka across every paper topology family.

use super::kruskal::kruskal;
use super::union_find::UnionFind;
use super::MstError;
use crate::graph::{Graph, NodeId};

/// Deterministic edge preference matching `Graph::sorted_edges` (and thus
/// Kruskal's tie-break): ascending weight, then endpoints. Uses
/// `f64::total_cmp`, so the comparison is total even for weights a
/// validation gap lets through — ordering can never panic here.
fn prefer(w: f64, u: NodeId, v: NodeId, best: Option<(f64, NodeId, NodeId)>) -> bool {
    match best {
        None => true,
        Some((bw, bu, bv)) => w.total_cmp(&bw).then_with(|| (u, v).cmp(&(bu, bv))).is_lt(),
    }
}

/// Reject cost graphs carrying non-finite weights before any ordering
/// runs over them — the re-planning path's input can come from online
/// probe estimates, and a drifted/poisoned NaN must surface as a clear
/// [`MstError::NonFinite`] instead of a mid-replan comparator panic.
fn check_finite(costs: &Graph) -> Result<(), MstError> {
    for e in costs.edges() {
        if !e.weight.is_finite() {
            return Err(MstError::NonFinite { u: e.u, v: e.v });
        }
    }
    Ok(())
}

/// Tree edges of the path between `from` and `to` as (u, v, weight)
/// triples. Panics if `to` is unreachable (callers pass a tree).
fn tree_path(tree: &Graph, from: NodeId, to: NodeId) -> Vec<(NodeId, NodeId, f64)> {
    let n = tree.node_count();
    let mut parent: Vec<Option<(NodeId, f64)>> = vec![None; n];
    let mut queue = std::collections::VecDeque::from([from]);
    let mut seen = vec![false; n];
    seen[from] = true;
    while let Some(u) = queue.pop_front() {
        if u == to {
            break;
        }
        for &(v, w) in tree.neighbors(u) {
            if !seen[v] {
                seen[v] = true;
                parent[v] = Some((u, w));
                queue.push_back(v);
            }
        }
    }
    let mut path = Vec::new();
    let mut cur = to;
    while cur != from {
        let (p, w) = parent[cur].expect("endpoints connected in a tree");
        path.push((p, cur, w));
        cur = p;
    }
    path
}

/// Rebuild `tree` with edge (`skip_u`, `skip_v`) replaced by
/// (`add_u`, `add_v`, `add_w`).
fn swap_edge(
    tree: &Graph,
    (skip_u, skip_v): (NodeId, NodeId),
    (add_u, add_v, add_w): (NodeId, NodeId, f64),
) -> Graph {
    let mut out = Graph::new(tree.node_count());
    for e in tree.edges() {
        if (e.u == skip_u && e.v == skip_v) || (e.u == skip_v && e.v == skip_u) {
            continue;
        }
        out.add_edge(e.u, e.v, e.weight);
    }
    out.add_edge(add_u, add_v, add_w);
    out
}

/// Update an MST after the weight of edge (`u`, `v`) changed to its
/// current value in `costs`. `tree` must be an MST of `costs` with the
/// edge at its *previous* weight; every other weight must agree with
/// `costs`. Returns a (possibly identical) MST of `costs`.
pub fn update_edge_weight(
    costs: &Graph,
    tree: &Graph,
    u: NodeId,
    v: NodeId,
) -> Result<Graph, MstError> {
    let n = costs.node_count();
    if n == 0 {
        return Err(MstError::Empty);
    }
    assert_eq!(tree.node_count(), n, "tree/costs node count mismatch");
    let new_w = costs
        .weight(u, v)
        .unwrap_or_else(|| panic!("changed edge ({u},{v}) not in the cost graph"));
    // only the changed weight needs validating here: every comparison
    // below is total_cmp-based (panic-free), and update_mst already
    // scans the full graph once — a second O(E) pass would erode the
    // fast path's point
    if !new_w.is_finite() {
        return Err(MstError::NonFinite { u, v });
    }

    if tree.has_edge(u, v) {
        // cut property: reconnect the two sides with the minimum
        // crossing edge (which may still be (u, v) itself)
        let mut uf = UnionFind::new(n);
        for e in tree.edges() {
            if (e.u == u && e.v == v) || (e.u == v && e.v == u) {
                continue;
            }
            uf.union(e.u, e.v);
        }
        let mut best: Option<(f64, NodeId, NodeId)> = None;
        for e in costs.edges() {
            if uf.connected(e.u, e.v) {
                continue;
            }
            if prefer(e.weight, e.u, e.v, best) {
                best = Some((e.weight, e.u, e.v));
            }
        }
        let (bw, bu, bv) = best.ok_or(MstError::Disconnected)?;
        if (bu == u && bv == v) || (bu == v && bv == u) {
            // the changed edge survives at its new weight
            debug_assert_eq!(bw.to_bits(), new_w.to_bits());
        }
        Ok(swap_edge(tree, (u, v), (bu, bv, bw)))
    } else {
        // cycle property: the changed edge enters only if it is now
        // strictly lighter than the heaviest edge on its tree cycle.
        // total_cmp keeps the ordering total: a NaN slipping past
        // validation can no longer panic the comparator mid-replan.
        let path = tree_path(tree, u, v);
        let &(mu, mv, mw) = path
            .iter()
            .max_by(|a, b| a.2.total_cmp(&b.2).then((a.0, a.1).cmp(&(b.0, b.1))))
            .expect("path between distinct nodes is non-empty");
        if new_w < mw {
            Ok(swap_edge(tree, (mu, mv), (u, v, new_w)))
        } else {
            Ok(tree.clone())
        }
    }
}

/// Refresh an MST after probing produced `new_costs`: when exactly one
/// edge weight differs from `old_costs` (and the edge sets match), take
/// the [`update_edge_weight`] edge-swap fast path; otherwise run Kruskal
/// from scratch. `tree` must be an MST of `old_costs`.
pub fn update_mst(tree: &Graph, old_costs: &Graph, new_costs: &Graph) -> Result<Graph, MstError> {
    // validate before any ordering (kruskal's sort included) touches the
    // refreshed weights: probed/drifted costs must fail loudly, not panic
    check_finite(new_costs)?;
    if old_costs.node_count() != new_costs.node_count()
        || old_costs.edge_count() != new_costs.edge_count()
    {
        return kruskal(new_costs);
    }
    let mut changed: Option<(NodeId, NodeId)> = None;
    for e in new_costs.edges() {
        match old_costs.weight(e.u, e.v) {
            Some(w) if w.to_bits() == e.weight.to_bits() => {}
            Some(_) if changed.is_none() => changed = Some((e.u, e.v)),
            _ => return kruskal(new_costs), // ≥2 changes or edge-set drift
        }
    }
    match changed {
        None => Ok(tree.clone()),
        Some((u, v)) => update_edge_weight(new_costs, tree, u, v),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mst::is_spanning_tree_of;

    /// The Fig-2-style diamond with a unique MST {01, 12, 23}.
    fn diamond() -> Graph {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(2, 3, 3.0);
        g.add_edge(3, 0, 4.0);
        g.add_edge(0, 2, 5.0);
        g
    }

    fn with_weight(g: &Graph, u: NodeId, v: NodeId, w: f64) -> Graph {
        let mut out = Graph::new(g.node_count());
        for e in g.edges() {
            let ew = if (e.u == u && e.v == v) || (e.u == v && e.v == u) { w } else { e.weight };
            out.add_edge(e.u, e.v, ew);
        }
        out
    }

    #[test]
    fn tree_edge_increase_swaps_in_crossing_edge() {
        let g = diamond();
        let tree = kruskal(&g).unwrap();
        // (2,3) jumps to 9: cut {0,1,2} | {3} reconnects via (3,0)=4
        let g2 = with_weight(&g, 2, 3, 9.0);
        let t2 = update_edge_weight(&g2, &tree, 2, 3).unwrap();
        assert!(t2.has_edge(0, 3));
        assert!(!t2.has_edge(2, 3));
        assert_eq!(t2.total_weight(), kruskal(&g2).unwrap().total_weight());
        assert!(is_spanning_tree_of(&t2, &g2));
    }

    #[test]
    fn tree_edge_increase_below_alternatives_keeps_tree() {
        let g = diamond();
        let tree = kruskal(&g).unwrap();
        let g2 = with_weight(&g, 2, 3, 3.5); // still cheaper than (3,0)=4
        let t2 = update_edge_weight(&g2, &tree, 2, 3).unwrap();
        assert!(t2.has_edge(2, 3));
        assert_eq!(t2.weight(2, 3), Some(3.5), "kept edge carries the new weight");
        assert_eq!(t2.total_weight(), kruskal(&g2).unwrap().total_weight());
    }

    #[test]
    fn tree_edge_decrease_keeps_tree() {
        let g = diamond();
        let tree = kruskal(&g).unwrap();
        let g2 = with_weight(&g, 1, 2, 0.5);
        let t2 = update_edge_weight(&g2, &tree, 1, 2).unwrap();
        assert!(t2.has_edge(1, 2));
        assert_eq!(t2.weight(1, 2), Some(0.5));
        assert_eq!(t2.total_weight(), kruskal(&g2).unwrap().total_weight());
    }

    #[test]
    fn non_tree_edge_decrease_swaps_out_heaviest_cycle_edge() {
        let g = diamond();
        let tree = kruskal(&g).unwrap();
        // (0,2) drops to 1.5: cycle 0-1-2 heaviest edge is (1,2)=2
        let g2 = with_weight(&g, 0, 2, 1.5);
        let t2 = update_edge_weight(&g2, &tree, 0, 2).unwrap();
        assert!(t2.has_edge(0, 2));
        assert!(!t2.has_edge(1, 2));
        assert_eq!(t2.total_weight(), kruskal(&g2).unwrap().total_weight());
        assert!(is_spanning_tree_of(&t2, &g2));
    }

    #[test]
    fn non_tree_edge_increase_is_a_no_op() {
        let g = diamond();
        let tree = kruskal(&g).unwrap();
        let g2 = with_weight(&g, 0, 2, 50.0);
        let t2 = update_edge_weight(&g2, &tree, 0, 2).unwrap();
        assert_eq!(t2.total_weight(), tree.total_weight());
        assert!(t2.has_edge(0, 1) && t2.has_edge(1, 2) && t2.has_edge(2, 3));
    }

    #[test]
    fn update_mst_takes_fast_path_and_fallback() {
        let g = diamond();
        let tree = kruskal(&g).unwrap();
        // no change -> clone
        let same = update_mst(&tree, &g, &g).unwrap();
        assert_eq!(same.total_weight(), tree.total_weight());
        // one change -> swap
        let g2 = with_weight(&g, 2, 3, 9.0);
        let t2 = update_mst(&tree, &g, &g2).unwrap();
        assert_eq!(t2.total_weight(), kruskal(&g2).unwrap().total_weight());
        // two changes -> kruskal fallback, still an MST of the new costs
        let g3 = with_weight(&g2, 0, 1, 6.0);
        let t3 = update_mst(&tree, &g, &g3).unwrap();
        assert_eq!(t3.total_weight(), kruskal(&g3).unwrap().total_weight());
        assert!(is_spanning_tree_of(&t3, &g3));
    }

    #[test]
    fn disconnecting_cut_reports_error() {
        // a 2-node graph whose only edge is the tree edge: the cut search
        // still finds the edge itself, so no error — but a disconnected
        // cost graph (edge removed) must fall back and report
        let mut g = Graph::new(2);
        g.add_edge(0, 1, 1.0);
        let tree = kruskal(&g).unwrap();
        let g2 = with_weight(&g, 0, 1, 7.0);
        let t2 = update_edge_weight(&g2, &tree, 0, 1).unwrap();
        assert_eq!(t2.weight(0, 1), Some(7.0));
        let empty = Graph::new(2);
        assert!(update_mst(&tree, &g, &empty).is_err());
    }
}
