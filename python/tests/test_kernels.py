"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (multiples of the kernels' block constraints) and
value distributions; fixed-seed cases pin the exact numerics.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import aggregate as agg
from compile.kernels import linear as lin
from compile.kernels import ref
from compile.kernels import sgd

hypothesis.settings.register_profile(
    "kernels", max_examples=20, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("kernels")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# gossip_aggregate
# ---------------------------------------------------------------------------

class TestAggregate:
    def test_matches_ref_basic(self):
        d = 2 * agg.BLOCK
        acc, m = rand(0, (d,)), rand(1, (d,))
        wa, wm = jnp.float32(3.0), jnp.float32(1.0)
        got, got_w = agg.gossip_aggregate(acc, wa, m, wm)
        want, want_w = ref.gossip_aggregate_ref(acc, wa, m, wm)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got_w, want_w)

    def test_equal_weights_is_mean(self):
        d = agg.BLOCK
        a, b = rand(2, (d,)), rand(3, (d,))
        got, w = agg.gossip_aggregate(a, jnp.float32(1.0), b, jnp.float32(1.0))
        np.testing.assert_allclose(got, (a + b) / 2.0, rtol=1e-5, atol=1e-6)
        assert float(w) == 2.0

    def test_zero_weight_neighbor_is_identity(self):
        d = agg.BLOCK
        a, b = rand(4, (d,)), rand(5, (d,))
        got, _ = agg.gossip_aggregate(a, jnp.float32(2.0), b, jnp.float32(0.0))
        np.testing.assert_allclose(got, a, rtol=1e-5, atol=1e-6)

    def test_fold_order_converges_to_fedavg(self):
        """Folding k models pairwise equals the flat weighted mean."""
        d = agg.BLOCK
        models = [rand(10 + i, (d,)) for i in range(4)]
        acc, w = models[0], jnp.float32(1.0)
        for mdl in models[1:]:
            acc, w = agg.gossip_aggregate(acc, w, mdl, jnp.float32(1.0))
        fedavg = sum(models) / len(models)
        np.testing.assert_allclose(acc, fedavg, rtol=1e-4, atol=1e-6)
        assert float(w) == 4.0

    @hypothesis.given(
        blocks=st.integers(min_value=1, max_value=3),
        wa=st.floats(min_value=0.25, max_value=16.0),
        wm=st.floats(min_value=0.25, max_value=16.0),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_sweep(self, blocks, wa, wm, seed):
        d = blocks * 8192
        acc = rand(seed, (d,), 2.0)
        m = rand(seed + 1, (d,), 2.0)
        got, got_w = agg.gossip_aggregate(
            acc, jnp.float32(wa), m, jnp.float32(wm), block=8192)
        want, want_w = ref.gossip_aggregate_ref(acc, jnp.float32(wa), m, jnp.float32(wm))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(got_w, want_w, rtol=1e-6)

    def test_rejects_unaligned_length(self):
        with pytest.raises(AssertionError):
            agg.gossip_aggregate(
                jnp.zeros((100,)), jnp.float32(1.0), jnp.zeros((100,)), jnp.float32(1.0))

    def test_vmem_footprint_within_budget(self):
        # 3 blocks of f32 must fit a 16 MiB VMEM with generous headroom
        assert agg.vmem_footprint_bytes() < 4 * 1024 * 1024


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------

class TestFusedLinear:
    def test_matches_ref_gelu(self):
        x, w, b = rand(0, (128, 256)), rand(1, (256, 128)), rand(2, (128,))
        got = lin.fused_linear(x, w, b, activation="gelu")
        want = ref.fused_linear_ref(x, w, b, activation="gelu")
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_matches_ref_none(self):
        x, w, b = rand(3, (256, 128)), rand(4, (128, 384)), rand(5, (384,))
        got = lin.fused_linear(x, w, b, activation="none")
        want = ref.fused_linear_ref(x, w, b, activation="none")
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_k_accumulation_multiblock(self):
        # K spans 4 blocks: exercises the accumulator init/finish logic
        x, w, b = rand(6, (128, 512)), rand(7, (512, 128)), jnp.zeros((128,))
        got = lin.fused_linear(x, w, b, activation="none")
        np.testing.assert_allclose(got, x @ w, rtol=1e-3, atol=1e-4)

    def test_gradients_match_ref(self):
        x, w, b = rand(8, (128, 128), 0.5), rand(9, (128, 128), 0.5), rand(10, (128,), 0.1)

        def f_kernel(x, w, b):
            return jnp.sum(lin.fused_linear(x, w, b, activation="gelu") ** 2)

        def f_ref(x, w, b):
            return jnp.sum(ref.fused_linear_ref(x, w, b, activation="gelu") ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
        for a, e, name in zip(gk, gr, "xwb"):
            np.testing.assert_allclose(a, e, rtol=2e-4, atol=2e-4,
                                       err_msg=f"grad wrt {name}")

    @hypothesis.given(
        m=st.sampled_from([128, 256]),
        k=st.sampled_from([128, 256]),
        n=st.sampled_from([128, 256]),
        act=st.sampled_from(["gelu", "none"]),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_shapes(self, m, k, n, act, seed):
        x, w, b = rand(seed, (m, k)), rand(seed + 1, (k, n)), rand(seed + 2, (n,))
        got = lin.fused_linear(x, w, b, activation=act)
        want = ref.fused_linear_ref(x, w, b, activation=act)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_rejects_ragged_shapes(self):
        with pytest.raises(AssertionError):
            lin.fused_linear(jnp.zeros((100, 128)), jnp.zeros((128, 128)), jnp.zeros((128,)))

    def test_mxu_utilization_estimate(self):
        assert lin.mxu_utilization_estimate(128, 128, 128) == 1.0
        assert lin.mxu_utilization_estimate(100, 128, 128) < 1.0

    def test_vmem_footprint_within_budget(self):
        assert lin.vmem_footprint_bytes() < 2 * 1024 * 1024


# ---------------------------------------------------------------------------
# sgd_update
# ---------------------------------------------------------------------------

class TestSgd:
    def test_matches_ref(self):
        d = sgd.BLOCK
        p, g = rand(0, (d,)), rand(1, (d,))
        got = sgd.sgd_update(p, g, jnp.float32(0.05))
        np.testing.assert_allclose(got, ref.sgd_update_ref(p, g, jnp.float32(0.05)),
                                   rtol=1e-4, atol=1e-7)

    def test_zero_lr_identity(self):
        d = sgd.BLOCK
        p, g = rand(2, (d,)), rand(3, (d,))
        np.testing.assert_allclose(sgd.sgd_update(p, g, jnp.float32(0.0)), p)

    @hypothesis.given(
        blocks=st.integers(min_value=1, max_value=3),
        lr=st.floats(min_value=1e-4, max_value=1.0),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_sweep(self, blocks, lr, seed):
        d = blocks * 8192
        p, g = rand(seed, (d,)), rand(seed + 1, (d,))
        got = sgd.sgd_update(p, g, jnp.float32(lr), block=8192)
        np.testing.assert_allclose(got, p - jnp.float32(lr) * g, rtol=1e-5, atol=1e-6)
