//! Golden test: the paper's Table I, replayed move for move.
//!
//! The fixture is the 10-node example (A..K skipping J); the assertions
//! pin the MST, the red/blue classes, selected intermediate rows, the
//! final reception-order strings of all ten nodes, and the 23-slot count.

use mosgu::coordinator::example as ex;
use mosgu::coordinator::gossip::{run_logical_round, GossipState};
use mosgu::coordinator::schedule::build_schedule;

fn run_paper_trace() -> (GossipState, mosgu::coordinator::gossip::RoundTrace) {
    let sched = build_schedule(
        &ex::paper_example_graph(),
        ex::paper_example_coloring(),
        14.0,
        56,
        ex::RED,
    );
    let mut state = GossipState::new(ex::paper_example_mst(), 0);
    let trace = run_logical_round(&mut state, &sched, ex::label, 64);
    (state, trace)
}

fn row(trace: &mosgu::coordinator::gossip::RoundTrace, slot_1idx: usize) -> &Vec<String> {
    &trace.rows[slot_1idx - 1]
}

#[test]
fn completes_in_exactly_23_slots() {
    let (state, trace) = run_paper_trace();
    assert!(state.is_complete());
    assert_eq!(trace.slots.len(), 23);
    // 12 red slots (odd 1-indexed), 11 blue
    let reds = trace.slots.iter().filter(|s| s.color == ex::RED).count();
    assert_eq!(reds, 12);
}

#[test]
fn first_row_matches_paper() {
    let (_, trace) = run_paper_trace();
    // Table I row 1 (after the first red slot):
    // A=AH B=BCI C=C D=DC E=E F=FEGH G=G H=H I=I K=KGI
    assert_eq!(
        row(&trace, 1),
        &vec!["AH", "BCI", "C", "DC", "E", "FEGH", "G", "H", "I", "KGI"]
            .into_iter()
            .map(String::from)
            .collect::<Vec<_>>()
    );
}

#[test]
fn second_row_matches_paper() {
    let (_, trace) = run_paper_trace();
    // Table I row 2 (after the first blue slot)
    assert_eq!(
        row(&trace, 2),
        &vec!["AH", "BCI", "CBD", "DC", "EF", "FEGH", "GFK", "HAF", "IBK", "KGI"]
            .into_iter()
            .map(String::from)
            .collect::<Vec<_>>()
    );
}

#[test]
fn mid_trace_rows_match_paper() {
    let (_, trace) = run_paper_trace();
    // row 5 (third red slot): A=AHF B=BCIDK F=FEGHAK K=KGIFB
    let r5 = row(&trace, 5);
    assert_eq!(r5[ex::A], "AHF");
    assert_eq!(r5[ex::B], "BCIDK");
    assert_eq!(r5[ex::F], "FEGHAK");
    assert_eq!(r5[ex::K], "KGIFB");
    // row 6 (third blue): C=CBDI E=EFG G=GFKEI H=HAFEG I=IBKCG
    let r6 = row(&trace, 6);
    assert_eq!(r6[ex::C], "CBDI");
    assert_eq!(r6[ex::E], "EFG");
    assert_eq!(r6[ex::G], "GFKEI");
    assert_eq!(r6[ex::H], "HAFEG");
    assert_eq!(r6[ex::I], "IBKCG");
}

#[test]
fn final_row_matches_paper_exactly() {
    let (state, _) = run_paper_trace();
    let expect = [
        "AHFEGKIBCD",
        "BCIDKGFEHA",
        "CBDIKGFEHA",
        "DCBIKGFEHA",
        "EFGHAKIBCD",
        "FEGHAKIBCD",
        "GFKEIHABCD",
        "HAFEGKIBCD",
        "IBKCGDFEHA",
        "KGIFBECHDA",
    ];
    for (u, want) in expect.iter().enumerate() {
        assert_eq!(&state.held_string(u, ex::label), want, "node {}", ex::label(u));
    }
}

#[test]
fn every_node_receives_each_model_exactly_once() {
    let (_, trace) = run_paper_trace();
    // on a tree with no failures, each (recipient, owner) pair appears once
    let mut seen = std::collections::HashSet::new();
    for slot in &trace.slots {
        for s in &slot.sends {
            assert!(
                seen.insert((s.to, s.key.owner)),
                "duplicate delivery of {} to {}",
                ex::label(s.key.owner),
                ex::label(s.to)
            );
        }
    }
    // 10 models x 9 recipients
    assert_eq!(seen.len(), 90);
}

#[test]
fn transmissions_respect_colors() {
    let (_, trace) = run_paper_trace();
    let coloring = ex::paper_example_coloring();
    for slot in &trace.slots {
        for s in &slot.sends {
            assert_eq!(
                coloring.color_of(s.from),
                slot.color,
                "node {} transmitted outside its slot",
                ex::label(s.from)
            );
        }
    }
}

#[test]
fn degree_one_nodes_send_only_their_own_model() {
    let (_, trace) = run_paper_trace();
    let tree = ex::paper_example_mst();
    for slot in &trace.slots {
        for s in &slot.sends {
            if tree.degree(s.from) == 1 {
                assert_eq!(
                    s.key.owner, s.from,
                    "leaf {} forwarded a received model",
                    ex::label(s.from)
                );
            }
        }
    }
}
