//! Hierarchical planning: per-subnet MST + coloring stitched through the
//! gateway backbone into one [`PlanEpoch`].
//!
//! The paper's moderator plans one flat overlay (§III-A/B/C). At
//! hierarchy scale the same three steps decompose along the subnet
//! structure the physical testbed already has (§IV-A, one subnetwork per
//! router):
//!
//! 1. **Tree** — each subnet's MST is computed independently over its
//!    induced cost subgraph; a backbone MST over the gateway-gateway
//!    pings stitches them into one spanning tree
//!    ([`crate::mst::hierarchical::stitched_mst`]).
//! 2. **Coloring** — each subnet's subtree is 2-colored independently;
//!    subnet parities are aligned across the gateway edges
//!    ([`crate::coloring::stitched_tree_coloring`]).
//! 3. **Schedule** — the paper's §III-C slot-length formula over the full
//!    cost graph, unchanged: `ping_max` ranges over every node's gossip
//!    neighbors, so the worst (usually backbone) edge budgets the slot.
//!
//! With a **single subnet** every step collapses to the flat planner bit
//! for bit — `tests/engine_equivalence.rs` pins that equivalence — so
//! hierarchical planning is a strict superset of the paper's, not a fork.

use super::engine::{PlanEpoch, TreeLane};
use super::schedule::build_schedule;
use crate::coloring::{stitched_tree_coloring, ColoringAlgorithm};
use crate::graph::generators::Hierarchy;
use crate::graph::Graph;
use crate::mst::hierarchical::stitched_mst;
use crate::mst::{extra_disjoint_trees, MstAlgorithm, MstError};

/// Plan one epoch (tree + slot schedule) hierarchically. `costs` is the
/// full overlay cost graph (measured pings, ms); `model_mb` the transfer
/// unit the §III-C formula budgets.
pub fn plan_hierarchical(
    costs: &Graph,
    hierarchy: &Hierarchy,
    mst: MstAlgorithm,
    coloring: ColoringAlgorithm,
    model_mb: f64,
    ping_size_bytes: u64,
    first_color: usize,
) -> Result<PlanEpoch, MstError> {
    assert_eq!(
        hierarchy.node_count(),
        costs.node_count(),
        "hierarchy and cost graph disagree on node count"
    );
    let tree = stitched_mst(costs, hierarchy.subnet_of(), hierarchy.gateways(), mst)?;
    let coloring = stitched_tree_coloring(&tree, hierarchy.subnet_of(), coloring);
    let schedule = build_schedule(costs, coloring, model_mb, ping_size_bytes, first_color);
    Ok(PlanEpoch::single(tree, schedule))
}

/// As [`plan_hierarchical`] with up to `trees - 1` extra edge-disjoint
/// dissemination lanes (multi-tree, `--trees k`). Extra lanes are carved
/// from the **admissible** cost graph — intra-subnet edges plus
/// gateway-gateway cross links, the same edge universe `stitched_mst`
/// draws from — so every lane honors the gateway-only-crossing invariant
/// while each subnet's residual links grow its own forest. Fewer (or
/// zero) extra lanes come back when the admissible residual disconnects
/// first; `trees = 1` is [`plan_hierarchical`] verbatim.
#[allow(clippy::too_many_arguments)]
pub fn plan_hierarchical_forest(
    costs: &Graph,
    hierarchy: &Hierarchy,
    mst: MstAlgorithm,
    coloring: ColoringAlgorithm,
    trees: usize,
    model_mb: f64,
    ping_size_bytes: u64,
    first_color: usize,
) -> Result<PlanEpoch, MstError> {
    let mut epoch =
        plan_hierarchical(costs, hierarchy, mst, coloring, model_mb, ping_size_bytes, first_color)?;
    if trees >= 2 {
        let admissible = gateway_admissible(costs, hierarchy);
        epoch.extra = extra_disjoint_trees(&admissible, &epoch.tree, trees - 1)
            .into_iter()
            .map(|tree| {
                let col = stitched_tree_coloring(&tree, hierarchy.subnet_of(), coloring);
                let schedule = build_schedule(costs, col, model_mb, ping_size_bytes, first_color);
                TreeLane { tree, schedule }
            })
            .collect();
    }
    // static verification plane: every hierarchical epoch (including the
    // ScaleScenario path that bypasses the Moderator) is re-linted in
    // debug builds against the costs it was budgeted from
    #[cfg(debug_assertions)]
    {
        let ctx = crate::analysis::LintContext { costs, unit_mb: model_mb, ping_size_bytes };
        let report = crate::analysis::lint_epoch(&epoch, &ctx);
        debug_assert!(
            report.is_clean(),
            "hierarchical planner produced a plan that fails lint:\n{report}"
        );
    }
    Ok(epoch)
}

/// The cost edges hierarchical planning may use: intra-subnet links plus
/// gateway-gateway cross links (non-gateway cross edges are physically
/// routed through routers and excluded from every lane, exactly as in
/// [`stitched_mst`]).
fn gateway_admissible(costs: &Graph, h: &Hierarchy) -> Graph {
    let mut g = Graph::new(costs.node_count());
    for e in costs.edges() {
        let cross = h.subnet(e.u) != h.subnet(e.v);
        if !cross || (h.is_gateway(e.u) && h.is_gateway(e.v)) {
            g.add_edge(e.u, e.v, e.weight);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::router_hierarchy;
    use crate::util::rng::Pcg64;

    fn costs_for(n: usize, subnets: usize, seed: u64) -> (Graph, Hierarchy) {
        let (structure, h) = router_hierarchy(n, subnets, 2, 4, &mut Pcg64::new(seed));
        // ping-like weights: intra cheap, gateway links expensive
        let mut costs = Graph::new(n);
        for e in structure.sorted_edges() {
            let cross = h.subnet(e.u) != h.subnet(e.v);
            let w = if cross { 25.0 + e.u as f64 * 0.1 } else { 1.0 + e.v as f64 * 0.01 };
            costs.add_edge(e.u, e.v, w);
        }
        (costs, h)
    }

    #[test]
    fn single_subnet_epoch_matches_flat_planner_bit_for_bit() {
        let (costs, h) = costs_for(12, 1, 3);
        let flat_tree = MstAlgorithm::Prim.run(&costs).unwrap();
        let flat_col = ColoringAlgorithm::Bfs.run(&flat_tree);
        let flat_sched = build_schedule(&costs, flat_col, 14.0, 56, 1);
        let epoch = plan_hierarchical(
            &costs,
            &h,
            MstAlgorithm::Prim,
            ColoringAlgorithm::Bfs,
            14.0,
            56,
            1,
        )
        .unwrap();
        assert_eq!(epoch.tree.edge_count(), flat_tree.edge_count());
        for e in flat_tree.edges() {
            assert!(epoch.tree.has_edge(e.u, e.v));
        }
        assert_eq!(epoch.schedule.coloring.assignment(), flat_sched.coloring.assignment());
        assert_eq!(epoch.schedule.slot_len_s.to_bits(), flat_sched.slot_len_s.to_bits());
        assert_eq!(epoch.schedule.first_color, flat_sched.first_color);
        let ctx =
            crate::analysis::LintContext { costs: &costs, unit_mb: 14.0, ping_size_bytes: 56 };
        let report = crate::analysis::lint_epoch(&epoch, &ctx);
        assert!(report.is_clean(), "{report}");
    }

    #[test]
    fn forest_adds_edge_disjoint_gateway_respecting_lanes() {
        let (_, h) = costs_for(12, 2, 5);
        // dense overlay: every pair measured, so extra lanes exist
        let n = 12;
        let mut costs = Graph::new(n);
        for u in 0..n {
            for v in (u + 1)..n {
                let cross = h.subnet(u) != h.subnet(v);
                let w = if cross { 25.0 } else { 1.0 } + (u * n + v) as f64 * 0.01;
                costs.add_edge(u, v, w);
            }
        }
        let epoch = plan_hierarchical_forest(
            &costs,
            &h,
            MstAlgorithm::Prim,
            ColoringAlgorithm::Bfs,
            3,
            14.0,
            56,
            1,
        )
        .unwrap();
        assert!(!epoch.extra.is_empty(), "dense overlay should admit an extra lane");
        let ctx =
            crate::analysis::LintContext { costs: &costs, unit_mb: 14.0, ping_size_bytes: 56 };
        let report = crate::analysis::lint_epoch(&epoch, &ctx);
        assert!(report.is_clean(), "{report}");
        let lanes = epoch.lanes();
        let trees: Vec<Graph> = lanes.iter().map(|l| l.tree.clone()).collect();
        assert!(crate::mst::disjoint::pairwise_edge_disjoint(&trees));
        for lane in &lanes {
            assert!(lane.tree.is_tree());
            assert!(lane.schedule.coloring.is_proper(&lane.tree));
            // every lane honors the gateway-only-crossing invariant
            for e in lane.tree.edges() {
                if h.subnet(e.u) != h.subnet(e.v) {
                    assert!(h.is_gateway(e.u) && h.is_gateway(e.v), "({}, {})", e.u, e.v);
                }
            }
        }
        // lane 0 and the slot schedule are plan_hierarchical verbatim
        let base = plan_hierarchical(
            &costs,
            &h,
            MstAlgorithm::Prim,
            ColoringAlgorithm::Bfs,
            14.0,
            56,
            1,
        )
        .unwrap();
        assert_eq!(epoch.tree.sorted_edges(), base.tree.sorted_edges());
        assert_eq!(epoch.schedule.slot_len_s.to_bits(), base.schedule.slot_len_s.to_bits());
        // trees = 1 keeps the epoch single-lane
        let single = plan_hierarchical_forest(
            &costs,
            &h,
            MstAlgorithm::Prim,
            ColoringAlgorithm::Bfs,
            1,
            14.0,
            56,
            1,
        )
        .unwrap();
        assert!(single.extra.is_empty());
    }

    #[test]
    fn multi_subnet_epoch_is_a_proper_plan() {
        let (costs, h) = costs_for(26, 4, 9);
        let epoch = plan_hierarchical(
            &costs,
            &h,
            MstAlgorithm::Prim,
            ColoringAlgorithm::Bfs,
            14.0,
            56,
            1,
        )
        .unwrap();
        assert!(epoch.tree.is_tree());
        assert!(epoch.schedule.coloring.is_proper(&epoch.tree));
        // the expensive gateway edges dominate ping_max, so the slot
        // budget reflects the backbone, not the cheap intra links
        let expect =
            crate::coordinator::schedule::slot_length_s(25.0, 14.0, 56);
        assert!(epoch.schedule.slot_len_s >= expect, "slot budget ignores the backbone");
        let ctx =
            crate::analysis::LintContext { costs: &costs, unit_mb: 14.0, ping_size_bytes: 56 };
        let report = crate::analysis::lint_epoch(&epoch, &ctx);
        assert!(report.is_clean(), "{report}");
    }
}
