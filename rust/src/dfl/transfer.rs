//! Segment-granular transfer planning (the checkpoint-slicing layer).
//!
//! The paper's schedule moves each checkpoint as one monolithic blob per
//! hop: a relay on a deep tree must hold the full 48 MB model before it
//! can forward anything. Hu et al., *Decentralized Federated Learning: A
//! Segmented Gossip Approach* (arXiv:1908.07782), show that splitting a
//! model into segments unlocks pipelined bandwidth: segment `i` can move
//! down the tree while segment `i+1` is still in flight upstream.
//!
//! A [`TransferPlan`] is the single source of truth for how one model
//! checkpoint is cut into wire-level transfer units. It is derived from
//! the Table II [`ModelSpec`](crate::dfl::models::ModelSpec) capacity (or
//! any explicit size in MB) plus the `segments` / `segment_mb`
//! configuration (CLI: `--segments` / `--segment-mb`), and is consumed by
//! every layer of the stack:
//!
//! * the round engine launches one flow per segment and drives
//!   cut-through forwarding over them
//!   ([`RoundEngine`](crate::coordinator::engine::RoundEngine)),
//! * the simulator sees segment-sized payloads (so the congestion-loss
//!   model inflates segments, not whole checkpoints),
//! * the live transport frames segments as
//!   [`Message::ModelSegment`](crate::transport::Message) and reassembles
//!   them at the receiver (payloads are synthetic in the in-process live
//!   mode; [`TransferPlan::segment_bounds`] / [`TransferPlan::slice`] are
//!   the slicing API for carrying real parameter bytes).
//!
//! `segments = 1` is the compatibility anchor: a single whole-model
//! transfer unit, bit-identical to the pre-segmentation engine.
//!
//! ## Compressed wire sizes
//!
//! A plan distinguishes the **logical** checkpoint size
//! ([`TransferPlan::model_mb`] — what the learning layer snapshots) from
//! the **wire** size ([`TransferPlan::wire_mb`] — what flows actually
//! move). [`TransferPlan::with_compression`] derives the wire size from a
//! [`CompressionConfig`](crate::dfl::compress::CompressionConfig)
//! (quantization / top-k — CLI `--compress`), and every consumer of
//! [`TransferPlan::segment_mb`] — the engine's flow launches, the §III-C
//! slot budget, the simulator's loss model — sees the compressed payload.
//! With `compress = none` the wire size **is** the logical size, bit for
//! bit.

use crate::dfl::compress::CompressionConfig;
use std::ops::Range;

/// How one model checkpoint is sliced into wire-level transfer units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferPlan {
    model_mb: f64,
    /// Bytes one copy actually moves (== `model_mb` without compression).
    wire_mb: f64,
    segments: usize,
}

impl TransferPlan {
    /// One whole-model transfer unit (the legacy engine's behavior).
    pub fn whole(model_mb: f64) -> Self {
        Self::segmented(model_mb, 1)
    }

    /// Slice the checkpoint into exactly `segments` equal units.
    pub fn segmented(model_mb: f64, segments: usize) -> Self {
        assert!(model_mb > 0.0, "model size must be positive, got {model_mb} MB");
        assert!(segments >= 1, "a transfer plan needs at least one segment");
        assert!(segments <= u16::MAX as usize, "segment count {segments} exceeds u16 wire field");
        TransferPlan { model_mb, wire_mb: model_mb, segments }
    }

    /// Slice the checkpoint into units of at most `segment_mb` MB:
    /// `k = ceil(model_mb / segment_mb)` equal segments, saturating at
    /// the wire format's `u16::MAX` ceiling (a derived count degrades to
    /// the finest supported slicing instead of panicking).
    pub fn by_segment_mb(model_mb: f64, segment_mb: f64) -> Self {
        assert!(segment_mb > 0.0, "segment size must be positive, got {segment_mb} MB");
        let k = ((model_mb / segment_mb).ceil().max(1.0) as usize).min(u16::MAX as usize);
        Self::segmented(model_mb, k)
    }

    /// Re-derive the wire size under `codec` (keeps the logical size and
    /// slicing): the size every flow launch, slot budget, and loss-model
    /// evaluation sees. `compress = none` leaves the wire size equal to
    /// the logical size, bit for bit.
    pub fn with_compression(mut self, codec: &CompressionConfig) -> Self {
        self.wire_mb = codec.wire_mb(self.model_mb);
        assert!(self.wire_mb > 0.0, "compressed wire size must stay positive");
        self
    }

    /// Full **logical** checkpoint size in MB (what the learning layer
    /// snapshots, regardless of compression).
    pub fn model_mb(&self) -> f64 {
        self.model_mb
    }

    /// Bytes one model copy actually moves on the wire, in MB. Equals
    /// [`TransferPlan::model_mb`] (same float bits) unless a compression
    /// codec was applied via [`TransferPlan::with_compression`].
    pub fn wire_mb(&self) -> f64 {
        self.wire_mb
    }

    /// Logical-to-wire size ratio (1.0 without compression).
    pub fn compression_ratio(&self) -> f64 {
        self.model_mb / self.wire_mb
    }

    /// Whether this plan moves compressed payloads.
    pub fn is_compressed(&self) -> bool {
        self.wire_mb.to_bits() != self.model_mb.to_bits()
    }

    /// Number of transfer units one copy is cut into (`k >= 1`).
    pub fn segments(&self) -> usize {
        self.segments
    }

    /// **Wire** size of one transfer unit in MB (equal split; for
    /// `segments == 1` this is exactly `wire_mb`, preserving the legacy
    /// payload bits when uncompressed).
    pub fn segment_mb(&self) -> f64 {
        if self.segments == 1 {
            self.wire_mb
        } else {
            self.wire_mb / self.segments as f64
        }
    }

    /// Whether transfers are segment-granular (more than one unit).
    pub fn is_segmented(&self) -> bool {
        self.segments > 1
    }

    /// The per-lane plan when one copy is striped round-robin across
    /// `lanes` edge-disjoint trees (multi-tree dissemination): each lane
    /// carries `1/lanes` of the logical and wire bytes as
    /// `ceil(segments / lanes)` units, so the lane payloads sum back to
    /// exactly one copy. `lanes == 1` returns `self` unchanged — the
    /// single-tree engine sees the same plan bits as today.
    pub fn stripe(&self, lanes: usize) -> TransferPlan {
        assert!(lanes >= 1, "striping needs at least one lane");
        if lanes == 1 {
            return *self;
        }
        TransferPlan {
            model_mb: self.model_mb / lanes as f64,
            wire_mb: self.wire_mb / lanes as f64,
            segments: self.segments.div_ceil(lanes).max(1),
        }
    }

    /// Element ranges slicing a flat parameter vector of `len` entries
    /// into the plan's segments: `k` contiguous near-equal chunks, first
    /// `len % k` chunks one element longer, covering `0..len` exactly.
    pub fn segment_bounds(&self, len: usize) -> Vec<Range<usize>> {
        let k = self.segments;
        let base = len / k;
        let extra = len % k;
        let mut out = Vec::with_capacity(k);
        let mut start = 0;
        for i in 0..k {
            let sz = base + usize::from(i < extra);
            out.push(start..start + sz);
            start += sz;
        }
        debug_assert_eq!(start, len);
        out
    }

    /// Slice a flat parameter vector into per-segment views.
    pub fn slice<'a, T>(&self, params: &'a [T]) -> Vec<&'a [T]> {
        self.segment_bounds(params.len()).into_iter().map(|r| &params[r]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_plan_is_one_segment_of_full_size() {
        let p = TransferPlan::whole(48.0);
        assert_eq!(p.segments(), 1);
        assert!(!p.is_segmented());
        // exact bits, not a divide-by-one roundtrip
        assert_eq!(p.segment_mb().to_bits(), 48.0f64.to_bits());
    }

    #[test]
    fn segmented_split_is_even() {
        let p = TransferPlan::segmented(48.0, 4);
        assert_eq!(p.segments(), 4);
        assert!((p.segment_mb() - 12.0).abs() < 1e-12);
        assert!((p.segment_mb() * 4.0 - p.model_mb()).abs() < 1e-9);
    }

    #[test]
    fn by_segment_mb_rounds_up() {
        assert_eq!(TransferPlan::by_segment_mb(48.0, 8.0).segments(), 6);
        assert_eq!(TransferPlan::by_segment_mb(11.6, 8.0).segments(), 2);
        assert_eq!(TransferPlan::by_segment_mb(5.0, 8.0).segments(), 1);
    }

    #[test]
    fn by_segment_mb_saturates_at_wire_ceiling() {
        // a derived count beyond the u16 wire field clamps instead of
        // panicking (explicit counts via segmented() still assert)
        let p = TransferPlan::by_segment_mb(100_000.0, 0.01);
        assert_eq!(p.segments(), u16::MAX as usize);
    }

    #[test]
    fn segment_bounds_cover_vector_exactly() {
        let p = TransferPlan::segmented(10.0, 3);
        let bounds = p.segment_bounds(10);
        assert_eq!(bounds, vec![0..4, 4..7, 7..10]);
        let p1 = TransferPlan::whole(10.0);
        assert_eq!(p1.segment_bounds(7), vec![0..7]);
    }

    #[test]
    fn slice_matches_bounds() {
        let params: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let p = TransferPlan::segmented(10.0, 4);
        let slices = p.slice(&params);
        assert_eq!(slices.len(), 4);
        let total: usize = slices.iter().map(|s| s.len()).sum();
        assert_eq!(total, params.len());
        assert_eq!(slices[0][0], 0.0);
        assert_eq!(*slices.last().unwrap().last().unwrap(), 10.0);
    }

    #[test]
    #[should_panic(expected = "at least one segment")]
    fn zero_segments_rejected() {
        TransferPlan::segmented(10.0, 0);
    }

    #[test]
    fn stripe_identity_for_one_lane() {
        let p = TransferPlan::segmented(48.0, 6).with_compression(&CompressionConfig::quant(8));
        let s = p.stripe(1);
        assert_eq!(s, p);
        assert_eq!(s.wire_mb().to_bits(), p.wire_mb().to_bits());
    }

    #[test]
    fn stripe_splits_bytes_and_segments_across_lanes() {
        let p = TransferPlan::segmented(48.0, 6);
        let s = p.stripe(3);
        assert_eq!(s.segments(), 2);
        assert!((s.model_mb() - 16.0).abs() < 1e-12);
        assert!((s.wire_mb() - 16.0).abs() < 1e-12);
        // lane payloads sum back to one full copy
        assert!((s.wire_mb() * 3.0 - p.wire_mb()).abs() < 1e-12);
        // uneven division rounds the per-lane unit count up, never to zero
        assert_eq!(TransferPlan::segmented(48.0, 4).stripe(3).segments(), 2);
        assert_eq!(TransferPlan::whole(48.0).stripe(4).segments(), 1);
    }

    #[test]
    fn stripe_preserves_compression_ratio() {
        let p = TransferPlan::segmented(48.0, 8).with_compression(&CompressionConfig::quant(8));
        let s = p.stripe(2);
        assert!(s.is_compressed());
        assert!((s.compression_ratio() - p.compression_ratio()).abs() < 1e-12);
        assert!((s.wire_mb() * 2.0 - p.wire_mb()).abs() < 1e-12);
    }

    #[test]
    fn uncompressed_plan_wire_equals_logical_bits() {
        let p = TransferPlan::whole(21.6);
        assert_eq!(p.wire_mb().to_bits(), p.model_mb().to_bits());
        assert!(!p.is_compressed());
        assert_eq!(p.compression_ratio(), 1.0);
        // none codec applied explicitly keeps the exact bits too
        let q = p.with_compression(&CompressionConfig::none());
        assert_eq!(q.wire_mb().to_bits(), 21.6f64.to_bits());
        assert_eq!(q.segment_mb().to_bits(), 21.6f64.to_bits());
        assert_eq!(q, p);
    }

    #[test]
    fn compressed_plan_shrinks_wire_units_not_logical_size() {
        let p = TransferPlan::segmented(48.0, 4).with_compression(&CompressionConfig::quant(8));
        assert_eq!(p.model_mb().to_bits(), 48.0f64.to_bits(), "logical size untouched");
        assert!(p.is_compressed());
        assert!(p.compression_ratio() > 3.5, "ratio {}", p.compression_ratio());
        // wire units split the wire size, not the logical size
        assert!((p.segment_mb() * 4.0 - p.wire_mb()).abs() < 1e-12);
        assert!(p.segment_mb() < 48.0 / 4.0 / 3.5);
        // slicing of the logical parameter vector is unchanged
        assert_eq!(p.segment_bounds(100).len(), 4);
    }
}
