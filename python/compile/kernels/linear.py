"""Layer-1 Pallas kernel: fused linear layer (x·W + b, optional GELU).

The compute hot-spot of the Layer-2 model. Tiled for the MXU systolic
array: (BM, BK) × (BK, BN) blocks with a f32 accumulator in VMEM scratch,
K-innermost grid so partial products accumulate in place — the TPU
counterpart of a CUDA tiled-shared-memory GEMM (no warps/WMMA; BlockSpec
expresses the HBM→VMEM schedule that threadblocks would).

Pallas calls carry no autodiff rules, so the public entry point wraps the
kernel in a `jax.custom_vjp`: the backward pass re-uses the same kernel
for the two transposed matmuls (dx = dz·Wᵀ, dW = xᵀ·dz), keeping the MXU
mapping on both sides of the tape.

interpret=True for CPU-PJRT executability; see aggregate.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128 matches the MXU's 128×128 systolic array; BK=128 keeps the three
# resident tiles at 3 × 128 × 128 × 4 B = 192 KiB of VMEM.
BM = 128
BK = 128
BN = 128


def _gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _gelu_grad(x):
    """d/dx of the tanh-approximate GELU."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    u = c * (x + 0.044715 * x**3)
    t = jnp.tanh(u)
    du = c * (1.0 + 3.0 * 0.044715 * x**2)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * du


def _linear_kernel(x_ref, w_ref, b_ref, out_ref, acc_ref, *, nsteps_k, activation):
    """Grid (M/BM, N/BN, K/BK), K innermost: accumulate x·w tiles, then on
    the last K step add bias and apply the activation."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nsteps_k - 1)
    def _finish():
        y = acc_ref[...] + b_ref[...]
        if activation == "gelu":
            y = _gelu(y)
        out_ref[...] = y


def _vmem_scratch(shape):
    """VMEM f32 scratch allocation (interpret-mode compatible)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _pallas_linear(x, w, b, activation, bm, bk, bn):
    """The raw kernel invocation (no AD)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        f"shapes ({m},{k},{n}) not tiles of ({bm},{bk},{bn})"
    assert activation in ("gelu", "none")
    nsteps_k = k // bk
    kernel = functools.partial(_linear_kernel, nsteps_k=nsteps_k, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nsteps_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[_vmem_scratch((bm, bn))],
        interpret=True,
    )(x, w, b.reshape(1, n))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fused_linear_ad(x, w, b, activation, bm, bk, bn):
    return _pallas_linear(x, w, b, activation, bm, bk, bn)


def _fused_linear_fwd(x, w, b, activation, bm, bk, bn):
    # keep the pre-activation for the backward pass (recompute-free)
    z = _pallas_linear(x, w, b, "none", bm, bk, bn)
    y = _gelu(z) if activation == "gelu" else z
    return y, (x, w, z)


def _fused_linear_bwd(activation, bm, bk, bn, residual, dy):
    x, w, z = residual
    dz = dy * _gelu_grad(z) if activation == "gelu" else dy
    n = w.shape[1]
    k = w.shape[0]
    zeros_k = jnp.zeros((k,), dz.dtype)
    zeros_n = jnp.zeros((n,), dz.dtype)
    # dx (M,K) = dz (M,N) @ wT (N,K); dw (K,N) = xT (K,M) @ dz (M,N)
    dx = _pallas_linear(dz, w.T, zeros_k, "none", bm, bn, bk)
    dw = _pallas_linear(x.T, dz, zeros_n, "none", bk, bm, bn)
    db = dz.sum(axis=0)
    return dx, dw, db


_fused_linear_ad.defvjp(_fused_linear_fwd, _fused_linear_bwd)


def fused_linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 activation: str = "gelu",
                 bm: int = BM, bk: int = BK, bn: int = BN) -> jnp.ndarray:
    """Compute ``act(x @ w + b)`` with an MXU-tiled Pallas kernel,
    differentiable via a custom VJP that re-uses the kernel for the
    transposed matmuls.

    Shapes must tile exactly: x (M,K), w (K,N), b (N,) with M%bm = K%bk =
    N%bn = 0. The model pads its dims to multiples of 128 at build time.
    """
    return _fused_linear_ad(x, w, b, activation, bm, bk, bn)


def mxu_utilization_estimate(m: int, k: int, n: int,
                             bm: int = BM, bk: int = BK, bn: int = BN) -> float:
    """Fraction of MXU issue slots doing useful work for these dims —
    1.0 when every tile is full (dims are multiples of the block shape)."""
    import math

    full = m * k * n
    padded = (math.ceil(m / bm) * bm) * (math.ceil(k / bk) * bk) * (math.ceil(n / bn) * bn)
    return full / padded


def vmem_footprint_bytes(bm: int = BM, bk: int = BK, bn: int = BN) -> int:
    """Resident VMEM per grid step: x, w, bias, out and the accumulator."""
    return 4 * (bm * bk + bk * bn + bn + bm * bn + bm * bn)
