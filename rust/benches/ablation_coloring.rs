//! Ablation: coloring algorithm choice (paper §III-C — BFS O(V+E) vs
//! DSatur, Welsh–Powell, LDF). Confirms the paper's claim that on an MST
//! every algorithm yields 2 colors, and times them on trees and on general
//! graphs where their color counts actually differ.

use mosgu::bench::{bench, section};
use mosgu::coloring::ColoringAlgorithm;
use mosgu::graph::topology::{barabasi_albert, complete, erdos_renyi};
use mosgu::mst::prim;
use mosgu::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::new(7);

    section("on MSTs (the paper's case): everyone 2-colors; BFS cheapest");
    for n in [10usize, 200, 2000] {
        let g = complete(n.min(400)); // cap K_n construction cost
        let tree = if n <= 400 {
            prim(&g).unwrap()
        } else {
            // big random tree via BA(m=1)
            barabasi_albert(n, 1, &mut rng)
        };
        for alg in ColoringAlgorithm::ALL {
            let c = alg.run(&tree);
            assert!(c.is_proper(&tree), "{alg:?} improper");
            let r = bench(&format!("{} on tree n={n}", alg.name()), 2, 20, || alg.run(&tree));
            // NOTE: paper §III-C says any algorithm 2-colors an MST; true
            // for BFS/DSatur, while WP/LDF may exceed 2 (see EXPERIMENTS.md)
            println!("{}  -> {} colors", r.report(), c.num_colors());
        }
    }

    section("on general graphs: color counts diverge (DSatur usually fewest)");
    let g = erdos_renyi(300, 0.1, &mut rng);
    for alg in ColoringAlgorithm::ALL {
        let c = alg.run(&g);
        assert!(c.is_proper(&g), "{alg:?} improper");
        let r = bench(&format!("{} on ER(300,0.1)", alg.name()), 2, 10, || alg.run(&g));
        println!("{}  -> {} colors", r.report(), c.num_colors());
    }
}
