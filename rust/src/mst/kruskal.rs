//! Kruskal's algorithm, O(E log E) — the paper's §III-B sparse-graph
//! alternative. Used in cross-checks and the MST ablation bench.

use super::union_find::UnionFind;
use super::MstError;
use crate::graph::Graph;

/// Compute the MST of `g` by sorting edges and joining components.
pub fn kruskal(g: &Graph) -> Result<Graph, MstError> {
    let n = g.node_count();
    if n == 0 {
        return Err(MstError::Empty);
    }
    let mut uf = UnionFind::new(n);
    let mut tree = Graph::new(n);
    for e in g.sorted_edges() {
        if uf.union(e.u, e.v) {
            tree.add_edge(e.u, e.v, e.weight);
            if tree.edge_count() == n - 1 {
                break;
            }
        }
    }
    if tree.edge_count() != n - 1 {
        return Err(MstError::Disconnected);
    }
    Ok(tree)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skips_cycle_closing_edges() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(0, 2, 3.0); // closes a cycle, must be skipped
        g.add_edge(2, 3, 4.0);
        let t = kruskal(&g).unwrap();
        assert!(!t.has_edge(0, 2));
        assert_eq!(t.total_weight(), 7.0);
    }

    #[test]
    fn stops_early_once_spanning() {
        // heaviest edge irrelevant; result must still be correct
        let mut g = Graph::new(3);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(0, 2, 100.0);
        let t = kruskal(&g).unwrap();
        assert_eq!(t.edge_count(), 2);
        assert_eq!(t.total_weight(), 3.0);
    }

    #[test]
    fn deterministic_on_equal_weights() {
        let mut g = Graph::new(4);
        for (u, v) in [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)] {
            g.add_edge(u, v, 1.0);
        }
        // sorted_edges tie-breaks by endpoints: picks (0,1),(0,2),(0,3)
        let t = kruskal(&g).unwrap();
        assert!(t.has_edge(0, 1) && t.has_edge(0, 2) && t.has_edge(0, 3));
    }
}
