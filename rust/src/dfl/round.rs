//! DFL round orchestration: local training → MOSGU gossip (through the
//! event-driven round engine, with real parameter payloads moving
//! between node states) → FedAvg aggregation → next round.
//!
//! Communication for **all** rounds runs through one long-lived
//! simulator via [`GossipSession::run_pipelined_rounds`]: a node seeds
//! round `t+1` the moment it has aggregated round `t`, so next-round
//! seeds gossip in slots round `t` has vacated (§III-D). Training and
//! aggregation then replay in causal round order using the engine's
//! actual per-node reception orders — gossip *content* moves real
//! parameter vectors while gossip *timing* comes from the pipelined
//! discrete-event run (the same dual the paper's testbed had: FTP moves
//! bytes, the protocol decides when).
//!
//! This module is what `examples/dfl_train.rs` drives end-to-end: the full
//! three-layer stack composing — Rust protocol + DES timing + PJRT
//! execution of the JAX/Pallas artifacts.

use super::compress::ErrorFeedback;
use super::trainer::{NodeModel, Trainer};
use crate::coordinator::session::GossipSession;
use anyhow::Result;

/// Per-round report for the training log / loss curve.
#[derive(Debug, Clone)]
pub struct DflRoundReport {
    pub round: u64,
    /// mean local training loss across nodes (before gossip)
    pub train_loss: f32,
    /// mean eval loss across nodes after aggregation
    pub eval_loss: f32,
    /// simulated communication time of the gossip round (exchange phase,
    /// measured from the round's first seed)
    pub comm_time_s: f64,
    /// slots the round's traffic was active in
    pub slots: usize,
    /// logical parameter MB a single model transfer represents
    pub model_mb: f64,
    /// MB a single model copy actually moved on the wire (== `model_mb`
    /// with `compress = none`)
    pub wire_mb: f64,
    /// wire segments each model copy traveled as (1 = whole-model)
    pub segments: usize,
    /// absolute pipeline time the round's first seed entered the engine
    pub start_s: f64,
    /// absolute pipeline time the round fully disseminated
    pub done_s: f64,
}

/// Drives `rounds` of decentralized federated learning over the session's
/// gossip tree. Returns one report per round.
///
/// Training and aggregation use the AOT artifacts; communication timing
/// and per-node reception orders come from one pipelined multi-round
/// engine run over a shared simulator (see the module docs).
pub fn run_dfl(
    session: &GossipSession,
    trainer: &Trainer,
    rounds: u64,
    local_steps: u32,
    lr: f32,
    mut on_round: impl FnMut(&DflRoundReport),
) -> Result<Vec<DflRoundReport>> {
    let n = session.tree().node_count();
    anyhow::ensure!(n > 0, "cannot run DFL over an empty session (n = 0)");
    let model_mb = trainer.artifacts().model_mb();

    // one long-lived simulator for every round's gossip, with
    // multi-round pipelining; content-free, so it can run up front. The
    // session's transfer plan decides whether checkpoints move whole or
    // as cut-through-forwarded segments (--segments / --segment-mb), and
    // the dynamic network plane (--drift / --probe-every /
    // --replan-threshold) drifts links and re-plans mid-session; with
    // the static defaults this is the plain pipeline bit for bit.
    let pipeline = session.run_adaptive_rounds(model_mb, rounds, 0x90551b);
    anyhow::ensure!(
        pipeline.rounds.len() == rounds as usize,
        "pipeline completed {} of {rounds} rounds",
        pipeline.rounds.len()
    );

    let mut nodes: Vec<NodeModel> = (0..n).map(|u| trainer.init_node(u, 0.02)).collect();
    let mut reports = Vec::new();

    // payload compression (--compress quant|topk): each node encodes
    // `params + residual` at snapshot time and gossips the *decoded*
    // payload, carrying the codec error forward as an error-feedback
    // residual. With compress = none this plumbing is skipped entirely
    // and the loop is the legacy full-width path.
    let codec = session.config().compression();
    let dim = nodes.first().map_or(0, |m| m.params.len());
    let mut feedback: Vec<ErrorFeedback> = if codec.is_none() {
        Vec::new()
    } else {
        (0..n).map(|_| ErrorFeedback::new(dim)).collect()
    };
    let wire_mb = session.transfer_plan(model_mb).wire_mb();
    // robust-aggregation policy (--fold); Mean is the legacy pairwise path
    let policy = session.fold_policy();

    for round in 0..rounds {
        // --- local training ---
        let mut train_loss = 0.0f32;
        for node in nodes.iter_mut() {
            let mut last = 0.0;
            for step in 0..local_steps {
                last = trainer.train_step(
                    node,
                    round * local_steps as u64 + step as u64,
                    lr,
                )?;
            }
            train_loss += last;
        }
        train_loss /= n as f32;

        // --- aggregation: fold every received model under the session's
        // fold policy, in the engine's actual delivery order for this
        // round. `--fold mean` replays the legacy pairwise FedAvg
        // artifact sequence verbatim; the robust policies fold the
        // canonical owner-sorted candidate set CPU-side. Under a
        // compression codec the snapshot is each node's decoded
        // (wire-visible) payload, and the sender adopts that decoded
        // payload as its own fold contribution too — so every node
        // averages the identical vector set and consensus stays exact;
        // the residual carries the codec error into the next round. An
        // active adversary corrupts the snapshot exactly where a real
        // Byzantine node would: between local training and the wire. ---
        let received = &pipeline.received[round as usize];
        let mut snapshot: Vec<Vec<f32>> = if codec.is_none() {
            nodes.iter().map(|m| m.params.clone()).collect()
        } else {
            nodes.iter().map(|m| feedback[m.node].compress(&m.params, &codec)).collect()
        };
        if let Some(scenario) = session.adversary() {
            scenario.corrupt_snapshot(&mut snapshot, round, session.config().seed);
        }
        let weights: Vec<f32> = nodes.iter().map(|m| m.weight).collect();
        let mut eval_loss = 0.0f32;
        for node in nodes.iter_mut() {
            node.weight = 1.0;
            if !codec.is_none() {
                node.params = snapshot[node.node].clone();
            }
            let payloads: Vec<(usize, &[f32], f32)> = received[node.node]
                .iter()
                .map(|&owner| (owner, snapshot[owner].as_slice(), weights[owner]))
                .collect();
            trainer.fold_received(node, &payloads, &policy)?;
            eval_loss += trainer.eval(node, u64::MAX ^ round)?;
            node.weight = 1.0;
        }
        eval_loss /= n as f32;

        let phase = &pipeline.rounds[round as usize];
        let report = DflRoundReport {
            round,
            train_loss,
            eval_loss,
            comm_time_s: phase.exchange_done_s - phase.first_seed_s,
            slots: phase.slot_span(),
            model_mb,
            wire_mb,
            segments: pipeline.segments,
            start_s: phase.first_seed_s,
            done_s: phase.done_s,
        };
        on_round(&report);
        reports.push(report);
    }
    Ok(reports)
}

/// After full dissemination + pairwise folding, every node holds the same
/// FedAvg model; used by integration tests to assert consensus. An empty
/// slice agrees vacuously (it must not panic — callers may filter down to
/// the honest subset first).
pub fn models_agree(nodes: &[NodeModel], atol: f32) -> bool {
    let Some(first) = nodes.first() else {
        return true;
    };
    let first = &first.params;
    nodes.iter().all(|m| {
        m.params.len() == first.len()
            && m.params.iter().zip(first.iter()).all(|(a, b)| (a - b).abs() <= atol)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn models_agree_detects_divergence() {
        let a = NodeModel { node: 0, params: vec![1.0, 2.0], weight: 1.0 };
        let mut b = a.clone();
        b.node = 1;
        assert!(models_agree(&[a.clone(), b.clone()], 1e-6));
        b.params[1] = 3.0;
        assert!(!models_agree(&[a, b], 1e-6));
    }

    #[test]
    fn models_agree_handles_empty_and_singleton_slices() {
        // `nodes[0]` used to panic on an empty slice — honest-subset
        // filtering under a Byzantine scenario can legitimately hit it
        assert!(models_agree(&[], 1e-6));
        let a = NodeModel { node: 0, params: vec![1.0], weight: 1.0 };
        assert!(models_agree(&[a], 1e-6));
    }

    #[test]
    fn pipeline_reception_orders_feed_full_aggregation() {
        // without artifacts we can still assert the engine hands the DFL
        // layer complete per-round fold inputs
        let cfg = crate::config::ExperimentConfig {
            latency_jitter: 0.0,
            ..Default::default()
        };
        let session = GossipSession::new(&cfg).unwrap();
        let p = session.run_pipelined_rounds(5.0, 2, 0x90551b);
        assert_eq!(p.received.len(), 2);
        for round in &p.received {
            for (u, order) in round.iter().enumerate() {
                assert_eq!(order.len(), 9, "node {u} must fold all peers");
                assert!(!order.contains(&u), "own model is not re-folded");
            }
        }
        // report-facing timings are well-formed
        for phase in &p.rounds {
            assert!(phase.exchange_done_s > phase.first_seed_s);
            assert!(phase.slot_span() > 10);
        }
    }

    #[test]
    fn segmented_pipeline_hands_dfl_full_fold_inputs() {
        // a segmented transfer plan must not change what the aggregation
        // layer sees: complete per-round reception orders for every node
        let cfg = crate::config::ExperimentConfig {
            segments: 4,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let session = GossipSession::new(&cfg).unwrap();
        let p = session.run_pipelined_rounds(21.6, 2, 0x90551b);
        assert_eq!(p.segments, 4);
        assert_eq!(p.received.len(), 2);
        for round in &p.received {
            for (u, order) in round.iter().enumerate() {
                assert_eq!(order.len(), 9, "node {u} must fold all peers");
                assert!(!order.contains(&u), "own model is not re-folded");
            }
        }
    }
}
