//! Dynamic membership ("churn") driver — the paper's §III-A requirement:
//! "From the second round onward, the moderator only needs to recompute
//! all graph-related computations and send information to affected nodes
//! when there are changes in the network, such as nodes joining or
//! leaving."
//!
//! The driver runs a sequence of communication rounds over the testbed;
//! between rounds, scripted [`ChurnEvent`]s remove or restore devices. On
//! a membership change the moderator epoch bumps, the MST/coloring/slot
//! schedule are recomputed over the surviving overlay, and the round runs
//! on the new tree; on quiet rounds the cached schedule is reused.

use super::engine::driver::SimDriver;
use super::engine::{RoundEngine, RoundOptions};
use super::gossip::GossipState;
use super::moderator::{Moderator, ScheduleBundle};
use crate::config::ExperimentConfig;
use crate::dfl::transfer::TransferPlan;
use crate::graph::{Graph, NodeId};
use crate::metrics::RoundMetrics;
use crate::netsim::testbed::Testbed;
use crate::netsim::DriftProcess;
use anyhow::Result;

/// A scripted membership change applied before round `round`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    /// Device (original id) leaves before the given round.
    Leave { round: u64, node: NodeId },
    /// Previously-left device rejoins before the given round.
    Rejoin { round: u64, node: NodeId },
}

/// Per-round report of the churn experiment.
#[derive(Debug, Clone)]
pub struct ChurnRoundReport {
    pub round: u64,
    /// original-id list of active members this round
    pub active: Vec<NodeId>,
    /// whether the moderator had to recompute the schedule
    pub recomputed: bool,
    pub metrics: RoundMetrics,
}

/// Runs `rounds` MOSGU communication rounds over the config's testbed,
/// applying `events` between rounds. Returns per-round reports.
///
/// The moderator recomputes iff membership changed — asserted by the
/// report's `recomputed` flag, which integration tests pin.
pub fn run_churn_experiment(
    cfg: &ExperimentConfig,
    model_mb: f64,
    rounds: u64,
    events: &[ChurnEvent],
) -> Result<Vec<ChurnRoundReport>> {
    let testbed = Testbed::new(cfg);
    let full_overlay = crate::graph::topology::complete(cfg.nodes);
    let full_costs = testbed.overlay_costs(&full_overlay);
    // same transfer plane as every other execution path: the config's
    // segments / segment_mb settings slice churn rounds too
    let plan = cfg.transfer_plan(model_mb);

    let mut active: Vec<bool> = vec![true; cfg.nodes];
    let mut moderator = Moderator::new(0, cfg.nodes, cfg.mst, cfg.coloring);
    let mut bundle: Option<(ScheduleBundle, Vec<NodeId>)> = None;
    let mut reports = Vec::new();

    for round in 0..rounds {
        // apply scripted events for this round
        let mut changed = bundle.is_none();
        for ev in events {
            match *ev {
                ChurnEvent::Leave { round: r, node } if r == round => {
                    anyhow::ensure!(active[node], "node {node} left twice");
                    active[node] = false;
                    changed = true;
                }
                ChurnEvent::Rejoin { round: r, node } if r == round => {
                    anyhow::ensure!(!active[node], "node {node} rejoined while active");
                    active[node] = true;
                    changed = true;
                }
                _ => {}
            }
        }
        let members: Vec<NodeId> =
            (0..cfg.nodes).filter(|&u| active[u]).collect();
        anyhow::ensure!(members.len() >= 2, "round {round}: fewer than 2 members");

        if changed {
            // membership epoch bump: fresh reports over the survivors
            moderator.membership_changed(members.len());
            let (sub_costs, map) = full_costs.induced(&members);
            for u in 0..sub_costs.node_count() {
                let peers: Vec<(usize, f64)> =
                    sub_costs.neighbors(u).iter().map(|&(v, w)| (v, w)).collect();
                moderator.submit_report(u, &peers);
            }
            let b = moderator.compute_schedule(plan.segment_mb(), cfg.ping_size_bytes, 1)?.clone();
            bundle = Some((b, map));
        }
        let recomputed = changed;
        debug_assert!(!moderator.needs_recompute());
        let (b, map) = bundle.as_ref().unwrap();

        // run a timed round over the (relabeled) tree; routes use original
        // ids, links drift per the config (amplitude 0 = static legacy)
        let drift = DriftProcess { amplitude: cfg.drift, interval_s: cfg.drift_interval_s };
        let metrics =
            run_round_on_tree(&testbed, &b.tree, &b.schedule, map, plan, cfg.seed ^ round, drift)?;
        reports.push(ChurnRoundReport { round, active: map.clone(), recomputed, metrics });
    }
    Ok(reports)
}

/// One timed MOSGU round over an arbitrary relabeled tree (`map[new] =
/// original device id` for testbed routing), driven through the shared
/// round engine with a device-mapped simulator driver.
///
/// Like every engine round (and the legacy session path), an incomplete
/// round within the slot budget is a protocol bug and panics rather
/// than returning `Err`.
#[allow(clippy::too_many_arguments)]
fn run_round_on_tree(
    testbed: &Testbed,
    tree: &Graph,
    schedule: &super::schedule::Schedule,
    map: &[NodeId],
    plan: TransferPlan,
    seed: u64,
    drift: DriftProcess,
) -> Result<RoundMetrics> {
    let mut driver = SimDriver::with_map_drift(testbed, seed, map.to_vec(), drift);
    let mut engine = RoundEngine::new(&mut driver, schedule);
    let mut state = GossipState::new(tree.clone(), 0);
    let n = tree.node_count();
    let opts = RoundOptions::reliable_plan(plan, 8 * n + 64);
    Ok(engine.run_round(&mut state, opts, |_, _| {}))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig { latency_jitter: 0.0, ..Default::default() }
    }

    #[test]
    fn quiet_rounds_reuse_schedule() {
        let reports = run_churn_experiment(&cfg(), 5.0, 4, &[]).unwrap();
        assert_eq!(reports.len(), 4);
        assert!(reports[0].recomputed, "first round always computes");
        for r in &reports[1..] {
            assert!(!r.recomputed, "round {}: no change, no recompute", r.round);
            assert_eq!(r.active.len(), 10);
        }
    }

    #[test]
    fn leave_triggers_recompute_and_smaller_tree() {
        let events = [ChurnEvent::Leave { round: 2, node: 3 }];
        let reports = run_churn_experiment(&cfg(), 5.0, 4, &events).unwrap();
        assert!(!reports[1].recomputed);
        assert!(reports[2].recomputed, "leave must recompute");
        assert_eq!(reports[2].active.len(), 9);
        assert!(!reports[2].active.contains(&3));
        // a 9-node round moves 9*8 copies
        assert_eq!(reports[2].metrics.transfer_count(), 72);
        assert!(!reports[3].recomputed, "stable again");
    }

    #[test]
    fn rejoin_restores_full_membership() {
        let events = [
            ChurnEvent::Leave { round: 1, node: 7 },
            ChurnEvent::Rejoin { round: 3, node: 7 },
        ];
        let reports = run_churn_experiment(&cfg(), 5.0, 5, &events).unwrap();
        assert_eq!(reports[1].active.len(), 9);
        assert!(reports[3].recomputed);
        assert_eq!(reports[3].active.len(), 10);
        assert_eq!(reports[3].metrics.transfer_count(), 90);
    }

    #[test]
    fn multiple_leaves_same_round() {
        let events = [
            ChurnEvent::Leave { round: 1, node: 0 },
            ChurnEvent::Leave { round: 1, node: 5 },
        ];
        let reports = run_churn_experiment(&cfg(), 5.0, 2, &events).unwrap();
        assert_eq!(reports[1].active.len(), 8);
        assert_eq!(reports[1].metrics.transfer_count(), 56);
    }

    #[test]
    fn double_leave_rejected() {
        let events = [
            ChurnEvent::Leave { round: 1, node: 2 },
            ChurnEvent::Leave { round: 2, node: 2 },
        ];
        assert!(run_churn_experiment(&cfg(), 5.0, 3, &events).is_err());
    }

    #[test]
    fn churn_rounds_remain_efficient() {
        // even after churn, the gossip round beats broadcast on bandwidth
        let events = [ChurnEvent::Leave { round: 1, node: 4 }];
        let reports = run_churn_experiment(&cfg(), 14.0, 2, &events).unwrap();
        let session =
            crate::coordinator::session::GossipSession::new(&cfg()).unwrap();
        let b = session.run_broadcast_round(14.0, 1);
        assert!(reports[1].metrics.bandwidth_mbps() > 2.0 * b.bandwidth_mbps());
    }

    #[test]
    fn churn_rounds_survive_link_drift() {
        // drifting links perturb timing but never correctness: every
        // round still moves the full copy set, deterministically per seed
        let cfg = ExperimentConfig { drift: 0.3, drift_interval_s: 0.5, ..cfg() };
        let events = [ChurnEvent::Leave { round: 1, node: 3 }];
        let a = run_churn_experiment(&cfg, 5.0, 2, &events).unwrap();
        assert_eq!(a[0].metrics.transfer_count(), 90);
        assert_eq!(a[1].metrics.transfer_count(), 72);
        let b = run_churn_experiment(&cfg, 5.0, 2, &events).unwrap();
        assert_eq!(
            a[1].metrics.total_time_s.to_bits(),
            b[1].metrics.total_time_s.to_bits(),
            "drift must replay deterministically"
        );
        // static config stays the legacy trajectory
        let static_runs = run_churn_experiment(&self::cfg(), 5.0, 1, &[]).unwrap();
        assert_eq!(static_runs[0].metrics.transfer_count(), 90);
    }

    #[test]
    fn churn_rounds_honor_the_config_transfer_plan() {
        // a segmented config slices churn rounds like every other path
        let cfg = ExperimentConfig { segments: 4, ..cfg() };
        let events = [ChurnEvent::Leave { round: 1, node: 6 }];
        let reports = run_churn_experiment(&cfg, 21.6, 2, &events).unwrap();
        for (round, copies) in [(0usize, 90usize), (1, 72)] {
            let m = &reports[round].metrics;
            assert_eq!(m.segments, 4, "round {round}");
            assert_eq!(m.transfer_count(), copies * 4, "round {round}");
            assert_eq!(m.model_copy_count(), copies, "round {round}");
        }
    }
}
