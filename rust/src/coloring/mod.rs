//! Graph coloring (paper §III-C, "S — Schedule communication").
//!
//! The paper selects **BFS** coloring because an MST is a tree, hence
//! bipartite, hence 2-colorable by any of the candidate algorithms; BFS
//! does it in O(V+E). We also implement the three alternatives the paper
//! compares against — DSatur, Welsh–Powell, Largest-Degree-First — for the
//! `ablation_coloring` bench and for scheduling on non-tree graphs.

pub mod bfs;
pub mod dsatur;
pub mod greedy;
pub mod stitch;

pub use bfs::bfs_coloring;
pub use dsatur::dsatur;
pub use greedy::{largest_degree_first, welsh_powell};
pub use stitch::stitched_tree_coloring;

use crate::graph::{Graph, NodeId};

/// A node coloring: `assignment[u]` is the color (timeslot class) of `u`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Coloring {
    assignment: Vec<usize>,
}

impl Coloring {
    pub fn new(assignment: Vec<usize>) -> Self {
        Coloring { assignment }
    }

    pub fn color_of(&self, u: NodeId) -> usize {
        self.assignment[u]
    }

    pub fn len(&self) -> usize {
        self.assignment.len()
    }

    pub fn is_empty(&self) -> bool {
        self.assignment.is_empty()
    }

    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }

    /// Number of distinct colors used.
    pub fn num_colors(&self) -> usize {
        self.assignment.iter().copied().max().map_or(0, |m| m + 1)
    }

    /// Nodes of a given color, ascending.
    pub fn class(&self, color: usize) -> Vec<NodeId> {
        (0..self.assignment.len()).filter(|&u| self.assignment[u] == color).collect()
    }

    /// All color classes, indexed by color.
    pub fn classes(&self) -> Vec<Vec<NodeId>> {
        (0..self.num_colors()).map(|c| self.class(c)).collect()
    }

    /// Proper iff no edge joins two same-colored nodes — the invariant that
    /// makes the paper's alternating slots collision-free on the MST.
    pub fn is_proper(&self, g: &Graph) -> bool {
        g.edges().iter().all(|e| self.assignment[e.u] != self.assignment[e.v])
    }

    /// Size of the largest color class (drives worst-case slot contention).
    pub fn max_class_size(&self) -> usize {
        let mut counts = vec![0usize; self.num_colors()];
        for &c in &self.assignment {
            counts[c] += 1;
        }
        counts.into_iter().max().unwrap_or(0)
    }
}

/// Algorithm selector for CLI / config.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColoringAlgorithm {
    Bfs,
    DSatur,
    WelshPowell,
    LargestDegreeFirst,
}

impl ColoringAlgorithm {
    pub const ALL: [ColoringAlgorithm; 4] = [
        ColoringAlgorithm::Bfs,
        ColoringAlgorithm::DSatur,
        ColoringAlgorithm::WelshPowell,
        ColoringAlgorithm::LargestDegreeFirst,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ColoringAlgorithm::Bfs => "bfs",
            ColoringAlgorithm::DSatur => "dsatur",
            ColoringAlgorithm::WelshPowell => "welsh-powell",
            ColoringAlgorithm::LargestDegreeFirst => "ldf",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "bfs" => Some(ColoringAlgorithm::Bfs),
            "dsatur" => Some(ColoringAlgorithm::DSatur),
            "welsh-powell" | "wp" => Some(ColoringAlgorithm::WelshPowell),
            "ldf" | "largest-degree-first" => Some(ColoringAlgorithm::LargestDegreeFirst),
            _ => None,
        }
    }

    pub fn run(&self, g: &Graph) -> Coloring {
        match self {
            ColoringAlgorithm::Bfs => bfs_coloring(g),
            ColoringAlgorithm::DSatur => dsatur(g),
            ColoringAlgorithm::WelshPowell => welsh_powell(g),
            ColoringAlgorithm::LargestDegreeFirst => largest_degree_first(g),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology::complete;
    use crate::mst::prim;

    #[test]
    fn class_queries() {
        let c = Coloring::new(vec![0, 1, 0, 2]);
        assert_eq!(c.num_colors(), 3);
        assert_eq!(c.class(0), vec![0, 2]);
        assert_eq!(c.class(2), vec![3]);
        assert_eq!(c.classes().len(), 3);
        assert_eq!(c.max_class_size(), 2);
    }

    #[test]
    fn proper_detects_conflicts() {
        let mut g = Graph::new(3);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 1.0);
        assert!(Coloring::new(vec![0, 1, 0]).is_proper(&g));
        assert!(!Coloring::new(vec![0, 0, 1]).is_proper(&g));
    }

    #[test]
    fn every_algorithm_proper_on_mst() {
        // §III-C claims every algorithm 2-colors an MST. That is exactly
        // true for BFS and DSatur (optimal on bipartite graphs); the
        // degree-greedy Welsh-Powell/LDF are merely *proper* and can need
        // 3+ colors on adversarial trees (EXPERIMENTS.md §Deviations).
        let g = complete(10);
        let t = prim(&g).unwrap();
        for alg in ColoringAlgorithm::ALL {
            let c = alg.run(&t);
            assert!(c.is_proper(&t), "{alg:?} produced improper coloring");
            if matches!(alg, ColoringAlgorithm::Bfs | ColoringAlgorithm::DSatur) {
                assert!(c.num_colors() <= 2, "{alg:?} used {} colors on a tree", c.num_colors());
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for alg in ColoringAlgorithm::ALL {
            assert_eq!(ColoringAlgorithm::parse(alg.name()), Some(alg));
        }
        assert_eq!(ColoringAlgorithm::parse("WP"), Some(ColoringAlgorithm::WelshPowell));
        assert_eq!(ColoringAlgorithm::parse("rainbow"), None);
    }
}
