//! Topology sweep: the paper's §V-B analysis — how the four underlay
//! families affect bandwidth, transfer time and round time, including the
//! qualitative claims (Erdős–Rényi best for large models, Barabási–Albert
//! second slowest, Complete best bandwidth for small/medium) — plus a
//! `--segments` dimension sweeping the segment-granular transfer plane
//! (cut-through forwarding) against whole-model transfers.
//!
//! ```bash
//! cargo run --release --example topology_sweep [-- --models v3s,b0,b3]
//! cargo run --release --example topology_sweep -- --segments 1,4,8
//! cargo run --release --example topology_sweep -- --drift 0.3
//! cargo run --release --example topology_sweep -- --subnets 1,4,8 --scale-nodes 96
//! cargo run --release --example topology_sweep -- --skip-grid --subnets 1,4
//! ```
//!
//! `--skip-grid` skips the paper Table II–V grid and runs only the
//! requested sweep dimensions (what CI's cookbook smoke uses).
//!
//! `--drift A` adds the dynamic-plane dimension: pipelined rounds over
//! drifting links (amplitude `A`), with the frozen session-start plan
//! vs online probing + re-planning (`--probe-every`, default 1).
//!
//! `--subnets a,b,c` adds the scale-out dimension: a router-hierarchy
//! overlay of `--scale-nodes` nodes per subnet count, hierarchically
//! planned (per-subnet MST + coloring stitched through the gateway
//! backbone), with the exchange phase run on the sequential simulator vs
//! the sharded per-subnet simulator (see docs/ARCHITECTURE.md).

use mosgu::bench::tables::{all_models, run_grid};
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::{GossipSession, ScaleScenario};
use mosgu::dfl::models::by_code;
use mosgu::dfl::transfer::TransferPlan;
use mosgu::graph::topology::TopologyKind;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    mosgu::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flag_value = |flag: &str| -> anyhow::Result<Option<String>> {
        match args.iter().position(|a| a == flag) {
            Some(i) => args
                .get(i + 1)
                .cloned()
                .map(Some)
                .ok_or_else(|| anyhow::anyhow!("{flag} needs a value")),
            None => Ok(None),
        }
    };
    let models = match flag_value("--models")? {
        Some(list) => list
            .split(',')
            .map(|c| by_code(c.trim()).ok_or_else(|| anyhow::anyhow!("unknown model {c}")))
            .collect::<Result<Vec<_>, _>>()?,
        None => all_models(),
    };
    let segment_counts: Vec<usize> = match flag_value("--segments")? {
        Some(list) => list
            .split(',')
            .map(|s| {
                let k: usize =
                    s.trim().parse().map_err(|e| anyhow::anyhow!("bad --segments {s}: {e}"))?;
                let max = u16::MAX as usize;
                anyhow::ensure!((1..=max).contains(&k), "--segments {k} out of 1..=65535");
                Ok(k)
            })
            .collect::<Result<Vec<_>, _>>()?,
        None => Vec::new(),
    };

    let drift: f64 = match flag_value("--drift")? {
        Some(a) => {
            let a: f64 = a.parse().map_err(|e| anyhow::anyhow!("bad --drift {a}: {e}"))?;
            anyhow::ensure!((0.0..1.0).contains(&a), "--drift {a} out of [0,1)");
            a
        }
        None => 0.0,
    };
    let probe_every: u64 = match flag_value("--probe-every")? {
        Some(r) => r.parse().map_err(|e| anyhow::anyhow!("bad --probe-every {r}: {e}"))?,
        None => 1,
    };
    let subnet_counts: Vec<usize> = match flag_value("--subnets")? {
        Some(list) => list
            .split(',')
            .map(|s| {
                let k: usize =
                    s.trim().parse().map_err(|e| anyhow::anyhow!("bad --subnets {s}: {e}"))?;
                anyhow::ensure!(k >= 1, "--subnets must be >= 1");
                Ok(k)
            })
            .collect::<Result<Vec<_>, _>>()?,
        None => Vec::new(),
    };
    let scale_nodes: usize = match flag_value("--scale-nodes")? {
        Some(n) => n.parse().map_err(|e| anyhow::anyhow!("bad --scale-nodes {n}: {e}"))?,
        None => 96,
    };
    // --skip-grid: jump straight to the requested sweep dimensions
    // (CI smokes the subnet sweep without paying for the paper grid)
    let skip_grid = args.iter().any(|a| a == "--skip-grid");

    let cfg = ExperimentConfig { repeats: 3, ..Default::default() };
    if !skip_grid {
        let cells = run_grid(&cfg, &TopologyKind::ALL, &models, |s| eprintln!("running {s}"))?;

        println!("\n{:<17}{:>6}{:>10}{:>10}{:>10}{:>11}{:>11}", "topology", "model", "P:bw", "P:xfer", "P:round", "bw-gain", "time-gain");
        for c in &cells {
            println!(
                "{:<17}{:>6}{:>10.2}{:>10.2}{:>10.2}{:>10.1}x{:>10.1}x",
                c.topology,
                c.model,
                c.proposed.bandwidth.mean(),
                c.proposed.transfer.mean(),
                c.proposed.exchange.mean(),
                c.proposed.bandwidth.mean() / c.broadcast.bandwidth.mean(),
                c.broadcast.total.mean() / c.proposed.exchange.mean(),
            );
        }

        // §V-B qualitative checks
        println!("\n== paper §V-B qualitative checks ==");
        let mean_over = |topo: &str, f: &dyn Fn(&mosgu::metrics::Cell) -> f64| {
            let xs: Vec<f64> = cells.iter().filter(|c| c.topology == topo).map(f).collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        let gain =
            |c: &mosgu::metrics::Cell| c.proposed.bandwidth.mean() / c.broadcast.bandwidth.mean();
        for kind in TopologyKind::ALL {
            println!(
                "  {:<17} mean bandwidth gain {:.2}x",
                kind.name(),
                mean_over(kind.name(), &gain)
            );
        }
        let ba = mean_over("Barabasi-Albert", &|c| c.proposed.transfer.mean());
        let er = mean_over("Erdos-Renyi", &|c| c.proposed.transfer.mean());
        println!(
            "  BA mean transfer {ba:.2} s vs ER {er:.2} s -> hubs slow BA down: {}",
            if ba > er { "yes (matches paper)" } else { "no" }
        );
    }

    // segment-granularity dimension: cut-through forwarding vs whole-model
    // transfers, on the paper grid plus the deep-relay shapes where
    // pipelining matters most (chain, balanced tree)
    if !segment_counts.is_empty() {
        println!("\n== segment sweep (full-dissemination time, seconds) ==");
        let mut header = format!("{:<17}{:>6}{:>10}", "topology", "model", "whole");
        for &k in &segment_counts {
            header.push_str(&format!("{:>10}", format!("k={k}")));
        }
        // best segmented time relative to the whole-model baseline
        header.push_str(&format!("{:>10}", "vs-whole"));
        println!("{header}");
        let sweep_kinds = [
            TopologyKind::Complete,
            TopologyKind::ErdosRenyi,
            TopologyKind::BalancedTree,
            TopologyKind::Chain,
        ];
        for kind in sweep_kinds {
            let tcfg = ExperimentConfig { topology: kind, ..cfg.clone() };
            let session = GossipSession::new(&tcfg)?;
            for spec in &models {
                let whole = session
                    .run_mosgu_round_planned(TransferPlan::whole(spec.capacity_mb), cfg.seed, 0.0)
                    .total_time_s;
                let mut row = format!("{:<17}{:>6}{:>10.2}", kind.name(), spec.code, whole);
                let mut best = f64::INFINITY;
                for &k in &segment_counts {
                    let plan = TransferPlan::segmented(spec.capacity_mb, k);
                    let m = session.run_mosgu_round_planned(plan, cfg.seed, 0.0);
                    best = best.min(m.total_time_s);
                    row.push_str(&format!("{:>10.2}", m.total_time_s));
                }
                row.push_str(&format!("{:>9.2}x", whole / best));
                println!("{row}");
            }
        }
    }

    // scale-out dimension: hierarchical planning + sharded simulation of
    // the exchange phase, sequential vs per-subnet-parallel
    if !subnet_counts.is_empty() {
        println!("\n== subnet sweep (exchange phase, n = {scale_nodes}) ==");
        println!(
            "{:<9}{:>8}{:>12}{:>12}{:>12}{:>12}{:>10}",
            "subnets", "shards", "sim_seq_s", "sim_shard_s", "wall_seq_s", "wall_shard_s", "speedup"
        );
        for &subnets in &subnet_counts {
            anyhow::ensure!(subnets <= scale_nodes, "--subnets {subnets} > --scale-nodes");
            let scfg = ExperimentConfig {
                nodes: scale_nodes,
                subnets,
                latency_jitter: 0.0,
                ..cfg.clone()
            };
            let scenario = ScaleScenario::new(&scfg, 14.0)?;
            let t0 = Instant::now();
            let seq = scenario.run_exchange(14.0, cfg.seed, 0.0, false, false);
            let wall_seq = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let shard = scenario.run_exchange(14.0, cfg.seed, 0.0, true, true);
            let wall_shard = t1.elapsed().as_secs_f64();
            println!(
                "{:<9}{:>8}{:>12.3}{:>12.3}{:>12.4}{:>12.4}{:>9.2}x",
                subnets,
                mosgu::netsim::shard::ShardedNetSim::planned_shard_count(subnets),
                seq.total_time_s,
                shard.total_time_s,
                wall_seq,
                wall_shard,
                wall_seq / wall_shard.max(1e-9),
            );
        }
    }

    // dynamic-plane dimension: pipelined rounds over drifting links,
    // frozen session-start plan vs online probing + re-planning
    if drift > 0.0 {
        println!("\n== drift sweep (amplitude {drift}, total pipeline time for 4 rounds, s) ==");
        println!(
            "{:<17}{:>6}{:>10}{:>10}{:>10}{:>9}",
            "topology", "model", "frozen", "adaptive", "gain", "replans"
        );
        for kind in TopologyKind::ALL {
            let frozen_cfg = ExperimentConfig {
                topology: kind,
                drift,
                probe_every: 0,
                ..cfg.clone()
            };
            let adaptive_cfg = ExperimentConfig {
                probe_every,
                replan_threshold: 0.15,
                ..frozen_cfg.clone()
            };
            let frozen_session = GossipSession::new(&frozen_cfg)?;
            let adaptive_session = GossipSession::new(&adaptive_cfg)?;
            for spec in &models {
                let frozen = frozen_session.run_adaptive_rounds(spec.capacity_mb, 4, cfg.seed);
                let adaptive = adaptive_session.run_adaptive_rounds(spec.capacity_mb, 4, cfg.seed);
                println!(
                    "{:<17}{:>6}{:>10.2}{:>10.2}{:>9.2}x{:>9}",
                    kind.name(),
                    spec.code,
                    frozen.total_time_s,
                    adaptive.total_time_s,
                    frozen.total_time_s / adaptive.total_time_s,
                    adaptive.replans.len()
                );
            }
        }
    }
    Ok(())
}
