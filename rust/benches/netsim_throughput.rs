//! L3 hot-path microbenchmarks: the discrete-event simulator's event rate,
//! max-min fair-share recomputation, gossip planning, and the moderator's
//! full M+O+S computation — the pieces §Perf of EXPERIMENTS.md tracks.

use mosgu::bench::{bench, section};
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::gossip::GossipState;
use mosgu::coordinator::moderator::Moderator;
use mosgu::coordinator::session::GossipSession;
use mosgu::netsim::fairshare::max_min_rates;
use mosgu::netsim::testbed::Testbed;
use mosgu::util::rng::Pcg64;

fn main() {
    let cfg = ExperimentConfig { latency_jitter: 0.0, ..Default::default() };

    section("fair-share allocation");
    let mut rng = Pcg64::new(1);
    for (nc, nf) in [(32usize, 100usize), (64, 500), (128, 2000)] {
        let caps: Vec<f64> = (0..nc).map(|_| rng.gen_f64_range(5.0, 50.0)).collect();
        let routes: Vec<Vec<usize>> = (0..nf)
            .map(|_| {
                let hops = 1 + rng.gen_range(3);
                (0..hops).map(|_| rng.gen_range(nc)).collect()
            })
            .collect();
        let r = bench(&format!("max_min_rates {nc}ch x {nf}flows"), 3, 30, || {
            max_min_rates(&caps, &routes)
        });
        println!("{}", r.report());
    }

    section("DES end-to-end: broadcast round (90 concurrent flows)");
    let tb = Testbed::new(&cfg);
    let r = bench("broadcast round N=10", 3, 30, || {
        mosgu::coordinator::broadcast::paper_baseline(&tb, 14.0, 1)
    });
    println!("{}  ({:.0} rounds/s)", r.report(), r.per_sec());

    section("gossip protocol planning (no DES)");
    let session = GossipSession::new(&cfg).expect("session");
    let tree = session.tree().clone();
    let sched = session.schedule().clone();
    let r = bench("full logical round N=10", 3, 100, || {
        let mut st = GossipState::new(tree.clone(), 0);
        for slot in 0..200 {
            if st.is_complete() {
                break;
            }
            let planned = st.plan_slot(&sched.transmitters(slot));
            for s in GossipState::sorted_sends(&planned) {
                st.deliver(s);
            }
        }
        st
    });
    println!("{}  ({:.0} rounds/s)", r.report(), r.per_sec());

    section("moderator M+O+S computation (reports -> schedule)");
    let costs = session.costs().clone();
    let r = bench("moderator schedule N=10 complete", 3, 100, || {
        let mut m = Moderator::new(
            0,
            10,
            mosgu::mst::MstAlgorithm::Prim,
            mosgu::coloring::ColoringAlgorithm::Bfs,
        );
        for u in 0..10 {
            let peers: Vec<(usize, f64)> =
                costs.neighbors(u).iter().map(|&(v, w)| (v, w)).collect();
            m.submit_report(u, &peers);
        }
        m.compute_schedule(14.0, 56, 1).unwrap().tree.edge_count()
    });
    println!("{}", r.report());

    section("timed MOSGU round through the DES");
    let r = bench("mosgu sim round N=10 (14MB)", 3, 30, || session.run_mosgu_round(14.0, 1, 0.0));
    println!("{}  ({:.0} rounds/s)", r.report(), r.per_sec());
    let r = bench("full Table cell (5 repeats b+p)", 1, 5, || {
        let mut b = mosgu::metrics::RepeatedMetrics::default();
        for rep in 0..5u64 {
            b.push(&session.run_broadcast_round(14.0, rep));
            b.push(&session.run_mosgu_round(14.0, rep, 0.0));
        }
        b
    });
    println!("{}", r.report());
}
