//! The paper-table grid runner: 4 topologies × 7 models × {broadcast,
//! MOSGU} × `repeats` seeds, producing the cells of Tables III, IV and V.
//! `cargo bench` targets and `mosgu tables` both call into here.

use crate::config::ExperimentConfig;
use crate::coordinator::session::GossipSession;
use crate::dfl::models::{ModelSpec, MODELS};
use crate::graph::topology::TopologyKind;
use crate::metrics::{render_table, Cell, RepeatedMetrics};
use anyhow::Result;

/// Which paper table to render from a grid of cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaperTable {
    /// Table III: bandwidth (MB/s)
    Bandwidth,
    /// Table IV: average single-transfer time (s)
    TransferTime,
    /// Table V: total time for one communication round (s)
    RoundTime,
}

impl PaperTable {
    pub fn title(&self) -> &'static str {
        match self {
            PaperTable::Bandwidth => "Table III: Bandwidth (MB/s)",
            PaperTable::TransferTime => "Table IV: Average time (s) for one transfer",
            PaperTable::RoundTime => "Table V: Average total time (s) for one FL communication round",
        }
    }

    /// Extract (broadcast, proposed) values from a cell.
    pub fn values(&self, cell: &Cell) -> (f64, f64) {
        match self {
            PaperTable::Bandwidth => (cell.broadcast.bandwidth.mean(), cell.proposed.bandwidth.mean()),
            PaperTable::TransferTime => (cell.broadcast.transfer.mean(), cell.proposed.transfer.mean()),
            // Table V uses the exchange-phase time for MOSGU (the blocking
            // part of one FL round; see metrics::RoundMetrics docs)
            PaperTable::RoundTime => (cell.broadcast.total.mean(), cell.proposed.exchange.mean()),
        }
    }
}

/// Run the full grid (or a subset of topologies/models) and return cells.
pub fn run_grid(
    cfg: &ExperimentConfig,
    topologies: &[TopologyKind],
    models: &[&ModelSpec],
    mut progress: impl FnMut(&str),
) -> Result<Vec<Cell>> {
    let mut cells = Vec::new();
    for &kind in topologies {
        let tcfg = ExperimentConfig { topology: kind, ..cfg.clone() };
        let session = GossipSession::new(&tcfg)?;
        for &spec in models {
            progress(&format!("{} / {}", kind.name(), spec.code));
            let mut broadcast = RepeatedMetrics::default();
            let mut proposed = RepeatedMetrics::default();
            for rep in 0..cfg.repeats as u64 {
                let seed = cfg.seed ^ (rep + 1).wrapping_mul(0x9e37_79b9);
                broadcast.push(&session.run_broadcast_round(spec.capacity_mb, seed));
                proposed.push(&session.run_mosgu_round(spec.capacity_mb, seed, 0.0));
            }
            cells.push(Cell {
                topology: kind.name().to_string(),
                model: spec.code.to_string(),
                broadcast,
                proposed,
            });
        }
    }
    Ok(cells)
}

/// Render the compression companion table: per (topology, model) cell,
/// the proposed side's logical MB per copy, wire MB per copy, and
/// logical/wire ratio (broadcast stays uncompressed full-width fp32, so
/// its column is the logical size). All three are 1:1 with the payloads
/// the flows actually moved — `RoundMetrics` records wire-sized
/// transfers and carries the logical size alongside.
pub fn render_compression(cells: &[Cell]) -> String {
    let mut out = String::new();
    out.push_str("== Compression: logical vs wire MB per model copy ==\n");
    out.push_str(&format!(
        "{:<17}{:>8}{:>12}{:>10}{:>8}\n",
        "topology", "model", "logical_mb", "wire_mb", "ratio"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<17}{:>8}{:>12.2}{:>10.2}{:>7.2}x\n",
            c.topology,
            c.model,
            c.proposed.logical_mb.mean(),
            c.proposed.wire_mb.mean(),
            c.proposed.compression_ratio()
        ));
    }
    out
}

/// Render one paper table from a cell grid.
pub fn render(table: PaperTable, cells: &[Cell]) -> String {
    let topologies: Vec<String> = {
        let mut seen = Vec::new();
        for c in cells {
            if !seen.contains(&c.topology) {
                seen.push(c.topology.clone());
            }
        }
        seen
    };
    let models: Vec<String> = {
        let mut seen = Vec::new();
        for c in cells {
            if !seen.contains(&c.model) {
                seen.push(c.model.clone());
            }
        }
        seen
    };
    render_table(table.title(), &topologies, &models, |c| table.values(c), cells)
}

/// Headline numbers (paper abstract: "reducing bandwidth and transfer time
/// by up to circa 8 and 4.4 times"): max improvement ratios over the grid.
pub struct Headline {
    pub bandwidth_improvement: f64,
    pub transfer_improvement: f64,
    pub round_improvement: f64,
}

pub fn headline(cells: &[Cell]) -> Headline {
    let mut h = Headline {
        bandwidth_improvement: 0.0,
        transfer_improvement: 0.0,
        round_improvement: 0.0,
    };
    for c in cells {
        let bw = c.proposed.bandwidth.mean() / c.broadcast.bandwidth.mean();
        let tx = c.broadcast.transfer.mean() / c.proposed.transfer.mean();
        let rt = c.broadcast.total.mean() / c.proposed.exchange.mean();
        h.bandwidth_improvement = h.bandwidth_improvement.max(bw);
        h.transfer_improvement = h.transfer_improvement.max(tx);
        h.round_improvement = h.round_improvement.max(rt);
    }
    h
}

/// All seven Table II models, in table column order.
pub fn all_models() -> Vec<&'static ModelSpec> {
    MODELS.iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig { repeats: 1, latency_jitter: 0.0, ..Default::default() }
    }

    #[test]
    fn grid_single_cell_runs() {
        let cells = run_grid(
            &tiny_cfg(),
            &[TopologyKind::Complete],
            &[&MODELS[0]],
            |_| {},
        )
        .unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert!(c.proposed.bandwidth.mean() > c.broadcast.bandwidth.mean());
    }

    #[test]
    fn render_all_three_tables() {
        let cells = run_grid(&tiny_cfg(), &[TopologyKind::Complete], &[&MODELS[0]], |_| {}).unwrap();
        for t in [PaperTable::Bandwidth, PaperTable::TransferTime, PaperTable::RoundTime] {
            let s = render(t, &cells);
            assert!(s.contains("Table"), "{s}");
            assert!(s.contains("Complete"));
        }
    }

    #[test]
    fn headline_ratios_exceed_one() {
        let cells = run_grid(&tiny_cfg(), &[TopologyKind::Complete], &[&MODELS[6]], |_| {}).unwrap();
        let h = headline(&cells);
        assert!(h.bandwidth_improvement > 1.0);
        assert!(h.transfer_improvement > 1.0);
        assert!(h.round_improvement > 1.0);
    }

    #[test]
    fn compressed_grid_reports_wire_savings() {
        use crate::dfl::compress::CompressionKind;
        let cfg = ExperimentConfig {
            compress: CompressionKind::Quant,
            quant_bits: 8,
            ..tiny_cfg()
        };
        let cells = run_grid(&cfg, &[TopologyKind::Complete], &[&MODELS[6]], |_| {}).unwrap();
        let c = &cells[0];
        // proposed side compresses; the broadcast baseline stays full-width
        assert!(c.proposed.compression_ratio() > 3.5, "{}", c.proposed.compression_ratio());
        assert!((c.broadcast.compression_ratio() - 1.0).abs() < 1e-12);
        assert!((c.proposed.logical_mb.mean() - 48.0).abs() < 1e-9);
        let s = render_compression(&cells);
        assert!(s.contains("wire_mb"), "{s}");
        assert!(s.contains("Complete"));
        // compressed payloads must beat the uncompressed proposed round
        let plain =
            run_grid(&tiny_cfg(), &[TopologyKind::Complete], &[&MODELS[6]], |_| {}).unwrap();
        assert!(
            c.proposed.exchange.mean() < plain[0].proposed.exchange.mean(),
            "compressed exchange {} vs full-width {}",
            c.proposed.exchange.mean(),
            plain[0].proposed.exchange.mean()
        );
    }
}
