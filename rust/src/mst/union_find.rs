//! Disjoint-set forest with union-by-rank and path halving.
//!
//! Substrate for Kruskal's and Borůvka's algorithms; near-O(α(n)) per op.

#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<usize>,
    rank: Vec<u8>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind { parent: (0..n).collect(), rank: vec![0; n], components: n }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint components remaining.
    pub fn components(&self) -> usize {
        self.components
    }

    /// Find with path halving (iterative; no recursion depth concerns).
    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }

    /// Union by rank; returns true iff the two sets were disjoint.
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        match self.rank[ra].cmp(&self.rank[rb]) {
            std::cmp::Ordering::Less => self.parent[ra] = rb,
            std::cmp::Ordering::Greater => self.parent[rb] = ra,
            std::cmp::Ordering::Equal => {
                self.parent[rb] = ra;
                self.rank[ra] += 1;
            }
        }
        self.components -= 1;
        true
    }

    pub fn connected(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_state_all_separate() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.components(), 5);
        assert!(!uf.connected(0, 1));
        assert_eq!(uf.find(3), 3);
    }

    #[test]
    fn union_merges_and_counts() {
        let mut uf = UnionFind::new(4);
        assert!(uf.union(0, 1));
        assert!(uf.union(2, 3));
        assert_eq!(uf.components(), 2);
        assert!(uf.connected(0, 1));
        assert!(!uf.connected(1, 2));
        assert!(uf.union(1, 2));
        assert_eq!(uf.components(), 1);
        assert!(uf.connected(0, 3));
    }

    #[test]
    fn union_same_set_returns_false() {
        let mut uf = UnionFind::new(3);
        uf.union(0, 1);
        assert!(!uf.union(1, 0));
        assert_eq!(uf.components(), 2);
    }

    #[test]
    fn transitive_chains() {
        let mut uf = UnionFind::new(100);
        for i in 0..99 {
            uf.union(i, i + 1);
        }
        assert_eq!(uf.components(), 1);
        assert!(uf.connected(0, 99));
    }
}
