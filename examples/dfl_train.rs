//! **End-to-end driver**: decentralized federated learning with the full
//! three-layer stack composing —
//!
//! * Layer 1/2: JAX + Pallas train/eval/aggregate steps, AOT-lowered to
//!   HLO text (`make artifacts`), executed from Rust through PJRT;
//! * Layer 3: the MOSGU protocol schedules gossip over the simulated
//!   three-router testbed; real parameter vectors move between nodes and
//!   are folded pairwise into FedAvg.
//!
//! Trains 10 federated nodes on a mildly non-IID synthetic next-token
//! task and logs the loss curve + communication cost per round; the run
//! is recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example dfl_train [ROUNDS] [LOCAL_STEPS]
//! ```

use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::GossipSession;
use mosgu::dfl::round::run_dfl;
use mosgu::dfl::trainer::Trainer;
use mosgu::runtime::{artifacts_dir, ArtifactSet, Runtime};

fn main() -> anyhow::Result<()> {
    mosgu::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rounds: u64 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(40);
    let local_steps: u32 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(5);

    let rt = Runtime::cpu()?;
    let artifacts = ArtifactSet::load(&rt, &artifacts_dir())?;
    println!(
        "model: {} params ({} padded) -> {:.1} MB gossip payload; PJRT {}",
        artifacts.manifest.param_count,
        artifacts.manifest.param_dim,
        artifacts.model_mb(),
        rt.platform(),
    );

    let cfg = ExperimentConfig::default();
    let session = GossipSession::with_model(&cfg, artifacts.model_mb())?;
    println!(
        "gossip tree: {} edges over {} nodes / {} subnets; slot {:.3} s",
        session.tree().edge_count(),
        cfg.nodes,
        cfg.subnets,
        session.schedule().slot_len_s
    );

    let trainer = Trainer::new(&rt, &artifacts);
    println!("\nround  train_loss  eval_loss  comm_s  slots");
    let t0 = std::time::Instant::now();
    let reports = run_dfl(&session, &trainer, rounds, local_steps, 0.1, |r| {
        println!(
            "{:>5}  {:>10.4}  {:>9.4}  {:>6.2}  {:>5}",
            r.round, r.train_loss, r.eval_loss, r.comm_time_s, r.slots
        );
    })?;

    let first = reports.first().expect("at least one round");
    let last = reports.last().unwrap();
    let total_comm: f64 = reports.iter().map(|r| r.comm_time_s).sum();
    println!("\n== summary ==");
    println!("rounds: {rounds} x {local_steps} local steps, wall {:.1} s", t0.elapsed().as_secs_f64());
    println!("train loss: {:.4} -> {:.4}", first.train_loss, last.train_loss);
    println!("eval  loss: {:.4} -> {:.4}", first.eval_loss, last.eval_loss);
    println!("simulated communication: {total_comm:.1} s total ({:.2} s/round)", total_comm / rounds as f64);
    anyhow::ensure!(
        last.eval_loss < first.eval_loss,
        "training did not reduce eval loss — e2e regression"
    );
    println!("OK: loss decreased through gossip + aggregation across all layers");
    Ok(())
}
