//! Integration tests over the PJRT runtime + DFL layer. These require the
//! AOT artifacts (`make artifacts`); they are skipped with a notice when
//! artifacts are absent so `cargo test` works on a fresh checkout.

use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::GossipSession;
use mosgu::dfl::round::{models_agree, run_dfl};
use mosgu::dfl::trainer::Trainer;
use mosgu::runtime::{artifacts_dir, ArtifactSet, Runtime};

fn load() -> Option<(Runtime, ArtifactSet)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts in {dir:?} (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let artifacts = ArtifactSet::load(&rt, &dir).expect("artifact load");
    Some((rt, artifacts))
}

#[test]
fn artifacts_load_and_manifest_consistent() {
    let Some((_rt, artifacts)) = load() else { return };
    let m = &artifacts.manifest;
    assert!(m.param_dim >= m.param_count);
    assert_eq!(m.param_dim % m.pad_multiple, 0);
    assert_eq!(artifacts.init_params.len(), m.param_dim);
    assert!(artifacts.model_mb() > 0.5);
}

#[test]
fn train_step_reduces_loss_from_rust() {
    let Some((rt, artifacts)) = load() else { return };
    let trainer = Trainer::new(&rt, &artifacts);
    let mut model = trainer.init_node(0, 0.0);
    let first = trainer.train_step(&mut model, 0, 0.1).unwrap();
    let mut last = first;
    for step in 1..10 {
        last = trainer.train_step(&mut model, step % 3, 0.1).unwrap();
    }
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first, "loss did not fall: {first} -> {last}");
}

#[test]
fn aggregate_artifact_matches_fedavg_semantics() {
    let Some((rt, artifacts)) = load() else { return };
    let trainer = Trainer::new(&rt, &artifacts);
    let a = trainer.init_node(0, 0.05);
    let b = trainer.init_node(1, 0.05);
    // fold b into a with equal weights => elementwise mean
    let mut acc = a.clone();
    trainer.aggregate_into(&mut acc, &b.params, 1.0).unwrap();
    assert_eq!(acc.weight, 2.0);
    for i in (0..acc.params.len()).step_by(10007) {
        let want = (a.params[i] + b.params[i]) / 2.0;
        assert!(
            (acc.params[i] - want).abs() < 1e-5,
            "idx {i}: {} vs {want}",
            acc.params[i]
        );
    }
}

#[test]
fn aggregating_identical_models_is_identity() {
    let Some((rt, artifacts)) = load() else { return };
    let trainer = Trainer::new(&rt, &artifacts);
    let a = trainer.init_node(0, 0.0);
    let mut acc = a.clone();
    trainer.aggregate_into(&mut acc, &a.params, 1.0).unwrap();
    for i in (0..acc.params.len()).step_by(9973) {
        assert!((acc.params[i] - a.params[i]).abs() < 1e-6);
    }
}

#[test]
fn eval_step_is_deterministic() {
    let Some((rt, artifacts)) = load() else { return };
    let trainer = Trainer::new(&rt, &artifacts);
    let model = trainer.init_node(2, 0.01);
    let l1 = trainer.eval(&model, 42).unwrap();
    let l2 = trainer.eval(&model, 42).unwrap();
    assert_eq!(l1, l2);
    assert!(l1.is_finite() && l1 > 0.0);
}

#[test]
fn two_dfl_rounds_compose_and_reach_consensus_losses() {
    let Some((rt, artifacts)) = load() else { return };
    let cfg = ExperimentConfig { latency_jitter: 0.0, ..Default::default() };
    let session = GossipSession::with_model(&cfg, artifacts.model_mb()).unwrap();
    let trainer = Trainer::new(&rt, &artifacts);
    let reports = run_dfl(&session, &trainer, 2, 2, 0.1, |_| {}).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert!(r.train_loss.is_finite());
        assert!(r.eval_loss.is_finite());
        assert!(r.comm_time_s > 0.0);
        assert!(r.slots > 10, "full dissemination takes many slots");
    }
}

#[test]
fn full_dissemination_plus_fedavg_reaches_identical_models() {
    // after one round every node folded the same 10 models (possibly in a
    // different order); pairwise weighted averaging is order-insensitive
    // up to f32 rounding, so models must agree to small tolerance
    let Some((rt, artifacts)) = load() else { return };
    let trainer = Trainer::new(&rt, &artifacts);
    let n = 4;
    let originals: Vec<_> = (0..n).map(|u| trainer.init_node(u, 0.05)).collect();
    let mut folded = Vec::new();
    for u in 0..n {
        // node u folds everyone else's model in a rotated order
        let mut acc = originals[u].clone();
        acc.weight = 1.0;
        for k in 1..n {
            let peer = (u + k) % n;
            trainer.aggregate_into(&mut acc, &originals[peer].params, 1.0).unwrap();
        }
        folded.push(acc);
    }
    assert!(models_agree(&folded, 1e-4), "fold order changed FedAvg result");
}
