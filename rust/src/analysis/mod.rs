//! Static verification plane: simulation-free checks over plan artifacts.
//!
//! The coordinator publishes rich artifacts — spanning trees, slot
//! colorings, forest lanes, striped transfer plans, participation
//! masks — and the engine, the netsim, and every paper claim *assume*
//! they are well formed. This module verifies those assumptions
//! statically, without running a simulator: see [`plan_lint`] for the
//! linter itself, the `lint-plan` CLI subcommand for the operator
//! surface, and the `debug_assertions` hooks inside
//! [`crate::coordinator::moderator`] and
//! [`crate::coordinator::hierarchy`] that re-lint every plan and replan
//! the moderator ever publishes during debug test runs.
//!
//! The concurrency half of the plane lives elsewhere by necessity:
//! [`crate::netsim::pool`] is model-checked under loom (build with
//! `--features loom`, see `tests/loom_pool.rs`), and CI runs Miri and
//! ThreadSanitizer over the pointer-heavy netsim/transport subsets.

pub mod plan_lint;

pub use plan_lint::{lint_bundle, lint_epoch, LintContext, LintReport, PlanLinter, Violation};
