"""Layer-2 correctness: shapes, flatten/unflatten round-trip, training
signal, Pallas-vs-reference forward equivalence, and aggregation semantics
at the model level."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.model import ModelConfig


@pytest.fixture(scope="module")
def cfg():
    return ModelConfig()


@pytest.fixture(scope="module")
def small_cfg():
    # tiny variant for fast gradient checks (still tile-aligned)
    return ModelConfig(d_model=128, d_ff=128, n_layers=1, seq_len=16)


class TestParams:
    def test_param_count_is_v2_class(self, cfg):
        # paper's model range: 2.9M - 12M parameters... our default sits at
        # the small end by design (CPU training); must be < padded dim
        n = M.param_count(cfg)
        assert 400_000 < n < 13_000_000
        assert M.padded_dim(cfg) % cfg.pad_multiple == 0
        assert M.padded_dim(cfg) >= n

    def test_flatten_roundtrip(self, cfg):
        params = M.init_params(cfg, seed=3)
        flat = M.flatten_params(cfg, params)
        back = M.unflatten_params(cfg, flat)
        for name in M.param_shapes(cfg):
            np.testing.assert_array_equal(np.asarray(params[name]), np.asarray(back[name]),
                                          err_msg=name)

    def test_init_deterministic(self, cfg):
        a = M.flatten_params(cfg, M.init_params(cfg, seed=1))
        b = M.flatten_params(cfg, M.init_params(cfg, seed=1))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = M.flatten_params(cfg, M.init_params(cfg, seed=2))
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_padding_is_zero(self, cfg):
        flat = M.flatten_params(cfg, M.init_params(cfg, seed=0))
        tail = np.asarray(flat[M.param_count(cfg):])
        np.testing.assert_array_equal(tail, np.zeros_like(tail))


class TestForward:
    def test_logits_shape(self, small_cfg):
        params = M.init_params(small_cfg, 0)
        x, _ = M.synth_batch(small_cfg, 0, 8)
        logits = M.forward(small_cfg, params, x)
        assert logits.shape == (8, small_cfg.seq_len, small_cfg.vocab)

    def test_pallas_and_ref_forward_agree(self, small_cfg):
        ref_cfg = dataclasses.replace(small_cfg, use_pallas=False)
        params = M.init_params(small_cfg, 0)
        x, y = M.synth_batch(small_cfg, 0, 8)
        lp = M.loss_fn(small_cfg, params, x, y)
        lr_ = M.loss_fn(ref_cfg, params, x, y)
        np.testing.assert_allclose(float(lp), float(lr_), rtol=1e-5)

    def test_initial_loss_near_uniform(self, small_cfg):
        params = M.init_params(small_cfg, 0)
        x, y = M.synth_batch(small_cfg, 0, 8)
        loss = float(M.loss_fn(small_cfg, params, x, y))
        assert abs(loss - np.log(small_cfg.vocab)) < 1.0, loss

    def test_causality(self, small_cfg):
        """Changing a future token must not affect earlier logits."""
        params = M.init_params(small_cfg, 0)
        x, _ = M.synth_batch(small_cfg, 0, 2)
        logits_a = M.forward(small_cfg, params, x)
        x2 = x.at[:, -1].set((x[:, -1] + 7) % small_cfg.vocab)
        logits_b = M.forward(small_cfg, params, x2)
        np.testing.assert_allclose(np.asarray(logits_a[:, :-1]),
                                   np.asarray(logits_b[:, :-1]), atol=1e-5)


class TestTraining:
    def test_loss_decreases(self, small_cfg):
        flat = M.flatten_params(small_cfg, M.init_params(small_cfg, 0))
        x, y = M.synth_batch(small_cfg, 0, 8)
        first = None
        for step in range(30):
            flat, loss = M.train_step(small_cfg, flat, x, y, jnp.float32(0.1))
            if first is None:
                first = float(loss)
        assert float(loss) < 0.5 * first, f"{first} -> {float(loss)}"

    def test_train_step_preserves_padding(self, small_cfg):
        flat = M.flatten_params(small_cfg, M.init_params(small_cfg, 0))
        x, y = M.synth_batch(small_cfg, 0, 8)
        new_flat, _ = M.train_step(small_cfg, flat, x, y, jnp.float32(0.1))
        tail = np.asarray(new_flat[M.param_count(small_cfg):])
        np.testing.assert_array_equal(tail, np.zeros_like(tail))

    def test_eval_step_matches_loss(self, small_cfg):
        flat = M.flatten_params(small_cfg, M.init_params(small_cfg, 0))
        x, y = M.synth_batch(small_cfg, 1, 8)
        le = float(M.eval_step(small_cfg, flat, x, y))
        lf = float(M.loss_fn(small_cfg, M.unflatten_params(small_cfg, flat), x, y))
        np.testing.assert_allclose(le, lf, rtol=1e-6)

    def test_zero_lr_keeps_params(self, small_cfg):
        flat = M.flatten_params(small_cfg, M.init_params(small_cfg, 0))
        x, y = M.synth_batch(small_cfg, 0, 8)
        new_flat, _ = M.train_step(small_cfg, flat, x, y, jnp.float32(0.0))
        np.testing.assert_allclose(np.asarray(new_flat), np.asarray(flat), atol=1e-7)


class TestAggregateAtModelLevel:
    def test_aggregating_identical_models_is_identity(self, small_cfg):
        flat = M.flatten_params(small_cfg, M.init_params(small_cfg, 0))
        out, w = M.aggregate_pair(flat, jnp.float32(1.0), flat, jnp.float32(1.0))
        np.testing.assert_allclose(np.asarray(out), np.asarray(flat), rtol=1e-6)
        assert float(w) == 2.0

    def test_aggregated_model_still_evaluates(self, small_cfg):
        fa = M.flatten_params(small_cfg, M.init_params(small_cfg, 1))
        fb = M.flatten_params(small_cfg, M.init_params(small_cfg, 2))
        out, _ = M.aggregate_pair(fa, jnp.float32(1.0), fb, jnp.float32(1.0))
        x, y = M.synth_batch(small_cfg, 0, 4)
        loss = float(M.eval_step(small_cfg, out, x, y))
        assert np.isfinite(loss)


class TestSynthData:
    def test_targets_are_shifted_inputs(self, small_cfg):
        x, y = M.synth_batch(small_cfg, 0, 4)
        np.testing.assert_array_equal(np.asarray(x[:, 1:]), np.asarray(y[:, :-1]))

    def test_non_iid_across_nodes(self, small_cfg):
        xa, _ = M.synth_batch(small_cfg, 0, 4, node=0)
        xb, _ = M.synth_batch(small_cfg, 0, 4, node=1)
        assert not np.array_equal(np.asarray(xa), np.asarray(xb))

    def test_deterministic_per_seed(self, small_cfg):
        xa, ya = M.synth_batch(small_cfg, 5, 4, node=2)
        xb, yb = M.synth_batch(small_cfg, 5, 4, node=2)
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
