//! Cost adjacency matrix (paper §III-A, Fig 1).
//!
//! The moderator receives per-node connectivity reports where each node
//! estimates its cost to every neighbor. The two directed estimates of one
//! edge may disagree slightly; the paper specifies the moderator stores the
//! *average* of the two. `CostMatrix` implements exactly that aggregation
//! and converts to/from [`Graph`].

use super::{Graph, NodeId};

/// Symmetric cost matrix; `None` = no direct connection.
#[derive(Debug, Clone, PartialEq)]
pub struct CostMatrix {
    n: usize,
    cost: Vec<Option<f64>>, // row-major n×n
}

impl CostMatrix {
    pub fn new(n: usize) -> Self {
        CostMatrix { n, cost: vec![None; n * n] }
    }

    pub fn node_count(&self) -> usize {
        self.n
    }

    #[inline]
    fn idx(&self, u: NodeId, v: NodeId) -> usize {
        assert!(u < self.n && v < self.n, "({u},{v}) out of range n={}", self.n);
        u * self.n + v
    }

    /// Set the symmetric cost of edge (u,v).
    pub fn set(&mut self, u: NodeId, v: NodeId, cost: f64) {
        assert!(u != v, "no self-edges");
        let (i, j) = (self.idx(u, v), self.idx(v, u));
        self.cost[i] = Some(cost);
        self.cost[j] = Some(cost);
    }

    pub fn get(&self, u: NodeId, v: NodeId) -> Option<f64> {
        self.cost[self.idx(u, v)]
    }

    /// Build the matrix from directed per-node reports, averaging the two
    /// estimates of each edge as the paper's moderator does (§III-A).
    /// A one-sided report (only u measured v) is taken at face value.
    pub fn from_reports(n: usize, reports: &[(NodeId, NodeId, f64)]) -> Self {
        let mut first: Vec<Option<f64>> = vec![None; n * n];
        for &(u, v, c) in reports {
            assert!(u < n && v < n && u != v, "bad report ({u},{v})");
            first[u * n + v] = Some(c);
        }
        let mut m = CostMatrix::new(n);
        for u in 0..n {
            for v in (u + 1)..n {
                match (first[u * n + v], first[v * n + u]) {
                    (Some(a), Some(b)) => m.set(u, v, (a + b) / 2.0),
                    (Some(a), None) | (None, Some(a)) => m.set(u, v, a),
                    (None, None) => {}
                }
            }
        }
        m
    }

    /// Lower into the adjacency-list representation.
    pub fn to_graph(&self) -> Graph {
        let mut g = Graph::new(self.n);
        for u in 0..self.n {
            for v in (u + 1)..self.n {
                if let Some(c) = self.get(u, v) {
                    g.add_edge(u, v, c);
                }
            }
        }
        g
    }

    /// Lift a graph into matrix form.
    pub fn from_graph(g: &Graph) -> Self {
        let mut m = CostMatrix::new(g.node_count());
        for e in g.edges() {
            m.set(e.u, e.v, e.weight);
        }
        m
    }

    /// Render like the paper's Fig 1 (".": no edge, numbers: cost).
    pub fn render(&self, labels: &[String]) -> String {
        assert_eq!(labels.len(), self.n);
        let mut out = String::new();
        out.push_str("      ");
        for l in labels {
            out.push_str(&format!("{l:>6}"));
        }
        out.push('\n');
        for u in 0..self.n {
            out.push_str(&format!("{:>6}", labels[u]));
            for v in 0..self.n {
                match if u == v { None } else { self.get(u, v) } {
                    Some(c) => out.push_str(&format!("{c:>6.1}")),
                    None => out.push_str(&format!("{:>6}", ".")),
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_symmetric() {
        let mut m = CostMatrix::new(3);
        m.set(0, 2, 4.5);
        assert_eq!(m.get(0, 2), Some(4.5));
        assert_eq!(m.get(2, 0), Some(4.5));
        assert_eq!(m.get(0, 1), None);
    }

    #[test]
    fn reports_are_averaged() {
        // u measures 10ms, v measures 12ms -> moderator stores 11ms (§III-A)
        let m = CostMatrix::from_reports(2, &[(0, 1, 10.0), (1, 0, 12.0)]);
        assert_eq!(m.get(0, 1), Some(11.0));
    }

    #[test]
    fn one_sided_report_taken_as_is() {
        let m = CostMatrix::from_reports(3, &[(0, 1, 7.0)]);
        assert_eq!(m.get(0, 1), Some(7.0));
        assert_eq!(m.get(1, 2), None);
    }

    #[test]
    fn graph_roundtrip() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(2, 3, 3.5);
        let m = CostMatrix::from_graph(&g);
        let g2 = m.to_graph();
        assert_eq!(g2.edge_count(), 3);
        assert_eq!(g2.weight(2, 3), Some(3.5));
        assert_eq!(CostMatrix::from_graph(&g2), m);
    }

    #[test]
    fn render_contains_costs_and_dots() {
        let mut m = CostMatrix::new(2);
        m.set(0, 1, 3.0);
        let s = m.render(&["A".into(), "B".into()]);
        assert!(s.contains("3.0"));
        assert!(s.contains('.'));
        assert!(s.contains('A') && s.contains('B'));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_get_panics() {
        CostMatrix::new(2).get(0, 5);
    }
}
