//! Minimal `log` facade backend (no `env_logger` offline).
//!
//! Writes `LEVEL target: message` lines to stderr, with the max level taken
//! from `MOSGU_LOG` (error|warn|info|debug|trace; default info).

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::io::Write;
use std::sync::Once;

struct StderrLogger {
    level: LevelFilter,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "{tag} {}: {}", record.target(), record.args());
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

static INIT: Once = Once::new();

/// Parse a level name; `None` on unknown input.
fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" | "warning" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger once; later calls are no-ops. Safe to call from tests,
/// examples and the CLI alike.
pub fn init() {
    INIT.call_once(|| {
        let level = std::env::var("MOSGU_LOG")
            .ok()
            .and_then(|s| parse_level(&s))
            .unwrap_or(LevelFilter::Info);
        let logger = Box::leak(Box::new(StderrLogger { level }));
        if log::set_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_known_names() {
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("WARN"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("warning"), Some(LevelFilter::Warn));
        assert_eq!(parse_level(" trace "), Some(LevelFilter::Trace));
        assert_eq!(parse_level("off"), Some(LevelFilter::Off));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init(); // must not panic on double-install
        log::info!("logger smoke line");
    }
}
