//! Micro-benchmark harness (criterion is not available offline): warmup +
//! timed iterations with mean/σ/min reporting, plus the shared
//! paper-table grid runner used by `cargo bench` targets and the CLI.

pub mod tables;

use crate::util::stats::Summary;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s
    }

    pub fn report(&self) -> String {
        let scale = |s: f64| {
            if s < 1e-6 {
                format!("{:8.1} ns", s * 1e9)
            } else if s < 1e-3 {
                format!("{:8.2} µs", s * 1e6)
            } else if s < 1.0 {
                format!("{:8.2} ms", s * 1e3)
            } else {
                format!("{:8.3} s ", s)
            }
        };
        format!(
            "{:<44} {} ± {} (min {}, {} iters)",
            self.name,
            scale(self.mean_s),
            scale(self.std_s),
            scale(self.min_s),
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs. The closure's
/// return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        s.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        std_s: s.std_dev(),
        min_s: s.min(),
        max_s: s.max(),
    }
}

/// Print a bench-section header (keeps `cargo bench` output scannable).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleeps() {
        let r = bench("spin", 1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(r.mean_s >= 0.002, "mean {}", r.mean_s);
        assert!(r.min_s >= 0.002);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn report_formats_units() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            mean_s: 0.0021,
            std_s: 0.0001,
            min_s: 0.002,
            max_s: 0.0025,
        };
        assert!(r.report().contains("ms"));
        assert!((r.per_sec() - 476.19).abs() < 1.0);
    }
}
