//! Communication metrics — the paper's three evaluation indicators (§V):
//! bandwidth (MB/s), average single-transfer time (s), and total time for
//! one communication round (s) — plus table formatting for the CLI and
//! benches.

use crate::netsim::FlowRecord;
use crate::util::stats::Summary;

/// Timing of one schedule slot as the round engine drove it: when the
/// slot's transfers started and when the last of them drained. Idle slots
/// (a color class with nothing pending) carry `copies == 0` and zero
/// duration — the engine burns no simulated time on them. This is the
/// overlap accounting the multi-round pipeline is measured with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlotTiming {
    /// Slot index within the round (or pipeline).
    pub slot: usize,
    /// Transmitting color class of the slot.
    pub color: usize,
    /// Simulated time the slot's transfers were launched.
    pub start_s: f64,
    /// Simulated time the slot's last transfer finished draining.
    pub end_s: f64,
    /// Model copies launched in the slot (0 = idle color).
    pub copies: usize,
}

impl SlotTiming {
    /// Simulated seconds the slot occupied.
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Metrics of one measured communication round.
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    /// Every completed model transfer in the round.
    pub transfers: Vec<FlowRecord>,
    /// Wall-clock (simulated) duration until full dissemination (every
    /// node holds every model).
    pub total_time_s: f64,
    /// Duration of the *exchange phase*: every node's own round-t update
    /// delivered to its gossip neighbors — the blocking part of one FL
    /// communication round (Table V's "total time"; dissemination of
    /// forwarded copies pipelines with the next round). For broadcast the
    /// two coincide.
    pub exchange_time_s: f64,
    /// Number of slots the schedule used (0 for broadcast).
    pub slots: usize,
    /// Per-slot timing as recorded by the round engine (empty for
    /// broadcast, which has no slot structure).
    pub slot_timings: Vec<SlotTiming>,
}

impl RoundMetrics {
    /// Mean observed per-transfer goodput — the paper's "Bandwidth (MB/s)".
    pub fn bandwidth_mbps(&self) -> f64 {
        let mut s = Summary::new();
        for t in &self.transfers {
            s.push(t.bandwidth_mbps());
        }
        s.mean()
    }

    /// Mean single-transfer duration — the paper's Table IV indicator.
    pub fn avg_transfer_s(&self) -> f64 {
        let mut s = Summary::new();
        for t in &self.transfers {
            s.push(t.duration());
        }
        s.mean()
    }

    pub fn transfer_count(&self) -> usize {
        self.transfers.len()
    }

    /// Total payload moved (MB), counting every copy.
    pub fn total_payload_mb(&self) -> f64 {
        self.transfers.iter().map(|t| t.payload_mb).sum()
    }

    /// Simulated seconds spent in slots that actually carried transfers.
    pub fn busy_time_s(&self) -> f64 {
        self.slot_timings.iter().map(|s| s.duration_s()).sum()
    }

    /// Slots that launched at least one copy (idle colors excluded).
    pub fn active_slots(&self) -> usize {
        self.slot_timings.iter().filter(|s| s.copies > 0).count()
    }
}

/// Aggregate over repeated rounds (the paper reports averaged figures).
#[derive(Debug, Clone, Default)]
pub struct RepeatedMetrics {
    pub bandwidth: Summary,
    pub transfer: Summary,
    /// full-dissemination time
    pub total: Summary,
    /// exchange-phase time (Table V's indicator)
    pub exchange: Summary,
}

impl RepeatedMetrics {
    pub fn push(&mut self, round: &RoundMetrics) {
        self.bandwidth.push(round.bandwidth_mbps());
        self.transfer.push(round.avg_transfer_s());
        self.total.push(round.total_time_s);
        self.exchange.push(round.exchange_time_s);
    }
}

/// One cell of a paper table: broadcast vs proposed for a (topology,
/// model) pair.
#[derive(Debug, Clone)]
pub struct Cell {
    pub topology: String,
    pub model: String,
    pub broadcast: RepeatedMetrics,
    pub proposed: RepeatedMetrics,
}

/// Table renderer shared by the CLI and bench harnesses: rows = topologies,
/// column groups = models, broadcast block then proposed block — mirroring
/// the layout of Tables III–V.
pub fn render_table(
    title: &str,
    topologies: &[String],
    models: &[String],
    value: impl Fn(&Cell) -> (f64, f64),
    cells: &[Cell],
) -> String {
    let find = |t: &str, m: &str| cells.iter().find(|c| c.topology == t && c.model == m);
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let width = 9;
    out.push_str(&format!("{:<17}", "topology"));
    for side in ["B", "P"] {
        for m in models {
            out.push_str(&format!("{:>width$}", format!("{side}:{m}")));
        }
    }
    out.push('\n');
    for t in topologies {
        out.push_str(&format!("{t:<17}"));
        for pick_broadcast in [true, false] {
            for m in models {
                match find(t, m) {
                    Some(cell) => {
                        let (b, p) = value(cell);
                        let v = if pick_broadcast { b } else { p };
                        out.push_str(&format!("{v:>width$.3}"));
                    }
                    None => out.push_str(&format!("{:>width$}", "-")),
                }
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::FlowRecord;

    fn rec(mb: f64, start: f64, end: f64) -> FlowRecord {
        FlowRecord { flow: 0, src: 0, dst: 1, payload_mb: mb, start, end, tag: 0 }
    }

    #[test]
    fn round_metrics_aggregates() {
        let m = RoundMetrics {
            transfers: vec![rec(10.0, 0.0, 2.0), rec(10.0, 0.0, 5.0)],
            total_time_s: 5.0,
            exchange_time_s: 5.0,
            slots: 2,
            slot_timings: vec![
                SlotTiming { slot: 0, color: 0, start_s: 0.0, end_s: 2.0, copies: 1 },
                SlotTiming { slot: 1, color: 1, start_s: 2.0, end_s: 5.0, copies: 1 },
            ],
        };
        assert!((m.bandwidth_mbps() - (5.0 + 2.0) / 2.0).abs() < 1e-12);
        assert!((m.avg_transfer_s() - 3.5).abs() < 1e-12);
        assert_eq!(m.transfer_count(), 2);
        assert!((m.total_payload_mb() - 20.0).abs() < 1e-12);
        assert!((m.busy_time_s() - 5.0).abs() < 1e-12);
        assert_eq!(m.active_slots(), 2);
    }

    #[test]
    fn slot_timing_duration_and_idle_slots() {
        let busy = SlotTiming { slot: 0, color: 1, start_s: 1.0, end_s: 3.5, copies: 4 };
        let idle = SlotTiming { slot: 1, color: 0, start_s: 3.5, end_s: 3.5, copies: 0 };
        assert!((busy.duration_s() - 2.5).abs() < 1e-12);
        assert_eq!(idle.duration_s(), 0.0);
        let m = RoundMetrics {
            transfers: vec![rec(10.0, 1.0, 3.5)],
            total_time_s: 3.5,
            exchange_time_s: 3.5,
            slots: 2,
            slot_timings: vec![busy, idle],
        };
        assert_eq!(m.active_slots(), 1);
        assert!((m.busy_time_s() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn repeated_metrics_average_rounds() {
        let mut rep = RepeatedMetrics::default();
        for total in [10.0, 20.0] {
            rep.push(&RoundMetrics {
                transfers: vec![rec(10.0, 0.0, 2.0)],
                total_time_s: total,
                exchange_time_s: total,
                slots: 1,
                slot_timings: Vec::new(),
            });
        }
        assert_eq!(rep.total.count(), 2);
        assert!((rep.total.mean() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn render_table_layout() {
        let mut cell = Cell {
            topology: "Complete".into(),
            model: "v3s".into(),
            broadcast: RepeatedMetrics::default(),
            proposed: RepeatedMetrics::default(),
        };
        cell.broadcast.push(&RoundMetrics {
            transfers: vec![rec(10.0, 0.0, 10.0)],
            total_time_s: 10.0,
            exchange_time_s: 10.0,
            slots: 0,
            slot_timings: Vec::new(),
        });
        cell.proposed.push(&RoundMetrics {
            transfers: vec![rec(10.0, 0.0, 2.0)],
            total_time_s: 3.0,
            exchange_time_s: 2.0,
            slots: 23,
            slot_timings: Vec::new(),
        });
        let s = render_table(
            "Table V",
            &["Complete".into()],
            &["v3s".into()],
            |c| (c.broadcast.total.mean(), c.proposed.total.mean()),
            &[cell],
        );
        assert!(s.contains("Table V"));
        assert!(s.contains("Complete"));
        assert!(s.contains("10.000"));
        assert!(s.contains("3.000"));
        assert!(s.contains("B:v3s") && s.contains("P:v3s"));
    }
}
