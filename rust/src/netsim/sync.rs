//! Concurrency primitives behind a cfg switch: `std::sync` in normal
//! builds, [loom](https://docs.rs/loom)'s permutation-exploring mocks
//! under `--features loom`.
//!
//! The `netsim` hot path keeps its concurrency kernel ([`super::pool`])
//! small enough to model-check exhaustively. Everything that kernel
//! synchronizes through — `Arc`, `Mutex`, `Condvar`, thread spawn/join —
//! is imported from here rather than `std` directly, so the loom build
//! swaps the entire substrate without touching the algorithm. The
//! `loom` cargo feature carries no dependency by itself; the CI `loom`
//! job adds the crate (`cargo add loom`) before building, keeping the
//! offline default build dependency-free.
//!
//! Model tests live in `tests/loom_pool.rs` and run with
//! `cargo test --release --features loom --test loom_pool`.

#[cfg(not(feature = "loom"))]
pub use std::sync::{Arc, Condvar, Mutex};
#[cfg(not(feature = "loom"))]
pub use std::thread::{spawn, JoinHandle};

#[cfg(feature = "loom")]
pub use loom::sync::{Arc, Condvar, Mutex};
#[cfg(feature = "loom")]
pub use loom::thread::{spawn, JoinHandle};
