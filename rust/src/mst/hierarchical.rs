//! Hierarchical (per-subnet) MST construction for the scale-out plane.
//!
//! The paper's moderator runs one MST over the whole overlay (§III-B).
//! At hierarchy scale the overlay decomposes: each subnet's spanning tree
//! is computed **independently** over the subnet's induced cost subgraph,
//! and the subnet trees are stitched through a **backbone MST** over the
//! gateway-gateway edges — the same divide the paper's physical testbed
//! imposes with its per-router subnetworks, and the segmented-topology
//! rationale of arXiv:1908.07782. With a single subnet the function *is*
//! the flat MST, float for float — the fallback anchor pinned by
//! `tests/engine_equivalence.rs`.

use super::{MstAlgorithm, MstError};
use crate::graph::{Graph, NodeId};

/// Per-subnet MSTs stitched by a backbone MST over gateway edges.
///
/// * `costs` — the full overlay cost graph (ping ms weights);
/// * `subnet_of[u]` — each node's subnet id (dense `0..gateways.len()`);
/// * `gateways[s]` — subnet `s`'s backbone representative.
///
/// Requirements: each subnet's induced cost subgraph is connected, and
/// `costs` carries an edge between every backbone-adjacent gateway pair
/// (the router-hierarchy generator guarantees both). Errors with
/// [`MstError::Disconnected`] otherwise.
pub fn stitched_mst(
    costs: &Graph,
    subnet_of: &[usize],
    gateways: &[NodeId],
    alg: MstAlgorithm,
) -> Result<Graph, MstError> {
    let n = costs.node_count();
    assert_eq!(subnet_of.len(), n, "subnet assignment covers every node");
    let k = gateways.len();
    assert!(k >= 1, "need at least one subnet");
    if k == 1 {
        // flat fallback: the moderator's own MST, bit for bit
        return alg.run(costs);
    }
    let mut tree = Graph::new(n);
    for s in 0..k {
        let members: Vec<NodeId> = (0..n).filter(|&u| subnet_of[u] == s).collect();
        if members.len() <= 1 {
            continue; // a singleton subnet hangs off the backbone alone
        }
        let (sub, map) = costs.induced(&members);
        let sub_tree = alg.run(&sub)?;
        for e in sub_tree.edges() {
            tree.add_edge(map[e.u], map[e.v], e.weight);
        }
    }
    // backbone MST over the measured gateway-gateway costs
    let mut quotient = Graph::new(k);
    for a in 0..k {
        for b in (a + 1)..k {
            if let Some(w) = costs.weight(gateways[a], gateways[b]) {
                quotient.add_edge(a, b, w);
            }
        }
    }
    let backbone = alg.run(&quotient)?;
    for e in backbone.edges() {
        tree.add_edge(gateways[e.u], gateways[e.v], e.weight);
    }
    if !tree.is_tree() {
        return Err(MstError::Disconnected);
    }
    Ok(tree)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{router_hierarchy, Hierarchy};
    use crate::util::rng::Pcg64;

    fn weighted(structure: &Graph, seed: u64) -> Graph {
        // distinct pseudo-random weights so MSTs are unique
        let mut rng = Pcg64::new(seed);
        let mut g = Graph::new(structure.node_count());
        for e in structure.sorted_edges() {
            g.add_edge(e.u, e.v, rng.gen_f64_range(1.0, 99.0));
        }
        g
    }

    #[test]
    fn single_subnet_is_the_flat_mst_bit_for_bit() {
        let (structure, h) = router_hierarchy(12, 1, 2, 4, &mut Pcg64::new(3));
        let costs = weighted(&structure, 7);
        let flat = MstAlgorithm::Prim.run(&costs).unwrap();
        let stitched =
            stitched_mst(&costs, h.subnet_of(), h.gateways(), MstAlgorithm::Prim).unwrap();
        assert_eq!(stitched.edge_count(), flat.edge_count());
        for e in flat.edges() {
            assert!(stitched.has_edge(e.u, e.v));
            assert_eq!(
                stitched.weight(e.u, e.v).unwrap().to_bits(),
                e.weight.to_bits(),
                "weight diverged on ({},{})",
                e.u,
                e.v
            );
        }
    }

    #[test]
    fn stitched_tree_spans_and_crosses_only_at_gateways() {
        let (structure, h) = router_hierarchy(26, 4, 2, 4, &mut Pcg64::new(5));
        let costs = weighted(&structure, 11);
        let tree =
            stitched_mst(&costs, h.subnet_of(), h.gateways(), MstAlgorithm::Kruskal).unwrap();
        assert!(tree.is_tree());
        assert_eq!(tree.node_count(), 26);
        let mut crossings = 0;
        for e in tree.edges() {
            if h.subnet(e.u) != h.subnet(e.v) {
                crossings += 1;
                assert!(h.is_gateway(e.u) && h.is_gateway(e.v));
            }
        }
        // a spanning backbone over 4 subnets has exactly 3 crossing edges
        assert_eq!(crossings, 3);
    }

    #[test]
    fn per_subnet_trees_are_subnet_msts() {
        let (structure, h) = router_hierarchy(24, 3, 2, 4, &mut Pcg64::new(8));
        let costs = weighted(&structure, 13);
        let tree = stitched_mst(&costs, h.subnet_of(), h.gateways(), MstAlgorithm::Prim).unwrap();
        for s in 0..3 {
            let members = h.members(s);
            let (sub_costs, _) = costs.induced(&members);
            let (sub_tree, _) = tree.induced(&members);
            let want = MstAlgorithm::Prim.run(&sub_costs).unwrap();
            assert!(
                (sub_tree.total_weight() - want.total_weight()).abs() < 1e-9,
                "subnet {s}: stitched part is not the subnet MST"
            );
        }
    }

    #[test]
    fn disconnected_backbone_is_an_error() {
        // two subnets but no gateway-gateway edge in the costs
        let mut costs = Graph::new(4);
        costs.add_edge(0, 2, 1.0); // subnet 0: {0, 2}
        costs.add_edge(1, 3, 1.0); // subnet 1: {1, 3}
        let h = Hierarchy::round_robin(4, 2);
        let err = stitched_mst(&costs, h.subnet_of(), h.gateways(), MstAlgorithm::Prim);
        assert!(matches!(err, Err(MstError::Disconnected)));
    }
}
