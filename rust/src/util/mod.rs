//! Utility substrates built in-repo because the offline environment carries
//! no `rand`, `env_logger`, or `proptest` crates: a PCG PRNG, statistics,
//! a `log` backend, and a seeded property-test driver.

pub mod logger;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Format a byte count as a human-readable string (MB with 1 decimal).
pub fn fmt_bytes(bytes: u64) -> String {
    const MB: f64 = 1024.0 * 1024.0;
    if bytes as f64 >= MB {
        format!("{:.1} MB", bytes as f64 / MB)
    } else if bytes >= 1024 {
        format!("{:.1} KB", bytes as f64 / 1024.0)
    } else {
        format!("{bytes} B")
    }
}

/// Format seconds with adaptive precision (matches the paper's tables).
pub fn fmt_secs(secs: f64) -> String {
    if secs < 0.01 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(10), "10 B");
        assert_eq!(fmt_bytes(2048), "2.0 KB");
        assert_eq!(fmt_bytes(21 * 1024 * 1024), "21.0 MB");
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(0.001), "1.00 ms");
        assert_eq!(fmt_secs(2.5), "2.500 s");
    }
}
