//! Max-min fair bandwidth allocation (progressive filling / water-filling).
//!
//! The fluid-flow model at the heart of the network simulator: every active
//! flow traverses a set of directed channels; each channel has a capacity;
//! rates are the unique max-min fair allocation.
//!
//! This is the *full* O(channels × flows) pass. The event loop no longer
//! calls it per event — `NetSim` re-water-fills only the dirty connected
//! component with allocation-free scratch (§Perf/L5) — but this function
//! remains the ground truth: max-min components are arithmetically
//! independent, so the restricted pass is bit-identical to this one, and
//! `tests/netsim_rerate.rs` pins the two against each other (enable the
//! full pass per event with `NetSim::set_full_rerate`).

/// Compute max-min fair rates.
///
/// * `capacity[c]` — capacity of channel `c` (MB/s).
/// * `routes[f]` — channel indices flow `f` traverses (must be non-empty).
///
/// Returns the rate of each flow.
pub fn max_min_rates<R: AsRef<[usize]>>(capacity: &[f64], routes: &[R]) -> Vec<f64> {
    let nf = routes.len();
    let nc = capacity.len();
    let mut rate = vec![0.0f64; nf];
    if nf == 0 {
        return rate;
    }
    let mut remaining: Vec<f64> = capacity.to_vec();
    let mut frozen = vec![false; nf];
    // flows per channel (only unfrozen count toward shares)
    let mut users: Vec<Vec<usize>> = vec![Vec::new(); nc];
    for (f, route) in routes.iter().enumerate() {
        let route = route.as_ref();
        assert!(!route.is_empty(), "flow {f} has empty route");
        for &c in route {
            users[c].push(f);
        }
    }
    let mut unfrozen_count: Vec<usize> = users.iter().map(|u| u.len()).collect();
    let mut left = nf;

    while left > 0 {
        // bottleneck channel: minimal fair share among channels in use
        let mut best_share = f64::INFINITY;
        let mut best_chan = usize::MAX;
        for c in 0..nc {
            if unfrozen_count[c] == 0 {
                continue;
            }
            let share = remaining[c] / unfrozen_count[c] as f64;
            if share < best_share {
                best_share = share;
                best_chan = c;
            }
        }
        if best_chan == usize::MAX {
            // remaining flows traverse only unused channels — cannot happen
            // because every unfrozen flow keeps its channels' counts > 0
            unreachable!("unfrozen flows with no channel");
        }
        // freeze every unfrozen flow through the bottleneck at best_share
        // (a flow may appear twice if its route crosses the channel twice)
        let to_freeze: Vec<usize> =
            users[best_chan].iter().copied().filter(|&f| !frozen[f]).collect();
        for f in to_freeze {
            if frozen[f] {
                continue; // duplicate occurrence already handled
            }
            frozen[f] = true;
            rate[f] = best_share;
            left -= 1;
            for &c in routes[f].as_ref() {
                remaining[c] -= best_share;
                unfrozen_count[c] -= 1;
            }
        }
        // guard against fp drift
        for r in remaining.iter_mut() {
            if *r < 0.0 {
                *r = 0.0;
            }
        }
    }
    rate
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-9
    }

    #[test]
    fn single_flow_gets_full_capacity() {
        let rates = max_min_rates(&[10.0], &[vec![0]]);
        assert!(close(rates[0], 10.0));
    }

    #[test]
    fn equal_flows_share_equally() {
        let rates = max_min_rates(&[12.0], &[vec![0], vec![0], vec![0]]);
        for r in rates {
            assert!(close(r, 4.0));
        }
    }

    #[test]
    fn classic_three_link_example() {
        // textbook max-min: flows A(link0,1), B(link0), C(link1)
        // caps: link0=10, link1=4 -> bottleneck link1 share 2 for A and C,
        // then B gets 10-2=8.
        let rates = max_min_rates(&[10.0, 4.0], &[vec![0, 1], vec![0], vec![1]]);
        assert!(close(rates[0], 2.0), "A {}", rates[0]);
        assert!(close(rates[1], 8.0), "B {}", rates[1]);
        assert!(close(rates[2], 2.0), "C {}", rates[2]);
    }

    #[test]
    fn disjoint_flows_independent() {
        let rates = max_min_rates(&[5.0, 7.0], &[vec![0], vec![1]]);
        assert!(close(rates[0], 5.0));
        assert!(close(rates[1], 7.0));
    }

    #[test]
    fn multi_hop_bottlenecked_by_thinnest() {
        let rates = max_min_rates(&[100.0, 1.0, 50.0], &[vec![0, 1, 2]]);
        assert!(close(rates[0], 1.0));
    }

    #[test]
    fn no_channel_oversubscribed() {
        // random-ish scenario; verify feasibility: sum of rates per channel <= cap
        let caps = [10.0, 6.0, 8.0, 3.0];
        let routes = vec![
            vec![0, 1],
            vec![1, 2],
            vec![0, 2, 3],
            vec![3],
            vec![0],
            vec![2],
        ];
        let rates = max_min_rates(&caps, &routes);
        for (c, &cap) in caps.iter().enumerate() {
            let load: f64 = routes
                .iter()
                .enumerate()
                .filter(|(_, r)| r.contains(&c))
                .map(|(f, _)| rates[f])
                .sum();
            assert!(load <= cap + 1e-6, "channel {c} overloaded: {load} > {cap}");
        }
        // every flow gets strictly positive rate
        assert!(rates.iter().all(|&r| r > 0.0));
    }

    #[test]
    fn maxmin_is_pareto_on_bottleneck() {
        // two flows share a channel; one also uses a private fat channel —
        // must not steal from the shared bottleneck
        let rates = max_min_rates(&[4.0, 100.0], &[vec![0, 1], vec![0]]);
        assert!(close(rates[0], 2.0));
        assert!(close(rates[1], 2.0));
    }

    #[test]
    fn empty_input() {
        assert!(max_min_rates::<Vec<usize>>(&[5.0], &[]).is_empty());
    }
}
