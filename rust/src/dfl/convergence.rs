//! Convergence harness for the learning-dynamics scenario zoo: a
//! CPU-only quadratic learner over the synthetic stride classes, driven
//! by the real pipelined engine for timing, reception orders, partial
//! participation and straggler holds.
//!
//! Like [`chaos`](super::chaos), the harness is artifact-free so CI can
//! gate learning dynamics without PJRT: node `u`'s "data" is the class
//! mixture `share_u` that `--dirichlet-alpha` deals it, its local
//! objective is `F_u(x) = ½‖x − m_u‖²/dim` with `m_u = Σ_c share_u[c]·t_c`
//! over seeded per-class targets `t_c`, and local SGD contracts toward
//! `m_u` exactly the way the real trainer contracts toward its shard.
//! Gossip content (FedAvg fold or D-PSGD mixing, compression + error
//! feedback, participation pruning) then replays CPU-side in the
//! engine's delivery orders, so accuracy-vs-round and accuracy-vs-wire
//! curves measure the *protocol's* effect on learning, not PJRT noise.
//! `tests/learning_dynamics.rs` and `benches/convergence_sweep.rs` both
//! drive this module.

use super::compress::ErrorFeedback;
use super::data::{self, AlgoKind, STRIDE_CLASSES};
use super::round::cumulative_wire_mb;
use crate::config::ExperimentConfig;
use crate::coordinator::session::GossipSession;
use crate::util::rng::Pcg64;
use anyhow::Result;

/// Harness knobs that are not part of [`ExperimentConfig`] (the zoo
/// knobs — alpha, participation, stragglers, algo — all come from the
/// config, as do compression and fold).
#[derive(Debug, Clone, Copy)]
pub struct ConvergenceOptions {
    /// Training/gossip rounds to run.
    pub rounds: u64,
    /// Synthetic parameter-vector width.
    pub dim: usize,
    /// Logical checkpoint size driving the timing simulation, MB.
    pub model_mb: f64,
    /// Local SGD steps per round.
    pub local_steps: u32,
    /// Local learning rate in (0, 1] (a contraction factor toward the
    /// node's shard mean).
    pub lr: f64,
    /// Per-transmission disruption probability composed on top of the
    /// scenario (0 = reliable links).
    pub failure_prob: f64,
}

impl Default for ConvergenceOptions {
    fn default() -> Self {
        ConvergenceOptions {
            rounds: 5,
            dim: 16,
            model_mb: 5.0,
            local_steps: 3,
            lr: 0.5,
            failure_prob: 0.0,
        }
    }
}

/// One round of the convergence curve.
#[derive(Debug, Clone)]
pub struct ConvergenceRound {
    pub round: u64,
    /// Mean local objective across this round's participants, after
    /// their local steps (before gossip).
    pub train_loss: f64,
    /// Mean local objective across *all* nodes after aggregation — each
    /// node evaluated on its own shard (the personalization convention
    /// `dfl::round` uses).
    pub eval_loss: f64,
    /// `1 / (1 + eval_loss)` — the curve ordinate.
    pub accuracy: f64,
    /// Cumulative wire MB the pipeline had moved by this round's full
    /// dissemination — the accuracy-vs-wire abscissa.
    pub cum_wire_mb: f64,
    /// Absolute pipeline time the round fully disseminated.
    pub done_s: f64,
}

/// Full convergence-run report.
#[derive(Debug, Clone)]
pub struct ConvergenceReport {
    pub rounds: Vec<ConvergenceRound>,
    /// Which nodes trained each round (`None` = everyone, every round).
    pub participants_per_round: Option<Vec<Vec<usize>>>,
    /// The straggling nodes (empty without `--straggler-frac`).
    pub stragglers: Vec<usize>,
    /// Algorithm label (`fedavg` / `dpsgd`).
    pub algo: String,
    /// Simulated time of the whole pipelined gossip run, seconds.
    pub total_time_s: f64,
}

impl ConvergenceReport {
    pub fn final_eval_loss(&self) -> f64 {
        self.rounds.last().map_or(0.0, |r| r.eval_loss)
    }

    pub fn first_eval_loss(&self) -> f64 {
        self.rounds.first().map_or(0.0, |r| r.eval_loss)
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map_or(0.0, |r| r.accuracy)
    }

    /// Total wire MB the run moved.
    pub fn total_wire_mb(&self) -> f64 {
        self.rounds.last().map_or(0.0, |r| r.cum_wire_mb)
    }

    /// Did the run learn at all (final eval beats round-0 eval)?
    pub fn improved(&self) -> bool {
        self.rounds.len() >= 2 && self.final_eval_loss() < self.first_eval_loss()
    }
}

/// Node `u`'s shard mean `m_u = Σ_c share_u[c] · t_c` over the seeded
/// class targets.
fn shard_means(cfg: &ExperimentConfig, n: usize, dim: usize) -> Vec<Vec<f64>> {
    // per-class targets: well-separated seeded points, shared by every
    // run at this (seed, dim)
    let targets: Vec<Vec<f64>> = (0..STRIDE_CLASSES)
        .map(|c| {
            let mut rng =
                Pcg64::new(cfg.seed ^ 0x7a26 ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            (0..dim).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()
        })
        .collect();
    let shares = data::trainer_shares(cfg.dirichlet_alpha, n, STRIDE_CLASSES, cfg.seed);
    shares
        .iter()
        .map(|s| {
            let mut m = vec![0.0f64; dim];
            for (c, &w) in s.iter().enumerate() {
                for (mi, &t) in m.iter_mut().zip(&targets[c]) {
                    *mi += w * t;
                }
            }
            m
        })
        .collect()
}

/// Mean-squared local objective `½‖x − m‖²/dim`.
fn local_loss(x: &[f32], m: &[f64]) -> f64 {
    let dim = x.len().max(1);
    x.iter().zip(m).map(|(&a, &b)| (a as f64 - b) * (a as f64 - b)).sum::<f64>() / (2.0 * dim as f64)
}

/// Run the convergence harness: real engine timing + reception orders
/// (with the config's participation pruning and straggler holds baked
/// into the pipeline), synthetic quadratic learning CPU-side.
pub fn run_convergence(cfg: &ExperimentConfig, opts: &ConvergenceOptions) -> Result<ConvergenceReport> {
    anyhow::ensure!(opts.rounds >= 1, "convergence needs at least one round");
    anyhow::ensure!(opts.dim >= 1, "convergence needs a non-empty parameter vector");
    anyhow::ensure!(opts.model_mb > 0.0, "model_mb must be positive");
    anyhow::ensure!(opts.lr > 0.0 && opts.lr <= 1.0, "lr must be in (0, 1]");
    anyhow::ensure!(
        (0.0..1.0).contains(&opts.failure_prob),
        "failure_prob must be in [0, 1)"
    );
    let session = GossipSession::with_model(cfg, opts.model_mb)?;
    let n = cfg.nodes;
    let pipeline = session.run_adaptive_rounds_with_failures(
        opts.model_mb,
        opts.rounds,
        cfg.seed ^ 0xc0e7e,
        opts.failure_prob,
    );
    anyhow::ensure!(
        pipeline.received.len() == opts.rounds as usize,
        "pipeline completed {} of {} rounds",
        pipeline.received.len(),
        opts.rounds
    );
    let cum_wire = cumulative_wire_mb(&pipeline);

    let means = shard_means(cfg, n, opts.dim);
    let participation = session.participation_plan(opts.rounds);
    let originates = |round: u64, u: usize| {
        participation.as_ref().map_or(true, |p| p.originates(round, u))
    };
    let stragglers =
        session.straggler_plan().map_or_else(Vec::new, |s| s.stragglers());
    let policy = session.fold_policy();
    let codec = cfg.compression();
    let mut feedback: Vec<ErrorFeedback> = if codec.is_none() {
        Vec::new()
    } else {
        (0..n).map(|_| ErrorFeedback::new(opts.dim)).collect()
    };

    // decentralized start: per-node seeded points (the init_node shape)
    let mut params: Vec<Vec<f32>> = (0..n)
        .map(|u| {
            let mut rng = Pcg64::new(cfg.seed ^ 0xc01d ^ (u as u64).wrapping_mul(0x9E37_79B9));
            (0..opts.dim).map(|_| 0.2 * (rng.gen_f64() as f32 - 0.5)).collect()
        })
        .collect();

    let mut rounds = Vec::with_capacity(opts.rounds as usize);
    for round in 0..opts.rounds {
        // --- local training: participants contract toward their shard
        // mean (gradient of the quadratic is exactly x − m_u) ---
        let mut train_loss = 0.0f64;
        let mut trained = 0u32;
        for u in 0..n {
            if !originates(round, u) {
                continue;
            }
            for _ in 0..opts.local_steps {
                for (x, &m) in params[u].iter_mut().zip(&means[u]) {
                    *x -= (opts.lr * (*x as f64 - m)) as f32;
                }
            }
            train_loss += local_loss(&params[u], &means[u]);
            trained += 1;
        }
        train_loss /= trained.max(1) as f64;

        // --- wire snapshot: originators only; EF residuals advance only
        // for nodes that actually transmit ---
        let mut snapshot: Vec<Vec<f32>> = params
            .iter()
            .enumerate()
            .map(|(u, p)| {
                if !originates(round, u) {
                    Vec::new()
                } else if codec.is_none() {
                    p.clone()
                } else {
                    feedback[u].compress(p, &codec)
                }
            })
            .collect();
        if let Some(s) = session.adversary() {
            s.corrupt_snapshot(&mut snapshot, round, cfg.seed);
        }

        // --- aggregation in the engine's delivery orders ---
        let received = &pipeline.received[round as usize];
        let mut next: Vec<Vec<f32>> = Vec::with_capacity(n);
        for u in 0..n {
            // a transmitting node adopts its own decoded payload so the
            // candidate set is identical everywhere (consensus stays
            // exact); its residual carries the codec error forward
            let own: &[f32] = if !codec.is_none() && originates(round, u) {
                &snapshot[u]
            } else {
                &params[u]
            };
            match cfg.algo {
                AlgoKind::FedAvg => {
                    if policy.is_mean() {
                        let mut acc = own.to_vec();
                        let mut w = 1.0f32;
                        for &o in &received[u] {
                            w += 1.0;
                            for (a, &x) in acc.iter_mut().zip(&snapshot[o]) {
                                *a += (x - *a) / w;
                            }
                        }
                        next.push(acc);
                    } else {
                        let others: Vec<(usize, &[f32])> =
                            received[u].iter().map(|&o| (o, snapshot[o].as_slice())).collect();
                        next.push(policy.fold(u, own, &others));
                    }
                }
                AlgoKind::DPsgd => {
                    let tree = session.tree();
                    let peers: Vec<(usize, &[f32])> = received[u]
                        .iter()
                        .filter(|&&o| tree.neighbors(u).iter().any(|&(v, _)| v == o))
                        .map(|&o| (o, snapshot[o].as_slice()))
                        .collect();
                    next.push(data::dpsgd_mix(tree, u, own, &peers));
                }
            }
        }
        params = next;

        let eval_loss =
            (0..n).map(|u| local_loss(&params[u], &means[u])).sum::<f64>() / n as f64;
        rounds.push(ConvergenceRound {
            round,
            train_loss,
            eval_loss,
            accuracy: data::accuracy_proxy(eval_loss),
            cum_wire_mb: cum_wire[round as usize],
            done_s: pipeline.rounds[round as usize].done_s,
        });
    }

    Ok(ConvergenceReport {
        rounds,
        participants_per_round: participation
            .map(|p| (0..opts.rounds).map(|r| p.participants(r).unwrap_or(&[]).to_vec()).collect()),
        stragglers,
        algo: cfg.algo.name().to_string(),
        total_time_s: pipeline.total_time_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfl::compress::CompressionKind;

    fn quiet_cfg() -> ExperimentConfig {
        ExperimentConfig { latency_jitter: 0.0, ..Default::default() }
    }

    #[test]
    fn iid_fedavg_learns_and_the_curve_is_well_formed() {
        let report = run_convergence(&quiet_cfg(), &ConvergenceOptions::default()).unwrap();
        assert_eq!(report.rounds.len(), 5);
        assert!(report.improved(), "full participation FedAvg must reduce eval loss");
        assert!(report.stragglers.is_empty());
        assert!(report.participants_per_round.is_none());
        assert_eq!(report.algo, "fedavg");
        // curves are monotone where they must be
        let wire: Vec<f64> = report.rounds.iter().map(|r| r.cum_wire_mb).collect();
        assert!(wire.windows(2).all(|w| w[0] <= w[1]));
        assert!(wire[0] > 0.0, "a gossip round moves bytes");
        let done: Vec<f64> = report.rounds.iter().map(|r| r.done_s).collect();
        assert!(done.windows(2).all(|w| w[0] < w[1]));
        for r in &report.rounds {
            assert!((0.0..=1.0).contains(&r.accuracy));
        }
    }

    #[test]
    fn dirichlet_skew_hurts_final_consensus_eval() {
        // under FedAvg full dissemination every node ends at the global
        // mean; with skewed shards the local evals sit farther from it
        let iid = run_convergence(&quiet_cfg(), &ConvergenceOptions::default()).unwrap();
        let skewed_cfg = ExperimentConfig { dirichlet_alpha: 0.1, ..quiet_cfg() };
        let skewed = run_convergence(&skewed_cfg, &ConvergenceOptions::default()).unwrap();
        assert!(
            skewed.final_eval_loss() > iid.final_eval_loss() * 0.5,
            "severe non-IID should not beat the one-hot baseline decisively: {} vs {}",
            skewed.final_eval_loss(),
            iid.final_eval_loss()
        );
    }

    #[test]
    fn quant8_error_feedback_tracks_uncompressed() {
        let base = run_convergence(&quiet_cfg(), &ConvergenceOptions::default()).unwrap();
        let qcfg = ExperimentConfig {
            compress: CompressionKind::Quant,
            quant_bits: 8,
            ..quiet_cfg()
        };
        let quant = run_convergence(&qcfg, &ConvergenceOptions::default()).unwrap();
        let diff = (quant.final_eval_loss() - base.final_eval_loss()).abs();
        assert!(diff < 0.05, "quant-8 + EF must track uncompressed, diff {diff}");
        assert!(
            quant.total_wire_mb() < base.total_wire_mb(),
            "quantization must shrink the wire"
        );
    }

    #[test]
    fn participation_and_stragglers_flow_into_the_report() {
        let cfg = ExperimentConfig {
            participation: 0.6,
            straggler_frac: 0.2,
            straggler_slowdown: 3.0,
            ..quiet_cfg()
        };
        let report = run_convergence(&cfg, &ConvergenceOptions::default()).unwrap();
        let per_round = report.participants_per_round.as_ref().unwrap();
        assert_eq!(per_round.len(), 5);
        for set in per_round {
            assert_eq!(set.len(), 6, "ceil(0.6 * 10) participants per round");
        }
        assert_eq!(report.stragglers.len(), 2, "ceil(0.2 * 10) stragglers");
        assert!(report.improved(), "partial participation still learns");
    }

    #[test]
    fn dpsgd_mixes_toward_consensus() {
        let cfg = ExperimentConfig { algo: AlgoKind::DPsgd, ..quiet_cfg() };
        let opts = ConvergenceOptions { rounds: 8, ..Default::default() };
        let report = run_convergence(&cfg, &opts).unwrap();
        assert_eq!(report.algo, "dpsgd");
        assert!(report.improved(), "neighbor mixing must still learn");
    }

    #[test]
    fn run_convergence_rejects_bad_options() {
        let cfg = quiet_cfg();
        let bad = |o: ConvergenceOptions| run_convergence(&cfg, &o).is_err();
        assert!(bad(ConvergenceOptions { rounds: 0, ..Default::default() }));
        assert!(bad(ConvergenceOptions { dim: 0, ..Default::default() }));
        assert!(bad(ConvergenceOptions { lr: 0.0, ..Default::default() }));
        assert!(bad(ConvergenceOptions { failure_prob: 1.0, ..Default::default() }));
    }
}
