//! End-to-end experiment session: topology → testbed → moderator →
//! timed MOSGU rounds through the event-driven round engine (and the
//! broadcast baseline), producing the paper's Tables III–V metrics.

use super::broadcast::{self, BroadcastMode};
use super::engine::driver::SimDriver;
use super::engine::sharded::{self, ShardedRoundOptions};
use super::engine::{
    PipelineMetrics, PipelineOptions, PlanEpoch, RoundEngine, RoundOptions, TreeLane,
};
use super::gossip::GossipState;
use super::hierarchy::plan_hierarchical_forest;
use super::moderator::{Moderator, ScheduleBundle};
use super::probe::{ReplanPolicy, Replanner};
use super::schedule::Schedule;
use crate::config::ExperimentConfig;
use crate::dfl::adversary::{AdversaryScenario, DropPlan};
use crate::dfl::data::{ParticipationPlan, StragglerPlan};
use crate::dfl::robust::FoldPolicy;
use crate::dfl::transfer::TransferPlan;
use crate::graph::generators::{self, Hierarchy};
use crate::graph::topology::TopologyKind;
use crate::graph::Graph;
use crate::metrics::RoundMetrics;
use crate::netsim::shard::ShardedNetSim;
use crate::netsim::testbed::Testbed;
use crate::netsim::DriftProcess;
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};
use std::rc::Rc;

/// A fully prepared experiment: structural overlay, simulated testbed, and
/// the moderator's published schedule bundle.
pub struct GossipSession {
    cfg: ExperimentConfig,
    testbed: Testbed,
    structure: Graph,
    costs: Graph,
    /// The moderator's noise-averaged connectivity matrix as a graph —
    /// the costs `bundle.tree` is actually an MST of (the report noise
    /// makes it differ slightly from `costs`). Online re-planning must
    /// baseline against this, not the clean pings, so the incremental
    /// MST update's precondition holds.
    measured_costs: Graph,
    /// Subnet structure when the overlay came from the router-hierarchy
    /// generator (`topology_gen = "hierarchy"`); `None` for flat overlays.
    hierarchy: Option<Hierarchy>,
    bundle: ScheduleBundle,
    /// The checkpoint size (MB) the session was planned for.
    model_mb: f64,
    /// The §III-C transfer unit (MB) the published slot budget covers —
    /// the whole checkpoint at `segments = 1`, one segment otherwise.
    unit_mb: f64,
    /// The robustness plane's Byzantine scenario (`--adversary`): which
    /// nodes are compromised and how they misbehave. `None` with
    /// `adversary = none` — every honest path stays bit-identical.
    adversary: Option<AdversaryScenario>,
}

impl GossipSession {
    /// Build the session: generate the underlay topology, stand up the
    /// testbed, run the paper's M-step (each node reports jittered pings to
    /// its neighbors; the moderator averages, prunes to the MST, colors and
    /// schedules).
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        Self::with_model(cfg, 14.0)
    }

    /// As [`GossipSession::new`] with an explicit model size (MB) for the
    /// slot-length computation. The published slot budget covers one
    /// **transfer unit** of the config's plan (the whole checkpoint at
    /// `segments = 1`, one segment otherwise — see `schedule`).
    pub fn with_model(cfg: &ExperimentConfig, model_mb: f64) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow::anyhow!("invalid config: {e}"))?;
        let mut rng = Pcg64::new(cfg.seed);
        let (structure, hierarchy) = generators::generate_structure(
            cfg.topology_gen,
            cfg.topology,
            cfg.nodes,
            cfg.subnets,
            cfg.gateway_links,
            &cfg.topology_params,
            &mut rng,
        );
        let testbed = Testbed::new(cfg);
        let costs = testbed.overlay_costs(&structure);

        // M-step: directed per-node reports with measurement noise; the
        // moderator averages the two sides (§III-A).
        let mut moderator = Moderator::new(0, cfg.nodes, cfg.mst, cfg.coloring);
        let mut noise = rng.fork(0x4d0d);
        for u in 0..cfg.nodes {
            let peers: Vec<(usize, f64)> = costs
                .neighbors(u)
                .iter()
                .map(|&(v, w)| (v, w * (1.0 + noise.gen_f64_range(-0.02, 0.02))))
                .collect();
            moderator.submit_report(u, &peers);
        }
        // multi-tree dissemination (`--trees k`): the moderator carves up
        // to k-1 extra edge-disjoint lanes; k = 1 is the paper's planner
        moderator.set_trees(cfg.trees);
        let unit_mb = cfg.transfer_plan(model_mb).segment_mb();
        // hierarchical overlays plan per subnet + backbone; a single
        // subnet is bit-identical to the flat planner, and flat overlays
        // take the flat path untouched
        let bundle = match hierarchy.as_ref().filter(|h| h.subnet_count() > 1) {
            Some(h) => moderator
                .compute_schedule_hierarchical(h, unit_mb, cfg.ping_size_bytes, 1)
                .context("moderator hierarchical schedule computation")?
                .clone(),
            None => moderator
                .compute_schedule(unit_mb, cfg.ping_size_bytes, 1)
                .context("moderator schedule computation")?
                .clone(),
        };
        let measured_costs =
            moderator.matrix().expect("matrix exists after compute_schedule").to_graph();
        // Byzantine scenario: seeded off the experiment seed so the same
        // config always compromises the same nodes (and, for a dropping
        // relay, the same tree edges)
        let adversary = AdversaryScenario::plan(&cfg.adversary_config(), &bundle.tree, cfg.seed);
        Ok(GossipSession {
            cfg: cfg.clone(),
            testbed,
            structure,
            costs,
            measured_costs,
            hierarchy,
            bundle,
            model_mb,
            unit_mb,
            adversary,
        })
    }

    /// Statically lint the session's published plan artifacts: every
    /// dissemination lane (spanning, coloring properness, half-duplex
    /// conflict freedom, slot budget vs the §III-C formula over the
    /// measured costs), cross-lane edge-disjointness, the neighbor
    /// table, stripe byte conservation against the config's
    /// [`TransferPlan`], and — when `--participation < 1` — the
    /// participation/origination masks over `rounds` rounds. Pure and
    /// simulation-free; the `lint-plan` CLI subcommand prints the
    /// resulting report.
    pub fn lint_report(&self, rounds: u64) -> crate::analysis::LintReport {
        let ctx = crate::analysis::LintContext {
            costs: &self.measured_costs,
            unit_mb: self.unit_mb,
            ping_size_bytes: self.cfg.ping_size_bytes,
        };
        let mut linter = crate::analysis::PlanLinter::new(ctx);
        linter.check_bundle(&self.bundle);
        let plan = self.cfg.transfer_plan(self.model_mb);
        let lanes = 1 + self.bundle.extra.len();
        let striped = vec![plan.stripe(lanes); lanes];
        linter.check_stripes(&plan, &striped);
        if let Some(participation) = self.participation_plan(rounds) {
            linter.check_participation(&participation, self.bundle.tree.node_count(), rounds);
        }
        linter.finish()
    }

    pub fn testbed(&self) -> &Testbed {
        &self.testbed
    }

    pub fn structure(&self) -> &Graph {
        &self.structure
    }

    pub fn costs(&self) -> &Graph {
        &self.costs
    }

    /// The moderator's noise-averaged cost matrix (what the published
    /// tree/schedule were computed from; the adaptive plane's baseline).
    pub fn measured_costs(&self) -> &Graph {
        &self.measured_costs
    }

    /// The overlay's subnet structure, when it came from the
    /// router-hierarchy generator.
    pub fn hierarchy(&self) -> Option<&Hierarchy> {
        self.hierarchy.as_ref()
    }

    pub fn tree(&self) -> &Graph {
        &self.bundle.tree
    }

    pub fn schedule(&self) -> &Schedule {
        &self.bundle.schedule
    }

    /// The extra dissemination lanes the moderator planned under
    /// `--trees k` (empty with `trees = 1`, possibly fewer than `k - 1`
    /// on sparse overlays).
    pub fn extra_lanes(&self) -> &[TreeLane] {
        &self.bundle.extra
    }

    /// Every dissemination lane: lane 0 (the paper's tree + schedule)
    /// followed by the extra edge-disjoint lanes.
    pub fn lanes(&self) -> Vec<TreeLane> {
        let mut lanes = vec![TreeLane {
            tree: self.bundle.tree.clone(),
            schedule: self.bundle.schedule.clone(),
        }];
        lanes.extend(self.bundle.extra.iter().cloned());
        lanes
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The active Byzantine scenario, if the config enables one
    /// (`--adversary`); `None` under `adversary = none`.
    pub fn adversary(&self) -> Option<&AdversaryScenario> {
        self.adversary.as_ref()
    }

    /// The session's robust-aggregation policy (`--fold`). With
    /// `fold_f = 0` (auto) the assumed-Byzantine count `f` resolves to
    /// the scenario's actual compromised-node count, or `max(1, n/5)`
    /// when no adversary is configured (defending blind).
    pub fn fold_policy(&self) -> FoldPolicy {
        let auto_f = self
            .adversary
            .as_ref()
            .map_or_else(|| (self.cfg.nodes / 5).max(1), AdversaryScenario::byzantine_count);
        self.cfg.fold_policy(auto_f)
    }

    /// The dropping-relay plan the engine's rounds must honor; `None`
    /// unless the scenario fields a relay that junks forwards.
    fn drop_plan(&self) -> Option<Rc<DropPlan>> {
        self.adversary.as_ref().and_then(AdversaryScenario::drop_plan)
    }

    /// The session's per-round originator sets over `rounds` pipelined
    /// rounds (`--participation`); `None` while dormant (`p = 1`, every
    /// node originates — the legacy engine bit for bit). Seeded by the
    /// config seed, so the DFL layer and the engine agree on who trains.
    pub fn participation_plan(&self, rounds: u64) -> Option<Rc<ParticipationPlan>> {
        if self.cfg.participation >= 1.0 {
            return None;
        }
        Some(Rc::new(ParticipationPlan::sample(
            self.cfg.participation,
            self.bundle.tree.node_count(),
            rounds,
            self.cfg.seed,
        )))
    }

    /// The session's straggler compute-hold plan (`--straggler-frac` /
    /// `--straggler-slowdown`); `None` while dormant (no stragglers, or
    /// a slowdown too small to cost a transmit opportunity).
    pub fn straggler_plan(&self) -> Option<Rc<StragglerPlan>> {
        if self.cfg.straggler_frac <= 0.0 {
            return None;
        }
        let plan = StragglerPlan::sample(
            self.cfg.straggler_frac,
            self.cfg.straggler_slowdown,
            self.bundle.tree.node_count(),
            self.cfg.seed,
        );
        if plan.is_noop() {
            None
        } else {
            Some(Rc::new(plan))
        }
    }

    /// The config's transfer plan for a `model_mb`-sized checkpoint
    /// (whole-model by default; `--segments` / `--segment-mb` slice it).
    pub fn transfer_plan(&self, model_mb: f64) -> TransferPlan {
        self.cfg.transfer_plan(model_mb)
    }

    /// Run one timed MOSGU communication round through the event-driven
    /// engine: alternate color slots; in each slot every transmitting
    /// node pops its oldest queue entry and ships a copy to each
    /// addressed neighbor through the simulator; the next slot opens when
    /// the current slot's per-flow completion events have all fired (the
    /// formula slot length is the budget, not a busy-wait — see
    /// DESIGN.md). Per-slot durations land in
    /// [`RoundMetrics::slot_timings`].
    ///
    /// The transfer unit comes from the config's plan: with `segments ≥
    /// 2` each copy moves as serial segment flows with cut-through relay
    /// forwarding (see `coordinator::engine`).
    ///
    /// `failure_prob` injects per-transmission network disruptions: the
    /// flow's bytes are spent but nothing is delivered, and the entry is
    /// re-queued for the node's next turn (§III-D).
    pub fn run_mosgu_round(&self, model_mb: f64, seed: u64, failure_prob: f64) -> RoundMetrics {
        self.run_mosgu_round_planned(self.transfer_plan(model_mb), seed, failure_prob)
    }

    /// As [`GossipSession::run_mosgu_round`] under an explicit transfer
    /// plan (ignoring the config's `segments` / `segment_mb`).
    pub fn run_mosgu_round_planned(
        &self,
        plan: TransferPlan,
        seed: u64,
        failure_prob: f64,
    ) -> RoundMetrics {
        let mut driver = SimDriver::new(&self.testbed, seed);
        let mut engine = RoundEngine::new(&mut driver, &self.bundle.schedule);
        let n = self.bundle.tree.node_count();
        let opts = RoundOptions {
            plan,
            failure_prob,
            // generous guard: retransmissions can stretch the round
            max_slots: 8 * n + 64,
            failure_rng: Pcg64::new(seed ^ 0xfa11),
            drops: self.drop_plan(),
        };
        if self.bundle.extra.is_empty() {
            // single tree: the paper's engine path, untouched
            let mut state = GossipState::new(self.bundle.tree.clone(), 0);
            engine.run_round(&mut state, opts, |_, _| {})
        } else {
            // multi-tree: stripe the plan round-robin across the lanes
            engine.run_forest_round(&self.lanes(), 0, opts)
        }
    }

    /// Run `rounds` MOSGU communication rounds through **one long-lived
    /// simulator** with multi-round pipelining: each node seeds round
    /// `t+1` the moment it holds every round-`t` model, so next-round
    /// seeds gossip in slots round `t` has vacated (§III-D, "forwarded
    /// copies pipeline with the next round"). The transfer unit comes
    /// from the config's plan.
    pub fn run_pipelined_rounds(&self, model_mb: f64, rounds: u64, seed: u64) -> PipelineMetrics {
        self.run_pipelined_rounds_planned(self.transfer_plan(model_mb), rounds, seed)
    }

    /// As [`GossipSession::run_pipelined_rounds`] under an explicit
    /// transfer plan.
    pub fn run_pipelined_rounds_planned(
        &self,
        plan: TransferPlan,
        rounds: u64,
        seed: u64,
    ) -> PipelineMetrics {
        let mut driver = SimDriver::new(&self.testbed, seed);
        let mut engine = RoundEngine::new(&mut driver, &self.bundle.schedule);
        let n = self.bundle.tree.node_count();
        let mut opts = PipelineOptions::reliable_plan(rounds, plan, n);
        opts.drops = self.drop_plan();
        opts.participants = self.participation_plan(rounds);
        opts.stragglers = self.straggler_plan();
        engine.run_pipelined(&self.bundle.tree, opts)
    }

    /// Run `rounds` pipelined MOSGU rounds with the **dynamic network
    /// plane** enabled from the config: the simulator's links drift
    /// (`drift` amplitude, re-drawn every `drift_interval_s` simulated
    /// seconds), the moderator probes every `probe_every` retired rounds
    /// through the driver and re-plans — incremental MST, recolor, fresh
    /// §III-C slot budget — when the smoothed ping estimates deviate more
    /// than `replan_threshold` from the planning baseline (threshold 0 =
    /// replan after every sweep). The engine migrates to each new plan at
    /// the next round boundary; applied migrations land in
    /// [`PipelineMetrics::replans`].
    ///
    /// With `drift = 0` and `probe_every = 0` (the defaults) this is
    /// **bit-identical** to [`GossipSession::run_pipelined_rounds`] —
    /// pinned by `tests/engine_equivalence.rs`.
    pub fn run_adaptive_rounds(&self, model_mb: f64, rounds: u64, seed: u64) -> PipelineMetrics {
        self.run_adaptive_rounds_with_failures(model_mb, rounds, seed, 0.0)
    }

    /// As [`GossipSession::run_adaptive_rounds`] with per-transmission
    /// network disruptions at `failure_prob` (bytes spent, nothing
    /// delivered, entry re-queued — the §III-D model). The chaos harness
    /// composes this with drift, compression and an active adversary;
    /// `failure_prob = 0` is the adaptive path verbatim.
    pub fn run_adaptive_rounds_with_failures(
        &self,
        model_mb: f64,
        rounds: u64,
        seed: u64,
        failure_prob: f64,
    ) -> PipelineMetrics {
        let plan = self.transfer_plan(model_mb);
        let drift =
            DriftProcess { amplitude: self.cfg.drift, interval_s: self.cfg.drift_interval_s };
        let mut driver = SimDriver::with_drift(&self.testbed, seed, drift);
        let policy = ReplanPolicy {
            probe_every: self.cfg.probe_every,
            replan_threshold: self.cfg.replan_threshold,
            ..ReplanPolicy::default()
        };
        // baseline = the moderator's averaged matrix: bundle.tree is an
        // MST of *these* costs, the precondition of the incremental
        // update (the clean pings differ by the ±2% report noise)
        let mut replanner = Replanner::new(
            &self.measured_costs,
            &self.bundle.tree,
            policy,
            self.cfg.coloring,
            plan.segment_mb(),
            self.cfg.ping_size_bytes,
            1,
        );
        let mut engine = RoundEngine::new(&mut driver, &self.bundle.schedule);
        let n = self.bundle.tree.node_count();
        let mut opts = PipelineOptions::reliable_plan(rounds, plan, n);
        opts.drops = self.drop_plan();
        opts.participants = self.participation_plan(rounds);
        opts.stragglers = self.straggler_plan();
        if failure_prob > 0.0 {
            opts.failure_prob = failure_prob;
            opts.failure_rng = Pcg64::new(seed ^ 0xfa11);
        }
        engine.run_pipelined_adaptive(
            &self.bundle.tree,
            opts,
            |d, round, _now| replanner.on_round_complete(d, round),
        )
    }

    /// The paper's baseline on this testbed: all-to-all direct push on the
    /// complete overlay (the broadcast columns of Tables III–V are one set
    /// of values regardless of underlay rows).
    pub fn run_broadcast_round(&self, model_mb: f64, seed: u64) -> RoundMetrics {
        broadcast::paper_baseline(&self.testbed, model_mb, seed)
    }

    /// Run one **whole-model** MOSGU round on the sharded simulator: one
    /// event queue per testbed subnet plus a backbone queue, slots driven
    /// by a round barrier (`parallel` drains shards on threads — see
    /// `netsim::shard`). The barrier runner always moves unsegmented
    /// copies — the config's `segments` / `segment_mb` keys are
    /// deliberately **not** consulted (segment-granular cut-through stays
    /// on the event-driven engine). With a single-subnet config this is
    /// the flat whole-model round —
    /// [`GossipSession::run_mosgu_round_planned`] with
    /// `TransferPlan::whole(model_mb)` — **bit for bit** (pinned by
    /// `tests/engine_equivalence.rs`); multi-shard runs decouple local
    /// from cross-subnet contention and trade that fidelity for
    /// wall-clock scalability. The robustness plane's dropping-relay
    /// plan is deliberately **not** consulted here — junk tracking lives
    /// on the event-driven engine, which is what the DFL fold runs on.
    pub fn run_sharded_round(
        &self,
        model_mb: f64,
        seed: u64,
        failure_prob: f64,
        parallel: bool,
    ) -> RoundMetrics {
        let mut sim = ShardedNetSim::sharded(&self.testbed, seed);
        let n = self.bundle.tree.node_count();
        let opts = ShardedRoundOptions {
            model_mb,
            // the config's codec shrinks the wire payload here too
            // (compress = none keeps wire == logical bit for bit)
            wire_mb: self.transfer_plan(model_mb).wire_mb(),
            failure_prob,
            max_slots: 8 * n + 64,
            failure_rng: Pcg64::new(seed ^ 0xfa11),
            parallel,
        };
        if self.bundle.extra.is_empty() {
            let mut state = GossipState::new(self.bundle.tree.clone(), 0);
            sharded::run_sharded_round(&mut sim, &mut state, &self.bundle.schedule, opts)
        } else {
            sharded::run_sharded_forest_round(&mut sim, &self.lanes(), opts)
        }
    }

    /// Flooding with relay on the session's structural overlay (ablation).
    pub fn run_flood_round(&self, model_mb: f64, seed: u64) -> RoundMetrics {
        broadcast::run_broadcast_round(
            &self.testbed,
            &self.structure,
            model_mb,
            BroadcastMode::Flood,
            seed,
        )
    }
}

/// Build one session per topology kind with a shared config template.
pub fn sessions_for_all_topologies(cfg: &ExperimentConfig) -> Result<Vec<(TopologyKind, GossipSession)>> {
    TopologyKind::ALL
        .iter()
        .map(|&kind| {
            let cfg = ExperimentConfig { topology: kind, ..cfg.clone() };
            Ok((kind, GossipSession::new(&cfg)?))
        })
        .collect()
}

/// Large-n hierarchical scenario: router-hierarchy overlay, hierarchical
/// planning straight from the measured cost graph, exchange rounds on the
/// sharded simulator.
///
/// [`GossipSession`] routes planning through the moderator's **dense**
/// cost matrix (faithful to §III-A, O(n²) memory) — fine at paper scale,
/// prohibitive at n ≥ 10k. This scenario plans from the sparse overlay
/// costs via [`plan_hierarchical_forest`] instead, and measures the **exchange
/// phase** of a round (every node's model to its tree neighbors — Table
/// V's blocking indicator; the O(n²) dissemination tail pipelines with
/// later rounds per §III-D) over [`ShardedNetSim`], sequential or
/// sharded-parallel. `benches/scale_sweep.rs` drives it to n = 10k.
pub struct ScaleScenario {
    cfg: ExperimentConfig,
    testbed: Testbed,
    structure: Graph,
    hierarchy: Hierarchy,
    epoch: PlanEpoch,
}

impl ScaleScenario {
    /// Generate the hierarchy overlay (`nodes`, `subnets`,
    /// `gateway_links`, lattice degree `ws_k`), measure edge costs on the
    /// testbed, and plan hierarchically. `model_mb` feeds the §III-C slot
    /// budget.
    pub fn new(cfg: &ExperimentConfig, model_mb: f64) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow::anyhow!("invalid config: {e}"))?;
        let mut rng = Pcg64::new(cfg.seed);
        let (structure, hierarchy) = generators::router_hierarchy(
            cfg.nodes,
            cfg.subnets,
            cfg.gateway_links,
            cfg.topology_params.ws_k,
            &mut rng,
        );
        let testbed = Testbed::new(cfg);
        let costs = testbed.overlay_costs(&structure);
        // trees = 1 is plan_hierarchical verbatim; trees ≥ 2 carves extra
        // edge-disjoint lanes per subnet + gateway backbone
        let epoch = plan_hierarchical_forest(
            &costs,
            &hierarchy,
            cfg.mst,
            cfg.coloring,
            cfg.trees,
            cfg.transfer_plan(model_mb).segment_mb(),
            cfg.ping_size_bytes,
            1,
        )
        .map_err(|e| anyhow::anyhow!("hierarchical planning: {e}"))?;
        Ok(ScaleScenario { cfg: cfg.clone(), testbed, structure, hierarchy, epoch })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn testbed(&self) -> &Testbed {
        &self.testbed
    }

    pub fn structure(&self) -> &Graph {
        &self.structure
    }

    pub fn hierarchy(&self) -> &Hierarchy {
        &self.hierarchy
    }

    pub fn tree(&self) -> &Graph {
        &self.epoch.tree
    }

    pub fn schedule(&self) -> &Schedule {
        &self.epoch.schedule
    }

    /// Run the exchange phase of one gossip round. `sharded` selects the
    /// per-subnet simulator ([`ShardedNetSim::sharded`]) vs the
    /// sequential single-queue baseline ([`ShardedNetSim::single`]) on
    /// the same topology and plan; `parallel` drains shards on threads.
    pub fn run_exchange(
        &self,
        model_mb: f64,
        seed: u64,
        failure_prob: f64,
        use_shards: bool,
        parallel: bool,
    ) -> RoundMetrics {
        self.run_exchange_pooled(model_mb, seed, failure_prob, use_shards, parallel, None)
    }

    /// As [`ScaleScenario::run_exchange`] with the barrier pool's width
    /// pinned: `drain_workers = Some(w)` drains with `w` concurrent
    /// workers (counting the barrier thread), `None` uses the machine's
    /// available parallelism. A pure scheduling knob — every width
    /// produces bit-identical results (pinned by `tests/scale_shard.rs`),
    /// so it exists for benchmarking the pool and testing determinism.
    pub fn run_exchange_pooled(
        &self,
        model_mb: f64,
        seed: u64,
        failure_prob: f64,
        use_shards: bool,
        parallel: bool,
        drain_workers: Option<usize>,
    ) -> RoundMetrics {
        let mut sim = if use_shards {
            ShardedNetSim::sharded(&self.testbed, seed)
        } else {
            ShardedNetSim::single(&self.testbed, seed)
        };
        if let Some(w) = drain_workers {
            sim.set_drain_workers(w);
        }
        let opts = ShardedRoundOptions {
            model_mb,
            wire_mb: self.cfg.transfer_plan(model_mb).wire_mb(),
            failure_prob,
            max_slots: 64 + 8 * self.epoch.schedule.coloring.num_colors(),
            failure_rng: Pcg64::new(seed ^ 0xfa11),
            parallel,
        };
        if self.epoch.extra.is_empty() {
            sharded::run_sharded_exchange(&mut sim, &self.epoch.tree, &self.epoch.schedule, opts)
        } else {
            sharded::run_sharded_forest_exchange(&mut sim, &self.epoch.lanes(), opts)
        }
    }

    /// The extra dissemination lanes (empty under `trees = 1`).
    pub fn extra_lanes(&self) -> &[TreeLane] {
        &self.epoch.extra
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_cfg() -> ExperimentConfig {
        ExperimentConfig { latency_jitter: 0.0, ..Default::default() }
    }

    #[test]
    fn session_builds_for_every_topology() {
        let sessions = sessions_for_all_topologies(&quiet_cfg()).unwrap();
        assert_eq!(sessions.len(), 4);
        for (kind, s) in sessions {
            assert!(s.tree().is_tree(), "{kind:?}");
            assert!(s.schedule().coloring.is_proper(s.tree()), "{kind:?}");
            assert!(s.schedule().slot_len_s > 0.0);
        }
    }

    #[test]
    fn mosgu_round_disseminates_everything() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let m = s.run_mosgu_round(11.6, 1, 0.0);
        // tree dissemination: each of the 10 models crosses each of the 9
        // edges exactly once = 90 deliveries... but copies are per-edge
        // directionally: total transfers = sum over slots of copies = 90.
        assert_eq!(m.transfer_count(), 90);
        assert!(m.slots >= 10, "needs many alternating slots, got {}", m.slots);
        assert!(m.total_time_s > 0.0);
    }

    #[test]
    fn mosgu_beats_broadcast_on_bandwidth_and_total_time() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        for mb in [11.6, 48.0] {
            let g = s.run_mosgu_round(mb, 1, 0.0);
            let b = s.run_broadcast_round(mb, 1);
            assert!(
                g.bandwidth_mbps() > 2.0 * b.bandwidth_mbps(),
                "mb={mb}: gossip {} vs broadcast {}",
                g.bandwidth_mbps(),
                b.bandwidth_mbps()
            );
            assert!(
                g.avg_transfer_s() < b.avg_transfer_s(),
                "mb={mb}: transfer {} vs {}",
                g.avg_transfer_s(),
                b.avg_transfer_s()
            );
        }
    }

    #[test]
    fn failure_injection_still_completes_with_retransmission() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let clean = s.run_mosgu_round(5.0, 2, 0.0);
        let lossy = s.run_mosgu_round(5.0, 2, 0.15);
        assert!(lossy.slots >= clean.slots, "failures must not shorten the round");
        assert!(lossy.transfer_count() >= clean.transfer_count());
    }

    #[test]
    fn deterministic_given_seed() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let a = s.run_mosgu_round(14.0, 7, 0.0);
        let b = s.run_mosgu_round(14.0, 7, 0.0);
        assert!((a.total_time_s - b.total_time_s).abs() < 1e-12);
        assert_eq!(a.transfer_count(), b.transfer_count());
    }

    #[test]
    fn slot_timings_cover_the_round() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let m = s.run_mosgu_round(14.0, 1, 0.0);
        // the engine records one timing entry per slot entered
        assert_eq!(m.slot_timings.len(), m.slots);
        let copies: usize = m.slot_timings.iter().map(|t| t.copies).sum();
        assert_eq!(copies, m.transfer_count());
        for pair in m.slot_timings.windows(2) {
            assert!(pair[0].end_s <= pair[1].start_s + 1e-12, "slots overlap");
        }
        let last_active = m.slot_timings.iter().rev().find(|t| t.copies > 0).unwrap();
        assert!((last_active.end_s - m.total_time_s).abs() < 1e-12);
        assert!(m.busy_time_s() > 0.0);
        assert!(m.busy_time_s() <= m.total_time_s + 1e-12);
    }

    #[test]
    fn pipelined_rounds_beat_sequential_on_total_time() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let rounds = 3u64;
        let sequential: f64 =
            (0..rounds).map(|_| s.run_mosgu_round(14.0, 1, 0.0).total_time_s).sum();
        let pipelined = s.run_pipelined_rounds(14.0, rounds, 1);
        assert_eq!(pipelined.rounds.len(), 3);
        assert!(
            pipelined.total_time_s < sequential,
            "pipelining must overlap rounds: {} vs {}",
            pipelined.total_time_s,
            sequential
        );
    }

    #[test]
    fn adaptive_rounds_default_config_matches_pipelined() {
        // drift 0 + probe_every 0 (defaults): the adaptive path must be
        // the plain pipeline bit for bit
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let a = s.run_adaptive_rounds(14.0, 2, 1);
        let b = s.run_pipelined_rounds(14.0, 2, 1);
        assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
        assert_eq!(a.slots, b.slots);
        assert_eq!(a.transfers, b.transfers);
        assert!(a.replans.is_empty());
    }

    #[test]
    fn adaptive_rounds_with_drift_and_probing_complete() {
        let cfg = ExperimentConfig {
            drift: 0.3,
            drift_interval_s: 0.5,
            probe_every: 1,
            replan_threshold: 0.1,
            ..quiet_cfg()
        };
        let s = GossipSession::new(&cfg).unwrap();
        let p = s.run_adaptive_rounds(14.0, 4, 1);
        assert_eq!(p.rounds.len(), 4);
        for (r, orders) in p.received.iter().enumerate() {
            for (u, o) in orders.iter().enumerate() {
                assert_eq!(o.len(), 9, "round {r} node {u} missed models under drift");
            }
        }
        // deterministic replay
        let again = s.run_adaptive_rounds(14.0, 4, 1);
        assert_eq!(p.total_time_s.to_bits(), again.total_time_s.to_bits());
        assert_eq!(p.replans, again.replans);
    }

    #[test]
    fn segmented_config_threads_through_session_rounds() {
        let cfg = ExperimentConfig {
            topology: TopologyKind::Chain,
            segments: 4,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let s = GossipSession::new(&cfg).unwrap();
        let m = s.run_mosgu_round(48.0, 1, 0.0);
        assert_eq!(m.segments, 4);
        // 10 models × 9 chain edges, 4 segment flows per copy
        assert_eq!(m.transfer_count(), 90 * 4);
        assert_eq!(m.model_copy_count(), 90);
        assert!(m.relay_copies > 0, "chain dissemination must use cut-through relays");

        // explicit plan overrides the config
        let whole = s.run_mosgu_round_planned(TransferPlan::whole(48.0), 1, 0.0);
        assert_eq!(whole.segments, 1);
        assert_eq!(whole.transfer_count(), 90);
    }

    #[test]
    fn segmented_plan_beats_whole_model_on_chain_session() {
        let cfg = ExperimentConfig {
            topology: TopologyKind::Chain,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let s = GossipSession::new(&cfg).unwrap();
        let whole = s.run_mosgu_round_planned(TransferPlan::whole(48.0), 1, 0.0);
        let seg = s.run_mosgu_round_planned(TransferPlan::segmented(48.0, 4), 1, 0.0);
        assert!(
            seg.total_time_s < whole.total_time_s,
            "cut-through must pipeline the chain: {} vs {}",
            seg.total_time_s,
            whole.total_time_s
        );
    }

    #[test]
    fn hierarchy_session_plans_and_runs_full_rounds() {
        let cfg = ExperimentConfig {
            nodes: 12,
            subnets: 3,
            topology_gen: crate::graph::generators::GeneratorKind::Hierarchy,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let s = GossipSession::new(&cfg).unwrap();
        let h = s.hierarchy().expect("hierarchy overlay carries its structure");
        assert_eq!(h.subnet_count(), 3);
        assert!(s.tree().is_tree());
        assert!(s.schedule().coloring.is_proper(s.tree()));
        // cross-subnet tree edges ride the gateway backbone only
        for e in s.tree().edges() {
            if h.subnet(e.u) != h.subnet(e.v) {
                assert!(h.is_gateway(e.u) && h.is_gateway(e.v));
            }
        }
        // the stitched plan still disseminates fully through the engine
        let m = s.run_mosgu_round(5.0, 1, 0.0);
        assert_eq!(m.transfer_count(), 12 * 11);
        // and through the sharded barrier runner, bytes conserved
        let sharded = s.run_sharded_round(5.0, 1, 0.0, true);
        assert_eq!(sharded.transfer_count(), 12 * 11);
        assert!((sharded.total_payload_mb() - 132.0 * 5.0).abs() < 1e-9);
    }

    #[test]
    fn geometric_session_builds_and_disseminates() {
        let cfg = ExperimentConfig {
            nodes: 12,
            topology_gen: crate::graph::generators::GeneratorKind::Geometric,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let s = GossipSession::new(&cfg).unwrap();
        assert!(s.hierarchy().is_none());
        assert!(s.structure().is_connected());
        let m = s.run_mosgu_round(5.0, 1, 0.0);
        assert_eq!(m.transfer_count(), 12 * 11);
    }

    #[test]
    fn scale_scenario_exchange_conserves_bytes_on_both_simulators() {
        let cfg = ExperimentConfig {
            nodes: 48,
            subnets: 6,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let sc = ScaleScenario::new(&cfg, 14.0).unwrap();
        assert!(sc.tree().is_tree());
        assert_eq!(sc.hierarchy().subnet_count(), 6);
        let expect_copies = 2 * (48 - 1);
        let expect_mb = expect_copies as f64 * 14.0;
        let seq = sc.run_exchange(14.0, 1, 0.0, false, false);
        let shd = sc.run_exchange(14.0, 1, 0.0, true, true);
        for (name, m) in [("sequential", &seq), ("sharded", &shd)] {
            assert_eq!(m.transfer_count(), expect_copies, "{name}");
            assert!((m.total_payload_mb() - expect_mb).abs() < 1e-6, "{name} bytes");
            assert_eq!(m.slots, 2, "{name}: one slot per color class");
        }
        // sharded runs replay deterministically
        let again = sc.run_exchange(14.0, 1, 0.0, true, true);
        assert_eq!(shd.total_time_s.to_bits(), again.total_time_s.to_bits());
        assert_eq!(shd.transfers, again.transfers);
    }

    #[test]
    fn multi_tree_session_disseminates_and_conserves_bytes() {
        let cfg = ExperimentConfig { trees: 2, ..quiet_cfg() };
        let s = GossipSession::new(&cfg).unwrap();
        // the default complete overlay is dense enough for a second lane
        assert_eq!(s.extra_lanes().len(), 1, "complete n=10 admits an extra lane");
        let lanes = s.lanes();
        let trees: Vec<Graph> = lanes.iter().map(|l| l.tree.clone()).collect();
        assert!(crate::mst::disjoint::pairwise_edge_disjoint(&trees));

        // event-driven engine: each lane moves every model across its 9
        // edges, each stripe carrying half the bytes — total conserved
        let m = s.run_mosgu_round(48.0, 1, 0.0);
        assert_eq!(m.transfer_count(), 2 * 90);
        assert!((m.total_payload_mb() - 90.0 * 48.0).abs() < 1e-6, "bytes conserved");

        // sharded barrier runner takes the forest path too
        let sharded = s.run_sharded_round(48.0, 1, 0.0, true);
        assert_eq!(sharded.transfer_count(), 2 * 90);
        assert!((sharded.total_payload_mb() - 90.0 * 48.0).abs() < 1e-6);

        // deterministic replay
        let again = s.run_mosgu_round(48.0, 1, 0.0);
        assert_eq!(m.total_time_s.to_bits(), again.total_time_s.to_bits());
        assert_eq!(m.transfers, again.transfers);
    }

    #[test]
    fn multi_tree_session_survives_failure_injection() {
        let cfg = ExperimentConfig { trees: 2, ..quiet_cfg() };
        let s = GossipSession::new(&cfg).unwrap();
        let m = s.run_mosgu_round(14.0, 5, 0.2);
        // disruption spends bytes; dissemination still completes (the
        // run_forest_round completion assert would panic otherwise)
        assert!(m.transfer_count() >= 2 * 90);
    }

    #[test]
    fn scale_scenario_forest_exchange_conserves_bytes() {
        let cfg = ExperimentConfig {
            nodes: 48,
            subnets: 6,
            trees: 2,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let sc = ScaleScenario::new(&cfg, 14.0).unwrap();
        let lanes = 1 + sc.extra_lanes().len();
        let m = sc.run_exchange(14.0, 1, 0.0, true, false);
        // every lane's exchange moves 2(n-1) stripes of 14/lanes MB, so
        // the byte total is lane-count invariant
        assert_eq!(m.transfer_count(), lanes * 2 * 47);
        assert!((m.total_payload_mb() - 2.0 * 47.0 * 14.0).abs() < 1e-6, "bytes conserved");
    }

    #[test]
    fn default_session_has_no_adversary_and_mean_fold() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        assert!(s.adversary().is_none());
        assert!(s.fold_policy().is_mean());
        // with no scenario, auto f falls back to the blind n/5 assumption
        let cfg = ExperimentConfig { fold: crate::dfl::robust::FoldKind::Krum, ..quiet_cfg() };
        let s = GossipSession::new(&cfg).unwrap();
        assert_eq!(s.fold_policy().f, 2, "blind auto f = max(1, 10/5)");
    }

    #[test]
    fn dropping_relay_censors_reception_orders_without_stalling() {
        let cfg = ExperimentConfig {
            topology: TopologyKind::Chain,
            adversary: crate::dfl::adversary::AdversaryKind::DroppingRelay,
            adversary_frac: 0.3,
            ..quiet_cfg()
        };
        let s = GossipSession::new(&cfg).unwrap();
        let scenario = s.adversary().expect("scenario is active");
        assert_eq!(scenario.byzantine_count(), 3);
        assert_eq!(s.fold_policy().f, 3, "auto f resolves to the scenario's count");
        let p = s.run_pipelined_rounds(5.0, 2, 1);
        assert_eq!(p.rounds.len(), 2, "junked forwards must not stall dissemination timing");
        // a chain relay junking all its edges censors everything it
        // forwards (three Byzantine nodes cannot all be chain endpoints,
        // so at least one actually relays)
        let folded: usize = p.received.iter().flatten().map(Vec::len).sum();
        assert!(folded < 2 * 10 * 9, "some payloads must be censored, got all {folded}");
        // deterministic replay, scenario included
        let again = s.run_pipelined_rounds(5.0, 2, 1);
        assert_eq!(p.received, again.received);
        assert_eq!(p.total_time_s.to_bits(), again.total_time_s.to_bits());
    }

    #[test]
    fn poison_adversaries_leave_gossip_timing_untouched() {
        // content attacks corrupt payloads, not the wire: timing and
        // reception orders must be bit-identical to the honest run
        let honest = GossipSession::new(&quiet_cfg()).unwrap();
        let cfg = ExperimentConfig {
            adversary: crate::dfl::adversary::AdversaryKind::ScaledPoison,
            fold: crate::dfl::robust::FoldKind::TrimmedMean,
            ..quiet_cfg()
        };
        let attacked = GossipSession::new(&cfg).unwrap();
        let a = honest.run_pipelined_rounds(14.0, 2, 1);
        let b = attacked.run_pipelined_rounds(14.0, 2, 1);
        assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
        assert_eq!(a.received, b.received);
    }

    #[test]
    fn different_topologies_yield_different_trees() {
        let sessions = sessions_for_all_topologies(&quiet_cfg()).unwrap();
        let weights: Vec<f64> = sessions.iter().map(|(_, s)| s.tree().total_weight()).collect();
        // not all identical (complete vs sparse graphs prune differently)
        assert!(weights.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9), "{weights:?}");
    }
}
