//! Barrier-driven round execution over the sharded simulator
//! ([`ShardedNetSim`]) — the scale-out counterpart of
//! [`RoundEngine`](super::RoundEngine).
//!
//! The event-driven engine keys slot state on *single* completion events,
//! which serializes the simulation: finding the globally next event means
//! merging every shard's queue. This module instead drives slots with a
//! **round barrier** — launch every copy of the slot, drain all shards to
//! idle (stealable tasks on a persistent worker pool when asked — see
//! [`DrainPool`](crate::netsim::pool::DrainPool)), re-synchronize the
//! clocks, then
//! apply deliveries in the engine's deterministic (sender, recipient)
//! order. Within a slot the shards share no state, so the trajectory is
//! identical whether shards drain in parallel or sequentially; with a
//! single shard the barrier drive replays the flat engine's whole-model
//! round **bit for bit** (`NetSim::run_until_idle` and per-event stepping
//! share one trajectory by construction; pinned in
//! `tests/engine_equivalence.rs`).
//!
//! Two scopes:
//!
//! * [`run_sharded_round`] — full §III-D dissemination over a
//!   [`GossipState`], exactly the engine's whole-model protocol. O(n²)
//!   copies; for equivalence tests and moderate n.
//! * [`run_sharded_exchange`] — the **exchange phase** only: every node's
//!   own model to each tree neighbor, slots alternating by color,
//!   failures retried. This is the blocking part of an FL round (Table
//!   V's indicator; §III-D: the forwarding tail pipelines with the next
//!   round), O(n) copies — the unit of measurement for n ≥ 10k scale
//!   scenarios (`benches/scale_sweep.rs`).

use super::super::broadcast::flow_tag;
use super::super::gossip::{GossipState, Send};
use super::super::schedule::Schedule;
use super::{exchange_time, whole_model_delivery_order, TreeLane};
use crate::graph::{Graph, NodeId};
use crate::metrics::{RoundMetrics, SlotTiming};
use crate::netsim::shard::ShardedNetSim;
use crate::util::rng::Pcg64;

/// Knobs of one barrier-driven sharded round.
#[derive(Debug, Clone)]
pub struct ShardedRoundOptions {
    /// Whole-model **logical** checkpoint size (MB) — the sharded plane
    /// moves unsegmented copies; segment-granular plans stay on the
    /// event-driven engine.
    pub model_mb: f64,
    /// Bytes each copy actually moves on the wire (MB); equals
    /// `model_mb` (same float bits) unless the session's compression
    /// codec shrank the payload.
    pub wire_mb: f64,
    /// Per-delivery §III-D disruption probability (bytes spent, nothing
    /// delivered, entry retried).
    pub failure_prob: f64,
    /// Hard slot budget (protocol-bug guard).
    pub max_slots: usize,
    /// Failure coin stream, drawn in deterministic (sender, recipient)
    /// order — the flat engine's exact sequence.
    pub failure_rng: Pcg64,
    /// Drain shards concurrently on the persistent pool at the slot
    /// barrier (worker count decoupled from shard count).
    pub parallel: bool,
}

impl ShardedRoundOptions {
    /// Failure-free uncompressed options with the session's conventional
    /// slot budget.
    pub fn reliable(model_mb: f64, nodes: usize, parallel: bool) -> Self {
        ShardedRoundOptions {
            model_mb,
            wire_mb: model_mb,
            failure_prob: 0.0,
            max_slots: 8 * nodes + 64,
            failure_rng: Pcg64::new(0),
            parallel,
        }
    }
}

/// Run one full-dissemination MOSGU round (the engine's whole-model
/// protocol) over the sharded simulator. Single-shard simulators replay
/// the flat engine bit for bit.
pub fn run_sharded_round(
    sim: &mut ShardedNetSim,
    state: &mut GossipState,
    schedule: &Schedule,
    mut opts: ShardedRoundOptions,
) -> RoundMetrics {
    let mut slots_used = 0;
    let mut slot_timings = Vec::new();
    for slot in 0..opts.max_slots {
        if state.is_complete() {
            break;
        }
        slots_used = slot + 1;
        let color = schedule.color_of_slot(slot);
        let transmitters = schedule.transmitters(slot);
        let planned = state.plan_slot(&transmitters);
        let start_s = sim.now();
        if planned.is_empty() {
            slot_timings.push(SlotTiming { slot, color, start_s, end_s: start_s, copies: 0 });
            continue;
        }
        let mut meta: Vec<(usize, NodeId)> = Vec::new();
        for (i, tx) in planned.iter().enumerate() {
            for &to in &tx.recipients {
                sim.start_flow(tx.from, to, opts.wire_mb, flow_tag(tx.entry.key.owner, tx.from));
                meta.push((i, to));
            }
        }
        let end_s = sim.drain_and_sync(opts.parallel);
        // the engine's deterministic delivery order — one shared
        // comparator, so the failure-coin sequence cannot drift from the
        // flat slot loop's
        let order = whole_model_delivery_order(&planned, &meta);
        let mut failed = vec![false; planned.len()];
        for j in order {
            let (i, to) = meta[j];
            if opts.failure_prob > 0.0 && opts.failure_rng.gen_bool(opts.failure_prob) {
                failed[i] = true;
                continue;
            }
            let tx = &planned[i];
            state.deliver(Send { from: tx.from, to, key: tx.entry.key });
        }
        for (i, tx) in planned.iter().enumerate() {
            if failed[i] {
                state.requeue(tx);
            }
        }
        slot_timings.push(SlotTiming { slot, color, start_s, end_s, copies: meta.len() });
    }
    assert!(
        state.is_complete(),
        "sharded round did not complete within {} slots",
        opts.max_slots
    );
    finish(sim, slots_used, slot_timings, &opts)
}

/// Multi-tree counterpart of [`run_sharded_round`]: one gossip state per
/// dissemination lane, each model striped `1/k` of its bytes down each of
/// the `k` edge-disjoint trees. All lanes launch into the **same**
/// simulator each slot, so striped flows contend for the physical links
/// exactly like the event-driven engine's forest rounds. A single lane
/// delegates to [`run_sharded_round`] verbatim.
pub fn run_sharded_forest_round(
    sim: &mut ShardedNetSim,
    lanes: &[TreeLane],
    mut opts: ShardedRoundOptions,
) -> RoundMetrics {
    assert!(!lanes.is_empty(), "a forest round needs at least one lane");
    if lanes.len() == 1 {
        let mut state = GossipState::new(lanes[0].tree.clone(), 0);
        return run_sharded_round(sim, &mut state, &lanes[0].schedule, opts);
    }
    let lane_wire = opts.wire_mb / lanes.len() as f64;
    let mut states: Vec<GossipState> =
        lanes.iter().map(|l| GossipState::new(l.tree.clone(), 0)).collect();
    let mut slots_used = 0;
    let mut slot_timings = Vec::new();
    for slot in 0..opts.max_slots {
        if states.iter().all(|s| s.is_complete()) {
            break;
        }
        slots_used = slot + 1;
        let color = lanes[0].schedule.color_of_slot(slot);
        let start_s = sim.now();
        let mut planned = Vec::new();
        let mut planned_lane: Vec<usize> = Vec::new();
        for (li, lane) in lanes.iter().enumerate() {
            let transmitters = lane.schedule.transmitters(slot);
            for tx in states[li].plan_slot(&transmitters) {
                planned_lane.push(li);
                planned.push(tx);
            }
        }
        if planned.is_empty() {
            slot_timings.push(SlotTiming { slot, color, start_s, end_s: start_s, copies: 0 });
            continue;
        }
        let mut meta: Vec<(usize, NodeId)> = Vec::new();
        for (i, tx) in planned.iter().enumerate() {
            for &to in &tx.recipients {
                sim.start_flow(tx.from, to, lane_wire, flow_tag(tx.entry.key.owner, tx.from));
                meta.push((i, to));
            }
        }
        let end_s = sim.drain_and_sync(opts.parallel);
        // (sender, recipient) pairs are unique across lanes — the trees
        // are edge-disjoint — so the shared comparator stays a total
        // order and the failure-coin sequence is well defined
        let order = whole_model_delivery_order(&planned, &meta);
        let mut failed = vec![false; planned.len()];
        for j in order {
            let (i, to) = meta[j];
            if opts.failure_prob > 0.0 && opts.failure_rng.gen_bool(opts.failure_prob) {
                failed[i] = true;
                continue;
            }
            let tx = &planned[i];
            states[planned_lane[i]].deliver(Send { from: tx.from, to, key: tx.entry.key });
        }
        for (i, tx) in planned.iter().enumerate() {
            if failed[i] {
                states[planned_lane[i]].requeue(tx);
            }
        }
        slot_timings.push(SlotTiming { slot, color, start_s, end_s, copies: meta.len() });
    }
    assert!(
        states.iter().all(|s| s.is_complete()),
        "sharded forest round did not complete within {} slots (lanes={})",
        opts.max_slots,
        lanes.len()
    );
    finish(sim, slots_used, slot_timings, &opts)
}

/// Multi-tree counterpart of [`run_sharded_exchange`]: each node owes a
/// `1/k` stripe of its own model to its neighbors in **every** lane's
/// tree. Total exchanged bytes match the single-tree exchange exactly
/// (`k` lanes × `1/k` wire each); the win is concurrency — stripes ride
/// edge-disjoint overlay links. A single lane delegates to
/// [`run_sharded_exchange`] verbatim.
pub fn run_sharded_forest_exchange(
    sim: &mut ShardedNetSim,
    lanes: &[TreeLane],
    mut opts: ShardedRoundOptions,
) -> RoundMetrics {
    assert!(!lanes.is_empty(), "a forest exchange needs at least one lane");
    if lanes.len() == 1 {
        return run_sharded_exchange(sim, &lanes[0].tree, &lanes[0].schedule, opts);
    }
    let lane_wire = opts.wire_mb / lanes.len() as f64;
    let n = lanes[0].tree.node_count();
    for l in lanes {
        assert!(l.tree.is_tree(), "exchange runs on planned gossip trees");
    }
    // pending[li][u] = lane-li neighbors still owed u's stripe
    let mut pending: Vec<Vec<Vec<NodeId>>> =
        lanes.iter().map(|l| (0..n).map(|u| l.tree.neighbor_ids(u)).collect()).collect();
    let mut left: usize = pending.iter().flatten().map(|p| p.len()).sum();
    let mut slots_used = 0;
    let mut slot_timings = Vec::new();
    for slot in 0..opts.max_slots {
        if left == 0 {
            break;
        }
        slots_used = slot + 1;
        let color = lanes[0].schedule.color_of_slot(slot);
        let start_s = sim.now();
        let mut launched: Vec<(usize, NodeId, NodeId)> = Vec::new();
        for (li, lane) in lanes.iter().enumerate() {
            for u in 0..n {
                if pending[li][u].is_empty() || !lane.schedule.transmits_in_slot(u, slot) {
                    continue;
                }
                for &v in &pending[li][u] {
                    sim.start_flow(u, v, lane_wire, flow_tag(u, u));
                    launched.push((li, u, v));
                }
            }
        }
        if launched.is_empty() {
            slot_timings.push(SlotTiming { slot, color, start_s, end_s: start_s, copies: 0 });
            continue;
        }
        let end_s = sim.drain_and_sync(opts.parallel);
        for &(li, u, v) in &launched {
            let dropped = opts.failure_prob > 0.0 && opts.failure_rng.gen_bool(opts.failure_prob);
            if !dropped {
                pending[li][u].retain(|&x| x != v);
                left -= 1;
            }
        }
        slot_timings.push(SlotTiming { slot, color, start_s, end_s, copies: launched.len() });
    }
    assert!(left == 0, "forest exchange did not complete within {} slots", opts.max_slots);
    finish(sim, slots_used, slot_timings, &opts)
}

/// Run the exchange phase of one round: each node's own model to every
/// tree neighbor, color classes alternating over slots, disrupted copies
/// retried on the sender's next turn. Completes in one slot per color
/// when failure-free.
pub fn run_sharded_exchange(
    sim: &mut ShardedNetSim,
    tree: &Graph,
    schedule: &Schedule,
    mut opts: ShardedRoundOptions,
) -> RoundMetrics {
    let n = tree.node_count();
    assert!(tree.is_tree(), "exchange runs on the planned gossip tree");
    // pending[u] = neighbors still owed u's own model
    let mut pending: Vec<Vec<NodeId>> = (0..n).map(|u| tree.neighbor_ids(u)).collect();
    let mut left: usize = pending.iter().map(|p| p.len()).sum();
    let mut slots_used = 0;
    let mut slot_timings = Vec::new();
    for slot in 0..opts.max_slots {
        if left == 0 {
            break;
        }
        slots_used = slot + 1;
        let color = schedule.color_of_slot(slot);
        let start_s = sim.now();
        let mut launched: Vec<(NodeId, NodeId)> = Vec::new();
        for u in 0..n {
            if pending[u].is_empty() || !schedule.transmits_in_slot(u, slot) {
                continue;
            }
            for &v in &pending[u] {
                sim.start_flow(u, v, opts.wire_mb, flow_tag(u, u));
                launched.push((u, v));
            }
        }
        if launched.is_empty() {
            slot_timings.push(SlotTiming { slot, color, start_s, end_s: start_s, copies: 0 });
            continue;
        }
        let end_s = sim.drain_and_sync(opts.parallel);
        for &(u, v) in &launched {
            let dropped = opts.failure_prob > 0.0 && opts.failure_rng.gen_bool(opts.failure_prob);
            if !dropped {
                pending[u].retain(|&x| x != v);
                left -= 1;
            }
        }
        slot_timings.push(SlotTiming { slot, color, start_s, end_s, copies: launched.len() });
    }
    assert!(left == 0, "exchange did not complete within {} slots", opts.max_slots);
    finish(sim, slots_used, slot_timings, &opts)
}

fn finish(
    sim: &mut ShardedNetSim,
    slots: usize,
    slot_timings: Vec<SlotTiming>,
    opts: &ShardedRoundOptions,
) -> RoundMetrics {
    let total_time_s = sim.now();
    let transfers = sim.take_completed();
    let exchange_time_s = exchange_time(&transfers);
    RoundMetrics {
        transfers,
        total_time_s,
        exchange_time_s,
        slots,
        slot_timings,
        segments: 1,
        relay_copies: 0,
        logical_model_mb: opts.model_mb,
        wire_model_mb: opts.wire_mb,
        // measured simulator work (cumulative over the sim's lifetime —
        // each run_sharded_* call here gets a fresh sim from its caller)
        sim: sim.counters(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::bfs_coloring;
    use crate::config::ExperimentConfig;
    use crate::graph::topology;
    use crate::netsim::testbed::Testbed;

    fn quiet_cfg(nodes: usize, subnets: usize) -> ExperimentConfig {
        ExperimentConfig { nodes, subnets, latency_jitter: 0.0, ..Default::default() }
    }

    fn chain_schedule(n: usize) -> (Graph, Schedule) {
        let tree = topology::chain(n);
        let coloring = bfs_coloring(&tree);
        (tree, Schedule { coloring, slot_len_s: 1.0, first_color: 0 })
    }

    #[test]
    fn exchange_completes_in_one_slot_per_color() {
        let cfg = quiet_cfg(12, 3);
        let tb = Testbed::new(&cfg);
        let (tree, schedule) = chain_schedule(12);
        let mut sim = ShardedNetSim::sharded(&tb, 1);
        let m = run_sharded_exchange(
            &mut sim,
            &tree,
            &schedule,
            ShardedRoundOptions::reliable(14.0, 12, false),
        );
        assert_eq!(m.slots, 2);
        // sum of degrees on a chain: 2(n-1) own-model copies
        assert_eq!(m.transfer_count(), 22);
        assert!((m.total_payload_mb() - 22.0 * 14.0).abs() < 1e-9, "bytes conserved");
        // all transfers are own-model, so exchange == last delivery
        assert!(m.exchange_time_s > 0.0);
    }

    #[test]
    fn exchange_with_failures_retries_until_delivered() {
        let cfg = quiet_cfg(10, 3);
        let tb = Testbed::new(&cfg);
        let (tree, schedule) = chain_schedule(10);
        let mut sim = ShardedNetSim::sharded(&tb, 2);
        let opts = ShardedRoundOptions {
            model_mb: 5.0,
            wire_mb: 5.0,
            failure_prob: 0.5,
            max_slots: 256,
            failure_rng: Pcg64::new(7),
            parallel: false,
        };
        let m = run_sharded_exchange(&mut sim, &tree, &schedule, opts);
        assert!(m.transfer_count() > 18, "disrupted copies spend bytes: {}", m.transfer_count());
        assert!(m.slots > 2);
    }

    #[test]
    fn exchange_parallel_matches_sequential_bit_for_bit() {
        let run = |parallel: bool| {
            let cfg = quiet_cfg(16, 4);
            let tb = Testbed::new(&cfg);
            let (tree, schedule) = chain_schedule(16);
            let mut sim = ShardedNetSim::sharded(&tb, 5);
            run_sharded_exchange(
                &mut sim,
                &tree,
                &schedule,
                ShardedRoundOptions::reliable(14.0, 16, parallel),
            )
        };
        let seq = run(false);
        let par = run(true);
        assert_eq!(seq.total_time_s.to_bits(), par.total_time_s.to_bits());
        assert_eq!(seq.transfers, par.transfers);
        assert_eq!(seq.slots, par.slots);
    }

    #[test]
    fn compressed_wire_size_shrinks_sharded_exchange() {
        let cfg = quiet_cfg(12, 3);
        let tb = Testbed::new(&cfg);
        let (tree, schedule) = chain_schedule(12);
        let run = |wire_mb: f64| {
            let mut sim = ShardedNetSim::sharded(&tb, 1);
            let opts = ShardedRoundOptions {
                wire_mb,
                ..ShardedRoundOptions::reliable(48.0, 12, false)
            };
            run_sharded_exchange(&mut sim, &tree, &schedule, opts)
        };
        let full = run(48.0);
        let compressed = run(12.0);
        assert_eq!(compressed.transfer_count(), full.transfer_count());
        // wire bytes shrink 4x; logical accounting stays at 48 MB/copy
        assert!((compressed.total_payload_mb() * 4.0 - full.total_payload_mb()).abs() < 1e-6);
        assert!((compressed.compression_ratio() - 4.0).abs() < 1e-12);
        assert!((compressed.total_logical_mb() - full.total_logical_mb()).abs() < 1e-9);
        assert!(compressed.exchange_time_s < full.exchange_time_s);
    }

    /// Two hand-built edge-disjoint spanning trees over 8 nodes: the
    /// chain 0-1-…-7 and an interleaved tree sharing none of its edges.
    fn two_lanes() -> Vec<TreeLane> {
        let (chain, chain_sched) = chain_schedule(8);
        let mut second = Graph::new(8);
        for (u, v) in [(0, 2), (2, 4), (4, 6), (1, 3), (3, 5), (5, 7), (0, 7)] {
            second.add_edge(u, v, 1.0);
        }
        let second_sched =
            Schedule { coloring: bfs_coloring(&second), slot_len_s: 1.0, first_color: 0 };
        vec![
            TreeLane { tree: chain, schedule: chain_sched },
            TreeLane { tree: second, schedule: second_sched },
        ]
    }

    #[test]
    fn forest_round_stripes_and_conserves_bytes() {
        let cfg = quiet_cfg(8, 2);
        let tb = Testbed::new(&cfg);
        let lanes = two_lanes();
        let mut sim = ShardedNetSim::sharded(&tb, 1);
        let m = run_sharded_forest_round(
            &mut sim,
            &lanes,
            ShardedRoundOptions::reliable(48.0, 8, false),
        );
        // each lane moves every model across its 7 tree edges: 2 × 8×7
        // lane-copies, each carrying half the bytes — total conserved
        assert_eq!(m.transfer_count(), 112);
        assert!((m.total_payload_mb() - 56.0 * 48.0).abs() < 1e-6, "bytes conserved");
        let copies: usize = m.slot_timings.iter().map(|t| t.copies).sum();
        assert_eq!(copies, m.transfer_count());
    }

    #[test]
    fn forest_round_single_lane_delegates_bit_for_bit() {
        let cfg = quiet_cfg(8, 2);
        let tb = Testbed::new(&cfg);
        let lanes = &two_lanes()[..1];
        let mut sim = ShardedNetSim::sharded(&tb, 3);
        let forest =
            run_sharded_forest_round(&mut sim, lanes, ShardedRoundOptions::reliable(14.0, 8, false));
        let mut sim2 = ShardedNetSim::sharded(&tb, 3);
        let mut state = GossipState::new(lanes[0].tree.clone(), 0);
        let plain = run_sharded_round(
            &mut sim2,
            &mut state,
            &lanes[0].schedule,
            ShardedRoundOptions::reliable(14.0, 8, false),
        );
        assert_eq!(forest.total_time_s.to_bits(), plain.total_time_s.to_bits());
        assert_eq!(forest.transfers, plain.transfers);
        assert_eq!(forest.slots, plain.slots);
    }

    #[test]
    fn forest_exchange_conserves_single_tree_byte_total() {
        let cfg = quiet_cfg(8, 2);
        let tb = Testbed::new(&cfg);
        let lanes = two_lanes();
        let mut sim = ShardedNetSim::sharded(&tb, 1);
        let m = run_sharded_forest_exchange(
            &mut sim,
            &lanes,
            ShardedRoundOptions::reliable(48.0, 8, false),
        );
        // per lane: sum of tree degrees = 2(n-1) stripes; 2 lanes double
        // the copy count while halving each copy's bytes
        assert_eq!(m.transfer_count(), 2 * 2 * 7);
        let mut single_sim = ShardedNetSim::sharded(&tb, 1);
        let single = run_sharded_exchange(
            &mut single_sim,
            &lanes[0].tree,
            &lanes[0].schedule,
            ShardedRoundOptions::reliable(48.0, 8, false),
        );
        assert!(
            (m.total_payload_mb() - single.total_payload_mb()).abs() < 1e-6,
            "striping must not change total exchanged bytes: {} vs {}",
            m.total_payload_mb(),
            single.total_payload_mb()
        );
    }

    #[test]
    fn full_round_disseminates_on_multi_shard_sim() {
        let cfg = quiet_cfg(12, 3);
        let tb = Testbed::new(&cfg);
        let (tree, schedule) = chain_schedule(12);
        let mut sim = ShardedNetSim::sharded(&tb, 3);
        let mut state = GossipState::new(tree, 0);
        let m = run_sharded_round(
            &mut sim,
            &mut state,
            &schedule,
            ShardedRoundOptions::reliable(5.0, 12, true),
        );
        assert!(state.is_complete());
        // every model crosses every chain edge once: 12 × 11 copies
        assert_eq!(m.transfer_count(), 132);
        assert!((m.total_payload_mb() - 132.0 * 5.0).abs() < 1e-9, "bytes conserved");
        let copies: usize = m.slot_timings.iter().map(|t| t.copies).sum();
        assert_eq!(copies, m.transfer_count());
    }
}
