//! Differential tests for the incremental per-component re-rate
//! (§Perf/L5): the lazy, dirty-component water-filling must reproduce
//! the legacy full recomputation — re-enabled via
//! `NetSim::set_full_rerate(true)` as the oracle — **bit for bit**
//! across random topologies with duplicate-hop routes, staggered
//! arrivals, same-horizon shift batches, and link drift, while never
//! doing more rate-recompute work than the oracle.

use mosgu::config::ExperimentConfig;
use mosgu::netsim::shard::ShardedNetSim;
use mosgu::netsim::testbed::Testbed;
use mosgu::netsim::{
    Channel, ChannelShift, DriftProcess, FlowRecord, LossModel, NetSim, SimCounters,
};
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;
use mosgu::{prop_assert, prop_assert_eq};

/// A fully pre-drawn workload, so the incremental and oracle runs replay
/// the exact same script (all randomness is spent before either sim runs).
struct Spec {
    chans: Vec<Channel>,
    loss: LossModel,
    overhead: f64,
    seed: u64,
    drift: Option<(DriftProcess, u64)>,
    shifts: Vec<ChannelShift>,
    /// arrival waves: advance the clock to `.0` (re-rating mid-drain),
    /// then launch the `.1` flows
    waves: Vec<(f64, Vec<(Vec<usize>, f64, u64)>)>,
}

fn random_spec(rng: &mut Pcg64) -> Spec {
    let nc = 2 + rng.gen_range(12);
    let chans: Vec<Channel> = (0..nc)
        .map(|i| Channel {
            capacity_mbps: rng.gen_f64_range(1.0, 60.0),
            latency_s: rng.gen_f64_range(0.0, 0.03),
            label: format!("c{i}").into(),
        })
        .collect();
    // half the cases exercise loss inflation: bottleneck occupancy feeds
    // the inflation factor, which the incremental path must reproduce
    let loss = if rng.gen_bool(0.5) {
        LossModel::default()
    } else {
        LossModel { gain: 0.0, size_scale_mb: 1.0 }
    };
    let drift = if rng.gen_bool(0.4) {
        Some((
            DriftProcess {
                amplitude: rng.gen_f64_range(0.05, 0.4),
                interval_s: rng.gen_f64_range(0.1, 0.8),
            },
            rng.next_u64(),
        ))
    } else {
        None
    };
    // shift batches: several channels shifting at the *same* instant must
    // collapse into one incremental recompute, not one per shift
    let mut shifts = Vec::new();
    for _ in 0..rng.gen_range(3) {
        let at = rng.gen_f64_range(0.1, 3.0);
        for _ in 0..(1 + rng.gen_range(3)) {
            shifts.push(ChannelShift {
                at_s: at,
                channel: rng.gen_range(nc),
                capacity_mbps: rng.gen_f64_range(1.0, 60.0),
                latency_s: rng.gen_f64_range(0.0, 0.03),
            });
        }
    }
    let mut waves = Vec::new();
    let mut t = 0.0;
    let mut tag = 0u64;
    for w in 0..(1 + rng.gen_range(4)) {
        if w > 0 {
            t += rng.gen_f64_range(0.05, 1.0);
        }
        let flows = (0..(1 + rng.gen_range(12)))
            .map(|_| {
                // duplicate hops allowed: a route may cross a channel twice
                let hops = 1 + rng.gen_range(4);
                let route: Vec<usize> = (0..hops).map(|_| rng.gen_range(nc)).collect();
                tag += 1;
                (route, rng.gen_f64_range(0.2, 20.0), tag)
            })
            .collect();
        waves.push((t, flows));
    }
    Spec {
        chans,
        loss,
        overhead: rng.gen_f64_range(0.0, 0.2),
        seed: rng.next_u64(),
        drift,
        shifts,
        waves,
    }
}

/// Replay `spec` in either mode; returns (final clock, records, counters,
/// payload MB launched).
fn run(spec: &Spec, full: bool) -> (f64, Vec<FlowRecord>, SimCounters, f64) {
    let mut sim = NetSim::new(spec.chans.clone(), spec.loss, spec.overhead, spec.seed);
    sim.set_full_rerate(full);
    if let Some((p, seed)) = spec.drift {
        sim.set_drift(p, seed);
    }
    if !spec.shifts.is_empty() {
        sim.schedule_shifts(spec.shifts.clone());
    }
    let mut launched = 0.0;
    for (at, flows) in &spec.waves {
        sim.advance_to(*at);
        for (route, mb, tag) in flows {
            sim.start_flow(0, 1, route.clone(), *mb, *tag);
            launched += *mb;
        }
    }
    let end = sim.run_until_idle();
    let recs = sim.take_completed();
    (end, recs, sim.counters(), launched)
}

#[test]
fn incremental_rerate_is_bit_identical_to_full_oracle() {
    check("incremental == oracle", 150, |rng| {
        let spec = random_spec(rng);
        let (end_i, rec_i, c_i, launched) = run(&spec, false);
        let (end_f, rec_f, c_f, _) = run(&spec, true);
        prop_assert_eq!(end_i.to_bits(), end_f.to_bits());
        prop_assert_eq!(rec_i.len(), rec_f.len());
        for (a, b) in rec_i.iter().zip(&rec_f) {
            prop_assert_eq!(a, b);
            prop_assert_eq!(a.start.to_bits(), b.start.to_bits());
            prop_assert_eq!(a.end.to_bits(), b.end.to_bits());
        }
        // byte conservation: every launched payload completes exactly once
        let nf: usize = spec.waves.iter().map(|(_, fl)| fl.len()).sum();
        prop_assert_eq!(rec_i.len(), nf);
        let delivered: f64 = rec_i.iter().map(|r| r.payload_mb).sum();
        prop_assert!(
            (delivered - launched).abs() < 1e-6 * launched.max(1.0),
            "bytes not conserved: launched {launched}, delivered {delivered}"
        );
        // same events walked; the incremental path never recomputes more
        prop_assert_eq!(c_i.events, c_f.events);
        prop_assert!(
            c_i.rate_recomputes <= c_f.rate_recomputes,
            "incremental did more work: {} vs oracle {}",
            c_i.rate_recomputes,
            c_f.rate_recomputes
        );
        Ok(())
    });
}

#[test]
fn event_by_event_trajectory_matches_oracle_under_shifts_and_drift() {
    // step both sims one completion at a time, comparing the clock at
    // every event — catches transient divergences an end-state
    // comparison could mask (e.g. rates wrong between two completions)
    let mk = |full: bool| {
        let chans: Vec<Channel> = (0..4)
            .map(|i| Channel {
                capacity_mbps: 6.0 + 3.0 * i as f64,
                latency_s: 0.005 * i as f64,
                label: format!("c{i}").into(),
            })
            .collect();
        let mut sim = NetSim::new(chans, LossModel::default(), 0.05, 11);
        sim.set_full_rerate(full);
        sim.set_drift(DriftProcess { amplitude: 0.25, interval_s: 0.3 }, 21);
        sim.schedule_shifts(vec![
            // two shifts sharing one horizon + a later one
            ChannelShift { at_s: 0.4, channel: 0, capacity_mbps: 2.0, latency_s: 0.01 },
            ChannelShift { at_s: 0.4, channel: 2, capacity_mbps: 30.0, latency_s: 0.0 },
            ChannelShift { at_s: 1.1, channel: 1, capacity_mbps: 4.5, latency_s: 0.02 },
        ]);
        for i in 0..10u64 {
            let route = vec![i as usize % 4, (i as usize + 1) % 4];
            sim.start_flow(0, 1, route, 1.0 + 0.7 * i as f64, i);
        }
        sim
    };
    let mut inc = mk(false);
    let mut ora = mk(true);
    loop {
        let a = inc.run_next_completion();
        let b = ora.run_next_completion();
        assert_eq!(a, b, "completion batch diverged");
        assert_eq!(inc.now().to_bits(), ora.now().to_bits(), "clock diverged mid-drain");
        if a.is_empty() {
            break;
        }
    }
    assert_eq!(inc.counters().events, ora.counters().events);
    assert!(inc.counters().rate_recomputes <= ora.counters().rate_recomputes);
}

#[test]
fn disjoint_components_do_strictly_less_recompute_work() {
    // two independent channels, staggered distinct-size flows on each: a
    // completion on one channel must not re-rate the other, so the
    // incremental pass count is strictly below the oracle's
    let mk = |full: bool| {
        let chans = vec![
            Channel { capacity_mbps: 10.0, latency_s: 0.0, label: "a".into() },
            Channel { capacity_mbps: 10.0, latency_s: 0.0, label: "b".into() },
        ];
        let mut sim = NetSim::new(chans, LossModel { gain: 0.0, size_scale_mb: 1.0 }, 0.0, 5);
        sim.set_full_rerate(full);
        for i in 0..6u64 {
            sim.start_flow(0, 1, vec![(i % 2) as usize], 1.0 + i as f64, i);
        }
        sim.run_until_idle();
        sim.counters()
    };
    let inc = mk(false);
    let ora = mk(true);
    assert_eq!(inc.events, ora.events);
    assert!(
        inc.rate_recomputes < ora.rate_recomputes,
        "disjoint completions must skip the untouched component: {} vs {}",
        inc.rate_recomputes,
        ora.rate_recomputes
    );
}

#[test]
fn sharded_sim_oracle_mode_matches_incremental() {
    // ShardedNetSim::set_full_rerate propagates to every shard (backbone
    // included); the pooled parallel drain stays bit-identical either way
    let cfg = ExperimentConfig { nodes: 16, subnets: 4, latency_jitter: 0.0, ..Default::default() };
    let tb = Testbed::new(&cfg);
    let run = |full: bool| {
        let mut sim = ShardedNetSim::sharded(&tb, 3);
        sim.set_full_rerate(full);
        for d in 0..16 {
            sim.start_flow(d, (d + 5) % 16, 6.0, d as u64); // mostly cross-subnet
            sim.start_flow(d, d ^ 1, 2.5, (100 + d) as u64); // intra pairs
        }
        let t = sim.drain_and_sync(true);
        (t, sim.take_completed(), sim.counters())
    };
    let (t_i, r_i, c_i) = run(false);
    let (t_f, r_f, c_f) = run(true);
    assert_eq!(t_i.to_bits(), t_f.to_bits());
    assert_eq!(r_i, r_f);
    assert_eq!(c_i.events, c_f.events);
    assert!(c_i.rate_recomputes <= c_f.rate_recomputes);
    assert!(c_i.events > 0 && c_i.rate_recomputes > 0, "counters must register work");
}
