//! DFL round orchestration: local training → MOSGU gossip (through the
//! network simulator for timing, with real parameter payloads moving
//! between node states) → FedAvg aggregation → next round.
//!
//! This module is what `examples/dfl_train.rs` drives end-to-end: the full
//! three-layer stack composing — Rust protocol + DES timing + PJRT
//! execution of the JAX/Pallas artifacts.

use super::trainer::{NodeModel, Trainer};
use crate::coordinator::gossip::GossipState;
use crate::coordinator::session::GossipSession;
use anyhow::Result;
use std::collections::HashMap;

/// Per-round report for the training log / loss curve.
#[derive(Debug, Clone)]
pub struct DflRoundReport {
    pub round: u64,
    /// mean local training loss across nodes (before gossip)
    pub train_loss: f32,
    /// mean eval loss across nodes after aggregation
    pub eval_loss: f32,
    /// simulated communication time of the gossip round (exchange phase)
    pub comm_time_s: f64,
    /// slots the gossip schedule used
    pub slots: usize,
    /// parameter MB a single model transfer moved
    pub model_mb: f64,
}

/// Drives `rounds` of decentralized federated learning over the session's
/// gossip tree. Returns one report per round.
///
/// Training and aggregation use the AOT artifacts; gossip *content* moves
/// real parameter vectors between node states while gossip *timing* comes
/// from the discrete-event simulator (the same dual the paper's testbed
/// had: FTP moves bytes, the protocol decides when).
pub fn run_dfl(
    session: &GossipSession,
    trainer: &Trainer,
    rounds: u64,
    local_steps: u32,
    lr: f32,
    mut on_round: impl FnMut(&DflRoundReport),
) -> Result<Vec<DflRoundReport>> {
    let n = session.tree().node_count();
    let model_mb = trainer.artifacts().model_mb();
    let mut nodes: Vec<NodeModel> =
        (0..n).map(|u| trainer.init_node(u, 0.02)).collect();
    let mut reports = Vec::new();

    for round in 0..rounds {
        // --- local training ---
        let mut train_loss = 0.0f32;
        for node in nodes.iter_mut() {
            let mut last = 0.0;
            for step in 0..local_steps {
                last = trainer.train_step(
                    node,
                    round * local_steps as u64 + step as u64,
                    lr,
                )?;
            }
            train_loss += last;
        }
        train_loss /= n as f32;

        // --- gossip (timing on the DES; payload = real parameter bytes) ---
        let metrics = session.run_mosgu_round(model_mb, 0x90551b ^ round, 0.0);

        // --- who received what: replay the same deterministic protocol ---
        let mut state = GossipState::new(session.tree().clone(), round);
        let schedule = session.schedule();
        let mut received: Vec<Vec<usize>> = vec![Vec::new(); n];
        let max_slots = 8 * n + 64;
        for slot in 0..max_slots {
            if state.is_complete() {
                break;
            }
            let planned = state.plan_slot(&schedule.transmitters(slot));
            for s in GossipState::sorted_sends(&planned) {
                if state.deliver(s) {
                    received[s.to].push(s.key.owner);
                }
            }
        }
        debug_assert!(state.is_complete());

        // --- aggregation: fold every received model pairwise (FedAvg) ---
        let snapshot: HashMap<usize, Vec<f32>> =
            nodes.iter().map(|m| (m.node, m.params.clone())).collect();
        let weights: HashMap<usize, f32> = nodes.iter().map(|m| (m.node, m.weight)).collect();
        let mut eval_loss = 0.0f32;
        for node in nodes.iter_mut() {
            node.weight = 1.0;
            for &owner in &received[node.node] {
                trainer.aggregate_into(node, &snapshot[&owner], weights[&owner])?;
            }
            eval_loss += trainer.eval(node, u64::MAX ^ round)?;
            node.weight = 1.0;
        }
        eval_loss /= n as f32;

        let report = DflRoundReport {
            round,
            train_loss,
            eval_loss,
            comm_time_s: metrics.exchange_time_s,
            slots: metrics.slots,
            model_mb,
        };
        on_round(&report);
        reports.push(report);
    }
    Ok(reports)
}

/// After full dissemination + pairwise folding, every node holds the same
/// FedAvg model; used by integration tests to assert consensus.
pub fn models_agree(nodes: &[NodeModel], atol: f32) -> bool {
    let first = &nodes[0].params;
    nodes.iter().all(|m| {
        m.params.len() == first.len()
            && m.params.iter().zip(first.iter()).all(|(a, b)| (a - b).abs() <= atol)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn models_agree_detects_divergence() {
        let a = NodeModel { node: 0, params: vec![1.0, 2.0], weight: 1.0 };
        let mut b = a.clone();
        b.node = 1;
        assert!(models_agree(&[a.clone(), b.clone()], 1e-6));
        b.params[1] = 3.0;
        assert!(!models_agree(&[a, b], 1e-6));
    }
}
