//! Regenerates **Table IV** — average time (s) for one transfer, broadcast
//! vs MOSGU, per topology × model.
//!
//! Paper reference values: broadcast 6.5 s (v3s) → 62.6 s (b3);
//! proposed 2.2–10.4 s (improvements 2.6–7.4×).

use mosgu::bench::tables::{all_models, render, run_grid, PaperTable};
use mosgu::bench::section;
use mosgu::config::ExperimentConfig;
use mosgu::graph::topology::TopologyKind;

fn main() {
    let cfg = ExperimentConfig::default();
    section("Table IV: average single-transfer time grid");
    let cells = run_grid(&cfg, &TopologyKind::ALL, &all_models(), |s| eprintln!("  {s}"))
        .expect("grid");
    println!("{}", render(PaperTable::TransferTime, &cells));

    // per-size-category summary (paper §V-A's small/medium/large reading)
    section("improvement factor by size category");
    for (cat, codes) in [
        ("small", vec!["v3s", "v2"]),
        ("medium", vec!["b0", "v3l"]),
        ("large", vec!["b1", "b2", "b3"]),
    ] {
        let mut ratio = 0.0;
        let mut count = 0;
        for c in &cells {
            if codes.contains(&c.model.as_str()) {
                ratio += c.broadcast.transfer.mean() / c.proposed.transfer.mean();
                count += 1;
            }
        }
        println!("  {cat:<7} mean transfer-time improvement: {:.2}x", ratio / count as f64);
    }
}
