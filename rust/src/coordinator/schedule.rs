//! Slot scheduling (paper §III-C, "S — Schedule communication").
//!
//! Nodes sharing a color transmit in the same timeslot; the two (tree)
//! color classes alternate. The slot length is fixed per round from the
//! paper's formula
//!
//! ```text
//! slot = ping_max × M_size × 1000 / ping_size   [seconds]
//! ```
//!
//! with `ping_max` the largest neighbor ping among nodes of the class
//! (seconds — the paper prints "ms" but the formula is only dimensionally
//! sensible with seconds; see DESIGN.md), `M_size` the transmitted model
//! size in MB and `ping_size` the ping probe payload in bytes. Intuition:
//! ping measures per-byte path cost at probe size; scaling to the model's
//! byte count budgets a full transfer.
//!
//! Under a segment-granular transfer plan the unit a transmitter moves
//! per turn is one *segment*, so `M_size` becomes the segment size: the
//! session feeds `TransferPlan::segment_mb()` into the moderator's
//! [`build_schedule`] call (see `GossipSession::with_model`), shrinking
//! the budget by the segment count while cut-through relays overlap the
//! per-hop transfers the old whole-model slots serialized. With
//! `segments = 1` the fed unit is the checkpoint itself, bit for bit.
//!
//! Under **hierarchical planning** (`coordinator::hierarchy`) the
//! coloring handed to [`build_schedule`] is the stitched per-subnet
//! coloring; the formula itself is untouched — `ping_max` still ranges
//! over every node's gossip neighbors in the full cost graph, so the
//! worst (typically backbone/gateway) edge budgets the slot for both
//! color classes, exactly as the flat §III-C schedule would.

use crate::coloring::Coloring;
use crate::graph::Graph;

/// The moderator's computed schedule for one communication round.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// 2-coloring (or k-coloring on non-tree schedules) of the gossip graph.
    pub coloring: Coloring,
    /// Seconds budgeted per slot, per the paper's formula.
    pub slot_len_s: f64,
    /// Color transmitting in slot 0 (the paper's Table I starts with red).
    pub first_color: usize,
}

impl Schedule {
    /// Color transmitting in slot `i` (alternating over all classes).
    pub fn color_of_slot(&self, slot: usize) -> usize {
        let k = self.coloring.num_colors().max(1);
        (self.first_color + slot) % k
    }

    /// Transmitting nodes of slot `i`.
    pub fn transmitters(&self, slot: usize) -> Vec<usize> {
        self.coloring.class(self.color_of_slot(slot))
    }

    /// Whether node `u` transmits in slot `i` — the membership test the
    /// adaptive pipeline uses so rounds planned on different schedule
    /// epochs can share one slot counter (see
    /// `coordinator::engine::RoundEngine::run_pipelined_adaptive`).
    pub fn transmits_in_slot(&self, u: usize, slot: usize) -> bool {
        self.coloring.color_of(u) == self.color_of_slot(slot)
    }
}

/// `ping_max` for a color class: the paper first takes each node's maximum
/// ping to its (gossip-graph) neighbors, then the maximum of those values
/// over the nodes of the class. Pings are edge weights in **ms**.
pub fn class_ping_max_ms(costs: &Graph, coloring: &Coloring, color: usize) -> f64 {
    let mut worst: f64 = 0.0;
    for u in coloring.class(color) {
        for &(_, w) in costs.neighbors(u) {
            worst = worst.max(w);
        }
    }
    worst
}

/// The paper's slot-length formula. `ping_max_ms` is converted to
/// seconds; `model_mb` is the size of one transfer unit — the checkpoint
/// under a whole-model plan, one segment (`TransferPlan::segment_mb`)
/// under a segmented one.
pub fn slot_length_s(ping_max_ms: f64, model_mb: f64, ping_size_bytes: u64) -> f64 {
    assert!(ping_size_bytes > 0);
    let ping_max_s = ping_max_ms / 1e3;
    ping_max_s * model_mb * 1000.0 / ping_size_bytes as f64
}

/// Build the full schedule: worst `ping_max` across classes (both classes
/// get the same fixed slot length), paper formula, red-first ordering.
pub fn build_schedule(
    costs: &Graph,
    coloring: Coloring,
    model_mb: f64,
    ping_size_bytes: u64,
    first_color: usize,
) -> Schedule {
    let ping_max_ms = (0..coloring.num_colors())
        .map(|c| class_ping_max_ms(costs, &coloring, c))
        .fold(0.0, f64::max);
    let slot_len_s = slot_length_s(ping_max_ms, model_mb, ping_size_bytes);
    Schedule { coloring, slot_len_s, first_color }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::bfs_coloring;
    use crate::dfl::transfer::TransferPlan;

    fn path3_costs() -> Graph {
        let mut g = Graph::new(3);
        g.add_edge(0, 1, 10.0);
        g.add_edge(1, 2, 30.0);
        g
    }

    #[test]
    fn slot_formula_matches_paper_units() {
        // ping_max 25 ms, model 11.6 MB, probe 56 B -> 0.025*11.6*1000/56 ≈ 5.18 s
        let s = slot_length_s(25.0, 11.6, 56);
        assert!((s - 5.178571).abs() < 1e-3, "s={s}");
    }

    #[test]
    fn slot_scales_linearly_with_model_size() {
        let small = slot_length_s(20.0, 10.0, 56);
        let large = slot_length_s(20.0, 40.0, 56);
        assert!((large / small - 4.0).abs() < 1e-9);
    }

    #[test]
    fn class_ping_max_takes_worst_neighbor_edge() {
        let g = path3_costs();
        let col = bfs_coloring(&g); // 0,1,0
        // class 0 = {0,2}: node 0 worst 10, node 2 worst 30 -> 30
        assert_eq!(class_ping_max_ms(&g, &col, 0), 30.0);
        // class 1 = {1}: worst(10,30) = 30
        assert_eq!(class_ping_max_ms(&g, &col, 1), 30.0);
    }

    #[test]
    fn schedule_alternates_colors_from_first() {
        let g = path3_costs();
        let sched = build_schedule(&g, bfs_coloring(&g), 10.0, 56, 1);
        assert_eq!(sched.color_of_slot(0), 1);
        assert_eq!(sched.color_of_slot(1), 0);
        assert_eq!(sched.color_of_slot(2), 1);
        assert_eq!(sched.transmitters(0), vec![1]);
        assert_eq!(sched.transmitters(1), vec![0, 2]);
    }

    #[test]
    fn adjacent_nodes_never_share_a_slot() {
        let g = path3_costs();
        let sched = build_schedule(&g, bfs_coloring(&g), 10.0, 56, 0);
        for slot in 0..4 {
            let tx = sched.transmitters(slot);
            for (i, &u) in tx.iter().enumerate() {
                for &v in &tx[i + 1..] {
                    assert!(!g.has_edge(u, v), "slot {slot} has adjacent {u},{v}");
                }
            }
        }
    }

    #[test]
    fn segment_slot_budget_scales_with_plan() {
        // the formula fed a plan's transfer unit: whole plan = checkpoint
        // bits exactly, k-segment plan = budget divided by k
        let whole = slot_length_s(25.0, TransferPlan::whole(48.0).segment_mb(), 56);
        assert_eq!(whole.to_bits(), slot_length_s(25.0, 48.0, 56).to_bits());
        let quartered = slot_length_s(25.0, TransferPlan::segmented(48.0, 4).segment_mb(), 56);
        assert!((whole / quartered - 4.0).abs() < 1e-9);
    }

    #[test]
    fn schedule_slot_len_uses_worst_class() {
        let g = path3_costs();
        let sched = build_schedule(&g, bfs_coloring(&g), 11.6, 56, 0);
        let expect = slot_length_s(30.0, 11.6, 56);
        assert!((sched.slot_len_s - expect).abs() < 1e-12);
    }
}
