//! Property tests for the scale-out generator suite
//! (`graph::generators`): seeded determinism, connectivity, degree and
//! edge-count bounds, and the hierarchy invariants the planner and the
//! sharded simulator rely on (every node in exactly one subnet, gateways
//! connected by the backbone, crossings only at gateways).

use mosgu::coordinator::hierarchy::plan_hierarchical;
use mosgu::graph::generators::{random_geometric, router_hierarchy, Hierarchy};
use mosgu::graph::Graph;
use mosgu::mst::MstAlgorithm;
use mosgu::coloring::ColoringAlgorithm;
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;
use mosgu::{prop_assert, prop_assert_eq};

fn same_edges(a: &Graph, b: &Graph) -> bool {
    a.edge_count() == b.edge_count()
        && a.sorted_edges()
            .iter()
            .zip(b.sorted_edges().iter())
            .all(|(x, y)| (x.u, x.v) == (y.u, y.v) && x.weight.to_bits() == y.weight.to_bits())
}

#[test]
fn geometric_is_deterministic_and_connected() {
    check("geometric determinism + connectivity", 80, |rng| {
        let n = 4 + rng.gen_range(60);
        let radius = rng.gen_f64_range(0.05, 0.6);
        let seed = rng.next_u64();
        let a = random_geometric(n, radius, &mut Pcg64::new(seed));
        let b = random_geometric(n, radius, &mut Pcg64::new(seed));
        prop_assert!(same_edges(&a, &b), "same seed must yield identical graphs");
        prop_assert!(a.is_connected(), "n={n} radius={radius} disconnected");
        prop_assert_eq!(a.node_count(), n);
        // connected on n nodes => at least a spanning tree's edges
        prop_assert!(a.edge_count() >= n - 1, "edge count below tree bound");
        Ok(())
    });
}

#[test]
fn geometric_edge_count_grows_with_radius() {
    check("geometric radius monotonicity", 40, |rng| {
        let n = 10 + rng.gen_range(40);
        let seed = rng.next_u64();
        // same positions (same seed), nested radii => nested raw edge
        // sets; Borůvka stitching adds at most ~2·components ≤ 2n extra
        // edges to the sparser graph
        let small = random_geometric(n, 0.15, &mut Pcg64::new(seed));
        let large = random_geometric(n, 0.6, &mut Pcg64::new(seed));
        prop_assert!(
            large.edge_count() + 2 * n >= small.edge_count(),
            "radius growth lost edges: {} vs {}",
            large.edge_count(),
            small.edge_count()
        );
        // the full-diagonal radius yields the complete graph
        let complete = random_geometric(n, 1.5, &mut Pcg64::new(seed));
        prop_assert_eq!(complete.edge_count(), n * (n - 1) / 2);
        Ok(())
    });
}

#[test]
fn hierarchy_generator_invariants() {
    check("router hierarchy invariants", 80, |rng| {
        let subnets = 1 + rng.gen_range(8);
        let per = 2 + rng.gen_range(12);
        let n = (subnets * per).max(2);
        let gateway_links = 1 + rng.gen_range(3);
        let intra_k = 2 + 2 * rng.gen_range(3);
        let seed = rng.next_u64();
        let (g, h) = router_hierarchy(n, subnets, gateway_links, intra_k, &mut Pcg64::new(seed));
        let (g2, h2) = router_hierarchy(n, subnets, gateway_links, intra_k, &mut Pcg64::new(seed));
        prop_assert!(same_edges(&g, &g2), "same seed must yield identical graphs");
        prop_assert_eq!(h, h2);

        prop_assert!(g.is_connected());
        prop_assert_eq!(h.node_count(), n);
        prop_assert_eq!(h.subnet_count(), subnets);
        // every node in exactly one subnet, round-robin like the testbed
        let mut counts = vec![0usize; subnets];
        for u in 0..n {
            prop_assert_eq!(h.subnet(u), u % subnets);
            counts[h.subnet(u)] += 1;
        }
        prop_assert!(counts.iter().all(|&c| c >= 1), "empty subnet");
        // gateways are members of their own subnet
        for s in 0..subnets {
            prop_assert_eq!(h.subnet(h.gateway(s)), s);
        }
        // crossings only at gateways; backbone (gateway-gateway edges)
        // connects every subnet
        let mut backbone = Graph::new(subnets);
        for e in g.edges() {
            let (su, sv) = (h.subnet(e.u), h.subnet(e.v));
            if su != sv {
                prop_assert!(
                    h.is_gateway(e.u) && h.is_gateway(e.v),
                    "crossing edge off the backbone"
                );
                if !backbone.has_edge(su, sv) {
                    backbone.add_edge(su, sv, 1.0);
                }
            }
        }
        if subnets > 1 {
            prop_assert!(backbone.is_connected(), "backbone does not span the subnets");
            // each gateway keeps >= gateway_links backbone links (ring +
            // chords; capped by the number of other subnets)
            let reach = gateway_links.min(subnets - 1);
            for s in 0..subnets {
                prop_assert!(
                    backbone.degree(s) >= reach.min(backbone.node_count() - 1),
                    "subnet {s} under-linked"
                );
            }
        }
        // degree bound: lattice degree + chords + backbone
        let max_intra = intra_k + per; // lattice ~intra_k plus at most len/4 chords each way
        for u in 0..n {
            let cap = max_intra + if h.is_gateway(u) { 2 * subnets } else { 0 };
            prop_assert!(g.degree(u) <= cap, "node {u} degree {} > {cap}", g.degree(u));
        }
        Ok(())
    });
}

#[test]
fn hierarchical_plans_are_proper_on_random_hierarchies() {
    check("hierarchical planning invariants", 60, |rng| {
        let subnets = 1 + rng.gen_range(6);
        let n = (subnets * (3 + rng.gen_range(8))).max(2);
        let (structure, h) =
            router_hierarchy(n, subnets, 2, 4, &mut Pcg64::new(rng.next_u64()));
        // ping-like weights, intra cheap / backbone expensive
        let mut costs = Graph::new(n);
        for e in structure.sorted_edges() {
            let cross = h.subnet(e.u) != h.subnet(e.v);
            let base = if cross { 20.0 } else { 1.0 };
            costs.add_edge(e.u, e.v, base * (1.0 + rng.gen_f64()));
        }
        let epoch = plan_hierarchical(
            &costs,
            &h,
            MstAlgorithm::Prim,
            ColoringAlgorithm::Bfs,
            14.0,
            56,
            1,
        )
        .map_err(|e| format!("planning failed: {e}"))?;
        prop_assert!(epoch.tree.is_tree());
        prop_assert_eq!(epoch.tree.node_count(), n);
        prop_assert!(epoch.schedule.coloring.is_proper(&epoch.tree));
        prop_assert!(epoch.schedule.slot_len_s > 0.0);
        for e in epoch.tree.edges() {
            if h.subnet(e.u) != h.subnet(e.v) {
                prop_assert!(h.is_gateway(e.u) && h.is_gateway(e.v));
            }
        }
        Ok(())
    });
}

#[test]
fn flat_hierarchy_plan_matches_flat_planner() {
    check("single-subnet plan == flat plan", 40, |rng| {
        let n = 4 + rng.gen_range(20);
        let (structure, _) = router_hierarchy(n, 1, 2, 4, &mut Pcg64::new(rng.next_u64()));
        let mut costs = Graph::new(n);
        for e in structure.sorted_edges() {
            costs.add_edge(e.u, e.v, rng.gen_f64_range(1.0, 50.0));
        }
        let flat_tree = MstAlgorithm::Prim.run(&costs).map_err(|e| e.to_string())?;
        let epoch = plan_hierarchical(
            &costs,
            &Hierarchy::flat(n),
            MstAlgorithm::Prim,
            ColoringAlgorithm::Bfs,
            14.0,
            56,
            1,
        )
        .map_err(|e| e.to_string())?;
        prop_assert!(same_edges(&epoch.tree, &flat_tree), "tree diverged from flat MST");
        let flat_col = ColoringAlgorithm::Bfs.run(&flat_tree);
        prop_assert_eq!(epoch.schedule.coloring.assignment(), flat_col.assignment());
        Ok(())
    });
}
