//! DFL round orchestration: local training → MOSGU gossip (through the
//! event-driven round engine, with real parameter payloads moving
//! between node states) → FedAvg aggregation → next round.
//!
//! Communication for **all** rounds runs through one long-lived
//! simulator via [`GossipSession::run_pipelined_rounds`]: a node seeds
//! round `t+1` the moment it has aggregated round `t`, so next-round
//! seeds gossip in slots round `t` has vacated (§III-D). Training and
//! aggregation then replay in causal round order using the engine's
//! actual per-node reception orders — gossip *content* moves real
//! parameter vectors while gossip *timing* comes from the pipelined
//! discrete-event run (the same dual the paper's testbed had: FTP moves
//! bytes, the protocol decides when).
//!
//! The learning-dynamics scenario zoo rides this loop: `--dirichlet-alpha`
//! shards each node's synthetic data non-IID, `--participation` trains
//! and originates only a seeded per-round subset (the engine prunes the
//! matching slots), `--straggler-*` delays slow trainers' first transmit
//! opportunities, and `--algo dpsgd` swaps the full-dissemination FedAvg
//! fold for Metropolis neighbor mixing over the tree. All dormant by
//! default.
//!
//! This module is what `examples/dfl_train.rs` drives end-to-end: the full
//! three-layer stack composing — Rust protocol + DES timing + PJRT
//! execution of the JAX/Pallas artifacts.

use super::compress::ErrorFeedback;
use super::data::{self, AlgoKind, STRIDE_CLASSES};
use super::trainer::{NodeModel, Trainer};
use crate::coordinator::session::GossipSession;
use crate::coordinator::engine::PipelineMetrics;
use anyhow::Result;

/// Per-round report for the training log / loss curve.
#[derive(Debug, Clone)]
pub struct DflRoundReport {
    pub round: u64,
    /// mean local training loss across participating nodes (before gossip)
    pub train_loss: f32,
    /// mean eval loss across nodes after aggregation
    pub eval_loss: f32,
    /// accuracy proxy `1 / (1 + eval_loss)` — the scenario zoo's
    /// accuracy-vs-round / accuracy-vs-wire curve ordinate
    pub accuracy: f64,
    /// simulated communication time of the gossip round (exchange phase,
    /// measured from the round's first seed)
    pub comm_time_s: f64,
    /// slots the round's traffic was active in
    pub slots: usize,
    /// logical parameter MB a single model transfer represents
    pub model_mb: f64,
    /// MB a single model copy actually moved on the wire (== `model_mb`
    /// with `compress = none`)
    pub wire_mb: f64,
    /// cumulative wire MB the pipeline had moved by this round's full
    /// dissemination — the accuracy-vs-wire-MB curve abscissa
    pub cum_wire_mb: f64,
    /// wire segments each model copy traveled as (1 = whole-model)
    pub segments: usize,
    /// absolute pipeline time the round's first seed entered the engine
    pub start_s: f64,
    /// absolute pipeline time the round fully disseminated
    pub done_s: f64,
}

/// Cumulative wire MB moved by each round's `done_s`: transfer records are
/// sorted by completion time (the driver emits them slot-ordered, but flows
/// *within* a slot drain in arbitrary order) and swept once against the
/// per-round phase deadlines. Attribution is by wall clock, not by round
/// tag — with pipelining, round `t+1` bytes in flight before round `t`
/// retires are honestly charged to the earlier point on the curve.
pub fn cumulative_wire_mb(pipeline: &PipelineMetrics) -> Vec<f64> {
    let mut done: Vec<(f64, f64)> =
        pipeline.transfers.iter().map(|t| (t.end, t.payload_mb)).collect();
    done.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut cum = Vec::with_capacity(pipeline.rounds.len());
    let mut total = 0.0f64;
    let mut i = 0;
    for phase in &pipeline.rounds {
        while i < done.len() && done[i].0 <= phase.done_s + 1e-9 {
            total += done[i].1;
            i += 1;
        }
        cum.push(total);
    }
    cum
}

/// Drives `rounds` of decentralized federated learning over the session's
/// gossip tree. Returns one report per round.
///
/// Training and aggregation use the AOT artifacts; communication timing
/// and per-node reception orders come from one pipelined multi-round
/// engine run over a shared simulator (see the module docs).
pub fn run_dfl(
    session: &GossipSession,
    trainer: &Trainer,
    rounds: u64,
    local_steps: u32,
    lr: f32,
    mut on_round: impl FnMut(&DflRoundReport),
) -> Result<Vec<DflRoundReport>> {
    let n = session.tree().node_count();
    anyhow::ensure!(n > 0, "cannot run DFL over an empty session (n = 0)");
    let model_mb = trainer.artifacts().model_mb();
    let cfg = session.config();

    // one long-lived simulator for every round's gossip, with
    // multi-round pipelining; content-free, so it can run up front. The
    // session's transfer plan decides whether checkpoints move whole or
    // as cut-through-forwarded segments (--segments / --segment-mb), the
    // dynamic network plane (--drift / --probe-every / --replan-threshold)
    // drifts links and re-plans mid-session, and the scenario zoo prunes
    // non-participants' slots and holds stragglers (--participation /
    // --straggler-*); with the static defaults this is the plain pipeline
    // bit for bit.
    let pipeline = session.run_adaptive_rounds(model_mb, rounds, 0x90551b);
    anyhow::ensure!(
        pipeline.rounds.len() == rounds as usize,
        "pipeline completed {} of {rounds} rounds",
        pipeline.rounds.len()
    );
    let cum_wire = cumulative_wire_mb(&pipeline);

    // per-node Dirichlet class mixtures (--dirichlet-alpha; None = the
    // legacy fixed node%5 class with byte-identical batches)
    let shares: Option<Vec<Vec<f64>>> = if cfg.dirichlet_alpha.is_finite() {
        Some(data::trainer_shares(cfg.dirichlet_alpha, n, STRIDE_CLASSES, cfg.seed))
    } else {
        None
    };
    let node_shares = |u: usize| shares.as_ref().map(|s| s[u].as_slice());
    // who trains/originates each round (--participation; None = everyone)
    let participation = session.participation_plan(rounds);
    let originates = |round: u64, u: usize| {
        participation.as_ref().map_or(true, |p| p.originates(round, u))
    };

    let mut nodes: Vec<NodeModel> =
        (0..n).map(|u| trainer.init_node(u, 0.02, cfg.seed)).collect();
    let mut reports = Vec::new();

    // payload compression (--compress quant|topk): each node encodes
    // `params + residual` at snapshot time and gossips the *decoded*
    // payload, carrying the codec error forward as an error-feedback
    // residual. With compress = none this plumbing is skipped entirely
    // and the loop is the legacy full-width path.
    let codec = cfg.compression();
    let dim = nodes.first().map_or(0, |m| m.params.len());
    let mut feedback: Vec<ErrorFeedback> = if codec.is_none() {
        Vec::new()
    } else {
        (0..n).map(|_| ErrorFeedback::new(dim)).collect()
    };
    let wire_mb = session.transfer_plan(model_mb).wire_mb();
    // robust-aggregation policy (--fold); Mean is the legacy pairwise path
    let policy = session.fold_policy();
    let algo = cfg.algo;

    for round in 0..rounds {
        // --- local training (participants only — a sampled-out node's
        // clock advances but its model does not) ---
        let mut train_loss = 0.0f32;
        let mut trained = 0u32;
        for node in nodes.iter_mut() {
            if !originates(round, node.node) {
                continue;
            }
            let mut last = 0.0;
            for step in 0..local_steps {
                last = trainer.train_step_shares(
                    node,
                    round * local_steps as u64 + step as u64,
                    lr,
                    node_shares(node.node),
                )?;
            }
            train_loss += last;
            trained += 1;
        }
        train_loss /= trained.max(1) as f32;

        // --- aggregation: fold every received model under the session's
        // fold policy, in the engine's actual delivery order for this
        // round. `--fold mean` replays the legacy pairwise FedAvg
        // artifact sequence verbatim; the robust policies fold the
        // canonical owner-sorted candidate set CPU-side; `--algo dpsgd`
        // instead mixes only with tree neighbors under Metropolis
        // weights. Under a compression codec the snapshot is each
        // originator's decoded (wire-visible) payload, and the sender
        // adopts that decoded payload as its own fold contribution too —
        // so every node averages the identical vector set and consensus
        // stays exact; the residual carries the codec error into the
        // next round. An active adversary corrupts the snapshot exactly
        // where a real Byzantine node would: between local training and
        // the wire. ---
        let received = &pipeline.received[round as usize];
        // non-originators ship nothing: their slot in the snapshot table
        // stays empty and their error-feedback residual is untouched
        let mut snapshot: Vec<Vec<f32>> = nodes
            .iter()
            .map(|m| {
                if !originates(round, m.node) {
                    Vec::new()
                } else if codec.is_none() {
                    m.params.clone()
                } else {
                    feedback[m.node].compress(&m.params, &codec)
                }
            })
            .collect();
        if let Some(scenario) = session.adversary() {
            scenario.corrupt_snapshot(&mut snapshot, round, cfg.seed);
        }
        let weights: Vec<f32> = nodes.iter().map(|m| m.weight).collect();
        let mut eval_loss = 0.0f32;
        for node in nodes.iter_mut() {
            let u = node.node;
            node.weight = 1.0;
            if !codec.is_none() && originates(round, u) {
                node.params = snapshot[u].clone();
            }
            match algo {
                AlgoKind::FedAvg => {
                    let payloads: Vec<(usize, &[f32], f32)> = received[u]
                        .iter()
                        .map(|&owner| (owner, snapshot[owner].as_slice(), weights[owner]))
                        .collect();
                    trainer.fold_received(node, &payloads, &policy)?;
                }
                AlgoKind::DPsgd => {
                    // D-PSGD mixes only with tree-neighbor payloads that
                    // actually arrived (and were originated) this round
                    let tree = session.tree();
                    let peers: Vec<(usize, &[f32])> = received[u]
                        .iter()
                        .filter(|&&o| tree.neighbors(u).iter().any(|&(v, _)| v == o))
                        .map(|&o| (o, snapshot[o].as_slice()))
                        .collect();
                    node.params = data::dpsgd_mix(tree, u, &node.params, &peers);
                }
            }
            eval_loss += trainer.eval_shares(node, u64::MAX ^ round, node_shares(u))?;
            node.weight = 1.0;
        }
        eval_loss /= n as f32;

        let phase = &pipeline.rounds[round as usize];
        let report = DflRoundReport {
            round,
            train_loss,
            eval_loss,
            accuracy: data::accuracy_proxy(eval_loss as f64),
            comm_time_s: phase.exchange_done_s - phase.first_seed_s,
            slots: phase.slot_span(),
            model_mb,
            wire_mb,
            cum_wire_mb: cum_wire[round as usize],
            segments: pipeline.segments,
            start_s: phase.first_seed_s,
            done_s: phase.done_s,
        };
        on_round(&report);
        reports.push(report);
    }
    Ok(reports)
}

/// After full dissemination + pairwise folding, every node holds the same
/// FedAvg model; used by integration tests to assert consensus. An empty
/// slice agrees vacuously (it must not panic — callers may filter down to
/// the honest subset first).
pub fn models_agree(nodes: &[NodeModel], atol: f32) -> bool {
    let Some(first) = nodes.first() else {
        return true;
    };
    let first = &first.params;
    nodes.iter().all(|m| {
        m.params.len() == first.len()
            && m.params.iter().zip(first.iter()).all(|(a, b)| (a - b).abs() <= atol)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn models_agree_detects_divergence() {
        let a = NodeModel { node: 0, params: vec![1.0, 2.0], weight: 1.0 };
        let mut b = a.clone();
        b.node = 1;
        assert!(models_agree(&[a.clone(), b.clone()], 1e-6));
        b.params[1] = 3.0;
        assert!(!models_agree(&[a, b], 1e-6));
    }

    #[test]
    fn models_agree_handles_empty_and_singleton_slices() {
        // `nodes[0]` used to panic on an empty slice — honest-subset
        // filtering under a Byzantine scenario can legitimately hit it
        assert!(models_agree(&[], 1e-6));
        let a = NodeModel { node: 0, params: vec![1.0], weight: 1.0 };
        assert!(models_agree(&[a], 1e-6));
    }

    #[test]
    fn pipeline_reception_orders_feed_full_aggregation() {
        // without artifacts we can still assert the engine hands the DFL
        // layer complete per-round fold inputs
        let cfg = crate::config::ExperimentConfig {
            latency_jitter: 0.0,
            ..Default::default()
        };
        let session = GossipSession::new(&cfg).unwrap();
        let p = session.run_pipelined_rounds(5.0, 2, 0x90551b);
        assert_eq!(p.received.len(), 2);
        for round in &p.received {
            for (u, order) in round.iter().enumerate() {
                assert_eq!(order.len(), 9, "node {u} must fold all peers");
                assert!(!order.contains(&u), "own model is not re-folded");
            }
        }
        // report-facing timings are well-formed
        for phase in &p.rounds {
            assert!(phase.exchange_done_s > phase.first_seed_s);
            assert!(phase.slot_span() > 10);
        }
    }

    #[test]
    fn segmented_pipeline_hands_dfl_full_fold_inputs() {
        // a segmented transfer plan must not change what the aggregation
        // layer sees: complete per-round reception orders for every node
        let cfg = crate::config::ExperimentConfig {
            segments: 4,
            latency_jitter: 0.0,
            ..Default::default()
        };
        let session = GossipSession::new(&cfg).unwrap();
        let p = session.run_pipelined_rounds(21.6, 2, 0x90551b);
        assert_eq!(p.segments, 4);
        assert_eq!(p.received.len(), 2);
        for round in &p.received {
            for (u, order) in round.iter().enumerate() {
                assert_eq!(order.len(), 9, "node {u} must fold all peers");
                assert!(!order.contains(&u), "own model is not re-folded");
            }
        }
    }

    #[test]
    fn cumulative_wire_mb_is_monotone_and_conserves_bytes() {
        let cfg = crate::config::ExperimentConfig {
            latency_jitter: 0.0,
            ..Default::default()
        };
        let session = GossipSession::new(&cfg).unwrap();
        let p = session.run_pipelined_rounds(5.0, 3, 0x90551b);
        let cum = cumulative_wire_mb(&p);
        assert_eq!(cum.len(), 3);
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "cumulative curve must be monotone");
        // by the last round's done_s every launched transfer has drained
        let total: f64 = p.transfers.iter().map(|t| t.payload_mb).sum();
        assert!((cum[2] - total).abs() < 1e-6, "cum {} vs total {}", cum[2], total);
    }
}
