//! Scale sweep: wall-clock cost of simulating one gossip-round exchange
//! phase on the sequential single-queue simulator vs the sharded
//! per-subnet simulator, over router-hierarchy overlays of growing n.
//!
//! The exchange phase (every node's own model to each tree neighbor) is
//! the blocking part of an FL round — Table V's indicator; the O(n²)
//! dissemination tail pipelines with later rounds (§III-D) — and is the
//! unit large-n scenarios are measured in. Both simulators run the *same*
//! topology and hierarchical plan; only the event-queue decomposition
//! differs, so the comparison isolates simulator scalability.
//!
//! Emits one `JSON {...}` line per cell; CI uploads them as the
//! `scale-sweep` artifact. Full mode gates on the ISSUE-4 acceptance
//! bar: a 32-subnet hierarchy at n = 10 000 must complete with
//! byte-conserving metrics and run ≥ 4× faster sharded than sequential
//! (mirrored by the `#[ignore]`d release test in `tests/scale_shard.rs`).
//!
//! ```bash
//! cargo bench --bench scale_sweep             # full grid incl. n = 10k + gate
//! cargo bench --bench scale_sweep -- --smoke  # CI subset (n <= 1k, no gate)
//! ```

use mosgu::bench::section;
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::ScaleScenario;
use std::time::Instant;

const MODEL_MB: f64 = 14.0;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let grid: &[(usize, usize)] = if smoke {
        &[(100, 8), (1_000, 32)]
    } else {
        &[(100, 8), (1_000, 32), (10_000, 32)]
    };

    section(&format!(
        "scale sweep: sequential vs sharded netsim, exchange phase ({} mode)",
        if smoke { "smoke" } else { "full" }
    ));
    println!(
        "{:>7} {:>8} {:>7} {:>11} {:>12} {:>12} {:>9} {:>12}",
        "n", "subnets", "copies", "sim_s", "wall_seq_s", "wall_shard_s", "speedup", "bytes_ok"
    );

    let mut ok = true;
    for &(n, subnets) in grid {
        let cfg = ExperimentConfig {
            nodes: n,
            subnets,
            // ties batch completions; per-transfer jitter would explode
            // the sequential event count (docs/EXPERIMENTS.md §Scale-out)
            latency_jitter: 0.0,
            ..Default::default()
        };
        let scenario = ScaleScenario::new(&cfg, MODEL_MB).expect("scenario");

        let t0 = Instant::now();
        let seq = scenario.run_exchange(MODEL_MB, 1, 0.0, false, false);
        let wall_seq = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let shard = scenario.run_exchange(MODEL_MB, 1, 0.0, true, true);
        let wall_shard = t1.elapsed().as_secs_f64();
        let speedup = wall_seq / wall_shard.max(1e-9);

        // byte conservation: 2(n-1) own-model copies of MODEL_MB each,
        // delivered exactly once on both simulators
        let expect_copies = 2 * (n - 1);
        let expect_mb = expect_copies as f64 * MODEL_MB;
        let bytes_ok = seq.transfer_count() == expect_copies
            && shard.transfer_count() == expect_copies
            && (seq.total_payload_mb() - expect_mb).abs() < 1e-6 * expect_mb
            && (shard.total_payload_mb() - expect_mb).abs() < 1e-6 * expect_mb;
        assert!(bytes_ok, "byte conservation violated at n={n}");

        println!(
            "{:>7} {:>8} {:>7} {:>11.3} {:>12.4} {:>12.4} {:>8.2}x {:>12}",
            n,
            subnets,
            seq.transfer_count(),
            shard.total_time_s,
            wall_seq,
            wall_shard,
            speedup,
            bytes_ok
        );
        println!(
            "JSON {{\"bench\":\"scale_sweep\",\"n\":{n},\"subnets\":{subnets},\
             \"copies\":{},\"model_mb\":{MODEL_MB},\
             \"sim_seq_s\":{:.6},\"sim_shard_s\":{:.6},\
             \"wall_seq_s\":{:.6},\"wall_shard_s\":{:.6},\"speedup\":{:.4},\
             \"payload_mb\":{:.3},\"bytes_conserved\":{bytes_ok}}}",
            seq.transfer_count(),
            seq.total_time_s,
            shard.total_time_s,
            wall_seq,
            wall_shard,
            speedup,
            shard.total_payload_mb(),
        );

        if n >= 10_000 {
            let pass = speedup >= 4.0;
            ok &= pass;
            println!(
                "  acceptance n={n}: sharded {:.3}s vs sequential {:.3}s -> {:.2}x ({})",
                wall_shard,
                wall_seq,
                speedup,
                if pass { "pass (>= 4x)" } else { "FAIL (< 4x)" }
            );
        }
    }

    if smoke {
        println!("acceptance: skipped in smoke mode (needs the n=10k cell; run without --smoke)");
    } else {
        println!("acceptance: {}", if ok { "pass" } else { "FAIL" });
        if !ok {
            std::process::exit(1);
        }
    }
}
