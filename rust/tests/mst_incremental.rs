//! Differential property tests for `mst::incremental`: after **any**
//! single-edge weight change, the union-find edge-swap update must land
//! on a minimum spanning tree of the new costs — pinned against
//! from-scratch Kruskal, Prim and Borůvka (total weight; ties permit
//! different but equally-optimal edge sets) across every paper topology
//! family, ≥ 256 cases per property.

use mosgu::graph::topology::{self, TopologyKind, TopologyParams};
use mosgu::graph::Graph;
use mosgu::mst::incremental::{update_edge_weight, update_mst};
use mosgu::mst::{boruvka, is_spanning_tree_of, kruskal, prim};
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;

/// A random connected structure from one of the eight topology families,
/// re-weighted with random (almost surely distinct) positive costs.
fn random_costs(rng: &mut Pcg64) -> Graph {
    let kind = TopologyKind::EXTENDED[rng.gen_range(TopologyKind::EXTENDED.len())];
    let n = 4 + rng.gen_range(17); // 4..=20 nodes
    let structure = topology::generate(kind, n, &TopologyParams::default(), rng);
    let mut g = Graph::new(n);
    for e in structure.edges() {
        g.add_edge(e.u, e.v, rng.gen_f64_range(1.0, 1000.0));
    }
    g
}

/// `g` with the weight of one edge replaced.
fn reweighted(g: &Graph, u: usize, v: usize, w: f64) -> Graph {
    let mut out = Graph::new(g.node_count());
    for e in g.edges() {
        let ew = if (e.u, e.v) == (u.min(v), u.max(v)) { w } else { e.weight };
        out.add_edge(e.u, e.v, ew);
    }
    out
}

#[test]
fn incremental_update_matches_scratch_algorithms_on_paper_topologies() {
    check("incremental MST == scratch MST", 320, |rng| {
        let g = random_costs(rng);
        let tree = kruskal(&g).map_err(|e| format!("base MST: {e}"))?;

        // perturb one random edge: grow, shrink, or wholesale re-draw
        let e = g.edges()[rng.gen_range(g.edge_count())];
        let new_w = match rng.gen_range(3) {
            0 => e.weight * rng.gen_f64_range(1.5, 8.0), // degrade
            1 => e.weight * rng.gen_f64_range(0.05, 0.8), // recover
            _ => rng.gen_f64_range(1.0, 1000.0),          // re-draw
        };
        let g2 = reweighted(&g, e.u, e.v, new_w);

        let inc = update_edge_weight(&g2, &tree, e.u, e.v)
            .map_err(|err| format!("incremental update: {err}"))?;
        if !is_spanning_tree_of(&inc, &g2) {
            return Err(format!(
                "incremental result is not a spanning tree of the new costs (edge {}-{} -> {new_w})",
                e.u, e.v
            ));
        }
        let want = kruskal(&g2).map_err(|err| format!("kruskal: {err}"))?.total_weight();
        for (name, got) in [
            ("incremental", inc.total_weight()),
            ("prim", prim(&g2).map_err(|err| format!("prim: {err}"))?.total_weight()),
            ("boruvka", boruvka(&g2).map_err(|err| format!("boruvka: {err}"))?.total_weight()),
        ] {
            if (got - want).abs() > 1e-6 * want.max(1.0) {
                return Err(format!(
                    "{name} weight {got} != kruskal {want} after ({},{}) -> {new_w}",
                    e.u, e.v
                ));
            }
        }

        // the moderator-facing diff entry must agree with the direct call
        let via_diff =
            update_mst(&tree, &g, &g2).map_err(|err| format!("update_mst: {err}"))?;
        if (via_diff.total_weight() - inc.total_weight()).abs() > 1e-9 {
            return Err("update_mst disagrees with update_edge_weight".into());
        }
        Ok(())
    });
}

#[test]
fn repeated_incremental_updates_track_the_true_mst() {
    // a drift episode: many successive single-edge changes, the tree
    // maintained incrementally throughout, must stay optimal at each step
    check("incremental MST tracks drift episodes", 64, |rng| {
        let mut costs = random_costs(rng);
        let mut tree = kruskal(&costs).map_err(|e| format!("base MST: {e}"))?;
        for step in 0..8 {
            let e = costs.edges()[rng.gen_range(costs.edge_count())];
            let new_w = rng.gen_f64_range(1.0, 1000.0);
            let next = reweighted(&costs, e.u, e.v, new_w);
            tree = update_mst(&tree, &costs, &next)
                .map_err(|err| format!("step {step}: {err}"))?;
            costs = next;
            let want = kruskal(&costs).map_err(|err| format!("step {step}: {err}"))?;
            if (tree.total_weight() - want.total_weight()).abs() > 1e-6 {
                return Err(format!(
                    "step {step}: maintained {} vs scratch {}",
                    tree.total_weight(),
                    want.total_weight()
                ));
            }
            if !is_spanning_tree_of(&tree, &costs) {
                return Err(format!("step {step}: maintained tree left the cost graph"));
            }
        }
        Ok(())
    });
}
