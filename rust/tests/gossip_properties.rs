//! Property tests on the gossip protocol invariants (DESIGN.md §6), run
//! over random trees and random failure schedules with the in-repo seeded
//! property driver.

use mosgu::coloring::bfs_coloring;
use mosgu::coordinator::gossip::{run_logical_round, GossipState};
use mosgu::coordinator::schedule::Schedule;
use mosgu::graph::Graph;
use mosgu::mst::prim;
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;
use mosgu::{prop_assert, prop_assert_eq};

/// Random connected tree on n nodes (random Prüfer-like attachment).
fn random_tree(n: usize, rng: &mut Pcg64) -> Graph {
    let mut g = Graph::new(n);
    for v in 1..n {
        let u = rng.gen_range(v);
        g.add_edge(u, v, rng.gen_f64_range(1.0, 50.0));
    }
    g
}

fn schedule_for(tree: &Graph) -> Schedule {
    Schedule { coloring: bfs_coloring(tree), slot_len_s: 1.0, first_color: 1 }
}

#[test]
fn dissemination_completes_on_random_trees() {
    check("gossip completes", 150, |rng| {
        let n = 2 + rng.gen_range(30);
        let tree = random_tree(n, rng);
        let sched = schedule_for(&tree);
        let mut st = GossipState::new(tree, 0);
        run_logical_round(&mut st, &sched, |u| (b'a' + (u % 26) as u8) as char, 16 * n + 64);
        prop_assert!(st.is_complete(), "n={n} did not complete");
        for u in 0..n {
            prop_assert_eq!(st.queue(u).held_count(), n);
        }
        Ok(())
    });
}

#[test]
fn no_duplicate_deliveries_on_trees() {
    check("no duplicates", 100, |rng| {
        let n = 2 + rng.gen_range(20);
        let tree = random_tree(n, rng);
        let sched = schedule_for(&tree);
        let mut st = GossipState::new(tree, 0);
        let trace = run_logical_round(&mut st, &sched, |_| 'x', 16 * n + 64);
        let mut seen = std::collections::HashSet::new();
        for slot in &trace.slots {
            for s in &slot.sends {
                prop_assert!(
                    seen.insert((s.to, s.key.owner)),
                    "duplicate ({},{})",
                    s.to,
                    s.key.owner
                );
            }
        }
        prop_assert_eq!(seen.len(), n * (n - 1));
        Ok(())
    });
}

#[test]
fn round_length_bounded_by_diameter() {
    // dissemination needs at most ~2*(diameter + n) alternating slots
    check("slots bounded", 100, |rng| {
        let n = 2 + rng.gen_range(25);
        let tree = random_tree(n, rng);
        let diam = tree.diameter_hops().unwrap();
        let sched = schedule_for(&tree);
        let mut st = GossipState::new(tree, 0);
        let trace = run_logical_round(&mut st, &sched, |_| 'x', 16 * n + 64);
        let bound = 2 * (diam + n) + 4;
        prop_assert!(
            trace.slots.len() <= bound,
            "n={n} diam={diam}: {} slots > bound {bound}",
            trace.slots.len()
        );
        Ok(())
    });
}

#[test]
fn adjacent_nodes_never_transmit_in_same_slot() {
    check("proper slot classes", 100, |rng| {
        let n = 2 + rng.gen_range(25);
        let tree = random_tree(n, rng);
        let sched = schedule_for(&tree);
        let mut st = GossipState::new(tree.clone(), 0);
        let trace = run_logical_round(&mut st, &sched, |_| 'x', 16 * n + 64);
        for slot in &trace.slots {
            let senders: Vec<usize> =
                slot.sends.iter().map(|s| s.from).collect();
            for (i, &a) in senders.iter().enumerate() {
                for &b in &senders[i + 1..] {
                    prop_assert!(a == b || !tree.has_edge(a, b), "adjacent {a},{b} same slot");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn failure_injection_preserves_exactly_once_holding() {
    // with random transmission failures + retransmission, every node still
    // ends with each model exactly once (dedup at receivers)
    check("failures -> exactly once", 60, |rng| {
        let n = 3 + rng.gen_range(12);
        let tree = random_tree(n, rng);
        let sched = schedule_for(&tree);
        let mut st = GossipState::new(tree, 0);
        let p_fail = rng.gen_f64_range(0.0, 0.3);
        let max_slots = 64 * n + 200;
        for slot in 0..max_slots {
            if st.is_complete() {
                break;
            }
            let planned = st.plan_slot(&sched.transmitters(slot));
            for tx in &planned {
                if rng.gen_bool(p_fail) {
                    st.requeue(tx);
                } else {
                    for s in tx.sends() {
                        st.deliver(s);
                    }
                }
            }
        }
        prop_assert!(st.is_complete(), "n={n} p={p_fail:.2} incomplete");
        for u in 0..n {
            prop_assert_eq!(st.queue(u).held_count(), n);
            // held_order has no duplicates
            let mut owners: Vec<usize> =
                st.queue(u).held_order().iter().map(|k| k.owner).collect();
            owners.sort_unstable();
            owners.dedup();
            prop_assert_eq!(owners.len(), n);
        }
        Ok(())
    });
}

#[test]
fn leaf_queues_drain_after_own_model() {
    check("leaf queue drains", 80, |rng| {
        let n = 3 + rng.gen_range(20);
        let tree = random_tree(n, rng);
        let sched = schedule_for(&tree);
        let mut st = GossipState::new(tree.clone(), 0);
        run_logical_round(&mut st, &sched, |_| 'x', 16 * n + 64);
        for u in 0..n {
            if tree.degree(u) == 1 {
                prop_assert!(st.queue(u).is_drained(), "leaf {u} queue not drained");
            }
        }
        Ok(())
    });
}
