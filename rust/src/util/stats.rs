//! Small statistics toolkit used by metrics collection and the bench harness.

/// Running summary of a stream of f64 samples (Welford's algorithm for
/// numerically stable mean/variance, plus min/max/sum).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.std_dev() / (self.n as f64).sqrt()
        }
    }
}

/// Percentile of a sample set (linear interpolation, `q` in [0,1]).
/// Sorts a copy; fine for metrics-sized vectors.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile must be in [0,1]");
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(samples: &[f64]) -> f64 {
    percentile(samples, 0.5)
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        f64::NAN
    } else {
        samples.iter().sum::<f64>() / samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.sum() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn summary_merge_equals_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Summary::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }

    #[test]
    fn empty_summary_is_nan_mean() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 1.0), 40.0);
        assert!((percentile(&v, 0.5) - 25.0).abs() < 1e-12);
        assert!((median(&v) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let v = [40.0, 10.0, 30.0, 20.0];
        assert!((median(&v) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn mean_empty_nan() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 0.5).is_nan());
    }
}
