//! Segment-granular transfer planning (the checkpoint-slicing layer).
//!
//! The paper's schedule moves each checkpoint as one monolithic blob per
//! hop: a relay on a deep tree must hold the full 48 MB model before it
//! can forward anything. Hu et al., *Decentralized Federated Learning: A
//! Segmented Gossip Approach* (arXiv:1908.07782), show that splitting a
//! model into segments unlocks pipelined bandwidth: segment `i` can move
//! down the tree while segment `i+1` is still in flight upstream.
//!
//! A [`TransferPlan`] is the single source of truth for how one model
//! checkpoint is cut into wire-level transfer units. It is derived from
//! the Table II [`ModelSpec`](crate::dfl::models::ModelSpec) capacity (or
//! any explicit size in MB) plus the `segments` / `segment_mb`
//! configuration (CLI: `--segments` / `--segment-mb`), and is consumed by
//! every layer of the stack:
//!
//! * the round engine launches one flow per segment and drives
//!   cut-through forwarding over them
//!   ([`RoundEngine`](crate::coordinator::engine::RoundEngine)),
//! * the simulator sees segment-sized payloads (so the congestion-loss
//!   model inflates segments, not whole checkpoints),
//! * the live transport frames segments as
//!   [`Message::ModelSegment`](crate::transport::Message) and reassembles
//!   them at the receiver (payloads are synthetic in the in-process live
//!   mode; [`TransferPlan::segment_bounds`] / [`TransferPlan::slice`] are
//!   the slicing API for carrying real parameter bytes).
//!
//! `segments = 1` is the compatibility anchor: a single whole-model
//! transfer unit, bit-identical to the pre-segmentation engine.

use std::ops::Range;

/// How one model checkpoint is sliced into wire-level transfer units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferPlan {
    model_mb: f64,
    segments: usize,
}

impl TransferPlan {
    /// One whole-model transfer unit (the legacy engine's behavior).
    pub fn whole(model_mb: f64) -> Self {
        Self::segmented(model_mb, 1)
    }

    /// Slice the checkpoint into exactly `segments` equal units.
    pub fn segmented(model_mb: f64, segments: usize) -> Self {
        assert!(model_mb > 0.0, "model size must be positive, got {model_mb} MB");
        assert!(segments >= 1, "a transfer plan needs at least one segment");
        assert!(segments <= u16::MAX as usize, "segment count {segments} exceeds u16 wire field");
        TransferPlan { model_mb, segments }
    }

    /// Slice the checkpoint into units of at most `segment_mb` MB:
    /// `k = ceil(model_mb / segment_mb)` equal segments, saturating at
    /// the wire format's `u16::MAX` ceiling (a derived count degrades to
    /// the finest supported slicing instead of panicking).
    pub fn by_segment_mb(model_mb: f64, segment_mb: f64) -> Self {
        assert!(segment_mb > 0.0, "segment size must be positive, got {segment_mb} MB");
        let k = ((model_mb / segment_mb).ceil().max(1.0) as usize).min(u16::MAX as usize);
        Self::segmented(model_mb, k)
    }

    /// Full checkpoint size in MB.
    pub fn model_mb(&self) -> f64 {
        self.model_mb
    }

    /// Number of transfer units one copy is cut into (`k >= 1`).
    pub fn segments(&self) -> usize {
        self.segments
    }

    /// Size of one transfer unit in MB (equal split; for `segments == 1`
    /// this is exactly `model_mb`, preserving the legacy payload bits).
    pub fn segment_mb(&self) -> f64 {
        if self.segments == 1 {
            self.model_mb
        } else {
            self.model_mb / self.segments as f64
        }
    }

    /// Whether transfers are segment-granular (more than one unit).
    pub fn is_segmented(&self) -> bool {
        self.segments > 1
    }

    /// Element ranges slicing a flat parameter vector of `len` entries
    /// into the plan's segments: `k` contiguous near-equal chunks, first
    /// `len % k` chunks one element longer, covering `0..len` exactly.
    pub fn segment_bounds(&self, len: usize) -> Vec<Range<usize>> {
        let k = self.segments;
        let base = len / k;
        let extra = len % k;
        let mut out = Vec::with_capacity(k);
        let mut start = 0;
        for i in 0..k {
            let sz = base + usize::from(i < extra);
            out.push(start..start + sz);
            start += sz;
        }
        debug_assert_eq!(start, len);
        out
    }

    /// Slice a flat parameter vector into per-segment views.
    pub fn slice<'a, T>(&self, params: &'a [T]) -> Vec<&'a [T]> {
        self.segment_bounds(params.len()).into_iter().map(|r| &params[r]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_plan_is_one_segment_of_full_size() {
        let p = TransferPlan::whole(48.0);
        assert_eq!(p.segments(), 1);
        assert!(!p.is_segmented());
        // exact bits, not a divide-by-one roundtrip
        assert_eq!(p.segment_mb().to_bits(), 48.0f64.to_bits());
    }

    #[test]
    fn segmented_split_is_even() {
        let p = TransferPlan::segmented(48.0, 4);
        assert_eq!(p.segments(), 4);
        assert!((p.segment_mb() - 12.0).abs() < 1e-12);
        assert!((p.segment_mb() * 4.0 - p.model_mb()).abs() < 1e-9);
    }

    #[test]
    fn by_segment_mb_rounds_up() {
        assert_eq!(TransferPlan::by_segment_mb(48.0, 8.0).segments(), 6);
        assert_eq!(TransferPlan::by_segment_mb(11.6, 8.0).segments(), 2);
        assert_eq!(TransferPlan::by_segment_mb(5.0, 8.0).segments(), 1);
    }

    #[test]
    fn by_segment_mb_saturates_at_wire_ceiling() {
        // a derived count beyond the u16 wire field clamps instead of
        // panicking (explicit counts via segmented() still assert)
        let p = TransferPlan::by_segment_mb(100_000.0, 0.01);
        assert_eq!(p.segments(), u16::MAX as usize);
    }

    #[test]
    fn segment_bounds_cover_vector_exactly() {
        let p = TransferPlan::segmented(10.0, 3);
        let bounds = p.segment_bounds(10);
        assert_eq!(bounds, vec![0..4, 4..7, 7..10]);
        let p1 = TransferPlan::whole(10.0);
        assert_eq!(p1.segment_bounds(7), vec![0..7]);
    }

    #[test]
    fn slice_matches_bounds() {
        let params: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let p = TransferPlan::segmented(10.0, 4);
        let slices = p.slice(&params);
        assert_eq!(slices.len(), 4);
        let total: usize = slices.iter().map(|s| s.len()).sum();
        assert_eq!(total, params.len());
        assert_eq!(slices[0][0], 0.0);
        assert_eq!(*slices.last().unwrap().last().unwrap(), 10.0);
    }

    #[test]
    #[should_panic(expected = "at least one segment")]
    fn zero_segments_rejected() {
        TransferPlan::segmented(10.0, 0);
    }
}
