//! Deterministic pseudo-random number generation.
//!
//! The offline build environment carries no `rand` crate, so we implement
//! PCG-XSH-RR 64/32 (O'Neill 2014) plus SplitMix64 seeding ourselves. All
//! experiment seeds in this repository flow through [`Pcg64`], making every
//! topology, workload and property-test run reproducible from a `u64` seed.

/// SplitMix64 — used to expand a single `u64` seed into PCG state/stream.
///
/// Reference: Steele, Lea, Flood, "Fast splittable pseudorandom number
/// generators", OOPSLA 2014.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output with random rotation.
///
/// Statistically solid for simulation workloads, tiny, and `Copy`-cheap to
/// fork per-node so concurrent actors draw from independent streams.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg64 {
    /// Construct from a single seed; stream constant is derived via SplitMix.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1; // stream must be odd
        let mut rng = Pcg64 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        let _ = rng.next_u32();
        rng
    }

    /// Fork an independent stream (e.g. one per simulated node).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        let _ = rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        // 64-bit Lemire: unbiased without 128-bit ops for bound << 2^32.
        loop {
            let x = self.next_u64();
            let hi = ((x as u128 * bound as u128) >> 64) as u64;
            let lo = (x as u128 * bound as u128) as u64;
            if lo >= bound.wrapping_neg() % bound || bound.is_power_of_two() {
                return hi as usize;
            }
            // Rejection is astronomically rare for simulation-size bounds.
            if lo >= (u64::MAX % bound).wrapping_add(1) {
                return hi as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range(i + 1);
            slice.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> &'a T {
        &slice[self.gen_range(slice.len())]
    }

    /// Exponential variate with rate `lambda` (inverse-CDF).
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.gen_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller (polar form avoided to stay branch-light).
    pub fn gen_normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = loop {
            let u = self.gen_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.gen_f64();
        let mag = (-2.0 * u1.ln()).sqrt();
        mean + std * mag * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Pcg64::new(7);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_respects_bound() {
        let mut rng = Pcg64::new(3);
        for bound in [1usize, 2, 3, 7, 10, 1000] {
            for _ in 0..200 {
                assert!(rng.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_covers_support() {
        let mut rng = Pcg64::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.gen_range(10)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all of 0..10 should appear");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Pcg64::new(5);
        for _ in 0..1000 {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_f64_mean_is_centered() {
        let mut rng = Pcg64::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(17);
        for _ in 0..50 {
            let s = rng.sample_indices(20, 8);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(19);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1);
        assert!((var - 4.0).abs() < 0.3);
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg64::new(23);
        let n = 20_000;
        let mean = (0..n).map(|_| rng.gen_exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.05);
    }
}
