"""Layer-2 JAX model: the federated learner's train/eval computation.

The paper trains mobile-class CNNs (MobileNet/EfficientNet, 2.9–12 M
parameters) and measures *communication only*; training accuracy is cited
from prior work. For the end-to-end example we therefore train a real
model of the same parameter class — a small GELU transformer LM (~3.3 M
params at the default config) on synthetic sequence data — with the dense
hot loops running through the Layer-1 Pallas kernels.

Everything here is build-time: `aot.py` lowers `train_step`, `eval_step`
and `aggregate_pair` to HLO text once; the Rust coordinator executes the
artifacts through PJRT and never imports Python.

Parameters cross the artifact boundary as ONE flat f32 vector (padded to
the aggregation kernel's block multiple), so the Rust side is completely
model-agnostic: gossip moves `param_dim` floats, aggregation folds them
pairwise, the train artifact consumes and returns the same flat vector.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import aggregate as agg_kernel
from .kernels import linear as linear_kernel
from .kernels import ref as kernels_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer-LM hyperparameters. Defaults give ~3.3 M parameters —
    the paper's "v2-class" size (MobileNetV2: 3.5 M)."""

    vocab: int = 256
    d_model: int = 128
    d_ff: int = 512
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    # pad the flat parameter vector to a multiple of this (the aggregation
    # kernel's block size)
    pad_multiple: int = 65536
    # use the Pallas fused_linear kernel for the feed-forward blocks
    use_pallas: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# parameter pytree <-> flat vector
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> dict:
    """Ordered dict of parameter array shapes."""
    shapes = {
        "embed": (cfg.vocab, cfg.d_model),
        "pos": (cfg.seq_len, cfg.d_model),
    }
    for i in range(cfg.n_layers):
        shapes.update({
            f"l{i}.ln1_g": (cfg.d_model,),
            f"l{i}.ln1_b": (cfg.d_model,),
            f"l{i}.wqkv": (cfg.d_model, 3 * cfg.d_model),
            f"l{i}.wo": (cfg.d_model, cfg.d_model),
            f"l{i}.ln2_g": (cfg.d_model,),
            f"l{i}.ln2_b": (cfg.d_model,),
            f"l{i}.w1": (cfg.d_model, cfg.d_ff),
            f"l{i}.b1": (cfg.d_ff,),
            f"l{i}.w2": (cfg.d_ff, cfg.d_model),
            f"l{i}.b2": (cfg.d_model,),
        })
    shapes.update({
        "lnf_g": (cfg.d_model,),
        "lnf_b": (cfg.d_model,),
        "head": (cfg.d_model, cfg.vocab),
    })
    return shapes


def param_count(cfg: ModelConfig) -> int:
    import math

    return sum(math.prod(s) for s in param_shapes(cfg).values())


def padded_dim(cfg: ModelConfig) -> int:
    """Flat vector length after padding to the kernel block multiple."""
    n = param_count(cfg)
    m = cfg.pad_multiple
    return ((n + m - 1) // m) * m


def init_params(cfg: ModelConfig, seed: int) -> dict:
    """He/Glorot-ish init, deterministic per seed."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith(("_b", ".b1", ".b2")) or name == "lnf_b":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith("_g") :
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            / jnp.sqrt(jnp.float32(fan_in)))
    return params


def flatten_params(cfg: ModelConfig, params: dict) -> jnp.ndarray:
    """Concatenate all parameters into one padded flat f32 vector."""
    parts = [params[name].reshape(-1) for name in param_shapes(cfg)]
    flat = jnp.concatenate(parts)
    pad = padded_dim(cfg) - flat.shape[0]
    return jnp.pad(flat, (0, pad))


def unflatten_params(cfg: ModelConfig, flat: jnp.ndarray) -> dict:
    """Inverse of `flatten_params` (ignores the padding tail)."""
    import math

    params = {}
    offset = 0
    for name, shape in param_shapes(cfg).items():
        size = math.prod(shape)
        params[name] = flat[offset:offset + size].reshape(shape)
        offset += size
    return params


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _ffn(cfg: ModelConfig, x, w1, b1, w2, b2):
    """Feed-forward block — the dense hot-spot, routed through the Pallas
    fused-linear kernel when shapes tile (they do by construction:
    d_model/d_ff are multiples of 128 and tokens are padded)."""
    bt, d = x.shape
    if cfg.use_pallas and bt % linear_kernel.BM == 0 and d % linear_kernel.BK == 0 \
            and w1.shape[1] % linear_kernel.BN == 0:
        h = linear_kernel.fused_linear(x, w1, b1, activation="gelu")
        return linear_kernel.fused_linear(h, w2, b2, activation="none")
    h = kernels_ref.fused_linear_ref(x, w1, b1, activation="gelu")
    return kernels_ref.fused_linear_ref(h, w2, b2, activation="none")


def _attention(cfg: ModelConfig, x, wqkv, wo):
    bt = x.shape[0]
    b = bt // cfg.seq_len
    qkv = (x @ wqkv).reshape(b, cfg.seq_len, 3, cfg.n_heads, cfg.head_dim)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    # (b, heads, t, hd)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(cfg.head_dim))
    causal = jnp.tril(jnp.ones((cfg.seq_len, cfg.seq_len), bool))
    scores = jnp.where(causal, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(bt, cfg.d_model)
    return out @ wo


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """Logits for a (batch, seq_len) int32 token array."""
    b, t = tokens.shape
    assert t == cfg.seq_len, f"seq len {t} != {cfg.seq_len}"
    x = params["embed"][tokens.reshape(-1)] + jnp.tile(params["pos"], (b, 1))
    for i in range(cfg.n_layers):
        h = _layer_norm(x, params[f"l{i}.ln1_g"], params[f"l{i}.ln1_b"])
        x = x + _attention(cfg, h, params[f"l{i}.wqkv"], params[f"l{i}.wo"])
        h = _layer_norm(x, params[f"l{i}.ln2_g"], params[f"l{i}.ln2_b"])
        x = x + _ffn(cfg, h, params[f"l{i}.w1"], params[f"l{i}.b1"],
                     params[f"l{i}.w2"], params[f"l{i}.b2"])
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    return (x @ params["head"]).reshape(b, t, cfg.vocab)


def loss_fn(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            targets: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


# ---------------------------------------------------------------------------
# the three AOT entry points
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(cfg: ModelConfig, flat: jnp.ndarray, tokens: jnp.ndarray,
               targets: jnp.ndarray, lr: jnp.ndarray):
    """One SGD step over the flat parameter vector.

    Returns `(new_flat, loss)`. Gradients flow through the same forward
    (including the Pallas FFN kernels); the update itself stays on the flat
    vector so the artifact signature is model-agnostic.
    """
    def flat_loss(f):
        return loss_fn(cfg, unflatten_params(cfg, f), tokens, targets)

    loss, grad = jax.value_and_grad(flat_loss)(flat)
    # fused SGD over the flat vector (same maths as kernels/sgd.py; inlined
    # jnp here so the train artifact stays a single fused HLO)
    new_flat = flat - lr * grad
    return new_flat, loss


@functools.partial(jax.jit, static_argnames=("cfg",))
def eval_step(cfg: ModelConfig, flat: jnp.ndarray, tokens: jnp.ndarray,
              targets: jnp.ndarray) -> jnp.ndarray:
    """Loss only (no update) — used for the example's held-out curve."""
    return loss_fn(cfg, unflatten_params(cfg, flat), tokens, targets)


def aggregate_pair(acc: jnp.ndarray, acc_weight: jnp.ndarray,
                   model: jnp.ndarray, weight: jnp.ndarray):
    """Pairwise FedAvg fold — the Pallas aggregation kernel, exported as
    its own artifact so the Rust gossip hot path can fold any number of
    neighbor models with one fixed-shape executable."""
    return agg_kernel.gossip_aggregate(acc, acc_weight, model, weight)


# ---------------------------------------------------------------------------
# synthetic workload (both for pytest and for the e2e example's data)
# ---------------------------------------------------------------------------

def synth_batch(cfg: ModelConfig, seed: int, batch: int, node: int = 0):
    """Deterministic synthetic next-token task: token sequences follow a
    per-node affine recurrence mod vocab, so the task is learnable and
    mildly non-IID across federated nodes (each node has its own stride)."""
    key = jax.random.PRNGKey(seed * 1000003 + node)
    start = jax.random.randint(key, (batch, 1), 0, cfg.vocab)
    stride = 3 + 2 * (node % 5)  # odd strides => full cycle mod 256
    idx = jnp.arange(cfg.seq_len + 1)
    seq = (start + stride * idx[None, :]) % cfg.vocab
    return seq[:, :-1].astype(jnp.int32), seq[:, 1:].astype(jnp.int32)
