//! The per-node FIFO gossip queue `F` (paper §III-D, "GU — Gossip and
//! Update recipient's queue").
//!
//! Each entry is a 3-tuple `(O, t, M)`: model owner, training-round index,
//! and the model payload (held by reference/id here — the coordinator moves
//! bytes, the queue moves bookkeeping). Entries are forwarded in arrival
//! order; once transmitted they leave `F`; a transmission interrupted by a
//! network failure stays queued for the node's next turn.

use crate::graph::NodeId;
use std::collections::{HashSet, VecDeque};

/// Identity of a model instance circulating in one communication round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelKey {
    /// The node that trained this model (the paper's `O`).
    pub owner: NodeId,
    /// Training round index (the paper's `t`).
    pub round: u64,
}

impl ModelKey {
    pub fn new(owner: NodeId, round: u64) -> Self {
        ModelKey { owner, round }
    }
}

/// Identity of one wire-level transfer unit: segment `index` of `total`
/// of a circulating model copy (see
/// [`TransferPlan`](crate::dfl::transfer::TransferPlan)). `total == 1`
/// is the whole-model unit of the legacy transfer plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SegmentKey {
    pub model: ModelKey,
    /// Segment index, `0..total`.
    pub index: u16,
    /// Segments per model copy under the active transfer plan.
    pub total: u16,
}

impl SegmentKey {
    pub fn new(model: ModelKey, index: u16, total: u16) -> Self {
        debug_assert!(total >= 1 && index < total, "segment {index}/{total} out of range");
        SegmentKey { model, index, total }
    }

    /// The single whole-model unit (legacy transfers).
    pub fn whole(model: ModelKey) -> Self {
        SegmentKey { model, index: 0, total: 1 }
    }
}

/// A queued forwarding obligation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueEntry {
    pub key: ModelKey,
    /// Neighbor the entry arrived from (`None` for the locally trained
    /// model) — receivers never get an entry echoed back to its source.
    pub received_from: Option<NodeId>,
}

/// FIFO queue `F` plus the set (and order) of models this node holds.
#[derive(Debug, Clone)]
pub struct GossipQueue {
    node: NodeId,
    fifo: VecDeque<QueueEntry>,
    /// reception order, starting with the local model — matches the
    /// left-to-right strings of the paper's Table I
    held_order: Vec<ModelKey>,
    held: HashSet<ModelKey>,
}

impl GossipQueue {
    pub fn new(node: NodeId) -> Self {
        GossipQueue { node, fifo: VecDeque::new(), held_order: Vec::new(), held: HashSet::new() }
    }

    pub fn node(&self) -> NodeId {
        self.node
    }

    /// Register the locally trained model for round `round` and queue it
    /// for transmission.
    pub fn seed_own(&mut self, round: u64) {
        let key = ModelKey::new(self.node, round);
        let fresh = self.held.insert(key);
        assert!(fresh, "own model for round {round} seeded twice");
        self.held_order.push(key);
        self.fifo.push_back(QueueEntry { key, received_from: None });
    }

    /// Record an incoming model. Returns `true` if it is new to this node.
    ///
    /// `enqueue` controls whether the model joins `F` for onward
    /// forwarding: a node of MST degree 1 receives everything from its only
    /// neighbor and never forwards back (§III-D), so its received entries
    /// are held but not enqueued.
    pub fn receive(&mut self, key: ModelKey, from: NodeId, enqueue: bool) -> bool {
        if !self.held.insert(key) {
            return false; // duplicate — ignored (cannot happen on a tree)
        }
        self.held_order.push(key);
        if enqueue {
            self.fifo.push_back(QueueEntry { key, received_from: Some(from) });
        }
        true
    }

    /// Pop the oldest pending entry (the node's next transmission).
    pub fn pop_oldest(&mut self) -> Option<QueueEntry> {
        self.fifo.pop_front()
    }

    /// Re-queue an entry at the *front* after a failed transmission, so it
    /// is retried on the node's next turn (§III-D network-disruption rule).
    pub fn push_front(&mut self, entry: QueueEntry) {
        self.fifo.push_front(entry);
    }

    /// Append a forwarding obligation at the back of `F`. Used by the
    /// cut-through engine when a relay's inline forward was disrupted:
    /// the relay already holds the model (so [`GossipQueue::receive`]
    /// would deduplicate it) but must now retransmit through the normal
    /// queued path on its next turn.
    pub fn push_back(&mut self, entry: QueueEntry) {
        self.fifo.push_back(entry);
    }

    /// Whether `key` is already queued for (re)transmission.
    pub fn has_pending(&self, key: &ModelKey) -> bool {
        self.fifo.iter().any(|e| e.key == *key)
    }

    pub fn pending_len(&self) -> usize {
        self.fifo.len()
    }

    pub fn is_drained(&self) -> bool {
        self.fifo.is_empty()
    }

    pub fn holds(&self, key: &ModelKey) -> bool {
        self.held.contains(key)
    }

    pub fn held_count(&self) -> usize {
        self.held.len()
    }

    /// Reception order (Table I string for this node).
    pub fn held_order(&self) -> &[ModelKey] {
        &self.held_order
    }

    /// Pending keys oldest-first (the black entries of Table I).
    pub fn pending_keys(&self) -> Vec<ModelKey> {
        self.fifo.iter().map(|e| e.key).collect()
    }

    /// Clear state between communication rounds (held models are consumed
    /// by aggregation; the queue must start a round empty).
    pub fn reset(&mut self) {
        self.fifo.clear();
        self.held.clear();
        self.held_order.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_then_pop_fifo_order() {
        let mut q = GossipQueue::new(3);
        q.seed_own(0);
        q.receive(ModelKey::new(1, 0), 5, true);
        q.receive(ModelKey::new(2, 0), 5, true);
        assert_eq!(q.pending_len(), 3);
        assert_eq!(q.pop_oldest().unwrap().key.owner, 3);
        assert_eq!(q.pop_oldest().unwrap().key.owner, 1);
        assert_eq!(q.pop_oldest().unwrap().key.owner, 2);
        assert!(q.is_drained());
    }

    #[test]
    fn duplicate_reception_ignored() {
        let mut q = GossipQueue::new(0);
        let k = ModelKey::new(4, 7);
        assert!(q.receive(k, 1, true));
        assert!(!q.receive(k, 2, true));
        assert_eq!(q.pending_len(), 1);
        assert_eq!(q.held_count(), 1);
    }

    #[test]
    fn degree_one_reception_not_enqueued() {
        let mut q = GossipQueue::new(0);
        assert!(q.receive(ModelKey::new(9, 0), 7, false));
        assert!(q.holds(&ModelKey::new(9, 0)));
        assert!(q.is_drained());
    }

    #[test]
    fn failed_send_retried_first() {
        let mut q = GossipQueue::new(0);
        q.seed_own(0);
        q.receive(ModelKey::new(1, 0), 2, true);
        let e = q.pop_oldest().unwrap();
        q.push_front(e); // network disruption: retry next turn
        assert_eq!(q.pop_oldest().unwrap().key.owner, 0);
    }

    #[test]
    fn held_order_tracks_reception_sequence() {
        let mut q = GossipQueue::new(2);
        q.seed_own(0);
        q.receive(ModelKey::new(0, 0), 1, true);
        q.receive(ModelKey::new(4, 0), 1, true);
        let owners: Vec<usize> = q.held_order().iter().map(|k| k.owner).collect();
        assert_eq!(owners, vec![2, 0, 4]);
    }

    #[test]
    fn rounds_are_distinct_keys() {
        let mut q = GossipQueue::new(0);
        assert!(q.receive(ModelKey::new(1, 0), 1, true));
        assert!(q.receive(ModelKey::new(1, 1), 1, true), "new round = new model");
        assert_eq!(q.held_count(), 2);
    }

    #[test]
    fn reset_clears_everything() {
        let mut q = GossipQueue::new(0);
        q.seed_own(0);
        q.receive(ModelKey::new(1, 0), 1, true);
        q.reset();
        assert_eq!(q.held_count(), 0);
        assert!(q.is_drained());
        assert!(q.held_order().is_empty());
    }

    #[test]
    #[should_panic(expected = "seeded twice")]
    fn double_seed_panics() {
        let mut q = GossipQueue::new(0);
        q.seed_own(0);
        q.seed_own(0);
    }

    #[test]
    fn push_back_requeues_for_held_model() {
        // cut-through relay failure: model is held, obligation re-enters F
        let mut q = GossipQueue::new(0);
        q.receive(ModelKey::new(3, 0), 1, false); // held, nothing queued
        assert!(q.is_drained());
        assert!(!q.has_pending(&ModelKey::new(3, 0)));
        q.push_back(QueueEntry { key: ModelKey::new(3, 0), received_from: Some(1) });
        assert!(q.has_pending(&ModelKey::new(3, 0)));
        assert_eq!(q.pop_oldest().unwrap().key.owner, 3);
    }

    #[test]
    fn segment_keys_order_and_identify() {
        let m = ModelKey::new(2, 5);
        let whole = SegmentKey::whole(m);
        assert_eq!((whole.index, whole.total), (0, 1));
        let s0 = SegmentKey::new(m, 0, 4);
        let s3 = SegmentKey::new(m, 3, 4);
        assert!(s0 < s3);
        assert_ne!(s0, whole);
        assert_ne!(s0, s3);
    }
}
