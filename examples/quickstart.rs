//! Quickstart: build a topology, let the moderator compute the MOSGU
//! schedule (MST + BFS 2-coloring + slot length), run one timed
//! communication round against the flooding-broadcast baseline, then let
//! the round engine pipeline several rounds over one shared simulator.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::GossipSession;
use mosgu::graph::dot::node_label;
use mosgu::graph::topology::TopologyKind;

fn main() -> anyhow::Result<()> {
    mosgu::util::logger::init();

    // The paper's setup: 10 nodes behind 3 routers, Watts-Strogatz underlay.
    let cfg = ExperimentConfig {
        topology: TopologyKind::WattsStrogatz,
        ..ExperimentConfig::default()
    };
    let session = GossipSession::new(&cfg)?;

    println!("== testbed ==");
    print!("{}", session.testbed().describe());

    println!("\n== moderator output (M + O + S) ==");
    let tree = session.tree();
    let n = tree.node_count();
    println!("MST ({} edges, total ping cost {:.1} ms):", tree.edge_count(), tree.total_weight());
    for e in tree.sorted_edges() {
        println!("  {} -- {}  ({:.2} ms)", node_label(e.u, n), node_label(e.v, n), e.weight);
    }
    let coloring = &session.schedule().coloring;
    for (color, name) in [(1, "red "), (0, "blue")] {
        let class: Vec<String> =
            coloring.class(color).into_iter().map(|u| node_label(u, n)).collect();
        println!("{name} slot class: {}", class.join(", "));
    }
    println!("slot length (paper formula): {:.3} s", session.schedule().slot_len_s);

    println!("\n== one communication round, MobileNetV2 (14 MB) ==");
    let gossip = session.run_mosgu_round(14.0, 1, 0.0);
    let bcast = session.run_broadcast_round(14.0, 1);
    println!(
        "MOSGU:     {:>3} transfers, bandwidth {:>5.2} MB/s, transfer {:>5.2} s, round {:>6.2} s ({} slots)",
        gossip.transfer_count(),
        gossip.bandwidth_mbps(),
        gossip.avg_transfer_s(),
        gossip.exchange_time_s,
        gossip.slots,
    );
    println!(
        "broadcast: {:>3} transfers, bandwidth {:>5.2} MB/s, transfer {:>5.2} s, round {:>6.2} s",
        bcast.transfer_count(),
        bcast.bandwidth_mbps(),
        bcast.avg_transfer_s(),
        bcast.total_time_s,
    );
    println!(
        "improvement: {:.1}x bandwidth, {:.1}x transfer time, {:.1}x round time",
        gossip.bandwidth_mbps() / bcast.bandwidth_mbps(),
        bcast.avg_transfer_s() / gossip.avg_transfer_s(),
        bcast.total_time_s / gossip.exchange_time_s,
    );

    // == engine: multi-round pipelining ==
    //
    // All round execution goes through coordinator::engine::RoundEngine
    // (run_mosgu_round above included). run_pipelined_rounds shares one
    // long-lived simulator across rounds: each node seeds round t+1 the
    // moment it holds every round-t model, so next-round seeds gossip in
    // slots round t has vacated (§III-D).
    println!("\n== engine: pipelining 3 rounds over one simulator ==");
    let rounds = 3u64;
    let sequential: f64 =
        (0..rounds).map(|_| session.run_mosgu_round(14.0, 1, 0.0).total_time_s).sum();
    let pipe = session.run_pipelined_rounds(14.0, rounds, 1);
    println!("sequential rounds: {sequential:>7.2} s simulated");
    println!(
        "pipelined rounds:  {:>7.2} s simulated ({:.1}% saved, {} slots)",
        pipe.total_time_s,
        100.0 * (1.0 - pipe.total_time_s / sequential),
        pipe.slots,
    );
    for ph in &pipe.rounds {
        println!(
            "  round {}: seeded {:>6.2}-{:>6.2} s, exchange done {:>6.2} s, disseminated {:>6.2} s (slots {}-{})",
            ph.round, ph.first_seed_s, ph.all_seeded_s, ph.exchange_done_s, ph.done_s,
            ph.first_slot, ph.last_slot,
        );
    }
    Ok(())
}
