//! Graphviz DOT export for the paper's figures (Fig 1, 2, 4, 5, 6).
//!
//! The paper draws inter-subnet edges black and intra-subnet ("local")
//! edges dashed blue; colored MSTs paint nodes red/blue. We reproduce that
//! styling so `dot -Tpng` regenerates figures directly comparable to the
//! paper's.

use super::Graph;
use crate::coloring::Coloring;

/// Styling input: which subnet each node belongs to (for edge style) and an
/// optional node coloring (for Fig 6-style output).
#[derive(Debug, Clone, Default)]
pub struct DotStyle {
    /// node -> subnet id; edges within one subnet render dashed blue.
    pub subnet: Option<Vec<usize>>,
    /// 2-coloring (or k-coloring) to paint node fills.
    pub coloring: Option<Coloring>,
    /// Show edge weights as labels.
    pub edge_labels: bool,
}

const FILL_PALETTE: [&str; 6] = ["#e06666", "#6fa8dc", "#93c47d", "#ffd966", "#b4a7d6", "#f6b26b"];

/// Node label: A, B, C... for n <= 26 (matching the paper), else n0, n1...
pub fn node_label(i: usize, n: usize) -> String {
    if n <= 26 {
        // The paper labels its 10-node example A..K skipping J.
        let alphabet: Vec<char> = ('A'..='Z').filter(|&c| c != 'J').collect();
        if i < alphabet.len() {
            return alphabet[i].to_string();
        }
    }
    format!("n{i}")
}

/// Render `g` as an undirected DOT graph.
pub fn to_dot(name: &str, g: &Graph, style: &DotStyle) -> String {
    let n = g.node_count();
    let mut out = String::new();
    out.push_str(&format!("graph \"{name}\" {{\n"));
    out.push_str("  layout=neato;\n  overlap=false;\n  node [style=filled, fillcolor=white, shape=circle];\n");
    for u in 0..n {
        let label = node_label(u, n);
        let mut attrs = vec![format!("label=\"{label}\"")];
        if let Some(col) = &style.coloring {
            let c = col.color_of(u);
            attrs.push(format!("fillcolor=\"{}\"", FILL_PALETTE[c % FILL_PALETTE.len()]));
        }
        out.push_str(&format!("  {u} [{}];\n", attrs.join(", ")));
    }
    for e in g.sorted_edges() {
        let mut attrs: Vec<String> = Vec::new();
        if let Some(subnet) = &style.subnet {
            if subnet[e.u] == subnet[e.v] {
                // local connection: dashed blue, as in the paper's figures
                attrs.push("style=dashed".into());
                attrs.push("color=\"#3c78d8\"".into());
            } else {
                attrs.push("color=black".into());
            }
        }
        if style.edge_labels {
            attrs.push(format!("label=\"{:.1}\"", e.weight));
        }
        if attrs.is_empty() {
            out.push_str(&format!("  {} -- {};\n", e.u, e.v));
        } else {
            out.push_str(&format!("  {} -- {} [{}];\n", e.u, e.v, attrs.join(", ")));
        }
    }
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::Coloring;

    fn tiny() -> Graph {
        let mut g = Graph::new(3);
        g.add_edge(0, 1, 1.5);
        g.add_edge(1, 2, 2.5);
        g
    }

    #[test]
    fn labels_match_paper_alphabet() {
        // paper's 10-node example is A..K without J
        let labels: Vec<String> = (0..10).map(|i| node_label(i, 10)).collect();
        assert_eq!(labels, vec!["A", "B", "C", "D", "E", "F", "G", "H", "I", "K"]);
    }

    #[test]
    fn big_graphs_use_numeric_labels() {
        assert_eq!(node_label(30, 40), "n30");
    }

    #[test]
    fn dot_contains_edges_and_name() {
        let s = to_dot("fig", &tiny(), &DotStyle::default());
        assert!(s.contains("graph \"fig\""));
        assert!(s.contains("0 -- 1"));
        assert!(s.contains("1 -- 2"));
    }

    #[test]
    fn subnet_styles_local_edges() {
        let style = DotStyle { subnet: Some(vec![0, 0, 1]), ..Default::default() };
        let s = to_dot("fig", &tiny(), &style);
        assert!(s.contains("style=dashed")); // 0-1 local
        assert!(s.contains("color=black")); // 1-2 inter-subnet
    }

    #[test]
    fn coloring_paints_nodes() {
        let col = Coloring::new(vec![0, 1, 0]);
        let style = DotStyle { coloring: Some(col), ..Default::default() };
        let s = to_dot("fig", &tiny(), &style);
        assert!(s.contains("#e06666"));
        assert!(s.contains("#6fa8dc"));
    }

    #[test]
    fn edge_labels_show_weights() {
        let style = DotStyle { edge_labels: true, ..Default::default() };
        let s = to_dot("fig", &tiny(), &style);
        assert!(s.contains("label=\"1.5\""));
        assert!(s.contains("label=\"2.5\""));
    }
}
