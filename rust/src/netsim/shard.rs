//! Sharded discrete-event simulation for the multi-subnet scale-out plane.
//!
//! A single [`NetSim`] event loop is sequential: every event recomputes
//! max-min rates over *all* active flows and channels, so simulating a
//! hierarchy of tens of thousands of devices in one queue is quadratic in
//! the round's flow count. [`ShardedNetSim`] splits the paper's testbed
//! (§IV-A: devices behind routers, routers fully interconnected) into one
//! independent event queue per subnet plus one **backbone shard**:
//!
//! * flows between devices of the same subnet run entirely inside that
//!   subnet's shard (route: up-link → down-link, exactly the flat route);
//! * flows crossing subnets run in the backbone shard over clones of the
//!   endpoints' device links plus the router-router channel — so gateway
//!   traffic contends with other gateway traffic, while intra-subnet
//!   traffic never blocks on a foreign subnet's congestion.
//!
//! Shards advance independently between **round barriers** and are
//! re-synchronized at each barrier ([`ShardedNetSim::drain_and_sync`]):
//! every busy shard — the backbone included, since at large subnet
//! counts it carries *all* gateway traffic and dominates the barrier —
//! becomes one stealable task on a persistent [`DrainPool`] whose worker
//! count is decoupled from the subnet count, then all clocks jump to the
//! latest shard's time. Within a barrier window the shards share no
//! state, so the result is bit-for-bit deterministic regardless of
//! thread scheduling or pool width — parallel and sequential drains of
//! the same sharded simulator are identical.
//!
//! **Fidelity contract.** The decomposition decouples one real coupling:
//! a device's local and cross-subnet flows no longer share its physical
//! up/down link. Single-subnet (and forced single-shard) configurations
//! have no cross flows and run over the *full* testbed wiring with the
//! *same* channel ids — pinned bit-identical to [`Testbed::netsim`]'s
//! flat simulator by `tests/engine_equivalence.rs`. Byte conservation
//! holds in every mode: each launched payload drains exactly once in
//! exactly one shard.

use super::pool::DrainPool;
use super::testbed::Testbed;
use super::{ChannelId, FlowRecord, HostId, NetSim, SimCounters};

/// Derive a shard's RNG stream from the experiment seed (tag 0 = the
/// backbone shard, 1 + subnet index = local shards; the single-shard mode
/// uses the seed untouched so it replays the flat simulator).
fn shard_seed(seed: u64, tag: u64) -> u64 {
    seed ^ (tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)).rotate_left(17) ^ 0x5bad_c0de
}

/// One event queue per subnet plus a backbone queue for gateway flows.
pub struct ShardedNetSim {
    /// Local shards, indexed by subnet (a single entry spanning the full
    /// wiring in single-shard mode).
    shards: Vec<NetSim>,
    /// Cross-subnet flows drain here (`None` in single-shard mode, where
    /// `shards[0]` carries everything over the flat routes).
    backbone: Option<NetSim>,
    /// device → testbed subnet (routing decisions).
    subnet_of: Vec<usize>,
    /// device → shard carrying its intra-subnet flows.
    shard_of: Vec<usize>,
    /// device → (up, down) channel ids inside shard `shard_of[device]`.
    local_links: Vec<(ChannelId, ChannelId)>,
    /// device → (up, down) channel-clone ids inside the backbone shard.
    backbone_links: Vec<(ChannelId, ChannelId)>,
    /// dense S×S router-link table; ids live in the backbone shard, or in
    /// `shards[0]` in single-shard mode.
    router_links: Vec<Option<ChannelId>>,
    subnets: usize,
    /// Payload launched so far (MB) — the byte-conservation ledger.
    launched_mb: f64,
    /// Persistent barrier pool, built lazily on the first parallel drain
    /// and reused across barriers (rebuilt only when the requested width
    /// changes). Pure scheduling state — never touches results.
    pool: Option<DrainPool>,
    /// Requested pool width; 0 = auto (`available_parallelism`).
    drain_workers: usize,
}

impl ShardedNetSim {
    /// One shard per testbed subnet (plus the backbone shard). A testbed
    /// with a single subnet degenerates to [`ShardedNetSim::single`].
    pub fn sharded(tb: &Testbed, seed: u64) -> Self {
        Self::build(tb, seed, tb.subnet_count())
    }

    /// One shard over the full testbed wiring — today's sequential
    /// simulator behind the sharded API, the baseline every speedup and
    /// equivalence claim is measured against.
    pub fn single(tb: &Testbed, seed: u64) -> Self {
        Self::build(tb, seed, 1)
    }

    fn build(tb: &Testbed, seed: u64, shard_count: usize) -> Self {
        let n = tb.node_count();
        let s = tb.subnet_count();
        let subnet_of: Vec<usize> = (0..n).map(|d| tb.subnet_of(d)).collect();
        let mut router_links = vec![None; s * s];

        if shard_count <= 1 || s == 1 {
            // the flat simulator, channel id for channel id — cross flows
            // route up → router-router → down inside the one shard
            for a in 0..s {
                for b in 0..s {
                    router_links[a * s + b] = tb.router_link_id(a, b);
                }
            }
            return ShardedNetSim {
                shards: vec![tb.netsim(seed)],
                backbone: None,
                subnet_of,
                shard_of: vec![0; n],
                local_links: (0..n).map(|d| tb.device_link_ids(d)).collect(),
                backbone_links: Vec::new(),
                router_links,
                subnets: s,
                launched_mb: 0.0,
                pool: None,
                drain_workers: 0,
            };
        }

        // local shards: each subnet's device up/down links, remapped dense
        let mut shards = Vec::with_capacity(s);
        let mut local_links = vec![(0, 0); n];
        for si in 0..s {
            let mut chs = Vec::new();
            for d in tb.subnet_members(si) {
                let (up, down) = tb.device_link_ids(d);
                local_links[d] = (chs.len(), chs.len() + 1);
                chs.push(tb.channels()[up].clone());
                chs.push(tb.channels()[down].clone());
            }
            shards.push(tb.netsim_for_channels(chs, shard_seed(seed, 1 + si as u64)));
        }
        // backbone shard: clones of every device link plus the router mesh
        let mut chs = Vec::new();
        let mut backbone_links = vec![(0, 0); n];
        for d in 0..n {
            let (up, down) = tb.device_link_ids(d);
            backbone_links[d] = (chs.len(), chs.len() + 1);
            chs.push(tb.channels()[up].clone());
            chs.push(tb.channels()[down].clone());
        }
        for a in 0..s {
            for b in 0..s {
                if let Some(c) = tb.router_link_id(a, b) {
                    router_links[a * s + b] = Some(chs.len());
                    chs.push(tb.channels()[c].clone());
                }
            }
        }
        let backbone = Some(tb.netsim_for_channels(chs, shard_seed(seed, 0)));
        ShardedNetSim {
            shards,
            backbone,
            shard_of: subnet_of.clone(),
            subnet_of,
            local_links,
            backbone_links,
            router_links,
            subnets: s,
            launched_mb: 0.0,
            pool: None,
            drain_workers: 0,
        }
    }

    /// Pin the barrier pool's parallelism (concurrent drainers, counting
    /// the calling thread); 0 restores the default
    /// (`std::thread::available_parallelism`). A pure scheduling knob:
    /// drains are bit-identical for every width (shards share no state
    /// within a barrier window), pinned by `tests/scale_shard.rs`.
    pub fn set_drain_workers(&mut self, workers: usize) {
        if self.drain_workers != workers {
            self.drain_workers = workers;
            self.pool = None;
        }
    }

    fn drain_parallelism(&self) -> usize {
        if self.drain_workers > 0 {
            self.drain_workers
        } else {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        }
    }

    /// Aggregate work counters across every shard (local + backbone).
    pub fn counters(&self) -> SimCounters {
        let mut c = SimCounters::default();
        for s in &self.shards {
            c.merge(s.counters());
        }
        if let Some(bb) = &self.backbone {
            c.merge(bb.counters());
        }
        c
    }

    /// Propagate the full-water-filling oracle mode (differential tests)
    /// to every shard; see `NetSim::set_full_rerate`.
    pub fn set_full_rerate(&mut self, full: bool) {
        for s in &mut self.shards {
            s.set_full_rerate(full);
        }
        if let Some(bb) = &mut self.backbone {
            bb.set_full_rerate(full);
        }
    }

    /// Event queues in play (local shards + backbone).
    pub fn shard_count(&self) -> usize {
        self.shards.len() + usize::from(self.backbone.is_some())
    }

    /// Event queues [`ShardedNetSim::sharded`] will use for a testbed of
    /// `subnets` subnets, without building one: one per subnet plus the
    /// backbone, degenerating to the single flat queue at one subnet.
    pub fn planned_shard_count(subnets: usize) -> usize {
        if subnets > 1 {
            subnets + 1
        } else {
            1
        }
    }

    pub fn subnet_count(&self) -> usize {
        self.subnets
    }

    /// Latest clock across all shards (the shared time after a barrier;
    /// between barriers shards advance independently).
    pub fn now(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| s.now())
            .chain(self.backbone.iter().map(|b| b.now()))
            .fold(0.0, f64::max)
    }

    pub fn active_flow_count(&self) -> usize {
        self.shards.iter().map(|s| s.active_flow_count()).sum::<usize>()
            + self.backbone.as_ref().map_or(0, |b| b.active_flow_count())
    }

    /// Payload launched so far (MB) — compare against the completed
    /// records' payload sum to assert byte conservation.
    pub fn launched_payload_mb(&self) -> f64 {
        self.launched_mb
    }

    /// Start a device-to-device transfer: same-subnet flows run in the
    /// subnet's shard, cross-subnet flows in the backbone shard (or the
    /// flat route in single-shard mode).
    pub fn start_flow(&mut self, src: HostId, dst: HostId, payload_mb: f64, tag: u64) {
        assert!(src != dst, "flow to self {src}");
        self.launched_mb += payload_mb;
        let (su, sd) = (self.subnet_of[src], self.subnet_of[dst]);
        if su == sd || self.backbone.is_none() {
            let shard = self.shard_of[src];
            let (up, _) = self.local_links[src];
            let (_, down) = self.local_links[dst];
            let route = if su == sd {
                vec![up, down]
            } else {
                // invariant: construction fills the router mesh for every
                // ordered pair of distinct subnets, and su != sd here
                #[allow(clippy::expect_used)]
                let rr = self.router_links[su * self.subnets + sd].expect("router link");
                vec![up, rr, down]
            };
            self.shards[shard].start_flow(src, dst, route, payload_mb, tag);
        } else if let Some(bb) = self.backbone.as_mut() {
            // reached only when `self.backbone.is_none()` failed above,
            // so the if-let never skips a flow
            let (up, _) = self.backbone_links[src];
            let (_, down) = self.backbone_links[dst];
            // invariant: as above — the router mesh is fully populated
            #[allow(clippy::expect_used)]
            let rr = self.router_links[su * self.subnets + sd].expect("router link");
            bb.start_flow(src, dst, vec![up, rr, down], payload_mb, tag);
        }
    }

    /// Round barrier: drain every shard to idle — as stealable tasks on
    /// the persistent pool when `parallel` — then advance all clocks to
    /// the latest shard's time. Returns the barrier time. Shards share no
    /// state inside the window, so parallel and sequential drains are
    /// bit-identical, whatever the pool width.
    pub fn drain_and_sync(&mut self, parallel: bool) -> f64 {
        let width = self.drain_parallelism();
        if parallel && self.shard_count() > 1 && width > 1 {
            let pool = self.pool.get_or_insert_with(|| DrainPool::new(width));
            // every busy queue is one task — the backbone too: it carries
            // all gateway traffic and dominates the barrier at large
            // subnet counts, so it must not serialize behind the others
            pool.drain(
                self.shards
                    .iter_mut()
                    .chain(self.backbone.as_mut())
                    .filter(|s| s.active_flow_count() > 0),
            );
        } else {
            for sim in self.shards.iter_mut() {
                sim.run_until_idle();
            }
            if let Some(bb) = self.backbone.as_mut() {
                bb.run_until_idle();
            }
        }
        let t = self.now();
        for sim in self.shards.iter_mut() {
            sim.advance_to(t);
        }
        if let Some(bb) = self.backbone.as_mut() {
            bb.advance_to(t);
        }
        t
    }

    /// Drain completed-transfer records from every shard (local shards in
    /// subnet order, then the backbone) — deterministic, and exactly the
    /// flat simulator's completion order in single-shard mode.
    pub fn take_completed(&mut self) -> Vec<FlowRecord> {
        let mut out = Vec::new();
        for sim in self.shards.iter_mut() {
            out.extend(sim.take_completed());
        }
        if let Some(bb) = self.backbone.as_mut() {
            out.extend(bb.take_completed());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn cfg(nodes: usize, subnets: usize) -> ExperimentConfig {
        ExperimentConfig { nodes, subnets, latency_jitter: 0.0, ..Default::default() }
    }

    #[test]
    fn single_shard_replays_flat_simulator_bit_for_bit() {
        let tb = Testbed::new(&cfg(10, 3));
        let mut flat = tb.netsim(7);
        for (src, dst) in [(0, 3), (0, 1), (2, 5), (4, 7)] {
            flat.start_flow(src, dst, tb.route(src, dst), 14.0, (src * 16 + dst) as u64);
        }
        flat.run_until_idle();

        let mut sharded = ShardedNetSim::single(&tb, 7);
        for (src, dst) in [(0, 3), (0, 1), (2, 5), (4, 7)] {
            sharded.start_flow(src, dst, 14.0, (src * 16 + dst) as u64);
        }
        sharded.drain_and_sync(false);
        assert_eq!(sharded.now().to_bits(), flat.now().to_bits());
        let a = sharded.take_completed();
        let b = flat.take_completed();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
            assert_eq!(x.end.to_bits(), y.end.to_bits());
        }
    }

    #[test]
    fn one_subnet_sharded_is_the_single_shard() {
        let tb = Testbed::new(&cfg(8, 1));
        let sharded = ShardedNetSim::sharded(&tb, 1);
        assert_eq!(sharded.shard_count(), 1);
        assert_eq!(sharded.subnet_count(), 1);
    }

    #[test]
    fn local_flows_stay_in_their_shard_and_cross_flows_hit_the_backbone() {
        let tb = Testbed::new(&cfg(12, 3));
        let mut sim = ShardedNetSim::sharded(&tb, 1);
        assert_eq!(sim.shard_count(), 4, "3 local shards + backbone");
        // 0 and 3 share subnet 0; 0 and 1 differ
        sim.start_flow(0, 3, 4.0, 1);
        assert_eq!(sim.shards[0].active_flow_count(), 1);
        sim.start_flow(0, 1, 4.0, 2);
        assert_eq!(sim.backbone.as_ref().unwrap().active_flow_count(), 1);
        let t = sim.drain_and_sync(false);
        assert!(t > 0.0);
        assert_eq!(sim.active_flow_count(), 0);
        let recs = sim.take_completed();
        assert_eq!(recs.len(), 2);
        let total: f64 = recs.iter().map(|r| r.payload_mb).sum();
        assert!((total - sim.launched_payload_mb()).abs() < 1e-9, "bytes conserved");
    }

    #[test]
    fn parallel_and_sequential_drains_are_bit_identical() {
        let run = |parallel: bool| {
            let tb = Testbed::new(&cfg(12, 4));
            let mut sim = ShardedNetSim::sharded(&tb, 3);
            for d in 0..12 {
                sim.start_flow(d, (d + 4) % 12, 5.0, d as u64); // cross flows
                sim.start_flow(d, (d + 8) % 12, 3.0, (100 + d) as u64);
            }
            let t = sim.drain_and_sync(parallel);
            (t, sim.take_completed())
        };
        let (t_seq, r_seq) = run(false);
        let (t_par, r_par) = run(true);
        assert_eq!(t_seq.to_bits(), t_par.to_bits());
        assert_eq!(r_seq.len(), r_par.len());
        for (a, b) in r_seq.iter().zip(&r_par) {
            assert_eq!(a, b);
            assert_eq!(a.end.to_bits(), b.end.to_bits());
        }
    }

    #[test]
    fn barrier_synchronizes_all_shard_clocks() {
        let tb = Testbed::new(&cfg(12, 3));
        let mut sim = ShardedNetSim::sharded(&tb, 1);
        sim.start_flow(0, 3, 20.0, 0); // slow intra flow in shard 0
        sim.start_flow(1, 4, 1.0, 1); // fast intra flow in shard 1
        let t = sim.drain_and_sync(false);
        for s in &sim.shards {
            assert_eq!(s.now().to_bits(), t.to_bits(), "shard clock off the barrier");
        }
        assert_eq!(sim.backbone.as_ref().unwrap().now().to_bits(), t.to_bits());
    }

    #[test]
    fn deterministic_across_runs_with_jitter() {
        let run = || {
            let mut c = cfg(12, 3);
            c.latency_jitter = 0.1; // enables per-shard transfer jitter
            let tb = Testbed::new(&c);
            let mut sim = ShardedNetSim::sharded(&tb, 9);
            for d in 0..12 {
                sim.start_flow(d, (d + 1) % 12, 4.0, d as u64);
            }
            let t = sim.drain_and_sync(true);
            (t, sim.take_completed())
        };
        let (t1, r1) = run();
        let (t2, r2) = run();
        assert_eq!(t1.to_bits(), t2.to_bits());
        assert_eq!(r1, r2);
    }
}
