//! # MOSGU — graph-based gossiping for decentralized federated learning
//!
//! Production-grade reproduction of *"Graph-based Gossiping for
//! Communication Efficiency in Decentralized Federated Learning"*
//! (Nguyen et al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate):** the MOSGU coordination protocol — rotating
//!   moderator, MST pruning, BFS-colored slot scheduling, FIFO gossip —
//!   plus a discrete-event network simulator standing in for the paper's
//!   physical three-router testbed, a flooding-broadcast baseline, and a
//!   live TCP cluster mode.
//! - **Layer 2 (build-time JAX):** the federated model's train/eval steps,
//!   AOT-lowered to HLO text artifacts.
//! - **Layer 1 (build-time Pallas):** aggregation / fused-linear / SGD
//!   kernels called from Layer 2 (interpret mode → portable HLO).
//!
//! The `runtime` module loads the AOT artifacts through PJRT so the gossip
//! request path never touches Python.
//!
//! Start with [`coordinator::session::GossipSession`] (one line to schedule
//! and run a round) or `examples/quickstart.rs`.

pub mod coloring;
pub mod config;
pub mod coordinator;
pub mod dfl;
pub mod graph;
pub mod metrics;
pub mod mst;
pub mod netsim;
pub mod runtime;
pub mod transport;
pub mod util;

pub mod bench;
