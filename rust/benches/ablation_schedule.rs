//! Ablations around the scheduling design choices DESIGN.md calls out:
//!
//! * scalability: MOSGU vs broadcast as N grows beyond the paper's 10;
//! * flooding-with-relay vs direct push (how much worse true flooding is);
//! * failure injection: retransmission cost as loss probability grows;
//! * slot-length sensitivity: the paper formula's budget vs actual slot
//!   occupancy.

use mosgu::bench::section;
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::GossipSession;
use mosgu::coordinator::schedule::slot_length_s;

fn main() {
    section("scalability sweep: N = 10..60, model v2 (14 MB)");
    println!("{:>4} {:>12} {:>12} {:>10} {:>12}", "N", "B total(s)", "P exch(s)", "speedup", "P dissem(s)");
    for n in [10usize, 20, 40, 60] {
        let cfg = ExperimentConfig { nodes: n, repeats: 1, ..Default::default() };
        let s = GossipSession::new(&cfg).expect("session");
        let b = s.run_broadcast_round(14.0, 1);
        let g = s.run_mosgu_round(14.0, 1, 0.0);
        println!(
            "{:>4} {:>12.2} {:>12.2} {:>10.2} {:>12.2}",
            n,
            b.total_time_s,
            g.exchange_time_s,
            b.total_time_s / g.exchange_time_s,
            g.total_time_s
        );
    }

    section("flooding-with-relay vs direct push (complete overlay, N=10, 14 MB)");
    let cfg = ExperimentConfig::default();
    let s = GossipSession::new(&cfg).expect("session");
    let direct = s.run_broadcast_round(14.0, 1);
    let flood = s.run_flood_round(14.0, 1);
    println!(
        "direct push: {} transfers, {:.1} s total;  flood: {} transfers, {:.1} s total ({:.2}x more bytes)",
        direct.transfer_count(),
        direct.total_time_s,
        flood.transfer_count(),
        flood.total_time_s,
        // float ratio: integer division here used to floor 1.9x to 1x
        flood.transfer_count() as f64 / direct.transfer_count().max(1) as f64
    );

    section("failure injection: retransmission overhead (MOSGU, v2)");
    println!("{:>6} {:>8} {:>12} {:>12}", "p_fail", "slots", "transfers", "dissem(s)");
    for p in [0.0, 0.05, 0.1, 0.2, 0.3] {
        let m = s.run_mosgu_round(14.0, 3, p);
        println!("{:>6.2} {:>8} {:>12} {:>12.2}", p, m.slots, m.transfer_count(), m.total_time_s);
    }

    section("slot-length formula vs observed occupancy");
    for (code, mb) in [("v3s", 11.6), ("b0", 21.2), ("b3", 48.0)] {
        let m = s.run_mosgu_round(mb, 1, 0.0);
        // the formula's budget with the session's worst ping
        let worst_ping_ms = s
            .costs()
            .edges()
            .iter()
            .fold(0.0f64, |acc, e| acc.max(e.weight));
        let budget = slot_length_s(worst_ping_ms, mb, 56);
        let occupancy = m.total_time_s / m.slots.max(1) as f64;
        println!(
            "{code:<4} formula budget {:>8.2} s/slot, observed mean occupancy {:>6.2} s/slot ({:.0}% of budget)",
            budget,
            occupancy,
            100.0 * occupancy / budget
        );
    }
}
