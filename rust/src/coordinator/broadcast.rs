//! The flooding-broadcast baseline (paper §V compares MOSGU against
//! "conventional flooding broadcast" [32]).
//!
//! Three modes:
//!
//! * [`BroadcastMode::DirectPush`] — every node pushes its model to every
//!   overlay neighbor simultaneously. On the paper's complete overlay this
//!   is the baseline of Tables III–V: N·(N−1) concurrent transfers, no
//!   scheduling, maximal contention.
//! * [`BroadcastMode::Flood`] — classic flooding with duplicate
//!   suppression at receivers: a node re-forwards every *new* model to all
//!   neighbors except the source. Strictly worse on dense overlays (the
//!   redundant copies still burn bandwidth); included for the ablation
//!   bench.
//! * [`BroadcastMode::RandomGossip`] — fanout-f push gossip in the style
//!   of the classic epidemic protocols (cf. arXiv:1908.07782): a node
//!   forwards each *new* model to `fanout` uniformly sampled neighbors
//!   instead of all of them. Caps the redundancy of flooding at the price
//!   of probabilistic coverage; with `fanout >=` the overlay's maximum
//!   degree it degenerates to flooding exactly.

use crate::graph::{Graph, NodeId};
use crate::metrics::RoundMetrics;
use crate::netsim::testbed::Testbed;
use crate::util::rng::Pcg64;
use std::collections::HashSet;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BroadcastMode {
    DirectPush,
    Flood,
    /// Push each new model to `fanout` randomly sampled eligible
    /// neighbors (sampling is seeded per node from the round seed, so
    /// runs replay bit-identically).
    RandomGossip { fanout: usize },
}

/// Tag layout for flow records: model owner in bits 0..16, segment index
/// in bits 16..32, sender in bits 32..64 — lets metrics recover which
/// model (and which slice of it) a flow carried. Shared by every driver
/// (broadcast, the engine's sim/logical/live drivers).
///
/// Whole-model transfers carry segment index 0, so their tags are
/// bit-identical to the pre-segmentation layout (owner in the low word,
/// sender above) for every node count the testbed supports (< 2^16).
pub fn flow_tag_segment(owner: NodeId, sender: NodeId, segment: u16) -> u64 {
    debug_assert!(owner < 1 << 16, "node id {owner} exceeds the 16-bit tag field");
    ((sender as u64) << 32) | ((segment as u64) << 16) | owner as u64
}

/// Whole-model (segment 0) tag — the legacy layout.
pub fn flow_tag(owner: NodeId, sender: NodeId) -> u64 {
    flow_tag_segment(owner, sender, 0)
}

pub fn tag_owner(tag: u64) -> NodeId {
    (tag & 0xffff) as NodeId
}

/// Segment index of the transfer unit this flow carried (0 for
/// whole-model transfers).
pub fn tag_segment(tag: u64) -> u16 {
    ((tag >> 16) & 0xffff) as u16
}

pub fn tag_sender(tag: u64) -> NodeId {
    (tag >> 32) as NodeId
}

/// Run one broadcast communication round of `model_mb`-sized models over
/// the overlay `structure`, timed on the testbed's simulator.
pub fn run_broadcast_round(
    testbed: &Testbed,
    structure: &Graph,
    model_mb: f64,
    mode: BroadcastMode,
    seed: u64,
) -> RoundMetrics {
    let n = structure.node_count();
    assert!(structure.is_connected(), "broadcast needs a connected overlay");
    let mut sim = testbed.netsim(seed);
    // holds[u] = set of model owners node u has
    let mut holds: Vec<HashSet<NodeId>> = (0..n).map(|u| HashSet::from([u])).collect();
    // per-node sampling streams (only RandomGossip draws from them, so
    // the other modes replay bit-identically to the pre-gossip engine)
    let mut rngs: Vec<Pcg64> = {
        let mut root = Pcg64::new(seed ^ 0x6055_1F00);
        (0..n).map(|u| root.fork(u as u64)).collect()
    };

    // t=0: every node pushes its own model to its push set (all overlay
    // neighbors, or a fanout-sized sample under RandomGossip)
    for u in 0..n {
        for v in push_targets(structure, u, u, u, mode, &mut rngs) {
            sim.start_flow(u, v, testbed.route(u, v), model_mb, flow_tag(u, u));
        }
    }

    match mode {
        BroadcastMode::DirectPush => {
            sim.run_until_idle();
            for rec in sim.completed() {
                holds[rec.dst].insert(tag_owner(rec.tag));
            }
        }
        BroadcastMode::Flood | BroadcastMode::RandomGossip { .. } => {
            // reactive: forward each newly received model to the push set
            // (all neighbors except the source, or a sample of them)
            let mut cursor = 0usize;
            loop {
                let Some(eta) = sim.next_completion_eta() else { break };
                sim.advance_to(eta);
                // apply newly completed deliveries in deterministic order
                let mut fresh: Vec<(NodeId, NodeId, NodeId)> = Vec::new(); // (dst, src, owner)
                while cursor < sim.completed().len() {
                    let rec = sim.completed()[cursor].clone();
                    cursor += 1;
                    fresh.push((rec.dst, rec.src, tag_owner(rec.tag)));
                }
                fresh.sort_unstable();
                for (dst, src, owner) in fresh {
                    if holds[dst].insert(owner) {
                        for v in push_targets(structure, dst, src, owner, mode, &mut rngs) {
                            sim.start_flow(dst, v, testbed.route(dst, v), model_mb, flow_tag(owner, dst));
                        }
                    }
                }
            }
        }
    }

    // dissemination completeness on a connected overlay: flooding always
    // delivers everything, DirectPush only on complete overlays, and
    // RandomGossip exactly when its fanout never truncates a push set
    // (it is then flooding move for move)
    let guaranteed = match mode {
        BroadcastMode::Flood => true,
        BroadcastMode::DirectPush => is_complete_graph(structure),
        BroadcastMode::RandomGossip { fanout } => (0..n).all(|u| structure.degree(u) <= fanout),
    };
    if guaranteed {
        debug_assert!(
            holds.iter().all(|h| h.len() == n),
            "broadcast round left nodes without models"
        );
    }

    let total = sim.now();
    let sim_counters = sim.counters();
    RoundMetrics {
        transfers: sim.take_completed(),
        total_time_s: total,
        exchange_time_s: total,
        slots: 0,
        slot_timings: Vec::new(),
        segments: 1,
        relay_copies: 0,
        // the baseline stays uncompressed full-width fp32 (the paper's
        // conventional flooding broadcast): wire == logical
        logical_model_mb: model_mb,
        wire_model_mb: model_mb,
        sim: sim_counters,
    }
}

/// The push set for an `owner`-model arriving at `at` from `src` (for the
/// t=0 self-push, `at == src == owner`): every eligible neighbor under
/// DirectPush/Flood, a seeded `fanout`-sized sample under RandomGossip.
/// Eligibility excludes the node the copy just came from and the model's
/// owner — neither needs it back.
fn push_targets(
    structure: &Graph,
    at: NodeId,
    src: NodeId,
    owner: NodeId,
    mode: BroadcastMode,
    rngs: &mut [Pcg64],
) -> Vec<NodeId> {
    let eligible: Vec<NodeId> = structure
        .neighbor_ids(at)
        .into_iter()
        .filter(|&v| v != src && v != owner)
        .collect();
    match mode {
        BroadcastMode::RandomGossip { fanout } => {
            let k = fanout.min(eligible.len());
            let mut picks: Vec<NodeId> = rngs[at]
                .sample_indices(eligible.len(), k)
                .into_iter()
                .map(|i| eligible[i])
                .collect();
            // launch order stays id-sorted like the dense modes'
            picks.sort_unstable();
            picks
        }
        _ => eligible,
    }
}

fn is_complete_graph(g: &Graph) -> bool {
    let n = g.node_count();
    g.edge_count() == n * (n - 1) / 2
}

/// Convenience: all-to-all direct push on the complete overlay — the exact
/// baseline of the paper's tables.
pub fn paper_baseline(testbed: &Testbed, model_mb: f64, seed: u64) -> RoundMetrics {
    let overlay = crate::graph::topology::complete(testbed.node_count());
    run_broadcast_round(testbed, &overlay, model_mb, BroadcastMode::DirectPush, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn tb() -> Testbed {
        Testbed::new(&ExperimentConfig { latency_jitter: 0.0, ..Default::default() })
    }

    #[test]
    fn direct_push_transfer_count() {
        let m = paper_baseline(&tb(), 11.6, 1);
        assert_eq!(m.transfer_count(), 90, "N(N-1) transfers");
        assert_eq!(m.slots, 0);
        assert!(m.total_time_s > 0.0);
    }

    #[test]
    fn direct_push_congestion_lowers_bandwidth() {
        let m = paper_baseline(&tb(), 11.6, 1);
        // ~9-way uplink sharing on 11 MB/s links => well under 3 MB/s each
        assert!(m.bandwidth_mbps() < 3.0, "bw={}", m.bandwidth_mbps());
        assert!(m.bandwidth_mbps() > 0.2);
    }

    #[test]
    fn bigger_models_lower_broadcast_bandwidth() {
        // paper Table III broadcast column: bandwidth falls with model size
        let small = paper_baseline(&tb(), 11.6, 1).bandwidth_mbps();
        let large = paper_baseline(&tb(), 48.0, 1).bandwidth_mbps();
        assert!(large < small, "large {large} should be slower than small {small}");
    }

    #[test]
    fn flood_on_sparse_overlay_reaches_everyone() {
        let mut overlay = Graph::new(10);
        for u in 0..9 {
            overlay.add_edge(u, u + 1, 1.0); // path overlay
        }
        let m = run_broadcast_round(&tb(), &overlay, 5.0, BroadcastMode::Flood, 1);
        // path flooding: each of the 10 models crosses each of the 9 edges once
        // => at least 90 transfers; duplicate-suppression keeps it finite
        assert!(m.transfer_count() >= 90, "{}", m.transfer_count());
        assert!(m.total_time_s > 0.0);
    }

    #[test]
    fn flood_on_complete_overlay_is_much_more_wasteful() {
        let overlay = crate::graph::topology::complete(6);
        // use a smaller testbed for speed
        let cfg = ExperimentConfig { nodes: 6, latency_jitter: 0.0, ..Default::default() };
        let tb = Testbed::new(&cfg);
        let direct = run_broadcast_round(&tb, &overlay, 2.0, BroadcastMode::DirectPush, 1);
        let flood = run_broadcast_round(&tb, &overlay, 2.0, BroadcastMode::Flood, 1);
        assert!(flood.transfer_count() > 2 * direct.transfer_count());
    }

    #[test]
    fn random_gossip_with_covering_fanout_is_flooding_move_for_move() {
        // on a path every push set has at most 2 nodes, so fanout 2 never
        // truncates anything: the sampled mode must replay the flood run
        // bit for bit (and therefore reach everyone — each of the 10
        // models crosses each of the 9 edges exactly once)
        let mut overlay = Graph::new(10);
        for u in 0..9 {
            overlay.add_edge(u, u + 1, 1.0);
        }
        let tb = tb();
        let flood = run_broadcast_round(&tb, &overlay, 5.0, BroadcastMode::Flood, 3);
        let gossip =
            run_broadcast_round(&tb, &overlay, 5.0, BroadcastMode::RandomGossip { fanout: 2 }, 3);
        assert_eq!(gossip.transfer_count(), flood.transfer_count());
        assert_eq!(gossip.transfer_count(), 90, "10 models x 9 edges, once each");
        assert_eq!(gossip.total_time_s.to_bits(), flood.total_time_s.to_bits());
        // every node receives all 9 foreign models
        let mut got: Vec<HashSet<NodeId>> = vec![HashSet::new(); 10];
        for r in &gossip.transfers {
            got[r.dst].insert(tag_owner(r.tag));
        }
        for (u, owners) in got.iter().enumerate() {
            assert_eq!(owners.len(), 9, "node {u} missed models");
        }
    }

    #[test]
    fn random_gossip_fanout_caps_flooding_redundancy_and_conserves_bytes() {
        let overlay = crate::graph::topology::complete(8);
        let cfg = ExperimentConfig { nodes: 8, latency_jitter: 0.0, ..Default::default() };
        let tb = Testbed::new(&cfg);
        let flood = run_broadcast_round(&tb, &overlay, 2.0, BroadcastMode::Flood, 1);
        let gossip =
            run_broadcast_round(&tb, &overlay, 2.0, BroadcastMode::RandomGossip { fanout: 1 }, 1);
        // each node launches at most one copy per model it first receives
        // (plus its own seed push): n + n(n-1) flows at the very most
        assert!(gossip.transfer_count() <= 8 + 8 * 7, "{}", gossip.transfer_count());
        assert!(gossip.transfer_count() >= 8, "every node seeds its own model");
        assert!(
            gossip.transfer_count() < flood.transfer_count(),
            "fanout 1 ({}) must undercut flooding ({})",
            gossip.transfer_count(),
            flood.transfer_count()
        );
        // byte conservation: every flow carries exactly one whole model
        let expect_mb = gossip.transfer_count() as f64 * 2.0;
        assert!((gossip.total_payload_mb() - expect_mb).abs() < 1e-9);
    }

    #[test]
    fn random_gossip_replays_bit_identically_per_seed() {
        let overlay = crate::graph::topology::complete(8);
        let cfg = ExperimentConfig { nodes: 8, latency_jitter: 0.0, ..Default::default() };
        let tb = Testbed::new(&cfg);
        let mode = BroadcastMode::RandomGossip { fanout: 2 };
        let a = run_broadcast_round(&tb, &overlay, 3.0, mode, 7);
        let b = run_broadcast_round(&tb, &overlay, 3.0, mode, 7);
        assert_eq!(a.transfer_count(), b.transfer_count());
        assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
        // and a different seed samples a different forwarding pattern
        // (counts may coincide; the flow lists should not)
        let c = run_broadcast_round(&tb, &overlay, 3.0, mode, 8);
        let pairs = |m: &RoundMetrics| {
            let mut p: Vec<(NodeId, NodeId, NodeId)> =
                m.transfers.iter().map(|r| (r.src, r.dst, tag_owner(r.tag))).collect();
            p.sort_unstable();
            p
        };
        assert_eq!(pairs(&a), pairs(&b));
        assert_ne!(pairs(&a), pairs(&c), "seed must steer the sampling");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = paper_baseline(&tb(), 14.0, 9);
        let b = paper_baseline(&tb(), 14.0, 9);
        assert_eq!(a.transfer_count(), b.transfer_count());
        assert!((a.total_time_s - b.total_time_s).abs() < 1e-12);
    }

    #[test]
    fn segment_tags_roundtrip_and_anchor_legacy_layout() {
        let t = flow_tag_segment(7, 3, 5);
        assert_eq!(tag_owner(t), 7);
        assert_eq!(tag_sender(t), 3);
        assert_eq!(tag_segment(t), 5);
        // segment 0 reproduces the pre-segmentation tag bits exactly
        assert_eq!(flow_tag(9, 4), ((4u64) << 32) | 9);
        assert_eq!(tag_segment(flow_tag(9, 4)), 0);
    }
}
