//! Topology sweep: the paper's §V-B analysis — how the four underlay
//! families affect bandwidth, transfer time and round time, including the
//! qualitative claims (Erdős–Rényi best for large models, Barabási–Albert
//! second slowest, Complete best bandwidth for small/medium).
//!
//! ```bash
//! cargo run --release --example topology_sweep [-- --models v3s,b0,b3]
//! ```

use mosgu::bench::tables::{all_models, run_grid};
use mosgu::config::ExperimentConfig;
use mosgu::dfl::models::by_code;
use mosgu::graph::topology::TopologyKind;

fn main() -> anyhow::Result<()> {
    mosgu::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let models = match args.iter().position(|a| a == "--models") {
        Some(i) => args[i + 1]
            .split(',')
            .map(|c| by_code(c.trim()).ok_or_else(|| anyhow::anyhow!("unknown model {c}")))
            .collect::<Result<Vec<_>, _>>()?,
        None => all_models(),
    };

    let cfg = ExperimentConfig { repeats: 3, ..Default::default() };
    let cells = run_grid(&cfg, &TopologyKind::ALL, &models, |s| eprintln!("running {s}"))?;

    println!("\n{:<17}{:>6}{:>10}{:>10}{:>10}{:>11}{:>11}", "topology", "model", "P:bw", "P:xfer", "P:round", "bw-gain", "time-gain");
    for c in &cells {
        println!(
            "{:<17}{:>6}{:>10.2}{:>10.2}{:>10.2}{:>10.1}x{:>10.1}x",
            c.topology,
            c.model,
            c.proposed.bandwidth.mean(),
            c.proposed.transfer.mean(),
            c.proposed.exchange.mean(),
            c.proposed.bandwidth.mean() / c.broadcast.bandwidth.mean(),
            c.broadcast.total.mean() / c.proposed.exchange.mean(),
        );
    }

    // §V-B qualitative checks
    println!("\n== paper §V-B qualitative checks ==");
    let mean_over = |topo: &str, f: &dyn Fn(&mosgu::metrics::Cell) -> f64| {
        let xs: Vec<f64> = cells.iter().filter(|c| c.topology == topo).map(f).collect();
        xs.iter().sum::<f64>() / xs.len() as f64
    };
    let gain = |c: &mosgu::metrics::Cell| c.proposed.bandwidth.mean() / c.broadcast.bandwidth.mean();
    for kind in TopologyKind::ALL {
        println!("  {:<17} mean bandwidth gain {:.2}x", kind.name(), mean_over(kind.name(), &gain));
    }
    let ba = mean_over("Barabasi-Albert", &|c| c.proposed.transfer.mean());
    let er = mean_over("Erdos-Renyi", &|c| c.proposed.transfer.mean());
    println!(
        "  BA mean transfer {ba:.2} s vs ER {er:.2} s -> hubs slow BA down: {}",
        if ba > er { "yes (matches paper)" } else { "no" }
    );
    Ok(())
}
