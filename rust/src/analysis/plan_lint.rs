//! The plan linter: a pure, simulation-free verifier over plan artifacts.
//!
//! Every claim the paper makes about a round rests on the published plan
//! being well formed: the gossip tree must *span* (§III-B — otherwise a
//! node never receives), the coloring must be *proper* so no slot ever
//! schedules two adjacent half-duplex transmitters (§III-C), extra
//! dissemination lanes must be pairwise *edge-disjoint* (multi-tree
//! striping conserves bytes only if stripes never contend for a link),
//! and the slot budget must equal the §III-C formula over the measured
//! costs. Until now those invariants were checked incidentally, deep
//! inside simulation tests; this module checks them **statically** — no
//! simulator, no engine, just the plan and the cost graph it was planned
//! from.
//!
//! Entry points:
//!
//! * [`lint_epoch`] / [`lint_bundle`] — one-shot verification of a
//!   [`PlanEpoch`] or [`ScheduleBundle`] against a [`LintContext`];
//! * [`PlanLinter`] — the accumulating form, for composing plan checks
//!   with transfer-plan ([`PlanLinter::check_stripes`]) and
//!   participation ([`PlanLinter::check_participation`]) checks;
//! * `mosgu lint-plan` on the CLI, and a `debug_assertions` hook inside
//!   the moderator after every plan/replan (see
//!   [`crate::coordinator::moderator::Moderator`]).
//!
//! The linter never panics on malformed input: a plan with the wrong
//! node count or a truncated coloring produces [`Violation`]s, not an
//! index panic, so it can sit in front of untrusted or corrupted plans.

use crate::coloring::Coloring;
use crate::coordinator::engine::PlanEpoch;
use crate::coordinator::moderator::ScheduleBundle;
use crate::coordinator::schedule::{class_ping_max_ms, slot_length_s, Schedule};
use crate::dfl::data::ParticipationPlan;
use crate::dfl::transfer::TransferPlan;
use crate::graph::{Graph, NodeId};
use std::collections::HashMap;
use std::fmt;

/// What a plan is linted *against*: the cost graph the schedule was
/// budgeted from and the §III-C slot-formula inputs the moderator used.
#[derive(Debug, Clone, Copy)]
pub struct LintContext<'a> {
    /// The measured cost graph (pings, ms) the plan was computed from.
    /// Lane trees must draw their edges from here, and the slot budget
    /// must equal the §III-C formula over these weights.
    pub costs: &'a Graph,
    /// The transfer unit (MB) fed to `build_schedule` — the whole
    /// checkpoint under a whole-model plan, one segment otherwise.
    pub unit_mb: f64,
    /// The ping probe payload (bytes) of the slot-length formula.
    pub ping_size_bytes: u64,
}

/// One statically detected plan defect. Each variant carries enough
/// graph context to render an actionable message (see the `Display`
/// impl); [`Violation::kind`] gives a stable machine-matchable label.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    /// A lane's tree covers a different node set than the cost graph.
    WrongNodeCount { lane: usize, tree_nodes: usize, plan_nodes: usize },
    /// A lane has the wrong edge count for a spanning tree (`n - 1`).
    NotSpanning { lane: usize, edges: usize, nodes: usize },
    /// A lane's tree does not reach every node (sample of the cut-off
    /// nodes, capped at eight).
    Disconnected { lane: usize, unreachable: Vec<NodeId> },
    /// A lane's coloring assigns a different number of nodes than the
    /// tree has.
    ColoringLength { lane: usize, colored: usize, nodes: usize },
    /// A tree edge joins two same-colored nodes — the §III-C properness
    /// invariant is broken.
    ImproperEdge { lane: usize, u: NodeId, v: NodeId, color: usize },
    /// A color below `num_colors` has no nodes: a slot with zero
    /// transmitters in every cycle.
    EmptyColorClass { lane: usize, color: usize, num_colors: usize },
    /// `first_color` does not name an existing class (with ≥ 2 colors;
    /// the modulo slot rotation makes it harmless only when `k = 1`).
    FirstColorOutOfRange { lane: usize, first_color: usize, num_colors: usize },
    /// Two adjacent nodes transmit in the same slot — a half-duplex
    /// conflict on a tree edge.
    SlotConflict { lane: usize, slot: usize, u: NodeId, v: NodeId },
    /// A lane uses an edge the cost graph never measured.
    ForeignEdge { lane: usize, u: NodeId, v: NodeId },
    /// Two lanes share an edge — stripes must be pairwise edge-disjoint.
    SharedEdge { lane_a: usize, lane_b: usize, u: NodeId, v: NodeId },
    /// The published slot length disagrees with the §III-C formula
    /// recomputed over the cost graph.
    SlotBudgetMismatch { lane: usize, got_s: f64, want_s: f64, ping_max_ms: f64 },
    /// The published neighbor table disagrees with the lane-0 tree.
    NeighborTableMismatch { node: NodeId },
    /// Striped per-lane transfer plans do not sum back to one copy.
    StripeByteLoss { lanes: usize, striped_mb: f64, copy_mb: f64 },
    /// A lane's stripe carries the wrong segment count.
    StripeSegmentMismatch { lane: usize, got: usize, want: usize },
    /// Segment bounds leave a gap or overlap inside the parameter vector.
    SegmentBoundsGap { segment: usize, start: usize, expected_start: usize },
    /// Segment bounds do not cover the parameter vector exactly.
    SegmentBoundsCoverage { covered: usize, len: usize },
    /// A round inside the linted horizon has no participant set.
    MissingParticipants { round: u64 },
    /// A round's participant set is empty — nobody originates.
    NoOriginators { round: u64 },
    /// A participant id is outside the node range.
    ParticipantOutOfRange { round: u64, node: NodeId, n: usize },
    /// `originates` and the participant list disagree about a node.
    OriginationMismatch { round: u64, node: NodeId, listed: bool, originates: bool },
}

impl Violation {
    /// Stable machine-matchable label (the mutation suite keys on it).
    pub fn kind(&self) -> &'static str {
        match self {
            Violation::WrongNodeCount { .. } => "wrong-node-count",
            Violation::NotSpanning { .. } => "not-spanning",
            Violation::Disconnected { .. } => "disconnected",
            Violation::ColoringLength { .. } => "coloring-length",
            Violation::ImproperEdge { .. } => "improper-edge",
            Violation::EmptyColorClass { .. } => "empty-color-class",
            Violation::FirstColorOutOfRange { .. } => "first-color-out-of-range",
            Violation::SlotConflict { .. } => "slot-conflict",
            Violation::ForeignEdge { .. } => "foreign-edge",
            Violation::SharedEdge { .. } => "shared-edge",
            Violation::SlotBudgetMismatch { .. } => "slot-budget-mismatch",
            Violation::NeighborTableMismatch { .. } => "neighbor-table-mismatch",
            Violation::StripeByteLoss { .. } => "stripe-byte-loss",
            Violation::StripeSegmentMismatch { .. } => "stripe-segment-mismatch",
            Violation::SegmentBoundsGap { .. } => "segment-bounds-gap",
            Violation::SegmentBoundsCoverage { .. } => "segment-bounds-coverage",
            Violation::MissingParticipants { .. } => "missing-participants",
            Violation::NoOriginators { .. } => "no-originators",
            Violation::ParticipantOutOfRange { .. } => "participant-out-of-range",
            Violation::OriginationMismatch { .. } => "origination-mismatch",
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::WrongNodeCount { lane, tree_nodes, plan_nodes } => write!(
                f,
                "lane {lane}: tree covers {tree_nodes} nodes but the plan is over {plan_nodes}"
            ),
            Violation::NotSpanning { lane, edges, nodes } => write!(
                f,
                "lane {lane}: {edges} edges for {nodes} nodes (a spanning tree needs n - 1 = {})",
                nodes.saturating_sub(1)
            ),
            Violation::Disconnected { lane, unreachable } => write!(
                f,
                "lane {lane}: tree does not reach nodes {unreachable:?} — they would never \
                 receive a model"
            ),
            Violation::ColoringLength { lane, colored, nodes } => write!(
                f,
                "lane {lane}: coloring assigns {colored} nodes but the tree has {nodes}"
            ),
            Violation::ImproperEdge { lane, u, v, color } => write!(
                f,
                "lane {lane}: tree edge ({u}, {v}) joins two color-{color} nodes — they would \
                 transmit in the same slot (§III-C properness broken)"
            ),
            Violation::EmptyColorClass { lane, color, num_colors } => write!(
                f,
                "lane {lane}: color {color} of {num_colors} has no nodes — a slot with zero \
                 transmitters every cycle"
            ),
            Violation::FirstColorOutOfRange { lane, first_color, num_colors } => write!(
                f,
                "lane {lane}: first_color {first_color} does not name one of the {num_colors} \
                 classes"
            ),
            Violation::SlotConflict { lane, slot, u, v } => write!(
                f,
                "lane {lane} slot {slot}: adjacent nodes {u} and {v} both transmit — half-duplex \
                 conflict on tree edge ({u}, {v})"
            ),
            Violation::ForeignEdge { lane, u, v } => write!(
                f,
                "lane {lane}: tree edge ({u}, {v}) is absent from the measured cost graph"
            ),
            Violation::SharedEdge { lane_a, lane_b, u, v } => write!(
                f,
                "lanes {lane_a} and {lane_b} share edge ({u}, {v}) — stripes must ride pairwise \
                 edge-disjoint trees"
            ),
            Violation::SlotBudgetMismatch { lane, got_s, want_s, ping_max_ms } => write!(
                f,
                "lane {lane}: published slot length {got_s:.6} s but the §III-C formula over the \
                 cost graph gives {want_s:.6} s (ping_max {ping_max_ms:.3} ms)"
            ),
            Violation::NeighborTableMismatch { node } => write!(
                f,
                "neighbor table for node {node} disagrees with the published tree"
            ),
            Violation::StripeByteLoss { lanes, striped_mb, copy_mb } => write!(
                f,
                "{lanes} striped lanes move {striped_mb:.6} MB total but one copy is \
                 {copy_mb:.6} MB — bytes are not conserved"
            ),
            Violation::StripeSegmentMismatch { lane, got, want } => write!(
                f,
                "stripe for lane {lane} carries {got} segments, expected {want}"
            ),
            Violation::SegmentBoundsGap { segment, start, expected_start } => write!(
                f,
                "segment {segment} starts at element {start}, expected {expected_start} \
                 (gap or overlap in the slicing)"
            ),
            Violation::SegmentBoundsCoverage { covered, len } => write!(
                f,
                "segment bounds cover {covered} of {len} parameter elements"
            ),
            Violation::MissingParticipants { round } => write!(
                f,
                "round {round}: no participant set inside the plan horizon"
            ),
            Violation::NoOriginators { round } => write!(
                f,
                "round {round}: empty participant set — nobody trains or originates"
            ),
            Violation::ParticipantOutOfRange { round, node, n } => write!(
                f,
                "round {round}: participant {node} is outside the {n}-node session"
            ),
            Violation::OriginationMismatch { round, node, listed, originates } => write!(
                f,
                "round {round}: node {node} listed={listed} but originates={originates} — the \
                 participant set and the origination mask disagree"
            ),
        }
    }
}

/// The linter's verdict: every violation found, in check order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LintReport {
    violations: Vec<Violation>,
}

impl LintReport {
    /// True when no check fired.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Every violation, in check order.
    pub fn violations(&self) -> &[Violation] {
        &self.violations
    }

    /// Whether any violation of the given [`Violation::kind`] fired.
    pub fn has(&self, kind: &str) -> bool {
        self.violations.iter().any(|v| v.kind() == kind)
    }

    /// Distinct kinds present, in first-seen order.
    pub fn kinds(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for v in &self.violations {
            if !out.contains(&v.kind()) {
                out.push(v.kind());
            }
        }
        out
    }
}

impl fmt::Display for LintReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.violations.is_empty() {
            return write!(f, "plan lint: clean");
        }
        writeln!(f, "plan lint: {} violation(s)", self.violations.len())?;
        for v in &self.violations {
            writeln!(f, "  - [{}] {v}", v.kind())?;
        }
        Ok(())
    }
}

/// Accumulating plan linter: run any subset of checks against one
/// [`LintContext`], then [`PlanLinter::finish`] into a [`LintReport`].
#[derive(Debug)]
pub struct PlanLinter<'a> {
    ctx: LintContext<'a>,
    violations: Vec<Violation>,
}

impl<'a> PlanLinter<'a> {
    pub fn new(ctx: LintContext<'a>) -> Self {
        PlanLinter { ctx, violations: Vec::new() }
    }

    /// All checks over one dissemination lane (tree + schedule):
    /// spanning/acyclicity, coloring shape + properness, empty classes,
    /// `first_color` range, per-slot half-duplex conflicts, edge
    /// membership in the cost graph, and the §III-C slot budget.
    pub fn check_lane(&mut self, lane: usize, tree: &Graph, schedule: &Schedule) {
        let plan_nodes = self.ctx.costs.node_count();
        let n = tree.node_count();
        if n != plan_nodes {
            self.violations.push(Violation::WrongNodeCount {
                lane,
                tree_nodes: n,
                plan_nodes,
            });
        }
        if n > 0 {
            if tree.edge_count() != n - 1 {
                self.violations.push(Violation::NotSpanning {
                    lane,
                    edges: tree.edge_count(),
                    nodes: n,
                });
            }
            let unreachable = unreachable_from(tree, 0);
            if !unreachable.is_empty() {
                self.violations.push(Violation::Disconnected { lane, unreachable });
            }
        }
        let coloring = &schedule.coloring;
        if coloring.len() != n {
            // further color checks would index out of bounds; the length
            // violation already names the root cause
            self.violations.push(Violation::ColoringLength {
                lane,
                colored: coloring.len(),
                nodes: n,
            });
            return;
        }
        for e in tree.edges() {
            if coloring.color_of(e.u) == coloring.color_of(e.v) {
                self.violations.push(Violation::ImproperEdge {
                    lane,
                    u: e.u,
                    v: e.v,
                    color: coloring.color_of(e.u),
                });
            }
        }
        let num_colors = coloring.num_colors();
        if num_colors >= 2 {
            let mut counts = vec![0usize; num_colors];
            for &c in coloring.assignment() {
                counts[c] += 1;
            }
            for (color, &count) in counts.iter().enumerate() {
                if count == 0 {
                    self.violations.push(Violation::EmptyColorClass {
                        lane,
                        color,
                        num_colors,
                    });
                }
            }
            if schedule.first_color >= num_colors {
                self.violations.push(Violation::FirstColorOutOfRange {
                    lane,
                    first_color: schedule.first_color,
                    num_colors,
                });
            }
        }
        // half-duplex conflict freedom, slot by slot over one full color
        // cycle: O(E·k), independent of class sizes
        for slot in 0..num_colors {
            for e in tree.edges() {
                if schedule.transmits_in_slot(e.u, slot) && schedule.transmits_in_slot(e.v, slot)
                {
                    self.violations.push(Violation::SlotConflict {
                        lane,
                        slot,
                        u: e.u,
                        v: e.v,
                    });
                }
            }
        }
        for e in tree.edges() {
            if e.u < plan_nodes && e.v < plan_nodes && !self.ctx.costs.has_edge(e.u, e.v) {
                self.violations.push(Violation::ForeignEdge { lane, u: e.u, v: e.v });
            }
        }
        self.check_slot_budget(lane, coloring, schedule.slot_len_s);
    }

    /// Recompute the §III-C slot length over the context's cost graph —
    /// the exact fold `build_schedule` runs — and compare.
    fn check_slot_budget(&mut self, lane: usize, coloring: &Coloring, got_s: f64) {
        let ping_max_ms = (0..coloring.num_colors())
            .map(|c| class_ping_max_ms(self.ctx.costs, coloring, c))
            .fold(0.0, f64::max);
        let want_s = slot_length_s(ping_max_ms, self.ctx.unit_mb, self.ctx.ping_size_bytes);
        if (got_s - want_s).abs() > want_s.abs() * 1e-9 + 1e-12 {
            self.violations.push(Violation::SlotBudgetMismatch {
                lane,
                got_s,
                want_s,
                ping_max_ms,
            });
        }
    }

    /// Pairwise edge-disjointness across the given lane trees (lane 0
    /// first). Reports each shared edge once, with both lane indices.
    pub fn check_disjoint(&mut self, trees: &[&Graph]) {
        let mut owner: HashMap<(NodeId, NodeId), usize> = HashMap::new();
        for (lane, tree) in trees.iter().enumerate() {
            for e in tree.edges() {
                let key = (e.u.min(e.v), e.u.max(e.v));
                match owner.get(&key) {
                    Some(&prev) => self.violations.push(Violation::SharedEdge {
                        lane_a: prev,
                        lane_b: lane,
                        u: key.0,
                        v: key.1,
                    }),
                    None => {
                        owner.insert(key, lane);
                    }
                }
            }
        }
    }

    /// Lint every lane of a [`PlanEpoch`] plus cross-lane disjointness.
    pub fn check_epoch(&mut self, epoch: &PlanEpoch) {
        self.check_lane(0, &epoch.tree, &epoch.schedule);
        for (i, lane) in epoch.extra.iter().enumerate() {
            self.check_lane(i + 1, &lane.tree, &lane.schedule);
        }
        let mut trees: Vec<&Graph> = vec![&epoch.tree];
        trees.extend(epoch.extra.iter().map(|l| &l.tree));
        self.check_disjoint(&trees);
    }

    /// Lint a published [`ScheduleBundle`]: every lane, cross-lane
    /// disjointness, and the neighbor table against the lane-0 tree.
    pub fn check_bundle(&mut self, bundle: &ScheduleBundle) {
        self.check_lane(0, &bundle.tree, &bundle.schedule);
        for (i, lane) in bundle.extra.iter().enumerate() {
            self.check_lane(i + 1, &lane.tree, &lane.schedule);
        }
        let mut trees: Vec<&Graph> = vec![&bundle.tree];
        trees.extend(bundle.extra.iter().map(|l| &l.tree));
        self.check_disjoint(&trees);
        let n = bundle.tree.node_count();
        if bundle.neighbor_table.len() != n {
            self.violations.push(Violation::NeighborTableMismatch {
                node: bundle.neighbor_table.len().min(n),
            });
        }
        for (u, table) in bundle.neighbor_table.iter().enumerate().take(n) {
            let mut want = bundle.tree.neighbor_ids(u);
            let mut got = table.clone();
            want.sort_unstable();
            got.sort_unstable();
            if got != want {
                self.violations.push(Violation::NeighborTableMismatch { node: u });
            }
        }
    }

    /// Byte conservation of a striped transfer: the per-lane plans must
    /// sum back to exactly one copy's wire bytes, each carrying the
    /// stripe segment count, and the full plan's segment bounds must
    /// tile a parameter vector without gap or loss.
    pub fn check_stripes(&mut self, plan: &TransferPlan, striped: &[TransferPlan]) {
        if !striped.is_empty() {
            let striped_mb: f64 = striped.iter().map(TransferPlan::wire_mb).sum();
            if (striped_mb - plan.wire_mb()).abs() > plan.wire_mb().abs() * 1e-9 + 1e-12 {
                self.violations.push(Violation::StripeByteLoss {
                    lanes: striped.len(),
                    striped_mb,
                    copy_mb: plan.wire_mb(),
                });
            }
            let want = plan.segments().div_ceil(striped.len()).max(1);
            for (lane, s) in striped.iter().enumerate() {
                if s.segments() != want {
                    self.violations.push(Violation::StripeSegmentMismatch {
                        lane,
                        got: s.segments(),
                        want,
                    });
                }
            }
        }
        // slicing coverage on a representative parameter vector (the
        // bounds are pure arithmetic, so one length exercises the tiling)
        let len = 64 * plan.segments() + 17;
        let mut expected_start = 0usize;
        for (segment, r) in plan.segment_bounds(len).into_iter().enumerate() {
            if r.start != expected_start {
                self.violations.push(Violation::SegmentBoundsGap {
                    segment,
                    start: r.start,
                    expected_start,
                });
            }
            expected_start = r.end;
        }
        if expected_start != len {
            self.violations.push(Violation::SegmentBoundsCoverage {
                covered: expected_start,
                len,
            });
        }
    }

    /// Participation-origination consistency over the first `rounds`
    /// rounds: every round has a non-empty in-range participant set, and
    /// the origination mask agrees with the listed set node for node.
    pub fn check_participation(&mut self, plan: &ParticipationPlan, nodes: usize, rounds: u64) {
        for round in 0..rounds {
            let Some(set) = plan.participants(round) else {
                self.violations.push(Violation::MissingParticipants { round });
                continue;
            };
            if set.is_empty() {
                self.violations.push(Violation::NoOriginators { round });
            }
            let mut listed = vec![false; nodes];
            for &u in set {
                if u >= nodes {
                    self.violations.push(Violation::ParticipantOutOfRange {
                        round,
                        node: u,
                        n: nodes,
                    });
                } else {
                    listed[u] = true;
                }
            }
            for (u, &l) in listed.iter().enumerate() {
                let o = plan.originates(round, u);
                if l != o {
                    self.violations.push(Violation::OriginationMismatch {
                        round,
                        node: u,
                        listed: l,
                        originates: o,
                    });
                }
            }
        }
    }

    /// Consume the linter, yielding the accumulated report.
    pub fn finish(self) -> LintReport {
        LintReport { violations: self.violations }
    }
}

/// Nodes a BFS from `start` never reaches (capped at eight for display).
fn unreachable_from(g: &Graph, start: NodeId) -> Vec<NodeId> {
    let n = g.node_count();
    if n == 0 || start >= n {
        return Vec::new();
    }
    let mut seen = vec![false; n];
    let mut stack = vec![start];
    seen[start] = true;
    while let Some(u) = stack.pop() {
        for &(v, _) in g.neighbors(u) {
            if !seen[v] {
                seen[v] = true;
                stack.push(v);
            }
        }
    }
    (0..n).filter(|&u| !seen[u]).take(8).collect()
}

/// One-shot lint of a [`PlanEpoch`] (all lanes + disjointness).
pub fn lint_epoch(epoch: &PlanEpoch, ctx: &LintContext<'_>) -> LintReport {
    let mut linter = PlanLinter::new(*ctx);
    linter.check_epoch(epoch);
    linter.finish()
}

/// One-shot lint of a published [`ScheduleBundle`] (all lanes +
/// disjointness + neighbor table).
pub fn lint_bundle(bundle: &ScheduleBundle, ctx: &LintContext<'_>) -> LintReport {
    let mut linter = PlanLinter::new(*ctx);
    linter.check_bundle(bundle);
    linter.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::ColoringAlgorithm;
    use crate::coordinator::schedule::build_schedule;
    use crate::mst::MstAlgorithm;

    fn dense_costs(n: usize) -> Graph {
        let mut g = Graph::new(n);
        for u in 0..n {
            for v in (u + 1)..n {
                g.add_edge(u, v, if v == u + 1 { 1.0 } else { 2.0 + (u * n + v) as f64 * 0.01 });
            }
        }
        g
    }

    fn plan(costs: &Graph) -> PlanEpoch {
        let tree = MstAlgorithm::Prim.run(costs).unwrap();
        let coloring = ColoringAlgorithm::Bfs.run(&tree);
        let schedule = build_schedule(costs, coloring, 11.6, 56, 1);
        PlanEpoch::single(tree, schedule)
    }

    #[test]
    fn clean_plan_lints_clean() {
        let costs = dense_costs(10);
        let epoch = plan(&costs);
        let ctx = LintContext { costs: &costs, unit_mb: 11.6, ping_size_bytes: 56 };
        let report = lint_epoch(&epoch, &ctx);
        assert!(report.is_clean(), "{report}");
        assert_eq!(format!("{report}"), "plan lint: clean");
    }

    #[test]
    fn dropped_edge_is_not_spanning() {
        let costs = dense_costs(8);
        let epoch = plan(&costs);
        let mut broken = Graph::new(8);
        for e in epoch.tree.edges().iter().skip(1) {
            broken.add_edge(e.u, e.v, e.weight);
        }
        let mutated = PlanEpoch::single(broken, epoch.schedule.clone());
        let ctx = LintContext { costs: &costs, unit_mb: 11.6, ping_size_bytes: 56 };
        let report = lint_epoch(&mutated, &ctx);
        assert!(report.has("not-spanning"), "{report}");
        assert!(report.has("disconnected"), "{report}");
    }

    #[test]
    fn merged_colors_fire_properness_and_slot_conflict() {
        let costs = dense_costs(8);
        let epoch = plan(&costs);
        let e = epoch.tree.edges()[0];
        let mut assignment = epoch.schedule.coloring.assignment().to_vec();
        assignment[e.v] = assignment[e.u];
        let schedule = Schedule {
            coloring: Coloring::new(assignment),
            slot_len_s: epoch.schedule.slot_len_s,
            first_color: epoch.schedule.first_color,
        };
        let mutated = PlanEpoch::single(epoch.tree.clone(), schedule);
        let ctx = LintContext { costs: &costs, unit_mb: 11.6, ping_size_bytes: 56 };
        let report = lint_epoch(&mutated, &ctx);
        assert!(report.has("improper-edge"), "{report}");
        assert!(report.has("slot-conflict"), "{report}");
    }

    #[test]
    fn shrunk_slot_budget_is_flagged_with_the_formula_value() {
        let costs = dense_costs(8);
        let epoch = plan(&costs);
        let want = epoch.schedule.slot_len_s;
        let schedule = Schedule { slot_len_s: want * 0.5, ..epoch.schedule.clone() };
        let mutated = PlanEpoch::single(epoch.tree.clone(), schedule);
        let ctx = LintContext { costs: &costs, unit_mb: 11.6, ping_size_bytes: 56 };
        let report = lint_epoch(&mutated, &ctx);
        assert!(report.has("slot-budget-mismatch"), "{report}");
        let Violation::SlotBudgetMismatch { want_s, .. } = report.violations()[0] else {
            panic!("unexpected violation order: {report}");
        };
        assert!((want_s - want).abs() < 1e-12);
    }

    #[test]
    fn overlapping_lanes_share_edges() {
        let costs = dense_costs(8);
        let epoch = plan(&costs);
        let mutated = PlanEpoch {
            tree: epoch.tree.clone(),
            schedule: epoch.schedule.clone(),
            extra: vec![crate::coordinator::engine::TreeLane {
                tree: epoch.tree.clone(),
                schedule: epoch.schedule.clone(),
            }],
        };
        let ctx = LintContext { costs: &costs, unit_mb: 11.6, ping_size_bytes: 56 };
        let report = lint_epoch(&mutated, &ctx);
        assert!(report.has("shared-edge"), "{report}");
        // every shared edge names both lanes
        for v in report.violations() {
            if let Violation::SharedEdge { lane_a, lane_b, .. } = v {
                assert_eq!((*lane_a, *lane_b), (0, 1));
            }
        }
    }

    #[test]
    fn stripe_corruption_loses_bytes() {
        let plan = TransferPlan::segmented(48.0, 6);
        let good = [plan.stripe(2), plan.stripe(2)];
        let mut linter = PlanLinter::new(LintContext {
            costs: &dense_costs(4),
            unit_mb: 1.0,
            ping_size_bytes: 56,
        });
        linter.check_stripes(&plan, &good);
        assert!(linter.finish().is_clean());

        let bad = [plan.stripe(2), plan.stripe(3)];
        let mut linter = PlanLinter::new(LintContext {
            costs: &dense_costs(4),
            unit_mb: 1.0,
            ping_size_bytes: 56,
        });
        linter.check_stripes(&plan, &bad);
        let report = linter.finish();
        assert!(report.has("stripe-byte-loss"), "{report}");
        assert!(report.has("stripe-segment-mismatch"), "{report}");
    }

    #[test]
    fn participation_horizon_overrun_is_flagged() {
        let costs = dense_costs(6);
        let plan = ParticipationPlan::sample(0.5, 6, 3, 7);
        let ctx = LintContext { costs: &costs, unit_mb: 1.0, ping_size_bytes: 56 };
        let mut linter = PlanLinter::new(ctx);
        linter.check_participation(&plan, 6, 3);
        assert!(linter.finish().is_clean());
        let mut linter = PlanLinter::new(ctx);
        linter.check_participation(&plan, 6, 4);
        let report = linter.finish();
        assert!(report.has("missing-participants"), "{report}");
    }

    #[test]
    fn report_renders_kinds_and_messages() {
        let costs = dense_costs(6);
        let epoch = plan(&costs);
        let schedule = Schedule { first_color: 9, ..epoch.schedule.clone() };
        let mutated = PlanEpoch::single(epoch.tree.clone(), schedule);
        let ctx = LintContext { costs: &costs, unit_mb: 11.6, ping_size_bytes: 56 };
        let report = lint_epoch(&mutated, &ctx);
        assert!(report.has("first-color-out-of-range"));
        assert_eq!(report.kinds(), vec!["first-color-out-of-range"]);
        let rendered = format!("{report}");
        assert!(rendered.contains("first_color 9"), "{rendered}");
    }
}
