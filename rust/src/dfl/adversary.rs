//! Byzantine node behaviors — the attack half of the adversarial
//! robustness plane (`dfl::robust` is the defense half).
//!
//! A fraction of nodes is seeded-randomly marked Byzantine and assigned a
//! [`NodeBehavior`]:
//!
//! - [`NodeBehavior::ScaledPoison`] — the node ships its honest payload
//!   multiplied by `factor` (the classic sign-flip / scaling attack);
//! - [`NodeBehavior::RandomPoison`] — the node ships seeded uniform noise;
//! - [`NodeBehavior::SybilClique`] — every clique member ships the *same*
//!   poisoned payload (the clique leader's, scaled), so naive means see it
//!   with `|members|`-fold weight;
//! - [`NodeBehavior::DroppingRelay`] — a *routing* attack: the node
//!   forwards garbage on a fraction of its tree edges. On an MST this is
//!   lethal without a defense — a single inner relay starves whole
//!   subtrees — which is exactly why it is exercised on the gossip trees
//!   (see `coordinator::gossip`'s junk tracking).
//!
//! Payload attacks act on the model snapshot each round
//! ([`AdversaryScenario::corrupt_snapshot`]); the dropping relay instead
//! compiles to a [`DropPlan`] of directed tree edges that the round engine
//! consults when a relay *forwards* another node's model. Dropped
//! forwards still ship bytes of the right size (a stealthy attacker does
//! not reveal itself in the timing channel), so slot timings, transfer
//! counts and completion invariants are untouched — only the *content*
//! is junk, and junked copies are excluded from the fold inputs.

use crate::graph::{Graph, NodeId};
use crate::util::rng::Pcg64;
use std::collections::HashSet;
use std::rc::Rc;

/// Which attack the Byzantine nodes mount.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdversaryKind {
    None,
    ScaledPoison,
    RandomPoison,
    SybilClique,
    DroppingRelay,
}

impl AdversaryKind {
    /// Parse a CLI/TOML spelling (`none`, `scaled-poison`, `random-poison`,
    /// `sybil`, `dropping-relay`).
    pub fn parse(s: &str) -> Option<AdversaryKind> {
        match s {
            "none" => Some(AdversaryKind::None),
            "scaled-poison" | "scaled" => Some(AdversaryKind::ScaledPoison),
            "random-poison" | "random" => Some(AdversaryKind::RandomPoison),
            "sybil" | "sybil-clique" => Some(AdversaryKind::SybilClique),
            "dropping-relay" | "drop" => Some(AdversaryKind::DroppingRelay),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdversaryKind::None => "none",
            AdversaryKind::ScaledPoison => "scaled-poison",
            AdversaryKind::RandomPoison => "random-poison",
            AdversaryKind::SybilClique => "sybil",
            AdversaryKind::DroppingRelay => "dropping-relay",
        }
    }

    pub fn is_none(&self) -> bool {
        *self == AdversaryKind::None
    }
}

/// Attack configuration as carried by the config/CLI layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdversaryConfig {
    pub kind: AdversaryKind,
    /// Fraction of nodes marked Byzantine (at least one when active).
    pub frac: f64,
    /// Multiplier for scaled-poison / sybil payloads; its magnitude is the
    /// noise amplitude for random-poison.
    pub poison_scale: f32,
    /// Fraction of a dropping relay's tree edges it junks.
    pub drop_edge_frac: f64,
}

impl AdversaryConfig {
    pub fn none() -> Self {
        AdversaryConfig {
            kind: AdversaryKind::None,
            frac: 0.2,
            poison_scale: -10.0,
            drop_edge_frac: 1.0,
        }
    }

    pub fn is_none(&self) -> bool {
        self.kind.is_none()
    }

    /// Range-check the knobs (dormant knobs are validated too, mirroring
    /// the compression plane's contract).
    pub fn validate(&self) -> Result<(), String> {
        if !(self.frac > 0.0 && self.frac < 1.0) {
            return Err(format!("adversary_frac {} must be in (0, 1)", self.frac));
        }
        if !self.poison_scale.is_finite() {
            return Err(format!("poison_scale {} must be finite", self.poison_scale));
        }
        if !(self.drop_edge_frac > 0.0 && self.drop_edge_frac <= 1.0) {
            return Err(format!("drop_edge_frac {} must be in (0, 1]", self.drop_edge_frac));
        }
        Ok(())
    }

    /// Compact label for bench tables (`none`, `scaled-poison@0.2`, ...).
    pub fn label(&self) -> String {
        if self.is_none() {
            "none".into()
        } else {
            format!("{}@{}", self.kind.name(), self.frac)
        }
    }
}

/// Per-node behavior assignment.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeBehavior {
    Honest,
    ScaledPoison { factor: f32 },
    RandomPoison,
    SybilClique { members: Vec<NodeId> },
    DroppingRelay { edge_frac: f64 },
}

impl NodeBehavior {
    pub fn is_honest(&self) -> bool {
        *self == NodeBehavior::Honest
    }
}

/// The directed tree edges on which a Byzantine relay junks forwarded
/// models. Consulted by the round engine on every fresh delivery.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DropPlan {
    dropped: HashSet<(NodeId, NodeId)>,
}

impl DropPlan {
    /// Build a plan from explicit directed `(relay, recipient)` edges
    /// (scenario planning uses [`AdversaryScenario::plan`]; this is for
    /// tests and benches that pin specific edges).
    pub fn from_edges(edges: impl IntoIterator<Item = (NodeId, NodeId)>) -> Self {
        DropPlan { dropped: edges.into_iter().collect() }
    }

    /// Whether the relay at `from` junks models it forwards to `to`.
    pub fn drops(&self, from: NodeId, to: NodeId) -> bool {
        self.dropped.contains(&(from, to))
    }

    pub fn is_empty(&self) -> bool {
        self.dropped.is_empty()
    }

    pub fn len(&self) -> usize {
        self.dropped.len()
    }
}

/// One concrete, seeded instantiation of an attack on a gossip tree.
#[derive(Debug, Clone)]
pub struct AdversaryScenario {
    /// Behavior per node id (`0..n`).
    pub behaviors: Vec<NodeBehavior>,
    /// Byzantine node ids, ascending.
    byzantine: Vec<NodeId>,
    /// Directed junked forward edges (empty unless `DroppingRelay`).
    drops: Rc<DropPlan>,
    poison_scale: f32,
}

impl AdversaryScenario {
    /// Instantiate `cfg` on `tree`: pick `max(1, floor(frac · n))`
    /// Byzantine nodes (never all of them) and, for dropping relays,
    /// `ceil(edge_frac · degree)` junked tree edges per relay. Fully
    /// deterministic in `seed`. Returns `None` when the attack is off.
    pub fn plan(cfg: &AdversaryConfig, tree: &Graph, seed: u64) -> Option<AdversaryScenario> {
        if cfg.is_none() {
            return None;
        }
        let n = tree.node_count();
        let count = ((cfg.frac * n as f64).floor() as usize).max(1).min(n.saturating_sub(1));
        if count == 0 {
            return None;
        }
        let mut rng = Pcg64::new(seed ^ 0x0bad_5eed);
        let mut byzantine = rng.sample_indices(n, count);
        byzantine.sort_unstable();
        let mut behaviors = vec![NodeBehavior::Honest; n];
        let mut dropped = HashSet::new();
        for &u in &byzantine {
            behaviors[u] = match cfg.kind {
                AdversaryKind::None => unreachable!("handled above"),
                AdversaryKind::ScaledPoison => {
                    NodeBehavior::ScaledPoison { factor: cfg.poison_scale }
                }
                AdversaryKind::RandomPoison => NodeBehavior::RandomPoison,
                AdversaryKind::SybilClique => {
                    NodeBehavior::SybilClique { members: byzantine.clone() }
                }
                AdversaryKind::DroppingRelay => {
                    let deg = tree.degree(u);
                    if deg > 0 {
                        let k = ((cfg.drop_edge_frac * deg as f64).ceil() as usize).clamp(1, deg);
                        for i in rng.sample_indices(deg, k) {
                            dropped.insert((u, tree.neighbors(u)[i].0));
                        }
                    }
                    NodeBehavior::DroppingRelay { edge_frac: cfg.drop_edge_frac }
                }
            };
        }
        Some(AdversaryScenario {
            behaviors,
            byzantine,
            drops: Rc::new(DropPlan { dropped }),
            poison_scale: cfg.poison_scale,
        })
    }

    /// Byzantine node ids, ascending.
    pub fn byzantine(&self) -> &[NodeId] {
        &self.byzantine
    }

    pub fn byzantine_count(&self) -> usize {
        self.byzantine.len()
    }

    pub fn is_byzantine(&self, u: NodeId) -> bool {
        self.byzantine.binary_search(&u).is_ok()
    }

    /// Honest node ids, ascending.
    pub fn honest(&self) -> Vec<NodeId> {
        (0..self.behaviors.len()).filter(|&u| !self.is_byzantine(u)).collect()
    }

    /// Whether the scenario corrupts payload *content* (poison / sybil).
    /// A dropping relay ships authentic content and attacks only the
    /// forwarding plane, so its envelope of trustworthy inputs is every
    /// node's snapshot, not just the honest subset.
    pub fn corrupts_content(&self) -> bool {
        self.behaviors.iter().any(|b| {
            matches!(
                b,
                NodeBehavior::ScaledPoison { .. }
                    | NodeBehavior::RandomPoison
                    | NodeBehavior::SybilClique { .. }
            )
        })
    }

    /// The drop plan for the round engine (`None` when no edges are junked,
    /// so payload-only attacks keep the engine on its zero-overhead path).
    pub fn drop_plan(&self) -> Option<Rc<DropPlan>> {
        if self.drops.is_empty() {
            None
        } else {
            Some(Rc::clone(&self.drops))
        }
    }

    /// Apply the payload attacks to one round's model snapshot (indexed by
    /// node id). Dropping relays leave payloads alone — their attack lives
    /// in the routing plane. Deterministic in `(seed, round)`.
    pub fn corrupt_snapshot(&self, snapshot: &mut [Vec<f32>], round: u64, seed: u64) {
        // capture the sybil leader's honest payload before any overwrite
        let sybil_src: Option<Vec<f32>> = self.behaviors.iter().find_map(|b| match b {
            NodeBehavior::SybilClique { members } => {
                members.first().and_then(|&l| snapshot.get(l).cloned())
            }
            _ => None,
        });
        for (u, behavior) in self.behaviors.iter().enumerate() {
            if u >= snapshot.len() {
                break;
            }
            match behavior {
                NodeBehavior::Honest | NodeBehavior::DroppingRelay { .. } => {}
                NodeBehavior::ScaledPoison { factor } => {
                    for x in &mut snapshot[u] {
                        *x *= factor;
                    }
                }
                NodeBehavior::RandomPoison => {
                    let mut rng = Pcg64::new(
                        seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                            ^ (u as u64).wrapping_mul(0xA24B_AED4_963E_E407),
                    );
                    let amp = (self.poison_scale.abs() as f64).max(1.0);
                    for x in &mut snapshot[u] {
                        *x = rng.gen_f64_range(-amp, amp) as f32;
                    }
                }
                NodeBehavior::SybilClique { .. } => {
                    if let Some(src) = &sybil_src {
                        snapshot[u] = src.iter().map(|&x| x * self.poison_scale).collect();
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn chain(n: usize) -> Graph {
        let mut g = Graph::new(n);
        for u in 0..n - 1 {
            g.add_edge(u, u + 1, 1.0);
        }
        g
    }

    fn cfg(kind: AdversaryKind) -> AdversaryConfig {
        AdversaryConfig { kind, ..AdversaryConfig::none() }
    }

    #[test]
    fn parse_round_trips_and_rejects_junk() {
        for kind in [
            AdversaryKind::None,
            AdversaryKind::ScaledPoison,
            AdversaryKind::RandomPoison,
            AdversaryKind::SybilClique,
            AdversaryKind::DroppingRelay,
        ] {
            assert_eq!(AdversaryKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(AdversaryKind::parse("drop"), Some(AdversaryKind::DroppingRelay));
        assert_eq!(AdversaryKind::parse("evil"), None);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(AdversaryConfig::none().validate().is_ok());
        assert!(AdversaryConfig { frac: 0.0, ..AdversaryConfig::none() }.validate().is_err());
        assert!(AdversaryConfig { frac: 1.0, ..AdversaryConfig::none() }.validate().is_err());
        assert!(AdversaryConfig { poison_scale: f32::NAN, ..AdversaryConfig::none() }
            .validate()
            .is_err());
        assert!(AdversaryConfig { drop_edge_frac: 0.0, ..AdversaryConfig::none() }
            .validate()
            .is_err());
        assert!(AdversaryConfig { drop_edge_frac: 1.1, ..AdversaryConfig::none() }
            .validate()
            .is_err());
    }

    #[test]
    fn none_plans_to_none() {
        assert!(AdversaryScenario::plan(&AdversaryConfig::none(), &chain(10), 7).is_none());
    }

    #[test]
    fn plan_is_deterministic_and_sized() {
        let tree = chain(10);
        let a = AdversaryScenario::plan(&cfg(AdversaryKind::ScaledPoison), &tree, 42).unwrap();
        let b = AdversaryScenario::plan(&cfg(AdversaryKind::ScaledPoison), &tree, 42).unwrap();
        assert_eq!(a.byzantine(), b.byzantine());
        assert_eq!(a.byzantine_count(), 2, "frac 0.2 of 10 nodes");
        assert_eq!(a.honest().len(), 8);
        for &u in a.byzantine() {
            assert!(a.is_byzantine(u));
            assert!(!a.behaviors[u].is_honest());
        }
        assert!(a.drop_plan().is_none(), "payload attack junks no edges");
    }

    #[test]
    fn at_least_one_but_never_all_byzantine() {
        let tree = chain(3);
        let low = AdversaryConfig { frac: 0.01, ..cfg(AdversaryKind::RandomPoison) };
        assert_eq!(AdversaryScenario::plan(&low, &tree, 1).unwrap().byzantine_count(), 1);
        let high = AdversaryConfig { frac: 0.99, ..cfg(AdversaryKind::RandomPoison) };
        assert_eq!(AdversaryScenario::plan(&high, &tree, 1).unwrap().byzantine_count(), 2);
    }

    #[test]
    fn dropping_relay_junks_its_own_tree_edges() {
        let tree = chain(10);
        let s = AdversaryScenario::plan(&cfg(AdversaryKind::DroppingRelay), &tree, 9).unwrap();
        let plan = s.drop_plan().expect("dropping relay must junk edges");
        assert!(!plan.is_empty());
        for &u in s.byzantine() {
            // edge_frac = 1.0: every tree edge out of u is junked
            for &(v, _) in tree.neighbors(u) {
                assert!(plan.drops(u, v), "missing drop {u} -> {v}");
                assert!(!plan.drops(v, u), "honest direction must not drop");
            }
        }
        assert_eq!(plan.len(), s.byzantine().iter().map(|&u| tree.degree(u)).sum::<usize>());
    }

    #[test]
    fn corrupt_snapshot_scales_poisoners_only() {
        let tree = chain(10);
        let s = AdversaryScenario::plan(&cfg(AdversaryKind::ScaledPoison), &tree, 42).unwrap();
        let mut snap: Vec<Vec<f32>> = (0..10).map(|u| vec![u as f32 + 1.0; 3]).collect();
        let orig = snap.clone();
        s.corrupt_snapshot(&mut snap, 0, 42);
        for u in 0..10 {
            if s.is_byzantine(u) {
                assert_eq!(snap[u][0], orig[u][0] * -10.0);
            } else {
                assert_eq!(snap[u], orig[u]);
            }
        }
    }

    #[test]
    fn sybil_clique_ships_one_identical_poisoned_payload() {
        let tree = chain(10);
        let s = AdversaryScenario::plan(&cfg(AdversaryKind::SybilClique), &tree, 42).unwrap();
        let mut snap: Vec<Vec<f32>> = (0..10).map(|u| vec![u as f32 + 1.0; 3]).collect();
        let orig = snap.clone();
        s.corrupt_snapshot(&mut snap, 0, 42);
        let leader = s.byzantine()[0];
        let want: Vec<f32> = orig[leader].iter().map(|&x| x * -10.0).collect();
        for &u in s.byzantine() {
            assert_eq!(snap[u], want, "clique member {u} diverged from the leader payload");
        }
    }

    #[test]
    fn random_poison_is_bounded_and_round_varying() {
        let tree = chain(10);
        let s = AdversaryScenario::plan(&cfg(AdversaryKind::RandomPoison), &tree, 42).unwrap();
        let byz = s.byzantine()[0];
        let mut r0: Vec<Vec<f32>> = vec![vec![0.0; 64]; 10];
        let mut r1 = r0.clone();
        s.corrupt_snapshot(&mut r0, 0, 42);
        s.corrupt_snapshot(&mut r1, 1, 42);
        assert_ne!(r0[byz], r1[byz], "noise must vary per round");
        assert!(r0[byz].iter().all(|x| x.abs() <= 10.0), "amplitude is |poison_scale|");
        let mut again: Vec<Vec<f32>> = vec![vec![0.0; 64]; 10];
        s.corrupt_snapshot(&mut again, 0, 42);
        assert_eq!(r0[byz], again[byz], "noise must be deterministic in (seed, round)");
    }

    #[test]
    fn dropping_relay_leaves_payloads_alone() {
        let tree = chain(10);
        let s = AdversaryScenario::plan(&cfg(AdversaryKind::DroppingRelay), &tree, 9).unwrap();
        let mut snap: Vec<Vec<f32>> = (0..10).map(|u| vec![u as f32; 2]).collect();
        let orig = snap.clone();
        s.corrupt_snapshot(&mut snap, 0, 9);
        assert_eq!(snap, orig);
    }
}
