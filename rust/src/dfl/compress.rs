//! Payload compression codecs for the gossip plane (the communication-
//! compression lever the paper's model-size ↔ latency correlation begs
//! for).
//!
//! The paper ships every checkpoint at full fp32 width; compressed
//! decentralized gossip is standard practice in the related literature
//! (communication-compressed decentralized FL per arXiv:2306.02570,
//! sparse segment exchange per arXiv:1908.07782). This module provides
//! the two workhorse codecs plus the error-feedback memory that keeps
//! compressed FedAvg converging:
//!
//! * **Uniform k-bit quantization** ([`quant_encode`] / [`quant_decode`]):
//!   each [`QUANT_CHUNK`]-element chunk is mapped to `2^bits` levels
//!   between its min and max (per-chunk `(min, step)` header). Wire cost
//!   ≈ `bits/32` of fp32, so `--quant-bits 8` is a ~4× reduction.
//! * **Top-k sparsification** ([`topk_encode`] / [`topk_decode`]): keep
//!   the `ceil(frac · n)` largest-magnitude entries as (index, value)
//!   pairs, zeros elsewhere. Wire cost ≈ `2 · frac` of fp32.
//! * **Error feedback** ([`ErrorFeedback`]): each node compresses
//!   `params + residual` and carries `residual = target − decoded` into
//!   the next round, so quantization/sparsification error accumulates
//!   nowhere (EF-SGD style memory).
//!
//! The decoders treat their input as **hostile**: a received encoding is
//! validated up front (header ranges, chunk/word counts, index bounds and
//! ordering) and rejected with a [`DecodeError`] instead of panicking or
//! writing out of bounds — one malformed payload must never take down the
//! fold (see `tests/robustness_plane.rs` for the fuzzing).
//!
//! The codecs operate on real parameter vectors (the DFL loop in
//! [`crate::dfl::round`] encodes at snapshot time and folds decoded
//! payloads); the *wire size* they imply is threaded through
//! [`TransferPlan`](crate::dfl::transfer::TransferPlan) →
//! [`Driver`](crate::coordinator::engine::driver::Driver) flow launches →
//! `netsim` payloads, so plans, slot budgets, and the Table III/IV
//! metrics all react to the smaller payloads. `compress = none` is the
//! compatibility anchor: the wire size is the logical size, bit for bit
//! (pinned in `tests/engine_equivalence.rs`).

/// Elements per quantization chunk (one `(min, step)` f32 pair of header
/// per chunk on the wire).
pub const QUANT_CHUNK: usize = 1024;

/// Why a received encoding was rejected. Decoders validate before they
/// allocate or index — a hostile payload fails the decode, it does not
/// panic the cluster.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum DecodeError {
    #[error("quant bits {0} out of range 1..=32")]
    BadBits(u32),
    #[error("chunk header count {got} != expected {want}")]
    ChunkCountMismatch { got: usize, want: usize },
    #[error("packed word count {got} != expected {want}")]
    WordCountMismatch { got: usize, want: usize },
    #[error("non-finite chunk header (min {min}, step {step})")]
    NonFiniteHeader { min: f32, step: f32 },
    #[error("{indices} indices vs {values} values")]
    ArityMismatch { indices: usize, values: usize },
    #[error("index {index} out of bounds for length {len}")]
    IndexOutOfBounds { index: u32, len: usize },
    #[error("indices not strictly ascending at position {at}")]
    IndicesNotAscending { at: usize },
}

/// Bytes per megabyte (the wire-size arithmetic's single constant).
const MB: f64 = 1024.0 * 1024.0;

/// Which codec compresses gossip payloads. CLI: `--compress`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionKind {
    /// Full-width fp32 checkpoints — the legacy wire format.
    None,
    /// Uniform k-bit quantization (`--quant-bits`).
    Quant,
    /// Top-k magnitude sparsification (`--topk-frac`).
    TopK,
}

impl CompressionKind {
    pub fn name(&self) -> &'static str {
        match self {
            CompressionKind::None => "none",
            CompressionKind::Quant => "quant",
            CompressionKind::TopK => "topk",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(CompressionKind::None),
            "quant" => Some(CompressionKind::Quant),
            "topk" | "top-k" => Some(CompressionKind::TopK),
            _ => None,
        }
    }
}

/// Full codec selection: kind plus its knobs. Both knobs always carry
/// values (paper-sensible defaults); only the active kind's knob matters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressionConfig {
    pub kind: CompressionKind,
    /// Quantization width in bits, `1..=16`. CLI: `--quant-bits`.
    pub quant_bits: u32,
    /// Fraction of entries top-k keeps, in `(0, 1]`. CLI: `--topk-frac`.
    pub topk_frac: f64,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        CompressionConfig { kind: CompressionKind::None, quant_bits: 8, topk_frac: 0.1 }
    }
}

impl CompressionConfig {
    /// The legacy full-width wire format.
    pub fn none() -> Self {
        CompressionConfig::default()
    }

    /// Uniform `bits`-bit quantization.
    pub fn quant(bits: u32) -> Self {
        CompressionConfig { kind: CompressionKind::Quant, quant_bits: bits, ..Self::default() }
    }

    /// Top-k sparsification keeping a `frac` fraction of entries.
    pub fn topk(frac: f64) -> Self {
        CompressionConfig { kind: CompressionKind::TopK, topk_frac: frac, ..Self::default() }
    }

    pub fn is_none(&self) -> bool {
        self.kind == CompressionKind::None
    }

    /// Human-readable codec label for logs/benches (`none`, `quant8`,
    /// `topk0.10`).
    pub fn label(&self) -> String {
        match self.kind {
            CompressionKind::None => "none".to_string(),
            CompressionKind::Quant => format!("quant{}", self.quant_bits),
            CompressionKind::TopK => format!("topk{:.2}", self.topk_frac),
        }
    }

    /// Knob sanity — the single source of truth for the codec ranges
    /// (`ExperimentConfig::validate` delegates here).
    pub fn validate(&self) -> Result<(), String> {
        if !(1..=16).contains(&self.quant_bits) {
            return Err(format!("quant_bits must be in 1..=16, got {}", self.quant_bits));
        }
        if !(self.topk_frac.is_finite() && self.topk_frac > 0.0 && self.topk_frac <= 1.0) {
            return Err(format!("topk_frac must be in (0, 1], got {}", self.topk_frac));
        }
        Ok(())
    }

    /// Wire MB a logically `model_mb`-sized fp32 checkpoint occupies
    /// under this codec. `None` returns `model_mb` **verbatim** (same
    /// float bits — the `compress = none` bit-identity anchor). The
    /// arithmetic mirrors the codecs' actual wire layout: packed codes +
    /// per-chunk `(min, step)` headers for quantization, 4-byte index +
    /// 4-byte value per kept entry for top-k.
    pub fn wire_mb(&self, model_mb: f64) -> f64 {
        match self.kind {
            CompressionKind::None => model_mb,
            CompressionKind::Quant => {
                let params = (model_mb * MB / 4.0).ceil();
                let chunks = (params / QUANT_CHUNK as f64).ceil();
                (params * self.quant_bits as f64 / 8.0 + chunks * 8.0) / MB
            }
            CompressionKind::TopK => {
                let params = (model_mb * MB / 4.0).ceil();
                let kept = (params * self.topk_frac).ceil().max(1.0);
                kept * 8.0 / MB
            }
        }
    }

    /// Nominal compression ratio (logical / wire) for a `model_mb`-sized
    /// checkpoint.
    pub fn ratio(&self, model_mb: f64) -> f64 {
        model_mb / self.wire_mb(model_mb)
    }

    /// One wire round-trip: what the receivers of a `params` snapshot
    /// actually see under this codec (identity for `None`).
    pub fn encode_decode(&self, params: &[f32]) -> Vec<f32> {
        match self.kind {
            CompressionKind::None => params.to_vec(),
            CompressionKind::Quant => quant_decode(&quant_encode(params, self.quant_bits))
                .expect("self-encoded quant payload is valid"),
            CompressionKind::TopK => topk_decode(&topk_encode(params, self.topk_frac))
                .expect("self-encoded top-k payload is valid"),
        }
    }
}

/// A k-bit-quantized parameter vector: per-chunk `(min, step)` headers
/// plus densely packed codes.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantEncoded {
    pub bits: u32,
    pub len: usize,
    /// `(min, step)` per [`QUANT_CHUNK`]-element chunk.
    pub chunks: Vec<(f32, f32)>,
    /// Codes packed little-endian-first into 64-bit words.
    pub words: Vec<u64>,
}

impl QuantEncoded {
    /// Exact wire size in bytes (packed codes + chunk headers).
    pub fn wire_bytes(&self) -> usize {
        (self.len * self.bits as usize).div_ceil(8) + self.chunks.len() * 8
    }
}

/// Uniformly quantize `params` to `bits` bits per element, chunk by
/// chunk. Non-finite inputs in a chunk collapse that chunk's range to a
/// zero step (decoded as the chunk min) rather than poisoning the codes.
pub fn quant_encode(params: &[f32], bits: u32) -> QuantEncoded {
    assert!((1..=16).contains(&bits), "quant bits must be in 1..=16, got {bits}");
    let levels = (1u64 << bits) - 1;
    let mut chunks = Vec::with_capacity(params.len().div_ceil(QUANT_CHUNK).max(1));
    let mut words = vec![0u64; (params.len() * bits as usize).div_ceil(64)];
    let mut bitpos = 0usize;
    for chunk in params.chunks(QUANT_CHUNK) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in chunk {
            if x.is_finite() {
                lo = lo.min(x);
                hi = hi.max(x);
            }
        }
        if !(lo.is_finite() && hi.is_finite()) {
            lo = 0.0;
            hi = 0.0;
        }
        let step = if hi > lo { (hi - lo) / levels as f32 } else { 0.0 };
        chunks.push((lo, step));
        for &x in chunk {
            let q: u64 = if step > 0.0 && x.is_finite() {
                (((x - lo) / step).round() as i64).clamp(0, levels as i64) as u64
            } else {
                0
            };
            let wi = bitpos / 64;
            let off = bitpos % 64;
            words[wi] |= q << off;
            if off + bits as usize > 64 {
                words[wi + 1] |= q >> (64 - off);
            }
            bitpos += bits as usize;
        }
    }
    QuantEncoded { bits, len: params.len(), chunks, words }
}

/// Decode a quantized vector back to f32 (`min + code · step` per
/// element), validating the encoding first: `bits` must be in `1..=32`
/// (checked **before** the `1 << bits` mask — a hostile `bits = 0` or
/// `bits > 32` header must not overflow the shift; the local encoder only
/// emits `1..=16` but the decoder cannot assume a friendly peer), the
/// chunk-header and packed-word counts must match `len`, and headers must
/// be finite (a NaN `(min, step)` would poison every decoded element).
pub fn quant_decode(enc: &QuantEncoded) -> Result<Vec<f32>, DecodeError> {
    if !(1..=32).contains(&enc.bits) {
        return Err(DecodeError::BadBits(enc.bits));
    }
    let bits = enc.bits as usize;
    let want = enc.len.div_ceil(QUANT_CHUNK);
    if enc.chunks.len() != want {
        return Err(DecodeError::ChunkCountMismatch { got: enc.chunks.len(), want });
    }
    let want = (enc.len * bits).div_ceil(64);
    if enc.words.len() != want {
        return Err(DecodeError::WordCountMismatch { got: enc.words.len(), want });
    }
    for &(min, step) in &enc.chunks {
        if !(min.is_finite() && step.is_finite()) {
            return Err(DecodeError::NonFiniteHeader { min, step });
        }
    }
    let mask = (1u64 << bits) - 1;
    let mut out = Vec::with_capacity(enc.len);
    let mut bitpos = 0usize;
    for i in 0..enc.len {
        let (lo, step) = enc.chunks[i / QUANT_CHUNK];
        let wi = bitpos / 64;
        let off = bitpos % 64;
        let mut q = enc.words[wi] >> off;
        if off + bits > 64 {
            q |= enc.words[wi + 1] << (64 - off);
        }
        q &= mask;
        out.push(lo + q as f32 * step);
        bitpos += bits;
    }
    Ok(out)
}

/// A top-k-sparsified parameter vector: the kept entries as parallel
/// (ascending index, value) arrays.
#[derive(Debug, Clone, PartialEq)]
pub struct TopKEncoded {
    pub len: usize,
    /// Kept positions, strictly ascending.
    pub indices: Vec<u32>,
    /// Values at those positions.
    pub values: Vec<f32>,
}

impl TopKEncoded {
    /// Exact wire size in bytes (4-byte index + 4-byte value per entry).
    pub fn wire_bytes(&self) -> usize {
        self.indices.len() * 8
    }
}

/// Keep the `ceil(frac · len)` largest-magnitude entries (deterministic
/// tie-break: lower index wins). Non-finite entries rank as **zero**
/// magnitude and, if still selected, are transmitted as 0.0 — a NaN/∞
/// parameter (or residual) must never ride the wire and poison every
/// receiver's FedAvg fold (quantization sanitizes the same way).
///
/// Selection is O(n + k log k): partition the top `k` out with
/// `select_nth_unstable_by`, then sort only the kept indices — a full
/// O(n log n) sort of a multi-million-parameter checkpoint per node per
/// round would dominate the DFL hot loop.
pub fn topk_encode(params: &[f32], frac: f64) -> TopKEncoded {
    assert!(
        frac.is_finite() && frac > 0.0 && frac <= 1.0,
        "topk fraction must be in (0, 1], got {frac}"
    );
    assert!(params.len() <= u32::MAX as usize, "top-k index field is 32-bit");
    if params.is_empty() {
        return TopKEncoded { len: 0, indices: Vec::new(), values: Vec::new() };
    }
    let mag = |x: f32| if x.is_finite() { x.abs() } else { 0.0 };
    let k = ((params.len() as f64 * frac).ceil() as usize).clamp(1, params.len());
    let mut keep: Vec<usize> = (0..params.len()).collect();
    if k < keep.len() {
        // strict total order (descending magnitude, then index), so the
        // selected set is deterministic
        keep.select_nth_unstable_by(k - 1, |&a, &b| {
            mag(params[b]).total_cmp(&mag(params[a])).then_with(|| a.cmp(&b))
        });
        keep.truncate(k);
    }
    keep.sort_unstable();
    TopKEncoded {
        len: params.len(),
        indices: keep.iter().map(|&i| i as u32).collect(),
        values: keep
            .iter()
            .map(|&i| if params[i].is_finite() { params[i] } else { 0.0 })
            .collect(),
    }
}

/// Densify a top-k vector (zeros at dropped positions), validating the
/// encoding first: the index and value arrays must have equal length and
/// the indices must be strictly ascending and `< len` — the unchecked
/// `out[i] = v` write this replaces let any corrupted index panic (or,
/// with a resized `len` header, scribble) the receiving fold.
pub fn topk_decode(enc: &TopKEncoded) -> Result<Vec<f32>, DecodeError> {
    if enc.indices.len() != enc.values.len() {
        return Err(DecodeError::ArityMismatch {
            indices: enc.indices.len(),
            values: enc.values.len(),
        });
    }
    for (j, &i) in enc.indices.iter().enumerate() {
        if i as usize >= enc.len {
            return Err(DecodeError::IndexOutOfBounds { index: i, len: enc.len });
        }
        // strict ascent also rejects duplicate indices
        if j > 0 && enc.indices[j - 1] >= i {
            return Err(DecodeError::IndicesNotAscending { at: j });
        }
    }
    let mut out = vec![0.0f32; enc.len];
    for (&i, &v) in enc.indices.iter().zip(&enc.values) {
        out[i as usize] = v;
    }
    Ok(out)
}

/// Per-node error-feedback memory: the residual the last compression
/// round failed to transmit, folded into the next round's payload so the
/// codec error telescopes instead of accumulating.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(len: usize) -> Self {
        ErrorFeedback { residual: vec![0.0; len] }
    }

    /// The currently carried residual.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Compress `params` with memory: encodes `params + residual`,
    /// updates the residual to `target − decoded`, and returns the
    /// decoded wire-visible payload (what every receiver — and, for
    /// exact consensus, the sender itself — folds). For `compress =
    /// none` this is a plain copy and the residual stays zero.
    ///
    /// A non-finite residual entry (a NaN parameter makes
    /// `target − sent` NaN) is reset to 0.0 instead of being carried —
    /// otherwise one bad training step would poison that coordinate's
    /// feedback forever.
    pub fn compress(&mut self, params: &[f32], cfg: &CompressionConfig) -> Vec<f32> {
        assert_eq!(params.len(), self.residual.len(), "error-feedback dimension mismatch");
        if cfg.is_none() {
            return params.to_vec();
        }
        let target: Vec<f32> = params.iter().zip(&self.residual).map(|(&p, &r)| p + r).collect();
        let sent = cfg.encode_decode(&target);
        for ((r, &t), &s) in self.residual.iter_mut().zip(&target).zip(&sent) {
            let next = t - s;
            *r = if next.is_finite() { next } else { 0.0 };
        }
        sent
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37).sin() * 3.0).collect()
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [CompressionKind::None, CompressionKind::Quant, CompressionKind::TopK] {
            assert_eq!(CompressionKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(CompressionKind::parse("TOPK"), Some(CompressionKind::TopK));
        assert_eq!(CompressionKind::parse("gzip"), None);
    }

    #[test]
    fn none_wire_size_is_bit_identical() {
        let c = CompressionConfig::none();
        for mb in [11.6, 21.6, 48.0] {
            assert_eq!(c.wire_mb(mb).to_bits(), mb.to_bits());
            assert_eq!(c.ratio(mb), 1.0);
        }
    }

    #[test]
    fn quant8_wire_size_is_about_4x_smaller() {
        let c = CompressionConfig::quant(8);
        for mb in [11.6, 48.0] {
            let ratio = c.ratio(mb);
            assert!(ratio > 3.5 && ratio < 4.1, "mb={mb}: ratio {ratio}");
        }
        // fewer bits compress harder
        assert!(CompressionConfig::quant(4).wire_mb(48.0) < c.wire_mb(48.0));
    }

    #[test]
    fn topk_wire_size_tracks_fraction() {
        let c = CompressionConfig::topk(0.1);
        let ratio = c.ratio(48.0);
        assert!((ratio - 5.0).abs() < 0.05, "frac 0.1 → 8 bytes per kept of 40 → 5x, got {ratio}");
    }

    #[test]
    fn quant_roundtrip_error_within_half_step() {
        for bits in [2u32, 4, 8, 12, 16] {
            let params = ramp(QUANT_CHUNK * 2 + 37);
            let enc = quant_encode(&params, bits);
            let dec = quant_decode(&enc).unwrap();
            assert_eq!(dec.len(), params.len());
            for (ci, chunk) in params.chunks(QUANT_CHUNK).enumerate() {
                let (_, step) = enc.chunks[ci];
                for (j, &x) in chunk.iter().enumerate() {
                    let err = (x - dec[ci * QUANT_CHUNK + j]).abs();
                    // half a step plus slack for f32 boundary rounding
                    let bound = step as f64 * 0.51 + 1e-6;
                    assert!(
                        (err as f64) <= bound,
                        "bits={bits} chunk {ci} elem {j}: err {err} > {bound}"
                    );
                }
            }
            // wire accounting matches the header math
            assert_eq!(
                enc.wire_bytes(),
                (params.len() * bits as usize).div_ceil(8) + enc.chunks.len() * 8
            );
        }
    }

    #[test]
    fn quant_constant_chunk_decodes_exactly() {
        let params = vec![2.5f32; 100];
        let dec = quant_decode(&quant_encode(&params, 4)).unwrap();
        assert_eq!(dec, params, "zero-range chunks must decode to the chunk min exactly");
    }

    #[test]
    fn quant_nonfinite_inputs_do_not_poison_the_chunk() {
        let mut params = ramp(16);
        params[3] = f32::NAN;
        params[9] = f32::INFINITY;
        let dec = quant_decode(&quant_encode(&params, 8)).unwrap();
        assert!(dec.iter().all(|x| x.is_finite()), "decoded payload must stay finite");
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let params = vec![0.1f32, -5.0, 0.2, 4.0, -0.05, 3.0];
        let enc = topk_encode(&params, 0.5); // k = 3
        assert_eq!(enc.indices, vec![1, 3, 5]);
        assert_eq!(enc.values, vec![-5.0, 4.0, 3.0]);
        let dec = topk_decode(&enc).unwrap();
        assert_eq!(dec, vec![0.0, -5.0, 0.0, 4.0, 0.0, 3.0]);
        assert_eq!(enc.wire_bytes(), 3 * 8);
    }

    #[test]
    fn topk_always_keeps_at_least_one() {
        let enc = topk_encode(&[0.0f32, 0.0, 7.0], 1e-9);
        assert_eq!(enc.indices.len(), 1);
        assert_eq!(enc.indices[0], 2);
    }

    #[test]
    fn topk_nonfinite_inputs_never_reach_the_wire() {
        // a NaN/∞ parameter ranks as zero magnitude and decodes as 0.0 —
        // it must not be preferentially selected or transmitted verbatim
        let params = vec![f32::NAN, 1.0, f32::INFINITY, -2.0, 0.5, f32::NEG_INFINITY];
        let enc = topk_encode(&params, 0.5); // k = 3
        assert_eq!(enc.indices, vec![1, 3, 4], "finite magnitudes win selection");
        let dec = topk_decode(&enc).unwrap();
        assert!(dec.iter().all(|x| x.is_finite()), "decoded payload must stay finite");
        // even at frac = 1.0 (every entry kept) the wire stays finite
        let all = topk_decode(&topk_encode(&params, 1.0)).unwrap();
        assert!(all.iter().all(|x| x.is_finite()));
        assert_eq!(all[1], 1.0);
        assert_eq!(all[0], 0.0);
    }

    #[test]
    fn error_feedback_self_heals_non_finite_residuals() {
        // one NaN training step must not poison the coordinate's
        // feedback memory for the rest of the session
        let cfg = CompressionConfig::topk(0.5);
        let mut ef = ErrorFeedback::new(4);
        let sent = ef.compress(&[f32::NAN, 1.0, -2.0, 0.25], &cfg);
        assert!(sent.iter().all(|x| x.is_finite()));
        assert!(ef.residual().iter().all(|r| r.is_finite()));
        // recovered params keep flowing normally afterwards
        let sent = ef.compress(&[0.5, 1.0, -2.0, 0.25], &cfg);
        assert!(sent.iter().all(|x| x.is_finite()));
        assert!(ef.residual().iter().all(|r| r.is_finite()));
    }

    #[test]
    fn error_feedback_conserves_the_residual() {
        let cfg = CompressionConfig::quant(4);
        let mut ef = ErrorFeedback::new(300);
        let params = ramp(300);
        let prev = ef.residual().to_vec();
        let sent = ef.compress(&params, &cfg);
        for i in 0..300 {
            let target = params[i] + prev[i];
            let recon = sent[i] + ef.residual()[i];
            assert!(
                (recon - target).abs() <= 1e-5,
                "elem {i}: sent + residual = {recon} != target {target}"
            );
        }
    }

    #[test]
    fn error_feedback_none_is_a_plain_copy() {
        let mut ef = ErrorFeedback::new(8);
        let params = ramp(8);
        let sent = ef.compress(&params, &CompressionConfig::none());
        assert_eq!(sent, params);
        assert!(ef.residual().iter().all(|&r| r == 0.0));
    }

    #[test]
    fn error_feedback_mean_error_shrinks_over_rounds() {
        // the EF memory re-transmits what earlier rounds dropped: over a
        // static target the cumulative transmitted signal approaches it
        let cfg = CompressionConfig::topk(0.25);
        let params = ramp(64);
        let mut ef = ErrorFeedback::new(64);
        let mut acc = vec![0.0f32; 64];
        let mut errs = Vec::new();
        for round in 0..8 {
            let sent = ef.compress(&params, &cfg);
            // receiver averages rounds (what FedAvg folding approximates)
            for i in 0..64 {
                acc[i] += (sent[i] - acc[i]) / (round + 1) as f32;
            }
            let err: f32 = acc.iter().zip(&params).map(|(a, p)| (a - p).abs()).sum::<f32>() / 64.0;
            errs.push(err);
        }
        assert!(errs[7] < errs[0], "EF must reduce steady-state error: {errs:?}");
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(CompressionConfig::quant(0).validate().is_err());
        assert!(CompressionConfig::quant(17).validate().is_err());
        assert!(CompressionConfig::topk(0.0).validate().is_err());
        assert!(CompressionConfig::topk(1.5).validate().is_err());
        assert!(CompressionConfig::topk(f64::NAN).validate().is_err());
        assert!(CompressionConfig::none().validate().is_ok());
        assert!(CompressionConfig::quant(8).validate().is_ok());
        assert!(CompressionConfig::topk(1.0).validate().is_ok());
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(CompressionConfig::none().label(), "none");
        assert_eq!(CompressionConfig::quant(8).label(), "quant8");
        assert_eq!(CompressionConfig::topk(0.1).label(), "topk0.10");
    }

    #[test]
    fn quant_decode_rejects_hostile_headers() {
        let good = quant_encode(&ramp(QUANT_CHUNK + 10), 8);
        assert!(quant_decode(&good).is_ok());
        // bits = 0 and bits > 32 must be rejected before the shift
        for bits in [0u32, 33, 64, u32::MAX] {
            let enc = QuantEncoded { bits, ..good.clone() };
            assert_eq!(quant_decode(&enc), Err(DecodeError::BadBits(bits)));
        }
        // truncated / padded word payloads
        let mut enc = good.clone();
        enc.words.pop();
        assert!(matches!(quant_decode(&enc), Err(DecodeError::WordCountMismatch { .. })));
        let mut enc = good.clone();
        enc.words.push(0);
        assert!(matches!(quant_decode(&enc), Err(DecodeError::WordCountMismatch { .. })));
        // a liar `len` header must not out-read the chunk table
        let enc = QuantEncoded { len: good.len + QUANT_CHUNK, ..good.clone() };
        assert!(quant_decode(&enc).is_err());
        // missing chunk headers
        let mut enc = good.clone();
        enc.chunks.pop();
        assert!(matches!(quant_decode(&enc), Err(DecodeError::ChunkCountMismatch { .. })));
        // NaN headers would decode every element to NaN
        let mut enc = good.clone();
        enc.chunks[0].1 = f32::NAN;
        assert!(matches!(quant_decode(&enc), Err(DecodeError::NonFiniteHeader { .. })));
    }

    #[test]
    fn topk_decode_rejects_hostile_indices() {
        let good = topk_encode(&ramp(64), 0.25);
        assert!(topk_decode(&good).is_ok());
        // out-of-bounds index: the old unchecked write panicked here
        let mut enc = good.clone();
        *enc.indices.last_mut().unwrap() = 64;
        assert_eq!(topk_decode(&enc), Err(DecodeError::IndexOutOfBounds { index: 64, len: 64 }));
        // a liar `len` header shrinks the output under the indices
        let enc = TopKEncoded { len: 3, ..good.clone() };
        assert!(matches!(topk_decode(&enc), Err(DecodeError::IndexOutOfBounds { .. })));
        // duplicate index (double-write) and descending order
        let mut enc = good.clone();
        enc.indices[1] = enc.indices[0];
        assert_eq!(topk_decode(&enc), Err(DecodeError::IndicesNotAscending { at: 1 }));
        let mut enc = good.clone();
        enc.indices.swap(0, 1);
        assert!(matches!(topk_decode(&enc), Err(DecodeError::IndicesNotAscending { .. })));
        // mismatched arities must not zip-truncate silently
        let mut enc = good.clone();
        enc.values.pop();
        assert!(matches!(topk_decode(&enc), Err(DecodeError::ArityMismatch { .. })));
    }
}
