//! The compression plane's contract tests:
//!
//! 1. codec round-trips, property-tested: quantization error stays under
//!    half a step, top-k keeps exactly the largest magnitudes with valid
//!    indices, and error feedback conserves `target = sent + residual`;
//! 2. the acceptance bar: `--compress quant --quant-bits 8` on
//!    balanced-tree underlays at n ≥ 10 moves ≥ 3.5× fewer wire bytes
//!    per round than `compress = none` while the exchange (and full
//!    dissemination) time strictly decreases, across jitter and failure
//!    injection — and pipelined DFL rounds still hand every node a
//!    complete fold set;
//! 3. compressed gossip + error-feedback folding reaches model consensus
//!    (the `models_agree` criterion) without the PJRT artifacts, by
//!    replaying the engine's actual reception orders over a plain
//!    weighted-average fold.

use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::GossipSession;
use mosgu::dfl::compress::{
    quant_decode, quant_encode, topk_decode, topk_encode, CompressionConfig, CompressionKind,
    ErrorFeedback, QUANT_CHUNK,
};
use mosgu::dfl::round::models_agree;
use mosgu::dfl::trainer::NodeModel;
use mosgu::graph::topology::TopologyKind;
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;

fn random_params(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    (0..len).map(|_| (rng.gen_f64_range(-4.0, 4.0)) as f32).collect()
}

#[test]
fn quantization_roundtrip_error_bounded_by_half_step() {
    check("quant roundtrip error bound", 128, |rng| {
        let len = 1 + rng.gen_range(3 * QUANT_CHUNK);
        let bits = 1 + rng.gen_range(16) as u32;
        let params = random_params(rng, len);
        let enc = quant_encode(&params, bits);
        let dec = quant_decode(&enc).expect("self-encoded quant payload is valid");
        if dec.len() != params.len() {
            return Err(format!("len {} != {}", dec.len(), params.len()));
        }
        for (i, (&x, &y)) in params.iter().zip(&dec).enumerate() {
            let (_, step) = enc.chunks[i / QUANT_CHUNK];
            // half a step plus slack for f32 rounding at code boundaries
            let bound = step as f64 * 0.51 + 1e-6;
            if ((x - y).abs() as f64) > bound {
                return Err(format!(
                    "bits={bits} elem {i}: |{x} - {y}| > half-step {bound}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn topk_indices_valid_and_magnitudes_maximal() {
    check("topk index validity + selection", 128, |rng| {
        let len = 1 + rng.gen_range(2000);
        let frac = rng.gen_f64_range(0.01, 1.0);
        let params = random_params(rng, len);
        let enc = topk_encode(&params, frac);
        let k = ((len as f64 * frac).ceil() as usize).clamp(1, len);
        if enc.indices.len() != k || enc.values.len() != k {
            return Err(format!("kept {} of expected {k}", enc.indices.len()));
        }
        // indices strictly ascending, in range, values match the source
        for (j, &i) in enc.indices.iter().enumerate() {
            if i as usize >= len {
                return Err(format!("index {i} out of range {len}"));
            }
            if j > 0 && enc.indices[j - 1] >= i {
                return Err("indices not strictly ascending".into());
            }
            if enc.values[j] != params[i as usize] {
                return Err(format!("value at {i} diverged"));
            }
        }
        // every kept magnitude >= every dropped magnitude
        let kept: std::collections::HashSet<usize> =
            enc.indices.iter().map(|&i| i as usize).collect();
        let min_kept = enc.values.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
        for (i, &x) in params.iter().enumerate() {
            if !kept.contains(&i) && x.abs() > min_kept {
                return Err(format!("dropped |{x}| at {i} exceeds kept min {min_kept}"));
            }
        }
        // decode: kept positions match, the rest are zero
        let dec = topk_decode(&enc).expect("self-encoded top-k payload is valid");
        for (i, &y) in dec.iter().enumerate() {
            let want = if kept.contains(&i) { params[i] } else { 0.0 };
            if y != want {
                return Err(format!("decoded[{i}] = {y}, want {want}"));
            }
        }
        Ok(())
    });
}

#[test]
fn error_feedback_residual_conservation() {
    check("EF residual conservation", 96, |rng| {
        let len = 1 + rng.gen_range(3000);
        let codec = if rng.gen_bool(0.5) {
            CompressionConfig::quant(1 + rng.gen_range(16) as u32)
        } else {
            CompressionConfig::topk(rng.gen_f64_range(0.05, 1.0))
        };
        let mut ef = ErrorFeedback::new(len);
        let mut prev = ef.residual().to_vec();
        for _round in 0..3 {
            let params = random_params(rng, len);
            let sent = ef.compress(&params, &codec);
            for i in 0..len {
                let target = params[i] + prev[i];
                let recon = sent[i] + ef.residual()[i];
                if (recon - target).abs() > 1e-4 {
                    return Err(format!(
                        "{}: elem {i} sent+residual {recon} != params+prev_residual {target}",
                        codec.label()
                    ));
                }
            }
            prev = ef.residual().to_vec();
        }
        Ok(())
    });
}

fn quiet_cfg(kind: TopologyKind, n: usize) -> ExperimentConfig {
    ExperimentConfig { topology: kind, nodes: n, latency_jitter: 0.0, ..Default::default() }
}

fn quant8(cfg: &ExperimentConfig) -> ExperimentConfig {
    ExperimentConfig { compress: CompressionKind::Quant, quant_bits: 8, ..cfg.clone() }
}

#[test]
fn quant8_cuts_wire_bytes_3_5x_and_strictly_speeds_rounds() {
    // the PR's acceptance bar, plus jitter/failure robustness
    for n in [10usize, 12] {
        let base = quiet_cfg(TopologyKind::BalancedTree, n);
        let plain = GossipSession::new(&base).unwrap();
        let compressed = GossipSession::new(&quant8(&base)).unwrap();
        for model_mb in [11.6, 48.0] {
            let a = plain.run_mosgu_round(model_mb, 1, 0.0);
            let b = compressed.run_mosgu_round(model_mb, 1, 0.0);
            // same protocol: every model still crosses every tree edge
            assert_eq!(b.transfer_count(), a.transfer_count(), "n={n} mb={model_mb}");
            let wire_ratio = a.total_payload_mb() / b.total_payload_mb();
            assert!(
                wire_ratio >= 3.5,
                "n={n} mb={model_mb}: wire bytes only dropped {wire_ratio:.2}x"
            );
            assert!((b.compression_ratio() - wire_ratio).abs() < 0.05);
            // logical accounting is unchanged
            assert!((b.total_logical_mb() - a.total_logical_mb()).abs() < 1e-9);
            // smaller payloads must strictly speed the round up
            assert!(
                b.exchange_time_s < a.exchange_time_s,
                "n={n} mb={model_mb}: exchange {} !< {}",
                b.exchange_time_s,
                a.exchange_time_s
            );
            assert!(b.total_time_s < a.total_time_s, "n={n} mb={model_mb}");
        }
    }
    // jitter + failure injection: compressed rounds stay complete and
    // deterministic, and still beat full-width on exchange time
    let base = ExperimentConfig { topology: TopologyKind::BalancedTree, ..Default::default() };
    let plain = GossipSession::new(&base).unwrap();
    let compressed = GossipSession::new(&quant8(&base)).unwrap();
    let a = plain.run_mosgu_round(48.0, 3, 0.15);
    let b = compressed.run_mosgu_round(48.0, 3, 0.15);
    assert!(b.exchange_time_s < a.exchange_time_s);
    let again = compressed.run_mosgu_round(48.0, 3, 0.15);
    assert_eq!(b.total_time_s.to_bits(), again.total_time_s.to_bits());
    assert_eq!(b.transfers, again.transfers);
}

#[test]
fn compressed_pipeline_hands_dfl_full_fold_inputs() {
    // run_dfl's communication path under compression: pipelined rounds
    // complete with full reception orders, and the wire payload shrinks
    let base = quiet_cfg(TopologyKind::BalancedTree, 10);
    let plain = GossipSession::new(&base).unwrap();
    let compressed = GossipSession::new(&quant8(&base)).unwrap();
    let a = plain.run_adaptive_rounds(21.6, 2, 0x90551b);
    let b = compressed.run_adaptive_rounds(21.6, 2, 0x90551b);
    assert_eq!(b.rounds.len(), 2);
    for (r, orders) in b.received.iter().enumerate() {
        for (u, order) in orders.iter().enumerate() {
            assert_eq!(order.len(), 9, "round {r} node {u} missed models");
        }
    }
    assert!((b.logical_model_mb - 21.6).abs() < 1e-12);
    assert!(b.wire_model_mb * 3.5 < b.logical_model_mb);
    assert!(b.total_time_s < a.total_time_s, "compressed pipeline must finish sooner");
    // topk threads through the same path
    let topk_cfg = ExperimentConfig {
        compress: CompressionKind::TopK,
        topk_frac: 0.1,
        ..base.clone()
    };
    let t = GossipSession::new(&topk_cfg).unwrap().run_adaptive_rounds(21.6, 2, 0x90551b);
    assert!((t.logical_model_mb / t.wire_model_mb - 5.0).abs() < 0.05);
    assert!(t.total_time_s < a.total_time_s);
}

/// Replay run_dfl's aggregation (weighted pairwise average in the
/// engine's reception orders) without the PJRT artifacts.
fn fold_round(snapshot: &[Vec<f32>], received: &[Vec<usize>]) -> Vec<Vec<f32>> {
    let n = snapshot.len();
    (0..n)
        .map(|u| {
            let mut acc = snapshot[u].clone();
            let mut weight = 1.0f32;
            for &owner in &received[u] {
                weight += 1.0;
                for (a, &o) in acc.iter_mut().zip(&snapshot[owner]) {
                    *a += (o - *a) / weight;
                }
            }
            acc
        })
        .collect()
}

#[test]
fn compressed_gossip_with_error_feedback_reaches_consensus() {
    // the models_agree criterion of the DFL loop, codec on: every node
    // folds the identical decoded snapshot set (the sender adopts its own
    // decoded payload, exactly as run_dfl does), so consensus holds to
    // float-reordering tolerance within the same round budget as none
    let dim = QUANT_CHUNK + 123;
    let rounds = 2u64;
    for codec in [
        CompressionConfig::none(),
        CompressionConfig::quant(8),
        CompressionConfig::topk(0.25),
    ] {
        let cfg = ExperimentConfig {
            compress: codec.kind,
            quant_bits: codec.quant_bits,
            topk_frac: codec.topk_frac,
            ..quiet_cfg(TopologyKind::BalancedTree, 10)
        };
        let session = GossipSession::new(&cfg).unwrap();
        let pipeline = session.run_pipelined_rounds(11.6, rounds, 0x90551b);
        let mut rng = Pcg64::new(0xfeed);
        let mut params: Vec<Vec<f32>> = (0..10).map(|_| random_params(&mut rng, dim)).collect();
        let mut feedback: Vec<ErrorFeedback> = (0..10).map(|_| ErrorFeedback::new(dim)).collect();
        for round in 0..rounds as usize {
            let snapshot: Vec<Vec<f32>> =
                (0..10).map(|u| feedback[u].compress(&params[u], &codec)).collect();
            params = fold_round(&snapshot, &pipeline.received[round]);
        }
        let nodes: Vec<NodeModel> = params
            .into_iter()
            .enumerate()
            .map(|(node, params)| NodeModel { node, params, weight: 1.0 })
            .collect();
        assert!(
            models_agree(&nodes, 1e-4),
            "{}: compressed gossip failed to reach consensus",
            codec.label()
        );
    }
}
