//! Per-subnet coloring stitched across a hierarchical gossip tree.
//!
//! Hierarchical planning (§III-C at scale) colors each subnet's subtree
//! **independently** — the subnet's own moderator could compute it with
//! no global view — then makes the colorings globally proper by flipping
//! whole subnets: the stitched tree's cross-subnet edges form a tree over
//! the subnets, so a BFS over that quotient tree can align each child
//! subnet's parity with its parent through the one gateway edge joining
//! them. With one subnet the function is exactly the flat coloring
//! algorithm, bit for bit.

use super::{Coloring, ColoringAlgorithm};
use crate::graph::Graph;
use std::collections::VecDeque;

/// Color `tree` (a stitched hierarchical gossip tree) subnet by subnet
/// and align parities across gateway edges. Falls back to running `alg`
/// over the whole tree when any per-subnet coloring uses more than two
/// colors (the greedy algorithms may on adversarial subtrees —
/// docs/EXPERIMENTS.md §Deviations), so the result is always a proper
/// coloring of `tree`.
pub fn stitched_tree_coloring(tree: &Graph, subnet_of: &[usize], alg: ColoringAlgorithm) -> Coloring {
    let n = tree.node_count();
    assert_eq!(subnet_of.len(), n, "subnet assignment covers every node");
    let k = subnet_of.iter().copied().max().map_or(0, |m| m + 1);
    if k <= 1 {
        return alg.run(tree); // flat fallback, bit for bit
    }
    let mut assignment = vec![0usize; n];
    for s in 0..k {
        let members: Vec<usize> = (0..n).filter(|&u| subnet_of[u] == s).collect();
        let (sub, map) = tree.induced(&members);
        let col = alg.run(&sub);
        if col.num_colors() > 2 {
            // parity flips only compose 2-colorings; stay proper globally
            return alg.run(tree);
        }
        for (new, &old) in map.iter().enumerate() {
            assignment[old] = col.color_of(new);
        }
    }
    // quotient tree: each cross-subnet tree edge joins two subnets once
    let mut crossing: Vec<Vec<(usize, usize, usize)>> = vec![Vec::new(); k];
    for e in tree.edges() {
        let (su, sv) = (subnet_of[e.u], subnet_of[e.v]);
        if su != sv {
            crossing[su].push((sv, e.u, e.v));
            crossing[sv].push((su, e.v, e.u));
        }
    }
    let mut seen = vec![false; k];
    seen[0] = true;
    let mut queue = VecDeque::from([0usize]);
    while let Some(s) = queue.pop_front() {
        for &(t, here, there) in &crossing[s] {
            if seen[t] {
                continue;
            }
            seen[t] = true;
            if assignment[here] == assignment[there] {
                // flip the child subnet so the gateway edge is bichromatic
                for u in 0..n {
                    if subnet_of[u] == t {
                        assignment[u] ^= 1;
                    }
                }
            }
            queue.push_back(t);
        }
    }
    let stitched = Coloring::new(assignment);
    // parity flips are only sound when subnets are connected in the tree
    // and the quotient is a tree (stitched_mst guarantees both); on any
    // other input, keep the properness contract via the global algorithm
    if stitched.is_proper(tree) {
        stitched
    } else {
        alg.run(tree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::hierarchy::plan_hierarchical;
    use crate::graph::generators::router_hierarchy;
    use crate::mst::MstAlgorithm;
    use crate::util::rng::Pcg64;

    #[test]
    fn single_subnet_matches_flat_coloring_bit_for_bit() {
        let (structure, h) = router_hierarchy(12, 1, 2, 4, &mut Pcg64::new(3));
        let tree = MstAlgorithm::Prim.run(&structure).unwrap();
        let flat = ColoringAlgorithm::Bfs.run(&tree);
        let stitched = stitched_tree_coloring(&tree, h.subnet_of(), ColoringAlgorithm::Bfs);
        assert_eq!(stitched.assignment(), flat.assignment());
    }

    #[test]
    fn stitched_coloring_is_proper_on_hierarchical_trees() {
        for (n, s) in [(18, 3), (26, 4), (40, 8)] {
            let (structure, h) = router_hierarchy(n, s, 2, 4, &mut Pcg64::new(n as u64));
            let epoch = plan_hierarchical(
                &structure,
                &h,
                MstAlgorithm::Prim,
                ColoringAlgorithm::Bfs,
                14.0,
                56,
                0,
            )
            .unwrap();
            let col = stitched_tree_coloring(&epoch.tree, h.subnet_of(), ColoringAlgorithm::Bfs);
            assert!(col.is_proper(&epoch.tree), "n={n} s={s}");
            assert!(col.num_colors() <= 2);
        }
    }

    #[test]
    fn fallback_to_global_coloring_stays_proper() {
        // force the fallback path with a greedy algorithm; even if a
        // per-subnet run used 3 colors, the result must stay proper
        let (structure, h) = router_hierarchy(30, 5, 2, 4, &mut Pcg64::new(17));
        let tree = crate::mst::stitched_mst(
            &structure,
            h.subnet_of(),
            h.gateways(),
            MstAlgorithm::Kruskal,
        )
        .unwrap();
        for alg in ColoringAlgorithm::ALL {
            let col = stitched_tree_coloring(&tree, h.subnet_of(), alg);
            assert!(col.is_proper(&tree), "{alg:?} produced an improper stitched coloring");
        }
    }
}
