//! Persistent work-stealing drain pool for round barriers.
//!
//! The old barrier spawned one OS thread per subnet per round
//! (`std::thread::scope` in `ShardedNetSim::drain_and_sync`): at 256
//! subnets that is 256 thread spawns/joins per barrier, with most threads
//! doing microseconds of work. [`DrainPool`] decouples parallelism from
//! shard count: a fixed set of workers lives across barriers, each busy
//! shard becomes one stealable *task*, and workers (plus the submitting
//! thread) claim tasks from a shared index until the queue is dry.
//!
//! ## Determinism
//!
//! Within a barrier window the shards share no state — each task drains
//! one [`Drainable`] to idle with purely private data. Claim order
//! therefore cannot influence any result: every drain computes the same
//! trajectory regardless of which worker runs it or when. Pool drains
//! with 1, 2, or N workers are bit-identical to each other and to a
//! sequential drain (pinned by tests here and in `tests/scale_shard.rs`).
//!
//! ## Static verification
//!
//! All synchronization goes through [`super::sync`], so building with
//! `--features loom` swaps in loom's model-checked primitives and
//! `tests/loom_pool.rs` exhaustively interleaves 2–3 drainers claiming
//! tasks — every schedule the memory model admits, including the ones
//! the claim/finish `debug_assert`s guard. CI additionally runs the
//! pool's tests under Miri (`netsim::pool` filter) to validate the raw
//! pointer discipline dynamically.

use super::sync::{spawn, Arc, Condvar, JoinHandle, Mutex};
use super::NetSim;
use std::sync::PoisonError;

/// Something the pool can drain to idle as one claimable task. Tasks in
/// one [`DrainPool::drain`] batch must be mutually independent: draining
/// one may not observe or affect another (the determinism contract
/// above, and the reason claim order is free to vary).
pub trait Drainable: Send {
    /// Run until no work remains (the barrier condition).
    fn drain_to_idle(&mut self);
}

impl Drainable for NetSim {
    fn drain_to_idle(&mut self) {
        self.run_until_idle();
    }
}

/// A claimable drain task. The raw pointer erases the caller's borrow so
/// the long-lived workers can hold it; the `DrainPool` invariants below
/// re-establish the exclusivity the borrow checker can no longer see.
struct Task<T>(*mut T);

impl<T> Clone for Task<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for Task<T> {}

// SAFETY: a Task is only ever dereferenced by the single thread that
// claimed it under the pool mutex (invariant I2 on `DrainPool`), and the
// pointee is `Send` via the `Drainable: Send` bound everywhere tasks are
// created, so moving the pointer across threads is sound.
unsafe impl<T: Send> Send for Task<T> {}

struct PoolState<T> {
    /// tasks for the current barrier window
    tasks: Vec<Task<T>>,
    /// claim ledger, parallel to `tasks` — `claimed[i]` flips false→true
    /// exactly once, under the mutex, when task `i` is handed out
    /// (upholds invariant I2; checked by `debug_assert`)
    claimed: Vec<bool>,
    /// next unclaimed index into `tasks`
    next: usize,
    /// claimed tasks not yet finished + unclaimed tasks
    outstanding: usize,
    shutdown: bool,
}

struct Shared<T> {
    state: Mutex<PoolState<T>>,
    /// workers wait here for tasks (or shutdown)
    work_cv: Condvar,
    /// the submitter waits here for `outstanding == 0`
    done_cv: Condvar,
}

/// A persistent pool draining batches of independent [`Drainable`]s
/// (defaulting to [`NetSim`] — the sharded simulator's barrier).
///
/// # Invariants
///
/// The pool erases `&mut T` borrows into raw pointers so long-lived
/// workers can hold them; these invariants restore exactly the
/// exclusivity the erased borrows promised. Every `unsafe` block in this
/// module cites them.
///
/// - **I1 (liveness of the pointee).** Tasks exist only between
///   [`DrainPool::drain`] publishing a batch and that same call
///   returning. `drain` blocks until `outstanding == 0` — every claimed
///   task has finished — so no worker can touch a pointee after the
///   caller's `&mut` borrows are released. The ledger is also cleared
///   (`tasks`/`claimed` emptied) before `drain` returns, so no stale
///   pointer survives the window.
/// - **I2 (sole claimant).** Task `i` is handed out exactly once: claims
///   mutate `next` (and the `claimed[i]` ledger) under `state`'s mutex,
///   and each increment of `next` transfers task `next` to exactly one
///   thread. The claimant dereferences the pointer only between its
///   claim and its matching `finish_one`, so at most one thread ever
///   holds a `&mut` into any pointee — `debug_assert`ed at every claim
///   site via the ledger.
/// - **I3 (batch independence).** Each `&mut T` in a batch is a distinct
///   exclusive borrow, so pointees are pairwise disjoint; with I2 this
///   gives data-race freedom without any ordering between tasks
///   (determinism contract in the module docs).
/// - **I4 (no overlapping windows).** `drain` takes `&self` but windows
///   never overlap: `outstanding` must be zero when a batch is
///   published (`debug_assert`ed) and `drain` does not return until it
///   is zero again. The sharded simulator upholds this by draining from
///   one coordinating thread.
///
/// The loom model in `tests/loom_pool.rs` checks I1/I2/I4 across every
/// interleaving of 2–3 drainers; Miri checks the pointer discipline on
/// the native tests.
pub struct DrainPool<T: Drainable = NetSim> {
    shared: Arc<Shared<T>>,
    handles: Vec<JoinHandle<()>>,
    parallelism: usize,
}

impl<T: Drainable + 'static> DrainPool<T> {
    /// Build a pool with `parallelism` concurrent drainers. The submitting
    /// thread participates in every drain, so `parallelism - 1` worker
    /// threads are spawned; `parallelism <= 1` spawns none and
    /// [`DrainPool::drain`] degenerates to a sequential loop.
    pub fn new(parallelism: usize) -> Self {
        let parallelism = parallelism.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                tasks: Vec::new(),
                claimed: Vec::new(),
                next: 0,
                outstanding: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..parallelism)
            .map(|_| {
                let shared = Arc::clone(&shared);
                spawn(move || worker_loop(&shared))
            })
            .collect();
        DrainPool { shared, handles, parallelism }
    }

    /// Concurrent drainers this pool runs with (including the submitter).
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Drain every item in `sims` to idle, stealing tasks onto all
    /// workers plus the calling thread. Blocks until the last task
    /// finishes (invariant I1; see the type-level invariant block).
    pub fn drain<'a, I>(&self, sims: I)
    where
        I: IntoIterator<Item = &'a mut T>,
        T: 'a,
    {
        let tasks: Vec<Task<T>> = sims.into_iter().map(|s| Task(s as *mut T)).collect();
        if tasks.is_empty() {
            return;
        }
        {
            let mut st = self.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            // I4: windows never overlap
            debug_assert!(st.outstanding == 0, "overlapping drain calls");
            st.outstanding = tasks.len();
            st.claimed = vec![false; tasks.len()];
            st.tasks = tasks;
            st.next = 0;
            self.shared.work_cv.notify_all();
        }
        // the submitter steals too: a 1-wide pool is just this loop
        while let Some(t) = claim(&self.shared) {
            // SAFETY: invariants I1–I3 — the pointee outlives the window
            // this call is inside, and `claim` made this thread the sole
            // claimant, so this is the only `&mut` into it
            unsafe { (*t.0).drain_to_idle() };
            finish_one(&self.shared);
        }
        let mut st = self.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
        while st.outstanding > 0 {
            st = self.shared.done_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        // I1: drop every erased pointer before the caller's borrows end
        st.tasks.clear();
        st.claimed.clear();
    }
}

impl<T: Drainable> Drop for DrainPool<T> {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim the next unclaimed task, if any, marking this thread its sole
/// claimant (invariant I2) — all under the state mutex.
fn claim<T: Drainable>(shared: &Shared<T>) -> Option<Task<T>> {
    let mut st = shared.state.lock().unwrap_or_else(PoisonError::into_inner);
    if st.next < st.tasks.len() {
        let i = st.next;
        st.next += 1;
        debug_assert!(!st.claimed[i], "task {i} claimed twice");
        st.claimed[i] = true;
        Some(st.tasks[i])
    } else {
        None
    }
}

fn worker_loop<T: Drainable>(shared: &Shared<T>) {
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if st.shutdown {
                    return;
                }
                if st.next < st.tasks.len() {
                    let i = st.next;
                    st.next += 1;
                    debug_assert!(!st.claimed[i], "task {i} claimed twice");
                    st.claimed[i] = true;
                    break st.tasks[i];
                }
                st = shared.work_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        // SAFETY: invariants I1–I3 — the claim above (under the mutex)
        // made this thread the sole claimant, and the submitter blocks
        // until `finish_one` below accounts for this task
        unsafe { (*task.0).drain_to_idle() };
        finish_one(shared);
    }
}

fn finish_one<T: Drainable>(shared: &Shared<T>) {
    let mut st = shared.state.lock().unwrap_or_else(PoisonError::into_inner);
    debug_assert!(st.outstanding > 0, "finish without a matching claim");
    st.outstanding -= 1;
    if st.outstanding == 0 {
        shared.done_cv.notify_all();
    }
}

#[cfg(all(test, not(feature = "loom")))]
mod tests {
    use super::*;
    use crate::netsim::{Channel, LossModel};

    fn busy_sims(n: usize) -> Vec<NetSim> {
        (0..n)
            .map(|i| {
                let chans = vec![
                    Channel { capacity_mbps: 8.0 + i as f64, latency_s: 0.01, label: "a".into() },
                    Channel { capacity_mbps: 3.0, latency_s: 0.0, label: "b".into() },
                ];
                let mut sim = NetSim::new(chans, LossModel::default(), 0.02, 7 + i as u64);
                for k in 0..5 {
                    sim.start_flow(0, 1, vec![0], 2.0 + k as f64, k as u64);
                    sim.start_flow(1, 0, vec![1], 1.5, (10 + k) as u64);
                }
                sim
            })
            .collect()
    }

    fn fingerprint(sims: &[NetSim]) -> Vec<(u64, usize)> {
        sims.iter().map(|s| (s.now().to_bits(), s.completed().len())).collect()
    }

    #[test]
    fn pool_drain_matches_sequential_bit_for_bit() {
        let mut seq = busy_sims(7);
        for s in seq.iter_mut() {
            s.run_until_idle();
        }
        let pool = DrainPool::new(4);
        let mut par = busy_sims(7);
        pool.drain(par.iter_mut());
        assert_eq!(fingerprint(&seq), fingerprint(&par));
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.completed(), b.completed());
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let base = {
            let mut sims = busy_sims(9);
            DrainPool::new(1).drain(sims.iter_mut());
            fingerprint(&sims)
        };
        for workers in [2, 3, 16] {
            let mut sims = busy_sims(9);
            DrainPool::new(workers).drain(sims.iter_mut());
            assert_eq!(fingerprint(&sims), base, "{workers} workers diverged");
        }
    }

    #[test]
    fn pool_is_reusable_across_barriers() {
        let pool = DrainPool::new(3);
        let mut sims = busy_sims(5);
        pool.drain(sims.iter_mut());
        assert!(sims.iter().all(|s| s.active_flow_count() == 0));
        // second barrier window: launch more flows, drain again
        for (i, s) in sims.iter_mut().enumerate() {
            s.start_flow(0, 1, vec![0], 4.0, 100 + i as u64);
        }
        pool.drain(sims.iter_mut().filter(|s| s.active_flow_count() > 0));
        assert!(sims.iter().all(|s| s.active_flow_count() == 0));
        // an empty batch is a no-op
        pool.drain(std::iter::empty());
    }

    #[test]
    fn tasks_exceeding_workers_all_complete() {
        let pool = DrainPool::new(2);
        let mut sims = busy_sims(40);
        pool.drain(sims.iter_mut());
        assert!(sims.iter().all(|s| s.active_flow_count() == 0));
    }

    #[test]
    fn custom_drainable_runs_every_task_once() {
        struct Probe {
            drains: usize,
        }
        impl Drainable for Probe {
            fn drain_to_idle(&mut self) {
                self.drains += 1;
            }
        }
        let pool: DrainPool<Probe> = DrainPool::new(3);
        let mut probes: Vec<Probe> = (0..17).map(|_| Probe { drains: 0 }).collect();
        pool.drain(probes.iter_mut());
        assert!(probes.iter().all(|p| p.drains == 1));
        pool.drain(probes.iter_mut());
        assert!(probes.iter().all(|p| p.drains == 2));
    }
}
