//! The paper's running example (Fig 1, Fig 2, Table I): a 10-node graph
//! labeled A..K (skipping J, as the paper does) whose Prim MST is the tree
//!
//! ```text
//!        A - H - F - E
//!                |
//!                G - K - I - B - C - D
//! ```
//!
//! with BFS 2-coloring (root A): blue = {A, B, D, F, K}, red = {C, E, G,
//! H, I}. Table I traces one gossip round on this tree starting with the
//! red slot; `tests/table1_trace.rs` replays it move for move.

use crate::coloring::{bfs_coloring, Coloring};
use crate::graph::{Graph, NodeId};

/// Node indices for the labels the paper uses.
pub const A: NodeId = 0;
pub const B: NodeId = 1;
pub const C: NodeId = 2;
pub const D: NodeId = 3;
pub const E: NodeId = 4;
pub const F: NodeId = 5;
pub const G: NodeId = 6;
pub const H: NodeId = 7;
pub const I: NodeId = 8;
pub const K: NodeId = 9;

/// Label of a node in the example (A..K skipping J).
pub fn label(u: NodeId) -> char {
    ['A', 'B', 'C', 'D', 'E', 'F', 'G', 'H', 'I', 'K'][u]
}

/// Parse a label back to its node id.
pub fn node_of(label: char) -> Option<NodeId> {
    "ABCDEFGHIK".find(label)
}

/// The example's weighted overlay graph. Edge weights are ping costs chosen
/// so that Prim's algorithm yields exactly the paper's MST; the extra
/// (non-MST) edges are the "redundant connections" Fig 2 prunes.
pub fn paper_example_graph() -> Graph {
    let mut g = Graph::new(10);
    // MST edges (cheap paths)
    g.add_edge(A, H, 1.0);
    g.add_edge(H, F, 1.2);
    g.add_edge(F, E, 1.1);
    g.add_edge(F, G, 1.3);
    g.add_edge(G, K, 1.0);
    g.add_edge(K, I, 1.2);
    g.add_edge(I, B, 1.1);
    g.add_edge(B, C, 1.0);
    g.add_edge(C, D, 1.3);
    // redundant edges removed by the MST (§III-B "eliminate unnecessary
    // edges or connections")
    g.add_edge(A, B, 4.0);
    g.add_edge(A, E, 3.5);
    g.add_edge(D, K, 5.0);
    g.add_edge(E, G, 2.8);
    g.add_edge(H, I, 3.2);
    g.add_edge(C, I, 2.6);
    g.add_edge(D, G, 4.4);
    g.add_edge(B, F, 3.9);
    g
}

/// The MST edge set the paper's Table I gossips over.
pub fn paper_example_mst_edges() -> Vec<(NodeId, NodeId)> {
    vec![
        (A, H),
        (H, F),
        (F, E),
        (F, G),
        (G, K),
        (K, I),
        (I, B),
        (B, C),
        (C, D),
    ]
}

/// The MST as a graph (weights from the example graph).
pub fn paper_example_mst() -> Graph {
    let g = paper_example_graph();
    let mut t = Graph::new(10);
    for (u, v) in paper_example_mst_edges() {
        t.add_edge(u, v, g.weight(u, v).unwrap());
    }
    t
}

/// BFS 2-coloring of the MST rooted at A: color 0 = blue {A,B,D,F,K},
/// color 1 = red {C,E,G,H,I}. The paper's Table I starts with red.
pub fn paper_example_coloring() -> Coloring {
    bfs_coloring(&paper_example_mst())
}

/// The color index that transmits first in Table I (red).
pub const RED: usize = 1;
/// The silent-first color (blue).
pub const BLUE: usize = 0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mst::prim;

    #[test]
    fn labels_roundtrip() {
        for u in 0..10 {
            assert_eq!(node_of(label(u)), Some(u));
        }
        assert_eq!(node_of('J'), None);
    }

    #[test]
    fn example_graph_is_connected_with_redundancy() {
        let g = paper_example_graph();
        assert!(g.is_connected());
        assert!(g.edge_count() > 9, "must contain redundant edges to prune");
    }

    #[test]
    fn prim_recovers_paper_mst() {
        let t = prim(&paper_example_graph()).unwrap();
        for (u, v) in paper_example_mst_edges() {
            assert!(t.has_edge(u, v), "missing ({},{})", label(u), label(v));
        }
        assert_eq!(t.edge_count(), 9);
    }

    #[test]
    fn coloring_matches_paper_classes() {
        let c = paper_example_coloring();
        let red: Vec<char> = c.class(RED).into_iter().map(label).collect();
        let blue: Vec<char> = c.class(BLUE).into_iter().map(label).collect();
        assert_eq!(red, vec!['C', 'E', 'G', 'H', 'I']);
        assert_eq!(blue, vec!['A', 'B', 'D', 'F', 'K']);
    }

    #[test]
    fn mst_is_tree_and_proper() {
        let t = paper_example_mst();
        assert!(t.is_tree());
        assert!(paper_example_coloring().is_proper(&t));
    }

    #[test]
    fn degree_one_nodes_match_paper() {
        // Table I's degree-1 observation applies to A, D, E (leaves)
        let t = paper_example_mst();
        let leaves: Vec<char> =
            (0..10).filter(|&u| t.degree(u) == 1).map(label).collect();
        assert_eq!(leaves, vec!['A', 'D', 'E']);
    }
}
