//! k edge-disjoint spanning trees (multi-tree dissemination plane).
//!
//! The paper's planner routes every segment down *one* MST, which leaves
//! all non-tree links idle. Following the parallel-partial-stream idea of
//! Segmented Gossip (arXiv:1908.07782), the moderator can instead extract
//! up to `k` pairwise edge-disjoint spanning trees from the cost graph and
//! stripe the model's segments round-robin across them, so differently
//! shaped trees share the forwarding load.
//!
//! Extraction is iterated Kruskal with used-edge removal: sort edges once
//! (NaN-safe `total_cmp`), greedily build a spanning tree, remove its
//! edges from the residual set, repeat. Ties between equal-weight edges
//! are broken **degree-aware** (prefer endpoints with low degree in the
//! tree under construction) so uniform-cost topologies produce path-like
//! trees instead of a star whose hub would strand the residual graph.
//! When the residual graph disconnects before `k` trees exist we
//! gracefully fall back to the trees found so far (a ring admits exactly
//! one; a complete graph several).
//! The degree-bounded variant additionally skips edges that would push a
//! node past `max_degree` inside one tree — a greedy filter, so it retries
//! unbounded when the bound makes the residual unspannable.

use super::union_find::UnionFind;
use super::MstError;
use crate::graph::{Edge, Graph};

/// Extract up to `k` pairwise edge-disjoint spanning trees of `g`.
///
/// Always returns at least one tree (an MST — identical to
/// [`super::kruskal`] whenever edge weights are distinct) or an error;
/// returns fewer than `k` trees when the residual graph disconnects
/// first. Deterministic: no RNG, edges ordered by `total_cmp` weight
/// with a degree-aware tie-break inside equal-weight runs.
pub fn disjoint_spanning_trees(g: &Graph, k: usize) -> Result<Vec<Graph>, MstError> {
    forest(g, k, usize::MAX)
}

/// Like [`disjoint_spanning_trees`] but each tree also respects a per-node
/// degree cap (bounded fan-out keeps any single relay from serializing a
/// whole stripe). The cap is a greedy filter, not a feasibility proof: if
/// a bounded pass cannot span, the pass retries unbounded so the result
/// still spans whenever the residual graph is connected.
pub fn degree_bounded_disjoint_trees(
    g: &Graph,
    k: usize,
    max_degree: usize,
) -> Result<Vec<Graph>, MstError> {
    forest(g, k, max_degree.max(1))
}

/// Extract up to `extra` additional spanning trees that are edge-disjoint
/// from `base` and from each other. Never errors: low connectivity just
/// yields fewer (possibly zero) trees. This is the moderator's entry
/// point — lane 0 stays whatever `MstAlgorithm` produced, and the extra
/// lanes are carved from the residual cost graph.
pub fn extra_disjoint_trees(g: &Graph, base: &Graph, extra: usize) -> Vec<Graph> {
    let n = g.node_count();
    if n == 0 || g.edges().iter().any(|e| !e.weight.is_finite()) {
        return Vec::new();
    }
    let edges = ordered_edges(g);
    let mut used = vec![false; edges.len()];
    for be in base.edges() {
        if let Some(i) = edges.iter().position(|e| e.u == be.u && e.v == be.v) {
            used[i] = true;
        }
    }
    let mut trees = Vec::new();
    while trees.len() < extra {
        let Some(picked) = kruskal_subset(n, &edges, &used, usize::MAX) else { break };
        trees.push(commit(n, &edges, &mut used, &picked));
        if n <= 1 {
            break; // a 1-node graph admits endless trivial trees
        }
    }
    trees
}

fn forest(g: &Graph, k: usize, max_degree: usize) -> Result<Vec<Graph>, MstError> {
    let n = g.node_count();
    if n == 0 {
        return Err(MstError::Empty);
    }
    if let Some(e) = g.edges().iter().find(|e| !e.weight.is_finite()) {
        return Err(MstError::NonFinite { u: e.u, v: e.v });
    }
    let k = k.max(1);
    let edges = ordered_edges(g);
    let mut used = vec![false; edges.len()];
    let mut trees = Vec::new();
    while trees.len() < k {
        let picked = kruskal_subset(n, &edges, &used, max_degree)
            .or_else(|| kruskal_subset(n, &edges, &used, usize::MAX));
        let Some(picked) = picked else { break };
        trees.push(commit(n, &edges, &mut used, &picked));
        if n <= 1 {
            break; // avoid returning k identical trivial trees
        }
    }
    if trees.is_empty() {
        return Err(MstError::Disconnected);
    }
    Ok(trees)
}

/// Deterministic NaN-safe ordering: weight via `total_cmp`, then endpoints.
fn ordered_edges(g: &Graph) -> Vec<Edge> {
    let mut edges = g.edges().to_vec();
    edges.sort_by(|a, b| a.weight.total_cmp(&b.weight).then(a.u.cmp(&b.u)).then(a.v.cmp(&b.v)));
    edges
}

/// One Kruskal pass over the unused edges, skipping edges that would push
/// an endpoint past `max_degree` within this tree. Returns the picked
/// indices iff they span all `n` nodes.
///
/// Within each **equal-weight run** the pick is degree-aware: among the
/// union-eligible candidates, choose the one minimizing
/// `(deg u + deg v, max(deg u, deg v), u, v)` where degrees count edges
/// already picked into *this* tree. Plain first-fit would turn every
/// uniform-cost clique into a star at node 0 — whose hub then has no
/// residual edges left, so no second disjoint tree could ever exist. The
/// degree-aware pick yields path-like trees instead, keeping the residual
/// connected for subsequent passes. On distinct weights every run has
/// length one and the pass is classical Kruskal.
fn kruskal_subset(n: usize, edges: &[Edge], used: &[bool], max_degree: usize) -> Option<Vec<usize>> {
    let mut uf = UnionFind::new(n);
    let mut deg = vec![0usize; n];
    let mut picked = Vec::with_capacity(n.saturating_sub(1));
    let mut i = 0;
    while i < edges.len() && picked.len() + 1 < n {
        let mut j = i + 1;
        while j < edges.len() && edges[j].weight.total_cmp(&edges[i].weight).is_eq() {
            j += 1;
        }
        // drain this equal-weight run degree-aware until nothing unions
        loop {
            let mut best: Option<(usize, (usize, usize, usize, usize))> = None;
            for (ei, e) in edges.iter().enumerate().take(j).skip(i) {
                if used[ei] || deg[e.u] >= max_degree || deg[e.v] >= max_degree {
                    continue;
                }
                if uf.connected(e.u, e.v) {
                    continue;
                }
                let key = (deg[e.u] + deg[e.v], deg[e.u].max(deg[e.v]), e.u, e.v);
                let better = match best {
                    None => true,
                    Some((_, k)) => key < k,
                };
                if better {
                    best = Some((ei, key));
                }
            }
            let Some((ei, _)) = best else { break };
            let e = edges[ei];
            uf.union(e.u, e.v);
            deg[e.u] += 1;
            deg[e.v] += 1;
            picked.push(ei);
            if picked.len() + 1 == n {
                break;
            }
        }
        i = j;
    }
    (picked.len() + 1 == n.max(1)).then_some(picked)
}

/// Materialize a picked edge set as a tree and mark its edges used.
fn commit(n: usize, edges: &[Edge], used: &mut [bool], picked: &[usize]) -> Graph {
    let mut t = Graph::new(n);
    for &i in picked {
        used[i] = true;
        let e = edges[i];
        t.add_edge(e.u, e.v, e.weight);
    }
    t
}

/// True iff no edge (as an unordered endpoint pair) appears in more than
/// one of `trees`. Shared by unit tests and the proptest suite.
pub fn pairwise_edge_disjoint(trees: &[Graph]) -> bool {
    let mut seen = std::collections::HashSet::new();
    trees.iter().flat_map(|t| t.edges()).all(|e| seen.insert((e.u, e.v)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology::{complete, ring};
    use crate::mst::{is_spanning_tree_of, kruskal};

    #[test]
    fn first_tree_is_the_kruskal_mst() {
        let g = crate::mst::tests::diamond();
        let trees = disjoint_spanning_trees(&g, 1).unwrap();
        assert_eq!(trees.len(), 1);
        let mst = kruskal(&g).unwrap();
        assert_eq!(trees[0].sorted_edges(), mst.sorted_edges());
    }

    #[test]
    fn complete_six_decomposes_into_three_trees() {
        // K6 has 15 edges = 3 spanning trees × 5 edges; the degree-aware
        // greedy achieves the perfect decomposition.
        let g = complete(6);
        let trees = disjoint_spanning_trees(&g, 5).unwrap();
        assert_eq!(trees.len(), 3);
        assert!(pairwise_edge_disjoint(&trees));
        for t in &trees {
            assert!(is_spanning_tree_of(t, &g));
        }
    }

    #[test]
    fn complete_graph_yields_several_disjoint_trees() {
        let g = complete(10);
        let trees = disjoint_spanning_trees(&g, 8).unwrap();
        // K10 admits 5 in theory (45 edges / 9); greedy extraction is not
        // a perfect packing, but must find several and never exceed 5.
        assert!(
            (3..=5).contains(&trees.len()),
            "expected 3..=5 disjoint trees on K10, got {}",
            trees.len()
        );
        assert!(pairwise_edge_disjoint(&trees));
        for t in &trees {
            assert!(is_spanning_tree_of(t, &g));
            let max_deg = (0..10).map(|u| t.degree(u)).max().unwrap();
            assert!(max_deg <= 4, "degree-aware greedy built a hub (max degree {max_deg})");
        }
    }

    #[test]
    fn ring_falls_back_to_one_tree() {
        let g = ring(8);
        let trees = disjoint_spanning_trees(&g, 3).unwrap();
        assert_eq!(trees.len(), 1);
        assert!(is_spanning_tree_of(&trees[0], &g));
    }

    #[test]
    fn disconnected_graph_errors() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 1.0);
        assert_eq!(disjoint_spanning_trees(&g, 2).unwrap_err(), MstError::Disconnected);
    }

    #[test]
    fn empty_graph_errors() {
        assert_eq!(disjoint_spanning_trees(&Graph::new(0), 2).unwrap_err(), MstError::Empty);
    }

    #[test]
    fn single_node_returns_one_trivial_tree() {
        let trees = disjoint_spanning_trees(&Graph::new(1), 4).unwrap();
        assert_eq!(trees.len(), 1);
        assert_eq!(trees[0].edge_count(), 0);
    }

    #[test]
    fn degree_bound_is_respected_on_complete_graph() {
        let g = complete(9);
        let trees = degree_bounded_disjoint_trees(&g, 3, 3).unwrap();
        assert_eq!(trees.len(), 3);
        assert!(pairwise_edge_disjoint(&trees));
        for t in &trees {
            assert!(is_spanning_tree_of(t, &g));
            for u in 0..9 {
                assert!(t.degree(u) <= 3, "degree({u}) = {} in bounded tree", t.degree(u));
            }
        }
    }

    #[test]
    fn degree_bound_falls_back_to_unbounded_on_a_star() {
        // A star's only spanning tree has a degree-(n-1) hub; the bounded
        // pass cannot span, so the unbounded retry must kick in.
        let g = crate::graph::topology::star(6);
        let trees = degree_bounded_disjoint_trees(&g, 2, 2).unwrap();
        assert_eq!(trees.len(), 1);
        assert!(is_spanning_tree_of(&trees[0], &g));
    }

    #[test]
    fn extra_trees_avoid_the_base_tree_edges() {
        // complete overlay where the chain 0-1-…-7 is strictly cheapest,
        // so the base MST is that chain for any MST algorithm
        let mut g = Graph::new(8);
        for u in 0..8 {
            for v in (u + 1)..8 {
                let w = if v == u + 1 { 1.0 } else { 2.0 };
                g.add_edge(u, v, w);
            }
        }
        let base = kruskal(&g).unwrap();
        assert_eq!(base.edge_count(), 7);
        let extra = extra_disjoint_trees(&g, &base, 2);
        assert_eq!(extra.len(), 2);
        let mut all = vec![base];
        all.extend(extra);
        assert!(pairwise_edge_disjoint(&all));
        for t in &all {
            assert!(is_spanning_tree_of(t, &g));
        }
    }

    #[test]
    fn extra_trees_empty_when_residual_disconnects() {
        let g = ring(6);
        let base = kruskal(&g).unwrap();
        assert!(extra_disjoint_trees(&g, &base, 2).is_empty());
    }

    #[test]
    fn deterministic_across_calls() {
        let g = complete(12);
        let a = disjoint_spanning_trees(&g, 4).unwrap();
        let b = disjoint_spanning_trees(&g, 4).unwrap();
        assert_eq!(a.len(), b.len());
        for (ta, tb) in a.iter().zip(&b) {
            assert_eq!(ta.sorted_edges(), tb.sorted_edges());
        }
    }
}
