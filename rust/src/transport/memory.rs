//! In-memory transport mesh: every node an mpsc receiver, senders cloned
//! across the mesh. Deterministic, instant — used by protocol unit tests
//! and as the reference behavior for the TCP mesh.

use super::{Message, Transport};
use anyhow::{Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

/// One endpoint of the in-memory mesh.
pub struct MemoryEndpoint {
    node: usize,
    n: usize,
    tx: Vec<Sender<(usize, Message)>>,
    rx: Receiver<(usize, Message)>,
}

/// Build an n-node fully connected in-memory mesh.
pub fn mesh(n: usize) -> Vec<MemoryEndpoint> {
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(node, rx)| MemoryEndpoint { node, n, tx: txs.clone(), rx })
        .collect()
}

impl Transport for MemoryEndpoint {
    fn node(&self) -> usize {
        self.node
    }

    fn len(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, msg: Message) -> Result<()> {
        anyhow::ensure!(to < self.n && to != self.node, "bad recipient {to}");
        self.tx[to]
            .send((self.node, msg))
            .ok()
            .context("peer endpoint dropped")
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<(usize, Message)>> {
        match self.rx.recv_timeout(timeout) {
            Ok(pair) => Ok(Some(pair)),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(e) => anyhow::bail!("mesh disconnected: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery() {
        let mut eps = mesh(3);
        let mut b = eps.remove(1);
        let mut a = eps.remove(0);
        a.send(1, Message::Vote { candidate: 2 }).unwrap();
        let (from, msg) = b.recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
        assert_eq!(from, 0);
        assert_eq!(msg, Message::Vote { candidate: 2 });
    }

    #[test]
    fn broadcast_reaches_everyone() {
        let mut eps = mesh(4);
        let mut rest: Vec<_> = eps.drain(1..).collect();
        eps[0].broadcast(Message::ModeratorIs { node: 0 }).unwrap();
        for ep in rest.iter_mut() {
            let got = ep.recv_timeout(Duration::from_millis(100)).unwrap();
            assert_eq!(got.unwrap().1, Message::ModeratorIs { node: 0 });
        }
    }

    #[test]
    fn recv_timeout_returns_none() {
        let mut eps = mesh(2);
        let got = eps[0].recv_timeout(Duration::from_millis(10)).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn send_to_self_rejected() {
        let mut eps = mesh(2);
        assert!(eps[0].send(0, Message::Shutdown).is_err());
    }

    #[test]
    fn fifo_order_per_sender() {
        let mut eps = mesh(2);
        let mut b = eps.remove(1);
        let mut a = eps.remove(0);
        for i in 0..10 {
            a.send(1, Message::Vote { candidate: i }).unwrap();
        }
        for i in 0..10 {
            let (_, msg) = b.recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
            assert_eq!(msg, Message::Vote { candidate: i });
        }
    }
}
