//! Experiment configuration: a TOML-subset parser (`toml` / `serde` are
//! unavailable offline) plus the typed [`ExperimentConfig`] consumed by the
//! CLI, benches and examples.

pub mod parser;

pub use parser::{ParseError, TomlValue, parse_toml};

use crate::coloring::ColoringAlgorithm;
use crate::dfl::adversary::{AdversaryConfig, AdversaryKind};
use crate::dfl::compress::{CompressionConfig, CompressionKind};
use crate::dfl::data::AlgoKind;
use crate::dfl::robust::{FoldKind, FoldPolicy};
use crate::dfl::transfer::TransferPlan;
use crate::graph::generators::GeneratorKind;
use crate::graph::topology::{TopologyKind, TopologyParams};
use crate::mst::MstAlgorithm;

/// Full experiment configuration with paper-faithful defaults
/// (N=10 nodes, 3 subnets, Prim + BFS, §IV hardware model).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Number of learning nodes (paper: 10).
    pub nodes: usize,
    /// Number of router subnets (paper: 3).
    pub subnets: usize,
    /// Topology family for the underlay.
    pub topology: TopologyKind,
    pub topology_params: TopologyParams,
    /// Which overlay generator builds the session structure: `Flat` (the
    /// default) uses the `topology` family; `hierarchy` selects the
    /// router-hierarchy scale-out generator (`subnets` groups joined by
    /// `gateway_links` backbone links per subnet); `geometric` the random
    /// geometric graph (`geo_radius`). CLI: `--topology-gen`.
    pub topology_gen: GeneratorKind,
    /// Backbone links each subnet's gateway maintains under the
    /// router-hierarchy generator (1 = gateway ring). CLI:
    /// `--gateway-links`.
    pub gateway_links: usize,
    /// MST algorithm (paper selects Prim).
    pub mst: MstAlgorithm,
    /// Coloring algorithm (paper selects BFS).
    pub coloring: ColoringAlgorithm,
    /// RNG seed for topology + netsim jitter.
    pub seed: u64,
    /// Link rate within a subnet, MB/s (device <-> its router).
    pub local_link_mbps: f64,
    /// Router <-> router backbone rate, MB/s.
    pub backbone_mbps: f64,
    /// One-way device->router latency, ms.
    pub local_latency_ms: f64,
    /// One-way router->router latency, ms.
    pub backbone_latency_ms: f64,
    /// Relative latency jitter (fraction of base, uniform).
    pub latency_jitter: f64,
    /// Ping probe payload size in bytes (paper's ping_size).
    pub ping_size_bytes: u64,
    /// Number of measurement repetitions to average over.
    pub repeats: usize,
    /// Per-transfer protocol overhead fraction (FTP/TCP headers, acks).
    pub protocol_overhead: f64,
    /// Segments each model copy is sliced into (1 = whole-model
    /// transfers, the legacy engine; ≥ 2 enables cut-through
    /// forwarding). CLI: `--segments`.
    pub segments: usize,
    /// Target segment size in MB (0 = disabled); when set, the segment
    /// count is derived per model as `ceil(model_mb / segment_mb)`.
    /// Mutually exclusive with `segments > 1`. CLI: `--segment-mb`.
    pub segment_mb: f64,
    /// Payload compression codec for gossiped checkpoints (`none` = the
    /// legacy full-width fp32 wire format, bit-identical to the
    /// uncompressed engine; `quant` = uniform k-bit quantization; `topk`
    /// = top-k sparsification). Compressed payloads shrink every flow the
    /// drivers launch and the §III-C slot budget with them; the DFL loop
    /// applies the codec with per-node error feedback at snapshot time.
    /// CLI: `--compress`.
    pub compress: CompressionKind,
    /// Quantization width in bits (1..=16) for `compress = quant`.
    /// CLI: `--quant-bits`.
    pub quant_bits: u32,
    /// Fraction of entries kept in (0, 1] for `compress = topk`.
    /// CLI: `--topk-frac`.
    pub topk_frac: f64,
    /// Link-quality drift amplitude in [0, 1) (0 = static links, the
    /// legacy behavior). Every `drift_interval_s` of simulated time each
    /// channel draws a factor `q ∈ [1 − drift, 1 + drift]` and runs at
    /// `capacity · q` with latency `latency / q`. CLI: `--drift`.
    pub drift: f64,
    /// Simulated seconds between drift re-draws. CLI: `--drift-interval-s`.
    pub drift_interval_s: f64,
    /// Spanning-tree lanes for multi-tree dissemination (1 = the paper's
    /// single-MST planner, bit-identical to the legacy engine; k >= 2
    /// asks the moderator to carve up to k-1 extra edge-disjoint trees
    /// and stripe each model copy across the forest). Dense underlays
    /// may yield fewer disjoint trees than requested — the planner keeps
    /// whatever it finds. CLI: `--trees`.
    pub trees: usize,
    /// Rounds between moderator ping sweeps in adaptive runs (0 = no
    /// online probing / re-planning). CLI: `--probe-every`.
    pub probe_every: u64,
    /// Relative smoothed-ping deviation from the planning baseline that
    /// triggers a mid-session replan (0 = replan after every sweep).
    /// CLI: `--replan-threshold`.
    pub replan_threshold: f64,
    /// Byzantine node model for the robustness plane (`none` = every
    /// node honest, bit-identical to the legacy engine; `scaled-poison`,
    /// `random-poison`, `sybil`, `dropping-relay` compromise
    /// `adversary_frac` of the nodes). CLI: `--adversary`.
    pub adversary: AdversaryKind,
    /// Fraction of nodes marked Byzantine in (0, 1) (at least one node
    /// when an attack is active). CLI: `--adversary-frac`.
    pub adversary_frac: f64,
    /// Poison multiplier for scaled-poison / sybil payloads; its
    /// magnitude is the random-poison noise amplitude. CLI:
    /// `--poison-scale`.
    pub poison_scale: f64,
    /// Fraction of a dropping relay's tree edges it junks, in (0, 1].
    /// CLI: `--drop-edge-frac`.
    pub drop_edge_frac: f64,
    /// Aggregation rule for the FedAvg fold (`mean` = the legacy
    /// pairwise running average, bit-identical; `trimmed-mean`,
    /// `median`, `krum` are the robust policies). CLI: `--fold`.
    pub fold: FoldKind,
    /// Byzantine tolerance `f` the robust folds assume (0 = auto:
    /// the scenario's actual compromised count, or `max(1, n/5)` blind).
    /// CLI: `--fold-f`.
    pub fold_f: usize,
    /// Dirichlet concentration for non-IID data sharding (`∞` = the
    /// legacy deterministic one-stride-class-per-node task, bit-identical;
    /// finite α draws each node's class mixture from Dirichlet(α) —
    /// small α ⇒ near-one-hot shards, large α ⇒ near-uniform). Seeded by
    /// `seed`, so shards replay per run. CLI: `--dirichlet-alpha`
    /// (accepts `inf`).
    pub dirichlet_alpha: f64,
    /// Fraction of nodes that train and originate payloads each round,
    /// in (0, 1] (1 = every node, the legacy engine bit for bit; below 1
    /// a seeded per-round subset of `ceil(p·n)` nodes originates while
    /// the rest only relay on the tree). CLI: `--participation`.
    pub participation: f64,
    /// Fraction of nodes marked as compute stragglers in [0, 1] (0 = no
    /// stragglers, bit-identical). CLI: `--straggler-frac`.
    pub straggler_frac: f64,
    /// Compute slowdown of a straggler relative to the baseline, ≥ 1: a
    /// straggler skips `ceil(slowdown − 1)` of its transmit opportunities
    /// at every round start (local training still running), entering the
    /// slot schedule late. Dormant while `straggler_frac = 0`. CLI:
    /// `--straggler-slowdown`.
    pub straggler_slowdown: f64,
    /// DFL aggregation algorithm (`fedavg` = full-dissemination FedAvg,
    /// the legacy fold; `dpsgd` = D-PSGD-style Metropolis neighbor
    /// mixing over the gossip tree — requires `fold = mean`). CLI:
    /// `--algo`.
    pub algo: AlgoKind,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        // Link rates are calibrated in `netsim::testbed` so that flooding
        // broadcast reproduces the paper's Table III broadcast column
        // (≈1.8 MB/s for v3s falling to ≈0.77 MB/s for b3) on the complete
        // topology; see EXPERIMENTS.md §Calibration.
        ExperimentConfig {
            nodes: 10,
            subnets: 3,
            topology: TopologyKind::Complete,
            topology_params: TopologyParams::default(),
            topology_gen: GeneratorKind::Flat,
            gateway_links: 2,
            mst: MstAlgorithm::Prim,
            coloring: ColoringAlgorithm::Bfs,
            seed: 2025,
            local_link_mbps: 22.0,
            backbone_mbps: 22.0,
            local_latency_ms: 0.4,
            backbone_latency_ms: 12.0,
            latency_jitter: 0.08,
            ping_size_bytes: 56,
            repeats: 5,
            protocol_overhead: 0.04,
            segments: 1,
            segment_mb: 0.0,
            compress: CompressionKind::None,
            quant_bits: 8,
            topk_frac: 0.1,
            drift: 0.0,
            drift_interval_s: 20.0,
            trees: 1,
            probe_every: 0,
            replan_threshold: 0.25,
            adversary: AdversaryKind::None,
            adversary_frac: 0.2,
            poison_scale: -10.0,
            drop_edge_frac: 1.0,
            fold: FoldKind::Mean,
            fold_f: 0,
            dirichlet_alpha: f64::INFINITY,
            participation: 1.0,
            straggler_frac: 0.0,
            straggler_slowdown: 4.0,
            algo: AlgoKind::FedAvg,
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file. Unknown keys are rejected so typos in
    /// experiment configs fail loudly.
    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Io(path.to_string(), e.to_string()))?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<Self, ConfigError> {
        let table = parse_toml(text)?;
        let mut cfg = ExperimentConfig::default();
        for (key, value) in table.iter() {
            cfg.apply(key, value)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply(&mut self, key: &str, value: &TomlValue) -> Result<(), ConfigError> {
        let bad = |exp: &str| ConfigError::Type(key.to_string(), exp.to_string());
        match key {
            "nodes" => self.nodes = value.as_int().ok_or_else(|| bad("integer"))? as usize,
            "subnets" => self.subnets = value.as_int().ok_or_else(|| bad("integer"))? as usize,
            "seed" => self.seed = value.as_int().ok_or_else(|| bad("integer"))? as u64,
            "repeats" => self.repeats = value.as_int().ok_or_else(|| bad("integer"))? as usize,
            "topology" => {
                let s = value.as_str().ok_or_else(|| bad("string"))?;
                self.topology = TopologyKind::parse(s)
                    .ok_or_else(|| ConfigError::Value(key.into(), s.to_string()))?;
            }
            "topology_gen" => {
                let s = value.as_str().ok_or_else(|| bad("string"))?;
                self.topology_gen = GeneratorKind::parse(s)
                    .ok_or_else(|| ConfigError::Value(key.into(), s.to_string()))?;
            }
            "gateway_links" => {
                self.gateway_links = value.as_int().ok_or_else(|| bad("integer"))? as usize
            }
            "geo_radius" => {
                self.topology_params.geo_radius = value.as_float().ok_or_else(|| bad("float"))?
            }
            "mst" => {
                let s = value.as_str().ok_or_else(|| bad("string"))?;
                self.mst = MstAlgorithm::parse(s)
                    .ok_or_else(|| ConfigError::Value(key.into(), s.to_string()))?;
            }
            "coloring" => {
                let s = value.as_str().ok_or_else(|| bad("string"))?;
                self.coloring = ColoringAlgorithm::parse(s)
                    .ok_or_else(|| ConfigError::Value(key.into(), s.to_string()))?;
            }
            "er_p" => self.topology_params.er_p = value.as_float().ok_or_else(|| bad("float"))?,
            "ws_k" => {
                self.topology_params.ws_k = value.as_int().ok_or_else(|| bad("integer"))? as usize
            }
            "ws_beta" => {
                self.topology_params.ws_beta = value.as_float().ok_or_else(|| bad("float"))?
            }
            "ba_m" => {
                self.topology_params.ba_m = value.as_int().ok_or_else(|| bad("integer"))? as usize
            }
            "local_link_mbps" => {
                self.local_link_mbps = value.as_float().ok_or_else(|| bad("float"))?
            }
            "backbone_mbps" => self.backbone_mbps = value.as_float().ok_or_else(|| bad("float"))?,
            "local_latency_ms" => {
                self.local_latency_ms = value.as_float().ok_or_else(|| bad("float"))?
            }
            "backbone_latency_ms" => {
                self.backbone_latency_ms = value.as_float().ok_or_else(|| bad("float"))?
            }
            "latency_jitter" => {
                self.latency_jitter = value.as_float().ok_or_else(|| bad("float"))?
            }
            "ping_size_bytes" => {
                self.ping_size_bytes = value.as_int().ok_or_else(|| bad("integer"))? as u64
            }
            "protocol_overhead" => {
                self.protocol_overhead = value.as_float().ok_or_else(|| bad("float"))?
            }
            "segments" => self.segments = value.as_int().ok_or_else(|| bad("integer"))? as usize,
            "segment_mb" => self.segment_mb = value.as_float().ok_or_else(|| bad("float"))?,
            "compress" => {
                let s = value.as_str().ok_or_else(|| bad("string"))?;
                self.compress = CompressionKind::parse(s)
                    .ok_or_else(|| ConfigError::Value(key.into(), s.to_string()))?;
            }
            "quant_bits" => self.quant_bits = value.as_int().ok_or_else(|| bad("integer"))? as u32,
            "topk_frac" => self.topk_frac = value.as_float().ok_or_else(|| bad("float"))?,
            "drift" => self.drift = value.as_float().ok_or_else(|| bad("float"))?,
            "drift_interval_s" => {
                self.drift_interval_s = value.as_float().ok_or_else(|| bad("float"))?
            }
            "trees" => self.trees = value.as_int().ok_or_else(|| bad("integer"))? as usize,
            "probe_every" => {
                self.probe_every = value.as_int().ok_or_else(|| bad("integer"))? as u64
            }
            "replan_threshold" => {
                self.replan_threshold = value.as_float().ok_or_else(|| bad("float"))?
            }
            "adversary" => {
                let s = value.as_str().ok_or_else(|| bad("string"))?;
                self.adversary = AdversaryKind::parse(s)
                    .ok_or_else(|| ConfigError::Value(key.into(), s.to_string()))?;
            }
            "adversary_frac" => {
                self.adversary_frac = value.as_float().ok_or_else(|| bad("float"))?
            }
            "poison_scale" => self.poison_scale = value.as_float().ok_or_else(|| bad("float"))?,
            "drop_edge_frac" => {
                self.drop_edge_frac = value.as_float().ok_or_else(|| bad("float"))?
            }
            "fold" => {
                let s = value.as_str().ok_or_else(|| bad("string"))?;
                self.fold = FoldKind::parse(s)
                    .ok_or_else(|| ConfigError::Value(key.into(), s.to_string()))?;
            }
            "fold_f" => self.fold_f = value.as_int().ok_or_else(|| bad("integer"))? as usize,
            "dirichlet_alpha" => {
                self.dirichlet_alpha = value.as_float().ok_or_else(|| bad("float"))?
            }
            "participation" => self.participation = value.as_float().ok_or_else(|| bad("float"))?,
            "straggler_frac" => {
                self.straggler_frac = value.as_float().ok_or_else(|| bad("float"))?
            }
            "straggler_slowdown" => {
                self.straggler_slowdown = value.as_float().ok_or_else(|| bad("float"))?
            }
            "algo" => {
                let s = value.as_str().ok_or_else(|| bad("string"))?;
                self.algo = AlgoKind::parse(s)
                    .ok_or_else(|| ConfigError::Value(key.into(), s.to_string()))?;
            }
            other => return Err(ConfigError::UnknownKey(other.to_string())),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let reject = |key: &str, why: &str| Err(ConfigError::Value(key.into(), why.into()));
        if self.nodes < 2 {
            return reject("nodes", "need >= 2");
        }
        // node ids live in a 16-bit flow-tag field (broadcast::flow_tag_segment)
        if self.nodes > u16::MAX as usize {
            return reject("nodes", "need <= 65535");
        }
        if self.subnets == 0 || self.subnets > self.nodes {
            return reject("subnets", "need 1 <= subnets <= nodes");
        }
        if self.local_link_mbps <= 0.0 || self.backbone_mbps <= 0.0 {
            return reject("link rates", "must be positive");
        }
        if !(0.0..1.0).contains(&self.latency_jitter) {
            return reject("latency_jitter", "must be in [0,1)");
        }
        if !(0.0..1.0).contains(&self.protocol_overhead) {
            return reject("protocol_overhead", "must be in [0,1)");
        }
        if self.ping_size_bytes == 0 {
            return reject("ping_size_bytes", "must be positive");
        }
        if self.repeats == 0 {
            return reject("repeats", "must be positive");
        }
        if self.segments == 0 || self.segments > u16::MAX as usize {
            return reject("segments", "need 1 <= segments <= 65535");
        }
        if self.segment_mb < 0.0 {
            return reject("segment_mb", "must be >= 0 (0 disables)");
        }
        // floor keeps the derived per-model segment count inside the u16
        // wire field for checkpoints up to ~655 MB; beyond that the plan
        // saturates at u16::MAX segments (TransferPlan::by_segment_mb)
        if self.segment_mb > 0.0 && self.segment_mb < 0.01 {
            return reject("segment_mb", "must be >= 0.01 MB (or 0 to disable)");
        }
        if self.segments > 1 && self.segment_mb > 0.0 {
            return reject("segment_mb", "set either segments or segment_mb, not both");
        }
        // compression knobs stay valid even while dormant (compress=none),
        // so flipping the codec on never trips a latent bad value; the
        // ranges live in CompressionConfig::validate (single source of
        // truth with the codec's own asserts)
        if let Err(why) = self.compression().validate() {
            return Err(ConfigError::Value("compress".into(), why));
        }
        if !(0.0..1.0).contains(&self.drift) {
            return reject("drift", "must be in [0,1)");
        }
        if self.drift_interval_s <= 0.0 {
            return reject("drift_interval_s", "must be positive");
        }
        if self.replan_threshold < 0.0 || !self.replan_threshold.is_finite() {
            return reject("replan_threshold", "must be a finite value >= 0");
        }
        // upper bound also catches negative TOML values wrapped by the
        // i64 -> usize cast (same trick the nodes/subnets checks use)
        if self.gateway_links == 0 || self.gateway_links > self.nodes {
            return reject("gateway_links", "need 1 <= gateway_links <= nodes");
        }
        let r = self.topology_params.geo_radius;
        if !(r > 0.0 && r.is_finite()) {
            return reject("geo_radius", "must be a finite value > 0");
        }
        // upper bound doubles as the negative-wrap guard (a spanning
        // forest of an n-node graph can never hold n disjoint trees)
        if self.trees == 0 || self.trees >= self.nodes {
            return reject("trees", "need 1 <= trees < nodes");
        }
        // adversary knobs stay valid even while dormant (adversary =
        // none), same contract as the compression plane; ranges live in
        // AdversaryConfig::validate
        if let Err(why) = self.adversary_config().validate() {
            return Err(ConfigError::Value("adversary".into(), why));
        }
        // upper bound doubles as the negative-wrap guard: a fold cannot
        // assume every node (or more) is Byzantine
        if self.fold_f >= self.nodes {
            return reject("fold_f", "need 0 <= fold_f < nodes (0 = auto)");
        }
        if let Err(why) = self.fold_policy(1).validate() {
            return Err(ConfigError::Value("fold".into(), why));
        }
        // scenario-zoo knobs stay valid even while dormant (same contract
        // as the compression/adversary planes)
        if self.dirichlet_alpha.is_nan() || self.dirichlet_alpha <= 0.0 {
            return reject("dirichlet_alpha", "must be > 0 (inf = legacy per-node class shards)");
        }
        if self.participation.is_nan() || self.participation <= 0.0 || self.participation > 1.0 {
            return reject("participation", "must be in (0, 1]");
        }
        if !(0.0..=1.0).contains(&self.straggler_frac) {
            return reject("straggler_frac", "must be in [0, 1]");
        }
        if self.straggler_slowdown < 1.0 || !self.straggler_slowdown.is_finite() {
            return reject("straggler_slowdown", "must be a finite value >= 1");
        }
        // D-PSGD replaces the fold entirely with neighbor mixing; a
        // robust fold selection would be silently ignored — reject it
        if self.algo == AlgoKind::DPsgd && self.fold != FoldKind::Mean {
            return reject("algo", "dpsgd requires fold = mean (mixing replaces the fold)");
        }
        Ok(())
    }

    /// The configured payload codec (knobs included).
    pub fn compression(&self) -> CompressionConfig {
        CompressionConfig {
            kind: self.compress,
            quant_bits: self.quant_bits,
            topk_frac: self.topk_frac,
        }
    }

    /// The configured Byzantine attack (knobs included).
    pub fn adversary_config(&self) -> AdversaryConfig {
        AdversaryConfig {
            kind: self.adversary,
            frac: self.adversary_frac,
            poison_scale: self.poison_scale as f32,
            drop_edge_frac: self.drop_edge_frac,
        }
    }

    /// The configured fold policy; `auto_f` substitutes for `fold_f = 0`
    /// (sessions pass the scenario's actual Byzantine count).
    pub fn fold_policy(&self, auto_f: usize) -> FoldPolicy {
        let f = if self.fold_f == 0 { auto_f } else { self.fold_f };
        FoldPolicy { kind: self.fold, f }
    }

    /// The transfer plan this config prescribes for a `model_mb`-sized
    /// checkpoint: `segment_mb` (per-model segment count, derived from
    /// the logical size) wins when set, then the fixed `segments` count;
    /// the default is the whole-model legacy plan. The configured
    /// compression codec then sets the plan's wire size (`compress =
    /// none` keeps wire == logical, bit for bit).
    pub fn transfer_plan(&self, model_mb: f64) -> TransferPlan {
        let plan = if self.segment_mb > 0.0 {
            TransferPlan::by_segment_mb(model_mb, self.segment_mb)
        } else {
            TransferPlan::segmented(model_mb, self.segments)
        };
        plan.with_compression(&self.compression())
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("cannot read {0}: {1}")]
    Io(String, String),
    #[error("parse error: {0}")]
    Parse(#[from] ParseError),
    #[error("unknown config key {0:?}")]
    UnknownKey(String),
    #[error("key {0:?}: expected {1}")]
    Type(String, String),
    #[error("key {0:?}: invalid value {1:?}")]
    Value(String, String),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_setup() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.nodes, 10);
        assert_eq!(cfg.subnets, 3);
        assert_eq!(cfg.mst, MstAlgorithm::Prim);
        assert_eq!(cfg.coloring, ColoringAlgorithm::Bfs);
        cfg.validate().unwrap();
    }

    #[test]
    fn full_toml_roundtrip() {
        let text = r#"
# experiment: watts-strogatz sweep
nodes = 20
subnets = 4
topology = "ws"
ws_k = 6
ws_beta = 0.25
mst = "kruskal"
coloring = "dsatur"
seed = 7
local_link_mbps = 50.0
backbone_latency_ms = 8.5
"#;
        let cfg = ExperimentConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.nodes, 20);
        assert_eq!(cfg.subnets, 4);
        assert_eq!(cfg.topology, TopologyKind::WattsStrogatz);
        assert_eq!(cfg.topology_params.ws_k, 6);
        assert_eq!(cfg.topology_params.ws_beta, 0.25);
        assert_eq!(cfg.mst, MstAlgorithm::Kruskal);
        assert_eq!(cfg.coloring, ColoringAlgorithm::DSatur);
        assert_eq!(cfg.local_link_mbps, 50.0);
        assert_eq!(cfg.backbone_latency_ms, 8.5);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = ExperimentConfig::from_toml_str("bogus = 3").unwrap_err();
        assert!(matches!(err, ConfigError::UnknownKey(k) if k == "bogus"));
    }

    #[test]
    fn wrong_type_rejected() {
        let err = ExperimentConfig::from_toml_str("nodes = \"ten\"").unwrap_err();
        assert!(matches!(err, ConfigError::Type(..)));
    }

    #[test]
    fn invalid_topology_value_rejected() {
        let err = ExperimentConfig::from_toml_str("topology = \"torus\"").unwrap_err();
        assert!(matches!(err, ConfigError::Value(..)));
    }

    #[test]
    fn semantic_validation_fires() {
        assert!(ExperimentConfig::from_toml_str("nodes = 1").is_err());
        assert!(ExperimentConfig::from_toml_str("nodes = 70000").is_err(), "16-bit tag field");
        assert!(ExperimentConfig::from_toml_str("subnets = 99").is_err());
        assert!(ExperimentConfig::from_toml_str("latency_jitter = 1.5").is_err());
    }

    #[test]
    fn int_accepted_for_float_keys() {
        let cfg = ExperimentConfig::from_toml_str("local_link_mbps = 100").unwrap();
        assert_eq!(cfg.local_link_mbps, 100.0);
    }

    #[test]
    fn segment_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml_str("segments = 4").unwrap();
        assert_eq!(cfg.segments, 4);
        assert_eq!(cfg.transfer_plan(48.0).segments(), 4);

        let cfg = ExperimentConfig::from_toml_str("segment_mb = 8.0").unwrap();
        assert_eq!(cfg.transfer_plan(48.0).segments(), 6);
        assert_eq!(cfg.transfer_plan(5.0).segments(), 1);

        assert!(ExperimentConfig::from_toml_str("segments = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("segment_mb = -1.0").is_err());
        assert!(
            ExperimentConfig::from_toml_str("segment_mb = 0.0001").is_err(),
            "tiny segment_mb must fail validation, not panic in TransferPlan"
        );
        assert!(ExperimentConfig::from_toml_str("segments = 4\nsegment_mb = 8.0").is_err());
    }

    #[test]
    fn drift_and_replan_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml_str(
            "drift = 0.2\ndrift_interval_s = 5.0\nprobe_every = 2\nreplan_threshold = 0.4",
        )
        .unwrap();
        assert_eq!(cfg.drift, 0.2);
        assert_eq!(cfg.drift_interval_s, 5.0);
        assert_eq!(cfg.probe_every, 2);
        assert_eq!(cfg.replan_threshold, 0.4);
        // defaults keep the static plane
        let d = ExperimentConfig::default();
        assert_eq!(d.drift, 0.0);
        assert_eq!(d.probe_every, 0);
        assert!(ExperimentConfig::from_toml_str("drift = 1.0").is_err());
        assert!(ExperimentConfig::from_toml_str("drift = -0.1").is_err());
        assert!(ExperimentConfig::from_toml_str("drift_interval_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("replan_threshold = -1.0").is_err());
    }

    #[test]
    fn scale_out_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml_str(
            "topology_gen = \"hierarchy\"\nnodes = 64\nsubnets = 8\ngateway_links = 3",
        )
        .unwrap();
        assert_eq!(cfg.topology_gen, GeneratorKind::Hierarchy);
        assert_eq!(cfg.subnets, 8);
        assert_eq!(cfg.gateway_links, 3);

        let cfg = ExperimentConfig::from_toml_str("topology_gen = \"geometric\"\ngeo_radius = 0.25")
            .unwrap();
        assert_eq!(cfg.topology_gen, GeneratorKind::Geometric);
        assert_eq!(cfg.topology_params.geo_radius, 0.25);

        // defaults keep the flat paper grid
        let d = ExperimentConfig::default();
        assert_eq!(d.topology_gen, GeneratorKind::Flat);
        assert_eq!(d.gateway_links, 2);

        assert!(ExperimentConfig::from_toml_str("topology_gen = \"torus\"").is_err());
        assert!(ExperimentConfig::from_toml_str("gateway_links = 0").is_err());
        assert!(
            ExperimentConfig::from_toml_str("gateway_links = -2").is_err(),
            "negative values must not wrap through the usize cast"
        );
        assert!(ExperimentConfig::from_toml_str("geo_radius = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("geo_radius = -1.0").is_err());
    }

    #[test]
    fn trees_key_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml_str("trees = 3").unwrap();
        assert_eq!(cfg.trees, 3);

        // the default keeps the paper's single-MST planner
        assert_eq!(ExperimentConfig::default().trees, 1);

        assert!(ExperimentConfig::from_toml_str("trees = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("trees = 10").is_err(), "trees must be < nodes");
        assert!(
            ExperimentConfig::from_toml_str("trees = -2").is_err(),
            "negative values must not wrap through the usize cast"
        );
        let cfg = ExperimentConfig::from_toml_str("nodes = 24\ntrees = 10").unwrap();
        assert_eq!(cfg.trees, 10);
    }

    #[test]
    fn default_transfer_plan_is_whole_model() {
        let plan = ExperimentConfig::default().transfer_plan(21.6);
        assert_eq!(plan.segments(), 1);
        assert_eq!(plan.model_mb().to_bits(), 21.6f64.to_bits());
        // the default codec is none: wire size is the logical size, bit
        // for bit — the compression plane's compatibility anchor
        assert_eq!(plan.wire_mb().to_bits(), 21.6f64.to_bits());
        assert!(!plan.is_compressed());
    }

    #[test]
    fn compression_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml_str("compress = \"quant\"\nquant_bits = 4").unwrap();
        assert_eq!(cfg.compress, CompressionKind::Quant);
        assert_eq!(cfg.quant_bits, 4);
        let plan = cfg.transfer_plan(48.0);
        assert!(plan.is_compressed());
        assert!(plan.compression_ratio() > 7.0, "4-bit ≈ 8x, got {}", plan.compression_ratio());

        let cfg = ExperimentConfig::from_toml_str("compress = \"topk\"\ntopk_frac = 0.25").unwrap();
        assert_eq!(cfg.compress, CompressionKind::TopK);
        assert!((cfg.transfer_plan(48.0).compression_ratio() - 2.0).abs() < 0.05);

        // defaults keep the legacy wire format
        let d = ExperimentConfig::default();
        assert_eq!(d.compress, CompressionKind::None);
        assert_eq!(d.quant_bits, 8);
        assert_eq!(d.topk_frac, 0.1);
        assert!(d.compression().is_none());

        assert!(ExperimentConfig::from_toml_str("compress = \"gzip\"").is_err());
        assert!(ExperimentConfig::from_toml_str("quant_bits = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("quant_bits = 17").is_err());
        assert!(
            ExperimentConfig::from_toml_str("quant_bits = -3").is_err(),
            "negative values must not wrap through the u32 cast"
        );
        assert!(ExperimentConfig::from_toml_str("topk_frac = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("topk_frac = 1.5").is_err());
    }

    #[test]
    fn adversary_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml_str(
            "adversary = \"scaled-poison\"\nadversary_frac = 0.3\npoison_scale = -5.0",
        )
        .unwrap();
        assert_eq!(cfg.adversary, AdversaryKind::ScaledPoison);
        assert_eq!(cfg.adversary_frac, 0.3);
        assert_eq!(cfg.poison_scale, -5.0);
        let a = cfg.adversary_config();
        assert_eq!(a.kind, AdversaryKind::ScaledPoison);
        assert_eq!(a.poison_scale, -5.0f32);

        let cfg =
            ExperimentConfig::from_toml_str("adversary = \"drop\"\ndrop_edge_frac = 0.5").unwrap();
        assert_eq!(cfg.adversary, AdversaryKind::DroppingRelay);
        assert_eq!(cfg.drop_edge_frac, 0.5);

        // defaults keep every node honest
        let d = ExperimentConfig::default();
        assert_eq!(d.adversary, AdversaryKind::None);
        assert!(d.adversary_config().is_none());

        assert!(ExperimentConfig::from_toml_str("adversary = \"evil\"").is_err());
        // dormant knobs are still range-checked (compression-plane contract)
        assert!(ExperimentConfig::from_toml_str("adversary_frac = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("adversary_frac = 1.0").is_err());
        assert!(ExperimentConfig::from_toml_str("drop_edge_frac = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("drop_edge_frac = 1.5").is_err());
    }

    #[test]
    fn fold_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml_str("fold = \"trimmed-mean\"\nfold_f = 2").unwrap();
        assert_eq!(cfg.fold, FoldKind::TrimmedMean);
        assert_eq!(cfg.fold_f, 2);
        let p = cfg.fold_policy(3);
        assert_eq!(p.kind, FoldKind::TrimmedMean);
        assert_eq!(p.f, 2, "explicit fold_f wins over auto");
        // fold_f = 0 defers to the caller's auto value
        let cfg = ExperimentConfig::from_toml_str("fold = \"krum\"").unwrap();
        assert_eq!(cfg.fold_policy(3).f, 3);

        // the default is the legacy pairwise mean
        let d = ExperimentConfig::default();
        assert_eq!(d.fold, FoldKind::Mean);
        assert_eq!(d.fold_f, 0);
        assert!(d.fold_policy(2).is_mean());

        assert!(ExperimentConfig::from_toml_str("fold = \"average\"").is_err());
        assert!(ExperimentConfig::from_toml_str("fold_f = 10").is_err(), "fold_f must be < nodes");
        assert!(
            ExperimentConfig::from_toml_str("fold_f = -1").is_err(),
            "negative values must not wrap through the usize cast"
        );
    }

    #[test]
    fn scenario_zoo_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml_str(
            "dirichlet_alpha = 0.3\nparticipation = 0.6\nstraggler_frac = 0.2\n\
             straggler_slowdown = 3.0\nalgo = \"dpsgd\"",
        )
        .unwrap();
        assert_eq!(cfg.dirichlet_alpha, 0.3);
        assert_eq!(cfg.participation, 0.6);
        assert_eq!(cfg.straggler_frac, 0.2);
        assert_eq!(cfg.straggler_slowdown, 3.0);
        assert_eq!(cfg.algo, AlgoKind::DPsgd);

        // the f64 parser accepts the infinity sentinel spelled out
        let cfg = ExperimentConfig::from_toml_str("dirichlet_alpha = inf").unwrap();
        assert!(cfg.dirichlet_alpha.is_infinite());

        // defaults keep the legacy IID-in-lockstep learning plane
        let d = ExperimentConfig::default();
        assert!(d.dirichlet_alpha.is_infinite());
        assert_eq!(d.participation, 1.0);
        assert_eq!(d.straggler_frac, 0.0);
        assert_eq!(d.straggler_slowdown, 4.0);
        assert_eq!(d.algo, AlgoKind::FedAvg);

        assert!(ExperimentConfig::from_toml_str("dirichlet_alpha = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("dirichlet_alpha = -1.0").is_err());
        assert!(ExperimentConfig::from_toml_str("dirichlet_alpha = nan").is_err());
        assert!(ExperimentConfig::from_toml_str("participation = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("participation = 1.5").is_err());
        assert!(ExperimentConfig::from_toml_str("straggler_frac = 1.5").is_err());
        assert!(ExperimentConfig::from_toml_str("straggler_frac = -0.1").is_err());
        assert!(ExperimentConfig::from_toml_str("straggler_slowdown = 0.5").is_err());
        assert!(ExperimentConfig::from_toml_str("straggler_slowdown = inf").is_err());
        assert!(ExperimentConfig::from_toml_str("algo = \"sgd\"").is_err());
        // mixing replaces the fold — a robust fold selection is a conflict
        assert!(ExperimentConfig::from_toml_str("algo = \"dpsgd\"\nfold = \"krum\"").is_err());
        // while fedavg composes with any fold
        ExperimentConfig::from_toml_str("algo = \"fedavg\"\nfold = \"krum\"").unwrap();
    }

    #[test]
    fn compression_composes_with_segments() {
        let cfg =
            ExperimentConfig::from_toml_str("compress = \"quant\"\nquant_bits = 8\nsegments = 4")
                .unwrap();
        let plan = cfg.transfer_plan(48.0);
        assert_eq!(plan.segments(), 4);
        // each wire unit is a quarter of the *compressed* payload
        assert!((plan.segment_mb() * 4.0 - plan.wire_mb()).abs() < 1e-12);
        assert!(plan.segment_mb() < 48.0 / 4.0 / 3.5);
    }
}
