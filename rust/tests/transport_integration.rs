//! Integration tests of the live transports: the full M-step protocol
//! (ping → report → schedule) over the in-memory mesh, and a mini gossip
//! round over shaped loopback TCP.

use mosgu::coloring::ColoringAlgorithm;
use mosgu::coordinator::moderator::Moderator;
use mosgu::coordinator::queue::{GossipQueue, ModelKey};
use mosgu::graph::Graph;
use mosgu::mst::MstAlgorithm;
use mosgu::transport::{memory, tcp, Message, Transport};
use std::time::Duration;

/// Run the report->schedule phase over any transport mesh: node 0 is the
/// moderator, costs are synthetic (|u-v| based), everyone gets a schedule.
fn m_step<T: Transport + 'static>(mut eps: Vec<T>) -> Vec<Message> {
    let n = eps.len();
    let moderator_ep = eps.remove(0);
    let workers: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            std::thread::spawn(move || {
                let me = ep.node();
                let edges: Vec<(u32, f64)> = (0..ep.len())
                    .filter(|&p| p != me)
                    .map(|p| (p as u32, 1.0 + (me as f64 - p as f64).abs()))
                    .collect();
                ep.send(0, Message::Report { edges }).unwrap();
                loop {
                    match ep.recv_timeout(Duration::from_secs(10)).unwrap() {
                        Some((_, msg @ Message::Schedule { .. })) => return msg,
                        Some(_) => {}
                        None => panic!("node {me}: no schedule"),
                    }
                }
            })
        })
        .collect();

    let moderator_thread = std::thread::spawn(move || {
        let mut ep = moderator_ep;
        let mut m = Moderator::new(0, n, MstAlgorithm::Prim, ColoringAlgorithm::Bfs);
        let own: Vec<(usize, f64)> = (1..n).map(|p| (p, 1.0 + p as f64)).collect();
        m.submit_report(0, &own);
        let mut pending = n - 1;
        while pending > 0 {
            if let Some((from, Message::Report { edges })) =
                ep.recv_timeout(Duration::from_secs(10)).unwrap()
            {
                let peers: Vec<(usize, f64)> =
                    edges.iter().map(|&(p, c)| (p as usize, c)).collect();
                m.submit_report(from, &peers);
                pending -= 1;
            }
        }
        let bundle = m.compute_schedule(2.0, 56, 1).unwrap().clone();
        let msg = Message::Schedule {
            tree_edges: bundle.tree.edges().iter().map(|e| (e.u as u32, e.v as u32)).collect(),
            colors: bundle.schedule.coloring.assignment().iter().map(|&c| c as u8).collect(),
            slot_len_s: bundle.schedule.slot_len_s,
            first_color: 1,
        };
        ep.broadcast(msg.clone()).unwrap();
        msg
    });

    let mut results = vec![moderator_thread.join().unwrap()];
    for w in workers {
        results.push(w.join().unwrap());
    }
    results
}

#[test]
fn m_step_over_memory_mesh() {
    let schedules = m_step(memory::mesh(6));
    // all nodes received the identical schedule
    for s in &schedules[1..] {
        assert_eq!(s, &schedules[0]);
    }
    let Message::Schedule { tree_edges, colors, .. } = &schedules[0] else {
        panic!("not a schedule")
    };
    assert_eq!(tree_edges.len(), 5, "spanning tree of 6 nodes");
    assert_eq!(colors.len(), 6);
    // the schedule's tree must be proper under its coloring
    let mut tree = Graph::new(6);
    for &(u, v) in tree_edges {
        tree.add_edge(u as usize, v as usize, 1.0);
    }
    assert!(tree.is_tree());
    for &(u, v) in tree_edges {
        assert_ne!(colors[u as usize], colors[v as usize], "improper edge ({u},{v})");
    }
}

#[test]
fn m_step_over_tcp_mesh() {
    let schedules = m_step(tcp::mesh(4, 500.0).unwrap());
    for s in &schedules[1..] {
        assert_eq!(s, &schedules[0]);
    }
}

#[test]
fn model_payloads_survive_tcp_gossip_hop() {
    // A -> B -> C relay of a model payload with queue bookkeeping
    let mut eps = tcp::mesh(3, 200.0).unwrap();
    let c = eps.remove(2);
    let b = eps.remove(1);
    let a = eps.remove(0);

    let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
    let expected = payload.clone();

    let ta = std::thread::spawn(move || {
        let mut a = a;
        a.send(1, Message::Model { owner: 0, round: 3, payload }).unwrap();
        a // keep alive
    });
    let tb = std::thread::spawn(move || {
        let mut b = b;
        let mut q = GossipQueue::new(1);
        let (from, msg) = b.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        let Message::Model { owner, round, payload } = msg else { panic!() };
        assert!(q.receive(ModelKey::new(owner as usize, round as u64), from, true));
        let entry = q.pop_oldest().unwrap();
        assert_eq!(entry.received_from, Some(0));
        // forward to C, not back to A
        b.send(2, Message::Model { owner, round, payload }).unwrap();
        b
    });
    let tc = std::thread::spawn(move || {
        let mut c = c;
        let (from, msg) = c.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        assert_eq!(from, 1);
        let Message::Model { owner, round, payload } = msg else { panic!() };
        assert_eq!(owner, 0);
        assert_eq!(round, 3);
        payload
    });
    let got = tc.join().unwrap();
    assert_eq!(got, expected);
    ta.join().unwrap();
    tb.join().unwrap();
}

#[test]
fn memory_mesh_handles_many_messages() {
    let mut eps = memory::mesh(3);
    let mut b = eps.remove(1);
    let mut a = eps.remove(0);
    for i in 0..500u32 {
        a.send(1, Message::Vote { candidate: i }).unwrap();
    }
    for i in 0..500u32 {
        let (_, msg) = b.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(msg, Message::Vote { candidate: i });
    }
}
