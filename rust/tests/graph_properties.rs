//! Property tests over the graph substrates: MST cross-algorithm
//! agreement, coloring properness, topology-generator guarantees.

use mosgu::coloring::ColoringAlgorithm;
use mosgu::graph::topology::{generate, TopologyKind, TopologyParams};
use mosgu::graph::Graph;
use mosgu::mst::{is_spanning_tree_of, MstAlgorithm};
use mosgu::util::proptest::check;
use mosgu::util::rng::Pcg64;
use mosgu::{prop_assert, prop_assert_eq};

fn random_connected(rng: &mut Pcg64) -> Graph {
    let n = 3 + rng.gen_range(25);
    // random tree + random extra edges => connected with cycles
    let mut g = Graph::new(n);
    for v in 1..n {
        let u = rng.gen_range(v);
        g.add_edge(u, v, rng.gen_f64_range(0.5, 99.5));
    }
    let extras = rng.gen_range(2 * n);
    for _ in 0..extras {
        let u = rng.gen_range(n);
        let v = rng.gen_range(n);
        if u != v && !g.has_edge(u, v) {
            g.add_edge(u, v, rng.gen_f64_range(0.5, 99.5));
        }
    }
    g
}

#[test]
fn mst_algorithms_agree_on_total_weight() {
    check("mst agreement", 200, |rng| {
        let g = random_connected(rng);
        let wp = MstAlgorithm::Prim.run(&g).unwrap().total_weight();
        let wk = MstAlgorithm::Kruskal.run(&g).unwrap().total_weight();
        let wb = MstAlgorithm::Boruvka.run(&g).unwrap().total_weight();
        prop_assert!((wp - wk).abs() < 1e-9, "prim {wp} vs kruskal {wk}");
        prop_assert!((wk - wb).abs() < 1e-9, "kruskal {wk} vs boruvka {wb}");
        Ok(())
    });
}

#[test]
fn mst_is_valid_spanning_tree() {
    check("mst validity", 200, |rng| {
        let g = random_connected(rng);
        for alg in MstAlgorithm::ALL {
            let t = alg.run(&g).unwrap();
            prop_assert!(is_spanning_tree_of(&t, &g), "{alg:?} invalid");
            prop_assert_eq!(t.edge_count(), g.node_count() - 1);
        }
        Ok(())
    });
}

#[test]
fn mst_weight_not_above_any_spanning_subgraph_sample() {
    // cut property spot-check: removing an MST edge and reconnecting via
    // any other edge across the induced cut cannot reduce total weight
    check("mst cut property", 80, |rng| {
        let g = random_connected(rng);
        let t = MstAlgorithm::Prim.run(&g).unwrap();
        let base = t.total_weight();
        for e in t.edges() {
            // component split without edge e
            let mut cut = Graph::new(t.node_count());
            for e2 in t.edges() {
                if (e2.u, e2.v) != (e.u, e.v) {
                    cut.add_edge(e2.u, e2.v, e2.weight);
                }
            }
            let side = cut.bfs_hops(e.u);
            for cand in g.edges() {
                let crosses =
                    (side[cand.u] != usize::MAX) != (side[cand.v] != usize::MAX);
                if crosses {
                    let alt = base - e.weight + cand.weight;
                    prop_assert!(
                        alt >= base - 1e-9,
                        "swap {:?} for {:?} improves MST",
                        cand,
                        e
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn colorings_are_proper_on_random_graphs() {
    check("coloring properness", 200, |rng| {
        let g = random_connected(rng);
        for alg in ColoringAlgorithm::ALL {
            let c = alg.run(&g);
            prop_assert!(c.is_proper(&g), "{alg:?} improper");
            prop_assert_eq!(c.len(), g.node_count());
        }
        Ok(())
    });
}

#[test]
fn trees_always_get_two_colors_under_exact_algorithms() {
    // BFS and DSatur are exact on bipartite graphs (⇒ 2 colors on every
    // tree); Welsh-Powell/LDF are proper but can exceed 2 — a correction
    // to the paper's §III-C claim (EXPERIMENTS.md §Deviations).
    check("tree 2-coloring", 150, |rng| {
        let g = random_connected(rng);
        let t = MstAlgorithm::Prim.run(&g).unwrap();
        for alg in [ColoringAlgorithm::Bfs, ColoringAlgorithm::DSatur] {
            let c = alg.run(&t);
            prop_assert!(c.num_colors() <= 2, "{alg:?} used {}", c.num_colors());
            prop_assert!(c.is_proper(&t));
        }
        for alg in [ColoringAlgorithm::WelshPowell, ColoringAlgorithm::LargestDegreeFirst] {
            let c = alg.run(&t);
            prop_assert!(c.is_proper(&t), "{alg:?} improper on tree");
            // degree-greedy on trees is O(log n)-bounded; generous cap
            prop_assert!(c.num_colors() <= 8, "{alg:?} used {}", c.num_colors());
        }
        Ok(())
    });
}

#[test]
fn generators_produce_connected_graphs_of_requested_size() {
    check("topology connectivity", 80, |rng| {
        // n > ws_k (default ring degree 4) keeps Watts-Strogatz valid
        let n = 6 + rng.gen_range(40);
        let params = TopologyParams::default();
        for kind in TopologyKind::ALL {
            let g = generate(kind, n, &params, rng);
            prop_assert_eq!(g.node_count(), n);
            prop_assert!(g.is_connected(), "{kind:?} disconnected at n={n}");
        }
        Ok(())
    });
}

#[test]
fn complete_topology_has_exact_edge_count() {
    check("complete edges", 40, |rng| {
        let n = 2 + rng.gen_range(30);
        let g = generate(TopologyKind::Complete, n, &TopologyParams::default(), rng);
        prop_assert_eq!(g.edge_count(), n * (n - 1) / 2);
        Ok(())
    });
}

#[test]
fn dijkstra_triangle_inequality() {
    check("dijkstra triangle", 80, |rng| {
        let g = random_connected(rng);
        let n = g.node_count();
        let src = rng.gen_range(n);
        let d = g.dijkstra(src);
        for e in g.edges() {
            prop_assert!(
                d[e.v] <= d[e.u] + e.weight + 1e-9,
                "triangle violated at edge {:?}",
                e
            );
            prop_assert!(d[e.u] <= d[e.v] + e.weight + 1e-9);
        }
        Ok(())
    });
}
