//! A small TOML-subset parser (flat `key = value` documents with comments;
//! values: integers, floats, booleans, strings, and homogeneous arrays).
//! Built in-repo because no TOML/serde crate is available offline. The
//! subset covers everything our experiment configs need; nesting tables is
//! deliberately unsupported (configs stay flat and greppable).

use std::collections::BTreeMap;

/// Parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`x = 3` where 3.0 is meant).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ParseError {
    #[error("line {0}: missing '=' separator")]
    MissingEquals(usize),
    #[error("line {0}: empty key")]
    EmptyKey(usize),
    #[error("line {0}: duplicate key {1:?}")]
    DuplicateKey(usize, String),
    #[error("line {0}: cannot parse value {1:?}")]
    BadValue(usize, String),
    #[error("line {0}: unterminated string")]
    UnterminatedString(usize),
    #[error("line {0}: table headers are not supported in this subset")]
    TableUnsupported(usize),
}

/// Parse a flat TOML document into an ordered key→value map.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>, ParseError> {
    let mut out = BTreeMap::new();
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            return Err(ParseError::TableUnsupported(lineno));
        }
        let eq = line.find('=').ok_or(ParseError::MissingEquals(lineno))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ParseError::EmptyKey(lineno));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        if out.insert(key.to_string(), value).is_some() {
            return Err(ParseError::DuplicateKey(lineno, key.to_string()));
        }
    }
    Ok(out)
}

/// Strip a `#` comment, respecting `"..."` string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (idx, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..idx],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue, ParseError> {
    let s = s.trim();
    if s.is_empty() {
        return Err(ParseError::BadValue(lineno, s.to_string()));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or(ParseError::UnterminatedString(lineno))?;
        if !rest[end + 1..].trim().is_empty() {
            return Err(ParseError::BadValue(lineno, s.to_string()));
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(ParseError::BadValue(lineno, s.to_string()));
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part, lineno)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    // ints before floats so "42" stays an Int
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(ParseError::BadValue(lineno, s.to_string()))
}

/// Split on commas that are outside string literals (arrays of strings).
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut current = String::new();
    let mut in_str = false;
    for ch in s.chars() {
        match ch {
            '"' => {
                in_str = !in_str;
                current.push(ch);
            }
            ',' if !in_str => {
                parts.push(std::mem::take(&mut current));
            }
            _ => current.push(ch),
        }
    }
    parts.push(current);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let t = parse_toml("a = 1\nb = 2.5\nc = true\nd = \"hi\"").unwrap();
        assert_eq!(t["a"], TomlValue::Int(1));
        assert_eq!(t["b"], TomlValue::Float(2.5));
        assert_eq!(t["c"], TomlValue::Bool(true));
        assert_eq!(t["d"], TomlValue::Str("hi".into()));
    }

    #[test]
    fn comments_and_blanks() {
        let t = parse_toml("# header\n\na = 1   # trailing\n").unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t["a"], TomlValue::Int(1));
    }

    #[test]
    fn hash_inside_string_not_a_comment() {
        let t = parse_toml("s = \"a#b\"").unwrap();
        assert_eq!(t["s"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn negative_and_underscored_numbers() {
        let t = parse_toml("a = -3\nb = 1_000\nc = -2.5e2").unwrap();
        assert_eq!(t["a"], TomlValue::Int(-3));
        assert_eq!(t["b"], TomlValue::Int(1000));
        assert_eq!(t["c"], TomlValue::Float(-250.0));
    }

    #[test]
    fn arrays() {
        let t = parse_toml("xs = [1, 2, 3]\nss = [\"a\", \"b,c\"]").unwrap();
        assert_eq!(
            t["xs"],
            TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
        assert_eq!(
            t["ss"],
            TomlValue::Array(vec![TomlValue::Str("a".into()), TomlValue::Str("b,c".into())])
        );
    }

    #[test]
    fn errors() {
        assert_eq!(parse_toml("novalue").unwrap_err(), ParseError::MissingEquals(1));
        assert_eq!(parse_toml(" = 3").unwrap_err(), ParseError::EmptyKey(1));
        assert_eq!(
            parse_toml("a = 1\na = 2").unwrap_err(),
            ParseError::DuplicateKey(2, "a".into())
        );
        assert_eq!(
            parse_toml("a = \"open").unwrap_err(),
            ParseError::UnterminatedString(1)
        );
        assert_eq!(parse_toml("[table]").unwrap_err(), ParseError::TableUnsupported(1));
        assert!(matches!(parse_toml("a = wat").unwrap_err(), ParseError::BadValue(1, _)));
    }

    #[test]
    fn as_float_accepts_ints() {
        assert_eq!(TomlValue::Int(3).as_float(), Some(3.0));
        assert_eq!(TomlValue::Str("x".into()).as_float(), None);
    }
}
