//! Scale-out topology generators: the overlay families behind the
//! multi-subnet scenario plane.
//!
//! [`crate::graph::topology`] carries the paper's §IV-B evaluation grid
//! (Erdős–Rényi, Watts–Strogatz, Barabási–Albert, Complete). This module
//! adds the generators large-n scenarios need:
//!
//! * [`random_geometric`] — nodes placed uniformly in the unit square,
//!   edges within a connection radius (the classic wireless/proximity
//!   model; components are stitched by nearest cross-component pairs so
//!   the result is always connected);
//! * [`router_hierarchy`] — the testbed's shape scaled up: nodes grouped
//!   into subnets (round-robin, matching [`crate::netsim::testbed::Testbed`]'s
//!   device→router assignment), a ring lattice plus seeded chords inside
//!   each subnet, and **gateway** nodes joined by backbone links across
//!   subnets. Returns the [`Hierarchy`] the planner and the sharded
//!   simulator consume.
//!
//! Every generator is a pure function of its arguments and the supplied
//! [`Pcg64`] — seeded determinism is property-tested in
//! `tests/generator_properties.rs`.

use super::topology::{self, TopologyKind, TopologyParams};
use super::{Graph, NodeId};
use crate::util::rng::Pcg64;

/// The subnet structure of a hierarchical overlay: which subnet each node
/// belongs to and which member speaks for the subnet on the backbone.
///
/// Invariants (enforced at construction): every node is in exactly one
/// subnet, every subnet id is dense in `0..subnet_count()`, and each
/// subnet's gateway is one of its own members.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hierarchy {
    /// node → subnet id.
    subnet_of: Vec<usize>,
    /// subnet id → its gateway node.
    gateways: Vec<NodeId>,
}

impl Hierarchy {
    pub fn new(subnet_of: Vec<usize>, gateways: Vec<NodeId>) -> Self {
        let k = gateways.len();
        assert!(k >= 1, "hierarchy needs at least one subnet");
        assert!(
            subnet_of.iter().all(|&s| s < k),
            "subnet id out of range (expected < {k})"
        );
        for (s, &g) in gateways.iter().enumerate() {
            assert!(
                g < subnet_of.len() && subnet_of[g] == s,
                "gateway {g} is not a member of subnet {s}"
            );
        }
        Hierarchy { subnet_of, gateways }
    }

    /// The degenerate single-subnet hierarchy over `n` nodes — the
    /// bit-identical fallback anchor of hierarchical planning.
    pub fn flat(n: usize) -> Self {
        assert!(n >= 1);
        Hierarchy { subnet_of: vec![0; n], gateways: vec![0] }
    }

    /// Round-robin assignment `node % subnets`, gateway = lowest-id
    /// member — exactly the testbed's device→router split, so overlay
    /// subnets and simulator shards always agree.
    pub fn round_robin(n: usize, subnets: usize) -> Self {
        assert!(subnets >= 1 && subnets <= n, "need 1 <= subnets <= nodes");
        Hierarchy {
            subnet_of: (0..n).map(|d| d % subnets).collect(),
            gateways: (0..subnets).collect(),
        }
    }

    pub fn node_count(&self) -> usize {
        self.subnet_of.len()
    }

    pub fn subnet_count(&self) -> usize {
        self.gateways.len()
    }

    /// node → subnet id, as a slice.
    pub fn subnet_of(&self) -> &[usize] {
        &self.subnet_of
    }

    pub fn subnet(&self, u: NodeId) -> usize {
        self.subnet_of[u]
    }

    pub fn gateways(&self) -> &[NodeId] {
        &self.gateways
    }

    pub fn gateway(&self, s: usize) -> NodeId {
        self.gateways[s]
    }

    pub fn is_gateway(&self, u: NodeId) -> bool {
        self.gateways.contains(&u)
    }

    /// Members of subnet `s`, ascending.
    pub fn members(&self, s: usize) -> Vec<NodeId> {
        (0..self.subnet_of.len()).filter(|&u| self.subnet_of[u] == s).collect()
    }
}

/// Random geometric graph: `n` nodes uniform in the unit square, an edge
/// between every pair within `radius`. Disconnected draws are stitched by
/// joining the nearest cross-component pair repeatedly, so the result is
/// always connected while staying geometrically plausible. Unit edge
/// weights — the testbed overlays measured ping costs (§III-A).
///
/// O(n²) pair scan: intended for overlays up to a few thousand nodes; the
/// scale-out plane uses [`router_hierarchy`], which is O(n·k).
pub fn random_geometric(n: usize, radius: f64, rng: &mut Pcg64) -> Graph {
    assert!(n >= 2, "need at least 2 nodes, got {n}");
    assert!(radius > 0.0 && radius.is_finite(), "bad radius {radius}");
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.gen_f64(), rng.gen_f64())).collect();
    let d2 = |u: usize, v: usize| {
        let (dx, dy) = (pts[u].0 - pts[v].0, pts[u].1 - pts[v].1);
        dx * dx + dy * dy
    };
    let mut g = Graph::new(n);
    let r2 = radius * radius;
    for u in 0..n {
        for v in (u + 1)..n {
            if d2(u, v) <= r2 {
                g.add_edge(u, v, 1.0);
            }
        }
    }
    // Stitch components Borůvka-style (deterministic given the
    // positions): each round joins every component to its nearest
    // foreign node in one O(n²) sweep, at least halving the component
    // count — O(n² log n) overall even for radii that leave ~n
    // singletons, where a one-merge-per-rescan loop would be O(n³).
    loop {
        let comp = topology::components(&g);
        let k = comp.iter().copied().max().unwrap() + 1;
        if k == 1 {
            return g;
        }
        let mut best: Vec<(f64, usize, usize)> = vec![(f64::INFINITY, 0, 0); k];
        for u in 0..n {
            for v in (u + 1)..n {
                if comp[u] == comp[v] {
                    continue;
                }
                let d = d2(u, v);
                if d < best[comp[u]].0 {
                    best[comp[u]] = (d, u, v);
                }
                if d < best[comp[v]].0 {
                    best[comp[v]] = (d, v, u);
                }
            }
        }
        for &(_, u, v) in &best {
            // two components may pick the same pair symmetrically
            if !g.has_edge(u, v) {
                g.add_edge(u, v, 1.0);
            }
        }
    }
}

/// The router-hierarchy generator: `n` nodes in `subnets` round-robin
/// groups (matching the testbed's device→router split), each subnet wired
/// as a ring lattice of degree ≈ `intra_k` plus `len/4` seeded chord
/// edges, and subnets joined by a gateway backbone — each subnet's
/// gateway links to the next `gateway_links` subnets' gateways around the
/// subnet ring (1 = a backbone ring, higher = denser backbone).
///
/// Connected by construction: each subnet's ring lattice is connected and
/// the backbone ring joins all gateways. Unit edge weights.
pub fn router_hierarchy(
    n: usize,
    subnets: usize,
    gateway_links: usize,
    intra_k: usize,
    rng: &mut Pcg64,
) -> (Graph, Hierarchy) {
    assert!(n >= 2, "need at least 2 nodes, got {n}");
    assert!(subnets >= 1 && subnets <= n, "need 1 <= subnets <= nodes");
    assert!(gateway_links >= 1, "gateway_links must be >= 1");
    let h = Hierarchy::round_robin(n, subnets);
    let mut g = Graph::new(n);
    for s in 0..subnets {
        let members = h.members(s);
        let len = members.len();
        if len <= 1 {
            continue;
        }
        // ring lattice: member i links to the next ~intra_k/2 members
        let half = (intra_k / 2).clamp(1, len - 1);
        for i in 0..len {
            for d in 1..=half {
                let (u, v) = (members[i], members[(i + d) % len]);
                if u != v && !g.has_edge(u, v) {
                    g.add_edge(u, v, 1.0);
                }
            }
        }
        // seeded chords: sparse shortcuts within the subnet
        for _ in 0..len / 4 {
            let (u, v) = (members[rng.gen_range(len)], members[rng.gen_range(len)]);
            if u != v && !g.has_edge(u, v) {
                g.add_edge(u, v, 1.0);
            }
        }
    }
    if subnets > 1 {
        let reach = gateway_links.min(subnets - 1);
        for s in 0..subnets {
            for j in 1..=reach {
                let (a, b) = (h.gateway(s), h.gateway((s + j) % subnets));
                if a != b && !g.has_edge(a, b) {
                    g.add_edge(a, b, 1.0);
                }
            }
        }
    }
    (g, h)
}

/// Which overlay generator a session uses (config key `topology_gen`,
/// CLI `--topology-gen`). `Flat` (the default) defers to the paper grid's
/// `topology` key; the others select from this module's scale-out suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GeneratorKind {
    /// Use the `topology` family ([`TopologyKind`]) — the paper's grid.
    Flat,
    /// [`random_geometric`] over the unit square (`geo_radius`).
    Geometric,
    /// Watts–Strogatz small world (alias for the `topology` family).
    WattsStrogatz,
    /// Barabási–Albert scale-free (alias for the `topology` family).
    BarabasiAlbert,
    /// [`router_hierarchy`]: subnets + gateway backbone (`subnets`,
    /// `gateway_links`, lattice degree `ws_k`).
    Hierarchy,
}

impl GeneratorKind {
    pub fn name(&self) -> &'static str {
        match self {
            GeneratorKind::Flat => "flat",
            GeneratorKind::Geometric => "geometric",
            GeneratorKind::WattsStrogatz => "watts-strogatz",
            GeneratorKind::BarabasiAlbert => "barabasi-albert",
            GeneratorKind::Hierarchy => "hierarchy",
        }
    }

    pub fn parse(s: &str) -> Option<GeneratorKind> {
        match s.to_ascii_lowercase().replace(['_', ' '], "-").as_str() {
            "flat" | "topology" => Some(GeneratorKind::Flat),
            "geometric" | "geo" | "rgg" | "random-geometric" => Some(GeneratorKind::Geometric),
            "watts-strogatz" | "ws" => Some(GeneratorKind::WattsStrogatz),
            "barabasi-albert" | "ba" => Some(GeneratorKind::BarabasiAlbert),
            "hierarchy" | "router-hierarchy" | "subnets" => Some(GeneratorKind::Hierarchy),
            _ => None,
        }
    }
}

/// Scenario entry point: generate the overlay structure a config's
/// generator kind prescribes, plus the [`Hierarchy`] when one exists.
/// `Flat` (and the WS/BA aliases) reproduce `topology::generate` draw for
/// draw, so default configs are untouched bit for bit.
pub fn generate_structure(
    kind: GeneratorKind,
    family: TopologyKind,
    n: usize,
    subnets: usize,
    gateway_links: usize,
    params: &TopologyParams,
    rng: &mut Pcg64,
) -> (Graph, Option<Hierarchy>) {
    match kind {
        GeneratorKind::Flat => (topology::generate(family, n, params, rng), None),
        GeneratorKind::Geometric => (random_geometric(n, params.geo_radius, rng), None),
        GeneratorKind::WattsStrogatz => {
            (topology::generate(TopologyKind::WattsStrogatz, n, params, rng), None)
        }
        GeneratorKind::BarabasiAlbert => {
            (topology::generate(TopologyKind::BarabasiAlbert, n, params, rng), None)
        }
        GeneratorKind::Hierarchy => {
            let (g, h) = router_hierarchy(n, subnets, gateway_links, params.ws_k, rng);
            (g, Some(h))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometric_connected_and_deterministic() {
        let a = random_geometric(40, 0.2, &mut Pcg64::new(5));
        let b = random_geometric(40, 0.2, &mut Pcg64::new(5));
        assert!(a.is_connected());
        assert_eq!(a.edge_count(), b.edge_count());
        for (ea, eb) in a.sorted_edges().iter().zip(b.sorted_edges().iter()) {
            assert_eq!((ea.u, ea.v), (eb.u, eb.v));
        }
    }

    #[test]
    fn geometric_large_radius_is_complete() {
        let g = random_geometric(12, 1.5, &mut Pcg64::new(1));
        assert_eq!(g.edge_count(), 12 * 11 / 2);
    }

    #[test]
    fn hierarchy_invariants_hold() {
        let (g, h) = router_hierarchy(26, 4, 2, 4, &mut Pcg64::new(9));
        assert!(g.is_connected());
        assert_eq!(h.node_count(), 26);
        assert_eq!(h.subnet_count(), 4);
        // round-robin split; every node in exactly one subnet
        for u in 0..26 {
            assert_eq!(h.subnet(u), u % 4);
        }
        // gateways are members of their subnet (lowest ids)
        for s in 0..4 {
            assert_eq!(h.gateway(s), s);
            assert!(h.members(s).contains(&h.gateway(s)));
        }
        // cross-subnet edges touch gateways only
        for e in g.edges() {
            if h.subnet(e.u) != h.subnet(e.v) {
                assert!(h.is_gateway(e.u) && h.is_gateway(e.v), "non-gateway crossing edge");
            }
        }
    }

    #[test]
    fn hierarchy_single_subnet_has_no_crossings() {
        let (g, h) = router_hierarchy(10, 1, 2, 4, &mut Pcg64::new(2));
        assert!(g.is_connected());
        assert_eq!(h.subnet_count(), 1);
        assert_eq!(h.gateways(), &[0]);
    }

    #[test]
    fn flat_hierarchy_constructor() {
        let h = Hierarchy::flat(7);
        assert_eq!(h.subnet_count(), 1);
        assert_eq!(h.members(0).len(), 7);
        assert!(h.is_gateway(0));
        assert!(!h.is_gateway(1));
    }

    #[test]
    #[should_panic(expected = "not a member")]
    fn bad_gateway_rejected() {
        Hierarchy::new(vec![0, 0, 1], vec![0, 0]);
    }

    #[test]
    fn generator_kind_parse_roundtrip() {
        for kind in [
            GeneratorKind::Flat,
            GeneratorKind::Geometric,
            GeneratorKind::WattsStrogatz,
            GeneratorKind::BarabasiAlbert,
            GeneratorKind::Hierarchy,
        ] {
            assert_eq!(GeneratorKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(GeneratorKind::parse("rgg"), Some(GeneratorKind::Geometric));
        assert_eq!(GeneratorKind::parse("nope"), None);
    }

    #[test]
    fn generate_structure_flat_matches_topology_generate() {
        let params = TopologyParams::default();
        let (a, h) = generate_structure(
            GeneratorKind::Flat,
            TopologyKind::ErdosRenyi,
            14,
            3,
            2,
            &params,
            &mut Pcg64::new(77),
        );
        assert!(h.is_none());
        let b = topology::generate(TopologyKind::ErdosRenyi, 14, &params, &mut Pcg64::new(77));
        assert_eq!(a.edge_count(), b.edge_count());
        for (ea, eb) in a.sorted_edges().iter().zip(b.sorted_edges().iter()) {
            assert_eq!((ea.u, ea.v), (eb.u, eb.v));
        }
    }
}
