//! Sequential rounds vs multi-round pipelining through the event-driven
//! engine: total simulated time for 3 communication rounds on ring, star,
//! balanced-tree and the paper's complete topology.
//!
//! Sequential = the classic mode, a fresh simulator per round, totals
//! summed. Pipelined = one long-lived simulator, round t+1 seeding as
//! nodes finish round t (§III-D). Emits one `JSON {...}` line per cell
//! for the bench trajectory.

use mosgu::bench::section;
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::session::GossipSession;
use mosgu::graph::topology::TopologyKind;

fn main() {
    let rounds = 3u64;
    let model_mb = 14.0;
    section(&format!("engine pipelining: {rounds}-round total simulated time (model {model_mb} MB)"));
    println!(
        "{:<16} {:>4} {:>14} {:>14} {:>9} {:>12}",
        "topology", "n", "sequential_s", "pipelined_s", "speedup", "slots(p)"
    );
    for kind in [
        TopologyKind::Ring,
        TopologyKind::Star,
        TopologyKind::BalancedTree,
        TopologyKind::Complete,
    ] {
        for n in [10usize, 16, 24] {
            let cfg = ExperimentConfig {
                topology: kind,
                nodes: n,
                latency_jitter: 0.0,
                ..Default::default()
            };
            let session = GossipSession::new(&cfg).expect("session");
            let sequential: f64 = (0..rounds)
                .map(|_| session.run_mosgu_round(model_mb, 1, 0.0).total_time_s)
                .sum();
            let pipe = session.run_pipelined_rounds(model_mb, rounds, 1);
            let speedup = sequential / pipe.total_time_s;
            println!(
                "{:<16} {:>4} {:>14.3} {:>14.3} {:>8.3}x {:>12}",
                kind.name(),
                n,
                sequential,
                pipe.total_time_s,
                speedup,
                pipe.slots
            );
            println!(
                "JSON {{\"bench\":\"engine_pipeline\",\"topology\":\"{}\",\"n\":{},\"rounds\":{},\
                 \"model_mb\":{},\"sequential_s\":{:.6},\"pipelined_s\":{:.6},\"speedup\":{:.4},\
                 \"slots\":{},\"exchange_done_s\":{:.6}}}",
                kind.name(),
                n,
                rounds,
                model_mb,
                sequential,
                pipe.total_time_s,
                speedup,
                pipe.slots,
                pipe.rounds.last().map(|p| p.exchange_done_s).unwrap_or(0.0),
            );
        }
    }

    section("per-round phase timeline (ring, n=16)");
    let cfg = ExperimentConfig {
        topology: TopologyKind::Ring,
        nodes: 16,
        latency_jitter: 0.0,
        ..Default::default()
    };
    let session = GossipSession::new(&cfg).expect("session");
    let pipe = session.run_pipelined_rounds(model_mb, rounds, 1);
    for ph in &pipe.rounds {
        println!(
            "round {}: seeded {:>8.2}-{:>8.2} s, exchange {:>8.2} s, done {:>8.2} s (slots {}-{})",
            ph.round, ph.first_seed_s, ph.all_seeded_s, ph.exchange_done_s, ph.done_s,
            ph.first_slot, ph.last_slot
        );
    }
    println!(
        "overlap: {:.2} s summed round spans vs {:.2} s wall",
        pipe.summed_round_spans_s(),
        pipe.total_time_s
    );
}
