//! Loom model-checking of the work-stealing `DrainPool`.
//!
//! Built only under `--features loom`, where `netsim::sync` swaps
//! `std::sync`/`std::thread` for loom's permutation-exploring mocks:
//! every test below runs its closure under **every** thread interleaving
//! the memory model admits, so the pool's three `unsafe` sites (the
//! `Send` pointer erasure and the two claim-then-dereference paths) are
//! exercised against all schedules, not just the ones a lucky run
//! happens to produce. The claim-ledger `debug_assert`s (sole-claimant
//! invariant I2) and the `outstanding` accounting (I1/I4) fire inside
//! the model if any interleaving violates them.
//!
//! Run with:
//!
//! ```text
//! cargo add loom@0.7          # the feature carries no dependency offline
//! cargo test --release --features loom --test loom_pool
//! ```
//!
//! `LOOM_MAX_PREEMPTIONS=3` bounds the search in CI; the models keep the
//! task and worker counts at 2–3 so exhaustive exploration stays in the
//! low seconds.
#![cfg(feature = "loom")]

use mosgu::netsim::pool::{DrainPool, Drainable};

/// A minimal drainable: counts how many times it was drained. Any
/// double-claim (two threads draining the same probe) is visible as a
/// count > 1 even if the racy increments happen to both land.
struct Probe {
    drains: usize,
}

impl Drainable for Probe {
    fn drain_to_idle(&mut self) {
        self.drains += 1;
    }
}

fn probes(n: usize) -> Vec<Probe> {
    (0..n).map(|_| Probe { drains: 0 }).collect()
}

#[test]
fn two_drainers_three_tasks_each_runs_once() {
    loom::model(|| {
        let pool: DrainPool<Probe> = DrainPool::new(2);
        let mut items = probes(3);
        pool.drain(items.iter_mut());
        for (i, p) in items.iter().enumerate() {
            assert_eq!(p.drains, 1, "task {i} drained {} times", p.drains);
        }
        drop(pool); // joins the worker inside the model
    });
}

#[test]
fn three_drainers_two_tasks_each_runs_once() {
    // more drainers than tasks: some threads must claim nothing and go
    // back to the condvar without touching any pointer
    loom::model(|| {
        let pool: DrainPool<Probe> = DrainPool::new(3);
        let mut items = probes(2);
        pool.drain(items.iter_mut());
        assert!(items.iter().all(|p| p.drains == 1));
        drop(pool);
    });
}

#[test]
fn reuse_across_barriers_stays_exclusive() {
    // two successive windows through one pool: the second publish must
    // not race the first window's tail (invariant I4), and stale tasks
    // from window one must never be re-claimed in window two (I1)
    loom::model(|| {
        let pool: DrainPool<Probe> = DrainPool::new(2);
        let mut items = probes(2);
        pool.drain(items.iter_mut());
        pool.drain(items.iter_mut());
        assert!(items.iter().all(|p| p.drains == 2));
        drop(pool);
    });
}

#[test]
fn empty_batch_is_a_no_op_under_every_schedule() {
    loom::model(|| {
        let pool: DrainPool<Probe> = DrainPool::new(2);
        pool.drain(std::iter::empty());
        let mut items = probes(1);
        pool.drain(items.iter_mut());
        assert_eq!(items[0].drains, 1);
        drop(pool);
    });
}
