//! The abstract's headline claim: "reducing bandwidth and transfer time by
//! up to circa 8 and 4.4 times, respectively, compared to naive flooding
//! broadcasting methods." Computes the max improvement ratios over the
//! full grid and per size category, emitting one `JSON {...}` line per
//! grid cell plus a `headline` summary line for the bench trajectory; CI
//! uploads them as the `headline` artifact.
//!
//! ```bash
//! cargo bench --bench headline             # full 4x7 grid, 5 repeats
//! cargo bench --bench headline -- --smoke  # CI subset: v3s + b3, 1 repeat
//! ```

use mosgu::bench::section;
use mosgu::bench::tables::{all_models, headline, run_grid};
use mosgu::config::ExperimentConfig;
use mosgu::dfl::models::by_code;
use mosgu::graph::topology::TopologyKind;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = if smoke {
        ExperimentConfig { repeats: 1, ..Default::default() }
    } else {
        ExperimentConfig::default()
    };
    let models = if smoke {
        vec![by_code("v3s").unwrap(), by_code("b3").unwrap()]
    } else {
        all_models()
    };
    section(&format!(
        "headline improvement factors (max over 4 topologies x {} models, {} mode)",
        models.len(),
        if smoke { "smoke" } else { "full" }
    ));
    let cells = run_grid(&cfg, &TopologyKind::ALL, &models, |s| eprintln!("  {s}"))
        .expect("grid");
    for c in &cells {
        println!(
            "JSON {{\"bench\":\"headline\",\"topology\":\"{}\",\"model\":\"{}\",\
             \"broadcast_bw_mbps\":{:.4},\"proposed_bw_mbps\":{:.4},\
             \"broadcast_total_s\":{:.6},\"proposed_exchange_s\":{:.6},\
             \"bw_ratio\":{:.4},\"round_ratio\":{:.4}}}",
            c.topology,
            c.model,
            c.broadcast.bandwidth.mean(),
            c.proposed.bandwidth.mean(),
            c.broadcast.total.mean(),
            c.proposed.exchange.mean(),
            c.proposed.bandwidth.mean() / c.broadcast.bandwidth.mean(),
            c.broadcast.total.mean() / c.proposed.exchange.mean(),
        );
    }
    let h = headline(&cells);
    println!("bandwidth improvement:     {:.2}x   (paper: up to ~8x)", h.bandwidth_improvement);
    println!("transfer-time improvement: {:.2}x   (paper Table IV spread: 2.6-7.4x)", h.transfer_improvement);
    println!("round-time improvement:    {:.2}x   (paper: up to 4.4x)", h.round_improvement);
    println!(
        "JSON {{\"bench\":\"headline\",\"summary\":true,\"bandwidth_improvement\":{:.4},\
         \"transfer_improvement\":{:.4},\"round_improvement\":{:.4}}}",
        h.bandwidth_improvement, h.transfer_improvement, h.round_improvement
    );

    section("paper §V-A observations checked");
    // small models gain least in bandwidth terms; large gain most
    let avg_bw_ratio = |code: &str| {
        let (mut sum, mut k) = (0.0, 0);
        for c in cells.iter().filter(|c| c.model == code) {
            sum += c.proposed.bandwidth.mean() / c.broadcast.bandwidth.mean();
            k += 1;
        }
        sum / k as f64
    };
    let small = avg_bw_ratio("v3s");
    let large = avg_bw_ratio("b3");
    println!("bandwidth ratio v3s: {small:.2}x, b3: {large:.2}x -> large models gain {}",
        if large > small { "MORE (matches paper)" } else { "LESS (MISMATCH)" });

    // the abstract's direction is the gate: the planner must actually
    // improve on flooding broadcast somewhere in the grid
    let ok = h.bandwidth_improvement > 1.0 && h.round_improvement > 1.0;
    println!("acceptance: {}", if ok { "pass" } else { "FAIL" });
    if !ok {
        std::process::exit(1);
    }
}
