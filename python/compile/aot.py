"""AOT export: lower the Layer-2 entry points to HLO **text** artifacts.

HLO text (not `HloModuleProto.serialize()`) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (written to ../artifacts by default):

  train_step.hlo.txt      (flat[D], tokens[B,T]i32, targets[B,T]i32, lr[]) -> (flat[D], loss[])
  eval_step.hlo.txt       (flat[D], tokens[B,T]i32, targets[B,T]i32)      -> (loss[],)
  aggregate.hlo.txt       (acc[D], w_acc[], model[D], w[])                 -> (acc[D], w[])
  manifest.txt            dimensions the Rust runtime needs (D, B, T, ...)

Python runs ONCE at build time (`make artifacts`); the Rust binary then
executes these through PJRT with no Python on the request path.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .model import ModelConfig


def to_hlo_text(lowered) -> str:
    """jax lowered -> stablehlo -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str, cfg: ModelConfig, batch: int) -> dict:
    """Lower and write every artifact; returns {name: path}."""
    os.makedirs(out_dir, exist_ok=True)
    d = model_mod.padded_dim(cfg)
    flat_spec = jax.ShapeDtypeStruct((d,), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    scalar_spec = jax.ShapeDtypeStruct((), jnp.float32)

    paths = {}

    def write(name: str, text: str) -> None:
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        paths[name] = path
        print(f"wrote {name}: {len(text)} chars -> {path}")

    train = jax.jit(lambda f, x, y, lr: model_mod.train_step(cfg, f, x, y, lr))
    write("train_step", to_hlo_text(train.lower(flat_spec, tok_spec, tok_spec, scalar_spec)))

    ev = jax.jit(lambda f, x, y: (model_mod.eval_step(cfg, f, x, y),))
    write("eval_step", to_hlo_text(ev.lower(flat_spec, tok_spec, tok_spec)))

    agg = jax.jit(model_mod.aggregate_pair)
    write("aggregate", to_hlo_text(agg.lower(flat_spec, scalar_spec, flat_spec, scalar_spec)))

    # initial parameters as raw little-endian f32 (seeded per node from Rust
    # by adding node-id noise; one shared init keeps artifacts small)
    init = model_mod.flatten_params(cfg, model_mod.init_params(cfg, seed=0))
    init_path = os.path.join(out_dir, "init_params.f32")
    import numpy as np

    np.asarray(init, dtype="<f4").tofile(init_path)
    paths["init_params"] = init_path
    print(f"wrote init_params: {init.shape[0]} f32 -> {init_path}")

    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write(f"param_dim = {d}\n")
        f.write(f"param_count = {model_mod.param_count(cfg)}\n")
        f.write(f"batch = {batch}\n")
        f.write(f"seq_len = {cfg.seq_len}\n")
        f.write(f"vocab = {cfg.vocab}\n")
        f.write(f"d_model = {cfg.d_model}\n")
        f.write(f"d_ff = {cfg.d_ff}\n")
        f.write(f"n_layers = {cfg.n_layers}\n")
        f.write(f"n_heads = {cfg.n_heads}\n")
        f.write(f"pad_multiple = {cfg.pad_multiple}\n")
    paths["manifest"] = manifest
    print(f"wrote manifest -> {manifest}")
    return paths


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--no-pallas", action="store_true",
                    help="use the pure-jnp reference FFN instead of the Pallas kernel")
    args = ap.parse_args()
    cfg = ModelConfig(
        d_model=args.d_model,
        d_ff=args.d_ff,
        n_layers=args.layers,
        seq_len=args.seq_len,
        use_pallas=not args.no_pallas,
    )
    print(f"model: {model_mod.param_count(cfg):,} params "
          f"(padded dim {model_mod.padded_dim(cfg):,})")
    export_all(os.path.abspath(args.out), cfg, args.batch)


if __name__ == "__main__":
    main()
