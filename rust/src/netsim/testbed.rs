//! The simulated physical testbed (paper §IV-A, Fig 3): N learning devices
//! attached to S routers (one subnet per router); routers fully
//! interconnected. Data between different subnets is relayed
//! source-device → source-router → destination-router → destination-device,
//! exactly the multi-hop path the paper describes.
//!
//! The testbed owns host/channel layout, routing, the simulated `ping`
//! measurement used as edge cost, and construction of the overlay cost
//! graph for a given topology structure.

use super::{Channel, ChannelId, DriftProcess, HostId, LossModel, NetSim};
use crate::config::ExperimentConfig;
use crate::graph::Graph;
use crate::util::rng::Pcg64;

/// Static testbed layout + channel tables. Build once per experiment, then
/// call [`Testbed::netsim`] to get a fresh simulator over the same wiring.
#[derive(Debug, Clone)]
pub struct Testbed {
    nodes: usize,
    subnets: usize,
    /// device -> subnet (round-robin, matching the paper's even split)
    subnet_of: Vec<usize>,
    channels: Vec<Channel>,
    /// device -> (uplink channel, downlink channel)
    device_links: Vec<(ChannelId, ChannelId)>,
    /// (router_a, router_b) -> directed channel a->b, stored dense S×S
    router_links: Vec<Option<ChannelId>>,
    cfg: ExperimentConfig,
}

impl Testbed {
    /// Build the testbed from an experiment config. Latency of each link is
    /// jittered once at build time (links have stable but unequal quality,
    /// like real cabling/geography).
    pub fn new(cfg: &ExperimentConfig) -> Self {
        let n = cfg.nodes;
        let s = cfg.subnets;
        let mut rng = Pcg64::new(cfg.seed ^ 0x7e57_bed0);
        let mut jittered = |base: f64| -> f64 {
            if cfg.latency_jitter > 0.0 {
                base * (1.0 + rng.gen_f64_range(-cfg.latency_jitter, cfg.latency_jitter))
            } else {
                base
            }
        };

        let subnet_of: Vec<usize> = (0..n).map(|d| d % s).collect();
        let mut channels = Vec::new();
        let mut device_links = Vec::with_capacity(n);
        for d in 0..n {
            let up = channels.len();
            channels.push(Channel {
                capacity_mbps: cfg.local_link_mbps,
                latency_s: jittered(cfg.local_latency_ms) / 1e3,
                label: format!("dev{d}->r{}", subnet_of[d]).into(),
            });
            let down = channels.len();
            channels.push(Channel {
                capacity_mbps: cfg.local_link_mbps,
                latency_s: jittered(cfg.local_latency_ms) / 1e3,
                label: format!("r{}->dev{d}", subnet_of[d]).into(),
            });
            device_links.push((up, down));
        }
        let mut router_links = vec![None; s * s];
        for a in 0..s {
            for b in 0..s {
                if a == b {
                    continue;
                }
                let id = channels.len();
                channels.push(Channel {
                    capacity_mbps: cfg.backbone_mbps,
                    latency_s: jittered(cfg.backbone_latency_ms) / 1e3,
                    label: format!("r{a}->r{b}").into(),
                });
                router_links[a * s + b] = Some(id);
            }
        }
        Testbed {
            nodes: n,
            subnets: s,
            subnet_of,
            channels,
            device_links,
            router_links,
            cfg: cfg.clone(),
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes
    }

    pub fn subnet_count(&self) -> usize {
        self.subnets
    }

    /// The experiment config this testbed was built from.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The full channel table, in construction order (device up/down
    /// pairs, then router-router links).
    pub fn channels(&self) -> &[Channel] {
        &self.channels
    }

    /// A device's (uplink, downlink) channel ids in the full table.
    pub fn device_link_ids(&self, d: HostId) -> (ChannelId, ChannelId) {
        self.device_links[d]
    }

    /// The directed router link a→b in the full table (`None` when a == b).
    pub fn router_link_id(&self, a: usize, b: usize) -> Option<ChannelId> {
        self.router_links[a * self.subnets + b]
    }

    /// Devices attached to router `s`, ascending.
    pub fn subnet_members(&self, s: usize) -> Vec<HostId> {
        (0..self.nodes).filter(|&d| self.subnet_of[d] == s).collect()
    }

    /// Which subnet (router) a device belongs to.
    pub fn subnet_of(&self, d: HostId) -> usize {
        self.subnet_of[d]
    }

    /// Device→subnet assignment vector (for DOT styling).
    pub fn subnet_assignment(&self) -> Vec<usize> {
        self.subnet_of.clone()
    }

    /// The channel route for a device-to-device transfer.
    ///
    /// Same subnet: up(src) → down(dst) (through the shared router).
    /// Different subnet: up(src) → router-router → down(dst).
    pub fn route(&self, src: HostId, dst: HostId) -> Vec<ChannelId> {
        assert!(src != dst, "route to self");
        let (su, sd) = (self.subnet_of[src], self.subnet_of[dst]);
        let (up, _) = self.device_links[src];
        let (_, down) = self.device_links[dst];
        if su == sd {
            vec![up, down]
        } else {
            // invariant: construction fills the router mesh for every
            // ordered pair of distinct subnets, and su != sd here
            #[allow(clippy::expect_used)]
            let rr = self.router_links[su * self.subnets + sd].expect("router link");
            vec![up, rr, down]
        }
    }

    /// One-way propagation latency of the route, seconds.
    pub fn route_latency(&self, src: HostId, dst: HostId) -> f64 {
        self.route(src, dst).iter().map(|&c| self.channels[c].latency_s).sum()
    }

    /// Simulated ping RTT in **milliseconds** — the paper's edge cost and
    /// the `ping_max` input of the slot-length formula. RTT = two one-way
    /// propagations plus the (tiny) serialization of the probe payload.
    pub fn ping_ms(&self, src: HostId, dst: HostId) -> f64 {
        let one_way = self.route_latency(src, dst);
        let probe_mb = self.cfg.ping_size_bytes as f64 / (1024.0 * 1024.0);
        let min_rate =
            self.route(src, dst).iter().map(|&c| self.channels[c].capacity_mbps).fold(f64::INFINITY, f64::min);
        (2.0 * one_way + 2.0 * probe_mb / min_rate) * 1e3
    }

    /// True if src and dst share a router (the paper's dashed-blue "local
    /// connection").
    pub fn is_local(&self, src: HostId, dst: HostId) -> bool {
        self.subnet_of[src] == self.subnet_of[dst]
    }

    /// Overlay cost graph: take a structural topology over the devices and
    /// weight each edge with the measured ping (ms) — how the moderator's
    /// adjacency matrix is populated in §III-A.
    pub fn overlay_costs(&self, structure: &Graph) -> Graph {
        assert_eq!(structure.node_count(), self.nodes);
        let mut g = Graph::new(self.nodes);
        for e in structure.edges() {
            g.add_edge(e.u, e.v, self.ping_ms(e.u, e.v));
        }
        g
    }

    /// Fresh simulator over this wiring.
    pub fn netsim(&self, seed: u64) -> NetSim {
        self.netsim_for_channels(self.channels.clone(), seed)
    }

    /// Fresh simulator over an arbitrary channel subset with this
    /// testbed's construction policy (default loss model, protocol
    /// overhead, transfer jitter derived from the latency jitter). The
    /// single place that policy lives: [`Testbed::netsim`] and every
    /// shard of [`super::shard::ShardedNetSim`] build through it, so the
    /// sharded simulators can never drift from the flat baseline.
    pub fn netsim_for_channels(&self, channels: Vec<Channel>, seed: u64) -> NetSim {
        let mut sim = NetSim::new(channels, LossModel::default(), self.cfg.protocol_overhead, seed);
        if self.cfg.latency_jitter > 0.0 {
            // transfer-size jitter kept small relative to latency jitter
            sim.set_transfer_jitter((self.cfg.latency_jitter / 2.0).min(0.49));
        }
        sim
    }

    /// Fresh simulator with seeded link-quality drift installed (the
    /// dynamic network plane): every `drift.interval_s` of simulated time
    /// each channel's capacity/latency are rescaled around their base
    /// values (see [`DriftProcess`]). `drift.amplitude == 0` is
    /// bit-identical to [`Testbed::netsim`].
    pub fn netsim_with_drift(&self, seed: u64, drift: DriftProcess) -> NetSim {
        let mut sim = self.netsim(seed);
        // an independent stream so drift draws never interleave with the
        // simulator's transfer-jitter rng
        sim.set_drift(drift, self.cfg.seed ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xd41f7);
        sim
    }

    /// Fresh simulator with an explicit loss model (used by calibration and
    /// ablation benches).
    pub fn netsim_with_loss(&self, seed: u64, loss: LossModel) -> NetSim {
        NetSim::new(self.channels.clone(), loss, self.cfg.protocol_overhead, seed)
    }

    /// Describe the testbed (CLI `sim --describe`; stands in for Fig 3).
    pub fn describe(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "testbed: {} devices, {} routers (fully interconnected)\n",
            self.nodes, self.subnets
        ));
        for s in 0..self.subnets {
            let members: Vec<String> = (0..self.nodes)
                .filter(|&d| self.subnet_of[d] == s)
                .map(|d| format!("dev{d}"))
                .collect();
            out.push_str(&format!("  subnet {s}: {}\n", members.join(", ")));
        }
        out.push_str(&format!(
            "  local link: {:.1} MB/s, {:.2} ms; backbone: {:.1} MB/s, {:.2} ms\n",
            self.cfg.local_link_mbps,
            self.cfg.local_latency_ms,
            self.cfg.backbone_mbps,
            self.cfg.backbone_latency_ms
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig { latency_jitter: 0.0, ..Default::default() }
    }

    #[test]
    fn paper_layout_ten_devices_three_routers() {
        let tb = Testbed::new(&cfg());
        assert_eq!(tb.node_count(), 10);
        assert_eq!(tb.subnet_count(), 3);
        // round-robin split 4/3/3
        let counts: Vec<usize> =
            (0..3).map(|s| (0..10).filter(|&d| tb.subnet_of(d) == s).count()).collect();
        assert_eq!(counts, vec![4, 3, 3]);
    }

    #[test]
    fn local_route_two_hops_inter_route_three() {
        let tb = Testbed::new(&cfg());
        // devices 0 and 3 share subnet 0; devices 0 and 1 differ
        assert!(tb.is_local(0, 3));
        assert_eq!(tb.route(0, 3).len(), 2);
        assert!(!tb.is_local(0, 1));
        assert_eq!(tb.route(0, 1).len(), 3);
    }

    #[test]
    fn inter_subnet_ping_much_larger() {
        let tb = Testbed::new(&cfg());
        let local = tb.ping_ms(0, 3);
        let inter = tb.ping_ms(0, 1);
        assert!(inter > 5.0 * local, "inter {inter} vs local {local}");
    }

    #[test]
    fn ping_symmetry_without_jitter() {
        let tb = Testbed::new(&cfg());
        assert!((tb.ping_ms(0, 1) - tb.ping_ms(1, 0)).abs() < 1e-12);
    }

    #[test]
    fn jitter_makes_pings_asymmetric_but_close() {
        let mut c = cfg();
        c.latency_jitter = 0.1;
        let tb = Testbed::new(&c);
        let a = tb.ping_ms(0, 1);
        let b = tb.ping_ms(1, 0);
        assert!((a - b).abs() / a < 0.5);
    }

    #[test]
    fn overlay_costs_use_ping() {
        let tb = Testbed::new(&cfg());
        let structure = crate::graph::topology::complete(10);
        let g = tb.overlay_costs(&structure);
        assert_eq!(g.edge_count(), 45);
        assert!((g.weight(0, 3).unwrap() - tb.ping_ms(0, 3)).abs() < 1e-12);
    }

    #[test]
    fn transfer_through_testbed_completes() {
        let tb = Testbed::new(&cfg());
        let mut sim = tb.netsim(1);
        let route = tb.route(0, 1);
        sim.start_flow(0, 1, route, 14.0, 0);
        let t = sim.run_until_idle();
        // 14MB at 22 MB/s bottleneck + 4% overhead ≈ 0.66s (uncontended:
        // the loss model does not fire for a single flow)
        assert!(t > 0.5 && t < 1.0, "t={t}");
    }

    #[test]
    fn concurrent_uplink_flows_contend() {
        let tb = Testbed::new(&cfg());
        let mut sim = tb.netsim_with_loss(1, LossModel { gain: 0.0, size_scale_mb: 1.0 });
        // device 0 sends to two peers at once: same uplink, halves rate
        sim.start_flow(0, 1, tb.route(0, 1), 11.0, 0);
        sim.start_flow(0, 2, tb.route(0, 2), 11.0, 1);
        sim.run_until_idle();
        for rec in sim.completed() {
            // 22 MB/s uplink shared two ways (loss disabled in this sim)
            assert!(rec.bandwidth_mbps() < 12.0, "should be near half rate: {rec:?}");
            assert!(rec.bandwidth_mbps() > 9.0, "{rec:?}");
        }
    }

    #[test]
    fn drift_free_netsim_with_drift_matches_netsim() {
        let tb = Testbed::new(&ExperimentConfig::default());
        let run = |mut sim: super::NetSim| {
            sim.start_flow(0, 1, tb.route(0, 1), 14.0, 0);
            sim.start_flow(2, 5, tb.route(2, 5), 14.0, 1);
            sim.run_until_idle();
            (sim.now(), sim.take_completed())
        };
        let (t0, r0) = run(tb.netsim(3));
        let (t1, r1) =
            run(tb.netsim_with_drift(3, DriftProcess { amplitude: 0.0, interval_s: 10.0 }));
        assert_eq!(t0.to_bits(), t1.to_bits());
        assert_eq!(r0, r1);
        // a real amplitude perturbs the trajectory
        let (t2, _) =
            run(tb.netsim_with_drift(3, DriftProcess { amplitude: 0.3, interval_s: 0.1 }));
        assert!(t0 != t2, "drift had no effect");
    }

    #[test]
    fn describe_mentions_subnets() {
        let tb = Testbed::new(&cfg());
        let d = tb.describe();
        assert!(d.contains("10 devices"));
        assert!(d.contains("subnet 2"));
    }
}
