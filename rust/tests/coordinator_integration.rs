//! Integration tests across the coordinator stack: moderator lifecycle
//! (rotation, voting, membership churn), timed sessions on every topology,
//! and cross-checks between the logical and simulated gossip drivers.

use mosgu::coloring::ColoringAlgorithm;
use mosgu::config::ExperimentConfig;
use mosgu::coordinator::moderator::{next_moderator_round_robin, tally_votes, Moderator};
use mosgu::coordinator::session::{sessions_for_all_topologies, GossipSession};
use mosgu::graph::topology::TopologyKind;
use mosgu::mst::MstAlgorithm;

fn cfg() -> ExperimentConfig {
    ExperimentConfig { latency_jitter: 0.0, ..Default::default() }
}

#[test]
fn all_topologies_end_to_end() {
    for (kind, session) in sessions_for_all_topologies(&cfg()).unwrap() {
        let g = session.run_mosgu_round(14.0, 1, 0.0);
        let b = session.run_broadcast_round(14.0, 1);
        assert_eq!(g.transfer_count(), 90, "{kind:?}");
        assert!(g.bandwidth_mbps() > b.bandwidth_mbps(), "{kind:?}");
        assert!(g.exchange_time_s < b.total_time_s, "{kind:?}");
        assert!(g.total_time_s >= g.exchange_time_s, "{kind:?}");
    }
}

#[test]
fn moderator_rotation_over_learning_rounds() {
    // simulate 5 learning rounds with round-robin rotation + voting
    let session = GossipSession::new(&cfg()).unwrap();
    let costs = session.costs().clone();
    let n = 10;
    let mut moderator = Moderator::new(0, n, MstAlgorithm::Prim, ColoringAlgorithm::Bfs);
    for u in 0..n {
        let peers: Vec<(usize, f64)> = costs.neighbors(u).iter().map(|&(v, w)| (v, w)).collect();
        moderator.submit_report(u, &peers);
    }
    let first_tree = moderator.compute_schedule(14.0, 56, 1).unwrap().tree.clone();

    let mut current = 0;
    for round in 0..5u64 {
        // everyone votes round-robin; current moderator tallies
        let votes: Vec<(usize, usize)> =
            (0..n).map(|v| (v, next_moderator_round_robin(current, n))).collect();
        let winner = tally_votes(&votes, n).unwrap();
        assert_eq!(winner, (current + 1) % n, "round {round}");
        moderator = moderator.handover(winner);
        current = winner;
        // stable membership: no recomputation needed, bundle preserved
        assert!(!moderator.needs_recompute(), "round {round}");
        let tree = &moderator.bundle().unwrap().tree;
        assert_eq!(tree.edge_count(), first_tree.edge_count());
        for e in first_tree.edges() {
            assert!(tree.has_edge(e.u, e.v));
        }
    }
}

#[test]
fn membership_change_triggers_recompute() {
    let session = GossipSession::new(&cfg()).unwrap();
    let costs = session.costs().clone();
    let mut m = Moderator::new(0, 10, MstAlgorithm::Prim, ColoringAlgorithm::Bfs);
    for u in 0..10 {
        let peers: Vec<(usize, f64)> = costs.neighbors(u).iter().map(|&(v, w)| (v, w)).collect();
        m.submit_report(u, &peers);
    }
    m.compute_schedule(14.0, 56, 1).unwrap();
    assert!(!m.needs_recompute());

    // a node leaves: 9 nodes, fresh reports required
    m.membership_changed(9);
    assert!(m.needs_recompute());
    assert!(m.compute_schedule(14.0, 56, 1).is_err(), "stale reports cleared");
    for u in 0..9 {
        let peers: Vec<(usize, f64)> = (0..9)
            .filter(|&v| v != u)
            .map(|v| (v, 1.0 + (u + v) as f64))
            .collect();
        m.submit_report(u, &peers);
    }
    let bundle = m.compute_schedule(14.0, 56, 1).unwrap();
    assert_eq!(bundle.tree.node_count(), 9);
    assert!(bundle.tree.is_tree());
}

#[test]
fn mst_and_coloring_algorithm_choices_compose() {
    // every MST algorithm x coloring algorithm combination yields a valid
    // schedule and a complete gossip round
    for mst in MstAlgorithm::ALL {
        for coloring in ColoringAlgorithm::ALL {
            let cfg = ExperimentConfig {
                mst,
                coloring,
                latency_jitter: 0.0,
                topology: TopologyKind::ErdosRenyi,
                ..Default::default()
            };
            let session = GossipSession::new(&cfg).unwrap();
            assert!(session.tree().is_tree(), "{mst:?}/{coloring:?}");
            let ncolors = session.schedule().coloring.num_colors();
            assert!(
                session.schedule().coloring.is_proper(session.tree()),
                "{mst:?}/{coloring:?} improper"
            );
            // NOTE: the paper claims (§III-C) every algorithm 2-colors an
            // MST; that holds for BFS and DSatur (exact on bipartite
            // graphs) but NOT for degree-greedy WP/LDF, which can need 3+
            // colors on trees — see EXPERIMENTS.md §Deviations. The k-color
            // schedule still rotates correctly.
            if matches!(coloring, ColoringAlgorithm::Bfs | ColoringAlgorithm::DSatur) {
                assert!(ncolors <= 2, "{mst:?}/{coloring:?} used {ncolors}");
            }
            let m = session.run_mosgu_round(11.6, 1, 0.0);
            assert_eq!(m.transfer_count(), 90, "{mst:?}/{coloring:?}");
        }
    }
}

#[test]
fn sim_round_transfer_counts_match_logical_protocol() {
    // the timed driver must move exactly the copies the logical trace does
    use mosgu::coordinator::gossip::{run_logical_round, GossipState};
    let session = GossipSession::new(&cfg()).unwrap();
    let mut st = GossipState::new(session.tree().clone(), 0);
    let trace = run_logical_round(&mut st, session.schedule(), |_| 'x', 256);
    let logical_sends: usize = trace.slots.iter().map(|s| s.sends.len()).sum();
    let timed = session.run_mosgu_round(14.0, 1, 0.0);
    assert_eq!(timed.transfer_count(), logical_sends);
    assert_eq!(timed.slots, trace.slots.len());
}

#[test]
fn exchange_time_is_reached_within_first_two_slot_phases() {
    // every node sends its own model on its first active slot, so the
    // exchange phase ends within the first red+blue pair (plus tail)
    let session = GossipSession::new(&cfg()).unwrap();
    let m = session.run_mosgu_round(14.0, 1, 0.0);
    assert!(m.exchange_time_s > 0.0);
    assert!(
        m.exchange_time_s < m.total_time_s,
        "exchange {} should precede dissemination end {}",
        m.exchange_time_s,
        m.total_time_s
    );
}

#[test]
fn larger_networks_still_complete() {
    for n in [20usize, 50] {
        let c = ExperimentConfig { nodes: n, latency_jitter: 0.0, ..Default::default() };
        let session = GossipSession::new(&c).unwrap();
        let m = session.run_mosgu_round(5.0, 1, 0.0);
        assert_eq!(m.transfer_count(), n * (n - 1), "n={n}");
    }
}

#[test]
fn failure_probability_increases_transfers() {
    let session = GossipSession::new(&cfg()).unwrap();
    let clean = session.run_mosgu_round(5.0, 3, 0.0);
    let lossy = session.run_mosgu_round(5.0, 3, 0.25);
    assert!(lossy.transfer_count() > clean.transfer_count());
    assert!(lossy.total_time_s > clean.total_time_s);
}
