//! End-to-end experiment session: topology → testbed → moderator →
//! timed MOSGU round on the network simulator (and the broadcast
//! baseline), producing the paper's Tables III–V metrics.

use super::broadcast::{self, BroadcastMode};
use super::gossip::GossipState;
use super::moderator::{Moderator, ScheduleBundle};
use super::schedule::Schedule;
use crate::config::ExperimentConfig;
use crate::graph::topology::{self, TopologyKind};
use crate::graph::Graph;
use crate::metrics::RoundMetrics;
use crate::netsim::testbed::Testbed;
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};

/// Tag for gossip flow records (owner id of the carried model).
fn tag(owner: usize, from: usize) -> u64 {
    ((from as u64) << 32) | owner as u64
}

/// A fully prepared experiment: structural overlay, simulated testbed, and
/// the moderator's published schedule bundle.
pub struct GossipSession {
    cfg: ExperimentConfig,
    testbed: Testbed,
    structure: Graph,
    costs: Graph,
    bundle: ScheduleBundle,
}

impl GossipSession {
    /// Build the session: generate the underlay topology, stand up the
    /// testbed, run the paper's M-step (each node reports jittered pings to
    /// its neighbors; the moderator averages, prunes to the MST, colors and
    /// schedules).
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        Self::with_model(cfg, 14.0)
    }

    /// As [`GossipSession::new`] with an explicit model size (MB) for the
    /// slot-length computation.
    pub fn with_model(cfg: &ExperimentConfig, model_mb: f64) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow::anyhow!("invalid config: {e}"))?;
        let mut rng = Pcg64::new(cfg.seed);
        let structure = topology::generate(cfg.topology, cfg.nodes, &cfg.topology_params, &mut rng);
        let testbed = Testbed::new(cfg);
        let costs = testbed.overlay_costs(&structure);

        // M-step: directed per-node reports with measurement noise; the
        // moderator averages the two sides (§III-A).
        let mut moderator = Moderator::new(0, cfg.nodes, cfg.mst, cfg.coloring);
        let mut noise = rng.fork(0x4d0d);
        for u in 0..cfg.nodes {
            let peers: Vec<(usize, f64)> = costs
                .neighbors(u)
                .iter()
                .map(|&(v, w)| (v, w * (1.0 + noise.gen_f64_range(-0.02, 0.02))))
                .collect();
            moderator.submit_report(u, &peers);
        }
        let bundle = moderator
            .compute_schedule(model_mb, cfg.ping_size_bytes, 1)
            .context("moderator schedule computation")?
            .clone();
        Ok(GossipSession { cfg: cfg.clone(), testbed, structure, costs, bundle })
    }

    pub fn testbed(&self) -> &Testbed {
        &self.testbed
    }

    pub fn structure(&self) -> &Graph {
        &self.structure
    }

    pub fn costs(&self) -> &Graph {
        &self.costs
    }

    pub fn tree(&self) -> &Graph {
        &self.bundle.tree
    }

    pub fn schedule(&self) -> &Schedule {
        &self.bundle.schedule
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Run one timed MOSGU communication round: alternate color slots; in
    /// each slot every transmitting node pops its oldest queue entry and
    /// ships a copy to each addressed neighbor through the simulator; the
    /// next slot opens when the current slot's transfers complete (the
    /// formula slot length is the budget, not a busy-wait — see DESIGN.md).
    ///
    /// `failure_prob` injects per-transmission network disruptions: the
    /// flow's bytes are spent but nothing is delivered, and the entry is
    /// re-queued for the node's next turn (§III-D).
    pub fn run_mosgu_round(&self, model_mb: f64, seed: u64, failure_prob: f64) -> RoundMetrics {
        let mut sim = self.testbed.netsim(seed);
        let mut state = GossipState::new(self.bundle.tree.clone(), 0);
        let mut rng = Pcg64::new(seed ^ 0xfa11);
        let schedule = &self.bundle.schedule;
        let n = state.node_count();
        // generous guard: retransmissions can stretch the round
        let max_slots = 8 * n + 64;
        let mut slots_used = 0;

        for slot in 0..max_slots {
            if state.is_complete() {
                break;
            }
            slots_used = slot + 1;
            let transmitters = schedule.transmitters(slot);
            let planned = state.plan_slot(&transmitters);
            if planned.is_empty() {
                // idle color this slot; burn no simulated time beyond zero
                continue;
            }
            let slot_start = sim.now();
            let mut flow_meta = Vec::new(); // (tx index, recipient, flow id)
            for (i, tx) in planned.iter().enumerate() {
                for &to in &tx.recipients {
                    let f = sim.start_flow(
                        tx.from,
                        to,
                        self.testbed.route(tx.from, to),
                        model_mb,
                        tag(tx.entry.key.owner, tx.from),
                    );
                    flow_meta.push((i, to, f));
                }
            }
            sim.run_until_idle();
            // deliveries in deterministic (from, to) order
            let mut order: Vec<usize> = (0..flow_meta.len()).collect();
            order.sort_by_key(|&j| (planned[flow_meta[j].0].from, flow_meta[j].1));
            let mut failed = vec![false; planned.len()];
            for j in order {
                let (i, to, _) = flow_meta[j];
                if failure_prob > 0.0 && rng.gen_bool(failure_prob) {
                    failed[i] = true;
                    continue;
                }
                let tx = &planned[i];
                state.deliver(super::gossip::Send { from: tx.from, to, key: tx.entry.key });
            }
            for (i, tx) in planned.iter().enumerate() {
                if failed[i] {
                    state.requeue(tx);
                }
            }
            let _ = slot_start;
        }
        assert!(
            state.is_complete(),
            "MOSGU round did not complete within {max_slots} slots (failure_prob={failure_prob})"
        );
        let total = sim.now();
        let transfers = sim.take_completed();
        // Exchange phase: the last delivery of a node's *own* round-t update
        // (owner == sender). Forwarded copies pipeline with the next round.
        let exchange = transfers
            .iter()
            .filter(|r| broadcast::tag_owner(r.tag) == broadcast::tag_sender(r.tag))
            .map(|r| r.end)
            .fold(0.0, f64::max);
        RoundMetrics { transfers, total_time_s: total, exchange_time_s: exchange, slots: slots_used }
    }

    /// The paper's baseline on this testbed: all-to-all direct push on the
    /// complete overlay (the broadcast columns of Tables III–V are one set
    /// of values regardless of underlay rows).
    pub fn run_broadcast_round(&self, model_mb: f64, seed: u64) -> RoundMetrics {
        broadcast::paper_baseline(&self.testbed, model_mb, seed)
    }

    /// Flooding with relay on the session's structural overlay (ablation).
    pub fn run_flood_round(&self, model_mb: f64, seed: u64) -> RoundMetrics {
        broadcast::run_broadcast_round(
            &self.testbed,
            &self.structure,
            model_mb,
            BroadcastMode::Flood,
            seed,
        )
    }
}

/// Build one session per topology kind with a shared config template.
pub fn sessions_for_all_topologies(cfg: &ExperimentConfig) -> Result<Vec<(TopologyKind, GossipSession)>> {
    TopologyKind::ALL
        .iter()
        .map(|&kind| {
            let cfg = ExperimentConfig { topology: kind, ..cfg.clone() };
            Ok((kind, GossipSession::new(&cfg)?))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_cfg() -> ExperimentConfig {
        ExperimentConfig { latency_jitter: 0.0, ..Default::default() }
    }

    #[test]
    fn session_builds_for_every_topology() {
        let sessions = sessions_for_all_topologies(&quiet_cfg()).unwrap();
        assert_eq!(sessions.len(), 4);
        for (kind, s) in sessions {
            assert!(s.tree().is_tree(), "{kind:?}");
            assert!(s.schedule().coloring.is_proper(s.tree()), "{kind:?}");
            assert!(s.schedule().slot_len_s > 0.0);
        }
    }

    #[test]
    fn mosgu_round_disseminates_everything() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let m = s.run_mosgu_round(11.6, 1, 0.0);
        // tree dissemination: each of the 10 models crosses each of the 9
        // edges exactly once = 90 deliveries... but copies are per-edge
        // directionally: total transfers = sum over slots of copies = 90.
        assert_eq!(m.transfer_count(), 90);
        assert!(m.slots >= 10, "needs many alternating slots, got {}", m.slots);
        assert!(m.total_time_s > 0.0);
    }

    #[test]
    fn mosgu_beats_broadcast_on_bandwidth_and_total_time() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        for mb in [11.6, 48.0] {
            let g = s.run_mosgu_round(mb, 1, 0.0);
            let b = s.run_broadcast_round(mb, 1);
            assert!(
                g.bandwidth_mbps() > 2.0 * b.bandwidth_mbps(),
                "mb={mb}: gossip {} vs broadcast {}",
                g.bandwidth_mbps(),
                b.bandwidth_mbps()
            );
            assert!(
                g.avg_transfer_s() < b.avg_transfer_s(),
                "mb={mb}: transfer {} vs {}",
                g.avg_transfer_s(),
                b.avg_transfer_s()
            );
        }
    }

    #[test]
    fn failure_injection_still_completes_with_retransmission() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let clean = s.run_mosgu_round(5.0, 2, 0.0);
        let lossy = s.run_mosgu_round(5.0, 2, 0.15);
        assert!(lossy.slots >= clean.slots, "failures must not shorten the round");
        assert!(lossy.transfer_count() >= clean.transfer_count());
    }

    #[test]
    fn deterministic_given_seed() {
        let s = GossipSession::new(&quiet_cfg()).unwrap();
        let a = s.run_mosgu_round(14.0, 7, 0.0);
        let b = s.run_mosgu_round(14.0, 7, 0.0);
        assert!((a.total_time_s - b.total_time_s).abs() < 1e-12);
        assert_eq!(a.transfer_count(), b.transfer_count());
    }

    #[test]
    fn different_topologies_yield_different_trees() {
        let sessions = sessions_for_all_topologies(&quiet_cfg()).unwrap();
        let weights: Vec<f64> = sessions.iter().map(|(_, s)| s.tree().total_weight()).collect();
        // not all identical (complete vs sparse graphs prune differently)
        assert!(weights.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9), "{weights:?}");
    }
}
